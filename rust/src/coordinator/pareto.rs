//! Pareto-front extraction over the paper's three efficiency metrics, plus
//! the accuracy-extended frontier the autotuner surfaces.
//!
//! Tables 4/5 box the best configuration per row and per metric; the
//! frontier view asks the sharper question the Dustin-style comparisons
//! need: which (config, benchmark, variant) points are not dominated on
//! **all** of (Gflop/s, Gflop/s/W, Gflop/s/mm²) simultaneously. All three
//! metrics are maximized. Extraction is a pure function of the measurement
//! set and the report order is fully specified, so — with the simulator
//! deterministic and measurements cache-stable bit-for-bit — `transpfp
//! pareto` output is identical across runs, warm or cold.
//!
//! The **accuracy-extended** frontier (`transpfp pareto --acc`) swaps area
//! efficiency for numerical error and spans the full five-rung precision
//! ladder: a point survives if no other point is at least as good on
//! (error↓, Gflop/s↑, Gflop/s/W↑) and strictly better on one — the
//! error/efficiency trade-off curve of the transprecision claim (§2).

use super::query::{points, QueryEngine, QueryFailure};
use super::sweep::Measurement;
use crate::config::ClusterConfig;
use crate::kernels::{Benchmark, Variant};
use crate::report::Table;
use crate::tuner::ladder::LADDER;

/// The maximized objective triple of a measurement:
/// (perf Gflop/s @ST, energy eff Gflop/s/W @NT, area eff Gflop/s/mm² @ST).
pub fn objectives(m: &Measurement) -> [f64; 3] {
    [m.metrics.perf_gflops, m.metrics.energy_eff, m.metrics.area_eff]
}

/// True if `a` Pareto-dominates `b`: at least as good on every objective
/// and strictly better on at least one. Ties on every objective (duplicate
/// points) dominate in neither direction.
pub fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    a.iter().zip(b).all(|(x, y)| x >= y) && a.iter().zip(b).any(|(x, y)| x > y)
}

/// Indices of the non-dominated points of `pts`, in input order. Exact
/// duplicates are all retained (each is non-dominated); a single point is
/// its own frontier.
pub fn pareto_front_indices(pts: &[[f64; 3]]) -> Vec<usize> {
    (0..pts.len())
        .filter(|&i| !pts.iter().enumerate().any(|(j, q)| j != i && dominates(q, &pts[i])))
        .collect()
}

/// The non-dominated measurements of `ms`, sorted for reporting: best
/// performance first, exact ties broken by (config, bench, variant) so the
/// order is total and reproducible.
pub fn pareto_front(ms: &[Measurement]) -> Vec<Measurement> {
    let pts: Vec<[f64; 3]> = ms.iter().map(objectives).collect();
    let mut front: Vec<Measurement> =
        pareto_front_indices(&pts).into_iter().map(|i| ms[i].clone()).collect();
    front.sort_by(|a, b| {
        b.metrics
            .perf_gflops
            .partial_cmp(&a.metrics.perf_gflops)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.cfg.mnemonic().cmp(&b.cfg.mnemonic()))
            .then_with(|| a.bench.name().cmp(b.bench.name()))
            .then_with(|| a.variant.label().cmp(b.variant.label()))
    });
    front
}

/// Render the frontier of `ms` as a report table.
pub fn pareto_table_from(ms: &[Measurement]) -> Table {
    let mut t = Table::new(vec![
        "config",
        "bench",
        "variant",
        "perf (Gflop/s)",
        "e.eff (Gflop/s/W)",
        "a.eff (Gflop/s/mm^2)",
        "cycles",
    ]);
    for m in pareto_front(ms) {
        t.row(vec![
            m.cfg.mnemonic(),
            m.bench.name().to_string(),
            m.variant.label().to_string(),
            format!("{:.3}", m.metrics.perf_gflops),
            format!("{:.3}", m.metrics.energy_eff),
            format!("{:.3}", m.metrics.area_eff),
            m.cycles.to_string(),
        ]);
    }
    t
}

/// `transpfp pareto`: the frontier of the full 18×8×2 design space,
/// resolved through `engine`'s measurement cache (the CLI passes
/// [`QueryEngine::global()`]).
pub fn pareto_table(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let pts = points(
        &ClusterConfig::design_space(),
        &Benchmark::all(),
        &[Variant::Scalar, Variant::VEC],
    );
    Ok(pareto_table_from(&engine.query(&pts)?))
}

// ------------------------------------------- accuracy-extended frontier

/// The accuracy-extended objective triple, all maximized: (−relative L2
/// error, perf Gflop/s @ST, energy eff Gflop/s/W @NT). Negating the error
/// lets the standard max-dominance test drive "lower error is better".
pub fn acc_objectives(m: &Measurement) -> [f64; 3] {
    [-m.err.rel, m.metrics.perf_gflops, m.metrics.energy_eff]
}

/// Non-dominated measurements over (error↓, perf↑, e.eff↑), sorted for
/// reporting: lowest error first, ties by descending performance, then by
/// (config, bench, variant) so the order is total and reproducible.
///
/// Unverified measurements are excluded up front — a run that diverged
/// from its bit-exact host mirror is known-untrustworthy, so its error
/// figure must neither appear on nor dominate the frontier (the same
/// admissibility rule the tuner applies).
pub fn accuracy_pareto_front(ms: &[Measurement]) -> Vec<Measurement> {
    let ms: Vec<&Measurement> = ms.iter().filter(|m| m.verified).collect();
    let pts: Vec<[f64; 3]> = ms.iter().map(|m| acc_objectives(m)).collect();
    let mut front: Vec<Measurement> =
        pareto_front_indices(&pts).into_iter().map(|i| ms[i].clone()).collect();
    front.sort_by(|a, b| {
        a.err
            .rel
            .partial_cmp(&b.err.rel)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| {
                b.metrics
                    .perf_gflops
                    .partial_cmp(&a.metrics.perf_gflops)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .then_with(|| a.cfg.mnemonic().cmp(&b.cfg.mnemonic()))
            .then_with(|| a.bench.name().cmp(b.bench.name()))
            .then_with(|| a.variant.label().cmp(b.variant.label()))
    });
    front
}

/// Render the accuracy-extended frontier of `ms` as a report table.
pub fn accuracy_pareto_table_from(ms: &[Measurement]) -> Table {
    let mut t = Table::new(vec![
        "config",
        "bench",
        "variant",
        "rel_err",
        "perf (Gflop/s)",
        "e.eff (Gflop/s/W)",
        "cycles",
    ]);
    for m in accuracy_pareto_front(ms) {
        t.row(vec![
            m.cfg.mnemonic(),
            m.bench.name().to_string(),
            m.variant.label().to_string(),
            format!("{:.3e}", m.err.rel),
            format!("{:.3}", m.metrics.perf_gflops),
            format!("{:.3}", m.metrics.energy_eff),
            m.cycles.to_string(),
        ]);
    }
    t
}

/// `transpfp pareto --acc`: the accuracy-extended frontier of the full
/// design space crossed with the five-rung precision ladder, resolved
/// through `engine`'s measurement cache (the CLI passes
/// [`QueryEngine::global()`]).
pub fn accuracy_pareto_table(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let pts = points(&ClusterConfig::design_space(), &Benchmark::all(), &LADDER);
    Ok(accuracy_pareto_table_from(&engine.query(&pts)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::counters::CoreCounters;
    use crate::model::Metrics;

    /// Synthetic measurement with the given objective triple.
    fn mk(perf: f64, eeff: f64, aeff: f64) -> Measurement {
        mk_err(perf, eeff, aeff, 0.0)
    }

    /// [`mk`] with an explicit relative error (accuracy-frontier tests).
    fn mk_err(perf: f64, eeff: f64, aeff: f64, rel: f64) -> Measurement {
        Measurement {
            cfg: ClusterConfig::new(8, 4, 1),
            bench: Benchmark::Fir,
            variant: Variant::Scalar,
            workers: 8,
            metrics: Metrics {
                perf_gflops: perf,
                energy_eff: eeff,
                area_eff: aeff,
                flops_per_cycle: 1.0,
            },
            cycles: 100,
            core_cycles: 800,
            agg: CoreCounters::default(),
            fp_intensity: 0.3,
            mem_intensity: 0.5,
            verified: true,
            err: crate::tuner::accuracy::ErrorStats { max_abs: rel, rms: rel, rel },
        }
    }

    #[test]
    fn dominance_rules() {
        assert!(dominates(&[2.0, 2.0, 2.0], &[1.0, 1.0, 1.0]));
        // Weakly better everywhere + strictly on one axis dominates.
        assert!(dominates(&[1.0, 1.0, 2.0], &[1.0, 1.0, 1.0]));
        // Equal triples dominate in neither direction.
        assert!(!dominates(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]));
        // Trade-offs dominate in neither direction.
        assert!(!dominates(&[2.0, 0.5, 1.0], &[1.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0, 1.0], &[2.0, 0.5, 1.0]));
    }

    #[test]
    fn interior_points_are_dropped() {
        let pts = [[3.0, 1.0, 1.0], [1.0, 3.0, 1.0], [2.0, 2.0, 0.5], [1.0, 1.0, 0.5]];
        // The last point is dominated by every other; the rest trade off.
        assert_eq!(pareto_front_indices(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn single_point_space_is_its_own_frontier() {
        assert_eq!(pareto_front_indices(&[[1.0, 2.0, 3.0]]), vec![0]);
        assert!(pareto_front_indices(&[]).is_empty());
        let front = pareto_front(&[mk(1.0, 2.0, 3.0)]);
        assert_eq!(front.len(), 1);
    }

    #[test]
    fn duplicate_points_are_all_retained() {
        let pts = [[2.0, 2.0, 2.0], [2.0, 2.0, 2.0], [1.0, 1.0, 1.0]];
        assert_eq!(pareto_front_indices(&pts), vec![0, 1]);
        let front = pareto_front(&[mk(2.0, 2.0, 2.0), mk(2.0, 2.0, 2.0), mk(1.0, 1.0, 1.0)]);
        assert_eq!(front.len(), 2);
    }

    #[test]
    fn ties_on_one_metric_keep_both_tradeoffs() {
        // Same perf, opposite trade on the other two axes: both survive.
        let pts = [[5.0, 3.0, 1.0], [5.0, 1.0, 3.0]];
        assert_eq!(pareto_front_indices(&pts), vec![0, 1]);
        // Same perf and energy, one strictly better on area: dominated.
        let pts = [[5.0, 3.0, 1.0], [5.0, 3.0, 2.0]];
        assert_eq!(pareto_front_indices(&pts), vec![1]);
    }

    #[test]
    fn accuracy_frontier_trades_error_for_efficiency() {
        // (rel_err, perf, eeff): the exact-but-slow point, the cheap-but-
        // noisy point, and a mid trade-off all survive; a point that is
        // both noisier and slower than another is dropped.
        let ms = [
            mk_err(1.0, 50.0, 1.0, 1e-7),  // precise baseline
            mk_err(2.0, 80.0, 1.0, 1e-3),  // mid rung
            mk_err(3.0, 120.0, 1.0, 5e-3), // cheap rung
            mk_err(1.5, 60.0, 1.0, 2e-2),  // dominated: worse error, slower than the cheap rung
        ];
        let front = accuracy_pareto_front(&ms);
        assert_eq!(front.len(), 3);
        // Sorted by ascending error.
        assert!(front.windows(2).all(|w| w[0].err.rel <= w[1].err.rel));
        assert!(front.iter().all(|m| m.err.rel < 2e-2));
        // Rendered table is deterministic.
        let a = accuracy_pareto_table_from(&ms).to_csv();
        assert_eq!(a, accuracy_pareto_table_from(&ms).to_csv());
        assert!(a.starts_with("config,bench,variant,rel_err,"));
        // An unverified point can neither join nor dominate the frontier,
        // no matter how good its figures claim to be.
        let mut broken = mk_err(100.0, 999.0, 1.0, 0.0);
        broken.verified = false;
        let mut with_broken = ms.to_vec();
        with_broken.push(broken);
        let front2 = accuracy_pareto_front(&with_broken);
        assert_eq!(front2.len(), 3, "unverified point must be excluded");
        assert!(front2.iter().all(|m| m.verified));
    }

    #[test]
    fn report_is_deterministic_and_sorted() {
        let ms = [mk(1.0, 9.0, 1.0), mk(3.0, 1.0, 1.0), mk(2.0, 2.0, 2.0), mk(0.5, 0.5, 0.5)];
        let a = pareto_table_from(&ms);
        let b = pareto_table_from(&ms);
        assert_eq!(a.to_csv(), b.to_csv());
        let csv = a.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 3, "dominated point must be absent");
        // Sorted by descending performance.
        assert!(rows[0].contains("3.000"));
        assert!(rows[1].contains("2.000"));
    }
}
