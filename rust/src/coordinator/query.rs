//! Design-space query planner.
//!
//! A [`QueryEngine`] accepts arbitrary batches of [`QueryPoint`]s (from the
//! `table*`/`fig*` emitters, the CLI `sweep`, or the `query`/`pareto`
//! subcommands), deduplicates them, partitions them into cache hits and
//! misses against its [`MeasurementCache`], and drives **only the misses**
//! through the lock-free parallel sweep workers
//! ([`crate::coordinator::sweep::run_parallel`]). Results come back in
//! request order, so callers see the exact contract of the old direct-run
//! paths — just without re-simulating points any previous query resolved.
//!
//! Planning (workload build + fingerprint + lookup) is separated from
//! execution so callers can inspect the partition (`transpfp query` prints
//! it) and tests can assert "a warm table issues zero simulator runs".
//!
//! Execution is **batched across concurrent calls**: each call's led misses
//! become jobs in a shared planner queue, and a single *drain leader*
//! executes the whole queue as one worker-pool pass — so 64 concurrent
//! *distinct* cold requests cost one or two planner passes instead of 64
//! independent pool spin-ups. The `batched_requests` / `batched_points` /
//! `planner_passes` counters expose this to the service's `stats` endpoint
//! and the serve bench gates.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::cache::{CacheKey, CacheStats, Fidelity, MeasurementCache, CACHE_FILE};
use super::flight::{Begin, FlightSlot, LeaderPoisoned, SingleFlight};
use super::sweep::{
    run_one_at, run_one_compiled_at, run_one_functional_at, run_parallel, run_parallel_reported,
    run_workload, run_workload_compiled, run_workload_functional, Measurement,
};
use crate::cluster::{CodeCache, RunError};
use crate::config::ClusterConfig;
use crate::kernels::{Benchmark, Variant, Workload};

/// One point of the design space to resolve: a (config, bench, variant)
/// triple at a team occupancy and an execution [`Fidelity`]. Occupancy is
/// part of the point (and the cache address) since the fig 5/6 emitters
/// went through the engine — `workers == cfg.cores` for every full-cluster
/// table. Fidelity selects the backend tier: accuracy-only plans run on
/// the functional backend and never touch the event engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QueryPoint {
    pub cfg: ClusterConfig,
    pub bench: Benchmark,
    pub variant: Variant,
    /// Active team size (1..=cfg.cores).
    pub workers: usize,
    /// Backend tier the point resolves on (cycle-accurate by default).
    pub fidelity: Fidelity,
    /// Resolve an accuracy-only point on the compiled tier instead of the
    /// functional interpreter. Only meaningful with
    /// [`Fidelity::Functional`]. Deliberately **not** part of the cache
    /// address: the four-way differential wall makes the two tiers
    /// bit-identical, so they share one cache entry — the flag only picks
    /// which engine executes a miss.
    pub compiled: bool,
}

impl QueryPoint {
    /// Full-occupancy cycle-accurate point for (`cfg`, `bench`, `variant`).
    pub fn new(cfg: &ClusterConfig, bench: Benchmark, variant: Variant) -> Self {
        Self::at(cfg, bench, variant, cfg.cores)
    }

    /// Cycle-accurate point under a `workers`-core team (fig 5/6 sweeps).
    pub fn at(cfg: &ClusterConfig, bench: Benchmark, variant: Variant, workers: usize) -> Self {
        assert!(workers >= 1 && workers <= cfg.cores, "occupancy out of range");
        QueryPoint {
            cfg: *cfg,
            bench,
            variant,
            workers,
            fidelity: Fidelity::CycleAccurate,
            compiled: false,
        }
    }

    /// Full-occupancy accuracy-only point (functional backend).
    pub fn functional(cfg: &ClusterConfig, bench: Benchmark, variant: Variant) -> Self {
        Self::new(cfg, bench, variant).with_fidelity(Fidelity::Functional)
    }

    /// The same point at a different fidelity.
    pub fn with_fidelity(mut self, fidelity: Fidelity) -> Self {
        self.fidelity = fidelity;
        self
    }

    /// The same accuracy-only point, executed on the compiled tier. Forces
    /// [`Fidelity::Functional`] — compilation never changes what is
    /// measured, only how fast the measurement runs.
    pub fn with_compiled(mut self) -> Self {
        self.fidelity = Fidelity::Functional;
        self.compiled = true;
        self
    }
}

/// One unresolvable point of a batch: the point plus the structured
/// execution error (hang, deadlock, architectural fault, or a quarantined
/// worker panic folded into [`RunError::Fault`]).
#[derive(Debug, Clone)]
pub struct QueryError {
    pub point: QueryPoint,
    pub error: RunError,
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let p = &self.point;
        write!(
            f,
            "{}/{} on {} @{} workers [{}]: {}",
            p.bench.name(),
            p.variant.label(),
            p.cfg,
            p.workers,
            p.fidelity.tag(),
            self.error
        )
    }
}

/// Structured report of a batch that could not fully resolve. Every point
/// that *did* resolve was already inserted into the cache before this was
/// returned, so a retry after fixing the bad points re-simulates nothing.
#[derive(Debug, Clone)]
pub struct QueryFailure {
    /// The unresolvable points: this call's own (led) misses first in plan
    /// order, then any failures inherited from flights it coalesced onto.
    pub errors: Vec<QueryError>,
    /// Points requested (including duplicates).
    pub requested: usize,
    /// Distinct points that resolved (cache hit or successful run).
    pub resolved: usize,
}

impl std::fmt::Display for QueryFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "query failed: {} of {} distinct point(s) unresolved ({} requested)",
            self.errors.len(),
            self.resolved + self.errors.len(),
            self.requested
        )?;
        for e in &self.errors {
            writeln!(f, "  - {e}")?;
        }
        write!(f, "resolved points were cached; rerun after fixing the points above")
    }
}

impl std::error::Error for QueryFailure {}

/// Cartesian product of configs × benches × variants, in the deterministic
/// (config, bench, variant) nesting every sweep and table uses.
pub fn points(
    configs: &[ClusterConfig],
    benches: &[Benchmark],
    variants: &[Variant],
) -> Vec<QueryPoint> {
    let mut pts = Vec::with_capacity(configs.len() * benches.len() * variants.len());
    for cfg in configs {
        for b in benches {
            for v in variants {
                pts.push(QueryPoint::new(cfg, *b, *v));
            }
        }
    }
    pts
}

/// A unique point with its content address and resolution state.
struct PlannedPoint {
    point: QueryPoint,
    key: CacheKey,
    /// Cache hit at plan time, or the result once executed.
    resolved: Option<Measurement>,
    /// Prebuilt workload, kept only for misses (it is rebuilt work the
    /// runner would otherwise redo — the program was already needed for the
    /// fingerprint).
    workload: Option<Workload>,
}

/// A batch partitioned against the cache, ready to execute.
pub struct QueryPlan {
    unique: Vec<PlannedPoint>,
    /// Input index → unique index (duplicates collapse onto one entry).
    order: Vec<usize>,
}

impl QueryPlan {
    /// Number of requested points (including duplicates).
    pub fn len(&self) -> usize {
        self.order.len()
    }

    /// True if the batch was empty.
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Number of distinct points after deduplication.
    pub fn unique_len(&self) -> usize {
        self.unique.len()
    }

    /// Distinct points already resolved by the cache.
    pub fn hit_count(&self) -> usize {
        self.unique.iter().filter(|p| p.resolved.is_some()).count()
    }

    /// Distinct points that will be simulated.
    pub fn miss_count(&self) -> usize {
        self.unique.len() - self.hit_count()
    }
}

/// One led miss traveling through the planner queue: the point, its
/// prebuilt workload (when the plan kept one), and the free-standing
/// [`FlightSlot`] the enqueuing call waits on. Whole batches of these are
/// executed by whichever call is the drain leader when they land.
struct BatchJob {
    point: QueryPoint,
    workload: Option<Workload>,
    slot: Arc<FlightSlot<FlightResult>>,
}

/// The batch planner's shared miss queue.
#[derive(Default)]
struct PlannerQueue {
    jobs: Vec<BatchJob>,
    /// True while some call is the drain leader. Read and written only
    /// under the queue lock, so enqueue-vs-exit races are impossible: a
    /// leader clears it only after observing the queue empty under the
    /// lock, and a call that observes it set is guaranteed its jobs will
    /// be taken by that leader's next pass.
    draining: bool,
}

/// Memoizing front-end to the sweep workers.
#[derive(Default)]
pub struct QueryEngine {
    cache: MeasurementCache,
    /// Workload fingerprints already computed this process, keyed by the
    /// workload identity (config × bench × variant — occupancy and
    /// fidelity do not change the program or its data, so all occupancies
    /// and both fidelities share one memo entry). Builders are
    /// deterministic and the builder code cannot change within a process,
    /// so a memoized fingerprint lets warm plans form cache keys without
    /// rebuilding (and re-hashing) the workload at all. Deliberately *not*
    /// persisted: a fresh process must rebuild workloads once to prove the
    /// persisted entries still match the current code.
    fingerprints: Mutex<HashMap<(ClusterConfig, Benchmark, Variant), u64>>,
    /// Cycle-accurate simulator executions this engine has issued (cache
    /// misses at [`Fidelity::CycleAccurate`]). The bench gates assert a
    /// warm tune issues zero of these for accuracy-rejected rungs.
    sim_runs: AtomicU64,
    /// Functional-backend executions this engine has issued.
    functional_runs: AtomicU64,
    /// Compiled-tier executions this engine has issued (accuracy-only
    /// misses carrying [`QueryPoint::compiled`]).
    compiled_runs: AtomicU64,
    /// Translation cache the engine's compiled-tier runs share: one
    /// translation per distinct program fingerprint for the engine's whole
    /// lifetime, however many probes and sweeps re-run it. `Arc` because
    /// each compiled run constructs a short-lived
    /// [`crate::cluster::CompiledBackend`] around it.
    code_cache: Arc<CodeCache>,
    /// In-flight table: identical concurrent misses coalesce onto one run.
    flight: SingleFlight<CacheKey, FlightResult>,
    /// Every key this engine has ever led a run for. `sim_runs +
    /// functional_runs + compiled_runs` minus this set's size is the
    /// duplicate-run count the service gates at zero.
    executed: Mutex<HashSet<CacheKey>>,
    /// Misses resolved by another in-flight (or just-published) run
    /// instead of a simulator execution of their own.
    coalesced: AtomicU64,
    /// Shared miss queue for the batch planner: concurrent calls' led
    /// misses pile in here and a single drain leader executes each take
    /// as one deduplicated worker-pool pass.
    planner: Mutex<PlannerQueue>,
    /// Calls whose led misses joined another call's in-flight drain.
    batched_requests: AtomicU64,
    /// Led misses that joined another call's in-flight drain.
    batched_points: AtomicU64,
    /// Worker-pool drains executed (one per non-empty queue take).
    planner_passes: AtomicU64,
}

/// What a flight leader hands its followers: the run's outcome, cloneable
/// so every waiter gets its own copy.
type FlightResult = Result<Measurement, RunError>;

impl QueryEngine {
    /// Engine with an empty in-memory cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine wrapping a pre-populated cache (e.g. loaded from disk).
    pub fn with_cache(cache: MeasurementCache) -> Self {
        QueryEngine { cache, ..Default::default() }
    }

    /// The engine's cache (for persistence and stats).
    pub fn cache(&self) -> &MeasurementCache {
        &self.cache
    }

    /// Cache statistics snapshot.
    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Cycle-accurate simulator executions issued so far.
    pub fn sim_runs(&self) -> u64 {
        self.sim_runs.load(Ordering::Relaxed)
    }

    /// Functional-backend executions issued so far.
    pub fn functional_runs(&self) -> u64 {
        self.functional_runs.load(Ordering::Relaxed)
    }

    /// Compiled-tier executions issued so far.
    pub fn compiled_runs(&self) -> u64 {
        self.compiled_runs.load(Ordering::Relaxed)
    }

    /// The engine's translation cache (hit/miss counters for the warm-tune
    /// economics gates; the service's status endpoint reports them).
    pub fn code_cache(&self) -> &Arc<CodeCache> {
        &self.code_cache
    }

    /// Misses resolved by coalescing onto another caller's in-flight run
    /// (or onto a result that landed between plan and execute) instead of
    /// issuing a simulator execution of their own.
    pub fn coalesced_runs(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// Calls whose led misses were executed by another call's planner
    /// drain instead of spinning up a worker pool of their own.
    pub fn batched_requests(&self) -> u64 {
        self.batched_requests.load(Ordering::Relaxed)
    }

    /// Led misses executed by another call's planner drain.
    pub fn batched_points(&self) -> u64 {
        self.batched_points.load(Ordering::Relaxed)
    }

    /// Worker-pool drains executed by the batch planner (one per
    /// non-empty queue take). The serve bench gates 64 concurrent
    /// distinct cold requests at ≤ 2 of these.
    pub fn planner_passes(&self) -> u64 {
        self.planner_passes.load(Ordering::Relaxed)
    }

    /// Executions issued beyond one per distinct point — the service's
    /// zero-duplicate-runs gate. Single-flight keeps this at 0 no matter
    /// how many concurrent identical requests arrive.
    pub fn duplicate_runs(&self) -> u64 {
        let distinct = self.executed.lock().unwrap().len() as u64;
        (self.sim_runs() + self.functional_runs() + self.compiled_runs())
            .saturating_sub(distinct)
    }

    /// The process-wide engine the CLI and the public table emitters share.
    /// Tests that assert on hit/miss counts should construct their own
    /// engine instead — this one's counters are shared state.
    pub fn global() -> &'static QueryEngine {
        static GLOBAL: OnceLock<QueryEngine> = OnceLock::new();
        GLOBAL.get_or_init(QueryEngine::new)
    }

    /// Deduplicate `pts` and partition them into cache hits and misses.
    /// Unique points are planned on the parallel worker pool: a cold plan's
    /// workload builds (input staging + host goldens) don't serialize, and
    /// a point whose fingerprint is already memoized skips the build
    /// entirely.
    pub fn plan(&self, pts: &[QueryPoint]) -> QueryPlan {
        let mut index: HashMap<QueryPoint, usize> = HashMap::with_capacity(pts.len());
        let mut uniq: Vec<QueryPoint> = Vec::new();
        let mut order = Vec::with_capacity(pts.len());
        for p in pts {
            let ui = *index.entry(*p).or_insert_with(|| {
                uniq.push(*p);
                uniq.len() - 1
            });
            order.push(ui);
        }
        let unique = run_parallel(&uniq, |p| self.plan_point(p));
        QueryPlan { unique, order }
    }

    /// Content address of a point given its workload fingerprint.
    fn key_for(&self, p: &QueryPoint, fp: u64) -> CacheKey {
        CacheKey::with_fingerprint(&p.cfg, p.bench, p.variant, p.workers, p.fidelity, fp)
    }

    /// Resolve one unique point against the fingerprint memo and the cache.
    fn plan_point(&self, p: &QueryPoint) -> PlannedPoint {
        let memo_key = (p.cfg, p.bench, p.variant);
        let memoized = self.fingerprints.lock().unwrap().get(&memo_key).copied();
        let (key, workload) = match memoized {
            Some(fp) => (self.key_for(p, fp), None),
            None => {
                let w = p.bench.build(p.variant, &p.cfg);
                let fp = super::cache::workload_fingerprint(&w);
                self.fingerprints.lock().unwrap().insert(memo_key, fp);
                (self.key_for(p, fp), Some(w))
            }
        };
        let resolved = self.cache.lookup(&key);
        let workload = if resolved.is_none() { workload } else { None };
        PlannedPoint { point: *p, key, resolved, workload }
    }

    /// Simulate the plan's misses in parallel, populate the cache, and
    /// return one measurement per requested point, in request order.
    ///
    /// Misses go through the engine's single-flight table first: if another
    /// caller is already simulating the same point, this call **follows**
    /// that flight instead of re-running it; if the point's result landed in
    /// the cache since planning, it resolves immediately. Only the points
    /// this call *leads* are batched into the worker pool — which is how 64
    /// concurrent identical cold requests cost exactly one simulator run.
    ///
    /// Led misses become jobs in the engine's shared **planner queue**: if
    /// another call is already draining the queue, this call's jobs join
    /// that drain (counted in `batched_requests`/`batched_points`) and it
    /// simply waits on their slots; otherwise this call becomes the drain
    /// leader and executes the whole queue — its own jobs plus any that
    /// concurrent calls pile in during the settle window — as one
    /// deduplicated worker-pool pass per take.
    ///
    /// Jobs run under `catch_unwind` in the worker pool: a point that
    /// hangs, deadlocks, faults, or outright panics is collected into the
    /// [`QueryFailure`] report while every *other* miss still completes
    /// **and is cached** before the error returns — a retry after fixing
    /// the bad points re-simulates nothing. Every led flight is published
    /// (success *or* failure), and each lead's [`LeadGuard`] poisons its
    /// flight if this thread unwinds first — so followers never block on a
    /// dead leader.
    ///
    /// [`LeadGuard`]: super::flight::LeadGuard
    pub fn execute(&self, plan: QueryPlan) -> Result<Vec<Measurement>, QueryFailure> {
        let QueryPlan { mut unique, order } = plan;
        let requested = order.len();
        // Partition the plan's misses through the flight table. Each led
        // miss keeps its [`LeadGuard`]: if this thread unwinds before the
        // publish loop below runs, the guards' drops poison the flights so
        // followers in other calls are released instead of hanging.
        let mut leads: Vec<(usize, super::flight::LeadGuard<'_, CacheKey, FlightResult>)> =
            Vec::new();
        let mut follows: Vec<(usize, Arc<FlightSlot<FlightResult>>)> = Vec::new();
        for (i, pp) in unique.iter_mut().enumerate() {
            if pp.resolved.is_some() {
                continue;
            }
            let key = pp.key;
            match self.flight.begin(&key, || self.cache.peek(&key).map(Ok)) {
                Begin::Lead(guard) => leads.push((i, guard)),
                Begin::Follow(slot) => follows.push((i, slot)),
                Begin::Resolved(Ok(m)) => {
                    self.coalesced.fetch_add(1, Ordering::Relaxed);
                    pp.resolved = Some(m);
                    pp.workload = None;
                }
                // peek() only yields successes; named for totality.
                Begin::Resolved(Err(_)) => unreachable!("cache peek cannot fail"),
            }
        }
        let mut errors: Vec<QueryError> = Vec::new();
        if !leads.is_empty() {
            // One batch job per led miss. A miss planned via the fingerprint
            // memo has no prebuilt workload; its worker rebuilds it (the
            // build is deterministic). The job owns the workload and a
            // free-standing result slot, so it can travel into another
            // call's drain while this call keeps only the slot handle.
            let mut jobs: Vec<BatchJob> = Vec::with_capacity(leads.len());
            let mut slots: Vec<Arc<FlightSlot<FlightResult>>> = Vec::with_capacity(leads.len());
            for &(i, _) in &leads {
                let slot = Arc::new(FlightSlot::new());
                jobs.push(BatchJob {
                    point: unique[i].point,
                    workload: unique[i].workload.take(),
                    slot: Arc::clone(&slot),
                });
                slots.push(slot);
            }
            let enqueued = jobs.len() as u64;
            let lead_drain = {
                let mut q = self.planner.lock().unwrap();
                let joined = q.draining;
                q.jobs.append(&mut jobs);
                if joined {
                    self.batched_requests.fetch_add(1, Ordering::Relaxed);
                    self.batched_points.fetch_add(enqueued, Ordering::Relaxed);
                } else {
                    q.draining = true;
                }
                !joined
            };
            if lead_drain {
                self.drain_planner();
            }
            // Collect this call's own outcomes and close its flights.
            for ((i, guard), slot) in leads.into_iter().zip(slots) {
                let key = unique[i].key;
                let outcome: FlightResult = match slot.wait() {
                    Ok(r) => r,
                    // Job slots are fulfilled, never poisoned; named for
                    // totality (and for robustness if that ever changes).
                    Err(LeaderPoisoned) => Err(RunError::Fault(
                        "batch drain leader panicked before fulfilling".into(),
                    )),
                };
                self.executed.lock().unwrap().insert(key);
                match &outcome {
                    Ok(m) => {
                        self.cache.insert(key, m.clone());
                        unique[i].resolved = Some(m.clone());
                    }
                    Err(e) => {
                        errors.push(QueryError { point: unique[i].point, error: e.clone() });
                    }
                }
                // Publish *after* the cache insert, so anyone who observes
                // the closed flight finds the value; and publish failures
                // too, so followers inherit the structured error instead of
                // blocking forever.
                guard.publish(outcome);
            }
        }
        // Collect followed flights only after this call's own leads have
        // published — two calls leading disjoint halves of the same batch
        // can therefore never deadlock on each other.
        for (i, slot) in follows {
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            match slot.wait() {
                Ok(Ok(m)) => {
                    unique[i].resolved = Some(m);
                    unique[i].workload = None;
                }
                Ok(Err(e)) => errors.push(QueryError { point: unique[i].point, error: e }),
                // The leader panicked before publishing: the guard's drop
                // released this wait with poison — fold it into the same
                // structured-error channel a worker panic uses.
                Err(LeaderPoisoned) => errors.push(QueryError {
                    point: unique[i].point,
                    error: RunError::Fault("flight leader panicked before publishing".into()),
                }),
            }
        }
        if !errors.is_empty() {
            let resolved = unique.iter().filter(|pp| pp.resolved.is_some()).count();
            return Err(QueryFailure { errors, requested, resolved });
        }
        Ok(order
            .into_iter()
            .map(|ui| unique[ui].resolved.clone().expect("point resolved"))
            .collect())
    }

    /// Execute one batch job on the tier its point selects, bumping the
    /// engine's per-tier run counter.
    fn run_job(&self, job: &BatchJob) -> Result<Measurement, RunError> {
        let p = &job.point;
        let w = job.workload.as_ref();
        match p.fidelity {
            Fidelity::CycleAccurate => {
                self.sim_runs.fetch_add(1, Ordering::Relaxed);
                match w {
                    Some(w) => run_workload(&p.cfg, p.bench, p.variant, p.workers, w),
                    None => run_one_at(&p.cfg, p.bench, p.variant, p.workers),
                }
            }
            Fidelity::Functional if p.compiled => {
                self.compiled_runs.fetch_add(1, Ordering::Relaxed);
                match w {
                    Some(w) => run_workload_compiled(
                        &p.cfg,
                        p.bench,
                        p.variant,
                        p.workers,
                        w,
                        &self.code_cache,
                    ),
                    None => run_one_compiled_at(
                        &p.cfg,
                        p.bench,
                        p.variant,
                        p.workers,
                        &self.code_cache,
                    ),
                }
            }
            Fidelity::Functional => {
                self.functional_runs.fetch_add(1, Ordering::Relaxed);
                match w {
                    Some(w) => run_workload_functional(&p.cfg, p.bench, p.variant, p.workers, w),
                    None => run_one_functional_at(&p.cfg, p.bench, p.variant, p.workers),
                }
            }
        }
    }

    /// Drain the shared planner queue as its leader: repeatedly take every
    /// queued job and execute the whole take as **one** worker-pool pass,
    /// fulfilling each job's slot with its outcome. Before each take, a
    /// short settle window (the queue must be observed unchanged twice,
    /// bounded at ~50 ms) lets concurrently arriving requests pile their
    /// misses into the same pass — this is what turns 64 concurrent
    /// distinct cold requests into one or two planner passes instead of
    /// 64 pool spin-ups; a lone sequential miss pays ~1 ms.
    ///
    /// The caller must have set `draining` under the planner lock. This
    /// function clears it (under the same lock) only after observing the
    /// queue empty, so a call that saw `draining` set is guaranteed its
    /// jobs are taken by a later pass of this drain. If the leader unwinds
    /// mid-drain, the obligation guard releases leadership and fails every
    /// still-queued job — mirroring [`LeadGuard`]'s poison-on-drop, no
    /// requester is ever left parked on an unfulfilled slot.
    ///
    /// [`LeadGuard`]: super::flight::LeadGuard
    fn drain_planner(&self) {
        struct DrainObligation<'e> {
            engine: &'e QueryEngine,
            done: bool,
        }
        impl Drop for DrainObligation<'_> {
            fn drop(&mut self) {
                if self.done {
                    return;
                }
                let mut q = self.engine.planner.lock().unwrap();
                q.draining = false;
                for job in q.jobs.drain(..) {
                    job.slot
                        .fulfill(Err(RunError::Fault("batch drain leader panicked".into())));
                }
            }
        }
        let mut obligation = DrainObligation { engine: self, done: false };
        loop {
            // Settle window: wait for the queue to go quiet before taking.
            let mut last = self.planner.lock().unwrap().jobs.len();
            let (mut quiet, mut rounds) = (0u32, 0u32);
            while quiet < 2 && rounds < 100 {
                std::thread::sleep(std::time::Duration::from_micros(500));
                rounds += 1;
                let now = self.planner.lock().unwrap().jobs.len();
                if now == last {
                    quiet += 1;
                } else {
                    quiet = 0;
                    last = now;
                }
            }
            let batch = {
                let mut q = self.planner.lock().unwrap();
                if q.jobs.is_empty() {
                    q.draining = false;
                    break;
                }
                std::mem::take(&mut q.jobs)
            };
            self.planner_passes.fetch_add(1, Ordering::Relaxed);
            let (results, quarantined) = run_parallel_reported(&batch, |job| self.run_job(job));
            let panicked: HashMap<usize, String> =
                quarantined.into_iter().map(|q| (q.index, q.payload)).collect();
            for (j, (job, r)) in batch.into_iter().zip(results).enumerate() {
                let outcome: FlightResult = match r {
                    Some(Ok(m)) => Ok(m),
                    Some(Err(e)) => Err(e),
                    None => {
                        let payload = panicked
                            .get(&j)
                            .cloned()
                            .unwrap_or_else(|| "unknown panic".to_string());
                        Err(RunError::Fault(format!("worker panicked: {payload}")))
                    }
                };
                job.slot.fulfill(outcome);
            }
        }
        obligation.done = true;
    }

    /// Plan + execute in one step.
    pub fn query(&self, pts: &[QueryPoint]) -> Result<Vec<Measurement>, QueryFailure> {
        self.execute(self.plan(pts))
    }

    /// Resolve a single point. Build it with the [`QueryPoint`]
    /// constructors — `QueryPoint::new` for full occupancy,
    /// `QueryPoint::at` for a team size, `QueryPoint::functional` for an
    /// accuracy-only probe — so the engine has exactly one single-point
    /// entry instead of mirroring every constructor.
    pub fn one(&self, point: QueryPoint) -> Result<Measurement, QueryFailure> {
        Ok(self.query(&[point])?.pop().expect("one measurement"))
    }
}

/// Directory the CLI persists the cache under: `$TRANSPFP_CACHE_DIR`, or
/// `artifacts/cache` relative to the working directory.
pub fn cache_dir() -> PathBuf {
    std::env::var_os("TRANSPFP_CACHE_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts").join("cache"))
}

/// Path of the persisted cache file.
pub fn cache_file() -> PathBuf {
    cache_dir().join(CACHE_FILE)
}

/// Load the persisted cache (if any) into the global engine; returns the
/// number of entries accepted. A missing or unreadable file is a cold
/// start, not an error.
pub fn load_global_cache() -> usize {
    QueryEngine::global().cache().load_csv(&cache_file()).unwrap_or(0)
}

/// Persist the global engine's cache; returns the entry count written.
pub fn save_global_cache() -> std::io::Result<usize> {
    QueryEngine::global().cache().save_csv(&cache_file())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_points() -> Vec<QueryPoint> {
        let cfg = ClusterConfig::new(8, 2, 0);
        vec![
            QueryPoint::new(&cfg, Benchmark::Fir, Variant::Scalar),
            QueryPoint::new(&cfg, Benchmark::Iir, Variant::Scalar),
            // Duplicate of the first point: must collapse in the plan.
            QueryPoint::new(&cfg, Benchmark::Fir, Variant::Scalar),
        ]
    }

    #[test]
    fn dedup_partition_and_order() {
        let engine = QueryEngine::new();
        let pts = small_points();
        let plan = engine.plan(&pts);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.unique_len(), 2);
        assert_eq!((plan.hit_count(), plan.miss_count()), (0, 2));

        let ms = engine.query(&pts).expect("kernel points resolve");
        assert_eq!(ms.len(), 3);
        assert_eq!(ms[0].bench, Benchmark::Fir);
        assert_eq!(ms[1].bench, Benchmark::Iir);
        assert_eq!(ms[2].bench, Benchmark::Fir);
        // Duplicates are the same run, not a re-simulation.
        assert_eq!(ms[0].cycles, ms[2].cycles);
        assert_eq!(ms[0].agg, ms[2].agg);
        assert!(ms.iter().all(|m| m.verified));
        // plan() was called twice (once standalone, once in query): the
        // standalone plan's lookups also count, so expect 4 misses total
        // and 2 resident entries.
        let st = engine.stats();
        assert_eq!(st.entries, 2);
        assert_eq!(st.misses, 4);
    }

    #[test]
    fn warm_queries_skip_simulation_and_reproduce_results() {
        let engine = QueryEngine::new();
        let pts = small_points();
        let cold = engine.query(&pts).unwrap();
        let st_cold = engine.stats();

        let plan = engine.plan(&pts);
        assert_eq!((plan.hit_count(), plan.miss_count()), (2, 0), "warm plan must be all hits");
        let warm = engine.execute(plan).unwrap();
        let st_warm = engine.stats();
        assert_eq!(st_warm.misses, st_cold.misses, "warm query must not simulate");
        assert_eq!(st_warm.hits, st_cold.hits + 2);

        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.cycles, b.cycles);
            assert_eq!(a.metrics.perf_gflops.to_bits(), b.metrics.perf_gflops.to_bits());
            assert_eq!(a.metrics.energy_eff.to_bits(), b.metrics.energy_eff.to_bits());
            assert_eq!(a.err.rel.to_bits(), b.err.rel.to_bits());
            assert_eq!(a.agg, b.agg);
        }
    }

    #[test]
    fn occupancy_is_part_of_the_address() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        let full = engine.one(QueryPoint::new(&cfg, Benchmark::Fir, Variant::Scalar)).unwrap();
        let half = engine.one(QueryPoint::at(&cfg, Benchmark::Fir, Variant::Scalar, 4)).unwrap();
        let solo = engine.one(QueryPoint::at(&cfg, Benchmark::Fir, Variant::Scalar, 1)).unwrap();
        assert_eq!(engine.stats().entries, 3, "each occupancy has its own entry");
        assert_eq!((full.workers, half.workers, solo.workers), (8, 4, 1));
        assert!(
            solo.cycles > half.cycles && half.cycles > full.cycles,
            "fewer workers must cost cycles: {} / {} / {}",
            solo.cycles,
            half.cycles,
            full.cycles
        );
        // Warm re-resolution hits for every occupancy.
        let st = engine.stats();
        let warm = engine.one(QueryPoint::at(&cfg, Benchmark::Fir, Variant::Scalar, 4)).unwrap();
        assert_eq!(engine.stats().misses, st.misses, "occupancy re-query must not simulate");
        assert_eq!(warm.cycles, half.cycles);
    }

    /// The tentpole gate, in miniature: concurrent identical cold misses
    /// coalesce onto one flight — one simulator run total, everyone gets
    /// the same measurement, and the duplicate-run counter stays at zero.
    #[test]
    fn concurrent_identical_misses_run_the_simulator_once() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 2, 0);
        let point = QueryPoint::new(&cfg, Benchmark::Fir, Variant::Scalar);
        let mut cycles: Vec<u64> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let engine = &engine;
                    s.spawn(move || engine.one(point).expect("point resolves").cycles)
                })
                .collect();
            for h in handles {
                cycles.push(h.join().unwrap());
            }
        });
        assert_eq!(
            engine.sim_runs(),
            1,
            "8 concurrent identical cold queries must cost exactly 1 run"
        );
        assert_eq!(engine.duplicate_runs(), 0);
        assert!(cycles.windows(2).all(|w| w[0] == w[1]), "all callers share one result");
        assert_eq!(engine.stats().entries, 1);
        // Each of the 8 callers either hit the cache at plan time (planned
        // after the leader published) or had its miss coalesced onto the
        // leader's flight; exactly one led the run itself.
        assert_eq!(engine.stats().hits + engine.coalesced_runs(), 7);
    }

    /// The batch-planner gate, in miniature: while one call's drain is
    /// open (a slow cycle-accurate run in flight), concurrent *distinct*
    /// misses join that drain instead of spinning up worker pools of
    /// their own — the batched counters move, the pass count stays far
    /// below the request count, and no run is ever duplicated.
    #[test]
    fn concurrent_distinct_misses_batch_into_one_drain() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        // The anchor: a cycle-accurate run, slow enough that the distinct
        // functional misses below land while its drain is still open.
        let anchor = QueryPoint::new(&cfg, Benchmark::Matmul, Variant::VEC);
        let workers: Vec<QueryPoint> =
            [Benchmark::Fir, Benchmark::Iir, Benchmark::Conv, Benchmark::Dwt]
                .into_iter()
                .map(|b| QueryPoint::functional(&cfg, b, Variant::Scalar))
                .collect();
        std::thread::scope(|s| {
            let engine = &engine;
            // Pre-plan the workers so their executes enqueue immediately.
            let plans: Vec<QueryPlan> =
                workers.iter().map(|p| engine.plan(std::slice::from_ref(p))).collect();
            let lead = s.spawn(move || engine.one(anchor).expect("anchor resolves"));
            // Wait until the anchor's pass has actually started running.
            while engine.sim_runs() == 0 {
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
            let handles: Vec<_> = plans
                .into_iter()
                .map(|plan| s.spawn(move || engine.execute(plan).expect("point resolves")))
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            lead.join().unwrap();
        });
        assert_eq!(engine.sim_runs(), 1);
        assert_eq!(engine.functional_runs(), 4);
        assert_eq!(engine.duplicate_runs(), 0, "batching must never duplicate a run");
        assert!(
            engine.batched_requests() >= 1 && engine.batched_points() >= 1,
            "distinct concurrent misses must join the open drain (got {} reqs / {} pts)",
            engine.batched_requests(),
            engine.batched_points()
        );
        assert!(
            engine.planner_passes() <= 5,
            "5 requests must not cost {} planner passes",
            engine.planner_passes()
        );
        assert_eq!(engine.stats().entries, 5, "every point resolved and cached");
    }

    /// Accuracy-only plans resolve entirely on the functional backend —
    /// zero event-engine runs — and carry the *same* error statistics as a
    /// cycle-accurate resolution of the same point (architectural parity),
    /// under a distinct cache address.
    #[test]
    fn functional_fidelity_never_touches_the_event_engine() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        let pts: Vec<QueryPoint> = [Benchmark::Fir, Benchmark::Matmul]
            .into_iter()
            .map(|b| QueryPoint::functional(&cfg, b, Variant::VEC))
            .collect();
        let ms = engine.query(&pts).unwrap();
        assert_eq!(engine.sim_runs(), 0, "functional plan must not simulate");
        assert_eq!(engine.functional_runs(), 2);
        for m in &ms {
            assert!(m.verified, "{}: functional run must verify", m.bench.name());
            assert!(m.err.rel.is_finite());
            assert_eq!(m.cycles, 0, "functional measurements carry no timing");
            assert_eq!(m.metrics.perf_gflops, 0.0);
        }
        // A cycle-accurate resolution is a separate entry with identical
        // accuracy but real timing.
        let ca = engine.one(QueryPoint::new(&cfg, Benchmark::Fir, Variant::VEC)).unwrap();
        assert_eq!(engine.sim_runs(), 1);
        assert_eq!(engine.stats().entries, 3);
        assert_eq!(ca.err.rel.to_bits(), ms[0].err.rel.to_bits(), "accuracy must be tier-equal");
        assert_eq!(ca.err.max_abs.to_bits(), ms[0].err.max_abs.to_bits());
        assert!(ca.cycles > 0);
        // Warm functional re-query hits.
        let before = engine.stats();
        let warm = engine.query(&pts).unwrap();
        assert_eq!(engine.stats().misses, before.misses);
        assert_eq!(warm[0].err.rel.to_bits(), ms[0].err.rel.to_bits());
        assert_eq!(engine.functional_runs(), 2, "warm functional re-query must not re-run");
    }

    /// Compiled points execute on the compiled tier (no simulator, no
    /// functional-interpreter runs), translate each program exactly once
    /// through the engine's code cache, and — because `compiled` is not
    /// part of the cache address — share cache entries with plain
    /// functional resolutions of the same points.
    #[test]
    fn compiled_points_run_the_compiled_tier_and_share_the_address() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        let pts: Vec<QueryPoint> = [Benchmark::Fir, Benchmark::Matmul]
            .into_iter()
            .map(|b| QueryPoint::functional(&cfg, b, Variant::VEC).with_compiled())
            .collect();
        let ms = engine.query(&pts).unwrap();
        assert_eq!(engine.sim_runs(), 0, "compiled plan must not simulate");
        assert_eq!(engine.functional_runs(), 0, "compiled plan must not interpret");
        assert_eq!(engine.compiled_runs(), 2);
        assert_eq!(engine.duplicate_runs(), 0);
        let (hits, misses) = engine.code_cache().stats();
        assert_eq!(misses, 2, "one translation per distinct program");
        assert_eq!(hits, 0);
        for m in &ms {
            assert!(m.verified, "{}: compiled run must verify", m.bench.name());
            assert!(m.err.rel.is_finite());
            assert_eq!(m.cycles, 0, "compiled measurements carry no timing");
            assert!(m.agg.instrs > 0);
        }
        // The plain functional resolution of the same points is a cache hit
        // — compiled is an engine choice, not a distinct address.
        let st = engine.stats();
        let plain: Vec<QueryPoint> = [Benchmark::Fir, Benchmark::Matmul]
            .into_iter()
            .map(|b| QueryPoint::functional(&cfg, b, Variant::VEC))
            .collect();
        let warm = engine.query(&plain).unwrap();
        assert_eq!(engine.stats().misses, st.misses, "shared address must hit");
        assert_eq!(engine.functional_runs(), 0);
        assert_eq!(warm[0].err.rel.to_bits(), ms[0].err.rel.to_bits());
        assert_eq!(warm[0].agg.instrs, ms[0].agg.instrs);
        // Re-running the compiled points re-uses the cache, not the
        // translator: the miss counter is frozen.
        let engine2 = QueryEngine::new();
        engine2.query(&pts).unwrap();
        engine2.query(&pts).unwrap();
        let (_, misses2) = engine2.code_cache().stats();
        assert_eq!(misses2, 2, "warm compiled re-query must not re-translate");
    }

    /// The failure report names every unresolved point with its structured
    /// error class and states that resolved points were cached.
    #[test]
    fn query_failure_report_is_structured() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let fail = QueryFailure {
            errors: vec![
                QueryError {
                    point: QueryPoint::new(&cfg, Benchmark::Matmul, Variant::VEC),
                    error: RunError::Timeout { budget: 1000 },
                },
                QueryError {
                    point: QueryPoint::functional(&cfg, Benchmark::Fir, Variant::Scalar),
                    error: RunError::Fault("worker panicked: boom".to_string()),
                },
            ],
            requested: 5,
            resolved: 2,
        };
        let report = fail.to_string();
        assert!(report.contains("2 of 4 distinct point(s) unresolved"), "got: {report}");
        assert!(report.contains("5 requested"), "got: {report}");
        assert!(report.contains("matmul/vector-f16"), "got: {report}");
        assert!(report.contains("timeout"), "got: {report}");
        assert!(report.contains("fir/scalar"), "got: {report}");
        assert!(report.contains("worker panicked: boom"), "got: {report}");
        assert!(report.contains("cached"), "got: {report}");
        // The per-point line carries the config mnemonic and fidelity tag.
        assert!(report.contains(&cfg.to_string()), "got: {report}");
        assert!(report.contains("[fn]") && report.contains("[ca]"), "got: {report}");
    }

    #[test]
    fn points_product_order() {
        let cfgs = [ClusterConfig::new(8, 4, 1), ClusterConfig::new(8, 8, 1)];
        let pts = points(&cfgs, &[Benchmark::Conv, Benchmark::Svm], &[Variant::Scalar]);
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].cfg, cfgs[0]);
        assert_eq!(pts[0].bench, Benchmark::Conv);
        assert_eq!(pts[1].bench, Benchmark::Svm);
        assert_eq!(pts[2].cfg, cfgs[1]);
    }
}
