//! Emitters for every table and figure in the paper's evaluation (§5–§6).
//! Each function resolves the necessary slice of the design space and
//! renders a text table (plus CSV via [`crate::report`]).
//!
//! Every emitter goes through the [`QueryEngine`] planner, so a warm cache
//! regenerates the paper's tables without issuing a single simulator run.
//! Each query-backed emitter takes the engine explicitly — the CLI passes
//! [`QueryEngine::global()`], benches and tests pass private engines so
//! hit/miss assertions are not shared state. (The old zero-argument /
//! `_with` duplicated pairs are collapsed.) Since ENGINE_VERSION 3 this
//! includes Fig 5 (power activity at 100 MHz — regenerated from the cached
//! counters via [`model::Activity::from_measurement`]) and Fig 6
//! (occupancy speed-ups — team size is part of the cache address and
//! [`Measurement`] carries `workers`/`core_cycles`).

use super::query::{points, QueryEngine, QueryFailure, QueryPoint};
use super::sweep::Measurement;
use crate::cluster::counters::RunStats;
use crate::cluster::RunError;
use crate::config::{ClusterConfig, Corner};
use crate::kernels::{Benchmark, Variant};
use crate::model;
use crate::report::{argmax, fmt_cell, minmax_normalize, Table};

/// Configurations with `cores` cores, in Table 2 order.
fn configs_for(cores: usize) -> Vec<ClusterConfig> {
    ClusterConfig::design_space().into_iter().filter(|c| c.cores == cores).collect()
}

/// Table 3: FP / memory intensity per benchmark and variant — measured on
/// the 8c8f1p configuration, side by side with the paper's values.
pub fn table3(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let cfg = ClusterConfig::new(8, 8, 1);
    let measurements =
        engine.query(&points(&[cfg], &Benchmark::all(), &[Variant::Scalar, Variant::VEC]))?;
    let mut t = Table::new(vec![
        "Apps",
        "FP I. scal (paper)",
        "M. I. scal (paper)",
        "FP I. vec (paper)",
        "M. I. vec (paper)",
    ]);
    for (b, pair) in Benchmark::all().iter().zip(measurements.chunks_exact(2)) {
        let (ms, mv) = (&pair[0], &pair[1]);
        let (fs, mems) = b.table3_intensity(Variant::Scalar);
        let (fv, memv) = b.table3_intensity(Variant::VEC);
        t.row(vec![
            b.name().to_string(),
            format!("{:.2} ({fs:.2})", ms.fp_intensity),
            format!("{:.2} ({mems:.2})", ms.mem_intensity),
            format!("{:.2} ({fv:.2})", mv.fp_intensity),
            format!("{:.2} ({memv:.2})", mv.mem_intensity),
        ]);
    }
    Ok(t)
}

/// Tables 4 / 5: performance, energy efficiency and area efficiency for
/// every benchmark on the 8-core (`cores = 8`) or 16-core (`cores = 16`)
/// configurations, scalar and vector variants, with the per-row best
/// configuration boxed and the normalized-average (NAVG) footer.
pub fn table45(engine: &QueryEngine, cores: usize) -> Result<Table, QueryFailure> {
    let configs = configs_for(cores);
    let measurements =
        engine.query(&points(&configs, &Benchmark::all(), &[Variant::Scalar, Variant::VEC]))?;
    let find = |b: Benchmark, v: Variant, cfg: &ClusterConfig| -> &Measurement {
        measurements
            .iter()
            .find(|m| m.bench == b && m.variant.label() == v.label() && m.cfg == *cfg)
            .expect("measurement present")
    };

    let mut headers = vec!["bench".to_string(), "metric".to_string()];
    for v in ["S", "V"] {
        for c in &configs {
            headers.push(format!("{v}:{}", c.mnemonic()));
        }
    }
    let mut t = Table::new(headers);

    // Collect per-metric column values for the NAVG footer: column order is
    // scalar configs then vector configs.
    let col_count = 2 * configs.len();
    let mut avg_perf = vec![0.0f64; col_count];
    let mut avg_eeff = vec![0.0f64; col_count];
    let mut avg_aeff = vec![0.0f64; col_count];

    for b in Benchmark::all() {
        let mut perf = Vec::with_capacity(col_count);
        let mut eeff = Vec::with_capacity(col_count);
        let mut aeff = Vec::with_capacity(col_count);
        for v in [Variant::Scalar, Variant::VEC] {
            for c in &configs {
                let m = find(b, v, c);
                perf.push(m.metrics.perf_gflops);
                eeff.push(m.metrics.energy_eff);
                aeff.push(m.metrics.area_eff);
            }
        }
        for (i, p) in perf.iter().enumerate() {
            avg_perf[i] += p / 8.0;
        }
        for (i, e) in eeff.iter().enumerate() {
            avg_eeff[i] += e / 8.0;
        }
        for (i, a) in aeff.iter().enumerate() {
            avg_aeff[i] += a / 8.0;
        }
        for (label, vals) in [("PERF", &perf), ("E.EFF", &eeff), ("A.EFF", &aeff)] {
            let best = argmax(vals);
            let mut row = vec![b.name().to_string(), label.to_string()];
            for (i, v) in vals.iter().enumerate() {
                row.push(fmt_cell(*v, i == best));
            }
            t.row(row);
        }
    }
    // NAVG footer (min-max normalized averages, like the tables' last rows).
    for (label, vals) in
        [("NAVG PERF", &avg_perf), ("NAVG E.EFF", &avg_eeff), ("NAVG A.EFF", &avg_aeff)]
    {
        let norm = minmax_normalize(vals);
        let best = argmax(&norm);
        let mut row = vec!["AVG".to_string(), label.to_string()];
        for (i, v) in norm.iter().enumerate() {
            row.push(if i == best { format!("[{v:.2}]") } else { format!("{v:.2}") });
        }
        t.row(row);
    }
    Ok(t)
}

/// Fig 3: min / median / max fmax over the FPU counts, per core count ×
/// pipeline × corner.
pub fn fig3() -> Table {
    let mut t = Table::new(vec!["corner", "cores", "pipe", "fmax min (MHz)", "median", "max"]);
    for corner in [Corner::Nt, Corner::St] {
        for cores in [8usize, 16] {
            for pipe in 0..=2u32 {
                let (lo, med, hi) = model::fig3_spread(cores, pipe, corner);
                t.row(vec![
                    corner.to_string(),
                    cores.to_string(),
                    format!("{pipe}p"),
                    format!("{lo:.0}"),
                    format!("{med:.0}"),
                    format!("{hi:.0}"),
                ]);
            }
        }
    }
    t
}

/// Fig 4: total area per configuration.
pub fn fig4() -> Table {
    let mut t = Table::new(vec!["config", "area (mm^2)"]);
    for cfg in ClusterConfig::design_space() {
        t.row(vec![cfg.mnemonic(), format!("{:.3}", model::area_mm2(&cfg))]);
    }
    t
}

/// Fig 5: total power at 100 MHz per configuration, running the f32 MATMUL
/// (the paper's power-analysis workload), at both corners. Resolved
/// through the query engine since ENGINE_VERSION 3: the activity rates
/// regenerate from cached counters ([`model::Activity::from_measurement`]),
/// so a warm `fig5` issues zero simulator runs.
pub fn fig5(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let configs = ClusterConfig::design_space();
    let ms = engine.query(&points(&configs, &[Benchmark::Matmul], &[Variant::Scalar]))?;
    let mut t = Table::new(vec!["config", "P @100MHz NT (mW)", "P @100MHz ST (mW)"]);
    for m in &ms {
        let act = model::Activity::from_measurement(m);
        let nt = model::power_mw(&m.cfg, Corner::Nt, &act, 100.0);
        let st = model::power_mw(&m.cfg, Corner::St, &act, 100.0);
        t.row(vec![m.cfg.mnemonic(), format!("{nt:.2}"), format!("{st:.2}")]);
    }
    Ok(t)
}

/// Fig 6: parallel + vectorization speed-ups on the 16-core architectures:
/// min / avg / max over the nine 16-core configurations, for teams of
/// 1/2/4/8/16 workers forked through the runtime, scalar and vector.
/// Baseline: 1-worker team, scalar, same config. Occupancy is part of the
/// cache address, so a warm `fig6` issues zero simulator runs.
pub fn fig6(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let mut t = Table::new(vec!["bench", "workers", "variant", "min", "avg", "max"]);
    let configs = configs_for(16);
    const OCCUPANCIES: [usize; 5] = [1, 2, 4, 8, 16];
    // One batch for the whole figure: (bench × occupancy × variant ×
    // config), deduplicated and partitioned against the cache in one plan.
    let mut pts = Vec::new();
    for b in Benchmark::all() {
        for workers in OCCUPANCIES {
            for v in [Variant::Scalar, Variant::VEC] {
                for c in &configs {
                    pts.push(QueryPoint::at(c, b, v, workers));
                }
            }
        }
    }
    let ms = engine.query(&pts)?;
    let mut it = ms.chunks_exact(configs.len());
    // Baselines: the (workers=1, scalar) row of each bench block.
    for b in Benchmark::all() {
        let mut base: Vec<f64> = Vec::new();
        for workers in OCCUPANCIES {
            for v in [Variant::Scalar, Variant::VEC] {
                let block = it.next().expect("fig6 block");
                if workers == 1 && v == Variant::Scalar {
                    base = block.iter().map(|m| m.cycles as f64).collect();
                }
                let speedups: Vec<f64> =
                    block.iter().zip(&base).map(|(m, c1)| c1 / m.cycles as f64).collect();
                let lo = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = speedups.iter().cloned().fold(0.0f64, f64::max);
                let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
                t.row(vec![
                    b.name().to_string(),
                    format!("{workers}CL"),
                    v.label().to_string(),
                    format!("{lo:.2}"),
                    format!("{avg:.2}"),
                    format!("{hi:.2}"),
                ]);
            }
        }
    }
    Ok(t)
}

/// Fig 7: normalized average performance / energy efficiency / area
/// efficiency versus the FPU sharing factor (pipeline fixed at 1).
pub fn fig7(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let mut t = Table::new(vec!["cores", "sharing", "PERF (norm)", "E.EFF (norm)", "A.EFF (norm)"]);
    for cores in [8usize, 16] {
        let configs: Vec<ClusterConfig> =
            [4usize, 2, 1].iter().map(|d| ClusterConfig::new(cores, cores / d, 1)).collect();
        let (p, e, a) = averaged_metrics(engine, &configs)?;
        let (pn, en, an) = (minmax_normalize(&p), minmax_normalize(&e), minmax_normalize(&a));
        for (i, d) in [4, 2, 1].iter().enumerate() {
            t.row(vec![
                cores.to_string(),
                format!("1/{d}"),
                format!("{:.2}", pn[i]),
                format!("{:.2}", en[i]),
                format!("{:.2}", an[i]),
            ]);
        }
    }
    Ok(t)
}

/// Fig 8: normalized averages versus the pipeline depth (1/1 sharing fixed).
pub fn fig8(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let mut t = Table::new(vec!["cores", "pipe", "PERF (norm)", "E.EFF (norm)", "A.EFF (norm)"]);
    for cores in [8usize, 16] {
        let configs: Vec<ClusterConfig> =
            (0..=2u32).map(|p| ClusterConfig::new(cores, cores, p)).collect();
        let (p, e, a) = averaged_metrics(engine, &configs)?;
        let (pn, en, an) = (minmax_normalize(&p), minmax_normalize(&e), minmax_normalize(&a));
        for (i, pipe) in (0..=2u32).enumerate() {
            t.row(vec![
                cores.to_string(),
                format!("{pipe}PS"),
                format!("{:.2}", pn[i]),
                format!("{:.2}", en[i]),
                format!("{:.2}", an[i]),
            ]);
        }
    }
    Ok(t)
}

/// Average the three metrics over all benchmarks × variants per config.
fn averaged_metrics(
    engine: &QueryEngine,
    configs: &[ClusterConfig],
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>), QueryFailure> {
    let ms = engine.query(&points(configs, &Benchmark::all(), &[Variant::Scalar, Variant::VEC]))?;
    let mut perf = vec![0.0; configs.len()];
    let mut eeff = vec![0.0; configs.len()];
    let mut aeff = vec![0.0; configs.len()];
    let per_cfg = (ms.len() / configs.len()) as f64;
    for m in &ms {
        let i = configs.iter().position(|c| *c == m.cfg).unwrap();
        perf[i] += m.metrics.perf_gflops / per_cfg;
        eeff[i] += m.metrics.energy_eff / per_cfg;
        aeff[i] += m.metrics.area_eff / per_cfg;
    }
    Ok((perf, eeff, aeff))
}

/// Table 6: the SoA comparison. Competitor rows are the paper's quoted
/// literature values; the three "This work" rows are **measured here** on
/// the f32 MATMUL (the paper's methodology) and printed next to the values
/// the paper reports for itself.
pub fn table6(engine: &QueryEngine) -> Result<Table, QueryFailure> {
    let mut t = Table::new(vec![
        "platform",
        "domain",
        "tech",
        "V",
        "freq (GHz)",
        "area (mm^2)",
        "perf (Gflop/s)",
        "en.eff (Gflop/s/W)",
        "area eff (Gflop/s/mm^2)",
    ]);
    for r in crate::report::soa::competitors() {
        t.row(vec![
            r.name.to_string(),
            r.domain.to_string(),
            r.technology.to_string(),
            r.voltage.to_string(),
            format!("{:.2}", r.freq_ghz),
            r.area_mm2.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
            format!("{:.2}", r.perf_gflops),
            format!("{:.2}", r.energy_eff),
            r.area_eff.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    for ps in crate::report::soa::paper_self_rows() {
        let cfg = ClusterConfig::parse(ps.mnemonic).unwrap();
        let m = engine.one(QueryPoint::new(&cfg, Benchmark::Matmul, Variant::Scalar))?;
        t.row(vec![
            format!("This work {} ({}) [measured]", ps.mnemonic, ps.role),
            "Embedded".to_string(),
            "GF 22FDX (modelled)".to_string(),
            if ps.mnemonic.contains("0p") { "0.65" } else { "0.80" }.to_string(),
            format!("{:.2}", model::fmax_mhz(&cfg, Corner::St) / 1000.0),
            format!("{:.2}", model::area_mm2(&cfg)),
            format!("{:.2}", m.metrics.perf_gflops),
            format!("{:.2}", m.metrics.energy_eff),
            format!("{:.2}", m.metrics.area_eff),
        ]);
        t.row(vec![
            format!("This work {} ({}) [paper]", ps.mnemonic, ps.role),
            "Embedded".to_string(),
            "GF 22FDX".to_string(),
            "-".to_string(),
            format!("{:.2}", ps.freq_ghz),
            format!("{:.2}", ps.area_mm2),
            format!("{:.2}", ps.perf_gflops),
            format!("{:.2}", ps.energy_eff),
            format!("{:.2}", ps.area_eff),
        ]);
    }
    Ok(t)
}

/// Measurement rows in the `sweep --csv` column layout — the shared output
/// format of the `sweep` and `query` subcommands and the CI artifacts.
pub fn measurements_table(ms: &[Measurement]) -> Table {
    let mut t = Table::new(vec![
        "config",
        "bench",
        "variant",
        "workers",
        "cycles",
        "flops_per_cycle",
        "perf_gflops",
        "energy_eff",
        "area_eff",
        "fp_intensity",
        "mem_intensity",
        "verified",
        "rel_err",
    ]);
    for m in ms {
        t.row(vec![
            m.cfg.mnemonic(),
            m.bench.name().to_string(),
            m.variant.label().to_string(),
            m.workers.to_string(),
            m.cycles.to_string(),
            format!("{:.4}", m.metrics.flops_per_cycle),
            format!("{:.4}", m.metrics.perf_gflops),
            format!("{:.2}", m.metrics.energy_eff),
            format!("{:.3}", m.metrics.area_eff),
            format!("{:.3}", m.fp_intensity),
            format!("{:.3}", m.mem_intensity),
            m.verified.to_string(),
            format!("{:.3e}", m.err.rel),
        ]);
    }
    t
}

/// Helper for the validate path and examples: run a workload and return the
/// stats (re-exported for binaries).
pub fn run_stats(cfg: &ClusterConfig, b: Benchmark, v: Variant) -> Result<RunStats, RunError> {
    let w = b.build(v, cfg);
    let (stats, out) = w.run(cfg)?;
    w.verify(&out).expect("workload verification");
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_table_has_12_rows() {
        let t = fig3();
        assert_eq!(t.render().lines().count(), 2 + 12);
    }

    #[test]
    fn fig4_covers_design_space() {
        let t = fig4();
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 1 + 18);
        assert!(csv.contains("16c16f1p"));
    }

    #[test]
    fn fig7_sharing_trends() {
        // §5.3.2: performance grows with the sharing factor (1/4 → 1/1).
        let t = fig7(QueryEngine::global()).expect("fig7 points resolve");
        let csv = t.to_csv();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| {
                l.split(',').skip(2).map(|x| x.parse::<f64>().unwrap()).collect::<Vec<f64>>()
            })
            .collect();
        // 8-core rows 0..3 in order 1/4, 1/2, 1/1: perf normalized 0..1.
        assert!(rows[0][0] < rows[2][0], "perf must grow with sharing factor");
        // Energy efficiency also grows with sharing (§5.3.2).
        assert!(rows[0][1] <= rows[2][1] + 0.05);
    }

    #[test]
    fn fig8_pipeline_trends() {
        // §5.3.3: 1 stage is the performance sweet spot; energy efficiency
        // strictly decreases with pipeline depth.
        let t = fig8(QueryEngine::global()).expect("fig8 points resolve");
        let csv = t.to_csv();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| {
                l.split(',').skip(2).map(|x| x.parse::<f64>().unwrap()).collect::<Vec<f64>>()
            })
            .collect();
        for cores_block in [0usize, 3] {
            let (p0, p1, p2) =
                (rows[cores_block][0], rows[cores_block + 1][0], rows[cores_block + 2][0]);
            assert!(p1 > p0, "1p must beat 0p on performance");
            assert!(p1 >= p2, "2p must not beat 1p on performance");
            let (e0, e1, e2) =
                (rows[cores_block][1], rows[cores_block + 1][1], rows[cores_block + 2][1]);
            assert!(e0 > e1 && e1 >= e2, "energy efficiency decreases with stages");
        }
    }
}
