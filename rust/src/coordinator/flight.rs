//! Single-flight coalescing: at most one in-flight computation per key.
//!
//! The service tentpole requires that N concurrent identical cache misses
//! trigger exactly **one** simulator run — the other N-1 callers park on the
//! leader's flight and receive a clone of its result. The table is generic
//! so it serves two layers:
//!
//! * the [`QueryEngine`](super::QueryEngine) coalesces per *design point*
//!   (`K = CacheKey`, `V = Result<Measurement, RunError>`), and
//! * the request router coalesces whole compound requests (`tune`,
//!   `pareto`) on their canonical wire line.
//!
//! Protocol: [`SingleFlight::begin`] either resolves immediately (the value
//! appeared since the caller planned), returns [`Begin::Follow`] with a slot
//! to [`FlightSlot::wait`] on, or returns [`Begin::Lead`] carrying a
//! [`LeadGuard`] — an RAII leadership token. The leader closes the flight
//! with [`LeadGuard::publish`]; if the guard is instead **dropped without
//! publishing** (the leader's computation panicked and unwound past it),
//! the flight is closed *poisoned* and every follower's `wait` returns
//! [`LeaderPoisoned`] instead of blocking forever. Leadership can no longer
//! be acquired without also acquiring the obligation to release it.

use std::collections::HashMap;
use std::fmt;
use std::hash::Hash;
use std::sync::{Arc, Condvar, Mutex};

/// What a leader hands the slot: a real value, or the tombstone left by a
/// [`LeadGuard`] that unwound before publishing.
enum Published<V> {
    Value(V),
    Poisoned,
}

/// A follower's wait ended on a flight whose leader panicked before
/// publishing. The computation was never completed — the caller should
/// surface a structured error (or retry, becoming the new leader).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaderPoisoned;

impl fmt::Display for LeaderPoisoned {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "flight leader panicked before publishing")
    }
}

impl std::error::Error for LeaderPoisoned {}

/// A parked computation: followers wait on the condvar until the leader
/// publishes its result — or until its [`LeadGuard`] drops poisoned.
pub struct FlightSlot<V> {
    result: Mutex<Option<Published<V>>>,
    done: Condvar,
}

impl<V: Clone> FlightSlot<V> {
    /// A fresh, unfulfilled slot. Crate-visible so the batch planner
    /// ([`QueryEngine`](super::QueryEngine)) can hand out free-standing
    /// slots for jobs that live in the shared planner queue rather than in
    /// a [`SingleFlight`] table.
    pub(crate) fn new() -> Self {
        FlightSlot { result: Mutex::new(None), done: Condvar::new() }
    }

    /// Fulfill the slot directly and wake every waiter. This is the batch
    /// planner's counterpart of [`LeadGuard::publish`] for slots that were
    /// never registered in a flight table; fulfilling twice is a logic
    /// error (the second value silently wins), so callers must route each
    /// slot through exactly one drain.
    pub(crate) fn fulfill(&self, value: V) {
        *self.result.lock().unwrap() = Some(Published::Value(value));
        self.done.notify_all();
    }

    /// Block until the leader closes the flight, then return a clone of its
    /// value — or [`LeaderPoisoned`] if the leader unwound first.
    pub fn wait(&self) -> Result<V, LeaderPoisoned> {
        let mut slot = self.result.lock().unwrap();
        loop {
            match &*slot {
                Some(Published::Value(v)) => return Ok(v.clone()),
                Some(Published::Poisoned) => return Err(LeaderPoisoned),
                None => slot = self.done.wait(slot).unwrap(),
            }
        }
    }
}

/// RAII leadership token for one key's flight. Obtained only through
/// [`SingleFlight::begin`]; consumed by [`LeadGuard::publish`]. Dropping it
/// unconsumed — which is exactly what a panic unwinding through the
/// leader's computation does — closes the flight poisoned so followers are
/// released with [`LeaderPoisoned`] instead of hanging.
pub struct LeadGuard<'f, K: Eq + Hash + Clone, V: Clone> {
    flight: &'f SingleFlight<K, V>,
    /// `Some` while the obligation is live; taken by `publish` (defusing
    /// the drop) or by `drop` (poisoning the flight).
    key: Option<K>,
}

impl<K: Eq + Hash + Clone, V: Clone> LeadGuard<'_, K, V> {
    /// Close the flight: wake every follower with a clone of `value` and
    /// defuse the poison-on-drop obligation.
    pub fn publish(mut self, value: V) {
        let key = self.key.take().expect("a live guard holds its key");
        self.flight.close(&key, Published::Value(value));
    }

    /// The key this guard leads.
    pub fn key(&self) -> &K {
        self.key.as_ref().expect("a live guard holds its key")
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Drop for LeadGuard<'_, K, V> {
    fn drop(&mut self) {
        if let Some(key) = self.key.take() {
            self.flight.close(&key, Published::Poisoned);
        }
    }
}

/// Outcome of [`SingleFlight::begin`].
pub enum Begin<'f, K: Eq + Hash + Clone, V: Clone> {
    /// No flight in progress: the caller leads. The guard *must* travel
    /// with the computation — publish through it on success, let the
    /// unwind drop it on panic.
    Lead(LeadGuard<'f, K, V>),
    /// Another caller is already computing this key: wait on the slot.
    Follow(Arc<FlightSlot<V>>),
    /// The `resolved` probe produced a value — nothing to compute.
    Resolved(V),
}

/// The in-flight table. `Default`-constructible so owners can keep deriving
/// `Default`.
pub struct SingleFlight<K, V> {
    inflight: Mutex<HashMap<K, Arc<FlightSlot<V>>>>,
}

impl<K, V> Default for SingleFlight<K, V> {
    fn default() -> Self {
        SingleFlight { inflight: Mutex::new(HashMap::new()) }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> SingleFlight<K, V> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Join or start the flight for `key`. The `resolved` probe runs under
    /// the table lock *before* a new flight is opened — pass a cheap cache
    /// peek so a value published after the caller's plan is still found
    /// (the classic plan-then-execute race).
    pub fn begin(&self, key: &K, resolved: impl FnOnce() -> Option<V>) -> Begin<'_, K, V> {
        let mut inflight = self.inflight.lock().unwrap();
        if let Some(slot) = inflight.get(key) {
            return Begin::Follow(Arc::clone(slot));
        }
        if let Some(v) = resolved() {
            return Begin::Resolved(v);
        }
        inflight.insert(key.clone(), Arc::new(FlightSlot::new()));
        Begin::Lead(LeadGuard { flight: self, key: Some(key.clone()) })
    }

    /// Close the flight for `key` and wake every follower. Reached only
    /// through a [`LeadGuard`] (publish or drop), so a key with no open
    /// flight is unreachable rather than silently ignored.
    fn close(&self, key: &K, outcome: Published<V>) {
        let slot = self.inflight.lock().unwrap().remove(key);
        if let Some(slot) = slot {
            *slot.result.lock().unwrap() = Some(outcome);
            slot.done.notify_all();
        }
    }

    /// Number of flights currently open (leaders that have not published).
    pub fn in_flight(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn leader_runs_once_followers_share_the_result() {
        let flight: SingleFlight<u32, u64> = SingleFlight::new();
        let computed = AtomicU64::new(0);
        let mut seen: Vec<u64> = Vec::new();

        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| match flight.begin(&7, || None) {
                        Begin::Lead(guard) => {
                            let v = 40 + computed.fetch_add(1, Ordering::SeqCst);
                            // Give followers time to pile onto the slot.
                            std::thread::sleep(std::time::Duration::from_millis(20));
                            guard.publish(v);
                            v
                        }
                        Begin::Follow(slot) => slot.wait().expect("leader published"),
                        Begin::Resolved(v) => v,
                    })
                })
                .collect();
            for h in handles {
                seen.push(h.join().unwrap());
            }
        });

        assert_eq!(computed.load(Ordering::SeqCst), 1, "exactly one leader computes");
        assert!(seen.iter().all(|&v| v == 40), "every caller sees the leader's value");
        assert_eq!(flight.in_flight(), 0, "publish closes the flight");
    }

    #[test]
    fn resolved_probe_short_circuits_a_new_flight() {
        let flight: SingleFlight<&'static str, i32> = SingleFlight::default();
        match flight.begin(&"k", || Some(11)) {
            Begin::Resolved(v) => assert_eq!(v, 11),
            _ => panic!("probe hit must resolve without opening a flight"),
        }
        assert_eq!(flight.in_flight(), 0);

        // Without a probe hit the same key opens a flight...
        let Begin::Lead(guard) = flight.begin(&"k", || None) else {
            panic!("cold key must lead");
        };
        assert_eq!(*guard.key(), "k");
        assert_eq!(flight.in_flight(), 1);
        // ...and an open flight wins over the probe: joiners must follow the
        // leader rather than race it through a stale cache view.
        assert!(matches!(flight.begin(&"k", || Some(99)), Begin::Follow(_)));
        guard.publish(5);
        assert_eq!(flight.in_flight(), 0);
    }

    /// The hot-path bugfix, exercised directly: a leader that panics before
    /// publishing used to leave its followers parked on the condvar forever
    /// (and the key wedged — every later caller became a follower of a dead
    /// flight). The guard's drop now closes the flight poisoned: all eight
    /// waiters return promptly with [`LeaderPoisoned`], and the key is free
    /// for a fresh leader afterwards.
    #[test]
    fn panicking_leader_releases_waiters_with_poison() {
        let flight: SingleFlight<u32, u64> = SingleFlight::new();
        let poisoned = AtomicU64::new(0);

        std::thread::scope(|s| {
            let Begin::Lead(guard) = flight.begin(&9, || None) else {
                panic!("cold key must lead");
            };
            let followers: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| match flight.begin(&9, || None) {
                        Begin::Follow(slot) => slot.wait(),
                        _ => panic!("open flight must be followed"),
                    })
                })
                .collect();
            // The leader's computation panics; the unwind drops the guard.
            let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                let _held_across_the_computation = guard;
                // Give followers time to pile onto the slot before the
                // unwind closes it.
                std::thread::sleep(std::time::Duration::from_millis(20));
                panic!("leader died mid-computation");
            }))
            .expect_err("leader must panic");
            assert!(payload.downcast_ref::<&str>().is_some());
            for h in followers {
                match h.join().unwrap() {
                    Err(LeaderPoisoned) => {
                        poisoned.fetch_add(1, Ordering::SeqCst);
                    }
                    Ok(v) => panic!("no value was ever published, got {v}"),
                }
            }
        });

        assert_eq!(poisoned.load(Ordering::SeqCst), 8, "every waiter must be released");
        assert_eq!(flight.in_flight(), 0, "the poisoned flight is closed, not wedged");
        // The key is usable again: a fresh leader can run to completion.
        let Begin::Lead(guard) = flight.begin(&9, || None) else {
            panic!("poison must not wedge the key");
        };
        guard.publish(42);
        assert_eq!(flight.in_flight(), 0);
    }

    /// Publishing defuses the drop obligation exactly once; `key()` exposes
    /// the led key while the obligation is live.
    #[test]
    fn guard_publish_defuses_the_poison() {
        let flight: SingleFlight<u8, u8> = SingleFlight::new();
        let Begin::Lead(guard) = flight.begin(&3, || None) else {
            panic!("cold key must lead");
        };
        let Begin::Follow(slot) = flight.begin(&3, || None) else {
            panic!("open flight must be followed");
        };
        guard.publish(9);
        assert_eq!(slot.wait(), Ok(9), "published value reaches followers, not poison");
        assert_eq!(flight.in_flight(), 0);
    }
}
