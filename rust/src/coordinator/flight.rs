//! Single-flight coalescing: at most one in-flight computation per key.
//!
//! The service tentpole requires that N concurrent identical cache misses
//! trigger exactly **one** simulator run — the other N-1 callers park on the
//! leader's flight and receive a clone of its result. The table is generic
//! so it serves two layers:
//!
//! * the [`QueryEngine`](super::QueryEngine) coalesces per *design point*
//!   (`K = CacheKey`, `V = Result<Measurement, RunError>`), and
//! * the request router coalesces whole compound requests (`tune`,
//!   `pareto`) on their canonical wire line.
//!
//! Protocol: [`SingleFlight::begin`] either resolves immediately (the value
//! appeared since the caller planned), returns [`Begin::Follow`] with a slot
//! to [`FlightSlot::wait`] on, or returns [`Begin::Lead`] — the caller is
//! now the leader and **must** eventually [`SingleFlight::publish`] for that
//! key (on success *and* on failure), or followers would block forever.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Condvar, Mutex};

/// A parked computation: followers wait on the condvar until the leader
/// publishes its result.
pub struct FlightSlot<V> {
    result: Mutex<Option<V>>,
    done: Condvar,
}

impl<V: Clone> FlightSlot<V> {
    fn new() -> Self {
        FlightSlot { result: Mutex::new(None), done: Condvar::new() }
    }

    /// Block until the leader publishes, then return a clone of its result.
    pub fn wait(&self) -> V {
        let mut slot = self.result.lock().unwrap();
        while slot.is_none() {
            slot = self.done.wait(slot).unwrap();
        }
        slot.clone().expect("leader published a result")
    }
}

/// Outcome of [`SingleFlight::begin`].
pub enum Begin<V> {
    /// No flight in progress: the caller leads and must `publish` the key.
    Lead,
    /// Another caller is already computing this key: wait on the slot.
    Follow(Arc<FlightSlot<V>>),
    /// The `resolved` probe produced a value — nothing to compute.
    Resolved(V),
}

/// The in-flight table. `Default`-constructible so owners can keep deriving
/// `Default`.
pub struct SingleFlight<K, V> {
    inflight: Mutex<HashMap<K, Arc<FlightSlot<V>>>>,
}

impl<K, V> Default for SingleFlight<K, V> {
    fn default() -> Self {
        SingleFlight { inflight: Mutex::new(HashMap::new()) }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> SingleFlight<K, V> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Join or start the flight for `key`. The `resolved` probe runs under
    /// the table lock *before* a new flight is opened — pass a cheap cache
    /// peek so a value published after the caller's plan is still found
    /// (the classic plan-then-execute race).
    pub fn begin(&self, key: &K, resolved: impl FnOnce() -> Option<V>) -> Begin<V> {
        let mut inflight = self.inflight.lock().unwrap();
        if let Some(slot) = inflight.get(key) {
            return Begin::Follow(Arc::clone(slot));
        }
        if let Some(v) = resolved() {
            return Begin::Resolved(v);
        }
        inflight.insert(key.clone(), Arc::new(FlightSlot::new()));
        Begin::Lead
    }

    /// Leader hand-off: close the flight and wake every follower with a
    /// clone of `value`. Publishing a key with no open flight is a no-op.
    pub fn publish(&self, key: &K, value: V) {
        let slot = self.inflight.lock().unwrap().remove(key);
        if let Some(slot) = slot {
            *slot.result.lock().unwrap() = Some(value);
            slot.done.notify_all();
        }
    }

    /// Number of flights currently open (leaders that have not published).
    pub fn in_flight(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn leader_runs_once_followers_share_the_result() {
        let flight: SingleFlight<u32, u64> = SingleFlight::new();
        let computed = AtomicU64::new(0);
        let mut seen: Vec<u64> = Vec::new();

        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| match flight.begin(&7, || None) {
                        Begin::Lead => {
                            let v = 40 + computed.fetch_add(1, Ordering::SeqCst);
                            // Give followers time to pile onto the slot.
                            std::thread::sleep(std::time::Duration::from_millis(20));
                            flight.publish(&7, v);
                            v
                        }
                        Begin::Follow(slot) => slot.wait(),
                        Begin::Resolved(v) => v,
                    })
                })
                .collect();
            for h in handles {
                seen.push(h.join().unwrap());
            }
        });

        assert_eq!(computed.load(Ordering::SeqCst), 1, "exactly one leader computes");
        assert!(seen.iter().all(|&v| v == 40), "every caller sees the leader's value");
        assert_eq!(flight.in_flight(), 0, "publish closes the flight");
    }

    #[test]
    fn resolved_probe_short_circuits_a_new_flight() {
        let flight: SingleFlight<&'static str, i32> = SingleFlight::default();
        match flight.begin(&"k", || Some(11)) {
            Begin::Resolved(v) => assert_eq!(v, 11),
            _ => panic!("probe hit must resolve without opening a flight"),
        }
        assert_eq!(flight.in_flight(), 0);

        // Without a probe hit the same key opens a flight...
        assert!(matches!(flight.begin(&"k", || None), Begin::Lead));
        assert_eq!(flight.in_flight(), 1);
        // ...and an open flight wins over the probe: joiners must follow the
        // leader rather than race it through a stale cache view.
        assert!(matches!(flight.begin(&"k", || Some(99)), Begin::Follow(_)));
        flight.publish(&"k", 5);
        assert_eq!(flight.in_flight(), 0);
    }

    #[test]
    fn publishing_an_unled_key_is_a_no_op() {
        let flight: SingleFlight<u8, u8> = SingleFlight::new();
        flight.publish(&3, 9);
        assert_eq!(flight.in_flight(), 0);
    }
}
