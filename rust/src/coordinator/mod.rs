//! Design-space-exploration coordinator: runs (configuration × benchmark ×
//! variant) sweeps on the cycle-accurate simulator, converts counters into
//! the paper's metrics, and produces every table and figure of §5/§6.
//!
//! Since PR 2 the coordinator is a memoizing **query engine**: measurements
//! are content-addressed in a [`MeasurementCache`] (keyed by program
//! fingerprint × config × variant × engine version), batches of points are
//! deduplicated and partitioned by a [`QueryEngine`] so only cache misses
//! reach the parallel sweep workers, and the [`pareto`] module extracts the
//! design space's Pareto frontier over the three paper metrics.

pub mod cache;
pub mod flight;
pub mod pareto;
pub mod query;
pub mod sweep;
pub mod tables;

pub use cache::{
    workload_fingerprint, CacheKey, CacheStats, Fidelity, MeasurementCache, ENGINE_VERSION,
};
pub use flight::{Begin, FlightSlot, LeadGuard, LeaderPoisoned, SingleFlight};
pub use pareto::{
    accuracy_pareto_front, accuracy_pareto_table, accuracy_pareto_table_from, pareto_front,
    pareto_table, pareto_table_from,
};
pub use query::{points, QueryEngine, QueryError, QueryFailure, QueryPlan, QueryPoint};
pub use sweep::{
    max_jobs, run_one, run_one_at, run_one_compiled_at, run_one_functional_at, run_parallel,
    run_parallel_reported, run_workload, run_workload_compiled, run_workload_functional,
    set_max_jobs, sweep, sweep_all, Measurement, QuarantinedJob,
};
pub use tables::{
    fig3, fig4, fig5, fig6, fig7, fig8, measurements_table, table3, table45, table6,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::kernels::{Benchmark, Variant};

    /// The headline calibration anchor: FIR vector on 16c16f0p must land in
    /// the neighbourhood of the paper's 167 Gflop/s/W peak, and FIR scalar
    /// near 99 Gflop/s/W (Tables 4/5 peaks; abstract quotes 162/97 for the
    /// 8-core cluster).
    #[test]
    fn energy_anchor() {
        let cfg = ClusterConfig::new(16, 16, 0);
        let mv = run_one(&cfg, Benchmark::Fir, Variant::VEC).unwrap();
        assert!(
            mv.metrics.energy_eff > 120.0 && mv.metrics.energy_eff < 215.0,
            "FIR vector 16c16f0p = {} Gflop/s/W (paper: 167)",
            mv.metrics.energy_eff
        );
        let ms = run_one(&cfg, Benchmark::Fir, Variant::Scalar).unwrap();
        assert!(
            ms.metrics.energy_eff > 70.0 && ms.metrics.energy_eff < 130.0,
            "FIR scalar 16c16f0p = {} Gflop/s/W (paper: 99)",
            ms.metrics.energy_eff
        );
    }

    /// Performance anchor: FIR vector on 16c16f1p ≈ 5.92 Gflop/s.
    #[test]
    fn performance_anchor() {
        let cfg = ClusterConfig::new(16, 16, 1);
        let m = run_one(&cfg, Benchmark::Fir, Variant::VEC).unwrap();
        assert!(
            m.metrics.perf_gflops > 4.2 && m.metrics.perf_gflops < 7.6,
            "FIR vector 16c16f1p = {} Gflop/s (paper: 5.92)",
            m.metrics.perf_gflops
        );
    }

    /// Table 3 check across the whole suite: measured FP/memory intensities
    /// within ±0.12 / ±0.15 of the paper's values.
    #[test]
    fn intensities_match_table3() {
        let cfg = ClusterConfig::new(8, 8, 1);
        for b in Benchmark::all() {
            for v in [Variant::Scalar, Variant::VEC] {
                let m = run_one(&cfg, b, v).unwrap();
                let (fp_ref, mem_ref) = b.table3_intensity(v);
                assert!(
                    (m.fp_intensity - fp_ref).abs() < 0.13,
                    "{} {}: fp {} vs paper {}",
                    b.name(),
                    v.label(),
                    m.fp_intensity,
                    fp_ref
                );
                assert!(
                    (m.mem_intensity - mem_ref).abs() < 0.25,
                    "{} {}: mem {} vs paper {}",
                    b.name(),
                    v.label(),
                    m.mem_intensity,
                    mem_ref
                );
            }
        }
    }

    /// Every benchmark × variant verifies numerically on corner configs.
    #[test]
    fn all_measurements_verified() {
        for cfg in [ClusterConfig::new(8, 2, 0), ClusterConfig::new(16, 16, 2)] {
            for b in Benchmark::all() {
                for v in [Variant::Scalar, Variant::VEC] {
                    let m = run_one(&cfg, b, v).unwrap();
                    assert!(m.verified, "{} {} on {}", b.name(), v.label(), cfg);
                }
            }
        }
    }
}
