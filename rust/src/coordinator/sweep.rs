//! Sweep engine: one measurement per (config, benchmark, variant), with a
//! scoped-thread parallel driver for the full 18×8×2 design space.
//!
//! Result collection is lock-free: workers pull job indices from an atomic
//! counter (dynamic load balancing) and buffer `(slot, Measurement)` pairs
//! locally; the coordinator writes each pair into its pre-sized slot after
//! joining, so no worker ever contends on a lock and the output order is
//! deterministically `(config, bench, variant)` regardless of scheduling.

use crate::cluster::counters::CoreCounters;
use crate::cluster::RunError;
use crate::config::ClusterConfig;
use crate::kernels::{Benchmark, Variant, Workload};
use crate::model::{self, Metrics};
use crate::tuner::accuracy::{error_stats, ErrorStats};

/// One point of the evaluation space.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Configuration under test.
    pub cfg: ClusterConfig,
    /// Benchmark and variant.
    pub bench: Benchmark,
    pub variant: Variant,
    /// Team occupancy the run used (`cfg.cores` for the full-cluster
    /// tables; the fig 5/6 sweeps fork smaller teams). Part of the cache
    /// address since ENGINE_VERSION 3.
    pub workers: usize,
    /// Paper metrics (Gflop/s @ST, Gflop/s/W @NT, Gflop/s/mm²).
    pub metrics: Metrics,
    /// Total cycles of the run.
    pub cycles: u64,
    /// Σ per-core wall-clock cycles (each core's reset→End span). Together
    /// with `cycles` and `cfg.cores` this reconstructs the finished-early
    /// gated time the activity-based power model needs
    /// ([`crate::model::Activity::from_measurement`]).
    pub core_cycles: u64,
    /// Aggregated counters.
    pub agg: CoreCounters,
    /// FP / memory intensity (Table 3).
    pub fp_intensity: f64,
    pub mem_intensity: f64,
    /// Numeric verification against the host golden passed.
    pub verified: bool,
    /// Quantitative error against the workload's binary64 reference — the
    /// signal the tuner and the accuracy-extended Pareto frontier consume.
    pub err: ErrorStats,
}

/// Run one benchmark variant on one configuration at full occupancy. A
/// point that cannot terminate (hang, deadlock, architectural fault) comes
/// back as a structured [`RunError`] instead of a panic.
pub fn run_one(
    cfg: &ClusterConfig,
    bench: Benchmark,
    variant: Variant,
) -> Result<Measurement, RunError> {
    run_one_at(cfg, bench, variant, cfg.cores)
}

/// [`run_one`] under a `workers`-core team (fig 5/6 occupancy sweeps).
pub fn run_one_at(
    cfg: &ClusterConfig,
    bench: Benchmark,
    variant: Variant,
    workers: usize,
) -> Result<Measurement, RunError> {
    let w = bench.build(variant, cfg);
    run_workload(cfg, bench, variant, workers, &w)
}

/// [`run_one_at`] on a workload the caller already built — the query
/// planner constructs workloads up front (it needs the program for the
/// cache fingerprint) and hands only the cache misses here.
pub fn run_workload(
    cfg: &ClusterConfig,
    bench: Benchmark,
    variant: Variant,
    workers: usize,
    w: &Workload,
) -> Result<Measurement, RunError> {
    let (stats, out) = w.run_on(cfg, workers)?;
    let verified = w.verify(&out).is_ok();
    let err = error_stats(&out, &w.reference);
    let agg = stats.aggregate();
    Ok(Measurement {
        cfg: *cfg,
        bench,
        variant,
        workers,
        metrics: model::metrics(cfg, &stats),
        cycles: stats.total_cycles,
        core_cycles: stats.per_core.iter().map(|c| c.cycles).sum(),
        fp_intensity: agg.fp_intensity(),
        mem_intensity: agg.mem_intensity(),
        agg,
        verified,
        err,
    })
}

/// Accuracy-only resolution of a point on the functional backend: the
/// outputs (and through them `verified` and `err`) are bit-identical to a
/// cycle-accurate run — the four-way differential wall enforces that —
/// but no timing exists, so every timing-derived field is zero. The only
/// populated counter is the retired-instruction count.
pub fn run_workload_functional(
    cfg: &ClusterConfig,
    bench: Benchmark,
    variant: Variant,
    workers: usize,
    w: &Workload,
) -> Result<Measurement, RunError> {
    let (instrs, out) = w.run_functional(cfg, workers)?;
    let verified = w.verify(&out).is_ok();
    let err = error_stats(&out, &w.reference);
    Ok(Measurement {
        cfg: *cfg,
        bench,
        variant,
        workers,
        metrics: Metrics {
            perf_gflops: 0.0,
            energy_eff: 0.0,
            area_eff: 0.0,
            flops_per_cycle: 0.0,
        },
        cycles: 0,
        core_cycles: 0,
        agg: CoreCounters { instrs, ..Default::default() },
        fp_intensity: 0.0,
        mem_intensity: 0.0,
        verified,
        err,
    })
}

/// [`run_workload_functional`] on a freshly built workload.
pub fn run_one_functional_at(
    cfg: &ClusterConfig,
    bench: Benchmark,
    variant: Variant,
    workers: usize,
) -> Result<Measurement, RunError> {
    let w = bench.build(variant, cfg);
    run_workload_functional(cfg, bench, variant, workers, &w)
}

/// [`run_workload_functional`]'s shape on the compiled tier: the same
/// accuracy-only measurement (zero timing, populated retired-instruction
/// count), but executed through [`crate::cluster::CompiledBackend`] with
/// translations drawn from `cache`. The four-way differential wall makes
/// the outputs — and therefore `verified`/`err` — bit-identical to every
/// other tier.
pub fn run_workload_compiled(
    cfg: &ClusterConfig,
    bench: Benchmark,
    variant: Variant,
    workers: usize,
    w: &Workload,
    cache: &std::sync::Arc<crate::cluster::CodeCache>,
) -> Result<Measurement, RunError> {
    let (instrs, out) = w.run_compiled(cfg, workers, cache)?;
    let verified = w.verify(&out).is_ok();
    let err = error_stats(&out, &w.reference);
    Ok(Measurement {
        cfg: *cfg,
        bench,
        variant,
        workers,
        metrics: Metrics {
            perf_gflops: 0.0,
            energy_eff: 0.0,
            area_eff: 0.0,
            flops_per_cycle: 0.0,
        },
        cycles: 0,
        core_cycles: 0,
        agg: CoreCounters { instrs, ..Default::default() },
        fp_intensity: 0.0,
        mem_intensity: 0.0,
        verified,
        err,
    })
}

/// [`run_workload_compiled`] on a freshly built workload.
pub fn run_one_compiled_at(
    cfg: &ClusterConfig,
    bench: Benchmark,
    variant: Variant,
    workers: usize,
    cache: &std::sync::Arc<crate::cluster::CodeCache>,
) -> Result<Measurement, RunError> {
    let w = bench.build(variant, cfg);
    run_workload_compiled(cfg, bench, variant, workers, &w, cache)
}

/// Run the full design space (18 configs × 8 benchmarks × 2 variants),
/// parallelized over std scoped threads. Results are in deterministic
/// (config, bench, variant) order; the first failing point aborts with its
/// structured error (kernel workloads are hang-free by construction).
pub fn sweep_all() -> Result<Vec<Measurement>, RunError> {
    sweep(&ClusterConfig::design_space(), &Benchmark::all(), &[Variant::Scalar, Variant::VEC])
}

/// Run an arbitrary slice of the space. This is the *raw* (uncached)
/// driver — the differential and determinism harnesses rely on every call
/// actually simulating. Cached resolution lives in
/// [`crate::coordinator::query::QueryEngine`], which drives its misses
/// through the same [`run_parallel`] worker pool.
pub fn sweep(
    configs: &[ClusterConfig],
    benches: &[Benchmark],
    variants: &[Variant],
) -> Result<Vec<Measurement>, RunError> {
    let mut jobs = Vec::new();
    for cfg in configs {
        for b in benches {
            for v in variants {
                jobs.push((*cfg, *b, *v));
            }
        }
    }
    run_parallel(&jobs, |&(cfg, b, v)| run_one(&cfg, b, v)).into_iter().collect()
}

/// Worker-thread cap for [`run_parallel`] (the CLI's `--jobs N`). Zero
/// means "unset": fall back to the built-in ceiling of 16.
static MAX_JOBS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Cap the worker threads every [`run_parallel`] call may spawn. The CLI
/// sets this once at startup from `--jobs N`; tests may set it freely (the
/// cap changes scheduling, never results — slot order is deterministic).
pub fn set_max_jobs(n: usize) {
    assert!(n >= 1, "--jobs must be >= 1");
    MAX_JOBS.store(n, std::sync::atomic::Ordering::Relaxed);
}

/// Current worker-thread cap (16 unless [`set_max_jobs`] lowered/raised it).
pub fn max_jobs() -> usize {
    match MAX_JOBS.load(std::sync::atomic::Ordering::Relaxed) {
        0 => 16,
        n => n,
    }
}

/// A job whose closure panicked inside the worker pool. The point is
/// pulled out of the result set (its slot stays `None`) and reported here
/// instead of aborting the whole run.
#[derive(Debug, Clone)]
pub struct QuarantinedJob {
    /// Index into the `jobs` slice handed to the driver.
    pub index: usize,
    /// Stringified panic payload (`&str`/`String` payloads verbatim).
    pub payload: String,
}

fn panic_payload(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Lock-free parallel job driver shared by the raw sweep and the query
/// planner (both its planning pass and its miss execution — including the
/// batch-planner drain, where one take of the cross-request queue becomes
/// one invocation of this pool). Workers pull
/// job indices from an atomic counter (dynamic load balancing) and buffer
/// `(slot, result)` pairs locally; the coordinator writes each pair into
/// its pre-sized slot after joining, so results are in `jobs` order
/// regardless of scheduling. Thread count is `available_parallelism`
/// capped by [`max_jobs`] (the CLI `--jobs` knob).
///
/// Each job body runs under `catch_unwind`: one panicking point is
/// quarantined (index + payload, sorted by index) while every other job
/// still completes and lands in its slot. No worker thread ever dies to a
/// job panic, so a single bad point can no longer take down a campaign.
pub fn run_parallel_reported<J, R, F>(jobs: &[J], run: F) -> (Vec<Option<R>>, Vec<QuarantinedJob>)
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    let next = std::sync::atomic::AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(max_jobs())
        .min(jobs.len().max(1));
    let mut results: Vec<Option<R>> = Vec::new();
    results.resize_with(jobs.len(), || None);
    let mut quarantined: Vec<QuarantinedJob> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(usize, Result<R, String>)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            run(&jobs[i])
                        }));
                        local.push((i, r.map_err(panic_payload)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            // Only a non-unwinding abort (e.g. stack-overflow kill) can fail
            // this join now; job panics were caught inside the loop.
            for (i, r) in h.join().expect("sweep worker died outside catch_unwind") {
                match r {
                    Ok(v) => results[i] = Some(v),
                    Err(payload) => quarantined.push(QuarantinedJob { index: i, payload }),
                }
            }
        }
    });
    quarantined.sort_by_key(|q| q.index);
    (results, quarantined)
}

/// Infallible-closure convenience over [`run_parallel_reported`]: every
/// job completes first, then a quarantined point (if any) re-raises its
/// panic on the coordinator thread with the job index attached. Callers
/// that want to survive bad points use the reported variant directly.
pub fn run_parallel<J, R, F>(jobs: &[J], run: F) -> Vec<R>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    let (results, quarantined) = run_parallel_reported(jobs, run);
    if let Some(q) = quarantined.first() {
        panic!("sweep job {} panicked: {}", q.index, q.payload);
    }
    results.into_iter().map(|r| r.expect("sweep slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A jobs cap of 1 funnels every job through a single worker thread;
    /// results and order are unchanged (the cap is a scheduling knob only).
    #[test]
    fn jobs_cap_serializes_without_changing_results() {
        let jobs: Vec<usize> = (0..24).collect();
        let baseline = run_parallel(&jobs, |&i| i * 3);
        set_max_jobs(1);
        let ids = std::sync::Mutex::new(std::collections::HashSet::new());
        let capped = run_parallel(&jobs, |&i| {
            ids.lock().unwrap().insert(std::thread::current().id());
            i * 3
        });
        set_max_jobs(16); // restore the default ceiling for other tests
        assert_eq!(capped, baseline);
        assert_eq!(ids.lock().unwrap().len(), 1, "--jobs 1 must use one worker");
        assert_eq!(capped, (0..24).map(|i| i * 3).collect::<Vec<_>>());
    }

    /// Functional measurements carry real accuracy and zero timing.
    #[test]
    fn functional_measurement_shape() {
        let cfg = ClusterConfig::new(8, 2, 0);
        let m = run_one_functional_at(&cfg, Benchmark::Fir, Variant::Scalar, cfg.cores).unwrap();
        assert!(m.verified);
        assert!(m.err.rel.is_finite() && m.err.rel < 1e-4);
        assert_eq!((m.cycles, m.core_cycles), (0, 0));
        assert!(m.agg.instrs > 0, "retired-instruction count must be populated");
        assert_eq!(m.agg.flops, 0);
        // Accuracy is tier-independent: the cycle-accurate run agrees bit
        // for bit.
        let ca = run_one(&cfg, Benchmark::Fir, Variant::Scalar).unwrap();
        assert_eq!(ca.err.rel.to_bits(), m.err.rel.to_bits());
        assert_eq!(ca.verified, m.verified);
    }

    #[test]
    fn sweep_slice_is_ordered_and_verified() {
        let configs = [ClusterConfig::new(8, 4, 1)];
        let ms = sweep(&configs, &[Benchmark::Matmul, Benchmark::Fir], &[Variant::Scalar])
            .expect("kernel workloads terminate");
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[0].bench, Benchmark::Matmul);
        assert_eq!(ms[1].bench, Benchmark::Fir);
        assert!(ms.iter().all(|m| m.verified));
        assert!(ms.iter().all(|m| m.metrics.perf_gflops > 0.0));
        // binary32 runs sit within f32 rounding noise of the f64 reference.
        assert!(ms.iter().all(|m| m.err.rel.is_finite() && m.err.rel < 1e-4), "f32 error too big");
    }

    /// Satellite (a) of the robustness PR: a deliberately panicking job is
    /// quarantined — index and payload land in the report — and every
    /// other job still completes in its slot.
    #[test]
    fn panicking_job_is_quarantined_and_the_rest_complete() {
        let jobs: Vec<usize> = (0..32).collect();
        let (results, quarantined) = run_parallel_reported(&jobs, |&i| {
            if i == 13 {
                panic!("deliberate test panic at job {i}");
            }
            i * 7
        });
        assert_eq!(quarantined.len(), 1, "exactly one point quarantined");
        assert_eq!(quarantined[0].index, 13);
        assert!(
            quarantined[0].payload.contains("deliberate test panic at job 13"),
            "panic payload must be preserved verbatim, got: {}",
            quarantined[0].payload
        );
        assert!(results[13].is_none(), "quarantined slot stays empty");
        for (i, r) in results.iter().enumerate() {
            if i != 13 {
                assert_eq!(*r, Some(i * 7), "job {i} must still complete");
            }
        }
    }

    /// The infallible wrapper finishes the whole batch, then re-raises the
    /// quarantined panic with the job index attached.
    #[test]
    fn run_parallel_reraises_quarantined_panic_with_index() {
        let jobs: Vec<usize> = (0..8).collect();
        let err = std::panic::catch_unwind(|| {
            run_parallel(&jobs, |&i| {
                if i == 5 {
                    panic!("boom");
                }
                i
            })
        })
        .expect_err("wrapper must re-raise");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .expect("re-raised payload is a formatted String");
        assert!(msg.contains("job 5"), "index must be attached, got: {msg}");
        assert!(msg.contains("boom"), "original payload must survive, got: {msg}");
    }
}
