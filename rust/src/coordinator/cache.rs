//! Content-addressed measurement cache.
//!
//! Every `table*`/`fig*` command, the CLI sweep, and the `query`/`pareto`
//! subcommands project the same 18×8×2 design space; before this cache each
//! of them re-simulated its slice from scratch. A [`Measurement`] is fully
//! determined by the program it runs, the cluster configuration, and the
//! timing-engine semantics, so results are addressed by a [`CacheKey`]
//! fingerprinting exactly those inputs:
//!
//! * the 64-bit content hash of the **workload**: the predecoded
//!   instruction stream ([`DecodedProgram::fingerprint`]) folded with the
//!   staged input data, the output window, the host goldens and the
//!   tolerances ([`workload_fingerprint`]) — editing a kernel's code *or*
//!   its input generation invalidates precisely its own entries;
//! * the [`ClusterConfig`] (including the blocked-FPU-map ablation knob)
//!   plus the benchmark / variant identity;
//! * [`ENGINE_VERSION`], a manually-bumped constant capturing the timing
//!   model itself — the cache invalidation rule for simulator changes the
//!   program hash cannot see (see EXPERIMENTS.md §Cache).
//!
//! The key deliberately does *not* include the issue engine
//! ([`crate::cluster::Engine`]): the differential harness keeps the event
//! and reference engines cycle-identical, so their measurements are
//! interchangeable (asserted by `engine_parity_justifies_shared_key` below).
//!
//! The in-memory map serves one process; [`MeasurementCache::save_csv`] /
//! [`MeasurementCache::load_csv`] persist it under `artifacts/cache/` so
//! repeated CLI invocations skip simulation entirely. Floats are stored as
//! IEEE-754 bit patterns, making a cache round-trip bit-exact — a warm
//! `pareto` report is byte-identical to a cold one.

use std::collections::HashMap;
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::sweep::Measurement;
use crate::cluster::counters::CoreCounters;
use crate::config::ClusterConfig;
use crate::isa::DecodedProgram;
use crate::kernels::{Benchmark, OutFmt, Staged, Variant, Workload};
use crate::model::Metrics;
use crate::transfp::FpMode;
use crate::tuner::accuracy::ErrorStats;

/// Version of the timing model **and measurement schema** baked into every
/// cache key. Bump this whenever a simulator change can alter cycles or
/// counters (issue rules, latencies, arbitration, the analytic models'
/// inputs) *or* the `Measurement` row gains fields: persisted entries from
/// older engines then miss and are re-simulated, never served stale.
///
/// v2: rows carry the accuracy triple (max-abs, RMS, relative L2 error
/// against the f64 reference). v1 rows — which predate the accuracy
/// metrics — are rejected on load by both the version check and the row
/// width, degrading to a cold start (see EXPERIMENTS.md §Tuner).
///
/// v3: the kernels' parallel sections moved onto the fork-join runtime
/// (cycle counts shift), team occupancy joined the key (fig 5/6 resolve
/// through the engine), rows gained `workers`/`core_cycles` fields and a
/// trailing FNV-1a row checksum. v2 rows are rejected by version, width
/// *and* checksum — they degrade to a cold start (EXPERIMENTS.md §Runtime).
///
/// v4: execution [`Fidelity`] joined the key and the row (a functional,
/// accuracy-only resolution must never be served where cycle-accurate
/// timing was asked for, and vice versa). v3 rows are rejected by version
/// and width — they degrade to a cold start (EXPERIMENTS.md §Backends).
///
/// v5: `End` no longer counts an active cycle, closing the one-cycle gap
/// the trace layer's reconciliation exposed (`active + stalls == cycles`
/// now holds exactly per core). Cached `active` counters — and the
/// activity-based power/energy figures derived from them — shift by one
/// cycle per core, so v4 rows are rejected by version and re-simulated
/// (EXPERIMENTS.md §Trace).
///
/// v6: [`DecodedProgram::fingerprint`] switched from hashing `Debug`
/// renderings to an unambiguous structural byte encoding (the compiled
/// tier's code-cache key made the textual form untenable), which changes
/// every workload hash and therefore every address in this cache. v5 rows
/// can no longer be looked up under their old keys; the version bump
/// retires them cleanly — they miss by version and degrade to a cold
/// start, never to a silent stale hit (EXPERIMENTS.md §Backends).
pub const ENGINE_VERSION: u32 = 6;

/// Execution fidelity of a resolved design-space point — which backend
/// tier produced (or may serve) the measurement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fidelity {
    /// Architectural-only run on the functional backend: `verified` and
    /// `err` are real, every timing-derived field is zero.
    Functional,
    /// Full cycle-accurate simulation on the event engine (the default).
    CycleAccurate,
}

impl Fidelity {
    /// Stable row/CSV tag.
    pub fn tag(self) -> &'static str {
        match self {
            Fidelity::Functional => "fn",
            Fidelity::CycleAccurate => "ca",
        }
    }

    /// Parse a row tag.
    pub fn parse_tag(s: &str) -> Option<Fidelity> {
        match s {
            "fn" => Some(Fidelity::Functional),
            "ca" => Some(Fidelity::CycleAccurate),
            _ => None,
        }
    }
}

/// File name of the persisted cache inside the cache directory.
pub const CACHE_FILE: &str = "measurements.csv";

/// First line of a persisted cache file; anything else is ignored on load
/// (treated as a cold start and rewritten on save).
const MAGIC: &str = "transpfp-cache-v1";

/// Content address of one measurement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// [`workload_fingerprint`] of the workload (program + staged data +
    /// goldens + tolerances).
    pub workload: u64,
    /// Configuration under test.
    pub cfg: ClusterConfig,
    /// Benchmark and variant identity.
    pub bench: Benchmark,
    pub variant: Variant,
    /// Team occupancy of the run (cycles — and through them every metric —
    /// depend on it; `cfg.cores` for full-cluster measurements).
    pub workers: usize,
    /// Execution fidelity the measurement carries (functional rows hold
    /// accuracy only; cycle-accurate rows hold timing too).
    pub fidelity: Fidelity,
    /// [`ENGINE_VERSION`] at key-construction time.
    pub engine_version: u32,
}

impl CacheKey {
    /// Full-occupancy key for running `w` (built by `bench`/`variant`) on
    /// `cfg` under the current engine version.
    pub fn new(cfg: &ClusterConfig, bench: Benchmark, variant: Variant, w: &Workload) -> Self {
        Self::at(cfg, bench, variant, cfg.cores, w)
    }

    /// Key for a `workers`-core team run of `w`.
    pub fn at(
        cfg: &ClusterConfig,
        bench: Benchmark,
        variant: Variant,
        workers: usize,
        w: &Workload,
    ) -> Self {
        let fp = workload_fingerprint(w);
        Self::with_fingerprint(cfg, bench, variant, workers, Fidelity::CycleAccurate, fp)
    }

    /// Key from an already-computed workload fingerprint (the query
    /// planner memoizes fingerprints per workload within a process).
    pub fn with_fingerprint(
        cfg: &ClusterConfig,
        bench: Benchmark,
        variant: Variant,
        workers: usize,
        fidelity: Fidelity,
        workload: u64,
    ) -> Self {
        CacheKey {
            workload,
            cfg: *cfg,
            bench,
            variant,
            workers,
            fidelity,
            engine_version: ENGINE_VERSION,
        }
    }
}

/// FNV-1a byte fold used to extend the program fingerprint.
fn fnv_fold(mut h: u64, bytes: impl IntoIterator<Item = u8>) -> u64 {
    for b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 64-bit content hash of a full workload. Cycle counts depend on control
/// flow, and some kernels branch on FP-compare results of staged data
/// (e.g. the KMEANS assignment step), while `verified` depends on the
/// goldens and tolerances — so the address must cover the **data**, not
/// just the instruction stream: the predecoded-program fingerprint is
/// folded with the staged input bytes, the output window, the expected
/// outputs and the tolerances. Editing a kernel's input generation without
/// touching its code still invalidates its entries.
pub fn workload_fingerprint(w: &Workload) -> u64 {
    let mut h = DecodedProgram::decode(&w.program).fingerprint();
    for (addr, data) in &w.stage {
        h = fnv_fold(h, addr.to_le_bytes());
        match data {
            Staged::F32(v) => {
                h = fnv_fold(h, [1u8]);
                for x in v {
                    h = fnv_fold(h, x.to_bits().to_le_bytes());
                }
            }
            Staged::U16(v) => {
                h = fnv_fold(h, [2u8]);
                for x in v {
                    h = fnv_fold(h, x.to_le_bytes());
                }
            }
            Staged::U32(v) => {
                h = fnv_fold(h, [3u8]);
                for x in v {
                    h = fnv_fold(h, x.to_le_bytes());
                }
            }
        }
    }
    h = fnv_fold(h, w.out_addr.to_le_bytes());
    h = fnv_fold(h, (w.out_len as u64).to_le_bytes());
    // The 16-bit spec inside `Pack16` is already pinned by the variant in
    // the key; a tag suffices here.
    let fmt_tag = match w.out_fmt {
        OutFmt::F32 => 1u8,
        OutFmt::Pack16(_) => 2,
    };
    h = fnv_fold(h, [fmt_tag]);
    for e in &w.expected {
        h = fnv_fold(h, e.to_bits().to_le_bytes());
    }
    // The f64 reference determines the cached accuracy metrics, so a
    // reference-only edit must move the address too.
    for r in &w.reference {
        h = fnv_fold(h, r.to_bits().to_le_bytes());
    }
    h = fnv_fold(h, w.rtol.to_bits().to_le_bytes());
    fnv_fold(h, w.atol.to_bits().to_le_bytes())
}

/// Lookup statistics of a [`MeasurementCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the map.
    pub hits: u64,
    /// Lookups that required simulation.
    pub misses: u64,
    /// Entries currently resident.
    pub entries: usize,
}

/// Number of independently locked map shards. Sixteen matches the worker
/// cap ([`super::sweep::max_jobs`] tops out at 16), so even a fully loaded
/// pool rarely serializes two lookups on the same mutex.
const SHARD_COUNT: usize = 16;

/// Shard selector: rehash the (already well-mixed) key with the stdlib
/// hasher rather than reusing a key field, so every component of the
/// address contributes to the spread.
fn shard_index(key: &CacheKey) -> usize {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % SHARD_COUNT as u64) as usize
}

/// Thread-safe content-addressed store of [`Measurement`]s, sharded
/// `SHARD_COUNT` ways so concurrent service requests contend on 1/16th of
/// the keyspace instead of one global lock.
pub struct MeasurementCache {
    shards: [Mutex<HashMap<CacheKey, Measurement>>; SHARD_COUNT],
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for MeasurementCache {
    fn default() -> Self {
        MeasurementCache {
            shards: std::array::from_fn(|_| Mutex::new(HashMap::new())),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }
}

impl MeasurementCache {
    /// Empty in-memory cache.
    pub fn new() -> Self {
        Self::default()
    }

    fn shard(&self, key: &CacheKey) -> &Mutex<HashMap<CacheKey, Measurement>> {
        &self.shards[shard_index(key)]
    }

    /// Look `key` up, counting the access as a hit or miss.
    pub fn lookup(&self, key: &CacheKey) -> Option<Measurement> {
        let found = self.shard(key).lock().unwrap().get(key).cloned();
        let ctr = if found.is_some() { &self.hits } else { &self.misses };
        ctr.fetch_add(1, Ordering::Relaxed);
        found
    }

    /// Look `key` up **without** touching the hit/miss counters. This is
    /// the single-flight resolution probe: it re-checks for a value that
    /// landed between plan and execute, and must not double-count an access
    /// the planner already recorded.
    pub fn peek(&self, key: &CacheKey) -> Option<Measurement> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    /// Insert (or overwrite) the measurement for `key`.
    pub fn insert(&self, key: CacheKey, m: Measurement) {
        self.shard(&key).lock().unwrap().insert(key, m);
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True if no entries are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current hit/miss/entry counts.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }

    /// Merge entries persisted at `path` into the map; returns how many were
    /// accepted. Rows from a different [`ENGINE_VERSION`] are skipped — a
    /// stale cache degrades to a cold start, it never fails a command or
    /// serves wrong data.
    ///
    /// A file judged **unreadable** — wrong magic line, or any row that
    /// fails to decode (truncation, bit flips, pre-v4 schemas) — is
    /// additionally moved aside to the first free `<name>.quarantined-<n>`
    /// sibling ([`quarantine_file`]): the evidence survives for post-mortem
    /// instead of being silently overwritten by the next save, while the
    /// rows that *did* decode bit-exactly are still served. Version-skipped
    /// rows that decode cleanly are not corruption and trigger no
    /// quarantine.
    pub fn load_csv(&self, path: &Path) -> io::Result<usize> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        if lines.next() != Some(MAGIC) {
            quarantine_file(path);
            return Ok(0);
        }
        let mut accepted = 0usize;
        let mut corrupt = false;
        for line in lines {
            match decode_row(line) {
                Some((key, m)) => {
                    if key.engine_version == ENGINE_VERSION {
                        self.insert(key, m);
                        accepted += 1;
                    }
                }
                None => corrupt = true,
            }
        }
        if corrupt {
            quarantine_file(path);
        }
        Ok(accepted)
    }

    /// Write every resident entry to `path` (creating parent directories),
    /// in a deterministic row order; returns the entry count.
    ///
    /// The write is **atomic**: the file is staged next to `path` (a
    /// `.tmp-<pid>-<tid>-<seq>` sibling, unique per process, per thread
    /// *and* per save, so concurrent savers — other processes or other
    /// threads of this one — never stage into each other) and then
    /// `rename`d over the target,
    /// which on POSIX replaces the name in one step. Concurrent processes
    /// sharing `TRANSPFP_CACHE_DIR` therefore observe either the complete
    /// old file or the complete new one — never a torn row. (A torn row
    /// would only degrade to a cold start anyway, thanks to the row
    /// checksum, but a torn *file* would silently drop every row after the
    /// tear.)
    pub fn save_csv(&self, path: &Path) -> io::Result<usize> {
        static SAVE_SEQ: AtomicU64 = AtomicU64::new(0);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Snapshot shard by shard (no global freeze), then sort for a
        // deterministic file regardless of shard layout.
        let mut rows: Vec<String> = Vec::new();
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            rows.extend(map.iter().map(|(k, m)| encode_row(k, m)));
        }
        rows.sort_unstable();
        let mut out = String::with_capacity(rows.len() * 192 + MAGIC.len() + 1);
        out.push_str(MAGIC);
        out.push('\n');
        for r in &rows {
            out.push_str(r);
            out.push('\n');
        }
        let seq = SAVE_SEQ.fetch_add(1, Ordering::Relaxed);
        // The staging name folds in the thread id on top of pid + counter:
        // the counter alone already makes in-process names unique, but the
        // tid keeps them unique even across a future counter reset or a
        // fork, and makes a leaked staging file attributable.
        let tid = std::thread::current().id();
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(format!(".tmp-{}-{tid:?}-{seq}", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, out)?;
        match std::fs::rename(&tmp, path) {
            Ok(()) => Ok(rows.len()),
            Err(e) => {
                // Never leave the staging file behind on a failed publish.
                std::fs::remove_file(&tmp).ok();
                Err(e)
            }
        }
    }
}

/// Move an unreadable cache file to its first free
/// `<name>.quarantined-<n>` sibling, preserving the bytes for post-mortem;
/// returns the quarantine path. Best-effort: a rename failure (or 100
/// existing quarantine siblings) leaves the file in place — the next save
/// overwrites it atomically either way.
fn quarantine_file(path: &Path) -> Option<std::path::PathBuf> {
    for n in 0..100u32 {
        let mut q = path.as_os_str().to_owned();
        q.push(format!(".quarantined-{n}"));
        let q = std::path::PathBuf::from(q);
        if q.exists() {
            continue;
        }
        return std::fs::rename(path, &q).ok().map(|()| q);
    }
    None
}

/// Mnemonic plus a `+b` suffix for the blocked-FPU-map ablation (the
/// mnemonic alone does not encode that knob).
fn encode_cfg(cfg: &ClusterConfig) -> String {
    if cfg.blocked_fpu_map {
        format!("{}+b", cfg.mnemonic())
    } else {
        cfg.mnemonic()
    }
}

fn decode_cfg(s: &str) -> Option<ClusterConfig> {
    match s.strip_suffix("+b") {
        Some(base) => ClusterConfig::parse(base).map(|c| c.with_blocked_fpu_map()),
        None => ClusterConfig::parse(s),
    }
}

fn encode_variant(v: Variant) -> &'static str {
    match v {
        Variant::Scalar => "scalar",
        Variant::Scalar16(FpMode::F16) => "scalarf16",
        Variant::Scalar16(FpMode::Bf16) => "scalarbf16",
        Variant::Vector(FpMode::VecF16) => "vecf16",
        Variant::Vector(FpMode::VecBf16) => "vecbf16",
        // Degenerate modes no kernel builds; named for totality.
        Variant::Scalar16(_) => "s16.invalid",
        Variant::Vector(FpMode::F32) => "vec.f32",
        Variant::Vector(FpMode::F16) => "vec.f16",
        Variant::Vector(FpMode::Bf16) => "vec.bf16",
    }
}

fn decode_variant(s: &str) -> Option<Variant> {
    match s {
        "scalar" => Some(Variant::Scalar),
        "scalarf16" => Some(Variant::Scalar16(FpMode::F16)),
        "scalarbf16" => Some(Variant::Scalar16(FpMode::Bf16)),
        "vecf16" => Some(Variant::Vector(FpMode::VecF16)),
        "vecbf16" => Some(Variant::Vector(FpMode::VecBf16)),
        "vec.f32" => Some(Variant::Vector(FpMode::F32)),
        "vec.f16" => Some(Variant::Vector(FpMode::F16)),
        "vec.bf16" => Some(Variant::Vector(FpMode::Bf16)),
        _ => None,
    }
}

/// Counter fields in row order (kept in `CoreCounters` declaration order).
fn counters_to_fields(c: &CoreCounters) -> [u64; 18] {
    [
        c.cycles,
        c.active,
        c.instrs,
        c.int_instrs,
        c.fp_instrs,
        c.fp_vec_instrs,
        c.mem_instrs,
        c.flops,
        c.tcdm_cont,
        c.l2_stall,
        c.fpu_stall,
        c.fpu_cont,
        c.divsqrt_cont,
        c.wb_stall,
        c.load_stall,
        c.icache_stall,
        c.barrier_idle,
        c.branch_stall,
    ]
}

fn counters_from_fields(f: &[u64; 18]) -> CoreCounters {
    CoreCounters {
        cycles: f[0],
        active: f[1],
        instrs: f[2],
        int_instrs: f[3],
        fp_instrs: f[4],
        fp_vec_instrs: f[5],
        mem_instrs: f[6],
        flops: f[7],
        tcdm_cont: f[8],
        l2_stall: f[9],
        fpu_stall: f[10],
        fpu_cont: f[11],
        divsqrt_cont: f[12],
        wb_stall: f[13],
        load_stall: f[14],
        icache_stall: f[15],
        barrier_idle: f[16],
        branch_stall: f[17],
    }
}

/// FNV-1a checksum of a row's payload (everything before the trailing
/// checksum field). Persisted rows must round-trip bit-exactly; the
/// checksum turns silent on-disk corruption (truncation, bit flips) into a
/// clean row rejection instead of a plausible-but-wrong measurement.
fn row_checksum(payload: &str) -> u64 {
    fnv_fold(0xcbf2_9ce4_8422_2325, payload.bytes())
}

/// One `key → measurement` entry as a CSV row. Floats are serialized as
/// IEEE-754 bit patterns (hex) so a load reproduces them bit-exactly.
///
/// Schema (v4): 19 key/metric fields (now including the execution
/// fidelity tag between `workers` and `verified`), the 18 aggregated
/// counters, and a trailing FNV-1a checksum over the payload. v1/v2/v3
/// rows had 31/34/37 fields — rejected by [`decode_row`]'s width and
/// checksum checks on top of the engine-version check.
fn encode_row(key: &CacheKey, m: &Measurement) -> String {
    let mut row = format!(
        "{:016x},{},{},{},{},{},{},{},{},{},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x}",
        key.workload,
        key.engine_version,
        encode_cfg(&key.cfg),
        key.bench.name(),
        encode_variant(key.variant),
        key.workers,
        key.fidelity.tag(),
        m.verified,
        m.cycles,
        m.core_cycles,
        m.metrics.perf_gflops.to_bits(),
        m.metrics.energy_eff.to_bits(),
        m.metrics.area_eff.to_bits(),
        m.metrics.flops_per_cycle.to_bits(),
        m.fp_intensity.to_bits(),
        m.mem_intensity.to_bits(),
        m.err.max_abs.to_bits(),
        m.err.rms.to_bits(),
        m.err.rel.to_bits(),
    );
    for f in counters_to_fields(&m.agg) {
        row.push(',');
        row.push_str(&f.to_string());
    }
    let sum = row_checksum(&row);
    row.push(',');
    row.push_str(&format!("{sum:016x}"));
    row
}

/// Inverse of [`encode_row`]; `None` on any malformed field, a row of the
/// wrong width (e.g. a pre-backend v1/v2/v3 row), or a checksum mismatch
/// (truncated or bit-flipped persistence).
fn decode_row(line: &str) -> Option<(CacheKey, Measurement)> {
    let fields: Vec<&str> = line.split(',').collect();
    if fields.len() != 19 + 18 + 1 {
        return None;
    }
    let u64hex = |s: &str| u64::from_str_radix(s, 16).ok();
    let f64bits = |s: &str| u64hex(s).map(f64::from_bits);
    // Verify the payload checksum before trusting any field.
    let payload_len = line.len() - (fields[37].len() + 1);
    if u64hex(fields[37])? != row_checksum(&line[..payload_len]) {
        return None;
    }
    let key = CacheKey {
        workload: u64hex(fields[0])?,
        engine_version: fields[1].parse().ok()?,
        cfg: decode_cfg(fields[2])?,
        bench: Benchmark::parse(fields[3])?,
        variant: decode_variant(fields[4])?,
        workers: fields[5].parse().ok()?,
        fidelity: Fidelity::parse_tag(fields[6])?,
    };
    let verified = match fields[7] {
        "true" => true,
        "false" => false,
        _ => return None,
    };
    let cycles: u64 = fields[8].parse().ok()?;
    let core_cycles: u64 = fields[9].parse().ok()?;
    let metrics = Metrics {
        perf_gflops: f64bits(fields[10])?,
        energy_eff: f64bits(fields[11])?,
        area_eff: f64bits(fields[12])?,
        flops_per_cycle: f64bits(fields[13])?,
    };
    let fp_intensity = f64bits(fields[14])?;
    let mem_intensity = f64bits(fields[15])?;
    let err = ErrorStats {
        max_abs: f64bits(fields[16])?,
        rms: f64bits(fields[17])?,
        rel: f64bits(fields[18])?,
    };
    let mut counters = [0u64; 18];
    for (slot, s) in counters.iter_mut().zip(&fields[19..37]) {
        *slot = s.parse().ok()?;
    }
    let m = Measurement {
        cfg: key.cfg,
        bench: key.bench,
        variant: key.variant,
        workers: key.workers,
        metrics,
        cycles,
        core_cycles,
        agg: counters_from_fields(&counters),
        fp_intensity,
        mem_intensity,
        verified,
        err,
    };
    Some((key, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, Engine};
    use crate::coordinator::sweep::run_one;

    fn sample_measurement(cfg: &ClusterConfig) -> Measurement {
        Measurement {
            cfg: *cfg,
            bench: Benchmark::Fir,
            variant: Variant::VEC,
            workers: cfg.cores,
            metrics: Metrics {
                perf_gflops: 5.92,
                energy_eff: 167.0,
                area_eff: 3.5,
                flops_per_cycle: 16.0,
            },
            cycles: 12345,
            core_cycles: 12345 * cfg.cores as u64,
            agg: CoreCounters { cycles: 12345, instrs: 999, flops: 4096, ..Default::default() },
            fp_intensity: 0.32,
            mem_intensity: 0.48,
            verified: true,
            err: ErrorStats { max_abs: 1.5e-3, rms: 4.0e-4, rel: 2.0e-4 },
        }
    }

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("transpfp-{}-{}", name, std::process::id()))
    }

    /// The `<name>.quarantined-<n>` sibling [`quarantine_file`] produces.
    fn quarantine_sibling(path: &Path, n: u32) -> std::path::PathBuf {
        let mut q = path.as_os_str().to_owned();
        q.push(format!(".quarantined-{n}"));
        std::path::PathBuf::from(q)
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let cache = MeasurementCache::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = Benchmark::Fir.build(Variant::VEC, &cfg);
        let key = CacheKey::new(&cfg, Benchmark::Fir, Variant::VEC, &w);
        assert!(cache.lookup(&key).is_none());
        cache.insert(key, sample_measurement(&cfg));
        let hit = cache.lookup(&key).expect("inserted entry");
        assert_eq!(hit.cycles, 12345);
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert!(!cache.is_empty());
    }

    /// Sharding is an internal layout change: every key is still found, the
    /// entry count sums across shards, and the spread actually uses more
    /// than one shard (otherwise the N-way locking buys nothing).
    #[test]
    fn sharded_map_behaves_like_one_map() {
        let cache = MeasurementCache::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = Benchmark::Fir.build(Variant::VEC, &cfg);
        let base = CacheKey::new(&cfg, Benchmark::Fir, Variant::VEC, &w);
        let keys: Vec<CacheKey> = (0..64u64)
            .map(|i| {
                let mut k = base;
                k.workload = 0x5eed_0000 + i;
                k
            })
            .collect();
        for k in &keys {
            cache.insert(*k, sample_measurement(&cfg));
        }
        assert_eq!(cache.len(), 64);
        for k in &keys {
            assert!(cache.peek(k).is_some(), "every inserted key resolves");
        }
        let shards_used: std::collections::HashSet<usize> =
            keys.iter().map(shard_index).collect();
        assert!(
            shards_used.len() > SHARD_COUNT / 2,
            "64 distinct keys should spread over most of the {SHARD_COUNT} shards, \
             used {}",
            shards_used.len()
        );
        // peek() is counter-neutral; only lookup() moves the stats.
        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (0, 0));
        assert!(cache.lookup(&keys[0]).is_some());
        assert_eq!(cache.stats().hits, 1);
    }

    /// Concurrent writers on disjoint keys and readers on all of them:
    /// the per-shard locks must never lose an insert.
    #[test]
    fn concurrent_inserts_and_lookups_are_coherent() {
        let cache = MeasurementCache::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = Benchmark::Fir.build(Variant::VEC, &cfg);
        let base = CacheKey::new(&cfg, Benchmark::Fir, Variant::VEC, &w);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let cache = &cache;
                s.spawn(move || {
                    for i in 0..32u64 {
                        let mut k = base;
                        k.workload = (t << 32) | i;
                        cache.insert(k, sample_measurement(&cfg));
                        assert!(cache.peek(&k).is_some());
                    }
                });
            }
        });
        assert_eq!(cache.len(), 8 * 32);
    }

    /// The key is stable across workload rebuilds and `Cluster::reset()`:
    /// the fingerprint addresses workload *content*, not run state.
    #[test]
    fn key_stable_across_rebuild_and_reset() {
        let cfg = ClusterConfig::new(8, 2, 0);
        let w1 = Benchmark::Matmul.build(Variant::Scalar, &cfg);
        let w2 = Benchmark::Matmul.build(Variant::Scalar, &cfg);
        let k1 = CacheKey::new(&cfg, Benchmark::Matmul, Variant::Scalar, &w1);
        let k2 = CacheKey::new(&cfg, Benchmark::Matmul, Variant::Scalar, &w2);
        assert_eq!(k1, k2, "deterministic builders must fingerprint equal");

        let mut cl = Cluster::new(cfg, w1.program.clone());
        let before = cl.decoded().fingerprint();
        w1.run_in(&mut cl, cfg.cores).unwrap();
        cl.reset();
        assert_eq!(cl.decoded().fingerprint(), before, "reset must not disturb the program");
        assert_eq!(workload_fingerprint(&w1), k1.workload, "fingerprint is pure");

        // Different variant (different program + data) → different address.
        let wv = Benchmark::Matmul.build(Variant::VEC, &cfg);
        let kv = CacheKey::new(&cfg, Benchmark::Matmul, Variant::VEC, &wv);
        assert_ne!(kv, k1);
        assert_ne!(kv.workload, k1.workload);
    }

    /// Data-only edits move the address: the same instruction stream over
    /// different staged inputs or goldens must not share a cache entry.
    #[test]
    fn staged_data_is_part_of_the_key() {
        let cfg = ClusterConfig::new(8, 2, 0);
        let base = Benchmark::Matmul.build(Variant::Scalar, &cfg);
        let h0 = workload_fingerprint(&base);

        let mut data_edit = Benchmark::Matmul.build(Variant::Scalar, &cfg);
        if let Some((_, Staged::F32(v))) = data_edit.stage.first_mut() {
            v[0] += 1.0;
        } else {
            panic!("expected f32 staging for scalar MATMUL");
        }
        assert_ne!(workload_fingerprint(&data_edit), h0, "staged inputs must be hashed");

        let mut golden_edit = Benchmark::Matmul.build(Variant::Scalar, &cfg);
        golden_edit.expected[0] += 1.0;
        assert_ne!(workload_fingerprint(&golden_edit), h0, "goldens must be hashed");

        let mut tol_edit = Benchmark::Matmul.build(Variant::Scalar, &cfg);
        tol_edit.rtol *= 2.0;
        assert_ne!(workload_fingerprint(&tol_edit), h0, "tolerances must be hashed");
    }

    /// The key omits the issue engine because both engines are
    /// cycle-identical; this is the local witness of the differential
    /// harness's guarantee the shared address relies on.
    #[test]
    fn engine_parity_justifies_shared_key() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = Benchmark::Matmul.build(Variant::Scalar, &cfg);
        let (se, oe) = w.run_with(&cfg, cfg.cores, Engine::Event).unwrap();
        let (sr, or) = w.run_with(&cfg, cfg.cores, Engine::Reference).unwrap();
        assert_eq!(se.total_cycles, sr.total_cycles);
        assert_eq!(oe, or);
        assert_eq!(se.per_core, sr.per_core);
    }

    #[test]
    fn csv_roundtrip_is_bit_exact() {
        let cache = MeasurementCache::new();
        let cfg = ClusterConfig::new(8, 8, 1);
        let m = run_one(&cfg, Benchmark::Iir, Variant::Scalar).unwrap();
        let w = Benchmark::Iir.build(Variant::Scalar, &cfg);
        let key = CacheKey::new(&cfg, Benchmark::Iir, Variant::Scalar, &w);
        cache.insert(key, m.clone());
        // Plus an ablation config, to exercise the `+b` suffix.
        let bcfg = ClusterConfig::new(8, 4, 1).with_blocked_fpu_map();
        let bkey = CacheKey { cfg: bcfg, ..key };
        cache.insert(bkey, sample_measurement(&bcfg));

        let path = tmp_path("cache-roundtrip.csv");
        assert_eq!(cache.save_csv(&path).unwrap(), 2);
        let loaded = MeasurementCache::new();
        assert_eq!(loaded.load_csv(&path).unwrap(), 2);
        std::fs::remove_file(&path).ok();

        let got = loaded.lookup(&key).expect("persisted entry");
        assert_eq!(got.cycles, m.cycles);
        assert_eq!(got.verified, m.verified);
        assert_eq!(got.metrics.perf_gflops.to_bits(), m.metrics.perf_gflops.to_bits());
        assert_eq!(got.metrics.energy_eff.to_bits(), m.metrics.energy_eff.to_bits());
        assert_eq!(got.fp_intensity.to_bits(), m.fp_intensity.to_bits());
        assert_eq!(got.err.max_abs.to_bits(), m.err.max_abs.to_bits());
        assert_eq!(got.err.rms.to_bits(), m.err.rms.to_bits());
        assert_eq!(got.err.rel.to_bits(), m.err.rel.to_bits());
        assert_eq!(got.agg, m.agg);
        let gb = loaded.lookup(&bkey).expect("blocked-map entry");
        assert!(gb.cfg.blocked_fpu_map);
    }

    /// Regression fixture for the schema migrations: literal cache files as
    /// PR 2 (ENGINE_VERSION 1, 31 fields) and PR 3 (ENGINE_VERSION 2, 34
    /// fields, no checksum) wrote them. Under the v3 schema such rows must
    /// be skipped — rejected by row width, engine version and checksum — so
    /// the load degrades to a cold start instead of erroring or serving
    /// stale pre-runtime cycle counts.
    #[test]
    fn pre_runtime_rows_degrade_to_cold_start() {
        // PR 3's v2 layout: 16 key/metric fields (no workers/core_cycles)
        // + 18 counters, engine_version=2, hex f64 bit patterns, no
        // trailing checksum.
        let v2_row = format!(
            "00000000deadbeef,2,8c4f1p,FIR,scalar,true,12345,\
             {:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},\
             12345,12000,999,500,300,40,200,4096,1,2,3,4,5,6,7,8,9,10",
            5.92f64.to_bits(),
            167.0f64.to_bits(),
            3.5f64.to_bits(),
            16.0f64.to_bits(),
            0.32f64.to_bits(),
            0.48f64.to_bits(),
            1.5e-3f64.to_bits(),
            4.0e-4f64.to_bits(),
            2.0e-4f64.to_bits(),
        );
        // Sanity: the fixture really is a 34-field row with a parseable key
        // prefix — i.e. it *would* have decoded under the v2 schema.
        assert_eq!(v2_row.split(',').count(), 34);
        assert!(decode_cfg("8c4f1p").is_some());
        assert!(decode_variant("scalar").is_some());
        // PR 2's v1 layout: the same minus the accuracy triple.
        let v1_row = format!(
            "00000000deadbeef,1,8c4f1p,FIR,scalar,true,12345,\
             {:016x},{:016x},{:016x},{:016x},{:016x},{:016x},\
             12345,12000,999,500,300,40,200,4096,1,2,3,4,5,6,7,8,9,10",
            5.92f64.to_bits(),
            167.0f64.to_bits(),
            3.5f64.to_bits(),
            16.0f64.to_bits(),
            0.32f64.to_bits(),
            0.48f64.to_bits(),
        );
        assert_eq!(v1_row.split(',').count(), 31);

        let path = tmp_path("cache-pre-runtime.csv");
        std::fs::write(&path, format!("transpfp-cache-v1\n{v2_row}\n{v1_row}\n")).unwrap();
        let cache = MeasurementCache::new();
        assert_eq!(cache.load_csv(&path).unwrap(), 0, "v1/v2 rows must be dropped, not served");
        assert!(cache.is_empty());
        // Undecodable rows mark the file unreadable: it moved aside for
        // post-mortem (satellite b of the robustness PR).
        assert!(!path.exists(), "unreadable file must be quarantined");
        std::fs::remove_file(quarantine_sibling(&path, 0)).unwrap();

        // PR 4's v3 layout: like v4 but without the fidelity tag (37 fields,
        // engine_version=3) and with a *valid* checksum over its own payload
        // — rejected by row width and engine version.
        let v3_payload = format!(
            "00000000deadbeef,3,8c4f1p,FIR,scalar,8,true,12345,98760,\
             {:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},{:016x},\
             12345,12000,999,500,300,40,200,4096,1,2,3,4,5,6,7,8,9,10",
            5.92f64.to_bits(),
            167.0f64.to_bits(),
            3.5f64.to_bits(),
            16.0f64.to_bits(),
            0.32f64.to_bits(),
            0.48f64.to_bits(),
            1.5e-3f64.to_bits(),
            4.0e-4f64.to_bits(),
            2.0e-4f64.to_bits(),
        );
        let v3_row = format!("{v3_payload},{:016x}", row_checksum(&v3_payload));
        assert_eq!(v3_row.split(',').count(), 37);
        let path3 = tmp_path("cache-v3-row.csv");
        std::fs::write(&path3, format!("transpfp-cache-v1\n{v3_row}\n")).unwrap();
        assert_eq!(cache.load_csv(&path3).unwrap(), 0, "v3 rows must be dropped, not served");
        assert!(!path3.exists(), "old-schema file must be quarantined");
        std::fs::remove_file(quarantine_sibling(&path3, 0)).unwrap();

        // And even a v4-width row stamped with the old engine version is
        // rejected by the version check alone.
        let stale = CacheKey {
            workload: 0x1234,
            cfg: ClusterConfig::new(8, 4, 1),
            bench: Benchmark::Fir,
            variant: Variant::Scalar,
            workers: 8,
            fidelity: Fidelity::CycleAccurate,
            engine_version: 3,
        };
        let path2 = tmp_path("cache-v3-version.csv");
        let row = encode_row(&stale, &sample_measurement(&stale.cfg));
        std::fs::write(&path2, format!("transpfp-cache-v1\n{row}\n")).unwrap();
        assert_eq!(cache.load_csv(&path2).unwrap(), 0);
        // A cleanly-decoding stale-version row is *not* corruption: the
        // file stays put (no quarantine on a mere cold start).
        assert!(path2.exists(), "version skip must not quarantine");
        std::fs::remove_file(&path2).ok();
    }

    /// Robustness fuzz: random truncations and byte flips of a persisted
    /// cache file must degrade to a cold start — the load never panics,
    /// and every accepted row is bit-identical to one it wrote (the row
    /// checksum rejects everything else).
    #[test]
    fn corrupted_persistence_degrades_to_cold_start() {
        use crate::testutil::{check_cases, Rng};

        let cache = MeasurementCache::new();
        let mut originals: HashMap<CacheKey, Measurement> = HashMap::new();
        for (i, cfg) in
            [ClusterConfig::new(8, 4, 1), ClusterConfig::new(16, 16, 0)].iter().enumerate()
        {
            for workers in [1usize, cfg.cores] {
                let key = CacheKey {
                    workload: 0x1000 + i as u64,
                    cfg: *cfg,
                    bench: Benchmark::Fir,
                    variant: Variant::VEC,
                    workers,
                    fidelity: Fidelity::CycleAccurate,
                    engine_version: ENGINE_VERSION,
                };
                let m = sample_measurement(cfg);
                cache.insert(key, m.clone());
                originals.insert(key, m);
            }
        }
        let path = tmp_path("cache-fuzz.csv");
        cache.save_csv(&path).unwrap();
        let pristine = std::fs::read(&path).unwrap();

        check_cases(40, |rng: &mut Rng| {
            let mut bytes = pristine.clone();
            match rng.below(3) {
                // Truncate at a random point.
                0 => bytes.truncate(rng.below(bytes.len() as u64 + 1) as usize),
                // Flip a random byte.
                1 => {
                    let i = rng.below(bytes.len() as u64) as usize;
                    bytes[i] ^= (1 + rng.below(255)) as u8;
                }
                // Truncate and flip.
                _ => {
                    let keep = bytes.len() / 2 + rng.below(bytes.len() as u64 / 2) as usize;
                    bytes.truncate(keep.max(1));
                    let i = rng.below(bytes.len() as u64) as usize;
                    bytes[i] ^= (1 + rng.below(255)) as u8;
                }
            }
            let fuzz_path = tmp_path("cache-fuzz-case.csv");
            std::fs::write(&fuzz_path, &bytes).unwrap();
            let loaded = MeasurementCache::new();
            // Never panics; whatever survives is bit-identical to an
            // original entry.
            let accepted = loaded.load_csv(&fuzz_path).unwrap_or(0);
            assert!(accepted <= originals.len());
            for (key, m) in originals.iter() {
                if let Some(got) = loaded.lookup(key) {
                    assert_eq!(got.cycles, m.cycles);
                    assert_eq!(got.core_cycles, m.core_cycles);
                    assert_eq!(got.workers, m.workers);
                    assert_eq!(got.metrics.perf_gflops.to_bits(), m.metrics.perf_gflops.to_bits());
                    assert_eq!(got.agg, m.agg);
                }
            }
            // Satellite (b): a load that judged the file unreadable moved
            // it aside byte-exactly instead of destroying the evidence —
            // and the cold-start rebuild then publishes a fully loadable
            // file next to the forensic copy.
            let forensic = quarantine_sibling(&fuzz_path, 0);
            if !fuzz_path.exists() {
                assert_eq!(
                    std::fs::read(&forensic).unwrap(),
                    bytes,
                    "forensic copy must hold the corrupt bytes verbatim"
                );
                cache.save_csv(&fuzz_path).unwrap();
                let rebuilt = MeasurementCache::new();
                assert_eq!(
                    rebuilt.load_csv(&fuzz_path).unwrap(),
                    originals.len(),
                    "rebuilt cache must round-trip in full"
                );
                assert!(forensic.exists(), "rebuild must not clobber the forensic copy");
            }
            std::fs::remove_file(&fuzz_path).ok();
            std::fs::remove_file(&forensic).ok();
        });
        std::fs::remove_file(&path).ok();
    }

    /// Quarantine picks the first free `-<n>` sibling, so repeated
    /// corruption events each keep their own evidence.
    #[test]
    fn quarantine_numbers_do_not_clobber_prior_evidence() {
        let path = tmp_path("cache-quarantine-seq.csv");
        let q0 = quarantine_sibling(&path, 0);
        let q1 = quarantine_sibling(&path, 1);
        std::fs::write(&q0, b"earlier evidence").unwrap();
        std::fs::write(&path, b"bad magic entirely").unwrap();
        let cache = MeasurementCache::new();
        assert_eq!(cache.load_csv(&path).unwrap(), 0);
        assert!(!path.exists());
        assert_eq!(std::fs::read(&q0).unwrap(), b"earlier evidence", "prior evidence untouched");
        assert_eq!(std::fs::read(&q1).unwrap(), b"bad magic entirely");
        std::fs::remove_file(&q0).ok();
        std::fs::remove_file(&q1).ok();
    }

    /// Scalar-16 variants have their own cache addresses and row encodings
    /// — they must never collide with `scalar` or the vector formats.
    #[test]
    fn scalar16_variants_are_distinct_cache_citizens() {
        let cfg = ClusterConfig::new(8, 2, 0);
        let keys: Vec<CacheKey> = Variant::all()
            .into_iter()
            .map(|v| {
                let w = Benchmark::Fir.build(v, &cfg);
                CacheKey::new(&cfg, Benchmark::Fir, v, &w)
            })
            .collect();
        for (i, a) in keys.iter().enumerate() {
            for b in &keys[i + 1..] {
                assert_ne!(a, b);
                assert_ne!(a.workload, b.workload, "workload fingerprints must differ");
            }
        }
        for v in Variant::all() {
            assert_eq!(decode_variant(encode_variant(v)), Some(v), "{v:?} must round-trip");
        }
    }

    #[test]
    fn stale_engine_versions_and_garbage_are_skipped() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let stale_key = CacheKey {
            workload: 0xdead_beef,
            cfg,
            bench: Benchmark::Fir,
            variant: Variant::Scalar,
            workers: cfg.cores,
            fidelity: Fidelity::CycleAccurate,
            engine_version: ENGINE_VERSION + 1,
        };
        let path = tmp_path("cache-stale.csv");
        let body = format!(
            "{}\n{}\nnot,a,valid,row\n",
            "transpfp-cache-v1",
            encode_row(&stale_key, &sample_measurement(&cfg))
        );
        std::fs::write(&path, body).unwrap();
        let cache = MeasurementCache::new();
        assert_eq!(cache.load_csv(&path).unwrap(), 0, "stale + garbage rows must be dropped");
        // The garbage row made the file unreadable → quarantined.
        assert!(!path.exists());
        std::fs::remove_file(quarantine_sibling(&path, 0)).unwrap();

        // A file with an unknown magic line is ignored wholesale (and
        // quarantined — its content is unaccounted for).
        let path2 = tmp_path("cache-badmagic.csv");
        std::fs::write(&path2, "transpfp-cache-v999\nwhatever\n").unwrap();
        assert_eq!(cache.load_csv(&path2).unwrap(), 0);
        assert!(!path2.exists());
        std::fs::remove_file(quarantine_sibling(&path2, 0)).unwrap();
        assert!(cache.is_empty());
    }

    /// The v5 bump (`End` stops counting an active cycle) retires v4 rows:
    /// a well-formed pre-bump row loads zero entries — re-simulated, never
    /// served with its off-by-one `active` — without quarantining the file
    /// (the row is valid, just from an older engine).
    #[test]
    fn pre_v5_rows_are_retired_not_quarantined() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let v4_key = CacheKey {
            workload: 0x01d_c0de,
            cfg,
            bench: Benchmark::Matmul,
            variant: Variant::Scalar,
            workers: cfg.cores,
            fidelity: Fidelity::CycleAccurate,
            engine_version: 4,
        };
        let path = tmp_path("cache-v4-row.csv");
        let body = format!("{MAGIC}\n{}\n", encode_row(&v4_key, &sample_measurement(&cfg)));
        std::fs::write(&path, &body).unwrap();
        let cache = MeasurementCache::new();
        assert_eq!(cache.load_csv(&path).unwrap(), 0, "v4 rows must not be served");
        assert!(path.exists(), "a merely-stale file is not evidence — no quarantine");
        assert!(cache.is_empty());
        std::fs::remove_file(&path).ok();
    }

    /// The v6 bump (structural fingerprint encoding) retires v5 rows: a
    /// well-formed pre-bump row loads zero entries — its keys were minted
    /// under the old textual hash and can never be addressed again —
    /// without quarantining the file (the row is valid, just from an older
    /// engine). The cache degrades to a cold start, exactly as the v4→v5
    /// migration did.
    #[test]
    fn pre_v6_rows_are_retired_not_quarantined() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let v5_key = CacheKey {
            workload: 0x0f1_c0de,
            cfg,
            bench: Benchmark::Matmul,
            variant: Variant::Scalar,
            workers: cfg.cores,
            fidelity: Fidelity::CycleAccurate,
            engine_version: 5,
        };
        let path = tmp_path("cache-v5-row.csv");
        let body = format!("{MAGIC}\n{}\n", encode_row(&v5_key, &sample_measurement(&cfg)));
        std::fs::write(&path, &body).unwrap();
        let cache = MeasurementCache::new();
        assert_eq!(cache.load_csv(&path).unwrap(), 0, "v5 rows must not be served");
        assert!(path.exists(), "a merely-stale file is not evidence — no quarantine");
        assert!(cache.is_empty());
        std::fs::remove_file(&path).ok();
    }

    /// Satellite gate (PR 9): concurrent in-process persistence is safe.
    /// Many threads saving the same destination simultaneously each stage
    /// into a distinct temp file (pid + thread id + per-process save
    /// counter), so every publish is a complete file: the survivor loads in
    /// full, nothing is quarantined, and no staging file leaks.
    #[test]
    fn concurrent_saves_never_corrupt_or_quarantine() {
        let cache = MeasurementCache::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        for i in 0..16u64 {
            let key = CacheKey {
                workload: 0x1000 + i,
                cfg,
                bench: Benchmark::Fir,
                variant: Variant::Scalar,
                workers: cfg.cores,
                fidelity: Fidelity::CycleAccurate,
                engine_version: ENGINE_VERSION,
            };
            cache.insert(key, sample_measurement(&cfg));
        }
        let path = tmp_path("cache-concurrent-persist.csv");
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..8 {
                        assert_eq!(cache.save_csv(&path).unwrap(), 16);
                    }
                });
            }
        });
        // The destination is a complete, loadable file…
        let loaded = MeasurementCache::new();
        assert_eq!(loaded.load_csv(&path).unwrap(), 16, "published file must be complete");
        assert!(path.exists(), "a clean load leaves the file in place");
        // …and no `.tmp-*` / `.quarantined-*` sibling was left behind.
        let dir = path.parent().unwrap();
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        for entry in std::fs::read_dir(dir).unwrap() {
            let f = entry.unwrap().file_name().to_string_lossy().into_owned();
            assert!(
                !(f.starts_with(&name) && f != name),
                "sibling left behind by concurrent saves: {f}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    /// Functional and cycle-accurate resolutions of the same point are
    /// distinct cache citizens: an accuracy-only row must never be served
    /// where timing was asked for.
    #[test]
    fn fidelity_is_part_of_the_address() {
        let cfg = ClusterConfig::new(8, 2, 0);
        let w = Benchmark::Fir.build(Variant::Scalar, &cfg);
        let fp = workload_fingerprint(&w);
        let ca = CacheKey::with_fingerprint(
            &cfg,
            Benchmark::Fir,
            Variant::Scalar,
            cfg.cores,
            Fidelity::CycleAccurate,
            fp,
        );
        let func = CacheKey::with_fingerprint(
            &cfg,
            Benchmark::Fir,
            Variant::Scalar,
            cfg.cores,
            Fidelity::Functional,
            fp,
        );
        assert_ne!(ca, func);
        let cache = MeasurementCache::new();
        cache.insert(ca, sample_measurement(&cfg));
        assert!(cache.lookup(&func).is_none(), "fidelities must not alias");
        // Both tags round-trip through a persisted file.
        cache.insert(func, sample_measurement(&cfg));
        let path = tmp_path("cache-fidelity.csv");
        assert_eq!(cache.save_csv(&path).unwrap(), 2);
        let loaded = MeasurementCache::new();
        assert_eq!(loaded.load_csv(&path).unwrap(), 2);
        assert!(loaded.lookup(&ca).is_some() && loaded.lookup(&func).is_some());
        std::fs::remove_file(&path).ok();
        for f in [Fidelity::Functional, Fidelity::CycleAccurate] {
            assert_eq!(Fidelity::parse_tag(f.tag()), Some(f));
        }
        assert_eq!(Fidelity::parse_tag("xx"), None);
    }

    /// Satellite gate: persistence is atomic. A simulated partial write —
    /// a torn temp file left by a killed process, plus an existing complete
    /// cache at the destination — never corrupts the published file: after
    /// `save_csv` the destination is complete and bit-exact, and no torn
    /// intermediate is ever observable at the destination path.
    #[test]
    fn save_is_atomic_over_partial_writes() {
        let cache = MeasurementCache::new();
        let cfg = ClusterConfig::new(8, 4, 1);
        for i in 0..4u64 {
            let key = CacheKey {
                workload: 0x42 + i,
                cfg,
                bench: Benchmark::Fir,
                variant: Variant::VEC,
                workers: cfg.cores,
                fidelity: Fidelity::CycleAccurate,
                engine_version: ENGINE_VERSION,
            };
            cache.insert(key, sample_measurement(&cfg));
        }
        let path = tmp_path("cache-atomic.csv");
        // An old complete file already sits at the destination…
        assert_eq!(cache.save_csv(&path).unwrap(), 4);
        let old = std::fs::read_to_string(&path).unwrap();
        // …and a killed writer left a torn staging file behind (simulated
        // partial write: half of the eventual content).
        let mut torn = path.as_os_str().to_owned();
        torn.push(".tmp-9999-0");
        let torn = std::path::PathBuf::from(torn);
        std::fs::write(&torn, &old[..old.len() / 2]).unwrap();

        // A fifth entry makes the new save observably different.
        let key5 = CacheKey {
            workload: 0x99,
            cfg,
            bench: Benchmark::Iir,
            variant: Variant::Scalar,
            workers: cfg.cores,
            fidelity: Fidelity::Functional,
            engine_version: ENGINE_VERSION,
        };
        cache.insert(key5, sample_measurement(&cfg));
        assert_eq!(cache.save_csv(&path).unwrap(), 5);
        // The stale torn staging file is untouched (never published) and
        // the destination holds the complete new content.
        assert_eq!(std::fs::read_to_string(&torn).unwrap(), &old[..old.len() / 2]);
        let loaded = MeasurementCache::new();
        assert_eq!(loaded.load_csv(&path).unwrap(), 5, "published file must be complete");
        assert!(loaded.lookup(&key5).is_some());
        // The destination never regresses to the torn prefix: every line of
        // the published file is either the magic or a full 38-field row.
        let text = std::fs::read_to_string(&path).unwrap();
        for line in text.lines().skip(1) {
            assert_eq!(line.split(',').count(), 38, "torn row published: {line}");
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&torn).ok();
    }
}
