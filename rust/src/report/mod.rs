//! Report formatting: plain-text/markdown table builder, CSV writer, and
//! the Table 6 state-of-the-art comparison data.

pub mod soa;

use std::fmt::Write as _;

/// A simple column-aligned text table with an optional "highlight" marker
/// (used to box the best configuration per row, like the paper's tables).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "| {:<w$} ", c, w = width[i]);
            }
            out.push_str("|\n");
        };
        fmt_row(&self.headers, &width, &mut out);
        for (i, w) in width.iter().enumerate() {
            let _ = write!(out, "|{:-<w$}", "", w = w + 2);
            if i == ncols - 1 {
                out.push_str("|\n");
            }
        }
        for r in &self.rows {
            fmt_row(r, &width, &mut out);
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Two-column key → value table, used for cache statistics and query-plan
/// summaries (`transpfp query` prints one to stderr next to the results).
pub fn kv_table(title: &str, pairs: &[(&str, String)]) -> Table {
    let mut t = Table::new(vec![title, "value"]);
    for (k, v) in pairs {
        t.row(vec![(*k).to_string(), v.clone()]);
    }
    t
}

/// Format a value with the paper's 2-significant-style precision and mark
/// the best column with a `[x]` box.
pub fn fmt_cell(v: f64, best: bool) -> String {
    let s = if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    };
    if best {
        format!("[{s}]")
    } else {
        s
    }
}

/// Min-max normalize a slice into [0, 1] (constant slices map to 0).
pub fn minmax_normalize(vals: &[f64]) -> Vec<f64> {
    let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if (hi - lo).abs() < 1e-30 {
        return vec![0.0; vals.len()];
    }
    vals.iter().map(|v| (v - lo) / (hi - lo)).collect()
}

/// Index of the maximum value.
pub fn argmax(vals: &[f64]) -> usize {
    let mut best = 0;
    for (i, v) in vals.iter().enumerate() {
        if *v > vals[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["name", "v"]);
        t.row(vec!["a", "1.0"]);
        t.row(vec!["long-name", "2"]);
        let r = t.render();
        assert!(r.contains("| long-name | 2   |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "q\"z"]);
        let c = t.to_csv();
        assert!(c.contains("\"x,y\",\"q\"\"z\""));
    }

    #[test]
    fn normalize_and_argmax() {
        let n = minmax_normalize(&[2.0, 4.0, 3.0]);
        assert_eq!(n, vec![0.0, 1.0, 0.5]);
        assert_eq!(argmax(&[1.0, 5.0, 2.0]), 1);
        assert_eq!(minmax_normalize(&[3.3, 3.3]), vec![0.0, 0.0]);
    }

    #[test]
    fn kv_table_shape() {
        let t = kv_table("cache", &[("hits", "3".to_string()), ("misses", "1".to_string())]);
        let csv = t.to_csv();
        assert_eq!(csv, "cache,value\nhits,3\nmisses,1\n");
    }

    #[test]
    fn cell_formatting() {
        assert_eq!(fmt_cell(167.3, false), "167");
        assert_eq!(fmt_cell(16.73, false), "16.7");
        assert_eq!(fmt_cell(1.673, true), "[1.67]");
    }
}
