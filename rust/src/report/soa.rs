//! Table 6 — comparison with state-of-the-art architectures. The competitor
//! rows are literature values quoted directly from the paper; the
//! "This work" columns are **measured** on our reproduction (single-precision
//! MATMUL, like the paper's methodology).

/// One platform row of Table 6.
#[derive(Debug, Clone)]
pub struct SoaRow {
    pub name: &'static str,
    pub domain: &'static str,
    pub technology: &'static str,
    pub voltage: &'static str,
    pub freq_ghz: f64,
    pub area_mm2: Option<f64>,
    pub perf_gflops: f64,
    pub energy_eff: f64,
    pub area_eff: Option<f64>,
    pub fp_formats: &'static str,
    pub exec_model: &'static str,
}

/// The competitor platforms (values transcribed from Table 6).
pub fn competitors() -> Vec<SoaRow> {
    vec![
        SoaRow {
            name: "Ara [27]",
            domain: "High-perf.",
            technology: "GF 22FDX",
            voltage: "0.80",
            freq_ghz: 1.04,
            area_mm2: Some(2.14),
            perf_gflops: 64.80,
            energy_eff: 81.60,
            area_eff: Some(30.34),
            fp_formats: "float/float16/bfloat16/minifloat",
            exec_model: "SIMD vector unit (accelerator)",
        },
        SoaRow {
            name: "Hwacha [28]",
            domain: "High-perf.",
            technology: "45nm SOI",
            voltage: "0.80",
            freq_ghz: 0.55,
            area_mm2: Some(3.00),
            perf_gflops: 3.44,
            energy_eff: 25.00,
            area_eff: Some(1.14),
            fp_formats: "double/float",
            exec_model: "SIMT vector-thread unit (accelerator)",
        },
        SoaRow {
            name: "Snitch [42]",
            domain: "High-perf.",
            technology: "GF 22FDX",
            voltage: "0.80",
            freq_ghz: 1.06,
            area_mm2: Some(0.89),
            perf_gflops: 14.38,
            energy_eff: 103.84,
            area_eff: Some(25.83),
            fp_formats: "double/float",
            exec_model: "Loop-buffer tensor streaming (accelerator)",
        },
        SoaRow {
            name: "Ariane [41]",
            domain: "High-perf.",
            technology: "GF 22FDX",
            voltage: "0.80",
            freq_ghz: 0.92,
            area_mm2: Some(0.39),
            perf_gflops: 2.04,
            energy_eff: 33.02,
            area_eff: Some(5.23),
            fp_formats: "float/float16/bfloat16/minifloat",
            exec_model: "SIMD processor",
        },
        SoaRow {
            name: "NTX [41]",
            domain: "High-perf.",
            technology: "GF 22FDX",
            voltage: "0.80",
            freq_ghz: 1.55,
            area_mm2: Some(0.56),
            perf_gflops: 18.27,
            energy_eff: 110.05,
            area_eff: Some(32.63),
            fp_formats: "float (wide accum.)",
            exec_model: "Loop-buffer tensor streaming (accelerator)",
        },
        SoaRow {
            name: "Xavier",
            domain: "Embedded",
            technology: "TSMC 12FFN",
            voltage: "0.75",
            freq_ghz: 1.38,
            area_mm2: Some(11.03),
            perf_gflops: 153.00,
            energy_eff: 52.39,
            area_eff: Some(13.84),
            fp_formats: "float/float16",
            exec_model: "SIMT vector-thread unit (accelerator)",
        },
        SoaRow {
            name: "STM32H7",
            domain: "Embedded",
            technology: "40nm CMOS",
            voltage: "1.80",
            freq_ghz: 0.48,
            area_mm2: None,
            perf_gflops: 0.07,
            energy_eff: 0.44,
            area_eff: None,
            fp_formats: "float",
            exec_model: "Processor",
        },
        SoaRow {
            name: "Mr.Wolf [2]",
            domain: "Embedded",
            technology: "40nm CMOS",
            voltage: "1.10",
            freq_ghz: 0.45,
            area_mm2: Some(10.00),
            perf_gflops: 1.00,
            energy_eff: 4.50,
            area_eff: Some(1.70),
            fp_formats: "float",
            exec_model: "Multi-core processor",
        },
    ]
}

/// The paper's reported values for its own three configurations, for
/// side-by-side comparison with our measured reproduction.
pub struct PaperSelf {
    pub mnemonic: &'static str,
    pub role: &'static str,
    pub freq_ghz: f64,
    pub area_mm2: f64,
    pub perf_gflops: f64,
    pub energy_eff: f64,
    pub area_eff: f64,
}

/// Table 6 "This work" columns as printed in the paper.
pub fn paper_self_rows() -> [PaperSelf; 3] {
    [
        PaperSelf {
            mnemonic: "16c16f1p",
            role: "best perf.",
            freq_ghz: 0.37,
            area_mm2: 2.10,
            perf_gflops: 2.86,
            energy_eff: 26.0,
            area_eff: 1.50,
        },
        PaperSelf {
            mnemonic: "16c16f0p",
            role: "best en. eff.",
            freq_ghz: 0.30,
            area_mm2: 1.80,
            perf_gflops: 2.30,
            energy_eff: 81.0,
            area_eff: 0.60,
        },
        PaperSelf {
            mnemonic: "8c4f1p",
            role: "best area eff.",
            freq_ghz: 0.43,
            area_mm2: 0.97,
            perf_gflops: 1.74,
            energy_eff: 23.4,
            area_eff: 1.78,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn competitor_data_is_complete() {
        let c = competitors();
        assert_eq!(c.len(), 8);
        assert!(c.iter().all(|r| r.perf_gflops > 0.0 && r.energy_eff > 0.0));
        // Paper ordering: high-perf first, embedded after.
        assert_eq!(c[0].name, "Ara [27]");
        assert_eq!(c[7].name, "Mr.Wolf [2]");
    }

    #[test]
    fn self_rows_match_paper_anchors() {
        let s = paper_self_rows();
        assert_eq!(s[0].freq_ghz, 0.37);
        assert_eq!(s[1].energy_eff, 81.0);
        assert_eq!(s[2].area_mm2, 0.97);
    }
}
