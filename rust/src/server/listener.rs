//! TCP front end: accept loop + per-connection serving.
//!
//! One OS thread per connection (the request path is dominated by either a
//! cache probe measured in microseconds or a simulator run measured in
//! milliseconds — a thread per client is the simplest model that keeps
//! slow requests from blocking fast ones). All connections share one
//! [`Server`], so the measurement cache, the single-flight tables and the
//! metrics are global across clients.

use std::io;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;

use crate::server::router::{PipeSummary, Server};

/// Serve one accepted connection until the client closes it.
pub fn serve_connection(server: &Server, stream: TcpStream) -> io::Result<PipeSummary> {
    // Replies are small frames; latency beats batching.
    let _ = stream.set_nodelay(true);
    let reader = io::BufReader::new(stream.try_clone()?);
    let writer = io::BufWriter::new(stream);
    server.serve_pipe(reader, writer)
}

/// Accept loop: spawn a serving thread per connection. Per-connection I/O
/// errors only tear down that connection; only accept-loop errors return.
pub fn serve_tcp(server: Arc<Server>, listener: TcpListener) -> io::Result<()> {
    for stream in listener.incoming() {
        match stream {
            Ok(stream) => {
                let server = Arc::clone(&server);
                thread::spawn(move || {
                    let _ = serve_connection(&server, stream);
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::QueryEngine;
    use crate::server::codec::read_reply;
    use std::io::{BufReader, Write};

    #[test]
    fn tcp_round_trip_serves_framed_replies() {
        let server = Arc::new(Server::new(Box::leak(Box::new(QueryEngine::new()))));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        {
            let server = Arc::clone(&server);
            thread::spawn(move || serve_tcp(server, listener));
        }

        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"ping\nnot-an-endpoint\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        let pong = read_reply(&mut reader).unwrap().unwrap();
        assert!(pong.ok);
        assert_eq!(pong.rows, vec!["pong"]);
        let err = read_reply(&mut reader).unwrap().unwrap();
        assert!(!err.ok);
        assert!(err.head.starts_with("err bad-request"));

        // Close our side; the connection thread winds down on EOF.
        drop(reader);
        drop(stream);
    }
}
