//! Request router: one [`Server`] shared by every connection.
//!
//! Each wire line parses into the typed [`Request`] (the same value the CLI
//! builds), routes to the [`QueryEngine`], and renders a framed [`Reply`].
//! Two layers of deduplication keep concurrent identical traffic cheap:
//!
//! 1. **Point-level single-flight** lives inside the engine itself
//!    ([`QueryEngine::execute`]): identical in-flight cache misses coalesce
//!    onto one simulator run regardless of which endpoint produced them.
//! 2. **Request-level single-flight** here covers the non-point endpoints
//!    (`tune`, `pareto`), keyed by the request's canonical line, so sixty
//!    concurrent identical tunes run the search once and share the table.
//!
//! Failure never tears down a connection: parse errors, oversized lines,
//! bad UTF-8 and structured simulation failures all become `err` frames and
//! the loop keeps reading.
//!
//! Every handled request also leaves a [`RequestSpan`] in a bounded ring —
//! phase timings (queued / planned / simulated / serialized), the plan's
//! cache outcome, and an aggregate sim-run attribution summary derived from
//! the resolved measurements' counters. The `trace` endpoint lists the
//! recent spans; `stats` reports how many are retained.

use std::collections::VecDeque;
use std::io::{self, BufRead, Write};
use std::sync::Mutex;
use std::time::Instant;

use crate::cluster::counters::CoreCounters;
use crate::coordinator::{
    accuracy_pareto_table, measurements_table, pareto_table, Begin, LeaderPoisoned, Measurement,
    QueryEngine, QueryFailure, SingleFlight,
};
use crate::report::Table;
use crate::server::codec::{read_line_bounded, write_reply, LineIn, Reply, MAX_LINE};
use crate::server::metrics::{Endpoint, ServerMetrics};
use crate::server::request::Request;
use crate::tuner;

/// Spans retained for the `trace` endpoint (newest evicts oldest).
pub const SPAN_CAP: usize = 64;

/// One handled request's observability span: what ran, how long each phase
/// took, what the cache contributed, and — when the request resolved
/// measurements — a one-line attribution summary of the simulated work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestSpan {
    /// Canonical wire line of the request (the raw line for invalid ones).
    pub line: String,
    /// Endpoint name ([`Endpoint::name`], `invalid` for unparsable lines).
    pub endpoint: &'static str,
    /// The reply was an `ok` frame.
    pub ok: bool,
    /// Time spent before routing began (wire parse), µs.
    pub queued_us: u64,
    /// Cache planning (dedup + fingerprint + lookup), µs.
    pub planned_us: u64,
    /// Simulation / search execution, µs.
    pub simulated_us: u64,
    /// Reply rendering, µs.
    pub serialized_us: u64,
    /// Distinct points the plan resolved from the cache / simulated.
    pub hits: u64,
    pub misses: u64,
    /// Misses that landed in a cross-request batch-planner drain while this
    /// request was executing (engine-counter delta; zero for warm requests
    /// and non-query endpoints).
    pub batched: u64,
    /// Aggregate sim-run attribution (active share + dominant stall),
    /// `-` when the request resolved no measurements.
    pub attribution: String,
}

/// Per-request phase timings and attribution, filled in by [`Server::route`].
#[derive(Default)]
struct Phases {
    planned_ns: u64,
    simulated_ns: u64,
    serialized_ns: u64,
    batched: u64,
    attribution: Option<String>,
}

/// One-line attribution summary of a batch of resolved measurements:
/// aggregate active share and the dominant stall cause across every point.
/// Uses the measurements' counter aggregates — no re-simulation.
fn attribution_summary(ms: &[Measurement]) -> Option<String> {
    let mut agg = CoreCounters::default();
    for m in ms {
        agg.accumulate(&m.agg);
    }
    if agg.cycles == 0 {
        // Functional-fidelity measurements carry no timing.
        return None;
    }
    let active_pct = 100.0 * agg.active as f64 / agg.cycles as f64;
    let (top, top_cycles) =
        agg.stall_breakdown().into_iter().max_by_key(|&(_, n)| n).expect("non-empty taxonomy");
    let top_pct = 100.0 * top_cycles as f64 / agg.cycles as f64;
    Some(format!("{} pt(s) · active {active_pct:.1}% · top stall {top} {top_pct:.1}%", ms.len()))
}

/// The shared service state. Cheap to share: all interior mutability is
/// atomics and short-held locks.
pub struct Server {
    engine: &'static QueryEngine,
    metrics: ServerMetrics,
    req_flight: SingleFlight<String, Reply>,
    max_line: usize,
    /// Recent request spans, newest last ([`SPAN_CAP`] retained).
    spans: Mutex<VecDeque<RequestSpan>>,
}

impl Server {
    /// A server routing into `engine` (usually [`QueryEngine::global`]).
    pub fn new(engine: &'static QueryEngine) -> Server {
        Server {
            engine,
            metrics: ServerMetrics::new(),
            req_flight: SingleFlight::new(),
            max_line: MAX_LINE,
            spans: Mutex::new(VecDeque::with_capacity(SPAN_CAP)),
        }
    }

    /// Override the request-line bound (tests use a tiny one).
    pub fn with_max_line(mut self, max: usize) -> Server {
        self.max_line = max;
        self
    }

    pub fn engine(&self) -> &'static QueryEngine {
        self.engine
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    pub fn max_line(&self) -> usize {
        self.max_line
    }

    /// Parse and handle one wire line. Parse time is the span's `queued`
    /// phase; unparsable lines leave an `invalid` span so bad traffic is
    /// visible in `trace` output too.
    pub fn handle_line(&self, line: &str) -> Reply {
        let start = Instant::now();
        match Request::parse_line(line) {
            Ok(req) => {
                let queued_ns = elapsed_ns(start);
                self.handle_queued(&req, queued_ns)
            }
            Err(msg) => {
                self.metrics.record(Endpoint::Invalid, false, 0, 0, 0);
                self.push_span(RequestSpan {
                    line: line.to_string(),
                    endpoint: Endpoint::Invalid.name(),
                    ok: false,
                    queued_us: elapsed_ns(start) / 1_000,
                    planned_us: 0,
                    simulated_us: 0,
                    serialized_us: 0,
                    hits: 0,
                    misses: 0,
                    batched: 0,
                    attribution: "-".to_string(),
                });
                Reply::err("bad-request", msg)
            }
        }
    }

    /// Handle one typed request, recording latency and cache traffic.
    pub fn handle(&self, req: &Request) -> Reply {
        self.handle_queued(req, 0)
    }

    fn handle_queued(&self, req: &Request, queued_ns: u64) -> Reply {
        let start = Instant::now();
        let mut ph = Phases::default();
        let (reply, hits, misses) = self.route(req, &mut ph);
        let latency_ns = elapsed_ns(start);
        self.metrics.record(Endpoint::of(req), reply.is_ok(), hits, misses, latency_ns);
        self.push_span(RequestSpan {
            line: req.to_line(),
            endpoint: Endpoint::of(req).name(),
            ok: reply.is_ok(),
            queued_us: queued_ns / 1_000,
            planned_us: ph.planned_ns / 1_000,
            simulated_us: ph.simulated_ns / 1_000,
            serialized_us: ph.serialized_ns / 1_000,
            hits,
            misses,
            batched: ph.batched,
            attribution: ph.attribution.unwrap_or_else(|| "-".to_string()),
        });
        reply
    }

    fn push_span(&self, span: RequestSpan) {
        let mut spans = self.spans.lock().unwrap();
        if spans.len() == SPAN_CAP {
            spans.pop_front();
        }
        spans.push_back(span);
    }

    /// Recent request spans, oldest first.
    pub fn recent_spans(&self) -> Vec<RequestSpan> {
        self.spans.lock().unwrap().iter().cloned().collect()
    }

    /// Route a request to the engine. Returns the reply plus the cache
    /// hits/misses its plan contributed (zero for non-query endpoints);
    /// phase timings land in `ph`.
    fn route(&self, req: &Request, ph: &mut Phases) -> (Reply, u64, u64) {
        match req {
            Request::Ping => (Reply::rows(vec!["pong".to_string()]), 0, 0),
            Request::Stats => {
                let t0 = Instant::now();
                let reply = Reply::rows(csv_rows(&self.stats_table()));
                ph.serialized_ns = elapsed_ns(t0);
                (reply, 0, 0)
            }
            Request::Trace => {
                let t0 = Instant::now();
                let reply = Reply::rows(csv_rows(&self.trace_table()));
                ph.serialized_ns = elapsed_ns(t0);
                (reply, 0, 0)
            }
            Request::InjectStatus => {
                let t0 = Instant::now();
                let mut t = Table::new(vec!["class", "count"]);
                for (class, count) in self.metrics.failure_counts() {
                    t.row(vec![class.to_string(), count.to_string()]);
                }
                let reply = Reply::rows(csv_rows(&t));
                ph.serialized_ns = elapsed_ns(t0);
                (reply, 0, 0)
            }
            Request::Query { .. } => {
                let pts = req.query_points().expect("query request");
                let t0 = Instant::now();
                let plan = self.engine.plan(&pts);
                ph.planned_ns = elapsed_ns(t0);
                let (hits, misses) = (plan.hit_count() as u64, plan.miss_count() as u64);
                let batched_before = self.engine.batched_points();
                let t1 = Instant::now();
                let executed = self.engine.execute(plan);
                ph.simulated_ns = elapsed_ns(t1);
                ph.batched = self.engine.batched_points().saturating_sub(batched_before);
                self.metrics
                    .record_batched(self.engine.batched_requests(), self.engine.batched_points());
                let t2 = Instant::now();
                let reply = match executed {
                    Ok(ms) => {
                        ph.attribution = attribution_summary(&ms);
                        Reply::rows(csv_rows(&measurements_table(&ms)))
                    }
                    Err(f) => self.query_failure("query-failed", f),
                };
                ph.serialized_ns = elapsed_ns(t2);
                (reply, hits, misses)
            }
            Request::Tune { budget, probe, .. } => {
                let (budget, probe) = (*budget, *probe);
                let cfgs = req.tune_configs().expect("tune request");
                let t0 = Instant::now();
                let reply = self.coalesced(req.to_line(), || {
                    let mut reports = Vec::with_capacity(cfgs.len());
                    for cfg in &cfgs {
                        match tuner::tune_with_probe(self.engine, cfg, budget, probe) {
                            Ok(r) => reports.push(r),
                            Err(f) => return self.query_failure("tune-failed", f),
                        }
                    }
                    Reply::rows(csv_rows(&tuner::tune_table(&reports)))
                });
                ph.simulated_ns = elapsed_ns(t0);
                (reply, 0, 0)
            }
            Request::Pareto { acc } => {
                let acc = *acc;
                let t0 = Instant::now();
                let reply = self.coalesced(req.to_line(), || {
                    let table = if acc {
                        accuracy_pareto_table(self.engine)
                    } else {
                        pareto_table(self.engine)
                    };
                    match table {
                        Ok(t) => Reply::rows(csv_rows(&t)),
                        Err(f) => self.query_failure("pareto-failed", f),
                    }
                });
                ph.simulated_ns = elapsed_ns(t0);
                (reply, 0, 0)
            }
        }
    }

    /// The `trace` endpoint payload: one row per retained span, oldest
    /// first. Columns mirror [`RequestSpan`]; the request line goes last so
    /// its spaces can't be confused with column separators.
    fn trace_table(&self) -> Table {
        let mut t = Table::new(vec![
            "endpoint",
            "ok",
            "queued_us",
            "planned_us",
            "simulated_us",
            "serialized_us",
            "hits",
            "misses",
            "batched",
            "attribution",
            "request",
        ]);
        for s in self.recent_spans() {
            t.row(vec![
                s.endpoint.to_string(),
                s.ok.to_string(),
                s.queued_us.to_string(),
                s.planned_us.to_string(),
                s.simulated_us.to_string(),
                s.serialized_us.to_string(),
                s.hits.to_string(),
                s.misses.to_string(),
                s.batched.to_string(),
                s.attribution,
                s.line,
            ]);
        }
        t
    }

    /// Render a structured query failure, bucketing every per-point error
    /// by its watchdog class for `inject-status`.
    fn query_failure(&self, class: &'static str, f: QueryFailure) -> Reply {
        for e in &f.errors {
            self.metrics.record_failure_class(e.error.class());
        }
        Reply::err(class, f.to_string())
    }

    /// Request-level single-flight: identical concurrent requests run
    /// `compute` once and share the reply. Replies are published for
    /// followers but never cached beyond the flight — a later identical
    /// request recomputes (and hits the measurement cache instead). The
    /// leader's guard travels across `compute`: if the handler panics, the
    /// unwinding drop poisons the flight and every follower receives a
    /// structured error frame instead of parking forever.
    fn coalesced(&self, key: String, compute: impl FnOnce() -> Reply) -> Reply {
        match self.req_flight.begin(&key, || None) {
            Begin::Lead(guard) => {
                let reply = compute();
                guard.publish(reply.clone());
                reply
            }
            Begin::Follow(slot) => match slot.wait() {
                Ok(r) => r,
                Err(LeaderPoisoned) => {
                    Reply::err("leader-panicked", "flight leader panicked before publishing")
                }
            },
            Begin::Resolved(r) => r,
        }
    }

    /// The `stats` endpoint payload: engine, cache and service counters.
    fn stats_table(&self) -> Table {
        let cache = self.engine.stats();
        let totals = self.metrics.totals();
        let (cc_hits, cc_misses) = self.engine.code_cache().stats();
        let mut t = Table::new(vec!["counter", "value"]);
        for (k, v) in [
            ("cache_entries", cache.entries as u64),
            ("cache_hits", cache.hits),
            ("cache_misses", cache.misses),
            ("sim_runs", self.engine.sim_runs()),
            ("functional_runs", self.engine.functional_runs()),
            ("compiled_runs", self.engine.compiled_runs()),
            ("codecache_hits", cc_hits),
            ("codecache_misses", cc_misses),
            ("codecache_evictions", self.engine.code_cache().evictions()),
            ("coalesced_runs", self.engine.coalesced_runs()),
            ("duplicate_runs", self.engine.duplicate_runs()),
            ("batched_requests", self.engine.batched_requests()),
            ("batched_points", self.engine.batched_points()),
            ("planner_passes", self.engine.planner_passes()),
            ("requests", totals.requests),
            ("request_errors", totals.errors),
            ("plan_cache_hits", totals.cache_hits),
            ("plan_cache_misses", totals.cache_misses),
            ("trace_spans", self.spans.lock().unwrap().len() as u64),
        ] {
            t.row(vec![k.to_string(), v.to_string()]);
        }
        t
    }

    /// Serve one request/reply stream until EOF. Used directly for `serve
    /// --stdin` and per-connection for TCP. Every reply is flushed before
    /// the next read so a pipelining client never deadlocks on a full
    /// buffer held by an unflushed reply.
    pub fn serve_pipe<R: BufRead, W: Write>(
        &self,
        mut input: R,
        mut output: W,
    ) -> io::Result<PipeSummary> {
        let mut summary = PipeSummary::default();
        loop {
            let reply = match read_line_bounded(&mut input, self.max_line)? {
                LineIn::Eof => break,
                LineIn::Line(line) => {
                    let line = line.trim();
                    if line.is_empty() {
                        continue;
                    }
                    self.handle_line(line)
                }
                LineIn::TooLong => {
                    self.metrics.record(Endpoint::Invalid, false, 0, 0, 0);
                    Reply::err(
                        "oversized",
                        format!("request line exceeds {} bytes", self.max_line),
                    )
                }
                LineIn::BadUtf8 => {
                    self.metrics.record(Endpoint::Invalid, false, 0, 0, 0);
                    Reply::err("bad-utf8", "request line is not valid UTF-8")
                }
            };
            summary.requests += 1;
            if reply.is_ok() {
                summary.replies_ok += 1;
            } else {
                summary.replies_err += 1;
            }
            write_reply(&mut output, &reply)?;
            output.flush()?;
        }
        Ok(summary)
    }
}

/// What one stream served.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PipeSummary {
    pub requests: u64,
    pub replies_ok: u64,
    pub replies_err: u64,
}

fn csv_rows(t: &Table) -> Vec<String> {
    t.to_csv().lines().map(str::to_string).collect()
}

fn elapsed_ns(since: Instant) -> u64 {
    u64::try_from(since.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::kernels::{Benchmark, Variant};
    use crate::server::request::{QueryTier, Selector};
    use std::io::Cursor;

    fn leaked_server() -> Server {
        Server::new(Box::leak(Box::new(QueryEngine::new())))
    }

    #[test]
    fn ping_stats_and_inject_status_reply_structured_rows() {
        let server = leaked_server();
        assert_eq!(server.handle_line("ping"), Reply::Ok(vec!["pong".to_string()]));

        let Reply::Ok(rows) = server.handle_line("inject-status") else {
            panic!("inject-status must succeed");
        };
        assert_eq!(rows[0], "class,count");
        assert_eq!(rows.len(), 4, "header + one row per failure class");

        let Reply::Ok(rows) = server.handle_line("stats") else {
            panic!("stats must succeed");
        };
        assert_eq!(rows[0], "counter,value");
        assert!(rows.iter().any(|r| r.starts_with("duplicate_runs,")));
        for counter in
            ["batched_requests", "batched_points", "planner_passes", "codecache_evictions"]
        {
            assert!(
                rows.iter().any(|r| r.starts_with(&format!("{counter},"))),
                "stats must expose `{counter}`: {rows:?}"
            );
        }
    }

    #[test]
    fn query_replies_measurement_csv_and_counts_plan_traffic() {
        let server = leaked_server();
        let Reply::Ok(rows) = server.handle_line("query 8c2f0p FIR scalar") else {
            panic!("query must succeed");
        };
        assert!(rows[0].starts_with("config,bench,variant"));
        assert_eq!(rows.len(), 2, "header + one measurement");

        let (req, err, hits, misses, _, _) = server.metrics().endpoint_snapshot(Endpoint::Query);
        assert_eq!((req, err), (1, 0));
        assert_eq!((hits, misses), (0, 1), "cold query is one plan miss");

        // Same query again: served from the cache, recorded as a hit.
        assert!(server.handle_line("query 8c2f0p FIR scalar").is_ok());
        let (_, _, hits, misses, _, _) = server.metrics().endpoint_snapshot(Endpoint::Query);
        assert_eq!((hits, misses), (1, 1));
        assert_eq!(server.engine().sim_runs(), 1, "second query must not re-simulate");
    }

    #[test]
    fn malformed_lines_are_structured_errors_not_panics() {
        let server = leaked_server();
        for bad in [
            "query",
            "query 8c8f1p",
            "query bad FIR scalar",
            "query 8c8f1p NOPE scalar",
            "query 8c8f1p FIR warp",
            "tune --budget",
            "tune --budget nan",
            "tune 8c8f1p extra words",
            "run 8c2f0p FIR scalar",
            "--csv query all FIR scalar",
            "query 8c2f0p FIR scalar --csv",
            "tune --jobs 4",
        ] {
            let reply = server.handle_line(bad);
            assert!(
                matches!(reply, Reply::Err { class: "bad-request", .. }),
                "`{bad}` must be a bad-request error, got {reply:?}"
            );
        }
        let (req, err, _, _, _, _) = server.metrics().endpoint_snapshot(Endpoint::Invalid);
        assert_eq!(req, 12);
        assert_eq!(err, 12);
    }

    #[test]
    fn pipe_recovers_from_oversized_and_non_utf8_lines() {
        let server = leaked_server().with_max_line(64);
        let mut input = vec![b'x'; 200];
        input.push(b'\n');
        input.extend_from_slice(&[0xff, 0xfe, b'\n']);
        input.extend_from_slice(b"ping\n\n  \nping\n");
        let mut out = Vec::new();
        let summary = server.serve_pipe(Cursor::new(input), &mut out).unwrap();
        assert_eq!(summary, PipeSummary { requests: 4, replies_ok: 2, replies_err: 2 });

        let text = String::from_utf8(out).unwrap();
        let heads: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("ok ") || l.starts_with("err "))
            .collect();
        assert_eq!(heads.len(), 4);
        assert!(heads[0].starts_with("err oversized"));
        assert!(heads[1].starts_with("err bad-utf8"));
        assert!(heads[2].starts_with("ok 1") && heads[3].starts_with("ok 1"));
    }

    #[test]
    fn trace_endpoint_lists_recent_spans_with_phase_timings() {
        let server = leaked_server();
        // A cold query (simulates), a warm query (all hits), and a bad line.
        assert!(server.handle_line("query 8c2f0p FIR scalar").is_ok());
        assert!(server.handle_line("query 8c2f0p FIR scalar").is_ok());
        assert!(!server.handle_line("query bad FIR scalar").is_ok());

        let spans = server.recent_spans();
        assert_eq!(spans.len(), 3);
        let cold = &spans[0];
        assert_eq!(cold.endpoint, "query");
        assert!(cold.ok);
        assert_eq!((cold.hits, cold.misses), (0, 1));
        assert!(cold.simulated_us > 0, "cold query must show simulate time");
        assert!(
            cold.attribution.contains("active") && cold.attribution.contains("top stall"),
            "cold query span carries the sim-run attribution: {}",
            cold.attribution
        );
        let warm = &spans[1];
        assert_eq!((warm.hits, warm.misses), (1, 0));
        assert!(warm.attribution.contains("1 pt(s)"), "warm hits still attribute: {}", warm.attribution);
        let bad = &spans[2];
        assert_eq!(bad.endpoint, "invalid");
        assert!(!bad.ok && bad.attribution == "-");

        // The wire endpoint renders the same spans (plus its own afterwards).
        let Reply::Ok(rows) = server.handle_line("trace") else {
            panic!("trace must succeed");
        };
        assert!(rows[0].starts_with("endpoint,ok,queued_us,planned_us,simulated_us"));
        assert_eq!(rows.len(), 1 + 3, "header + the three spans handled before this request");
        assert!(rows[1].contains("query 8c2f0p FIR scalar"));
        // The trace request itself is now a span too.
        assert_eq!(server.recent_spans().len(), 4);
        assert_eq!(server.recent_spans()[3].endpoint, "trace");
    }

    #[test]
    fn span_ring_is_bounded() {
        let server = leaked_server();
        for _ in 0..(SPAN_CAP + 10) {
            assert!(server.handle_line("ping").is_ok());
        }
        assert_eq!(server.recent_spans().len(), SPAN_CAP);
        // stats reports the retained count.
        let Reply::Ok(rows) = server.handle_line("stats") else {
            panic!("stats must succeed");
        };
        assert!(
            rows.iter().any(|r| r == &format!("trace_spans,{SPAN_CAP}")),
            "stats must expose the span count: {rows:?}"
        );
    }

    #[test]
    fn cli_and_wire_build_the_same_request() {
        let argv = ["query", "8c4f1p", "FIR", "scalar"];
        let cli_req = crate::cli::parse_cli(argv.iter().map(|s| s.to_string()))
            .unwrap()
            .to_request()
            .unwrap();
        let wire_req = Request::parse_line("query 8c4f1p FIR scalar").unwrap();
        assert_eq!(cli_req, wire_req);
        assert_eq!(
            wire_req,
            Request::Query {
                cfg: Selector::One(ClusterConfig::new(8, 4, 1)),
                bench: Selector::One(Benchmark::Fir),
                variant: Selector::One(Variant::Scalar),
                tier: QueryTier::Cycle,
            }
        );
    }
}
