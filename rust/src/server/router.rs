//! Request router: one [`Server`] shared by every connection.
//!
//! Each wire line parses into the typed [`Request`] (the same value the CLI
//! builds), routes to the [`QueryEngine`], and renders a framed [`Reply`].
//! Two layers of deduplication keep concurrent identical traffic cheap:
//!
//! 1. **Point-level single-flight** lives inside the engine itself
//!    ([`QueryEngine::execute`]): identical in-flight cache misses coalesce
//!    onto one simulator run regardless of which endpoint produced them.
//! 2. **Request-level single-flight** here covers the non-point endpoints
//!    (`tune`, `pareto`), keyed by the request's canonical line, so sixty
//!    concurrent identical tunes run the search once and share the table.
//!
//! Failure never tears down a connection: parse errors, oversized lines,
//! bad UTF-8 and structured simulation failures all become `err` frames and
//! the loop keeps reading.

use std::io::{self, BufRead, Write};
use std::time::Instant;

use crate::coordinator::{
    accuracy_pareto_table, measurements_table, pareto_table, Begin, QueryEngine, QueryFailure,
    SingleFlight,
};
use crate::report::Table;
use crate::server::codec::{read_line_bounded, write_reply, LineIn, Reply, MAX_LINE};
use crate::server::metrics::{Endpoint, ServerMetrics};
use crate::server::request::Request;
use crate::tuner;

/// The shared service state. Cheap to share: all interior mutability is
/// atomics and short-held locks.
pub struct Server {
    engine: &'static QueryEngine,
    metrics: ServerMetrics,
    req_flight: SingleFlight<String, Reply>,
    max_line: usize,
}

impl Server {
    /// A server routing into `engine` (usually [`QueryEngine::global`]).
    pub fn new(engine: &'static QueryEngine) -> Server {
        Server {
            engine,
            metrics: ServerMetrics::new(),
            req_flight: SingleFlight::new(),
            max_line: MAX_LINE,
        }
    }

    /// Override the request-line bound (tests use a tiny one).
    pub fn with_max_line(mut self, max: usize) -> Server {
        self.max_line = max;
        self
    }

    pub fn engine(&self) -> &'static QueryEngine {
        self.engine
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    pub fn max_line(&self) -> usize {
        self.max_line
    }

    /// Parse and handle one wire line.
    pub fn handle_line(&self, line: &str) -> Reply {
        match Request::parse_line(line) {
            Ok(req) => self.handle(&req),
            Err(msg) => {
                self.metrics.record(Endpoint::Invalid, false, 0, 0, 0);
                Reply::err("bad-request", msg)
            }
        }
    }

    /// Handle one typed request, recording latency and cache traffic.
    pub fn handle(&self, req: &Request) -> Reply {
        let start = Instant::now();
        let (reply, hits, misses) = self.route(req);
        let latency_ns = u64::try_from(start.elapsed().as_nanos()).unwrap_or(u64::MAX);
        self.metrics.record(Endpoint::of(req), reply.is_ok(), hits, misses, latency_ns);
        reply
    }

    /// Route a request to the engine. Returns the reply plus the cache
    /// hits/misses its plan contributed (zero for non-query endpoints).
    fn route(&self, req: &Request) -> (Reply, u64, u64) {
        match req {
            Request::Ping => (Reply::rows(vec!["pong".to_string()]), 0, 0),
            Request::Stats => (Reply::rows(csv_rows(&self.stats_table())), 0, 0),
            Request::InjectStatus => {
                let mut t = Table::new(vec!["class", "count"]);
                for (class, count) in self.metrics.failure_counts() {
                    t.row(vec![class.to_string(), count.to_string()]);
                }
                (Reply::rows(csv_rows(&t)), 0, 0)
            }
            Request::Query { .. } => {
                let pts = req.query_points().expect("query request");
                let plan = self.engine.plan(&pts);
                let (hits, misses) = (plan.hit_count() as u64, plan.miss_count() as u64);
                let reply = match self.engine.execute(plan) {
                    Ok(ms) => Reply::rows(csv_rows(&measurements_table(&ms))),
                    Err(f) => self.query_failure("query-failed", f),
                };
                (reply, hits, misses)
            }
            Request::Tune { budget, probe, .. } => {
                let (budget, probe) = (*budget, *probe);
                let cfgs = req.tune_configs().expect("tune request");
                let reply = self.coalesced(req.to_line(), || {
                    let mut reports = Vec::with_capacity(cfgs.len());
                    for cfg in &cfgs {
                        match tuner::tune_with_probe(self.engine, cfg, budget, probe) {
                            Ok(r) => reports.push(r),
                            Err(f) => return self.query_failure("tune-failed", f),
                        }
                    }
                    Reply::rows(csv_rows(&tuner::tune_table(&reports)))
                });
                (reply, 0, 0)
            }
            Request::Pareto { acc } => {
                let acc = *acc;
                let reply = self.coalesced(req.to_line(), || {
                    let table = if acc {
                        accuracy_pareto_table(self.engine)
                    } else {
                        pareto_table(self.engine)
                    };
                    match table {
                        Ok(t) => Reply::rows(csv_rows(&t)),
                        Err(f) => self.query_failure("pareto-failed", f),
                    }
                });
                (reply, 0, 0)
            }
        }
    }

    /// Render a structured query failure, bucketing every per-point error
    /// by its watchdog class for `inject-status`.
    fn query_failure(&self, class: &'static str, f: QueryFailure) -> Reply {
        for e in &f.errors {
            self.metrics.record_failure_class(e.error.class());
        }
        Reply::err(class, f.to_string())
    }

    /// Request-level single-flight: identical concurrent requests run
    /// `compute` once and share the reply. Replies are published for
    /// followers but never cached beyond the flight — a later identical
    /// request recomputes (and hits the measurement cache instead).
    fn coalesced(&self, key: String, compute: impl FnOnce() -> Reply) -> Reply {
        match self.req_flight.begin(&key, || None) {
            Begin::Lead => {
                let reply = compute();
                self.req_flight.publish(&key, reply.clone());
                reply
            }
            Begin::Follow(slot) => slot.wait(),
            Begin::Resolved(r) => r,
        }
    }

    /// The `stats` endpoint payload: engine, cache and service counters.
    fn stats_table(&self) -> Table {
        let cache = self.engine.stats();
        let totals = self.metrics.totals();
        let mut t = Table::new(vec!["counter", "value"]);
        for (k, v) in [
            ("cache_entries", cache.entries as u64),
            ("cache_hits", cache.hits),
            ("cache_misses", cache.misses),
            ("sim_runs", self.engine.sim_runs()),
            ("functional_runs", self.engine.functional_runs()),
            ("coalesced_runs", self.engine.coalesced_runs()),
            ("duplicate_runs", self.engine.duplicate_runs()),
            ("requests", totals.requests),
            ("request_errors", totals.errors),
            ("plan_cache_hits", totals.cache_hits),
            ("plan_cache_misses", totals.cache_misses),
        ] {
            t.row(vec![k.to_string(), v.to_string()]);
        }
        t
    }

    /// Serve one request/reply stream until EOF. Used directly for `serve
    /// --stdin` and per-connection for TCP. Every reply is flushed before
    /// the next read so a pipelining client never deadlocks on a full
    /// buffer held by an unflushed reply.
    pub fn serve_pipe<R: BufRead, W: Write>(
        &self,
        mut input: R,
        mut output: W,
    ) -> io::Result<PipeSummary> {
        let mut summary = PipeSummary::default();
        loop {
            let reply = match read_line_bounded(&mut input, self.max_line)? {
                LineIn::Eof => break,
                LineIn::Line(line) => {
                    let line = line.trim();
                    if line.is_empty() {
                        continue;
                    }
                    self.handle_line(line)
                }
                LineIn::TooLong => {
                    self.metrics.record(Endpoint::Invalid, false, 0, 0, 0);
                    Reply::err(
                        "oversized",
                        format!("request line exceeds {} bytes", self.max_line),
                    )
                }
                LineIn::BadUtf8 => {
                    self.metrics.record(Endpoint::Invalid, false, 0, 0, 0);
                    Reply::err("bad-utf8", "request line is not valid UTF-8")
                }
            };
            summary.requests += 1;
            if reply.is_ok() {
                summary.replies_ok += 1;
            } else {
                summary.replies_err += 1;
            }
            write_reply(&mut output, &reply)?;
            output.flush()?;
        }
        Ok(summary)
    }
}

/// What one stream served.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PipeSummary {
    pub requests: u64,
    pub replies_ok: u64,
    pub replies_err: u64,
}

fn csv_rows(t: &Table) -> Vec<String> {
    t.to_csv().lines().map(str::to_string).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::kernels::{Benchmark, Variant};
    use crate::server::request::Selector;
    use std::io::Cursor;

    fn leaked_server() -> Server {
        Server::new(Box::leak(Box::new(QueryEngine::new())))
    }

    #[test]
    fn ping_stats_and_inject_status_reply_structured_rows() {
        let server = leaked_server();
        assert_eq!(server.handle_line("ping"), Reply::Ok(vec!["pong".to_string()]));

        let Reply::Ok(rows) = server.handle_line("inject-status") else {
            panic!("inject-status must succeed");
        };
        assert_eq!(rows[0], "class,count");
        assert_eq!(rows.len(), 4, "header + one row per failure class");

        let Reply::Ok(rows) = server.handle_line("stats") else {
            panic!("stats must succeed");
        };
        assert_eq!(rows[0], "counter,value");
        assert!(rows.iter().any(|r| r.starts_with("duplicate_runs,")));
    }

    #[test]
    fn query_replies_measurement_csv_and_counts_plan_traffic() {
        let server = leaked_server();
        let Reply::Ok(rows) = server.handle_line("query 8c2f0p FIR scalar") else {
            panic!("query must succeed");
        };
        assert!(rows[0].starts_with("config,bench,variant"));
        assert_eq!(rows.len(), 2, "header + one measurement");

        let (req, err, hits, misses, _, _) = server.metrics().endpoint_snapshot(Endpoint::Query);
        assert_eq!((req, err), (1, 0));
        assert_eq!((hits, misses), (0, 1), "cold query is one plan miss");

        // Same query again: served from the cache, recorded as a hit.
        assert!(server.handle_line("query 8c2f0p FIR scalar").is_ok());
        let (_, _, hits, misses, _, _) = server.metrics().endpoint_snapshot(Endpoint::Query);
        assert_eq!((hits, misses), (1, 1));
        assert_eq!(server.engine().sim_runs(), 1, "second query must not re-simulate");
    }

    #[test]
    fn malformed_lines_are_structured_errors_not_panics() {
        let server = leaked_server();
        for bad in [
            "query",
            "query 8c8f1p",
            "query bad FIR scalar",
            "query 8c8f1p NOPE scalar",
            "query 8c8f1p FIR warp",
            "tune --budget",
            "tune --budget nan",
            "tune 8c8f1p extra words",
            "run 8c2f0p FIR scalar",
            "--csv query all FIR scalar",
            "query 8c2f0p FIR scalar --csv",
            "tune --jobs 4",
        ] {
            let reply = server.handle_line(bad);
            assert!(
                matches!(reply, Reply::Err { class: "bad-request", .. }),
                "`{bad}` must be a bad-request error, got {reply:?}"
            );
        }
        let (req, err, _, _, _, _) = server.metrics().endpoint_snapshot(Endpoint::Invalid);
        assert_eq!(req, 12);
        assert_eq!(err, 12);
    }

    #[test]
    fn pipe_recovers_from_oversized_and_non_utf8_lines() {
        let server = leaked_server().with_max_line(64);
        let mut input = vec![b'x'; 200];
        input.push(b'\n');
        input.extend_from_slice(&[0xff, 0xfe, b'\n']);
        input.extend_from_slice(b"ping\n\n  \nping\n");
        let mut out = Vec::new();
        let summary = server.serve_pipe(Cursor::new(input), &mut out).unwrap();
        assert_eq!(summary, PipeSummary { requests: 4, replies_ok: 2, replies_err: 2 });

        let text = String::from_utf8(out).unwrap();
        let heads: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("ok ") || l.starts_with("err "))
            .collect();
        assert_eq!(heads.len(), 4);
        assert!(heads[0].starts_with("err oversized"));
        assert!(heads[1].starts_with("err bad-utf8"));
        assert!(heads[2].starts_with("ok 1") && heads[3].starts_with("ok 1"));
    }

    #[test]
    fn cli_and_wire_build_the_same_request() {
        let argv = ["query", "8c4f1p", "FIR", "scalar"];
        let cli_req = crate::cli::parse_cli(argv.iter().map(|s| s.to_string()))
            .unwrap()
            .to_request()
            .unwrap();
        let wire_req = Request::parse_line("query 8c4f1p FIR scalar").unwrap();
        assert_eq!(cli_req, wire_req);
        assert_eq!(
            wire_req,
            Request::Query {
                cfg: Selector::One(ClusterConfig::new(8, 4, 1)),
                bench: Selector::One(Benchmark::Fir),
                variant: Selector::One(Variant::Scalar),
            }
        );
    }
}
