//! The unified request type.
//!
//! [`Request`] is the one value both front ends produce: the CLI lowers
//! `transpfp query/tune/pareto` argument lists into it via
//! [`crate::cli::Cli::to_request`], and the serve wire protocol parses the
//! same grammar from a newline-delimited line via [`Request::parse_line`].
//! The wire is *stricter* than the CLI — the first token must be a servable
//! endpoint and only the flags named in that command's
//! [`crate::cli::CommandSpec::wire_flags`] allowlist are accepted — but a
//! line that passes the wire check is then parsed by the very same
//! registry-driven [`crate::cli::parse_cli`], so the two front ends cannot
//! drift apart.
//!
//! [`Request::to_line`] renders the canonical wire form; `parse_line ∘
//! to_line` is the identity (floats round-trip through `Display`).

use crate::cli;
use crate::config::ClusterConfig;
use crate::coordinator::{points, Fidelity, QueryPoint};
use crate::kernels::{Benchmark, Variant};
use crate::tuner::{ladder, Probe};

/// `all` or one specific value — the query grammar's axis selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selector<T> {
    All,
    One(T),
}

impl<T: Clone> Selector<T> {
    /// Expand to concrete values, pulling the full axis lazily for `All`.
    pub fn resolve(&self, all: impl FnOnce() -> Vec<T>) -> Vec<T> {
        match self {
            Selector::All => all(),
            Selector::One(v) => vec![v.clone()],
        }
    }
}

/// Which backend tier a `query` resolves its cache misses on (the
/// `--tier` flag). Architectural results are bit-identical across tiers
/// (the four-way differential wall), so the tier changes *what is
/// measured* only in that architectural tiers carry no timing; the two
/// architectural tiers even share one cache address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueryTier {
    /// Cycle-accurate event simulation — real timing (the default).
    #[default]
    Cycle,
    /// Architectural-only resolution, executed on the **compiled** tier
    /// (loop traces + fused blocks): the fast default for accuracy-only
    /// queries.
    Functional,
    /// Architectural-only resolution on the functional interpreter — an
    /// explicit opt-out of the compiled tier (differential debugging).
    Interpreter,
}

impl QueryTier {
    /// Stable name used by the CLI flag registry and the wire protocol.
    pub fn name(self) -> &'static str {
        match self {
            QueryTier::Cycle => "cycle",
            QueryTier::Functional => "functional",
            QueryTier::Interpreter => "interpreter",
        }
    }

    /// Inverse of [`QueryTier::name`] (the long form `cycle-accurate` and
    /// the engine-centric alias `compiled` are also accepted).
    pub fn parse(s: &str) -> Option<QueryTier> {
        match s {
            "cycle" | "cycle-accurate" => Some(QueryTier::Cycle),
            "functional" | "compiled" => Some(QueryTier::Functional),
            "interpreter" => Some(QueryTier::Interpreter),
            _ => None,
        }
    }
}

/// A typed service request — every endpoint the daemon (and the CLI's
/// service-shaped subcommands) can execute.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Resolve a batch of design-space points through the cache.
    Query {
        cfg: Selector<ClusterConfig>,
        bench: Selector<Benchmark>,
        variant: Selector<Variant>,
        tier: QueryTier,
    },
    /// Accuracy-aware precision autotuning under an error budget.
    Tune { cfg: Selector<ClusterConfig>, budget: f64, probe: Probe },
    /// Pareto frontier (plain or accuracy-extended).
    Pareto { acc: bool },
    /// Structured failure-class counters seen by the service.
    InjectStatus,
    /// Engine + cache + request counters.
    Stats,
    /// Recent per-request spans (queue/plan/simulate/serialize timings).
    Trace,
    /// Liveness check.
    Ping,
}

fn cfg_token(s: &Selector<ClusterConfig>) -> String {
    match s {
        Selector::All => "all".to_string(),
        Selector::One(c) => c.mnemonic(),
    }
}

impl Request {
    /// The design-space points a `Query` spans (`None` for non-queries).
    /// `all` variants means the full 5-rung precision ladder, exactly as on
    /// the CLI.
    pub fn query_points(&self) -> Option<Vec<QueryPoint>> {
        let Request::Query { cfg, bench, variant, tier } = self else {
            return None;
        };
        let cfgs = cfg.resolve(ClusterConfig::design_space);
        let benches = bench.resolve(|| Benchmark::all().to_vec());
        let variants = variant.resolve(|| ladder().to_vec());
        let pts = points(&cfgs, &benches, &variants);
        Some(match tier {
            QueryTier::Cycle => pts,
            // `with_compiled` forces Fidelity::Functional: the compiled
            // tier shares the functional cache address, it only changes
            // which engine executes a miss.
            QueryTier::Functional => pts.into_iter().map(QueryPoint::with_compiled).collect(),
            QueryTier::Interpreter => {
                pts.into_iter().map(|p| p.with_fidelity(Fidelity::Functional)).collect()
            }
        })
    }

    /// The configurations a `Tune` covers (`None` for non-tunes).
    pub fn tune_configs(&self) -> Option<Vec<ClusterConfig>> {
        let Request::Tune { cfg, .. } = self else {
            return None;
        };
        Some(cfg.resolve(ClusterConfig::design_space))
    }

    /// Canonical wire form. `parse_line(&r.to_line()) == Ok(r)`.
    pub fn to_line(&self) -> String {
        match self {
            Request::Query { cfg, bench, variant, tier } => {
                let b = match bench {
                    Selector::All => "all",
                    Selector::One(b) => b.name(),
                };
                let v = match variant {
                    Selector::All => "all",
                    Selector::One(v) => v.label(),
                };
                let t = match tier {
                    QueryTier::Cycle => String::new(),
                    t => format!(" --tier {}", t.name()),
                };
                format!("query {} {b} {v}{t}", cfg_token(cfg))
            }
            Request::Tune { cfg, budget, probe } => {
                format!("tune {} --budget {budget} --probe {}", cfg_token(cfg), probe.name())
            }
            Request::Pareto { acc: true } => "pareto --acc".to_string(),
            Request::Pareto { acc: false } => "pareto".to_string(),
            Request::InjectStatus => "inject-status".to_string(),
            Request::Stats => "stats".to_string(),
            Request::Trace => "trace".to_string(),
            Request::Ping => "ping".to_string(),
        }
    }

    /// Parse one wire line. Stricter than the CLI: the first token must be
    /// a servable endpoint, and only that endpoint's allowlisted flags may
    /// appear — `tune --jobs 4` is a structured error on the wire even
    /// though the CLI accepts `--jobs` anywhere.
    pub fn parse_line(line: &str) -> Result<Request, String> {
        let tokens: Vec<&str> = line.split_whitespace().collect();
        let Some(&first) = tokens.first() else {
            return Err("empty request".to_string());
        };
        if first.starts_with('-') {
            return Err(format!("request must start with an endpoint, not flag `{first}`"));
        }
        let spec = cli::command_spec(first).filter(|c| c.wire).ok_or_else(|| {
            format!(
                "`{first}` is not a service endpoint (expected query, tune, pareto, \
                 inject-status, stats, trace or ping)"
            )
        })?;
        for t in &tokens[1..] {
            if t.starts_with('-') && !spec.wire_flags.iter().any(|w| w == t) {
                return Err(format!("flag `{t}` is not valid for `{first}` requests"));
            }
        }
        cli::parse_cli(tokens.iter().map(|s| s.to_string()))?.to_request()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::DEFAULT_BUDGET;

    #[test]
    fn canonical_lines_round_trip() {
        let reqs = [
            Request::Query {
                cfg: Selector::One(ClusterConfig::new(8, 4, 1)),
                bench: Selector::One(Benchmark::Fir),
                variant: Selector::One(Variant::Scalar),
                tier: QueryTier::Cycle,
            },
            Request::Query {
                cfg: Selector::All,
                bench: Selector::All,
                variant: Selector::All,
                tier: QueryTier::Cycle,
            },
            Request::Query {
                cfg: Selector::One(ClusterConfig::new(8, 8, 1)),
                bench: Selector::One(Benchmark::Matmul),
                variant: Selector::One(Variant::VEC),
                tier: QueryTier::Functional,
            },
            Request::Query {
                cfg: Selector::One(ClusterConfig::new(8, 8, 1)),
                bench: Selector::One(Benchmark::Matmul),
                variant: Selector::One(Variant::VEC),
                tier: QueryTier::Interpreter,
            },
            Request::Tune {
                cfg: Selector::One(ClusterConfig::new(16, 8, 1)),
                budget: 1e-3,
                probe: Probe::CycleAccurate,
            },
            Request::Tune { cfg: Selector::All, budget: DEFAULT_BUDGET, probe: Probe::Functional },
            Request::Pareto { acc: false },
            Request::Pareto { acc: true },
            Request::InjectStatus,
            Request::Stats,
            Request::Trace,
            Request::Ping,
        ];
        for r in reqs {
            let line = r.to_line();
            assert_eq!(Request::parse_line(&line), Ok(r), "round-trip of `{line}`");
        }
    }

    #[test]
    fn wire_is_stricter_than_the_cli() {
        // CLI-only commands are not endpoints.
        let err = Request::parse_line("run 8c4f1p FIR scalar").unwrap_err();
        assert!(err.contains("not a service endpoint"), "{err}");
        // Flags outside the endpoint's allowlist are rejected by name.
        let err = Request::parse_line("tune 8c8f1p --jobs 4").unwrap_err();
        assert!(err.contains("--jobs") && err.contains("tune"), "{err}");
        let err = Request::parse_line("query 8c8f1p FIR scalar --csv").unwrap_err();
        assert!(err.contains("--csv"), "{err}");
        // Leading flags and empty lines are structured errors.
        assert!(Request::parse_line("--csv query all FIR scalar").is_err());
        assert!(Request::parse_line("   ").is_err());
    }

    #[test]
    fn query_points_span_the_selectors() {
        let one = Request::Query {
            cfg: Selector::One(ClusterConfig::new(8, 2, 0)),
            bench: Selector::One(Benchmark::Fir),
            variant: Selector::One(Variant::Scalar),
            tier: QueryTier::Cycle,
        };
        assert_eq!(one.query_points().unwrap().len(), 1);

        let ladder_width = ladder().len();
        let all_variants = Request::Query {
            cfg: Selector::One(ClusterConfig::new(8, 2, 0)),
            bench: Selector::One(Benchmark::Fir),
            variant: Selector::All,
            tier: QueryTier::Cycle,
        };
        assert_eq!(all_variants.query_points().unwrap().len(), ladder_width);

        assert!(Request::Ping.query_points().is_none());
        assert_eq!(
            Request::Tune { cfg: Selector::All, budget: 1e-2, probe: Probe::Functional }
                .tune_configs()
                .unwrap()
                .len(),
            ClusterConfig::design_space().len()
        );
    }

    /// `--tier` selects the misses' execution tier: the default is
    /// cycle-accurate, `functional` routes through the compiled engine
    /// (same cache address as the interpreter), `interpreter` opts out.
    #[test]
    fn query_tier_selects_fidelity_and_engine() {
        let mk = |tier| Request::Query {
            cfg: Selector::One(ClusterConfig::new(8, 2, 0)),
            bench: Selector::One(Benchmark::Fir),
            variant: Selector::One(Variant::Scalar),
            tier,
        };
        let ca = mk(QueryTier::Cycle).query_points().unwrap();
        assert_eq!(ca[0].fidelity, Fidelity::CycleAccurate);
        assert!(!ca[0].compiled);
        let fast = mk(QueryTier::Functional).query_points().unwrap();
        assert_eq!(fast[0].fidelity, Fidelity::Functional);
        assert!(fast[0].compiled, "functional tier must route through the compiled engine");
        let interp = mk(QueryTier::Interpreter).query_points().unwrap();
        assert_eq!(interp[0].fidelity, Fidelity::Functional);
        assert!(!interp[0].compiled);
        // The default renders bare; overrides carry the flag; aliases parse.
        assert!(!mk(QueryTier::Cycle).to_line().contains("--tier"));
        assert!(mk(QueryTier::Functional).to_line().ends_with("--tier functional"));
        assert_eq!(QueryTier::parse("compiled"), Some(QueryTier::Functional));
        assert_eq!(QueryTier::parse("cycle-accurate"), Some(QueryTier::Cycle));
        assert_eq!(QueryTier::parse("warp-speed"), None);
    }
}
