//! `transpfp serve` — the concurrent design-space query service.
//!
//! A long-running daemon that answers `query` / `tune` / `pareto` /
//! `inject-status` / `stats` / `ping` requests over a newline-delimited
//! protocol, on TCP (loopback) or a stdin/stdout pipe. The layering:
//!
//! * [`request`] — the typed [`Request`] both the CLI and the wire build
//!   (one grammar, two front ends), plus the canonical line codec;
//! * [`codec`] — bounded line reads and `ok <n>` / `err <class>` reply
//!   frames; malformed, oversized and non-UTF-8 input become structured
//!   errors, never panics or desyncs;
//! * [`router`] — the shared [`Server`]: routes requests into the global
//!   [`crate::coordinator::QueryEngine`], coalesces identical in-flight
//!   `tune`/`pareto` requests (point-level coalescing for `query` lives in
//!   the engine's own single-flight), and records per-endpoint metrics;
//! * [`metrics`] — relaxed-atomic request/error/hit/latency counters with
//!   a stable CSV schema;
//! * [`listener`] — thread-per-connection TCP accept loop.
//!
//! Concurrency contract (gated by `benches/serve.rs`): N concurrent
//! identical cold requests execute the simulator exactly once, and the
//! warm path sustains ≥100k queries/s across pipelined connections. See
//! EXPERIMENTS.md §Serve for the protocol grammar.

pub mod codec;
pub mod listener;
pub mod metrics;
pub mod request;
pub mod router;

pub use codec::{read_reply, LineIn, Reply, WireReply, MAX_LINE};
pub use listener::{serve_connection, serve_tcp};
pub use metrics::{Endpoint, MetricsTotals, ServerMetrics};
pub use request::{QueryTier, Request, Selector};
pub use router::{PipeSummary, Server};
