//! Per-endpoint service counters.
//!
//! Every request the router handles is recorded against its endpoint:
//! request and error counts, cache hits/misses contributed by the request's
//! query plan, and latency (cumulative + max, nanoseconds). Structured
//! simulation failures are additionally bucketed by watchdog class
//! (deadlock / timeout / fault) for the `inject-status` endpoint. All
//! counters are relaxed atomics — recording must never serialize the
//! request path it is measuring.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::report::Table;
use crate::server::request::Request;

/// The service endpoints, plus the `Invalid` bucket for lines that never
/// parsed into a [`Request`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    Query,
    Tune,
    Pareto,
    InjectStatus,
    Stats,
    Trace,
    Ping,
    Invalid,
}

impl Endpoint {
    /// Every endpoint, in metrics-table order.
    pub const ALL: [Endpoint; 8] = [
        Endpoint::Query,
        Endpoint::Tune,
        Endpoint::Pareto,
        Endpoint::InjectStatus,
        Endpoint::Stats,
        Endpoint::Trace,
        Endpoint::Ping,
        Endpoint::Invalid,
    ];

    /// Stable wire name.
    pub fn name(self) -> &'static str {
        match self {
            Endpoint::Query => "query",
            Endpoint::Tune => "tune",
            Endpoint::Pareto => "pareto",
            Endpoint::InjectStatus => "inject-status",
            Endpoint::Stats => "stats",
            Endpoint::Trace => "trace",
            Endpoint::Ping => "ping",
            Endpoint::Invalid => "invalid",
        }
    }

    /// The endpoint a parsed request belongs to.
    pub fn of(req: &Request) -> Endpoint {
        match req {
            Request::Query { .. } => Endpoint::Query,
            Request::Tune { .. } => Endpoint::Tune,
            Request::Pareto { .. } => Endpoint::Pareto,
            Request::InjectStatus => Endpoint::InjectStatus,
            Request::Stats => Endpoint::Stats,
            Request::Trace => Endpoint::Trace,
            Request::Ping => Endpoint::Ping,
        }
    }

    fn index(self) -> usize {
        match self {
            Endpoint::Query => 0,
            Endpoint::Tune => 1,
            Endpoint::Pareto => 2,
            Endpoint::InjectStatus => 3,
            Endpoint::Stats => 4,
            Endpoint::Trace => 5,
            Endpoint::Ping => 6,
            Endpoint::Invalid => 7,
        }
    }
}

#[derive(Default)]
struct EndpointStats {
    requests: AtomicU64,
    errors: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    latency_ns: AtomicU64,
    latency_max_ns: AtomicU64,
}

/// Cross-endpoint totals.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsTotals {
    pub requests: u64,
    pub errors: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// All service counters; shared by every connection thread.
#[derive(Default)]
pub struct ServerMetrics {
    per: [EndpointStats; 8],
    deadlocks: AtomicU64,
    timeouts: AtomicU64,
    faults: AtomicU64,
    batched_requests: AtomicU64,
    batched_points: AtomicU64,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one handled request.
    pub fn record(&self, ep: Endpoint, ok: bool, hits: u64, misses: u64, latency_ns: u64) {
        let s = &self.per[ep.index()];
        s.requests.fetch_add(1, Relaxed);
        if !ok {
            s.errors.fetch_add(1, Relaxed);
        }
        s.cache_hits.fetch_add(hits, Relaxed);
        s.cache_misses.fetch_add(misses, Relaxed);
        s.latency_ns.fetch_add(latency_ns, Relaxed);
        s.latency_max_ns.fetch_max(latency_ns, Relaxed);
    }

    /// Bucket one structured simulation failure by its watchdog class
    /// ([`crate::cluster::RunError::class`]).
    pub fn record_failure_class(&self, class: &str) {
        match class {
            "deadlock" => self.deadlocks.fetch_add(1, Relaxed),
            "timeout" => self.timeouts.fetch_add(1, Relaxed),
            _ => self.faults.fetch_add(1, Relaxed),
        };
    }

    /// Mirror the engine's cross-request batch-planner totals. The engine
    /// owns the authoritative counters; the router feeds the latest observed
    /// totals here after each query so the metrics snapshot can report them
    /// without reaching into the coordinator. `fetch_max` keeps the mirror
    /// monotone when concurrent observers race to publish their reads.
    pub fn record_batched(&self, total_requests: u64, total_points: u64) {
        self.batched_requests.fetch_max(total_requests, Relaxed);
        self.batched_points.fetch_max(total_points, Relaxed);
    }

    /// `(batched_requests, batched_points)` — the latest engine totals seen
    /// by [`ServerMetrics::record_batched`].
    pub fn batched(&self) -> (u64, u64) {
        (self.batched_requests.load(Relaxed), self.batched_points.load(Relaxed))
    }

    /// `(class, count)` for every failure class, stable order.
    pub fn failure_counts(&self) -> [(&'static str, u64); 3] {
        [
            ("deadlock", self.deadlocks.load(Relaxed)),
            ("timeout", self.timeouts.load(Relaxed)),
            ("fault", self.faults.load(Relaxed)),
        ]
    }

    /// `(requests, errors, cache_hits, cache_misses, latency_ns,
    /// latency_max_ns)` for one endpoint.
    pub fn endpoint_snapshot(&self, ep: Endpoint) -> (u64, u64, u64, u64, u64, u64) {
        let s = &self.per[ep.index()];
        (
            s.requests.load(Relaxed),
            s.errors.load(Relaxed),
            s.cache_hits.load(Relaxed),
            s.cache_misses.load(Relaxed),
            s.latency_ns.load(Relaxed),
            s.latency_max_ns.load(Relaxed),
        )
    }

    /// Totals across every endpoint.
    pub fn totals(&self) -> MetricsTotals {
        let mut t = MetricsTotals::default();
        for ep in Endpoint::ALL {
            let (req, err, hits, misses, _, _) = self.endpoint_snapshot(ep);
            t.requests += req;
            t.errors += err;
            t.cache_hits += hits;
            t.cache_misses += misses;
        }
        t
    }

    /// The per-endpoint metrics table. Every endpoint gets a row even at
    /// zero requests so the CSV schema is stable run to run.
    pub fn table(&self) -> Table {
        let mut t = Table::new(vec![
            "endpoint",
            "requests",
            "errors",
            "cache_hits",
            "cache_misses",
            "hit_rate",
            "avg_latency_us",
            "max_latency_us",
        ]);
        for ep in Endpoint::ALL {
            let (req, err, hits, misses, lat_ns, max_ns) = self.endpoint_snapshot(ep);
            let lookups = hits + misses;
            let hit_rate = if lookups > 0 { 100.0 * hits as f64 / lookups as f64 } else { 0.0 };
            let avg_us = if req > 0 { lat_ns as f64 / req as f64 / 1e3 } else { 0.0 };
            t.row(vec![
                ep.name().to_string(),
                req.to_string(),
                err.to_string(),
                hits.to_string(),
                misses.to_string(),
                format!("{hit_rate:.1}%"),
                format!("{avg_us:.1}"),
                format!("{:.1}", max_ns as f64 / 1e3),
            ]);
        }
        t
    }

    /// The metrics table as CSV.
    pub fn to_csv(&self) -> String {
        self.table().to_csv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_endpoint() {
        let m = ServerMetrics::new();
        m.record(Endpoint::Query, true, 3, 1, 2_000);
        m.record(Endpoint::Query, false, 0, 0, 10_000);
        m.record(Endpoint::Ping, true, 0, 0, 500);

        let (req, err, hits, misses, lat, max) = m.endpoint_snapshot(Endpoint::Query);
        assert_eq!((req, err, hits, misses), (2, 1, 3, 1));
        assert_eq!(lat, 12_000);
        assert_eq!(max, 10_000);

        let t = m.totals();
        assert_eq!(t.requests, 3);
        assert_eq!(t.errors, 1);
        assert_eq!(t.cache_hits, 3);
        assert_eq!(t.cache_misses, 1);
    }

    #[test]
    fn failure_classes_bucket_by_watchdog_class() {
        let m = ServerMetrics::new();
        m.record_failure_class("deadlock");
        m.record_failure_class("timeout");
        m.record_failure_class("timeout");
        m.record_failure_class("fault");
        m.record_failure_class("anything-else");
        assert_eq!(m.failure_counts(), [("deadlock", 1), ("timeout", 2), ("fault", 2)]);
    }

    #[test]
    fn batched_mirror_is_monotone() {
        let m = ServerMetrics::new();
        assert_eq!(m.batched(), (0, 0));
        m.record_batched(3, 12);
        m.record_batched(2, 9); // stale observation: must not roll back
        assert_eq!(m.batched(), (3, 12));
        m.record_batched(5, 40);
        assert_eq!(m.batched(), (5, 40));
    }

    #[test]
    fn metrics_csv_has_a_stable_schema() {
        let m = ServerMetrics::new();
        let csv = m.to_csv();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "endpoint,requests,errors,cache_hits,cache_misses,hit_rate,avg_latency_us,max_latency_us"
        );
        // One row per endpoint, even with zero traffic.
        assert_eq!(lines.count(), Endpoint::ALL.len());
        m.record(Endpoint::Tune, true, 1, 1, 1_000);
        assert_eq!(m.to_csv().lines().count(), 1 + Endpoint::ALL.len());
    }
}
