//! Wire framing: bounded line reads and `ok`/`err` reply frames.
//!
//! Requests are newline-delimited UTF-8 lines. Replies are framed so a
//! pipelining client can always resynchronize:
//!
//! ```text
//! ok <n>\n        followed by exactly n payload rows (CSV), or
//! err <class> <message>\n
//! ```
//!
//! The reader is *bounded*: a line longer than the limit is consumed up to
//! its newline and reported as [`LineIn::TooLong`] instead of growing an
//! unbounded buffer — a misbehaving client gets a structured `oversized`
//! error and the connection keeps serving. Invalid UTF-8 likewise maps to
//! [`LineIn::BadUtf8`], never a panic.

use std::io::{self, BufRead, Write};

/// Default request-line bound: far above any legitimate request (the
/// longest canonical request line is well under 100 bytes) but small enough
/// that a garbage stream cannot balloon resident memory.
pub const MAX_LINE: usize = 64 * 1024;

/// One framed read off the request stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LineIn {
    /// Clean end of stream.
    Eof,
    /// A complete line (without the trailing newline).
    Line(String),
    /// The line exceeded the bound; it was consumed through its newline.
    TooLong,
    /// The line was not valid UTF-8; it was consumed through its newline.
    BadUtf8,
}

/// Read one newline-terminated line, never buffering more than `max`
/// bytes of it. A final line without a trailing newline (EOF mid-line)
/// still counts as a line.
pub fn read_line_bounded<R: BufRead>(r: &mut R, max: usize) -> io::Result<LineIn> {
    let mut buf: Vec<u8> = Vec::new();
    let mut overflow = false;
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            // EOF. Partial data (or a consumed overflow) still terminates.
            return Ok(if overflow {
                LineIn::TooLong
            } else if buf.is_empty() {
                LineIn::Eof
            } else {
                finish(buf)
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(nl) => {
                if !overflow && buf.len() + nl <= max {
                    buf.extend_from_slice(&chunk[..nl]);
                } else {
                    overflow = true;
                }
                r.consume(nl + 1);
                return Ok(if overflow { LineIn::TooLong } else { finish(buf) });
            }
            None => {
                let take = chunk.len();
                if !overflow && buf.len() + take <= max {
                    buf.extend_from_slice(chunk);
                } else {
                    overflow = true;
                }
                r.consume(take);
            }
        }
    }
}

fn finish(buf: Vec<u8>) -> LineIn {
    match String::from_utf8(buf) {
        Ok(mut s) => {
            if s.ends_with('\r') {
                s.pop();
            }
            LineIn::Line(s)
        }
        Err(_) => LineIn::BadUtf8,
    }
}

/// One reply frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Reply {
    /// Success: the payload rows (typically CSV, header first).
    Ok(Vec<String>),
    /// Structured failure with a machine-stable class token.
    Err { class: &'static str, msg: String },
}

impl Reply {
    /// Success from payload rows.
    pub fn rows(rows: Vec<String>) -> Reply {
        Reply::Ok(rows)
    }

    /// Structured error.
    pub fn err(class: &'static str, msg: impl Into<String>) -> Reply {
        Reply::Err { class, msg: msg.into() }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, Reply::Ok(_))
    }
}

/// Write one reply frame. Embedded newlines in the error message are
/// flattened so the frame stays one header line.
pub fn write_reply<W: Write>(w: &mut W, reply: &Reply) -> io::Result<()> {
    match reply {
        Reply::Ok(rows) => {
            writeln!(w, "ok {}", rows.len())?;
            for row in rows {
                writeln!(w, "{row}")?;
            }
        }
        Reply::Err { class, msg } => {
            let flat: String =
                msg.chars().map(|c| if c == '\n' || c == '\r' { ' ' } else { c }).collect();
            writeln!(w, "err {class} {flat}")?;
        }
    }
    Ok(())
}

/// A reply as decoded by a client.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireReply {
    /// `true` for `ok` frames.
    pub ok: bool,
    /// The header line (`ok <n>` or `err <class> <msg>`).
    pub head: String,
    /// Payload rows of an `ok` frame.
    pub rows: Vec<String>,
}

/// Client-side frame decoder: `None` on clean EOF, `InvalidData` on a
/// stream that does not follow the framing.
pub fn read_reply<R: BufRead>(r: &mut R) -> io::Result<Option<WireReply>> {
    let mut head = String::new();
    if r.read_line(&mut head)? == 0 {
        return Ok(None);
    }
    let head = head.trim_end().to_string();
    if let Some(count) = head.strip_prefix("ok ") {
        let n: usize = count
            .trim()
            .parse()
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, format!("bad frame: {head}")))?;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let mut row = String::new();
            if r.read_line(&mut row)? == 0 {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "truncated ok frame"));
            }
            rows.push(row.trim_end().to_string());
        }
        Ok(Some(WireReply { ok: true, head, rows }))
    } else if head.starts_with("err ") {
        Ok(Some(WireReply { ok: false, head, rows: Vec::new() }))
    } else {
        Err(io::Error::new(io::ErrorKind::InvalidData, format!("bad frame: {head}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn bounded_reader_frames_lines_and_overflow() {
        let mut r = Cursor::new(b"ping\nstats\r\n".to_vec());
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::Line("ping".to_string()));
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::Line("stats".to_string()));
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::Eof);

        // Oversized line is consumed through its newline; the next line is
        // still served (recovery, not desync).
        let long = vec![b'x'; 200];
        let mut input = long.clone();
        input.push(b'\n');
        input.extend_from_slice(b"ping\n");
        let mut r = Cursor::new(input);
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::TooLong);
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::Line("ping".to_string()));

        // Truncated final line (no newline at EOF) still arrives.
        let mut r = Cursor::new(b"ping".to_vec());
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::Line("ping".to_string()));

        // Oversized truncated final line is TooLong, not a hang or panic.
        let mut r = Cursor::new(vec![b'y'; 200]);
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::TooLong);

        // Invalid UTF-8 is structured.
        let mut r = Cursor::new(vec![0xff, 0xfe, b'\n']);
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), LineIn::BadUtf8);
    }

    #[test]
    fn reply_frames_round_trip() {
        let mut out = Vec::new();
        write_reply(&mut out, &Reply::rows(vec!["a,b".to_string(), "1,2".to_string()])).unwrap();
        write_reply(&mut out, &Reply::err("bad-request", "multi\nline\rmessage")).unwrap();
        write_reply(&mut out, &Reply::rows(Vec::new())).unwrap();

        let mut r = Cursor::new(out);
        let first = read_reply(&mut r).unwrap().unwrap();
        assert!(first.ok);
        assert_eq!(first.rows, vec!["a,b", "1,2"]);
        let second = read_reply(&mut r).unwrap().unwrap();
        assert!(!second.ok);
        assert_eq!(second.head, "err bad-request multi line message");
        let third = read_reply(&mut r).unwrap().unwrap();
        assert!(third.ok && third.rows.is_empty());
        assert!(read_reply(&mut r).unwrap().is_none());
    }

    #[test]
    fn client_decoder_rejects_unframed_streams() {
        let mut r = Cursor::new(b"hello world\n".to_vec());
        assert!(read_reply(&mut r).is_err());
        let mut r = Cursor::new(b"ok two\n".to_vec());
        assert!(read_reply(&mut r).is_err());
        let mut r = Cursor::new(b"ok 3\nonly-one-row\n".to_vec());
        assert!(read_reply(&mut r).is_err());
    }
}
