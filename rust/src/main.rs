//! `transpfp` — CLI launcher for the transprecision-cluster reproduction.
//!
//! Subcommands regenerate every table/figure of the paper, run individual
//! benchmarks, and validate the simulator's numerics against the
//! AOT-compiled JAX/Pallas goldens (`artifacts/*.hlo.txt`).

use std::process::ExitCode;

use transpfp::config::{ClusterConfig, Corner};
use transpfp::coordinator::{self, run_one};
use transpfp::kernels::{Benchmark, Variant};
use transpfp::model;
use transpfp::transfp::FpMode;

const USAGE: &str = "\
transpfp — transprecision FP cluster reproduction (TPDS 2021)

USAGE: transpfp <command> [args]

COMMANDS:
  configs                 list the Table 2 design space
  run <cfg> <bench> <scalar|vector|bf16>
                          run one benchmark (e.g. `run 8c4f1p MATMUL vector`)
  table3                  FP/memory intensities (measured vs paper)
  table4                  8-core benchmark tables (perf / e-eff / a-eff)
  table5                  16-core benchmark tables
  table6                  state-of-the-art comparison (measured + paper)
  fig3                    fmax spread per pipeline/corner
  fig4                    area per configuration
  fig5                    power @100 MHz per configuration
  fig6                    parallel + vectorization speed-ups (16-core)
  fig7                    metrics vs FPU sharing factor
  fig8                    metrics vs pipeline stages
  validate [dir]          check simulator numerics vs XLA goldens (artifacts/)
  sweep                   run the full 18x8x2 design space, CSV to stdout

Add `--csv` to any table command for CSV output.";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let csv = args.iter().any(|a| a == "--csv");
    let args: Vec<&str> = args.iter().map(|s| s.as_str()).filter(|a| *a != "--csv").collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };

    let emit = |t: transpfp::report::Table| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
    };

    match *cmd {
        "configs" => {
            println!(
                "Table 2 design space ({} configurations):",
                ClusterConfig::design_space().len()
            );
            for cfg in ClusterConfig::design_space() {
                println!(
                    "  {:9}  fmax {}MHz(ST) {}MHz(NT)  area {:.2} mm2",
                    cfg.mnemonic(),
                    model::fmax_mhz(&cfg, Corner::St).round(),
                    model::fmax_mhz(&cfg, Corner::Nt).round(),
                    model::area_mm2(&cfg)
                );
            }
        }
        "run" => {
            if args.len() < 4 {
                eprintln!("usage: transpfp run <cfg> <bench> <scalar|vector|bf16>");
                return ExitCode::FAILURE;
            }
            let Some(cfg) = ClusterConfig::parse(args[1]) else {
                eprintln!("bad config mnemonic {}", args[1]);
                return ExitCode::FAILURE;
            };
            let Some(bench) = Benchmark::parse(args[2]) else {
                eprintln!("unknown benchmark {}", args[2]);
                return ExitCode::FAILURE;
            };
            let variant = match args[3] {
                "scalar" => Variant::Scalar,
                "vector" | "f16" => Variant::VEC,
                "bf16" => Variant::Vector(FpMode::VecBf16),
                other => {
                    eprintln!("unknown variant {other}");
                    return ExitCode::FAILURE;
                }
            };
            let m = run_one(&cfg, bench, variant);
            println!("{} {} on {}:", bench.name(), variant.label(), cfg.mnemonic());
            println!("  cycles            {}", m.cycles);
            println!("  flops/cycle       {:.3}", m.metrics.flops_per_cycle);
            println!(
                "  perf              {:.2} Gflop/s @ {} MHz (ST)",
                m.metrics.perf_gflops,
                model::fmax_mhz(&cfg, Corner::St).round()
            );
            println!("  energy efficiency {:.1} Gflop/s/W (NT)", m.metrics.energy_eff);
            println!("  area efficiency   {:.2} Gflop/s/mm2", m.metrics.area_eff);
            println!(
                "  FP intensity      {:.2}   memory intensity {:.2}",
                m.fp_intensity, m.mem_intensity
            );
            println!("  verified          {}", m.verified);
            println!(
                "  counters          active={} fpu_cont={} fpu_stall={} tcdm_cont={} wb={} icache={} barrier={}",
                m.agg.active,
                m.agg.fpu_cont,
                m.agg.fpu_stall,
                m.agg.tcdm_cont,
                m.agg.wb_stall,
                m.agg.icache_stall,
                m.agg.barrier_idle
            );
            if !m.verified {
                return ExitCode::FAILURE;
            }
        }
        "table3" => emit(coordinator::table3()),
        "table4" => emit(coordinator::table45(8)),
        "table5" => emit(coordinator::table45(16)),
        "table6" => emit(coordinator::table6()),
        "fig3" => emit(coordinator::fig3()),
        "fig4" => emit(coordinator::fig4()),
        "fig5" => emit(coordinator::fig5()),
        "fig6" => emit(coordinator::fig6()),
        "fig7" => emit(coordinator::fig7()),
        "fig8" => emit(coordinator::fig8()),
        "sweep" => {
            let ms = coordinator::sweep_all();
            println!("config,bench,variant,cycles,flops_per_cycle,perf_gflops,energy_eff,area_eff,fp_intensity,mem_intensity,verified");
            for m in ms {
                println!(
                    "{},{},{},{},{:.4},{:.4},{:.2},{:.3},{:.3},{:.3},{}",
                    m.cfg.mnemonic(),
                    m.bench.name(),
                    m.variant.label(),
                    m.cycles,
                    m.metrics.flops_per_cycle,
                    m.metrics.perf_gflops,
                    m.metrics.energy_eff,
                    m.metrics.area_eff,
                    m.fp_intensity,
                    m.mem_intensity,
                    m.verified
                );
            }
        }
        "validate" => {
            let dir = args.get(1).copied().unwrap_or("artifacts");
            match transpfp::runtime::validate_all(dir) {
                Ok(report) => {
                    print!("{report}");
                }
                Err(e) => {
                    eprintln!("validation failed: {e}");
                    return ExitCode::FAILURE;
                }
            }
        }
        other => {
            eprintln!("unknown command {other}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}
