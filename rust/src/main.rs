//! `transpfp` — CLI launcher for the transprecision-cluster reproduction.
//!
//! Subcommands regenerate every table/figure of the paper, run individual
//! benchmarks, resolve arbitrary design-space queries, and validate the
//! simulator's numerics against the AOT-compiled JAX/Pallas goldens
//! (`artifacts/*.hlo.txt`). Every command that consumes full-occupancy
//! measurements goes through the memoizing query engine: results persist
//! under `artifacts/cache/` (override with `TRANSPFP_CACHE_DIR`, disable
//! with `--no-cache`), so repeated invocations skip simulation entirely.

use std::process::ExitCode;

use transpfp::cluster::BackendKind;
use transpfp::config::{ClusterConfig, Corner};
use transpfp::coordinator::{self, QueryEngine};
use transpfp::faults::{self, SiteClass};
use transpfp::kernels::{Benchmark, Variant};
use transpfp::model;
use transpfp::report;
use transpfp::transfp::FpMode;
use transpfp::tuner;

const USAGE: &str = "\
transpfp — transprecision FP cluster reproduction (TPDS 2021)

USAGE: transpfp <command> [args] [flags]

COMMANDS:
  configs                 list the Table 2 design space
  run <cfg> <bench> <variant>
                          run one benchmark (e.g. `run 8c4f1p MATMUL vector`);
                          variants: scalar, scalar-f16, scalar-bf16,
                          vector (vector-f16), vector-bf16; with
                          --tiles <t>, run the DMA double-buffered tiled
                          build (MATMUL/CONV scalar, dataset in L2 beyond
                          the TCDM, streamed through ping-pong buffers);
                          with --backend <event|reference|functional>, run
                          uncached on the chosen execution tier (the
                          functional tier verifies numerics with no timing)
  query <cfg|all> <bench|all> <variant|all>
                          resolve a batch of design-space points through the
                          measurement cache (plan stats on stderr); `all`
                          spans the full 5-rung precision ladder
  tune [cfg|all]          accuracy-aware precision autotuning: select the
                          cheapest admissible ladder rung per benchmark
                          under --budget (relative L2 error vs the f64
                          reference; default 1e-2); default config 8c8f1p.
                          --probe functional (default) measures every
                          rung's accuracy on the functional backend and
                          simulates only admissible rungs; --probe cycle
                          restores all-cycle-accurate probing
  pareto                  Pareto frontier of the full design space over
                          (Gflop/s, Gflop/s/W, Gflop/s/mm^2); with --acc,
                          the accuracy-extended frontier over
                          (rel. error, Gflop/s, Gflop/s/W) across the ladder
  table3                  FP/memory intensities (measured vs paper)
  table4                  8-core benchmark tables (perf / e-eff / a-eff)
  table5                  16-core benchmark tables
  table6                  state-of-the-art comparison (measured + paper)
  fig3                    fmax spread per pipeline/corner
  fig4                    area per configuration
  fig5                    power @100 MHz per configuration (cache-backed)
  fig6                    parallel + vectorization speed-ups on the 16-core
                          configurations: occupancy (1..=16 workers) is
                          swept through the fork-join runtime's teams and
                          resolved via the measurement cache
  fig7                    metrics vs FPU sharing factor
  fig8                    metrics vs pipeline stages
  validate [dir]          check simulator numerics vs XLA goldens (artifacts/)
  sweep                   run the full 18x8x2 design space, CSV to stdout
  inject <cfg>            seeded SEU fault-injection campaign on one config:
                          samples --rate upset points per benchmark x rung
                          from the --seed stream, flips one bit per run in a
                          --sites structure (TCDM word, register cell, or
                          in-flight DMA payload), and classifies every point
                          as masked / tolerable / sdc / crash / hang against
                          the fault-free baseline and the binary64 reference
                          (--budget splits tolerable from sdc). Summary table
                          by default; --csv emits the per-point campaign CSV.
                          Deterministic: same seed + flags => bit-identical
                          CSV, regardless of --jobs

FLAGS:
  --csv                   CSV output for table/fig/pareto/query/tune/inject
  --no-cache              don't load or persist the measurement cache
  --acc                   accuracy-extended frontier (pareto only)
  --budget <rel-err>      error budget for `tune` and `inject` (default 1e-2)
  --tiles <t>             run the DMA double-buffered tiled kernel with t
                          tiles (`run` with MATMUL or CONV, scalar)
  --backend <b>           execution tier for `run`: event, reference or
                          functional (architectural-only, no timing)
  --probe <p>             accuracy probe for `tune`: functional (default)
                          or cycle
  --jobs <n>              cap sweep/query worker threads (default: all
                          cores, at most 16)
  --seed <s>              campaign sampling seed for `inject` (default 1)
  --rate <n>              injected points per benchmark x rung for `inject`
                          (default 8)
  --sites <list>          structure classes for `inject`: comma-separated
                          subset of tcdm,reg,dma, or `all` (default all)
  --no-recover            disable the detect-and-retry recovery loop for
                          `inject` (report raw outcomes only)

Simulation failures are structured, never panics: a hung or deadlocked run
is reported with its watchdog class, failing query points are listed per
point (resolved points stay cached), and the exit code is non-zero.

Measurements are memoized under artifacts/cache/measurements.csv, keyed by
(program fingerprint, config, variant, occupancy, fidelity, engine
version); see EXPERIMENTS.md §Cache + §Tuner + §Backends for the
invalidation rules. TRANSPFP_CACHE_DIR overrides the directory.";

/// Parsed command line: recognized flags plus positional arguments.
/// Unknown flags are an error — a typo like `--cvs` must fail loudly, not
/// be silently treated as a positional (or worse, filtered away).
struct Cli {
    csv: bool,
    no_cache: bool,
    acc: bool,
    budget: Option<f64>,
    tiles: Option<usize>,
    backend: Option<BackendKind>,
    probe: Option<tuner::Probe>,
    jobs: Option<usize>,
    seed: Option<u64>,
    rate: Option<usize>,
    sites: Option<Vec<SiteClass>>,
    no_recover: bool,
    args: Vec<String>,
}

fn parse_cli<I: IntoIterator<Item = String>>(raw: I) -> Result<Cli, String> {
    let mut cli = Cli {
        csv: false,
        no_cache: false,
        acc: false,
        budget: None,
        tiles: None,
        backend: None,
        probe: None,
        jobs: None,
        seed: None,
        rate: None,
        sites: None,
        no_recover: false,
        args: Vec::new(),
    };
    let mut it = raw.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--csv" => cli.csv = true,
            "--no-cache" => cli.no_cache = true,
            "--acc" => cli.acc = true,
            "--budget" => {
                let v = it
                    .next()
                    .ok_or_else(|| "flag `--budget` needs a value (e.g. `--budget 1e-2`)".to_string())?;
                match v.parse::<f64>() {
                    Ok(b) if b.is_finite() && b >= 0.0 => cli.budget = Some(b),
                    _ => return Err(format!("bad `--budget` value `{v}`")),
                }
            }
            "--tiles" => {
                let v = it
                    .next()
                    .ok_or_else(|| "flag `--tiles` needs a value (e.g. `--tiles 8`)".to_string())?;
                match v.parse::<usize>() {
                    Ok(t) if t >= 1 => cli.tiles = Some(t),
                    _ => return Err(format!("bad `--tiles` value `{v}`")),
                }
            }
            "--backend" => {
                let v = it.next().ok_or_else(|| {
                    "flag `--backend` needs a value (event, reference or functional)".to_string()
                })?;
                match BackendKind::parse(&v) {
                    Some(b) => cli.backend = Some(b),
                    None => return Err(format!("bad `--backend` value `{v}`")),
                }
            }
            "--probe" => {
                let v = it.next().ok_or_else(|| {
                    "flag `--probe` needs a value (functional or cycle)".to_string()
                })?;
                match v.as_str() {
                    "functional" => cli.probe = Some(tuner::Probe::Functional),
                    "cycle" | "cycle-accurate" => cli.probe = Some(tuner::Probe::CycleAccurate),
                    _ => return Err(format!("bad `--probe` value `{v}`")),
                }
            }
            "--jobs" => {
                let v = it
                    .next()
                    .ok_or_else(|| "flag `--jobs` needs a value (e.g. `--jobs 4`)".to_string())?;
                match v.parse::<usize>() {
                    Ok(n) if n >= 1 => cli.jobs = Some(n),
                    _ => return Err(format!("bad `--jobs` value `{v}` (must be >= 1)")),
                }
            }
            "--seed" => {
                let v = it
                    .next()
                    .ok_or_else(|| "flag `--seed` needs a value (e.g. `--seed 7`)".to_string())?;
                match v.parse::<u64>() {
                    Ok(s) => cli.seed = Some(s),
                    _ => return Err(format!("bad `--seed` value `{v}`")),
                }
            }
            "--rate" => {
                let v = it
                    .next()
                    .ok_or_else(|| "flag `--rate` needs a value (e.g. `--rate 16`)".to_string())?;
                match v.parse::<usize>() {
                    Ok(n) if n >= 1 => cli.rate = Some(n),
                    _ => return Err(format!("bad `--rate` value `{v}` (must be >= 1)")),
                }
            }
            "--sites" => {
                let v = it.next().ok_or_else(|| {
                    "flag `--sites` needs a value (comma-separated subset of tcdm,reg,dma, or \
                     `all`)"
                        .to_string()
                })?;
                match SiteClass::parse_list(&v) {
                    Some(s) => cli.sites = Some(s),
                    None => return Err(format!("bad `--sites` value `{v}`")),
                }
            }
            "--no-recover" => cli.no_recover = true,
            s if s.starts_with('-') => {
                return Err(format!(
                    "unknown flag `{s}` (known flags: --csv, --no-cache, --acc, \
                     --budget <rel-err>, --tiles <t>, --backend <b>, --probe <p>, \
                     --jobs <n>, --seed <s>, --rate <n>, --sites <list>, --no-recover)"
                ));
            }
            _ => cli.args.push(a),
        }
    }
    Ok(cli)
}

/// Variant names accepted by `run` and `query`: the canonical labels
/// (single source of truth: [`Variant::parse_label`]) plus historical
/// short-form aliases.
fn parse_variant(s: &str) -> Option<Variant> {
    Variant::parse_label(s).or_else(|| match s {
        "sf16" => Some(Variant::SCALAR_F16),
        "sbf16" => Some(Variant::SCALAR_BF16),
        "vector" | "f16" => Some(Variant::VEC),
        "bf16" => Some(Variant::Vector(FpMode::VecBf16)),
        _ => None,
    })
}

/// Print the result block of a direct (uncached) backend run and map
/// verification onto the exit code. Shared by `run --tiles` and
/// `run --backend`.
fn report_backend_run(
    title: &str,
    run: &transpfp::cluster::BackendRun,
    outputs: Option<usize>,
    verified: bool,
) -> ExitCode {
    println!("{title}:");
    match &run.stats {
        Some(stats) => println!("  cycles            {}", stats.total_cycles),
        None => println!("  cycles            - (architectural run)"),
    }
    println!("  instrs            {}", run.instrs);
    if let Some(n) = outputs {
        println!("  outputs           {n}");
    }
    println!("  verified          {verified}");
    if verified {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Print a structured failure report to stderr and fail the process.
/// Every simulation error reaches the user through here — the CLI never
/// panics on a hung, deadlocked, or faulting run.
fn fail(err: &dyn std::fmt::Display) -> ExitCode {
    eprintln!("{err}");
    ExitCode::FAILURE
}

/// Emit a query-backed table, or its structured failure report.
fn emit_table(
    t: Result<report::Table, coordinator::QueryFailure>,
    csv: bool,
) -> ExitCode {
    match t {
        Ok(t) => {
            if csv {
                print!("{}", t.to_csv());
            } else {
                print!("{}", t.render());
            }
            ExitCode::SUCCESS
        }
        Err(e) => fail(&e),
    }
}

fn main() -> ExitCode {
    let cli = match parse_cli(std::env::args().skip(1)) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("{e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    if let Some(jobs) = cli.jobs {
        coordinator::set_max_jobs(jobs);
    }
    if !cli.no_cache {
        coordinator::query::load_global_cache();
    }
    let code = dispatch(&cli);
    if !cli.no_cache && QueryEngine::global().stats().misses > 0 {
        if let Err(e) = coordinator::query::save_global_cache() {
            eprintln!("warning: could not persist measurement cache: {e}");
        }
    }
    code
}

fn dispatch(cli: &Cli) -> ExitCode {
    let args: Vec<&str> = cli.args.iter().map(|s| s.as_str()).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let csv = cli.csv;

    let emit = |t: transpfp::report::Table| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
    };

    match *cmd {
        "configs" => {
            println!(
                "Table 2 design space ({} configurations):",
                ClusterConfig::design_space().len()
            );
            for cfg in ClusterConfig::design_space() {
                println!(
                    "  {:9}  fmax {}MHz(ST) {}MHz(NT)  area {:.2} mm2",
                    cfg.mnemonic(),
                    model::fmax_mhz(&cfg, Corner::St).round(),
                    model::fmax_mhz(&cfg, Corner::Nt).round(),
                    model::area_mm2(&cfg)
                );
            }
        }
        "run" => {
            if args.len() < 4 {
                eprintln!(
                    "usage: transpfp run <cfg> <bench> \
                     <scalar|scalar-f16|scalar-bf16|vector|vector-bf16>"
                );
                return ExitCode::FAILURE;
            }
            let Some(cfg) = ClusterConfig::parse(args[1]) else {
                eprintln!("bad config mnemonic {}", args[1]);
                return ExitCode::FAILURE;
            };
            let Some(bench) = Benchmark::parse(args[2]) else {
                eprintln!("unknown benchmark {}", args[2]);
                return ExitCode::FAILURE;
            };
            let Some(variant) = parse_variant(args[3]) else {
                eprintln!("unknown variant {}", args[3]);
                return ExitCode::FAILURE;
            };
            if let Some(tiles) = cli.tiles {
                if variant.label() != "scalar" {
                    eprintln!("--tiles supports the scalar variant only");
                    return ExitCode::FAILURE;
                }
                let Some(w) = bench.build_tiled(&cfg, tiles) else {
                    eprintln!(
                        "--tiles supports the streaming kernels (MATMUL, CONV), not {}",
                        bench.name()
                    );
                    return ExitCode::FAILURE;
                };
                // Tiled runs stream L2-resident datasets through the DMA;
                // they are one-off scenario runs, not cached design points.
                let kind = cli.backend.unwrap_or(BackendKind::Event);
                let (run, out) = match w.run_on_backend(&cfg, cfg.cores, kind.get()) {
                    Ok(r) => r,
                    Err(e) => return fail(&e),
                };
                let verified = w.verify(&out).is_ok();
                let title = format!(
                    "{} on {} (DMA double-buffered, {})",
                    w.name,
                    cfg.mnemonic(),
                    kind.name()
                );
                return report_backend_run(&title, &run, Some(out.len()), verified);
            }
            if let Some(kind) = cli.backend {
                // Explicit tier selection: a direct, uncached run.
                let w = bench.build(variant, &cfg);
                let (run, out) = match w.run_on_backend(&cfg, cfg.cores, kind.get()) {
                    Ok(r) => r,
                    Err(e) => return fail(&e),
                };
                let verified = w.verify(&out).is_ok();
                let title = format!(
                    "{} {} on {} ({})",
                    bench.name(),
                    variant.label(),
                    cfg.mnemonic(),
                    kind.name()
                );
                return report_backend_run(&title, &run, None, verified);
            }
            let m = match QueryEngine::global().one(&cfg, bench, variant) {
                Ok(m) => m,
                Err(e) => return fail(&e),
            };
            println!("{} {} on {}:", bench.name(), variant.label(), cfg.mnemonic());
            println!("  cycles            {}", m.cycles);
            println!("  flops/cycle       {:.3}", m.metrics.flops_per_cycle);
            println!(
                "  perf              {:.2} Gflop/s @ {} MHz (ST)",
                m.metrics.perf_gflops,
                model::fmax_mhz(&cfg, Corner::St).round()
            );
            println!("  energy efficiency {:.1} Gflop/s/W (NT)", m.metrics.energy_eff);
            println!("  area efficiency   {:.2} Gflop/s/mm2", m.metrics.area_eff);
            println!(
                "  FP intensity      {:.2}   memory intensity {:.2}",
                m.fp_intensity, m.mem_intensity
            );
            println!("  verified          {}", m.verified);
            println!(
                "  counters          active={} fpu_cont={} fpu_stall={} tcdm_cont={} wb={} icache={} barrier={}",
                m.agg.active,
                m.agg.fpu_cont,
                m.agg.fpu_stall,
                m.agg.tcdm_cont,
                m.agg.wb_stall,
                m.agg.icache_stall,
                m.agg.barrier_idle
            );
            if !m.verified {
                return ExitCode::FAILURE;
            }
        }
        "query" => {
            if args.len() < 4 {
                eprintln!("usage: transpfp query <cfg|all> <bench|all> <variant|all>");
                return ExitCode::FAILURE;
            }
            let configs: Vec<ClusterConfig> = if args[1] == "all" {
                ClusterConfig::design_space()
            } else {
                match ClusterConfig::parse(args[1]) {
                    Some(cfg) => vec![cfg],
                    None => {
                        eprintln!("bad config mnemonic {}", args[1]);
                        return ExitCode::FAILURE;
                    }
                }
            };
            let benches: Vec<Benchmark> = if args[2] == "all" {
                Benchmark::all().to_vec()
            } else {
                match Benchmark::parse(args[2]) {
                    Some(b) => vec![b],
                    None => {
                        eprintln!("unknown benchmark {}", args[2]);
                        return ExitCode::FAILURE;
                    }
                }
            };
            let variants: Vec<Variant> = if args[3] == "all" {
                tuner::ladder().to_vec()
            } else {
                match parse_variant(args[3]) {
                    Some(v) => vec![v],
                    None => {
                        eprintln!("unknown variant {}", args[3]);
                        return ExitCode::FAILURE;
                    }
                }
            };
            let pts = coordinator::points(&configs, &benches, &variants);
            let engine = QueryEngine::global();
            let plan = engine.plan(&pts);
            let plan_summary = [
                ("points", plan.len().to_string()),
                ("unique", plan.unique_len().to_string()),
                ("cache hits", plan.hit_count().to_string()),
                ("cache misses", plan.miss_count().to_string()),
            ];
            let ms = match engine.execute(plan) {
                Ok(ms) => ms,
                // Resolved points were cached before the failure surfaced, so
                // a rerun after fixing the listed points re-simulates nothing.
                Err(e) => return fail(&e),
            };
            emit(coordinator::measurements_table(&ms));
            let mut summary = plan_summary.to_vec();
            summary.push(("entries", engine.stats().entries.to_string()));
            eprint!("{}", report::kv_table("query plan", &summary).render());
        }
        "pareto" => {
            return if cli.acc {
                emit_table(coordinator::accuracy_pareto_table(), csv)
            } else {
                emit_table(coordinator::pareto_table(), csv)
            };
        }
        "tune" => {
            let budget = cli.budget.unwrap_or(tuner::DEFAULT_BUDGET);
            let configs: Vec<ClusterConfig> = match args.get(1) {
                None => vec![ClusterConfig::new(8, 8, 1)],
                Some(&"all") => ClusterConfig::design_space(),
                Some(&m) => match ClusterConfig::parse(m) {
                    Some(cfg) => vec![cfg],
                    None => {
                        eprintln!("bad config mnemonic {m}");
                        return ExitCode::FAILURE;
                    }
                },
            };
            let engine = QueryEngine::global();
            let probe = cli.probe.unwrap_or(tuner::Probe::Functional);
            let mut reports: Vec<tuner::TuneReport> = Vec::with_capacity(configs.len());
            for cfg in &configs {
                match tuner::tune_with_probe(engine, cfg, budget, probe) {
                    Ok(r) => reports.push(r),
                    Err(e) => return fail(&e),
                }
            }
            emit(tuner::tune_table(&reports));
            for r in &reports {
                let summary = [
                    ("config", r.cfg.mnemonic()),
                    ("budget (rel err)", format!("{budget:e}")),
                    ("sub-F32 selections", format!("{}/{}", r.sub_f32_count(), r.choices.len())),
                    (
                        "within budget",
                        format!(
                            "{}/{}",
                            r.choices.iter().filter(|c| c.within_budget(budget)).count(),
                            r.choices.len()
                        ),
                    ),
                    ("cache entries", engine.stats().entries.to_string()),
                ];
                eprint!("{}", report::kv_table("tune", &summary).render());
            }
        }
        "table3" => return emit_table(coordinator::table3(), csv),
        "table4" => return emit_table(coordinator::table45(8), csv),
        "table5" => return emit_table(coordinator::table45(16), csv),
        "table6" => return emit_table(coordinator::table6(), csv),
        "fig3" => emit(coordinator::fig3()),
        "fig4" => emit(coordinator::fig4()),
        "fig5" => return emit_table(coordinator::fig5(), csv),
        "fig6" => return emit_table(coordinator::fig6(), csv),
        "fig7" => return emit_table(coordinator::fig7(), csv),
        "fig8" => return emit_table(coordinator::fig8(), csv),
        "sweep" => {
            let pts = coordinator::points(
                &ClusterConfig::design_space(),
                &Benchmark::all(),
                &[Variant::Scalar, Variant::VEC],
            );
            let ms = match QueryEngine::global().query(&pts) {
                Ok(ms) => ms,
                Err(e) => return fail(&e),
            };
            print!("{}", coordinator::measurements_table(&ms).to_csv());
        }
        "inject" => {
            let Some(&mnemonic) = args.get(1) else {
                eprintln!(
                    "usage: transpfp inject <cfg> [--seed <s>] [--rate <n>] \
                     [--sites tcdm,reg,dma|all] [--budget <rel-err>] [--no-recover] [--csv]"
                );
                return ExitCode::FAILURE;
            };
            let Some(cfg) = ClusterConfig::parse(mnemonic) else {
                eprintln!("bad config mnemonic {mnemonic}");
                return ExitCode::FAILURE;
            };
            let mut spec = faults::CampaignSpec::new(cfg);
            if let Some(s) = cli.seed {
                spec.seed = s;
            }
            if let Some(r) = cli.rate {
                spec.points_per_target = r;
            }
            if let Some(sites) = &cli.sites {
                spec.sites = sites.clone();
            }
            if let Some(b) = cli.budget {
                spec.budget = b;
            }
            if cli.no_recover {
                spec.recovery = None;
            }
            // Injected runs never abort the campaign; only a broken
            // fault-free baseline (the config itself cannot run) fails here.
            let report = match faults::run_campaign(&spec) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("inject: fault-free baseline failed: {e}");
                    return ExitCode::FAILURE;
                }
            };
            if csv {
                print!("{}", report.to_csv());
            } else {
                print!("{}", report.summary_table().render());
            }
            let counts = report.counts();
            let summary = [
                ("config", cfg.mnemonic()),
                ("seed", spec.seed.to_string()),
                ("points", report.points.len().to_string()),
                ("masked/tolerable", format!("{}/{}", counts[0], counts[1])),
                ("sdc/crash/hang", format!("{}/{}/{}", counts[2], counts[3], counts[4])),
                (
                    "recovered",
                    report.points.iter().filter(|p| p.recovered).count().to_string(),
                ),
                ("vulnerability", format!("{:.3}", report.vulnerability())),
            ];
            eprint!("{}", report::kv_table("inject", &summary).render());
        }
        "validate" => {
            let dir = args.get(1).copied().unwrap_or("artifacts");
            match transpfp::runtime::validate_all(dir) {
                Ok(report) => {
                    print!("{report}");
                }
                Err(e) => {
                    eprintln!("validation failed: {e}");
                    return ExitCode::FAILURE;
                }
            }
        }
        other => {
            eprintln!("unknown command {other}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(args: &[&str]) -> Result<Cli, String> {
        parse_cli(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn known_flags_are_extracted_in_any_position() {
        let c = cli(&["table4", "--csv"]).unwrap();
        assert!(c.csv && !c.no_cache);
        assert_eq!(c.args, vec!["table4"]);

        let c = cli(&["--no-cache", "query", "all", "FIR", "--csv", "scalar"]).unwrap();
        assert!(c.csv && c.no_cache);
        assert_eq!(c.args, vec!["query", "all", "FIR", "scalar"]);
    }

    #[test]
    fn unknown_flags_are_rejected_not_filtered() {
        for bad in ["--cvs", "--cache", "-x", "--", "--csv=always", "--budget=1e-2"] {
            let err = cli(&["table4", bad]).unwrap_err();
            assert!(err.contains(bad.split('=').next().unwrap()), "error must name the flag: {err}");
        }
        // Positionals are never mistaken for flags.
        assert!(cli(&["run", "8c4f1p", "MATMUL", "vector"]).is_ok());
    }

    #[test]
    fn budget_flag_takes_a_value() {
        let c = cli(&["tune", "--budget", "1e-3", "--csv"]).unwrap();
        assert_eq!(c.budget, Some(1e-3));
        assert!(c.csv);
        assert_eq!(c.args, vec!["tune"]);

        assert!(cli(&["tune", "--budget"]).is_err(), "missing value must fail");
        assert!(cli(&["tune", "--budget", "not-a-number"]).is_err());
        assert!(cli(&["tune", "--budget", "-1"]).is_err(), "negative budget is invalid");
        assert!(cli(&["tune", "--budget", "inf"]).is_err(), "non-finite budget is invalid");

        let c = cli(&["pareto", "--acc"]).unwrap();
        assert!(c.acc && c.budget.is_none());
    }

    #[test]
    fn backend_probe_and_jobs_flags_take_values() {
        let c = cli(&["run", "8c4f1p", "FIR", "scalar", "--backend", "functional"]).unwrap();
        assert_eq!(c.backend, Some(BackendKind::Functional));
        assert_eq!(c.args, vec!["run", "8c4f1p", "FIR", "scalar"]);
        let r = cli(&["run", "--backend", "ref"]).unwrap();
        assert_eq!(r.backend, Some(BackendKind::Reference));
        assert!(cli(&["run", "--backend"]).is_err(), "missing value must fail");
        assert!(cli(&["run", "--backend", "turbo"]).is_err());

        let c = cli(&["tune", "--probe", "functional"]).unwrap();
        assert_eq!(c.probe, Some(tuner::Probe::Functional));
        let p = cli(&["tune", "--probe", "cycle"]).unwrap();
        assert_eq!(p.probe, Some(tuner::Probe::CycleAccurate));
        assert!(cli(&["tune", "--probe"]).is_err());
        assert!(cli(&["tune", "--probe", "psychic"]).is_err());

        let c = cli(&["sweep", "--jobs", "4"]).unwrap();
        assert_eq!(c.jobs, Some(4));
        assert!(cli(&["sweep", "--jobs"]).is_err(), "missing value must fail");
        assert!(cli(&["sweep", "--jobs", "0"]).is_err(), "zero workers is invalid");
        assert!(cli(&["sweep", "--jobs", "many"]).is_err());
    }

    #[test]
    fn tiles_flag_takes_a_value() {
        let c = cli(&["run", "8c8f1p", "MATMUL", "scalar", "--tiles", "8"]).unwrap();
        assert_eq!(c.tiles, Some(8));
        assert_eq!(c.args, vec!["run", "8c8f1p", "MATMUL", "scalar"]);
        assert!(cli(&["run", "--tiles"]).is_err(), "missing value must fail");
        assert!(cli(&["run", "--tiles", "0"]).is_err(), "zero tiles is invalid");
        assert!(cli(&["run", "--tiles", "x"]).is_err());
    }

    #[test]
    fn inject_flags_take_values() {
        let c = cli(&["inject", "8c8f1p", "--seed", "7", "--rate", "16"]).unwrap();
        assert_eq!(c.seed, Some(7));
        assert_eq!(c.rate, Some(16));
        assert_eq!(c.args, vec!["inject", "8c8f1p"]);
        assert!(!c.no_recover && c.sites.is_none());

        let c = cli(&["inject", "8c8f1p", "--sites", "tcdm,dma", "--no-recover"]).unwrap();
        assert_eq!(c.sites, Some(vec![SiteClass::Tcdm, SiteClass::Dma]));
        assert!(c.no_recover);
        let c = cli(&["inject", "8c8f1p", "--sites", "all"]).unwrap();
        assert_eq!(c.sites, Some(SiteClass::all().to_vec()));

        assert!(cli(&["inject", "--seed"]).is_err(), "missing value must fail");
        assert!(cli(&["inject", "--seed", "x"]).is_err());
        assert!(cli(&["inject", "--rate", "0"]).is_err(), "zero points is invalid");
        assert!(cli(&["inject", "--sites", "l2"]).is_err(), "unknown site class");
        assert!(cli(&["inject", "--sites"]).is_err());
    }

    #[test]
    fn variant_names() {
        assert_eq!(parse_variant("scalar"), Some(Variant::Scalar));
        assert_eq!(parse_variant("scalar-f16"), Some(Variant::SCALAR_F16));
        assert_eq!(parse_variant("sbf16"), Some(Variant::SCALAR_BF16));
        assert_eq!(parse_variant("vector"), Some(Variant::VEC));
        assert_eq!(parse_variant("vector-f16"), Some(Variant::VEC));
        assert_eq!(parse_variant("f16"), Some(Variant::VEC));
        assert_eq!(parse_variant("bf16"), Some(Variant::Vector(FpMode::VecBf16)));
        assert_eq!(parse_variant("vector-bf16"), Some(Variant::Vector(FpMode::VecBf16)));
        assert_eq!(parse_variant("f64"), None);
        // Every canonical label parses.
        for v in Variant::all() {
            assert_eq!(parse_variant(v.label()), Some(v));
        }
    }
}
