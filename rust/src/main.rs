//! `transpfp` — CLI launcher for the transprecision-cluster reproduction.
//!
//! Subcommands regenerate every table/figure of the paper, run individual
//! benchmarks, resolve arbitrary design-space queries, and validate the
//! simulator's numerics against the AOT-compiled JAX/Pallas goldens
//! (`artifacts/*.hlo.txt`). Parsing is driven by the declarative registries
//! in [`transpfp::cli`] (shared with the serve wire protocol); the
//! service-shaped subcommands (`query`, `tune`, `pareto`) lower into the
//! same typed [`Request`] the daemon executes. Every command that consumes
//! full-occupancy measurements goes through the memoizing query engine:
//! results persist under `artifacts/cache/` (override with
//! `TRANSPFP_CACHE_DIR`, disable with `--no-cache`), so repeated
//! invocations skip simulation entirely.

use std::process::ExitCode;
use std::sync::Arc;

use transpfp::cli::{self, parse_cli, usage, Cli, DEFAULT_PORT};
use transpfp::cluster::BackendKind;
use transpfp::config::{ClusterConfig, Corner};
use transpfp::coordinator::{self, QueryEngine, QueryPoint};
use transpfp::faults;
use transpfp::kernels::Benchmark;
use transpfp::model;
use transpfp::report;
use transpfp::server::{serve_tcp, Request, Server};
use transpfp::tuner;

/// Print the result block of a direct (uncached) backend run and map
/// verification onto the exit code. Shared by `run --tiles` and
/// `run --backend`.
fn report_backend_run(
    title: &str,
    run: &transpfp::cluster::BackendRun,
    outputs: Option<usize>,
    verified: bool,
) -> ExitCode {
    println!("{title}:");
    match &run.stats {
        Some(stats) => println!("  cycles            {}", stats.total_cycles),
        None => println!("  cycles            - (architectural run)"),
    }
    println!("  instrs            {}", run.instrs);
    if let Some(n) = outputs {
        println!("  outputs           {n}");
    }
    println!("  verified          {verified}");
    if verified {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Print a structured failure report to stderr and fail the process.
/// Every simulation error reaches the user through here — the CLI never
/// panics on a hung, deadlocked, or faulting run.
fn fail(err: &dyn std::fmt::Display) -> ExitCode {
    eprintln!("{err}");
    ExitCode::FAILURE
}

/// Emit a query-backed table, or its structured failure report.
fn emit_table(t: Result<report::Table, coordinator::QueryFailure>, csv: bool) -> ExitCode {
    match t {
        Ok(t) => {
            if csv {
                print!("{}", t.to_csv());
            } else {
                print!("{}", t.render());
            }
            ExitCode::SUCCESS
        }
        Err(e) => fail(&e),
    }
}

fn main() -> ExitCode {
    let cli = match parse_cli(std::env::args().skip(1)) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("{e}\n\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    if let Some(jobs) = cli.jobs {
        coordinator::set_max_jobs(jobs);
    }
    if !cli.no_cache {
        coordinator::query::load_global_cache();
    }
    let code = dispatch(&cli);
    if !cli.no_cache && QueryEngine::global().stats().misses > 0 {
        if let Err(e) = coordinator::query::save_global_cache() {
            eprintln!("warning: could not persist measurement cache: {e}");
        }
    }
    code
}

fn dispatch(cli: &Cli) -> ExitCode {
    let args: Vec<&str> = cli.args.iter().map(|s| s.as_str()).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let csv = cli.csv;
    let engine = QueryEngine::global();

    let emit = |t: report::Table| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
    };

    match *cmd {
        "configs" => {
            println!(
                "Table 2 design space ({} configurations):",
                ClusterConfig::design_space().len()
            );
            for cfg in ClusterConfig::design_space() {
                println!(
                    "  {:9}  fmax {}MHz(ST) {}MHz(NT)  area {:.2} mm2",
                    cfg.mnemonic(),
                    model::fmax_mhz(&cfg, Corner::St).round(),
                    model::fmax_mhz(&cfg, Corner::Nt).round(),
                    model::area_mm2(&cfg)
                );
            }
        }
        "run" => {
            if args.len() < 4 {
                eprintln!(
                    "usage: transpfp run <cfg> <bench> \
                     <scalar|scalar-f16|scalar-bf16|vector|vector-bf16>"
                );
                return ExitCode::FAILURE;
            }
            let Some(cfg) = ClusterConfig::parse(args[1]) else {
                eprintln!("bad config mnemonic {}", args[1]);
                return ExitCode::FAILURE;
            };
            let Some(bench) = Benchmark::parse(args[2]) else {
                eprintln!("unknown benchmark {}", args[2]);
                return ExitCode::FAILURE;
            };
            let Some(variant) = cli::parse_variant(args[3]) else {
                eprintln!("unknown variant {}", args[3]);
                return ExitCode::FAILURE;
            };
            if let Some(tiles) = cli.tiles {
                if variant.label() != "scalar" {
                    eprintln!("--tiles supports the scalar variant only");
                    return ExitCode::FAILURE;
                }
                let Some(w) = bench.build_tiled(&cfg, tiles) else {
                    eprintln!(
                        "--tiles supports the streaming kernels (MATMUL, CONV), not {}",
                        bench.name()
                    );
                    return ExitCode::FAILURE;
                };
                // Tiled runs stream L2-resident datasets through the DMA;
                // they are one-off scenario runs, not cached design points.
                let kind = cli.backend.unwrap_or(BackendKind::Event);
                let (run, out) = match w.run_on_backend(&cfg, cfg.cores, kind.get()) {
                    Ok(r) => r,
                    Err(e) => return fail(&e),
                };
                let verified = w.verify(&out).is_ok();
                let title = format!(
                    "{} on {} (DMA double-buffered, {})",
                    w.name,
                    cfg.mnemonic(),
                    kind.name()
                );
                return report_backend_run(&title, &run, Some(out.len()), verified);
            }
            if let Some(kind) = cli.backend {
                // Explicit tier selection: a direct, uncached run.
                let w = bench.build(variant, &cfg);
                let (run, out) = match w.run_on_backend(&cfg, cfg.cores, kind.get()) {
                    Ok(r) => r,
                    Err(e) => return fail(&e),
                };
                let verified = w.verify(&out).is_ok();
                let title = format!(
                    "{} {} on {} ({})",
                    bench.name(),
                    variant.label(),
                    cfg.mnemonic(),
                    kind.name()
                );
                return report_backend_run(&title, &run, None, verified);
            }
            let m = match engine.one(QueryPoint::new(&cfg, bench, variant)) {
                Ok(m) => m,
                Err(e) => return fail(&e),
            };
            println!("{} {} on {}:", bench.name(), variant.label(), cfg.mnemonic());
            println!("  cycles            {}", m.cycles);
            println!("  flops/cycle       {:.3}", m.metrics.flops_per_cycle);
            println!(
                "  perf              {:.2} Gflop/s @ {} MHz (ST)",
                m.metrics.perf_gflops,
                model::fmax_mhz(&cfg, Corner::St).round()
            );
            println!("  energy efficiency {:.1} Gflop/s/W (NT)", m.metrics.energy_eff);
            println!("  area efficiency   {:.2} Gflop/s/mm2", m.metrics.area_eff);
            println!(
                "  FP intensity      {:.2}   memory intensity {:.2}",
                m.fp_intensity, m.mem_intensity
            );
            println!("  verified          {}", m.verified);
            println!(
                "  counters          active={} fpu_cont={} fpu_stall={} tcdm_cont={} wb={} icache={} barrier={}",
                m.agg.active,
                m.agg.fpu_cont,
                m.agg.fpu_stall,
                m.agg.tcdm_cont,
                m.agg.wb_stall,
                m.agg.icache_stall,
                m.agg.barrier_idle
            );
            if !m.verified {
                return ExitCode::FAILURE;
            }
        }
        // The service-shaped subcommands lower into the same typed Request
        // the serve daemon executes, then run against the global engine.
        "query" | "tune" | "pareto" => {
            let req = match cli.to_request() {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{e}");
                    return ExitCode::FAILURE;
                }
            };
            return run_request(cli, &req);
        }
        "table3" => return emit_table(coordinator::table3(engine), csv),
        "table4" => return emit_table(coordinator::table45(engine, 8), csv),
        "table5" => return emit_table(coordinator::table45(engine, 16), csv),
        "table6" => return emit_table(coordinator::table6(engine), csv),
        "fig3" => emit(coordinator::fig3()),
        "fig4" => emit(coordinator::fig4()),
        "fig5" => return emit_table(coordinator::fig5(engine), csv),
        "fig6" => return emit_table(coordinator::fig6(engine), csv),
        "fig7" => return emit_table(coordinator::fig7(engine), csv),
        "fig8" => return emit_table(coordinator::fig8(engine), csv),
        "sweep" => {
            let pts = coordinator::points(
                &ClusterConfig::design_space(),
                &Benchmark::all(),
                &[transpfp::kernels::Variant::Scalar, transpfp::kernels::Variant::VEC],
            );
            let ms = match engine.query(&pts) {
                Ok(ms) => ms,
                Err(e) => return fail(&e),
            };
            print!("{}", coordinator::measurements_table(&ms).to_csv());
        }
        "inject" => {
            let Some(&mnemonic) = args.get(1) else {
                eprintln!(
                    "usage: transpfp inject <cfg> [--seed <s>] [--rate <n>] \
                     [--sites tcdm,reg,dma|all] [--budget <rel-err>] [--no-recover] [--csv]"
                );
                return ExitCode::FAILURE;
            };
            let Some(cfg) = ClusterConfig::parse(mnemonic) else {
                eprintln!("bad config mnemonic {mnemonic}");
                return ExitCode::FAILURE;
            };
            let mut spec = faults::CampaignSpec::new(cfg);
            if let Some(s) = cli.seed {
                spec.seed = s;
            }
            if let Some(r) = cli.rate {
                spec.points_per_target = r;
            }
            if let Some(sites) = &cli.sites {
                spec.sites = sites.clone();
            }
            if let Some(b) = cli.budget {
                spec.budget = b;
            }
            if cli.no_recover {
                spec.recovery = None;
            }
            // Injected runs never abort the campaign; only a broken
            // fault-free baseline (the config itself cannot run) fails here.
            let report = match faults::run_campaign(&spec) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("inject: fault-free baseline failed: {e}");
                    return ExitCode::FAILURE;
                }
            };
            if csv {
                print!("{}", report.to_csv());
            } else {
                print!("{}", report.summary_table().render());
            }
            let counts = report.counts();
            let summary = [
                ("config", cfg.mnemonic()),
                ("seed", spec.seed.to_string()),
                ("points", report.points.len().to_string()),
                ("masked/tolerable", format!("{}/{}", counts[0], counts[1])),
                ("sdc/crash/hang", format!("{}/{}/{}", counts[2], counts[3], counts[4])),
                (
                    "recovered",
                    report.points.iter().filter(|p| p.recovered).count().to_string(),
                ),
                ("vulnerability", format!("{:.3}", report.vulnerability())),
            ];
            eprint!("{}", report::kv_table("inject", &summary).render());
        }
        "validate" => {
            let dir = args.get(1).copied().unwrap_or("artifacts");
            match transpfp::runtime::validate_all(dir) {
                Ok(report) => {
                    print!("{report}");
                }
                Err(e) => {
                    eprintln!("validation failed: {e}");
                    return ExitCode::FAILURE;
                }
            }
        }
        "trace" => {
            if args.len() < 3 {
                eprintln!(
                    "usage: transpfp trace <cfg> <bench> [--variant <v>] [--tiles <t>] \
                     [--region <name>] [--out <path>] [--format csv|chrome]"
                );
                return ExitCode::FAILURE;
            }
            let Some(cfg) = ClusterConfig::parse(args[1]) else {
                eprintln!("bad config mnemonic {}", args[1]);
                return ExitCode::FAILURE;
            };
            let Some(bench) = Benchmark::parse(args[2]) else {
                eprintln!("unknown benchmark {}", args[2]);
                return ExitCode::FAILURE;
            };
            return trace_cmd(cli, &cfg, bench);
        }
        "serve" => return serve(cli),
        other => {
            eprintln!("unknown command {other}\n\n{}", usage());
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

/// `transpfp trace`: run one kernel on the event engine with the tracer
/// attached, print the cycle-attribution report (reconciled exactly against
/// the run's counters), and export the raw record stream under
/// `artifacts/trace/` (or `--out`).
fn trace_cmd(cli: &Cli, cfg: &ClusterConfig, bench: Benchmark) -> ExitCode {
    use transpfp::cli::TraceFormat;
    use transpfp::cluster::Engine;
    use transpfp::kernels::Variant;
    use transpfp::trace::{export, TraceConfig};

    let variant = cli.variant.unwrap_or(Variant::Scalar);
    let w = if let Some(tiles) = cli.tiles {
        if variant.label() != "scalar" {
            eprintln!("--tiles supports the scalar variant only");
            return ExitCode::FAILURE;
        }
        let Some(w) = bench.build_tiled(cfg, tiles) else {
            eprintln!(
                "--tiles supports the streaming kernels (MATMUL, CONV), not {}",
                bench.name()
            );
            return ExitCode::FAILURE;
        };
        w
    } else {
        bench.build(variant, cfg)
    };
    let tcfg = TraceConfig::default();
    let (stats, out, tracer) = match w.run_traced(cfg, cfg.cores, Engine::Event, tcfg) {
        Ok(r) => r,
        Err(e) => return fail(&e),
    };
    let verified = w.verify(&out).is_ok();
    let report = tracer.report();
    // Attribution is built from counter snapshot diffs, so it must agree
    // with the run's own counters to the last cycle.
    if let Err(e) = report.reconcile(&stats) {
        eprintln!("trace: attribution does not reconcile with run counters: {e}");
        return ExitCode::FAILURE;
    }
    if cli.csv {
        print!("{}", report.to_csv());
    } else {
        print!("{}", report.table().render());
    }
    if let Some(region) = &cli.region {
        if !report.regions().contains(&region.as_str()) {
            eprintln!(
                "trace: no region named `{region}` (have: {})",
                report.regions().join(", ")
            );
            return ExitCode::FAILURE;
        }
        if !cli.csv {
            println!("region {region} per core:");
        }
        if cli.csv {
            print!("{}", report.region_table(region).to_csv());
        } else {
            print!("{}", report.region_table(region).render());
        }
    }
    eprintln!("trace: {}", report.summary_line());
    eprintln!(
        "trace: records retained {} dropped {} (ring {} / core)",
        tracer.db().total_len(),
        tracer.db().total_dropped(),
        tcfg.ring_capacity
    );
    eprintln!("trace: verified {verified}");
    let format = cli.format.unwrap_or_default();
    let contents = match format {
        TraceFormat::Csv => export::records_csv(tracer.db(), tracer.region_names()),
        TraceFormat::Chrome => export::chrome_json(tracer.db(), tracer.region_names(), &w.name),
    };
    let written = match &cli.out {
        Some(path) => std::fs::write(path, &contents).map(|()| std::path::PathBuf::from(path)),
        None => {
            let base = format!("{}-{}", bench.name().to_lowercase(), variant.label());
            export::write_artifact(&export::default_dir(), &base, format.ext(), &contents)
        }
    };
    match written {
        Ok(p) => eprintln!("trace: wrote {}", p.display()),
        Err(e) => {
            eprintln!("trace: could not write export: {e}");
            return ExitCode::FAILURE;
        }
    }
    if verified {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Execute a typed service request on the CLI, with the CLI's reporting
/// conventions (tables on stdout, plan/tune summaries on stderr).
fn run_request(cli: &Cli, req: &Request) -> ExitCode {
    let engine = QueryEngine::global();
    let emit = |t: report::Table| {
        if cli.csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
    };
    match req {
        Request::Query { .. } => {
            let pts = req.query_points().expect("query request");
            let plan = engine.plan(&pts);
            let plan_summary = [
                ("points", plan.len().to_string()),
                ("unique", plan.unique_len().to_string()),
                ("cache hits", plan.hit_count().to_string()),
                ("cache misses", plan.miss_count().to_string()),
            ];
            let ms = match engine.execute(plan) {
                Ok(ms) => ms,
                // Resolved points were cached before the failure surfaced, so
                // a rerun after fixing the listed points re-simulates nothing.
                Err(e) => return fail(&e),
            };
            emit(coordinator::measurements_table(&ms));
            let mut summary = plan_summary.to_vec();
            summary.push(("entries", engine.stats().entries.to_string()));
            eprint!("{}", report::kv_table("query plan", &summary).render());
            ExitCode::SUCCESS
        }
        Request::Tune { budget, probe, .. } => {
            let configs = req.tune_configs().expect("tune request");
            let mut reports: Vec<tuner::TuneReport> = Vec::with_capacity(configs.len());
            for cfg in &configs {
                match tuner::tune_with_probe(engine, cfg, *budget, *probe) {
                    Ok(r) => reports.push(r),
                    Err(e) => return fail(&e),
                }
            }
            emit(tuner::tune_table(&reports));
            for r in &reports {
                let summary = [
                    ("config", r.cfg.mnemonic()),
                    ("budget (rel err)", format!("{budget:e}")),
                    ("sub-F32 selections", format!("{}/{}", r.sub_f32_count(), r.choices.len())),
                    (
                        "within budget",
                        format!(
                            "{}/{}",
                            r.choices.iter().filter(|c| c.within_budget(*budget)).count(),
                            r.choices.len()
                        ),
                    ),
                    ("cache entries", engine.stats().entries.to_string()),
                ];
                eprint!("{}", report::kv_table("tune", &summary).render());
            }
            ExitCode::SUCCESS
        }
        Request::Pareto { acc } => {
            if *acc {
                emit_table(coordinator::accuracy_pareto_table(engine), cli.csv)
            } else {
                emit_table(coordinator::pareto_table(engine), cli.csv)
            }
        }
        // Wire-only endpoints; the CLI dispatcher never builds these.
        Request::InjectStatus | Request::Stats | Request::Trace | Request::Ping => {
            eprintln!("`{}` is a serve-only endpoint; send it to a running daemon", req.to_line());
            ExitCode::FAILURE
        }
    }
}

/// `transpfp serve`: run the concurrent query service until EOF (--stdin)
/// or forever (TCP).
fn serve(cli: &Cli) -> ExitCode {
    let server = Arc::new(Server::new(QueryEngine::global()));
    if cli.stdin_mode {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let summary = match server.serve_pipe(stdin.lock(), stdout.lock()) {
            Ok(s) => s,
            Err(e) => return fail(&e),
        };
        let engine = server.engine();
        let totals = server.metrics().totals();
        let lookups = totals.cache_hits + totals.cache_misses;
        let hit_rate =
            if lookups > 0 { 100.0 * totals.cache_hits as f64 / lookups as f64 } else { 0.0 };
        eprint!("{}", server.metrics().table().render());
        eprintln!("serve-requests: {}", summary.requests);
        eprintln!("serve-replies-ok: {}", summary.replies_ok);
        eprintln!("serve-replies-err: {}", summary.replies_err);
        eprintln!("serve-cache-hits: {}", totals.cache_hits);
        eprintln!("serve-cache-misses: {}", totals.cache_misses);
        eprintln!("serve-hit-rate: {hit_rate:.1}%");
        eprintln!("serve-sim-runs: {}", engine.sim_runs());
        eprintln!("serve-functional-runs: {}", engine.functional_runs());
        eprintln!("serve-compiled-runs: {}", engine.compiled_runs());
        eprintln!("serve-coalesced-runs: {}", engine.coalesced_runs());
        eprintln!("serve-duplicate-runs: {}", engine.duplicate_runs());
        eprintln!("serve-batched-requests: {}", engine.batched_requests());
        eprintln!("serve-batched-points: {}", engine.batched_points());
        eprintln!("serve-planner-passes: {}", engine.planner_passes());
        eprintln!("serve-codecache-evictions: {}", engine.code_cache().evictions());
        if let Some(path) = &cli.metrics {
            if let Err(e) = std::fs::write(path, server.metrics().to_csv()) {
                eprintln!("warning: could not write metrics CSV {path}: {e}");
                return ExitCode::FAILURE;
            }
        }
        ExitCode::SUCCESS
    } else {
        let port = cli.port.unwrap_or(DEFAULT_PORT);
        let listener = match std::net::TcpListener::bind(("127.0.0.1", port)) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("serve: could not bind 127.0.0.1:{port}: {e}");
                return ExitCode::FAILURE;
            }
        };
        eprintln!(
            "transpfp serve: listening on 127.0.0.1:{port} \
             (newline-delimited requests; see EXPERIMENTS.md §Serve)"
        );
        match serve_tcp(server, listener) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => fail(&e),
        }
    }
}
