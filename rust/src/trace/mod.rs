//! Opt-in cycle-attribution tracing for the timed engines.
//!
//! A [`Tracer`] is attached to a `Cluster` with
//! `Cluster::attach_tracer`; when absent (the default) the engines pay a
//! single predictable branch per hook site, keeping the disabled path bit-
//! and speed-identical (gated ≤2% in `benches/sim_hotpath.rs`). When
//! attached, every issue attempt, categorized stall, event/barrier sleep,
//! and DMA transfer is appended to a bounded per-core ring ([`TraceDb`]),
//! and region markers (emitted by the ISA builder, the runtime's
//! `parallel_for`, and the tiled kernels) fold the run into an exact
//! [`AttributionReport`].
//!
//! ## Region semantics
//!
//! A marker is metadata on a *pc*: when the instruction at that pc first
//! issues on a core, the marker fires on that core. A region therefore
//! begins when its first instruction issues — fetch/operand stalls of that
//! first instruction are charged to the *enclosing* context. An `Exit` is
//! statically matched to its `Enter` at build time and only pops a matching
//! stack top, so an exit whose pc is shared with another control path (the
//! instruction after a master-only block, say) is a no-op on cores that
//! never entered the region. Marker fires are deduplicated against contention
//! retries of the same pc (an instruction that loses arbitration re-issues
//! at the same pc and must not re-fire); a revisit after *any other* pc
//! issued re-fires, so loop bodies mark every iteration. Known limit: a
//! marked single-instruction self-loop fires once, not per iteration.
//!
//! ## Attribution
//!
//! Attribution uses counter snapshot diffs, not ring replay: at every
//! marker fire (and at `End`) the interval's `CoreCounters` delta is
//! credited to the innermost active region ("self time"). Summed rows
//! reconcile exactly with `RunStats` by construction, independent of ring
//! capacity, and each interval satisfies
//! `active + stalls() == cycles` (the invariant the counter-reconciliation
//! wall in `tests/trace.rs` pins suite-wide).

pub mod db;
pub mod export;
pub mod report;

pub use db::{StallCause, TraceDb, TraceKind, TraceRecord, TraceSink};
pub use report::{AttributionReport, RegionRow};

use std::collections::HashMap;

use crate::cluster::counters::CoreCounters;
use crate::isa::builder::MarkerOp;

/// Region id credited to code outside any marked region.
pub const OUTSIDE_REGION: u16 = 0;

/// Tracing configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Per-core ring capacity of the backing [`TraceDb`].
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { ring_capacity: 1 << 16 }
    }
}

/// A marker resolved to an interned region id. `Exit` carries the id of
/// the statically matching `Enter`, so a fire can verify it pops the
/// region it closes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MarkerSlot {
    Enter(u16),
    Exit(u16),
}

/// Per-core attribution state.
struct PerCore {
    /// Stack of active region ids (innermost last).
    stack: Vec<u16>,
    /// Counter snapshot at the last boundary.
    last_snap: CoreCounters,
    /// Cycle of the last boundary.
    last_cycle: u64,
    /// Last pc that reached class dispatch — marker-fire dedup against
    /// same-pc contention retries.
    last_e_pc: u32,
}

impl PerCore {
    fn fresh() -> Self {
        PerCore {
            stack: Vec::new(),
            last_snap: CoreCounters::default(),
            last_cycle: 0,
            last_e_pc: u32::MAX,
        }
    }
}

/// The live tracing state attached to a cluster: marker table, per-core
/// region stacks and counter snapshots, the region accumulator, DMA busy
/// tracking, and the record database.
pub struct Tracer {
    cfg: TraceConfig,
    kernel: String,
    /// Interned region names; index 0 is [`OUTSIDE_REGION`].
    names: Vec<String>,
    /// pc → marker ops, resolved from the program's marker side table.
    markers: HashMap<u32, Vec<MarkerSlot>>,
    per_core: Vec<PerCore>,
    /// `accum[region][core]`: self-time counter deltas.
    accum: Vec<Vec<CoreCounters>>,
    /// DMA busy accounting: engine-busy frontier and accumulated busy
    /// cycles (overlap-collapsed — concurrent triggers queue on one engine).
    dma_frontier: u64,
    dma_busy: u64,
    db: TraceDb,
}

/// Credit the interval since the last boundary to the innermost region and
/// advance the snapshot. Free function so callers can hold disjoint-field
/// borrows (`markers`) across it.
fn flush_boundary(
    st: &mut PerCore,
    accum: &mut [Vec<CoreCounters>],
    ci: usize,
    t: u64,
    counters: &CoreCounters,
) {
    let mut d = counters.delta_from(&st.last_snap);
    // Engines only write `counters.cycles` at End; the boundary clock is
    // the hook-time cycle.
    d.cycles = t - st.last_cycle;
    let top = st.stack.last().copied().unwrap_or(OUTSIDE_REGION) as usize;
    accum[top][ci].accumulate(&d);
    st.last_snap = *counters;
    st.last_cycle = t;
}

impl Tracer {
    /// Build a tracer for `cores` cores over the given marker side table
    /// (pc, op) in emission order. Duplicate names merge — every
    /// `dma-wait` region, for example, accumulates into one row.
    pub fn new(cfg: TraceConfig, cores: usize, kernel: &str, markers: &[(u32, MarkerOp)]) -> Self {
        let mut names: Vec<String> = vec!["(outside)".to_string()];
        let mut table: HashMap<u32, Vec<MarkerSlot>> = HashMap::new();
        // Static matching of exits to enters (the builder guarantees the
        // side table is balanced in emission order).
        let mut open: Vec<u16> = Vec::new();
        for (pc, op) in markers {
            let slot = match op {
                MarkerOp::Enter(name) => {
                    let id = match names.iter().position(|n| n == name) {
                        Some(i) => i,
                        None => {
                            names.push(name.clone());
                            names.len() - 1
                        }
                    };
                    assert!(id <= u16::MAX as usize, "too many trace regions");
                    open.push(id as u16);
                    MarkerSlot::Enter(id as u16)
                }
                MarkerOp::Exit => match open.pop() {
                    Some(id) => MarkerSlot::Exit(id),
                    None => continue, // unmatched exit: drop the slot
                },
            };
            table.entry(*pc).or_default().push(slot);
        }
        let nregions = names.len();
        Tracer {
            cfg,
            kernel: kernel.to_string(),
            names,
            markers: table,
            per_core: (0..cores).map(|_| PerCore::fresh()).collect(),
            accum: vec![vec![CoreCounters::default(); cores]; nregions],
            dma_frontier: 0,
            dma_busy: 0,
            db: TraceDb::new(cores, cfg.ring_capacity),
        }
    }

    /// Clear all per-run state (records, stacks, snapshots, accumulators),
    /// keeping the marker table. Called by `Cluster::reset`.
    pub fn reset(&mut self) {
        for st in &mut self.per_core {
            *st = PerCore::fresh();
        }
        for lane in &mut self.accum {
            for c in lane.iter_mut() {
                *c = CoreCounters::default();
            }
        }
        self.dma_frontier = 0;
        self.dma_busy = 0;
        self.db.clear();
    }

    /// The backing record database.
    pub fn db(&self) -> &TraceDb {
        &self.db
    }

    /// Interned region names (index = region id; 0 is `"(outside)"`).
    pub fn region_names(&self) -> &[String] {
        &self.names
    }

    /// The configuration the tracer was attached with.
    pub fn config(&self) -> TraceConfig {
        self.cfg
    }

    /// Kernel name the tracer was attached for.
    pub fn kernel(&self) -> &str {
        &self.kernel
    }

    /// Hook: an issue attempt on core `ci` at pc `pc` reached class
    /// dispatch at cycle `t`. Fires markers (deduplicated against same-pc
    /// retries) and records the attempt.
    pub fn on_issue(&mut self, ci: usize, pc: u32, t: u64, counters: &CoreCounters) {
        if self.per_core[ci].last_e_pc != pc {
            self.per_core[ci].last_e_pc = pc;
            if let Some(ops) = self.markers.get(&pc) {
                flush_boundary(&mut self.per_core[ci], &mut self.accum, ci, t, counters);
                let st = &mut self.per_core[ci];
                for op in ops {
                    match op {
                        MarkerSlot::Enter(id) => {
                            st.stack.push(*id);
                            self.db.record(
                                ci,
                                TraceRecord {
                                    cycle: t,
                                    pc,
                                    kind: TraceKind::RegionEnter,
                                    arg: *id as u64,
                                },
                            );
                        }
                        MarkerSlot::Exit(id) => {
                            // Pop only a matching top: cores that skipped
                            // the enter (a shared pc past a master-only
                            // block) must not have their stack corrupted.
                            if st.stack.last() == Some(id) {
                                st.stack.pop();
                                self.db.record(
                                    ci,
                                    TraceRecord {
                                        cycle: t,
                                        pc,
                                        kind: TraceKind::RegionExit,
                                        arg: *id as u64,
                                    },
                                );
                            }
                        }
                    }
                }
            }
        }
        self.db.record(ci, TraceRecord { cycle: t, pc, kind: TraceKind::Issue, arg: 0 });
    }

    /// Hook: core `ci` lost `amount` cycles to `cause` at attempt cycle
    /// `t` (bulk amount, matching the counter bump exactly). No-op for
    /// `amount == 0` so both engines skip the same degenerate bumps.
    pub fn on_stall(&mut self, ci: usize, pc: u32, t: u64, cause: StallCause, amount: u64) {
        if amount == 0 {
            return;
        }
        self.db.record(ci, TraceRecord { cycle: t, pc, kind: TraceKind::Stall(cause), arg: amount });
    }

    /// Hook: core `ci` (asleep since `since`, resuming at `wake`) was woken
    /// by a set-event (`TraceKind::EventWait`) or barrier completion
    /// (`TraceKind::Barrier`). `pc` is the sleeper's resume pc. The record
    /// lands on the sleeper's own lane; `arg` mirrors the `barrier_idle`
    /// counter bump.
    pub fn on_wake(&mut self, ci: usize, pc: u32, kind: TraceKind, since: u64, wake: u64) {
        debug_assert!(matches!(kind, TraceKind::EventWait | TraceKind::Barrier));
        self.db.record(ci, TraceRecord { cycle: since, pc, kind, arg: wake - since });
    }

    /// Hook: core `ci` triggered a DMA transfer at cycle `t`; the engine
    /// works on it over `[start, done)` (`start ≥ t` when queued behind an
    /// earlier transfer). Records the trigger and the landing and folds the
    /// busy span into the overlap accounting.
    pub fn on_dma(&mut self, ci: usize, pc: u32, t: u64, start: u64, done: u64, words: u32) {
        self.db.record(
            ci,
            TraceRecord { cycle: t, pc, kind: TraceKind::DmaStart, arg: words as u64 },
        );
        self.db.record(
            ci,
            TraceRecord { cycle: done, pc, kind: TraceKind::DmaLand, arg: done - start },
        );
        let s = self.dma_frontier.max(start);
        self.dma_busy += done.saturating_sub(s);
        self.dma_frontier = self.dma_frontier.max(done);
    }

    /// Hook: core `ci` retired `End` at cycle `t`. Flushes the final
    /// interval so the core's attribution telescopes to its full counters.
    pub fn on_end(&mut self, ci: usize, t: u64, counters: &CoreCounters) {
        flush_boundary(&mut self.per_core[ci], &mut self.accum, ci, t, counters);
    }

    /// Fold the attribution state into a report. Call after the run
    /// completes (every core retired `End`).
    pub fn report(&self) -> AttributionReport {
        let cores = self.per_core.len();
        let mut rows = Vec::new();
        let mut dma_wait_cycles = 0u64;
        for (rid, lane) in self.accum.iter().enumerate() {
            for (ci, delta) in lane.iter().enumerate() {
                if *delta == CoreCounters::default() {
                    continue;
                }
                if self.names[rid] == "dma-wait" {
                    dma_wait_cycles += delta.cycles;
                }
                rows.push(RegionRow { region: self.names[rid].clone(), core: ci, delta: *delta });
            }
        }
        AttributionReport {
            kernel: self.kernel.clone(),
            cores,
            rows,
            dma_busy: self.dma_busy,
            dma_wait_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counters(active: u64, tcdm: u64) -> CoreCounters {
        CoreCounters { active, tcdm_cont: tcdm, ..CoreCounters::default() }
    }

    #[test]
    fn markers_intern_and_merge_names() {
        let markers = vec![
            (4u32, MarkerOp::Enter("a".to_string())),
            (8u32, MarkerOp::Exit),
            (10u32, MarkerOp::Enter("a".to_string())),
            (12u32, MarkerOp::Exit),
            (14u32, MarkerOp::Enter("b".to_string())),
            (20u32, MarkerOp::Exit),
        ];
        let tr = Tracer::new(TraceConfig::default(), 1, "k", &markers);
        assert_eq!(tr.region_names(), &["(outside)", "a", "b"]);
    }

    #[test]
    fn snapshot_diff_attribution_telescopes() {
        let markers = vec![(2u32, MarkerOp::Enter("hot".to_string())), (5u32, MarkerOp::Exit)];
        let mut tr = Tracer::new(TraceConfig::default(), 1, "k", &markers);
        // pc 0,1 outside; pc 2..4 inside "hot"; pc 5 exits; End at t=40.
        tr.on_issue(0, 0, 0, &counters(0, 0));
        tr.on_issue(0, 1, 1, &counters(1, 0));
        tr.on_issue(0, 2, 5, &counters(2, 3)); // boundary: outside gets [0,5)
        tr.on_issue(0, 3, 6, &counters(3, 3));
        tr.on_issue(0, 4, 7, &counters(4, 3));
        tr.on_issue(0, 5, 12, &counters(5, 7)); // boundary: hot gets [5,12)
        let mut fin = counters(9, 7);
        fin.cycles = 40;
        tr.on_end(0, 40, &fin); // outside gets [12,40)
        let rep = tr.report();
        let outside = rep.region_total("(outside)");
        let hot = rep.region_total("hot");
        assert_eq!(outside.cycles + hot.cycles, 40);
        assert_eq!(hot.cycles, 7);
        assert_eq!(hot.tcdm_cont, 4);
        assert_eq!(hot.active, 3);
        assert_eq!(outside.tcdm_cont, 3);
        assert_eq!(outside.active, 6);
    }

    #[test]
    fn same_pc_retry_does_not_refire_markers() {
        let markers = vec![(3u32, MarkerOp::Enter("r".to_string())), (4u32, MarkerOp::Exit)];
        let mut tr = Tracer::new(TraceConfig::default(), 1, "k", &markers);
        tr.on_issue(0, 3, 2, &counters(0, 0));
        tr.on_issue(0, 3, 3, &counters(0, 1)); // contention retry, same pc
        tr.on_issue(0, 4, 4, &counters(1, 1));
        let enters = tr
            .db()
            .records(0)
            .filter(|r| r.kind == TraceKind::RegionEnter)
            .count();
        assert_eq!(enters, 1);
        // Loop revisit after another pc issued re-fires.
        tr.on_issue(0, 3, 9, &counters(2, 1));
        let enters = tr
            .db()
            .records(0)
            .filter(|r| r.kind == TraceKind::RegionEnter)
            .count();
        assert_eq!(enters, 2);
    }

    #[test]
    fn unentered_exit_is_ignored() {
        // The exit pc is shared with a path that never entered the region
        // (e.g. workers branching over a master-only block).
        let markers = vec![(5u32, MarkerOp::Enter("m".to_string())), (9u32, MarkerOp::Exit)];
        let mut tr = Tracer::new(TraceConfig::default(), 2, "k", &markers);
        // Core 0 (master) enters at 5 and exits at 9.
        tr.on_issue(0, 5, 1, &counters(1, 0));
        tr.on_issue(0, 9, 4, &counters(3, 0));
        // Core 1 (worker) jumps straight to 9: the exit must be a no-op.
        tr.on_issue(1, 9, 4, &counters(2, 0));
        let exits =
            |ci: usize| tr.db().records(ci).filter(|r| r.kind == TraceKind::RegionExit).count();
        assert_eq!(exits(0), 1);
        assert_eq!(exits(1), 0);
        assert!(tr.per_core[1].stack.is_empty());
    }

    #[test]
    fn dma_busy_collapses_overlap() {
        let mut tr = Tracer::new(TraceConfig::default(), 1, "k", &[]);
        // Transfer 1: [10, 30). Transfer 2 triggered at 12, queued: [30, 50).
        tr.on_dma(0, 7, 10, 10, 30, 16);
        tr.on_dma(0, 7, 12, 30, 50, 16);
        let rep = tr.report();
        assert_eq!(rep.dma_busy, 40);
        let kinds: Vec<TraceKind> = tr.db().records(0).map(|r| r.kind).collect();
        assert_eq!(
            kinds,
            vec![TraceKind::DmaStart, TraceKind::DmaLand, TraceKind::DmaStart, TraceKind::DmaLand]
        );
    }

    #[test]
    fn reset_clears_everything() {
        let markers = vec![(1u32, MarkerOp::Enter("x".to_string())), (2u32, MarkerOp::Exit)];
        let mut tr = Tracer::new(TraceConfig { ring_capacity: 8 }, 2, "k", &markers);
        tr.on_issue(0, 1, 3, &counters(1, 0));
        tr.on_dma(1, 9, 5, 5, 20, 4);
        let mut fin = counters(2, 0);
        fin.cycles = 10;
        tr.on_end(0, 10, &fin);
        tr.reset();
        assert!(tr.db().is_empty());
        assert!(tr.report().rows.is_empty());
        assert_eq!(tr.report().dma_busy, 0);
        // Marker table survives reset: re-running still fires markers.
        tr.on_issue(0, 1, 3, &counters(1, 0));
        assert_eq!(tr.db().len(0), 2); // RegionEnter + Issue
    }
}
