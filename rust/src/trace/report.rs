//! Cycle-attribution reports: fold a traced run into per-region × per-core
//! counter deltas that reconcile **exactly** with the run's `RunStats`.
//!
//! The tracer snapshots each core's `CoreCounters` at every region boundary
//! (marker fire) and at `End`, crediting the interval delta to the
//! innermost active region ("self time"). Because attribution is built from
//! snapshot diffs — not by replaying ring records — it stays exact even
//! when the bounded trace rings drop records.

use crate::cluster::counters::{CoreCounters, RunStats};
use crate::report::Table;

/// Self-time counters for one (region, core) pair. `delta.cycles` is the
/// number of cycles credited to this region on this core, and the
/// per-interval invariant `delta.active + delta.stalls() == delta.cycles`
/// holds row by row.
#[derive(Debug, Clone)]
pub struct RegionRow {
    /// Region name (`"(outside)"` for un-marked code).
    pub region: String,
    /// Core index.
    pub core: usize,
    /// Counter delta credited to the region's self time.
    pub delta: CoreCounters,
}

/// A per-kernel cycle-attribution report.
#[derive(Debug, Clone)]
pub struct AttributionReport {
    /// Kernel / program name the trace came from.
    pub kernel: String,
    /// Number of cores in the traced cluster.
    pub cores: usize,
    /// Every (region, core) pair with a nonzero delta.
    pub rows: Vec<RegionRow>,
    /// Cycles the DMA engine was busy (transfer setup + data beats),
    /// overlap-collapsed across concurrent triggers.
    pub dma_busy: u64,
    /// Cycles cores spent spinning in `dma-wait` regions (summed over
    /// cores), i.e. DMA time the cluster failed to hide behind compute.
    pub dma_wait_cycles: u64,
}

impl AttributionReport {
    /// DMA-overlap efficiency in `[0, 1]`: the fraction of DMA busy time
    /// hidden behind compute (`1 - dma_wait / dma_busy`, clamped). `None`
    /// when the run triggered no DMA.
    pub fn dma_overlap_efficiency(&self) -> Option<f64> {
        if self.dma_busy == 0 {
            return None;
        }
        let ratio = self.dma_wait_cycles as f64 / self.dma_busy as f64;
        Some((1.0 - ratio).clamp(0.0, 1.0))
    }

    /// Region names present in the report, in first-appearance order.
    pub fn regions(&self) -> Vec<&str> {
        let mut seen: Vec<&str> = Vec::new();
        for r in &self.rows {
            if !seen.contains(&r.region.as_str()) {
                seen.push(&r.region);
            }
        }
        seen
    }

    /// Summed delta across cores for one region name.
    pub fn region_total(&self, region: &str) -> CoreCounters {
        let mut acc = CoreCounters::default();
        for r in &self.rows {
            if r.region == region {
                acc.accumulate(&r.delta);
            }
        }
        acc
    }

    /// Check the report against the run's final counters. Exact — every
    /// field of every core's `CoreCounters` must equal the sum of that
    /// core's region deltas, and every row must satisfy
    /// `active + stalls() == cycles`. Returns a description of the first
    /// mismatch, if any.
    pub fn reconcile(&self, stats: &RunStats) -> Result<(), String> {
        for row in &self.rows {
            let d = &row.delta;
            if d.active + d.stalls() != d.cycles {
                return Err(format!(
                    "region '{}' core {}: active {} + stalls {} != cycles {}",
                    row.region,
                    row.core,
                    d.active,
                    d.stalls(),
                    d.cycles
                ));
            }
        }
        let mut per_core = vec![CoreCounters::default(); stats.per_core.len()];
        for row in &self.rows {
            if row.core >= per_core.len() {
                return Err(format!("row for core {} out of range", row.core));
            }
            per_core[row.core].accumulate(&row.delta);
        }
        for (ci, (got, want)) in per_core.iter().zip(stats.per_core.iter()).enumerate() {
            if got != want {
                return Err(format!(
                    "core {ci}: attributed sum {got:?} != run counters {want:?}"
                ));
            }
        }
        Ok(())
    }

    /// Per-region summary table (summed across cores): cycles, active, and
    /// the full stall taxonomy, with a share-of-total-cycles column.
    pub fn table(&self) -> Table {
        let mut headers = vec![
            "region".to_string(),
            "cycles".to_string(),
            "share".to_string(),
            "active".to_string(),
            "instrs".to_string(),
        ];
        for (name, _) in CoreCounters::default().stall_breakdown() {
            headers.push(name.to_string());
        }
        let mut t = Table::new(headers);
        let grand: u64 = self.regions().iter().map(|r| self.region_total(r).cycles).sum();
        for region in self.regions() {
            let c = self.region_total(region);
            let share = if grand == 0 { 0.0 } else { 100.0 * c.cycles as f64 / grand as f64 };
            let mut cells = vec![
                region.to_string(),
                c.cycles.to_string(),
                format!("{share:.1}%"),
                c.active.to_string(),
                c.instrs.to_string(),
            ];
            for (_, v) in c.stall_breakdown() {
                cells.push(v.to_string());
            }
            t.row(cells);
        }
        t
    }

    /// Per-core rows for one region (used by `transpfp trace --region`).
    pub fn region_table(&self, region: &str) -> Table {
        let mut headers = vec!["core".to_string(), "cycles".to_string(), "active".to_string()];
        for (name, _) in CoreCounters::default().stall_breakdown() {
            headers.push(name.to_string());
        }
        let mut t = Table::new(headers);
        for row in self.rows.iter().filter(|r| r.region == region) {
            let mut cells = vec![
                row.core.to_string(),
                row.delta.cycles.to_string(),
                row.delta.active.to_string(),
            ];
            for (_, v) in row.delta.stall_breakdown() {
                cells.push(v.to_string());
            }
            t.row(cells);
        }
        t
    }

    /// Full per-(region, core) attribution as CSV, plus DMA summary lines
    /// are left to the caller (they are scalars, not rows).
    pub fn to_csv(&self) -> String {
        let mut headers = vec![
            "kernel".to_string(),
            "region".to_string(),
            "core".to_string(),
            "cycles".to_string(),
            "active".to_string(),
            "instrs".to_string(),
        ];
        for (name, _) in CoreCounters::default().stall_breakdown() {
            headers.push(name.to_string());
        }
        let mut t = Table::new(headers);
        for row in &self.rows {
            let mut cells = vec![
                self.kernel.clone(),
                row.region.clone(),
                row.core.to_string(),
                row.delta.cycles.to_string(),
                row.delta.active.to_string(),
                row.delta.instrs.to_string(),
            ];
            for (_, v) in row.delta.stall_breakdown() {
                cells.push(v.to_string());
            }
            t.row(cells);
        }
        t.to_csv()
    }

    /// One-line summary for serve spans and logs: total cycles, active
    /// share, and the single largest stall bucket.
    pub fn summary_line(&self) -> String {
        let mut total = CoreCounters::default();
        for r in &self.rows {
            total.accumulate(&r.delta);
        }
        if total.cycles == 0 {
            return "cycles=0".to_string();
        }
        let active_pct = 100.0 * total.active as f64 / total.cycles as f64;
        let (mut top_name, mut top_v) = ("none", 0u64);
        for (name, v) in total.stall_breakdown() {
            if v > top_v {
                top_name = name;
                top_v = v;
            }
        }
        let top_pct = 100.0 * top_v as f64 / total.cycles as f64;
        match self.dma_overlap_efficiency() {
            Some(eff) => format!(
                "cycles={} active={active_pct:.1}% top-stall={top_name}:{top_pct:.1}% dma-overlap={:.2}",
                total.cycles, eff
            ),
            None => format!(
                "cycles={} active={active_pct:.1}% top-stall={top_name}:{top_pct:.1}%",
                total.cycles
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(cycles: u64, active: u64, tcdm: u64) -> CoreCounters {
        CoreCounters {
            cycles,
            active,
            tcdm_cont: tcdm,
            ..CoreCounters::default()
        }
    }

    fn report() -> AttributionReport {
        AttributionReport {
            kernel: "K".to_string(),
            cores: 2,
            rows: vec![
                RegionRow { region: "(outside)".into(), core: 0, delta: delta(10, 8, 2) },
                RegionRow { region: "hot".into(), core: 0, delta: delta(20, 15, 5) },
                RegionRow { region: "hot".into(), core: 1, delta: delta(30, 30, 0) },
            ],
            dma_busy: 100,
            dma_wait_cycles: 25,
        }
    }

    #[test]
    fn reconcile_accepts_matching_stats() {
        let rep = report();
        let stats = RunStats {
            per_core: vec![delta(30, 23, 7), delta(30, 30, 0)],
            total_cycles: 30,
        };
        assert_eq!(rep.reconcile(&stats), Ok(()));
    }

    #[test]
    fn reconcile_rejects_any_field_drift() {
        let rep = report();
        let stats = RunStats {
            per_core: vec![delta(30, 23, 7), delta(31, 31, 0)],
            total_cycles: 31,
        };
        assert!(rep.reconcile(&stats).is_err());
    }

    #[test]
    fn reconcile_rejects_uncategorized_rows() {
        let mut rep = report();
        // 5 cycles with no active/stall coverage — the taxonomy gap the
        // satellite fix closes must never reappear.
        rep.rows[0].delta.cycles += 5;
        let stats = RunStats {
            per_core: vec![delta(35, 23, 7), delta(30, 30, 0)],
            total_cycles: 35,
        };
        assert!(rep.reconcile(&stats).is_err());
    }

    #[test]
    fn overlap_and_summary() {
        let rep = report();
        let eff = rep.dma_overlap_efficiency().unwrap();
        assert!((eff - 0.75).abs() < 1e-12);
        let line = rep.summary_line();
        assert!(line.contains("cycles=60"), "{line}");
        assert!(line.contains("dma-overlap=0.75"), "{line}");
        let mut none = rep.clone();
        none.dma_busy = 0;
        assert!(none.dma_overlap_efficiency().is_none());
    }

    #[test]
    fn tables_have_taxonomy_columns() {
        let rep = report();
        let csv = rep.table().to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.contains("tcdm_cont"));
        assert!(header.contains("branch_stall"));
        assert_eq!(rep.regions(), vec!["(outside)", "hot"]);
        let full = rep.to_csv();
        assert_eq!(full.lines().count(), 4);
        assert!(full.contains("K,hot,1,30,30"));
    }
}
