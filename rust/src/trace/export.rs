//! Trace exporters: a flat records CSV and a chrome://tracing JSON
//! (`chrome://tracing` / Perfetto "trace event format"), both hand-written
//! so the crate stays dependency-free.
//!
//! Files land under `artifacts/trace/` by default, named
//! `<kernel>-<variant>.{csv,json}`.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use super::db::{TraceDb, TraceKind, TraceRecord};

/// Flat CSV of every retained record:
/// `core,cycle,pc,kind,cause,region,arg`. `cause` is filled for stall
/// records, `region` for region enter/exit records (resolved through
/// `names`), both empty otherwise.
pub fn records_csv(db: &TraceDb, names: &[String]) -> String {
    let mut out = String::from("core,cycle,pc,kind,cause,region,arg\n");
    for ci in 0..db.cores() {
        for r in db.records(ci) {
            let cause = match r.kind {
                TraceKind::Stall(c) => c.name(),
                _ => "",
            };
            let region = match r.kind {
                TraceKind::RegionEnter | TraceKind::RegionExit => {
                    names.get(r.arg as usize).map(String::as_str).unwrap_or("?")
                }
                _ => "",
            };
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                ci,
                r.cycle,
                r.pc,
                r.kind.name(),
                cause,
                region,
                r.arg
            ));
        }
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Chrome trace-event JSON. Cycles map 1:1 to microseconds (`ts`/`dur`),
/// so the viewer's time axis reads directly as cycles. Per core (`tid` =
/// core index): `B`/`E` events for regions and `X` duration events for
/// stalls and event/barrier idle time; DMA transfers go on a dedicated
/// lane (`tid` = core count) as `X` events. `Issue` records are omitted —
/// they are per-attempt and would swamp the viewer; use the CSV for those.
pub fn chrome_json(db: &TraceDb, names: &[String], kernel: &str) -> String {
    let mut events: Vec<String> = Vec::new();
    let dma_tid = db.cores();
    events.push(format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"tid\":0,\
         \"args\":{{\"name\":\"{}\"}}}}",
        json_escape(kernel)
    ));
    for ci in 0..db.cores() {
        events.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{ci},\
             \"args\":{{\"name\":\"core{ci}\"}}}}"
        ));
    }
    events.push(format!(
        "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{dma_tid},\
         \"args\":{{\"name\":\"dma\"}}}}"
    ));
    for ci in 0..db.cores() {
        for r in db.records(ci) {
            if let Some(e) = event_json(r, ci, dma_tid, names) {
                events.push(e);
            }
        }
    }
    format!("{{\"traceEvents\":[\n{}\n]}}\n", events.join(",\n"))
}

fn event_json(r: &TraceRecord, ci: usize, dma_tid: usize, names: &[String]) -> Option<String> {
    let region_name = |id: u64| -> String {
        json_escape(names.get(id as usize).map(String::as_str).unwrap_or("?"))
    };
    match r.kind {
        TraceKind::Issue => None,
        TraceKind::RegionEnter => Some(format!(
            "{{\"name\":\"{}\",\"cat\":\"region\",\"ph\":\"B\",\"pid\":0,\
             \"tid\":{ci},\"ts\":{}}}",
            region_name(r.arg),
            r.cycle
        )),
        TraceKind::RegionExit => Some(format!(
            "{{\"name\":\"{}\",\"cat\":\"region\",\"ph\":\"E\",\"pid\":0,\
             \"tid\":{ci},\"ts\":{}}}",
            region_name(r.arg),
            r.cycle
        )),
        TraceKind::Stall(cause) => Some(format!(
            "{{\"name\":\"{}\",\"cat\":\"stall\",\"ph\":\"X\",\"pid\":0,\
             \"tid\":{ci},\"ts\":{},\"dur\":{},\"args\":{{\"pc\":{}}}}}",
            cause.name(),
            r.cycle,
            r.arg.max(1),
            r.pc
        )),
        TraceKind::EventWait | TraceKind::Barrier => Some(format!(
            "{{\"name\":\"{}\",\"cat\":\"idle\",\"ph\":\"X\",\"pid\":0,\
             \"tid\":{ci},\"ts\":{},\"dur\":{},\"args\":{{\"pc\":{}}}}}",
            r.kind.name(),
            r.cycle,
            r.arg.max(1),
            r.pc
        )),
        // One X event per transfer, emitted at the landing record so the
        // busy span (`arg`) is known; the start record only marks the
        // trigger instant.
        TraceKind::DmaStart => None,
        TraceKind::DmaLand => Some(format!(
            "{{\"name\":\"dma\",\"cat\":\"dma\",\"ph\":\"X\",\"pid\":0,\
             \"tid\":{dma_tid},\"ts\":{},\"dur\":{},\"args\":{{\"core\":{ci}}}}}",
            r.cycle - r.arg,
            r.arg.max(1)
        )),
    }
}

/// Default artifact directory for trace exports.
pub fn default_dir() -> PathBuf {
    PathBuf::from("artifacts/trace")
}

/// Write `contents` to `<dir>/<base>.<ext>`, creating the directory.
pub fn write_artifact(dir: &Path, base: &str, ext: &str, contents: &str) -> io::Result<PathBuf> {
    fs::create_dir_all(dir)?;
    let path = dir.join(format!("{base}.{ext}"));
    fs::write(&path, contents)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::db::{StallCause, TraceSink};

    fn sample_db() -> (TraceDb, Vec<String>) {
        let mut db = TraceDb::new(2, 64);
        let names = vec!["(outside)".to_string(), "hot\"loop".to_string()];
        db.record(0, TraceRecord { cycle: 4, pc: 2, kind: TraceKind::RegionEnter, arg: 1 });
        db.record(0, TraceRecord { cycle: 5, pc: 3, kind: TraceKind::Issue, arg: 0 });
        db.record(
            0,
            TraceRecord { cycle: 6, pc: 3, kind: TraceKind::Stall(StallCause::L2), arg: 9 },
        );
        db.record(0, TraceRecord { cycle: 20, pc: 7, kind: TraceKind::RegionExit, arg: 1 });
        db.record(1, TraceRecord { cycle: 8, pc: 5, kind: TraceKind::DmaStart, arg: 16 });
        db.record(1, TraceRecord { cycle: 34, pc: 5, kind: TraceKind::DmaLand, arg: 26 });
        (db, names)
    }

    #[test]
    fn csv_has_header_and_all_records() {
        let (db, names) = sample_db();
        let csv = records_csv(&db, &names);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "core,cycle,pc,kind,cause,region,arg");
        assert_eq!(lines.len(), 1 + 6);
        assert!(csv.contains("0,6,3,stall,l2_stall,,9"));
        assert!(csv.contains("1,8,5,dma_start,,,16"));
    }

    #[test]
    fn chrome_json_is_structurally_sound() {
        let (db, names) = sample_db();
        let j = chrome_json(&db, &names, "matmul");
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.trim_end().ends_with("]}"));
        // Region name with a quote is escaped.
        assert!(j.contains("hot\\\"loop"));
        // DMA lands as an X on the dma lane starting at land - busy.
        assert!(j.contains("\"cat\":\"dma\""));
        assert!(j.contains("\"ts\":8,\"dur\":26"));
        // Braces balance (cheap well-formedness check without a parser).
        let opens = j.matches('{').count();
        let closes = j.matches('}').count();
        assert_eq!(opens, closes);
        // No dangling comma before the closing bracket.
        assert!(!j.contains(",\n]"));
    }

    #[test]
    fn write_artifact_creates_dirs() {
        let dir = std::env::temp_dir().join("transpfp-trace-test");
        let _ = fs::remove_dir_all(&dir);
        let p = write_artifact(&dir, "matmul-scalar", "csv", "a,b\n").unwrap();
        assert!(p.ends_with("matmul-scalar.csv"));
        assert_eq!(fs::read_to_string(&p).unwrap(), "a,b\n");
        let _ = fs::remove_dir_all(&dir);
    }
}
