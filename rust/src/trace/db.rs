//! Trace database: compact per-core event records in bounded rings.
//!
//! Records are appended by the issue engines through the [`TraceSink`]
//! trait; the [`TraceDb`] keeps one bounded ring per core so a trace can
//! never grow without bound (a full ring drops its oldest records and
//! counts the drops). Records are 24-byte `Copy` values — cycle, pc, kind,
//! argument — small enough to trace multi-million-cycle runs.
//!
//! The differential wall (`tests/differential.rs`) asserts that both timed
//! engines emit **bit-identical** streams: same records, same cycles, same
//! order after a per-core sort.

use std::collections::VecDeque;

/// Why an issue attempt lost cycles. One variant per stall counter of
/// [`crate::cluster::counters::CoreCounters`], so every categorized stall
/// cycle has a trace-level cause.
///
/// `BarrierIdle` exists for the attribution taxonomy but never appears in a
/// [`TraceKind::Stall`] record: sleep time is traced with the dedicated
/// [`TraceKind::EventWait`] / [`TraceKind::Barrier`] kinds (whose `arg`
/// carries the idle amount, mirroring the `barrier_idle` counter bump).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StallCause {
    /// Lost a TCDM bank grant to another core (`tcdm_cont`).
    TcdmContention,
    /// Blocked on an L2 access latency (`l2_stall`).
    L2,
    /// Waiting for an in-flight FPU / DIV-SQRT result (`fpu_stall`).
    FpuLatency,
    /// Lost FPU-port arbitration to another core (`fpu_cont`).
    FpuContention,
    /// Waiting for the shared DIV-SQRT block (`divsqrt_cont`).
    DivSqrtContention,
    /// Write-back port conflict, FP result vs int/LSU write (`wb_stall`).
    Writeback,
    /// Load-use interlock on an integer load (`load_stall`).
    LoadUse,
    /// Instruction-cache miss (`icache_stall`).
    Icache,
    /// Asleep at the event unit (`barrier_idle`) — see the note above.
    BarrierIdle,
    /// Taken-branch flush bubbles (`branch_stall`).
    Branch,
}

impl StallCause {
    /// All causes, in `CoreCounters` field order.
    pub const ALL: [StallCause; 10] = [
        StallCause::TcdmContention,
        StallCause::L2,
        StallCause::FpuLatency,
        StallCause::FpuContention,
        StallCause::DivSqrtContention,
        StallCause::Writeback,
        StallCause::LoadUse,
        StallCause::Icache,
        StallCause::BarrierIdle,
        StallCause::Branch,
    ];

    /// The matching `CoreCounters` field name (stable; used in CSV exports
    /// and report columns).
    pub fn name(&self) -> &'static str {
        match self {
            StallCause::TcdmContention => "tcdm_cont",
            StallCause::L2 => "l2_stall",
            StallCause::FpuLatency => "fpu_stall",
            StallCause::FpuContention => "fpu_cont",
            StallCause::DivSqrtContention => "divsqrt_cont",
            StallCause::Writeback => "wb_stall",
            StallCause::LoadUse => "load_stall",
            StallCause::Icache => "icache_stall",
            StallCause::BarrierIdle => "barrier_idle",
            StallCause::Branch => "branch_stall",
        }
    }
}

/// What a [`TraceRecord`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TraceKind {
    /// An issue attempt that reached class dispatch. An instruction that
    /// lost arbitration `k` times appears as `k+1` `Issue` records with `k`
    /// `Stall` records interleaved — a faithful per-attempt trace.
    Issue,
    /// Lost cycles with their cause; `arg` = the bulk amount (matches the
    /// counter bump exactly).
    Stall(StallCause),
    /// Slept on a software event line; `cycle` = sleep start, `arg` = idle
    /// cycles until the wake (mirrors the `barrier_idle` bump).
    EventWait,
    /// Slept at (or completed) a barrier; same convention as `EventWait`.
    Barrier,
    /// A DMA transfer was triggered; `cycle` = trigger, `arg` = words.
    DmaStart,
    /// The transfer completed; `cycle` = completion, `arg` = busy cycles
    /// (setup + words) the engine spent on it.
    DmaLand,
    /// Entered an attribution region; `arg` = interned region id.
    RegionEnter,
    /// Left an attribution region; `arg` = interned region id.
    RegionExit,
}

impl TraceKind {
    /// Stable kind tag for exports.
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Issue => "issue",
            TraceKind::Stall(_) => "stall",
            TraceKind::EventWait => "event_wait",
            TraceKind::Barrier => "barrier",
            TraceKind::DmaStart => "dma_start",
            TraceKind::DmaLand => "dma_land",
            TraceKind::RegionEnter => "region_enter",
            TraceKind::RegionExit => "region_exit",
        }
    }
}

/// One per-core trace event. Derived `Ord` sorts by cycle first — the
/// per-core sort the differential wall compares under.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceRecord {
    /// Cycle the event is anchored at (issue attempt / sleep start /
    /// trigger / completion).
    pub cycle: u64,
    /// Program counter of the instruction involved.
    pub pc: u32,
    /// Event kind.
    pub kind: TraceKind,
    /// Kind-specific argument (stall amount, idle cycles, words, region id).
    pub arg: u64,
}

/// Where trace records go. The engines call this through the tracer; tests
/// can substitute counting or filtering sinks.
pub trait TraceSink {
    /// Append `rec` to core `core`'s stream.
    fn record(&mut self, core: usize, rec: TraceRecord);
}

/// Bounded per-core ring buffers of trace records.
pub struct TraceDb {
    capacity: usize,
    lanes: Vec<Lane>,
}

struct Lane {
    buf: VecDeque<TraceRecord>,
    dropped: u64,
}

impl TraceDb {
    /// A database with one ring of at most `capacity` records per core.
    pub fn new(cores: usize, capacity: usize) -> Self {
        assert!(capacity > 0, "trace ring capacity must be positive");
        TraceDb {
            capacity,
            lanes: (0..cores).map(|_| Lane { buf: VecDeque::new(), dropped: 0 }).collect(),
        }
    }

    /// Number of core lanes.
    pub fn cores(&self) -> usize {
        self.lanes.len()
    }

    /// Per-core ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Records currently held for core `ci`, oldest first.
    pub fn records(&self, ci: usize) -> impl Iterator<Item = &TraceRecord> {
        self.lanes[ci].buf.iter()
    }

    /// Records held for core `ci`, sorted by `(cycle, pc, kind, arg)` — the
    /// canonical order the differential wall compares under.
    pub fn sorted(&self, ci: usize) -> Vec<TraceRecord> {
        let mut v: Vec<TraceRecord> = self.lanes[ci].buf.iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// Records held for core `ci`.
    pub fn len(&self, ci: usize) -> usize {
        self.lanes[ci].buf.len()
    }

    /// True if no core holds any record.
    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.buf.is_empty())
    }

    /// Records dropped from core `ci`'s ring because it was full.
    pub fn dropped(&self, ci: usize) -> u64 {
        self.lanes[ci].dropped
    }

    /// Total records held across all cores.
    pub fn total_len(&self) -> usize {
        self.lanes.iter().map(|l| l.buf.len()).sum()
    }

    /// Total records dropped across all cores.
    pub fn total_dropped(&self) -> u64 {
        self.lanes.iter().map(|l| l.dropped).sum()
    }

    /// Forget every record and drop count, keeping allocations (called by
    /// `Cluster::reset` between repetitions).
    pub fn clear(&mut self) {
        for l in &mut self.lanes {
            l.buf.clear();
            l.dropped = 0;
        }
    }
}

impl TraceSink for TraceDb {
    fn record(&mut self, core: usize, rec: TraceRecord) {
        let lane = &mut self.lanes[core];
        if lane.buf.len() == self.capacity {
            lane.buf.pop_front();
            lane.dropped += 1;
        }
        lane.buf.push_back(rec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(cycle: u64, pc: u32) -> TraceRecord {
        TraceRecord { cycle, pc, kind: TraceKind::Issue, arg: 0 }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut db = TraceDb::new(2, 3);
        for i in 0..5 {
            db.record(0, rec(i, i as u32));
        }
        assert_eq!(db.len(0), 3);
        assert_eq!(db.dropped(0), 2);
        assert_eq!(db.len(1), 0);
        let kept: Vec<u64> = db.records(0).map(|r| r.cycle).collect();
        assert_eq!(kept, vec![2, 3, 4], "oldest records evicted first");
        db.clear();
        assert!(db.is_empty());
        assert_eq!(db.total_dropped(), 0);
    }

    #[test]
    fn sorted_orders_by_cycle_first() {
        let mut db = TraceDb::new(1, 16);
        db.record(0, rec(9, 1));
        db.record(0, rec(3, 7));
        db.record(0, TraceRecord {
            cycle: 3,
            pc: 2,
            kind: TraceKind::Stall(StallCause::TcdmContention),
            arg: 1,
        });
        let s = db.sorted(0);
        assert_eq!(s[0].cycle, 3);
        assert_eq!(s[0].pc, 2);
        assert_eq!(s[2].cycle, 9);
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(StallCause::ALL.len(), 10);
        let names: Vec<&str> = StallCause::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(names[0], "tcdm_cont");
        assert_eq!(names[9], "branch_stall");
        // All distinct: the report keys columns on them.
        for (i, a) in names.iter().enumerate() {
            for b in &names[i + 1..] {
                assert_ne!(a, b);
            }
        }
        assert_eq!(TraceKind::Issue.name(), "issue");
        assert_eq!(TraceKind::Stall(StallCause::L2).name(), "stall");
    }
}
