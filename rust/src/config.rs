//! Cluster configuration — the design space of Table 2.
//!
//! A configuration is (number of cores, number of FPU instances, FPU
//! pipeline stages), written `<c>c<f>f<p>p` (e.g. `8c4f1p`). The 18
//! configurations of Table 2 are the cross product {8,16} × sharing factor
//! {1/4, 1/2, 1/1} × pipeline {0,1,2}.

use std::fmt;

/// Supply-voltage corner (§3.3): near-threshold 0.65 V or super-threshold
/// 0.8 V. Performance/area efficiency are reported at ST, energy efficiency
/// at NT, matching Tables 4/5.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Corner {
    /// 0.65 V near-threshold.
    Nt,
    /// 0.8 V super-threshold.
    St,
}

impl Corner {
    /// Supply voltage in volts.
    pub fn vdd(&self) -> f64 {
        match self {
            Corner::Nt => 0.65,
            Corner::St => 0.80,
        }
    }
}

impl fmt::Display for Corner {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Corner::Nt => write!(f, "NT(0.65V)"),
            Corner::St => write!(f, "ST(0.8V)"),
        }
    }
}

/// One point of the Table 2 design space, plus the fixed memory parameters
/// of §3.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ClusterConfig {
    /// Number of RI5CY cores (8 or 16).
    pub cores: usize,
    /// Number of shared FPU instances (cores/4, cores/2 or cores).
    pub fpus: usize,
    /// FPU pipeline stages (0, 1 or 2).
    pub pipe: u32,
    /// Ablation knob: use a *blocked* core→FPU mapping (core c → FPU
    /// c / sharing) instead of the paper's interleaved allocation (§3.2).
    /// Always `false` in the Table 2 design space.
    pub blocked_fpu_map: bool,
}

impl ClusterConfig {
    /// Construct and validate (interleaved FPU mapping, as in the paper).
    pub fn new(cores: usize, fpus: usize, pipe: u32) -> Self {
        let c = ClusterConfig { cores, fpus, pipe, blocked_fpu_map: false };
        c.validate();
        c
    }

    /// Ablation variant with the blocked (non-interleaved) FPU mapping.
    pub fn with_blocked_fpu_map(mut self) -> Self {
        self.blocked_fpu_map = true;
        self
    }

    fn validate(&self) {
        assert!(self.cores > 0 && self.cores <= 64, "cores out of range");
        assert!(self.fpus > 0 && self.fpus <= self.cores, "fpus out of range");
        assert!(self.cores % self.fpus == 0, "cores must be a multiple of fpus");
        assert!(self.pipe <= 2, "pipeline stages 0..=2");
    }

    /// The 18 configurations of Table 2, in table order.
    pub fn design_space() -> Vec<ClusterConfig> {
        let mut v = Vec::new();
        for &cores in &[8usize, 16] {
            for sharing_div in [4usize, 2, 1] {
                for pipe in 0..=2u32 {
                    v.push(ClusterConfig::new(cores, cores / sharing_div, pipe));
                }
            }
        }
        v
    }

    /// Sharing factor denominator: 1/N cores per FPU (4, 2 or 1).
    pub fn sharing_div(&self) -> usize {
        self.cores / self.fpus
    }

    /// TCDM size in bytes: 64 kB for 8-core, 128 kB for 16-core (§3.1).
    pub fn tcdm_bytes(&self) -> usize {
        if self.cores <= 8 {
            64 * 1024
        } else {
            128 * 1024
        }
    }

    /// Number of TCDM banks (banking factor 2, the PULP cluster default).
    pub fn tcdm_banks(&self) -> usize {
        self.cores * 2
    }

    /// L2 size in bytes (512 kB, §3.1).
    pub fn l2_bytes(&self) -> usize {
        512 * 1024
    }

    /// L2 access latency in cycles (§3.1: "15-cycle latency multi-banked
    /// scratchpad").
    pub fn l2_latency(&self) -> u64 {
        15
    }

    /// Static core→FPU mapping. Interleaved allocation (§3.2, Fig 2): core
    /// `c` uses FPU `c mod fpus`, so neighbouring cores hit different units
    /// when parallel sections use fewer workers than cores. The blocked
    /// ablation maps `c / sharing` instead (neighbours share).
    pub fn fpu_of_core(&self, core: usize) -> usize {
        if self.blocked_fpu_map {
            core / self.sharing_div()
        } else {
            core % self.fpus
        }
    }

    /// Mnemonic per Table 2, e.g. `16c8f1p`.
    pub fn mnemonic(&self) -> String {
        format!("{}c{}f{}p", self.cores, self.fpus, self.pipe)
    }

    /// Parse a Table 2 mnemonic.
    pub fn parse(s: &str) -> Option<ClusterConfig> {
        let s = s.trim();
        let c_pos = s.find('c')?;
        let f_pos = s.find('f')?;
        let p_pos = s.find('p')?;
        if !(c_pos < f_pos && f_pos < p_pos) {
            return None;
        }
        let cores: usize = s[..c_pos].parse().ok()?;
        let fpus: usize = s[c_pos + 1..f_pos].parse().ok()?;
        let pipe: u32 = s[f_pos + 1..p_pos].parse().ok()?;
        if cores == 0 || fpus == 0 || fpus > cores || cores % fpus != 0 || pipe > 2 {
            return None;
        }
        Some(ClusterConfig { cores, fpus, pipe, blocked_fpu_map: false })
    }
}

impl fmt::Display for ClusterConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.mnemonic())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_space_matches_table2() {
        let ds = ClusterConfig::design_space();
        assert_eq!(ds.len(), 18);
        let mnems: Vec<String> = ds.iter().map(|c| c.mnemonic()).collect();
        // Table 2 rows, in order.
        let expect = [
            "8c2f0p", "8c2f1p", "8c2f2p", "8c4f0p", "8c4f1p", "8c4f2p", "8c8f0p", "8c8f1p",
            "8c8f2p", "16c4f0p", "16c4f1p", "16c4f2p", "16c8f0p", "16c8f1p", "16c8f2p",
            "16c16f0p", "16c16f1p", "16c16f2p",
        ];
        assert_eq!(mnems, expect);
    }

    #[test]
    fn parse_roundtrip() {
        for cfg in ClusterConfig::design_space() {
            assert_eq!(ClusterConfig::parse(&cfg.mnemonic()), Some(cfg));
        }
        assert_eq!(ClusterConfig::parse("bogus"), None);
        assert_eq!(ClusterConfig::parse("8c16f0p"), None); // fpus > cores
        assert_eq!(ClusterConfig::parse("8c3f0p"), None); // not a divisor
    }

    #[test]
    fn interleaved_mapping() {
        // Fig 2: 8 cores, 4 FPUs → FPU i serves cores i and i+4.
        let cfg = ClusterConfig::new(8, 4, 1);
        assert_eq!(cfg.fpu_of_core(0), 0);
        assert_eq!(cfg.fpu_of_core(4), 0);
        assert_eq!(cfg.fpu_of_core(1), 1);
        assert_eq!(cfg.fpu_of_core(5), 1);
        assert_eq!(cfg.fpu_of_core(7), 3);
        assert_eq!(cfg.sharing_div(), 2);
    }

    #[test]
    fn memory_parameters() {
        assert_eq!(ClusterConfig::new(8, 8, 0).tcdm_bytes(), 64 * 1024);
        assert_eq!(ClusterConfig::new(16, 4, 2).tcdm_bytes(), 128 * 1024);
        assert_eq!(ClusterConfig::new(16, 16, 1).tcdm_banks(), 32);
        assert_eq!(ClusterConfig::new(8, 2, 0).l2_latency(), 15);
    }

    #[test]
    fn corners() {
        assert_eq!(Corner::Nt.vdd(), 0.65);
        assert_eq!(Corner::St.vdd(), 0.80);
    }
}
