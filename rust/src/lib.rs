//! # transpfp — a transprecision floating-point cluster, reproduced in software
//!
//! Reproduction of *"A Transprecision Floating-Point Cluster for Efficient
//! Near-Sensor Data Analytics"* (TPDS 2021). The crate contains:
//!
//! * [`transfp`] — bit-accurate softfloat for the FPnew formats (binary32,
//!   binary16, bfloat16; scalar + packed-SIMD + widening FMA + casts);
//! * [`isa`] — the RI5CY/Xpulp-like instruction set and assembler DSL the
//!   benchmark kernels are written in;
//! * [`cluster`] — the cycle-accurate cluster simulator (cores, shared FPUs,
//!   DIV-SQRT, banked TCDM, I$, event unit, DMA) and the tiered execution
//!   backends behind it (event / reference / functional interpreter /
//!   compiled fused-block translator, differentially tested four ways);
//! * [`config`] — the Table 2 design space;
//! * [`model`] — calibrated frequency / power / area models (Figs 3–5);
//! * [`kernels`] — the 8 near-sensor benchmarks × {scalar, vector};
//! * [`coordinator`] — the design-space-exploration engine producing the
//!   paper's tables and figures;
//! * [`tuner`] — the accuracy-aware transprecision autotuner (per-kernel
//!   precision ladders, error metrics, `transpfp tune`);
//! * [`faults`] — seeded SEU injection campaigns with outcome
//!   classification and detect-and-retry recovery (`transpfp inject`);
//! * [`runtime`] — PJRT loading of the AOT-compiled JAX/Pallas goldens
//!   (`artifacts/*.hlo.txt`) for numeric validation;
//! * [`report`] — table/CSV emitters and the Table 6 SoA data;
//! * [`cli`] — the declarative flag/command registries both the binary and
//!   the serve wire protocol parse with;
//! * [`server`] — `transpfp serve`, the concurrent design-space query
//!   service (newline-delimited protocol, single-flight dedup,
//!   per-endpoint metrics);
//! * [`trace`] — opt-in cycle-attribution tracing: per-core trace
//!   database, region markers, attribution reports that reconcile exactly
//!   with `RunStats`, and CSV / chrome://tracing exporters
//!   (`transpfp trace`).
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod faults;
pub mod isa;
pub mod kernels;
pub mod model;
pub mod report;
pub mod runtime;
pub mod server;
pub mod testutil;
pub mod trace;
pub mod transfp;
pub mod tuner;

/// The types almost every downstream use of the crate needs: build a
/// [`prelude::QueryPoint`], resolve it through a
/// [`prelude::QueryEngine`], or lower a CLI/wire command into a
/// [`prelude::Request`].
pub mod prelude {
    pub use crate::cli::{parse_cli, Cli};
    pub use crate::config::ClusterConfig;
    pub use crate::coordinator::{points, Measurement, QueryEngine, QueryFailure, QueryPoint};
    pub use crate::kernels::{Benchmark, Variant};
    pub use crate::server::{QueryTier, Reply, Request, Selector, Server};
    pub use crate::trace::{
        AttributionReport, StallCause, TraceConfig, TraceDb, TraceKind, TraceRecord, TraceSink,
        Tracer,
    };
    pub use crate::tuner::Probe;
}
