//! Accuracy-aware transprecision autotuner.
//!
//! The paper's core claim is that precision is a *tunable knob*: a
//! near-sensor workload should run at the cheapest FP format that still
//! meets its accuracy requirement (§2, §5.2). The rest of the crate can
//! simulate every format and measure performance/energy/area — this module
//! closes the loop:
//!
//! * [`accuracy`] — quantitative error metrics (max-abs, RMS, relative L2)
//!   of a run against the per-workload binary64 reference;
//! * [`ladder`] — the ordered per-kernel precision ladder
//!   F32 → scalar-16 → vec-16, in both 16-bit formats;
//! * [`search`] — greedy descent + exhaustive fallback over the ladder,
//!   resolved through the memoizing [`crate::coordinator::QueryEngine`]
//!   (warm tuning runs issue zero simulator runs), producing a
//!   [`search::TuneReport`] with (error, Gflop/s, Gflop/s/W) deltas vs
//!   binary32.
//!
//! The CLI surface is `transpfp tune --budget <rel-err>`; the
//! accuracy-extended Pareto frontier over (error, perf, energy efficiency)
//! lives in [`crate::coordinator::pareto`].

pub mod accuracy;
pub mod ladder;
pub mod search;

pub use accuracy::{error_stats, ErrorStats};
pub use ladder::{ladder, LADDER};
pub use search::{
    tune, tune_table, tune_with, tune_with_probe, Probe, TuneChoice, TuneReport, DEFAULT_BUDGET,
};
