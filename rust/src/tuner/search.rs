//! Precision-ladder search: greedy descent with an exhaustive fallback.
//!
//! Given an error budget (relative L2 against the f64 reference), the tuner
//! resolves every rung of every benchmark's ladder through the memoizing
//! [`QueryEngine`] — so a warm tune issues **zero** simulator runs
//! (`benches/tuner.rs` gates this) — and then selects, per benchmark, the
//! most energy-efficient rung whose measured error meets the budget:
//!
//! 1. **Greedy descent** walks the ladder top-down while the next rung
//!    stays admissible. This alone would under-tune: error is not monotone
//!    along the ladder (the vector rungs accumulate in binary32, so
//!    `vector-f16` often beats `scalar-bf16` on accuracy *and* speed).
//! 2. **Exhaustive fallback** therefore scans every admissible rung and
//!    picks the best by (energy efficiency, then performance, then ladder
//!    depth). With five rungs per benchmark the scan is trivially cheap —
//!    all candidates are already resolved for step 1.
//!
//! If no rung meets the budget (including binary32 itself), the choice
//! falls back to the binary32 baseline and is flagged over-budget in the
//! report.

use std::cmp::Ordering;

use super::ladder::LADDER;
use crate::config::ClusterConfig;
use crate::coordinator::query::points;
use crate::coordinator::sweep::Measurement;
use crate::coordinator::QueryEngine;
use crate::kernels::Benchmark;
use crate::report::Table;

/// Default relative-error budget of `transpfp tune`.
pub const DEFAULT_BUDGET: f64 = 1e-2;

/// One benchmark's tuning outcome.
#[derive(Debug, Clone)]
pub struct TuneChoice {
    pub bench: Benchmark,
    /// The binary32 scalar baseline (rung 0).
    pub baseline: Measurement,
    /// The selected rung's measurement.
    pub chosen: Measurement,
    /// Index of the selected rung in [`LADDER`] (0 = stayed at binary32).
    pub rung: usize,
    /// Where the greedy descent alone stopped (before the fallback scan).
    pub greedy_rung: usize,
    /// How many of the five rungs met the budget.
    pub admissible: usize,
}

impl TuneChoice {
    /// True if the selection's measured error meets `budget`.
    pub fn within_budget(&self, budget: f64) -> bool {
        self.chosen.err.within(budget)
    }

    /// Performance of the selection relative to binary32 (×).
    pub fn speedup(&self) -> f64 {
        self.chosen.metrics.perf_gflops / self.baseline.metrics.perf_gflops
    }

    /// Energy efficiency of the selection relative to binary32 (×).
    pub fn eeff_gain(&self) -> f64 {
        self.chosen.metrics.energy_eff / self.baseline.metrics.energy_eff
    }
}

/// A full `transpfp tune` result: one choice per benchmark.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub cfg: ClusterConfig,
    pub budget: f64,
    pub choices: Vec<TuneChoice>,
}

impl TuneReport {
    /// Benchmarks for which a sub-binary32 rung was selected.
    pub fn sub_f32_count(&self) -> usize {
        self.choices.iter().filter(|c| c.chosen.variant.is_sub_f32()).count()
    }

    /// True if every selection's measured error meets the budget.
    pub fn all_within_budget(&self) -> bool {
        self.choices.iter().all(|c| c.within_budget(self.budget))
    }
}

/// Admissibility: numerically verified against the variant's own golden
/// *and* within the relative-error budget against the f64 reference.
fn admissible(m: &Measurement, budget: f64) -> bool {
    m.verified && m.err.within(budget)
}

/// Selection over one benchmark's resolved rungs (in [`LADDER`] order):
/// returns (chosen rung, greedy rung, admissible count). Factored out so
/// the policy is unit-testable on synthetic measurements.
fn select(rungs: &[Measurement], budget: f64) -> (usize, usize, usize) {
    // Greedy descent: keep stepping down while the next rung is admissible.
    let mut greedy = 0usize;
    while greedy + 1 < rungs.len() && admissible(&rungs[greedy + 1], budget) {
        greedy += 1;
    }
    let count = rungs.iter().filter(|m| admissible(m, budget)).count();
    // Exhaustive fallback: best admissible rung by (e.eff, perf, depth).
    let best = rungs
        .iter()
        .enumerate()
        .filter(|(_, m)| admissible(m, budget))
        .max_by(|(ia, a), (ib, b)| {
            a.metrics
                .energy_eff
                .partial_cmp(&b.metrics.energy_eff)
                .unwrap_or(Ordering::Equal)
                .then(
                    a.metrics
                        .perf_gflops
                        .partial_cmp(&b.metrics.perf_gflops)
                        .unwrap_or(Ordering::Equal),
                )
                .then(ia.cmp(ib))
        });
    match best {
        Some((i, _)) => (i, greedy, count),
        None => (0, greedy, count), // budget unattainable: stay at binary32
    }
}

/// Tune every benchmark on `cfg` under `budget`, resolving all candidates
/// through `engine`'s measurement cache.
pub fn tune_with(engine: &QueryEngine, cfg: &ClusterConfig, budget: f64) -> TuneReport {
    let benches = Benchmark::all();
    let ms = engine.query(&points(&[*cfg], &benches, &LADDER));
    let choices = benches
        .iter()
        .enumerate()
        .map(|(bi, &bench)| {
            let rungs = &ms[bi * LADDER.len()..(bi + 1) * LADDER.len()];
            let (rung, greedy_rung, admissible) = select(rungs, budget);
            TuneChoice {
                bench,
                baseline: rungs[0].clone(),
                chosen: rungs[rung].clone(),
                rung,
                greedy_rung,
                admissible,
            }
        })
        .collect();
    TuneReport { cfg: *cfg, budget, choices }
}

/// [`tune_with`] on the process-wide engine.
pub fn tune(cfg: &ClusterConfig, budget: f64) -> TuneReport {
    tune_with(QueryEngine::global(), cfg, budget)
}

/// Render one or more tune reports as a single table (text or CSV). The
/// leading `config` column keeps multi-config output (`transpfp tune all
/// --csv`) one well-formed CSV stream: one header, one row per
/// (config, benchmark).
pub fn tune_table(reports: &[TuneReport]) -> Table {
    let mut t = Table::new(vec![
        "config",
        "bench",
        "chosen",
        "rel_err",
        "within_budget",
        "admissible_rungs",
        "perf_gflops",
        "speedup_vs_f32",
        "energy_eff",
        "eeff_vs_f32",
        "cycles",
    ]);
    for r in reports {
        for c in &r.choices {
            t.row(vec![
                r.cfg.mnemonic(),
                c.bench.name().to_string(),
                c.chosen.variant.label().to_string(),
                format!("{:.3e}", c.chosen.err.rel),
                c.within_budget(r.budget).to_string(),
                c.admissible.to_string(),
                format!("{:.3}", c.chosen.metrics.perf_gflops),
                format!("{:.2}", c.speedup()),
                format!("{:.1}", c.chosen.metrics.energy_eff),
                format!("{:.2}", c.eeff_gain()),
                c.chosen.cycles.to_string(),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::counters::CoreCounters;
    use crate::kernels::Variant;
    use crate::model::Metrics;
    use crate::tuner::accuracy::ErrorStats;

    /// Synthetic rung measurement with the given (rel error, eeff, perf).
    fn rung(variant: Variant, rel: f64, eeff: f64, perf: f64, verified: bool) -> Measurement {
        Measurement {
            cfg: ClusterConfig::new(8, 8, 1),
            bench: Benchmark::Fir,
            variant,
            workers: 8,
            metrics: Metrics {
                perf_gflops: perf,
                energy_eff: eeff,
                area_eff: 1.0,
                flops_per_cycle: 1.0,
            },
            cycles: 1000,
            core_cycles: 8000,
            agg: CoreCounters::default(),
            fp_intensity: 0.3,
            mem_intensity: 0.5,
            verified,
            err: ErrorStats { max_abs: rel, rms: rel, rel },
        }
    }

    fn synthetic_ladder(errs: [f64; 5]) -> Vec<Measurement> {
        // Monotone cost model: deeper rungs are more efficient and faster.
        LADDER
            .iter()
            .zip(errs)
            .enumerate()
            .map(|(i, (&v, e))| rung(v, e, 50.0 + 10.0 * i as f64, 1.0 + i as f64, true))
            .collect()
    }

    #[test]
    fn greedy_descends_contiguous_prefix() {
        // All rungs admissible → greedy reaches the bottom, fallback keeps it.
        let rungs = synthetic_ladder([1e-7, 1e-3, 2e-3, 5e-4, 3e-3]);
        let (chosen, greedy, count) = select(&rungs, 1e-2);
        assert_eq!((chosen, greedy, count), (4, 4, 5));
    }

    #[test]
    fn exhaustive_fallback_beats_early_greedy_stop() {
        // scalar-f16 blows the budget but vector-f16 meets it: greedy stops
        // at the baseline, the exhaustive scan still finds rung 3.
        let rungs = synthetic_ladder([1e-7, 5e-2, 6e-2, 1e-3, 4e-2]);
        let (chosen, greedy, count) = select(&rungs, 1e-2);
        assert_eq!(greedy, 0, "greedy must stop at the first inadmissible rung");
        assert_eq!(chosen, 3, "fallback must find the admissible deep rung");
        assert_eq!(count, 2);
    }

    #[test]
    fn unattainable_budget_stays_at_f32() {
        let rungs = synthetic_ladder([1e-7, 1e-2, 1e-2, 1e-2, 1e-2]);
        let (chosen, _, count) = select(&rungs, 1e-9);
        assert_eq!(chosen, 0);
        assert_eq!(count, 0);
    }

    #[test]
    fn unverified_rungs_are_never_selected() {
        let mut rungs = synthetic_ladder([1e-7, 1e-4, 1e-4, 1e-4, 1e-4]);
        for r in &mut rungs[1..] {
            r.verified = false;
        }
        let (chosen, greedy, count) = select(&rungs, 1e-2);
        assert_eq!((chosen, greedy, count), (0, 0, 1));
    }

    /// Acceptance gate: on the paper's 8-core full-sharing configuration a
    /// 1e-2 budget must push at least half of the 8 benchmarks below
    /// binary32, every selection's measured error must meet the budget, and
    /// a warm re-tune must issue zero simulator runs.
    #[test]
    fn tune_descends_and_is_warm_cacheable() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 8, 1);
        let r = tune_with(&engine, &cfg, DEFAULT_BUDGET);
        assert_eq!(r.choices.len(), 8);
        assert!(
            r.sub_f32_count() >= 4,
            "budget 1e-2 must select a sub-F32 variant for at least half \
             of the benchmarks, got {}",
            r.sub_f32_count()
        );
        for c in &r.choices {
            assert!(c.within_budget(r.budget), "{}: over budget", c.bench.name());
            assert!(c.chosen.verified);
            assert!(c.speedup() > 0.0 && c.eeff_gain() > 0.0);
        }
        assert!(r.all_within_budget());

        let cold = engine.stats();
        let warm = tune_with(&engine, &cfg, DEFAULT_BUDGET);
        let after = engine.stats();
        assert_eq!(after.misses, cold.misses, "warm tune must not simulate");
        assert_eq!(warm.sub_f32_count(), r.sub_f32_count());
        for (a, b) in r.choices.iter().zip(&warm.choices) {
            assert_eq!(a.rung, b.rung, "{}: warm selection drifted", a.bench.name());
            assert_eq!(a.chosen.err.rel.to_bits(), b.chosen.err.rel.to_bits());
        }
    }

    #[test]
    fn tune_table_has_one_row_per_config_and_benchmark() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 2, 0);
        let r = tune_with(&engine, &cfg, DEFAULT_BUDGET);
        let csv = tune_table(std::slice::from_ref(&r)).to_csv();
        assert_eq!(csv.lines().count(), 1 + 8);
        assert!(csv.starts_with("config,bench,chosen,rel_err,"));
        // Two reports concatenate into one stream with a single header.
        let two = tune_table(&[r.clone(), r]).to_csv();
        assert_eq!(two.lines().count(), 1 + 16);
        assert_eq!(two.lines().filter(|l| l.starts_with("config,")).count(), 1);
    }
}
