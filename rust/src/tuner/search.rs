//! Precision-ladder search: greedy descent with an exhaustive fallback.
//!
//! Given an error budget (relative L2 against the f64 reference), the tuner
//! resolves every rung of every benchmark's ladder through the memoizing
//! [`QueryEngine`] — so a warm tune issues **zero** simulator runs
//! (`benches/tuner.rs` gates this) — and then selects, per benchmark, the
//! most energy-efficient rung whose measured error meets the budget:
//!
//! 1. **Greedy descent** walks the ladder top-down while the next rung
//!    stays admissible. This alone would under-tune: error is not monotone
//!    along the ladder (the vector rungs accumulate in binary32, so
//!    `vector-f16` often beats `scalar-bf16` on accuracy *and* speed).
//! 2. **Exhaustive fallback** therefore scans every admissible rung and
//!    picks the best by (energy efficiency, then performance, then ladder
//!    depth). With five rungs per benchmark the scan is trivially cheap —
//!    all candidates are already resolved for step 1.
//!
//! If no rung meets the budget (including binary32 itself), the choice
//! falls back to the binary32 baseline and is flagged over-budget in the
//! report.

use std::cmp::Ordering;

use super::ladder::LADDER;
use crate::config::ClusterConfig;
use crate::coordinator::query::{points, QueryPoint};
use crate::coordinator::sweep::Measurement;
use crate::coordinator::{Fidelity, QueryEngine, QueryFailure};
use crate::kernels::Benchmark;
use crate::report::Table;

/// Default relative-error budget of `transpfp tune`.
pub const DEFAULT_BUDGET: f64 = 1e-2;

/// How `tune` evaluates a rung's accuracy before paying for its timing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    /// Resolve every rung's `ErrorStats` on the functional interpreter
    /// first; only the binary32 baseline and the budget-admissible rungs
    /// are run cycle-accurately (accuracy-rejected rungs never touch the
    /// event engine).
    Functional,
    /// Like [`Probe::Functional`], but the accuracy probes execute on the
    /// compiled tier ([`crate::cluster::CompiledBackend`]) through the
    /// engine's translation cache — same bit-exact accuracy (the four-way
    /// differential wall), ≥10× the interpreter's instruction throughput
    /// on the loop-dominated kernels, and a warm tune re-translates
    /// nothing. The default of [`tune_with`] and the `tune` command.
    Compiled,
    /// Resolve every rung cycle-accurately (the pre-backend behaviour).
    CycleAccurate,
}

impl Probe {
    /// Stable name used by the CLI flag registry and the serve protocol.
    pub fn name(self) -> &'static str {
        match self {
            Probe::Functional => "functional",
            Probe::Compiled => "compiled",
            Probe::CycleAccurate => "cycle",
        }
    }

    /// Inverse of [`Probe::name`] (the long form `cycle-accurate` is also
    /// accepted).
    pub fn parse(s: &str) -> Option<Probe> {
        match s {
            "functional" => Some(Probe::Functional),
            "compiled" => Some(Probe::Compiled),
            "cycle" | "cycle-accurate" => Some(Probe::CycleAccurate),
            _ => None,
        }
    }
}

/// One benchmark's tuning outcome.
#[derive(Debug, Clone)]
pub struct TuneChoice {
    pub bench: Benchmark,
    /// The binary32 scalar baseline (rung 0).
    pub baseline: Measurement,
    /// The selected rung's measurement.
    pub chosen: Measurement,
    /// Index of the selected rung in [`LADDER`] (0 = stayed at binary32).
    pub rung: usize,
    /// Where the greedy descent alone stopped (before the fallback scan).
    pub greedy_rung: usize,
    /// How many of the five rungs met the budget.
    pub admissible: usize,
}

impl TuneChoice {
    /// True if the selection's measured error meets `budget`.
    pub fn within_budget(&self, budget: f64) -> bool {
        self.chosen.err.within(budget)
    }

    /// Performance of the selection relative to binary32 (×).
    pub fn speedup(&self) -> f64 {
        self.chosen.metrics.perf_gflops / self.baseline.metrics.perf_gflops
    }

    /// Energy efficiency of the selection relative to binary32 (×).
    pub fn eeff_gain(&self) -> f64 {
        self.chosen.metrics.energy_eff / self.baseline.metrics.energy_eff
    }
}

/// A full `transpfp tune` result: one choice per benchmark.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub cfg: ClusterConfig,
    pub budget: f64,
    pub choices: Vec<TuneChoice>,
}

impl TuneReport {
    /// Benchmarks for which a sub-binary32 rung was selected.
    pub fn sub_f32_count(&self) -> usize {
        self.choices.iter().filter(|c| c.chosen.variant.is_sub_f32()).count()
    }

    /// True if every selection's measured error meets the budget.
    pub fn all_within_budget(&self) -> bool {
        self.choices.iter().all(|c| c.within_budget(self.budget))
    }
}

/// Admissibility: numerically verified against the variant's own golden
/// *and* within the relative-error budget against the f64 reference.
fn admissible(m: &Measurement, budget: f64) -> bool {
    m.verified && m.err.within(budget)
}

/// Selection over one benchmark's resolved rungs (in [`LADDER`] order):
/// returns (chosen rung, greedy rung, admissible count). Factored out so
/// the policy is unit-testable on synthetic measurements.
fn select(rungs: &[Measurement], budget: f64) -> (usize, usize, usize) {
    // Greedy descent: keep stepping down while the next rung is admissible.
    let mut greedy = 0usize;
    while greedy + 1 < rungs.len() && admissible(&rungs[greedy + 1], budget) {
        greedy += 1;
    }
    let count = rungs.iter().filter(|m| admissible(m, budget)).count();
    // Exhaustive fallback: best admissible rung by (e.eff, perf, depth).
    let best = rungs
        .iter()
        .enumerate()
        .filter(|(_, m)| admissible(m, budget))
        .max_by(|(ia, a), (ib, b)| {
            a.metrics
                .energy_eff
                .partial_cmp(&b.metrics.energy_eff)
                .unwrap_or(Ordering::Equal)
                .then(
                    a.metrics
                        .perf_gflops
                        .partial_cmp(&b.metrics.perf_gflops)
                        .unwrap_or(Ordering::Equal),
                )
                .then(ia.cmp(ib))
        });
    match best {
        Some((i, _)) => (i, greedy, count),
        None => (0, greedy, count), // budget unattainable: stay at binary32
    }
}

/// Tune every benchmark on `cfg` under `budget` with the default
/// **compiled** accuracy probe: every ladder rung's `ErrorStats` comes
/// from the compiled tier (bit-identical to the interpreter, ≥10× its
/// instruction throughput on the loop-dominated kernels, one translation
/// per program through the engine's code cache), and only the baseline
/// plus the budget-admissible rungs are simulated cycle-accurately. Pass
/// [`Probe::Functional`] to [`tune_with_probe`] for the interpreter.
pub fn tune_with(
    engine: &QueryEngine,
    cfg: &ClusterConfig,
    budget: f64,
) -> Result<TuneReport, QueryFailure> {
    tune_with_probe(engine, cfg, budget, Probe::Compiled)
}

/// [`tune_with`] with an explicit probe mode.
pub fn tune_with_probe(
    engine: &QueryEngine,
    cfg: &ClusterConfig,
    budget: f64,
    probe: Probe,
) -> Result<TuneReport, QueryFailure> {
    let benches = Benchmark::all();
    let rung_sets: Vec<Vec<Measurement>> = match probe {
        Probe::CycleAccurate => {
            let ms = engine.query(&points(&[*cfg], &benches, &LADDER))?;
            ms.chunks(LADDER.len()).map(|c| c.to_vec()).collect()
        }
        Probe::Functional | Probe::Compiled => {
            // 1. Accuracy of every rung on the architectural tier the probe
            // names (interpreter or compiled — bit-identical results, so
            // the rest of the search is probe-agnostic).
            let compiled = probe == Probe::Compiled;
            let probe_pts: Vec<QueryPoint> = points(&[*cfg], &benches, &LADDER)
                .into_iter()
                .map(|p| {
                    let p = p.with_fidelity(Fidelity::Functional);
                    if compiled {
                        p.with_compiled()
                    } else {
                        p
                    }
                })
                .collect();
            let probes = engine.query(&probe_pts)?;
            // 2. Cycle-accurate runs only for the baseline and the rungs
            // whose functional accuracy admits them.
            let mut ca_pts = Vec::new();
            for (bi, &bench) in benches.iter().enumerate() {
                let pb = &probes[bi * LADDER.len()..(bi + 1) * LADDER.len()];
                for (ri, &v) in LADDER.iter().enumerate() {
                    if ri == 0 || admissible(&pb[ri], budget) {
                        ca_pts.push(QueryPoint::new(cfg, bench, v));
                    }
                }
            }
            let mut ca = engine.query(&ca_pts)?.into_iter();
            // 3. Stitch full rung vectors: admissible rungs carry their
            // cycle-accurate measurement; rejected rungs keep the
            // functional probe as an inadmissibility witness (`select` can
            // never pick one — outputs are tier-identical, so a rung the
            // probe rejects is rejected, full stop).
            benches
                .iter()
                .enumerate()
                .map(|(bi, _)| {
                    let pb = &probes[bi * LADDER.len()..(bi + 1) * LADDER.len()];
                    pb.iter()
                        .enumerate()
                        .map(|(ri, pm)| {
                            if ri == 0 || admissible(pm, budget) {
                                ca.next().expect("planned cycle-accurate point")
                            } else {
                                pm.clone()
                            }
                        })
                        .collect()
                })
                .collect()
        }
    };
    let choices = benches
        .iter()
        .zip(&rung_sets)
        .map(|(&bench, rungs)| {
            let (rung, greedy_rung, admissible) = select(rungs, budget);
            TuneChoice {
                bench,
                baseline: rungs[0].clone(),
                chosen: rungs[rung].clone(),
                rung,
                greedy_rung,
                admissible,
            }
        })
        .collect();
    Ok(TuneReport { cfg: *cfg, budget, choices })
}

/// [`tune_with`] on the process-wide engine.
pub fn tune(cfg: &ClusterConfig, budget: f64) -> Result<TuneReport, QueryFailure> {
    tune_with(QueryEngine::global(), cfg, budget)
}

/// Render one or more tune reports as a single table (text or CSV). The
/// leading `config` column keeps multi-config output (`transpfp tune all
/// --csv`) one well-formed CSV stream: one header, one row per
/// (config, benchmark).
pub fn tune_table(reports: &[TuneReport]) -> Table {
    let mut t = Table::new(vec![
        "config",
        "bench",
        "chosen",
        "rel_err",
        "within_budget",
        "admissible_rungs",
        "perf_gflops",
        "speedup_vs_f32",
        "energy_eff",
        "eeff_vs_f32",
        "cycles",
    ]);
    for r in reports {
        for c in &r.choices {
            t.row(vec![
                r.cfg.mnemonic(),
                c.bench.name().to_string(),
                c.chosen.variant.label().to_string(),
                format!("{:.3e}", c.chosen.err.rel),
                c.within_budget(r.budget).to_string(),
                c.admissible.to_string(),
                format!("{:.3}", c.chosen.metrics.perf_gflops),
                format!("{:.2}", c.speedup()),
                format!("{:.1}", c.chosen.metrics.energy_eff),
                format!("{:.2}", c.eeff_gain()),
                c.chosen.cycles.to_string(),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::counters::CoreCounters;
    use crate::kernels::Variant;
    use crate::model::Metrics;
    use crate::tuner::accuracy::ErrorStats;

    /// Synthetic rung measurement with the given (rel error, eeff, perf).
    fn rung(variant: Variant, rel: f64, eeff: f64, perf: f64, verified: bool) -> Measurement {
        Measurement {
            cfg: ClusterConfig::new(8, 8, 1),
            bench: Benchmark::Fir,
            variant,
            workers: 8,
            metrics: Metrics {
                perf_gflops: perf,
                energy_eff: eeff,
                area_eff: 1.0,
                flops_per_cycle: 1.0,
            },
            cycles: 1000,
            core_cycles: 8000,
            agg: CoreCounters::default(),
            fp_intensity: 0.3,
            mem_intensity: 0.5,
            verified,
            err: ErrorStats { max_abs: rel, rms: rel, rel },
        }
    }

    fn synthetic_ladder(errs: [f64; 5]) -> Vec<Measurement> {
        // Monotone cost model: deeper rungs are more efficient and faster.
        LADDER
            .iter()
            .zip(errs)
            .enumerate()
            .map(|(i, (&v, e))| rung(v, e, 50.0 + 10.0 * i as f64, 1.0 + i as f64, true))
            .collect()
    }

    #[test]
    fn greedy_descends_contiguous_prefix() {
        // All rungs admissible → greedy reaches the bottom, fallback keeps it.
        let rungs = synthetic_ladder([1e-7, 1e-3, 2e-3, 5e-4, 3e-3]);
        let (chosen, greedy, count) = select(&rungs, 1e-2);
        assert_eq!((chosen, greedy, count), (4, 4, 5));
    }

    #[test]
    fn exhaustive_fallback_beats_early_greedy_stop() {
        // scalar-f16 blows the budget but vector-f16 meets it: greedy stops
        // at the baseline, the exhaustive scan still finds rung 3.
        let rungs = synthetic_ladder([1e-7, 5e-2, 6e-2, 1e-3, 4e-2]);
        let (chosen, greedy, count) = select(&rungs, 1e-2);
        assert_eq!(greedy, 0, "greedy must stop at the first inadmissible rung");
        assert_eq!(chosen, 3, "fallback must find the admissible deep rung");
        assert_eq!(count, 2);
    }

    #[test]
    fn unattainable_budget_stays_at_f32() {
        let rungs = synthetic_ladder([1e-7, 1e-2, 1e-2, 1e-2, 1e-2]);
        let (chosen, _, count) = select(&rungs, 1e-9);
        assert_eq!(chosen, 0);
        assert_eq!(count, 0);
    }

    #[test]
    fn unverified_rungs_are_never_selected() {
        let mut rungs = synthetic_ladder([1e-7, 1e-4, 1e-4, 1e-4, 1e-4]);
        for r in &mut rungs[1..] {
            r.verified = false;
        }
        let (chosen, greedy, count) = select(&rungs, 1e-2);
        assert_eq!((chosen, greedy, count), (0, 0, 1));
    }

    /// Acceptance gate: on the paper's 8-core full-sharing configuration a
    /// 1e-2 budget must push at least half of the 8 benchmarks below
    /// binary32, every selection's measured error must meet the budget, and
    /// a warm re-tune must issue zero simulator runs.
    #[test]
    fn tune_descends_and_is_warm_cacheable() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 8, 1);
        let r = tune_with(&engine, &cfg, DEFAULT_BUDGET).unwrap();
        assert_eq!(r.choices.len(), 8);
        assert!(
            r.sub_f32_count() >= 4,
            "budget 1e-2 must select a sub-F32 variant for at least half \
             of the benchmarks, got {}",
            r.sub_f32_count()
        );
        for c in &r.choices {
            assert!(c.within_budget(r.budget), "{}: over budget", c.bench.name());
            assert!(c.chosen.verified);
            assert!(c.speedup() > 0.0 && c.eeff_gain() > 0.0);
        }
        assert!(r.all_within_budget());

        let cold = engine.stats();
        let warm = tune_with(&engine, &cfg, DEFAULT_BUDGET).unwrap();
        let after = engine.stats();
        assert_eq!(after.misses, cold.misses, "warm tune must not simulate");
        assert_eq!(warm.sub_f32_count(), r.sub_f32_count());
        for (a, b) in r.choices.iter().zip(&warm.choices) {
            assert_eq!(a.rung, b.rung, "{}: warm selection drifted", a.bench.name());
            assert_eq!(a.chosen.err.rel.to_bits(), b.chosen.err.rel.to_bits());
        }
    }

    /// The functional probe resolves all 40 rungs architecturally and
    /// issues cycle-accurate runs **only** for the baseline and the
    /// admissible rungs — an accuracy-rejected rung never touches the
    /// event engine (checked point-by-point against the cache).
    #[test]
    fn functional_probe_skips_ca_runs_for_inadmissible_rungs() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 8, 1);
        // A tight budget guarantees some rungs are rejected.
        let budget = 1e-3;
        let r = tune_with_probe(&engine, &cfg, budget, Probe::Functional).unwrap();
        assert_eq!(engine.functional_runs(), 8 * LADDER.len() as u64);
        assert!(engine.sim_runs() >= 8, "the baseline is always cycle-accurate");
        let mut rejected = 0usize;
        for c in &r.choices {
            for (ri, &v) in LADDER.iter().enumerate() {
                // Ground truth straight from the cached functional probe.
                let fm = engine
                    .query(&[QueryPoint::functional(&cfg, c.bench, v)])
                    .unwrap()
                    .pop()
                    .unwrap();
                let adm = fm.verified && fm.err.within(budget);
                let plan = engine.plan(&[QueryPoint::new(&cfg, c.bench, v)]);
                let expect_ca = ri == 0 || adm;
                assert_eq!(
                    plan.hit_count() == 1,
                    expect_ca,
                    "{} rung {ri}: CA run iff baseline or admissible",
                    c.bench.name()
                );
                if !expect_ca {
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "budget 1e-3 must reject at least one rung");
        assert!(r.all_within_budget() || r.choices.iter().any(|c| c.rung == 0));
    }

    /// All three probe modes pick identical rungs with bit-equal errors —
    /// accuracy is tier-independent, so the cheap probes lose nothing.
    #[test]
    fn probe_modes_agree_on_selections() {
        let cfg = ClusterConfig::new(8, 4, 0);
        let fast =
            tune_with_probe(&QueryEngine::new(), &cfg, DEFAULT_BUDGET, Probe::Functional).unwrap();
        let comp =
            tune_with_probe(&QueryEngine::new(), &cfg, DEFAULT_BUDGET, Probe::Compiled).unwrap();
        let full = tune_with_probe(&QueryEngine::new(), &cfg, DEFAULT_BUDGET, Probe::CycleAccurate)
            .unwrap();
        for ((a, c), b) in fast.choices.iter().zip(&comp.choices).zip(&full.choices) {
            assert_eq!(a.rung, b.rung, "{}: probes disagree", a.bench.name());
            assert_eq!(c.rung, b.rung, "{}: compiled probe disagrees", c.bench.name());
            assert_eq!(a.greedy_rung, b.greedy_rung);
            assert_eq!(c.greedy_rung, b.greedy_rung);
            assert_eq!(a.admissible, b.admissible);
            assert_eq!(c.admissible, b.admissible);
            assert_eq!(a.chosen.err.rel.to_bits(), b.chosen.err.rel.to_bits());
            assert_eq!(c.chosen.err.rel.to_bits(), b.chosen.err.rel.to_bits());
            assert_eq!(a.chosen.cycles, b.chosen.cycles, "chosen rung must be cycle-accurate");
            assert_eq!(c.chosen.cycles, b.chosen.cycles, "chosen rung must be cycle-accurate");
        }
    }

    /// `tune --probe compiled` economics: a cold tune translates each of
    /// the 40 ladder programs exactly once; a warm re-tune over the full
    /// ladder performs **zero** re-translations — it never even consults
    /// the translator, because every rung is a measurement-cache hit.
    /// Audited point-by-point against the hit counters.
    #[test]
    fn compiled_probe_warm_tune_performs_zero_retranslations() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 8, 1);
        let ladder_points = (Benchmark::all().len() * LADDER.len()) as u64;
        let cold = tune_with_probe(&engine, &cfg, DEFAULT_BUDGET, Probe::Compiled).unwrap();
        assert_eq!(engine.compiled_runs(), ladder_points, "one compiled probe per rung");
        assert_eq!(engine.functional_runs(), 0, "compiled probe replaces the interpreter");
        let (hits_cold, misses_cold) = engine.code_cache().stats();
        assert_eq!(misses_cold, ladder_points, "one translation per distinct rung program");
        assert_eq!(hits_cold, 0, "a cold ladder has nothing to reuse");

        let warm = tune_with_probe(&engine, &cfg, DEFAULT_BUDGET, Probe::Compiled).unwrap();
        let (hits_warm, misses_warm) = engine.code_cache().stats();
        assert_eq!(misses_warm, misses_cold, "warm tune must not re-translate");
        assert_eq!(hits_warm, hits_cold, "warm tune must not consult the translator at all");
        assert_eq!(engine.compiled_runs(), ladder_points, "warm tune issues zero compiled runs");
        // Point-by-point audit: every rung of every benchmark is already
        // resolved at the shared accuracy address.
        for &bench in &Benchmark::all() {
            for &v in LADDER.iter() {
                let plan = engine.plan(&[QueryPoint::functional(&cfg, bench, v).with_compiled()]);
                assert_eq!(
                    (plan.hit_count(), plan.miss_count()),
                    (1, 0),
                    "{} {}: warm rung must be a cache hit",
                    bench.name(),
                    v.label()
                );
            }
        }
        // And the warm selections are bit-stable.
        for (a, b) in cold.choices.iter().zip(&warm.choices) {
            assert_eq!(a.rung, b.rung, "{}: warm selection drifted", a.bench.name());
            assert_eq!(a.chosen.err.rel.to_bits(), b.chosen.err.rel.to_bits());
        }
    }

    #[test]
    fn tune_table_has_one_row_per_config_and_benchmark() {
        let engine = QueryEngine::new();
        let cfg = ClusterConfig::new(8, 2, 0);
        let r = tune_with(&engine, &cfg, DEFAULT_BUDGET).unwrap();
        let csv = tune_table(std::slice::from_ref(&r)).to_csv();
        assert_eq!(csv.lines().count(), 1 + 8);
        assert!(csv.starts_with("config,bench,chosen,rel_err,"));
        // Two reports concatenate into one stream with a single header.
        let two = tune_table(&[r.clone(), r]).to_csv();
        assert_eq!(two.lines().count(), 1 + 16);
        assert_eq!(two.lines().filter(|l| l.starts_with("config,")).count(), 1);
    }
}
