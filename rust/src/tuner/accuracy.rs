//! Quantitative accuracy metrics.
//!
//! Every workload carries a binary64 ground-truth output
//! ([`crate::kernels::Workload::reference`], identical across variants of a
//! benchmark); this module reduces a run's outputs against it to three
//! scalar error figures. They replace the old boolean pass/fail tolerance
//! as the signal the autotuner descends the precision ladder on:
//!
//! * **max-abs** — worst-case `|out − ref|` (the near-sensor "is any sample
//!   broken" view);
//! * **RMS** — `sqrt(mean((out − ref)²))` (average noise floor added by the
//!   reduced precision);
//! * **rel** — relative L2 error `‖out − ref‖₂ / ‖ref‖₂`, the
//!   scale-free figure `transpfp tune --budget` compares against.

/// Error of one run's outputs against the f64 reference.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ErrorStats {
    /// Worst-case absolute error.
    pub max_abs: f64,
    /// Root-mean-square error.
    pub rms: f64,
    /// Relative L2 error `‖out − ref‖₂ / ‖ref‖₂`.
    pub rel: f64,
}

impl ErrorStats {
    /// Sentinel for "no usable comparison" (missing reference, length
    /// mismatch, or non-finite outputs): infinitely bad, so it can never be
    /// admitted under any finite budget and never poisons a comparison the
    /// way NaN would.
    pub const UNBOUNDED: ErrorStats =
        ErrorStats { max_abs: f64::INFINITY, rms: f64::INFINITY, rel: f64::INFINITY };

    /// True if the relative error meets `budget` (strictly finite check —
    /// UNBOUNDED never passes).
    pub fn within(&self, budget: f64) -> bool {
        self.rel <= budget
    }
}

/// Reduce `outputs` against `reference`. A missing reference, a length
/// mismatch, or any non-finite deviation yields [`ErrorStats::UNBOUNDED`]
/// rather than NaN-poisoned numbers.
pub fn error_stats(outputs: &[f64], reference: &[f64]) -> ErrorStats {
    if reference.is_empty() || outputs.len() != reference.len() {
        return ErrorStats::UNBOUNDED;
    }
    let mut max_abs = 0.0f64;
    let mut sq = 0.0f64;
    let mut ref_sq = 0.0f64;
    for (o, r) in outputs.iter().zip(reference) {
        let d = o - r;
        if !d.is_finite() {
            return ErrorStats::UNBOUNDED;
        }
        max_abs = max_abs.max(d.abs());
        sq += d * d;
        ref_sq += r * r;
    }
    let rms = (sq / outputs.len() as f64).sqrt();
    let rel = if ref_sq > 0.0 {
        (sq / ref_sq).sqrt()
    } else if sq == 0.0 {
        0.0
    } else {
        f64::INFINITY
    };
    ErrorStats { max_abs, rms, rel }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_outputs_have_zero_error() {
        let r = [1.0, -2.0, 3.0];
        let e = error_stats(&r, &r);
        assert_eq!(e, ErrorStats { max_abs: 0.0, rms: 0.0, rel: 0.0 });
        assert!(e.within(0.0));
    }

    #[test]
    fn known_deviation() {
        // out = ref + [0.3, -0.4, 0]: max 0.4, rms = 0.5/sqrt(3),
        // rel = 0.5 / ||(3,4,12)|| = 0.5/13.
        let reference = [3.0, 4.0, 12.0];
        let out = [3.3, 3.6, 12.0];
        let e = error_stats(&out, &reference);
        assert!((e.max_abs - 0.4).abs() < 1e-12);
        assert!((e.rms - 0.5 / 3.0f64.sqrt()).abs() < 1e-12);
        assert!((e.rel - 0.5 / 13.0).abs() < 1e-12);
        assert!(e.within(0.05));
        assert!(!e.within(0.01));
    }

    #[test]
    fn degenerate_inputs_are_unbounded() {
        assert_eq!(error_stats(&[1.0], &[]), ErrorStats::UNBOUNDED);
        assert_eq!(error_stats(&[1.0, 2.0], &[1.0]), ErrorStats::UNBOUNDED);
        assert_eq!(error_stats(&[f64::NAN], &[1.0]), ErrorStats::UNBOUNDED);
        assert_eq!(error_stats(&[f64::INFINITY], &[1.0]), ErrorStats::UNBOUNDED);
        assert!(!ErrorStats::UNBOUNDED.within(f64::MAX));
    }

    #[test]
    fn zero_reference_norm() {
        // All-zero reference: exact match → 0, any deviation → unbounded rel.
        assert_eq!(error_stats(&[0.0, 0.0], &[0.0, 0.0]).rel, 0.0);
        let e = error_stats(&[1e-3, 0.0], &[0.0, 0.0]);
        assert_eq!(e.rel, f64::INFINITY);
        assert!((e.max_abs - 1e-3).abs() < 1e-18);
    }
}
