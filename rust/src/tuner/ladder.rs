//! The per-kernel precision ladder.
//!
//! Every benchmark can run on five rungs, ordered from the most to the
//! least precise — which, on this architecture, is also the direction of
//! increasing performance and energy efficiency (§5.2: "the cheapest FP
//! format that still meets the accuracy requirement"):
//!
//! | rung | variant        | arithmetic            | memory traffic |
//! |------|----------------|-----------------------|----------------|
//! | 0    | `scalar`       | binary32 scalar       | words          |
//! | 1    | `scalar-f16`   | binary16 scalar       | halfwords      |
//! | 2    | `scalar-bf16`  | bfloat16 scalar       | halfwords      |
//! | 3    | `vector-f16`   | packed 2×binary16     | halfwords ×2   |
//! | 4    | `vector-bf16`  | packed 2×bfloat16     | halfwords ×2   |
//!
//! Error is *not* monotone along the ladder: the vector rungs accumulate
//! dot products in binary32 (`vfdotpex`), so `vector-f16` is often more
//! accurate than `scalar-bf16` while also being faster. That is why the
//! search pairs a greedy descent with an exhaustive fallback
//! ([`super::search`]).

use crate::kernels::Variant;
use crate::transfp::FpMode;

/// The ladder, most precise first.
pub const LADDER: [Variant; 5] = [
    Variant::Scalar,
    Variant::Scalar16(FpMode::F16),
    Variant::Scalar16(FpMode::Bf16),
    Variant::Vector(FpMode::VecF16),
    Variant::Vector(FpMode::VecBf16),
];

/// The ladder as a slice (convenience for `points()` callers).
pub fn ladder() -> &'static [Variant] {
    &LADDER
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_shape() {
        assert_eq!(LADDER.len(), 5);
        assert_eq!(LADDER[0], Variant::Scalar);
        assert!(!LADDER[0].is_sub_f32());
        for v in &LADDER[1..] {
            assert!(v.is_sub_f32(), "{v:?} must count as a descent target");
        }
        // The ladder is exactly the buildable variant set, in order.
        assert_eq!(LADDER, Variant::all());
    }

    #[test]
    fn ladder_labels_are_unique() {
        let mut labels: Vec<&str> = ladder().iter().map(|v| v.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), LADDER.len());
    }
}
