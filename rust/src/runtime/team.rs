//! Fork-join team abstraction over the simulated cluster.
//!
//! A [`Team`] is the host-side handle of one fork-join region: a cluster
//! configuration plus the number of workers forked into the parallel
//! section. `Team::run*` spawns the team (activating exactly `workers`
//! cores — the rest terminate immediately, and the event unit's barrier
//! width shrinks to the team), executes the SPMD program, and joins at the
//! program's final barrier. The figure emitters sweep occupancy by running
//! the same workload under teams of 1..=N workers.
//!
//! The module also carries the program-side emission helpers the
//! DMA-double-buffered kernels use: master/worker event handshakes over the
//! event unit's software lines ([`EV_TILE_READY`]) and the memory-mapped
//! DMA programming sequence ([`dma_copy`], [`dma_wait`]).

use crate::cluster::counters::RunStats;
use crate::cluster::mem::{dma_reg, DMA_BASE};
use crate::cluster::{Cluster, Engine, RunError};
use crate::config::ClusterConfig;
use crate::isa::builder::regs;
use crate::isa::{ProgramBuilder, Reg};
use crate::kernels::Workload;

/// Event line the tile pipeline's master raises when a tile's data is
/// resident (workers sleep on it between tiles).
pub const EV_TILE_READY: u8 = 1;

/// One fork-join team: `workers` cores of a cluster configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Team {
    /// Cluster the team forks on.
    pub cfg: ClusterConfig,
    /// Active workers (1..=cfg.cores).
    pub workers: usize,
}

impl Team {
    /// Team of `workers` cores on `cfg`.
    pub fn new(cfg: &ClusterConfig, workers: usize) -> Team {
        assert!(
            workers >= 1 && workers <= cfg.cores,
            "team of {workers} on a {}-core cluster",
            cfg.cores
        );
        Team { cfg: *cfg, workers }
    }

    /// Full-occupancy team.
    pub fn full(cfg: &ClusterConfig) -> Team {
        Team::new(cfg, cfg.cores)
    }

    /// True if the team occupies every core.
    pub fn is_full(&self) -> bool {
        self.workers == self.cfg.cores
    }

    /// Spawn the team in `cl` (reset + occupancy limit): after this the
    /// HAL's `NCORES` register reads the team size and barriers span
    /// exactly the team.
    pub fn spawn_in(&self, cl: &mut Cluster) {
        cl.reset();
        cl.limit_active_cores(self.workers);
    }

    /// Fork-join execution of a workload on this team: spawn, run to the
    /// joining barrier, collect stats + outputs. A hung or deadlocked team
    /// comes back as a structured [`RunError`], never a panic.
    pub fn run(&self, w: &Workload) -> Result<(RunStats, Vec<f64>), RunError> {
        w.run_with(&self.cfg, self.workers, Engine::Event)
    }

    /// [`Team::run`] on a selectable issue engine (differential harness).
    pub fn run_with(
        &self,
        w: &Workload,
        engine: Engine,
    ) -> Result<(RunStats, Vec<f64>), RunError> {
        w.run_with(&self.cfg, self.workers, engine)
    }
}

// ------------------------------------------------- program-side emission

/// Emit a master-only block: cores other than core 0 branch over `emit`'s
/// instructions to the `tag` label (which must be unique per call site).
/// The tile pipelines use this for DMA programming and tile-ready signals.
pub fn master_only(
    p: &mut ProgramBuilder,
    tag: &str,
    emit: &mut dyn FnMut(&mut ProgramBuilder),
) {
    p.bne(regs::CORE_ID, regs::ZERO, tag);
    emit(p);
    p.label(tag);
}

/// Emit the DMA programming sequence for one transfer: latch `src`, `dst`
/// and `words`, then trigger. `t0`/`t1` are caller-provided scratch
/// registers. The transfer runs in the background; overlap compute with it
/// and [`dma_wait`] before touching the destination.
pub fn dma_copy(p: &mut ProgramBuilder, t0: Reg, t1: Reg, src: u32, dst: u32, words: u32) {
    p.li(t0, DMA_BASE);
    p.li(t1, src);
    p.sw(t1, t0, dma_reg::SRC as i32);
    p.li(t1, dst);
    p.sw(t1, t0, dma_reg::DST as i32);
    p.li(t1, words);
    p.sw(t1, t0, dma_reg::LEN as i32);
    p.sw(t1, t0, dma_reg::CMD as i32);
}

/// Emit a spin-wait until every outstanding DMA transfer has completed
/// (`STATUS == 0`). The spin occupies the polling core only — sleeping
/// workers wait on [`EV_TILE_READY`] instead.
pub fn dma_wait(p: &mut ProgramBuilder, t0: Reg, t1: Reg) {
    let tag = format!("dw{}", p.here());
    // All spins share one "dma-wait" trace region, so the attribution
    // report's DMA-overlap efficiency can sum every wait in one row. The
    // exit lands on the caller's next instruction; cores that branched over
    // the spin ignore it (exits only pop a matching region).
    p.region_enter("dma-wait");
    p.li(t0, DMA_BASE);
    p.label(&tag);
    p.lw(t1, t0, dma_reg::STATUS as i32);
    p.bne(t1, regs::ZERO, &tag);
    p.region_exit();
}

/// Emit the master-side "tile ready" signal: raise [`EV_TILE_READY`] for
/// the whole team (sleeping workers wake; everyone else buffers it).
pub fn signal_tile_ready(p: &mut ProgramBuilder) {
    p.set_event(EV_TILE_READY);
}

/// Emit the team-side "wait for tile" sleep. Every core (master included —
/// it buffered its own signal) consumes one ready event per tile.
pub fn wait_tile_ready(p: &mut ProgramBuilder) {
    p.wait_event(EV_TILE_READY);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::mem::{L2_BASE, TCDM_BASE};
    use crate::kernels::{Benchmark, Variant};

    #[test]
    fn team_bounds_are_enforced() {
        let cfg = ClusterConfig::new(8, 4, 1);
        assert!(Team::new(&cfg, 1).workers == 1);
        assert!(Team::full(&cfg).is_full());
        assert!(std::panic::catch_unwind(|| Team::new(&cfg, 0)).is_err());
        assert!(std::panic::catch_unwind(|| Team::new(&cfg, 9)).is_err());
    }

    /// A team run equals the raw partial-occupancy run (the team is the
    /// occupancy mechanism, not a new semantics).
    #[test]
    fn team_run_matches_limit_active_cores() {
        let cfg = ClusterConfig::new(8, 8, 1);
        let w = Benchmark::Fir.build(Variant::Scalar, &cfg);
        for workers in [1usize, 3, 8] {
            let team = Team::new(&cfg, workers);
            let (ts, to) = team.run(&w).unwrap();
            let (rs, ro) = w.run_on(&cfg, workers).unwrap();
            assert_eq!(ts.total_cycles, rs.total_cycles, "{workers} workers");
            assert_eq!(to, ro);
        }
    }

    /// The emission helpers produce a working double-buffer skeleton: the
    /// master stages two blocks back-to-back, overlapping the second DMA
    /// with "compute" on the first.
    #[test]
    fn dma_handshake_skeleton_runs() {
        let mut p = ProgramBuilder::new("skeleton");
        p.bne(regs::CORE_ID, regs::ZERO, "worker");
        dma_copy(&mut p, 1, 2, L2_BASE, TCDM_BASE, 4);
        dma_wait(&mut p, 1, 2);
        signal_tile_ready(&mut p);
        // Prefetch the next block while "computing".
        dma_copy(&mut p, 1, 2, L2_BASE + 16, TCDM_BASE + 16, 4);
        p.label("worker");
        wait_tile_ready(&mut p);
        p.li(3, TCDM_BASE);
        p.lw(4, 3, 0);
        p.barrier();
        // Master drains the prefetch before the join.
        p.bne(regs::CORE_ID, regs::ZERO, "join");
        dma_wait(&mut p, 1, 2);
        p.label("join");
        p.barrier();
        p.end();
        let cfg = ClusterConfig::new(8, 8, 0);
        let mut cl = Cluster::new(cfg, p.build());
        cl.mem.write_u32_slice(L2_BASE, &[11, 12, 13, 14, 21, 22, 23, 24]);
        let stats = cl.run().unwrap();
        assert!(stats.total_cycles > 0);
        assert_eq!(cl.mem.load(TCDM_BASE, crate::isa::MemSize::Word), 11);
        assert_eq!(cl.mem.load(TCDM_BASE + 16, crate::isa::MemSize::Word), 21);
        assert_eq!(cl.cores[5].reg(4), 11, "workers read the staged tile");
        assert_eq!(cl.dmac.words_moved(), 8);
    }
}
