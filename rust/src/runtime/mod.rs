//! The software runtime of §4: the fork-join parallel runtime the kernels
//! emit their parallel sections through, plus the golden-validation matrix.
//!
//! * [`team`] — the fork-join [`team::Team`] abstraction (spawn at any
//!   occupancy over the event unit, join at the final barrier) and the
//!   DMA double-buffer emission helpers;
//! * [`sched`] — the work-sharing loop scheduler
//!   ([`sched::parallel_for`]): static / dynamic / guided policies over
//!   TCDM work queues, with every-index-exactly-once invariants locked by
//!   tests.
//!
//! The remainder of this file is the **golden-validation** runtime: the
//! case matrix and parameter plumbing for checking the simulator's
//! numerics against the AOT-compiled JAX/Pallas goldens
//! (`artifacts/*.hlo.txt`, see `python/compile/aot.py`). The build
//! environment is fully offline, so the PJRT/XLA execution backend is
//! **stubbed**: [`Golden::load`] and [`Golden::run_f32`] return an error
//! explaining that no backend is vendored (gate: the `xla` cargo feature,
//! declared but intentionally unbacked). Everything that does not need XLA
//! — the validation case matrix, tolerance bookkeeping, and the
//! reconstruction of golden input parameters from a workload's staged
//! buffers — is real code with tests, so a future vendored backend only
//! has to supply the two `Golden` methods.

pub mod sched;
pub mod team;

pub use sched::{parallel_for, LoopRegs, Schedule, WorkQueue};
pub use team::Team;

use std::fmt;
use std::path::Path;

use crate::config::ClusterConfig;
use crate::kernels::{Benchmark, Staged, Variant, Workload};
use crate::transfp::{FpMode, FpSpec};

/// Runtime error: a plain message (the offline build carries no error-
/// handling dependencies).
#[derive(Debug)]
pub struct RtError(String);

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

/// Local result alias.
pub type Result<T> = std::result::Result<T, RtError>;

fn err(msg: impl Into<String>) -> RtError {
    RtError(msg.into())
}

/// A golden executable handle. In the offline build this is a name/path
/// record: loading checks the artifact exists, and execution reports the
/// missing backend; with a vendored XLA it would own the PJRT client +
/// executable.
pub struct Golden {
    /// Artifact name (diagnostics).
    pub name: String,
    /// Artifact path on disk.
    pub path: std::path::PathBuf,
}

impl Golden {
    /// Load `<dir>/<name>.hlo.txt`. Fails if the artifact is missing.
    pub fn load(dir: &str, name: &str) -> Result<Golden> {
        let path = Path::new(dir).join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(err(format!(
                "artifact {} missing — run `make artifacts`",
                path.display()
            )));
        }
        Ok(Golden { name: name.to_string(), path })
    }

    /// Execute with f32 inputs (`(data, dims)` pairs); returns the flattened
    /// f32 outputs of the 1-tuple result. Offline stub: always errors — the
    /// `xla` cargo feature is declared but unbacked, so numeric verification
    /// uses the host-mirror goldens in kernels/ instead.
    pub fn run_f32(&self, _inputs: &[(Vec<f32>, Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
        Err(err(format!(
            "{} ({}): no PJRT/XLA backend in the offline build",
            self.name,
            self.path.display()
        )))
    }
}

/// One validation case: artifact name ↔ (benchmark, variant) + tolerances.
pub struct Case {
    pub artifact: &'static str,
    pub bench: Benchmark,
    pub variant: Variant,
    pub rtol: f64,
    pub atol: f64,
}

/// The validation matrix: every benchmark in binary32, MATMUL and FIR
/// additionally in both 16-bit formats.
pub fn cases() -> Vec<Case> {
    use Benchmark::*;
    let f32c = |artifact, bench| Case {
        artifact,
        bench,
        variant: Variant::Scalar,
        rtol: 2e-4,
        atol: 1e-5,
    };
    vec![
        f32c("matmul_f32", Matmul),
        f32c("fir_f32", Fir),
        f32c("conv_f32", Conv),
        f32c("dwt_f32", Dwt),
        Case { artifact: "fft_f32", bench: Fft, variant: Variant::Scalar, rtol: 2e-3, atol: 2e-4 },
        f32c("iir_f32", Iir),
        f32c("kmeans_f32", Kmeans),
        f32c("svm_f32", Svm),
        Case { artifact: "matmul_f16", bench: Matmul, variant: Variant::VEC, rtol: 6e-3, atol: 2e-2 },
        Case {
            artifact: "matmul_bf16",
            bench: Matmul,
            variant: Variant::Vector(FpMode::VecBf16),
            rtol: 4e-2,
            atol: 8e-2,
        },
        Case { artifact: "fir_f16", bench: Fir, variant: Variant::VEC, rtol: 6e-3, atol: 6e-3 },
    ]
}

/// Reconstruct the golden's f32 parameters from a workload's staged buffers
/// (dequantizing 16-bit lanes — the graph re-quantizes on the same RNE
/// lattice, so values round-trip exactly).
pub fn params_from_stage(
    w: &Workload,
    bench: Benchmark,
    variant: Variant,
) -> Vec<(Vec<f32>, Vec<i64>)> {
    let spec: &FpSpec = crate::kernels::spec_of(variant);
    let as_f32 = |s: &Staged| -> Vec<f32> {
        match s {
            Staged::F32(v) => v.clone(),
            Staged::U16(q) => q.iter().map(|&b| spec.to_f64(b) as f32).collect(),
            Staged::U32(_) => panic!("raw u32 staging has no golden parameter"),
        }
    };
    let st = &w.stage;
    match bench {
        Benchmark::Matmul => {
            let n = (as_f32(&st[0].1).len() as f64).sqrt() as i64;
            vec![(as_f32(&st[0].1), vec![n, n]), (as_f32(&st[1].1), vec![n, n])]
        }
        Benchmark::Fir => {
            let h = as_f32(&st[1].1);
            let mut x = as_f32(&st[0].1);
            // The vector staging appends a guard pair — the golden's x has
            // exactly n + taps samples.
            x.truncate(w.out_len + h.len());
            let (xl, hl) = (x.len() as i64, h.len() as i64);
            vec![(x, vec![xl]), (h, vec![hl])]
        }
        Benchmark::Conv => {
            let img = as_f32(&st[0].1);
            let k = as_f32(&st[1].1);
            let w_img = 32i64; // default workload size
            let h_img = img.len() as i64 / w_img;
            vec![(img, vec![h_img, w_img]), (k[..9].to_vec(), vec![3, 3])]
        }
        Benchmark::Dwt => {
            let mut x = as_f32(&st[0].1);
            x.truncate(w.out_len); // drop the zero pad
            let n = x.len() as i64;
            vec![(x, vec![n])]
        }
        Benchmark::Fft => {
            let x = as_f32(&st[0].1);
            let n = x.len() as i64;
            vec![(x, vec![n])]
        }
        Benchmark::Iir => {
            let x = as_f32(&st[0].1);
            let x = x[2..].to_vec(); // drop the two leading zeros
            let n = x.len() as i64;
            vec![(x, vec![n])]
        }
        Benchmark::Kmeans => {
            let pts = as_f32(&st[0].1);
            let cent = as_f32(&st[1].1);
            let k = 4i64;
            let d = cent.len() as i64 / k;
            let n = pts.len() as i64 / d;
            vec![(pts, vec![n, d]), (cent, vec![k, d])]
        }
        Benchmark::Svm => {
            let sv = as_f32(&st[0].1);
            let alpha = as_f32(&st[1].1);
            let x = as_f32(&st[2].1);
            let bias = as_f32(&st[4].1);
            let nsv = alpha.len() as i64;
            let d = x.len() as i64;
            vec![(sv, vec![nsv, d]), (alpha, vec![nsv]), (x, vec![d]), (bias, vec![1])]
        }
    }
}

/// Validate one case: run the simulator workload and the XLA golden on the
/// same inputs and compare outputs. Returns (max abs diff, elements).
pub fn validate_case(dir: &str, case: &Case) -> Result<(f64, usize)> {
    let cfg = ClusterConfig::new(8, 8, 0);
    let w = case.bench.build(case.variant, &cfg);
    let (_, sim_out) = w.run(&cfg).map_err(|e| err(format!("simulation failed: {e}")))?;
    w.verify(&sim_out)
        .map_err(|e| err(format!("simulator self-check: {e}")))?;

    let golden = Golden::load(dir, case.artifact)?;
    let params = params_from_stage(&w, case.bench, case.variant);
    let out = golden.run_f32(&params)?;
    let xla_out = &out[0];

    if xla_out.len() != sim_out.len() {
        return Err(err(format!(
            "{}: XLA output length {} != simulator {}",
            case.artifact,
            xla_out.len(),
            sim_out.len()
        )));
    }
    let mut max_diff = 0.0f64;
    for (i, (x, s)) in xla_out.iter().zip(&sim_out).enumerate() {
        let diff = (*x as f64 - s).abs();
        let tol = case.atol + case.rtol * s.abs();
        if diff > tol {
            return Err(err(format!(
                "{}: mismatch at {i}: xla={x} sim={s} (|diff|={diff:.3e} > tol={tol:.3e})",
                case.artifact
            )));
        }
        max_diff = max_diff.max(diff);
    }
    Ok((max_diff, sim_out.len()))
}

/// Run the full validation matrix; returns a human-readable report.
pub fn validate_all(dir: &str) -> Result<String> {
    if !Path::new(dir).join("MANIFEST").exists() {
        return Err(err(format!("no artifacts in `{dir}` — run `make artifacts` first")));
    }
    let mut report = String::new();
    report.push_str("simulator vs XLA golden validation\n");
    let mut failures = 0;
    for case in cases() {
        match validate_case(dir, &case) {
            Ok((max_diff, n)) => {
                report.push_str(&format!(
                    "  {:12} {:7} {:6} elems  max|diff| {:.3e}  OK\n",
                    case.artifact,
                    case.variant.label(),
                    n,
                    max_diff
                ));
            }
            Err(e) => {
                failures += 1;
                report.push_str(&format!("  {:12} FAILED: {e}\n", case.artifact));
            }
        }
    }
    if failures > 0 {
        return Err(err(format!("{failures} validation case(s) failed:\n{report}")));
    }
    report.push_str("all cases passed\n");
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The case matrix covers all eight benchmarks in f32, plus the 16-bit
    /// extras, with vector tolerances looser than scalar ones.
    #[test]
    fn case_matrix_covers_suite() {
        let cs = cases();
        for b in Benchmark::all() {
            assert!(
                cs.iter().any(|c| c.bench == b && c.variant == Variant::Scalar),
                "{b:?} missing a scalar case"
            );
        }
        assert!(cs.iter().any(|c| c.artifact == "matmul_bf16"));
        for c in &cs {
            if matches!(c.variant, Variant::Vector(_)) {
                assert!(c.rtol >= 2e-4, "{}: vector rtol too tight", c.artifact);
            }
        }
    }

    /// Parameter reconstruction produces shape-consistent inputs for every
    /// case (element counts match the declared dims).
    #[test]
    fn params_match_declared_dims() {
        let cfg = ClusterConfig::new(8, 8, 0);
        for case in cases() {
            let w = case.bench.build(case.variant, &cfg);
            let params = params_from_stage(&w, case.bench, case.variant);
            assert!(!params.is_empty(), "{}", case.artifact);
            for (data, dims) in &params {
                let n: i64 = dims.iter().product();
                assert_eq!(data.len() as i64, n, "{}: shape mismatch", case.artifact);
            }
        }
    }

    /// The offline stub reports missing artifacts before reporting the
    /// missing backend.
    #[test]
    fn golden_load_reports_missing_artifact() {
        let e = Golden::load("definitely-missing-dir", "matmul_f32").unwrap_err();
        assert!(e.to_string().contains("missing"), "{e}");
    }

    /// validate_all without an artifact directory errors out cleanly.
    #[test]
    fn validate_all_requires_manifest() {
        let e = validate_all("definitely-missing-dir").unwrap_err();
        assert!(e.to_string().contains("no artifacts"), "{e}");
    }
}
