//! PJRT runtime: load the AOT-compiled JAX/Pallas goldens
//! (`artifacts/*.hlo.txt`) and execute them on the XLA CPU client from the
//! Rust hot path — Python is never involved at run time.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md). Every golden takes binary32 inputs in the
//! order of the benchmark's staged non-scratch buffers and returns a
//! 1-tuple of binary32 arrays.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ClusterConfig;
use crate::kernels::{Benchmark, Staged, Variant, Workload};
use crate::transfp::{FpMode, FpSpec};

/// A compiled golden executable on the PJRT CPU client.
pub struct Golden {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (diagnostics).
    pub name: String,
}

impl Golden {
    /// Load and compile `<dir>/<name>.hlo.txt`.
    pub fn load(dir: &str, name: &str) -> Result<Golden> {
        let path = Path::new(dir).join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {} missing — run `make artifacts`", path.display());
        }
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(wrap)
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(wrap)?;
        Ok(Golden { client, exe, name: name.to_string() })
    }

    /// Execute with f32 inputs (`(data, dims)` pairs); returns the flattened
    /// f32 outputs of the 1-tuple result.
    pub fn run_f32(&self, inputs: &[(Vec<f32>, Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
        let _ = &self.client;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data).reshape(dims).map_err(wrap)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let tuple = result.to_tuple().map_err(wrap)?;
        tuple.into_iter().map(|l| l.to_vec::<f32>().map_err(wrap)).collect()
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// One validation case: artifact name ↔ (benchmark, variant) + tolerances.
pub struct Case {
    artifact: &'static str,
    bench: Benchmark,
    variant: Variant,
    rtol: f64,
    atol: f64,
}

/// The validation matrix: every benchmark in binary32, MATMUL and FIR
/// additionally in both 16-bit formats.
fn cases() -> Vec<Case> {
    use Benchmark::*;
    let f32c = |artifact, bench| Case { artifact, bench, variant: Variant::Scalar, rtol: 2e-4, atol: 1e-5 };
    vec![
        f32c("matmul_f32", Matmul),
        f32c("fir_f32", Fir),
        f32c("conv_f32", Conv),
        f32c("dwt_f32", Dwt),
        Case { artifact: "fft_f32", bench: Fft, variant: Variant::Scalar, rtol: 2e-3, atol: 2e-4 },
        f32c("iir_f32", Iir),
        f32c("kmeans_f32", Kmeans),
        f32c("svm_f32", Svm),
        Case { artifact: "matmul_f16", bench: Matmul, variant: Variant::VEC, rtol: 6e-3, atol: 2e-2 },
        Case {
            artifact: "matmul_bf16",
            bench: Matmul,
            variant: Variant::Vector(FpMode::VecBf16),
            rtol: 4e-2,
            atol: 8e-2,
        },
        Case { artifact: "fir_f16", bench: Fir, variant: Variant::VEC, rtol: 6e-3, atol: 6e-3 },
    ]
}

/// Reconstruct the golden's f32 parameters from a workload's staged buffers
/// (dequantizing 16-bit lanes — the graph re-quantizes on the same RNE
/// lattice, so values round-trip exactly).
fn params_from_stage(w: &Workload, bench: Benchmark, variant: Variant) -> Vec<(Vec<f32>, Vec<i64>)> {
    let spec: &FpSpec = crate::kernels::spec_of(variant);
    let as_f32 = |s: &Staged| -> Vec<f32> {
        match s {
            Staged::F32(v) => v.clone(),
            Staged::U16(q) => q.iter().map(|&b| spec.to_f64(b) as f32).collect(),
            Staged::U32(_) => panic!("raw u32 staging has no golden parameter"),
        }
    };
    let st = &w.stage;
    match bench {
        Benchmark::Matmul => {
            let n = (as_f32(&st[0].1).len() as f64).sqrt() as i64;
            vec![
                (as_f32(&st[0].1), vec![n, n]),
                (as_f32(&st[1].1), vec![n, n]),
            ]
        }
        Benchmark::Fir => {
            let h = as_f32(&st[1].1);
            let mut x = as_f32(&st[0].1);
            // The vector staging appends a guard pair — the golden's x has
            // exactly n + taps samples.
            x.truncate(w.out_len + h.len());
            let (xl, hl) = (x.len() as i64, h.len() as i64);
            vec![(x, vec![xl]), (h, vec![hl])]
        }
        Benchmark::Conv => {
            let img = as_f32(&st[0].1);
            let k = as_f32(&st[1].1);
            let w_img = 32i64; // default workload size
            let h_img = img.len() as i64 / w_img;
            vec![(img, vec![h_img, w_img]), (k[..9].to_vec(), vec![3, 3])]
        }
        Benchmark::Dwt => {
            let mut x = as_f32(&st[0].1);
            x.truncate(w.out_len); // drop the zero pad
            let n = x.len() as i64;
            vec![(x, vec![n])]
        }
        Benchmark::Fft => {
            let x = as_f32(&st[0].1);
            let n = x.len() as i64;
            vec![(x, vec![n])]
        }
        Benchmark::Iir => {
            let x = as_f32(&st[0].1);
            let x = x[2..].to_vec(); // drop the two leading zeros
            let n = x.len() as i64;
            vec![(x, vec![n])]
        }
        Benchmark::Kmeans => {
            let pts = as_f32(&st[0].1);
            let cent = as_f32(&st[1].1);
            let k = 4i64;
            let d = cent.len() as i64 / k;
            let n = pts.len() as i64 / d;
            vec![(pts, vec![n, d]), (cent, vec![k, d])]
        }
        Benchmark::Svm => {
            let sv = as_f32(&st[0].1);
            let alpha = as_f32(&st[1].1);
            let x = as_f32(&st[2].1);
            let bias = as_f32(&st[4].1);
            let nsv = alpha.len() as i64;
            let d = x.len() as i64;
            vec![(sv, vec![nsv, d]), (alpha, vec![nsv]), (x, vec![d]), (bias, vec![1])]
        }
    }
}

/// Validate one case: run the simulator workload and the XLA golden on the
/// same inputs and compare outputs. Returns (max abs diff, elements).
pub fn validate_case(dir: &str, case: &Case) -> Result<(f64, usize)> {
    let cfg = ClusterConfig::new(8, 8, 0);
    let w = case.bench.build(case.variant, &cfg);
    let (_, sim_out) = w.run(&cfg);
    w.verify(&sim_out).map_err(|e| anyhow!("simulator self-check: {e}"))?;

    let golden = Golden::load(dir, case.artifact)?;
    let params = params_from_stage(&w, case.bench, case.variant);
    let out = golden.run_f32(&params)?;
    let xla_out = &out[0];

    if xla_out.len() != sim_out.len() {
        bail!(
            "{}: XLA output length {} != simulator {}",
            case.artifact,
            xla_out.len(),
            sim_out.len()
        );
    }
    let mut max_diff = 0.0f64;
    for (i, (x, s)) in xla_out.iter().zip(&sim_out).enumerate() {
        let diff = (*x as f64 - s).abs();
        let tol = case.atol + case.rtol * s.abs();
        if diff > tol {
            bail!(
                "{}: mismatch at {i}: xla={x} sim={s} (|diff|={diff:.3e} > tol={tol:.3e})",
                case.artifact
            );
        }
        max_diff = max_diff.max(diff);
    }
    Ok((max_diff, sim_out.len()))
}

/// Run the full validation matrix; returns a human-readable report.
pub fn validate_all(dir: &str) -> Result<String> {
    if !Path::new(dir).join("MANIFEST").exists() {
        bail!("no artifacts in `{dir}` — run `make artifacts` first");
    }
    let mut report = String::new();
    report.push_str("simulator vs XLA golden validation\n");
    let mut failures = 0;
    for case in cases() {
        match validate_case(dir, &case) {
            Ok((max_diff, n)) => {
                report.push_str(&format!(
                    "  {:12} {:7} {:6} elems  max|diff| {:.3e}  OK\n",
                    case.artifact,
                    case.variant.label(),
                    n,
                    max_diff
                ));
            }
            Err(e) => {
                failures += 1;
                report.push_str(&format!("  {:12} FAILED: {e}\n", case.artifact));
            }
        }
    }
    if failures > 0 {
        bail!("{failures} validation case(s) failed:\n{report}");
    }
    report.push_str("all cases passed\n");
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Path::new("artifacts/MANIFEST").exists()
    }

    /// Full matrix — requires `make artifacts` to have run (skips otherwise,
    /// like the FPGA bitstream prerequisite in the paper's flow).
    #[test]
    fn validate_against_xla_goldens() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts/ (run `make artifacts`)");
            return;
        }
        let report = validate_all("artifacts").expect("validation");
        assert!(report.contains("all cases passed"), "{report}");
    }

    /// The exg_mlp e2e artifact loads and produces finite logits.
    #[test]
    fn exg_mlp_runs() {
        if !have_artifacts() {
            return;
        }
        let g = Golden::load("artifacts", "exg_mlp").unwrap();
        let windows = vec![0.1f32; 16 * 64];
        let w1: Vec<f32> = (0..64 * 64).map(|i| ((i % 13) as f32 - 6.0) / 40.0).collect();
        let w2: Vec<f32> = (0..64 * 16).map(|i| ((i % 7) as f32 - 3.0) / 40.0).collect();
        let out = g
            .run_f32(&[
                (windows, vec![16, 64]),
                (w1, vec![64, 64]),
                (w2, vec![64, 16]),
            ])
            .unwrap();
        assert_eq!(out[0].len(), 16 * 16);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }
}
