//! Work-sharing loop scheduler — the `parallel_for` of the fork-join
//! runtime (§4: "a full-fledged software stack support, including a
//! parallel runtime").
//!
//! [`parallel_for`] emits the parallel-loop skeleton into a kernel's
//! [`ProgramBuilder`] stream: the per-core chunk computation, the chunk
//! grab loop, and the per-index loop control. The kernel supplies two
//! closures — `chunk_setup`, emitted once per *claimed chunk* (pointer
//! materialization from the chunk's start index), and `body`, emitted once
//! per *index*. Three OpenMP-style policies are supported:
//!
//! * [`Schedule::Static`] — `ceil(n/W)` contiguous indices per core,
//!   computed from the HAL's `CORE_ID`/`NCORES` registers. Exactly the
//!   chunking every kernel hand-rolled before the runtime existed, so
//!   outputs are bit-identical to the pre-runtime programs.
//! * [`Schedule::Dynamic`] — cores self-schedule fixed-size chunks from a
//!   TCDM-resident grab counter via the `amoadd.w` atomic. Load balance
//!   for irregular bodies; deterministic under the simulator's rotating
//!   bank arbitration.
//! * [`Schedule::Guided`] — chunk sizes decay with the remaining work
//!   (`remaining / 2W`, floored at `min_chunk`); the read-size-update
//!   sequence is serialized by an `amoswap.w` test-and-set lock next to
//!   the counter.
//!
//! Register contract ([`LoopRegs`]): `idx`, `limit` and `n` are live across
//! `body` and must be preserved by it; `chunk` and `scratch` are dead
//! outside the scheduler's own grab sequence and may be clobbered freely.
//! Every index in `[0, n)` is claimed exactly once under every policy ×
//! occupancy × trip count (locked by the invariant tests below), so any
//! body whose iterations are independent computes identical results under
//! all three policies.

use crate::isa::builder::regs;
use crate::isa::{Operand, ProgramBuilder, Reg};
use crate::kernels::Alloc;

/// Loop-scheduling policy of a [`parallel_for`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous `ceil(n/W)` chunk per core (the paper's kernels).
    Static,
    /// Self-scheduled fixed-size chunks from the TCDM grab counter.
    Dynamic {
        /// Indices claimed per grab (≥ 1).
        chunk: u32,
        /// TCDM work queue backing the grab counter.
        queue: WorkQueue,
    },
    /// Decaying chunks (`remaining / 2W`, floored at `min_chunk`).
    Guided {
        /// Smallest chunk a grab may claim (≥ 1).
        min_chunk: u32,
        /// TCDM work queue backing the counter + lock.
        queue: WorkQueue,
    },
}

/// TCDM words backing one dynamic/guided loop instance: a grab counter and
/// (for guided) a test-and-set lock. Both words must be **zero on entry**;
/// the TCDM is zeroed at reset and the scheduler leaves the lock at zero,
/// so allocating one queue per `parallel_for` instance suffices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkQueue {
    /// Byte address of the counter word; the lock lives at `addr + 4`.
    pub addr: u32,
}

impl WorkQueue {
    /// Allocate the queue's two words in the TCDM.
    pub fn alloc(al: &mut Alloc) -> WorkQueue {
        WorkQueue { addr: al.words(2) }
    }
}

/// Registers the scheduler emits against. `idx`/`limit`/`n` are live across
/// the body; `chunk`/`scratch` are scheduler-internal scratch.
#[derive(Debug, Clone, Copy)]
pub struct LoopRegs {
    /// Trip count (read-only input; must be preserved by the body).
    pub n: Reg,
    /// Scratch: chunk size during the grab sequence.
    pub chunk: Reg,
    /// Current index — the body's induction variable.
    pub idx: Reg,
    /// First index past the current chunk.
    pub limit: Reg,
    /// Scratch for address/size arithmetic.
    pub scratch: Reg,
}

impl LoopRegs {
    /// The register convention all 8 benchmark kernels use (r24 = n,
    /// r12/r25 scratch, r13 = index, r14 = limit) — chosen so the
    /// runtime-scheduled programs reuse the registers the hand-chunked
    /// versions did.
    pub const KERNEL: LoopRegs = LoopRegs { n: 24, chunk: 12, idx: 13, limit: 14, scratch: 25 };
}

/// Emit a work-shared parallel loop over `[0, r.n)`.
///
/// `chunk_setup` is emitted after each chunk grab with `r.idx` holding the
/// chunk's first index and `r.limit` its end; `body` is emitted once and
/// executed per index with `r.idx` valid. The loop synchronizes nothing:
/// callers place their own barrier after the loop (fork-join sections end
/// with one, matching the paper's kernels).
pub fn parallel_for(
    p: &mut ProgramBuilder,
    sched: Schedule,
    r: LoopRegs,
    mut chunk_setup: impl FnMut(&mut ProgramBuilder),
    mut body: impl FnMut(&mut ProgramBuilder),
) {
    // Call-site-unique label prefix (the emission cursor is unique).
    let tag = format!("pf{}", p.here());
    let done = format!("{tag}_done");
    let head = format!("{tag}_head");
    // Every core executes the first scheduler instruction and the first
    // instruction past `done` under every policy, so the trace region
    // brackets the whole work-shared loop on every lane.
    p.region_enter(&tag);
    match sched {
        Schedule::Static => {
            // chunk = ceil(n / W); idx = id·chunk; limit = min(idx+chunk, n)
            // — exactly the pre-runtime hand-chunking sequence.
            p.add(r.scratch, r.n, regs::NCORES)
                .addi(r.scratch, r.scratch, -1)
                .divi(r.chunk, r.scratch, Operand::Reg(regs::NCORES));
            p.mul(r.idx, regs::CORE_ID, r.chunk);
            p.add(r.limit, r.idx, r.chunk).imin(r.limit, r.limit, r.n);
            p.bge(r.idx, r.limit, &done);
            chunk_setup(p);
            p.label(&head);
            body(p);
            p.addi(r.idx, r.idx, 1);
            p.blt(r.idx, r.limit, &head);
        }
        Schedule::Dynamic { chunk, queue } => {
            assert!(chunk >= 1, "dynamic chunk must be >= 1");
            let grab = format!("{tag}_grab");
            p.label(&grab);
            p.li(r.chunk, chunk);
            p.li(r.scratch, queue.addr);
            // idx = fetch-and-add(counter, chunk)
            p.amo_add(r.idx, r.scratch, 0, r.chunk);
            p.bge(r.idx, r.n, &done);
            p.add(r.limit, r.idx, r.chunk).imin(r.limit, r.limit, r.n);
            chunk_setup(p);
            p.label(&head);
            body(p);
            p.addi(r.idx, r.idx, 1);
            p.blt(r.idx, r.limit, &head);
            p.j(&grab);
        }
        Schedule::Guided { min_chunk, queue } => {
            assert!(min_chunk >= 1, "guided min_chunk must be >= 1");
            let grab = format!("{tag}_grab");
            let lock = format!("{tag}_lock");
            let out = format!("{tag}_out");
            p.label(&grab);
            p.li(r.scratch, queue.addr);
            // Acquire the test-and-set lock guarding the counter.
            p.label(&lock);
            p.li(r.chunk, 1);
            p.amo_swap(r.chunk, r.scratch, 4, r.chunk);
            p.bne(r.chunk, regs::ZERO, &lock);
            p.lw(r.idx, r.scratch, 0);
            p.bge(r.idx, r.n, &out);
            // chunk = max(min_chunk, remaining / 2W) — the OpenMP guided
            // decay, with the division on the core's iterative divider.
            p.sub(r.chunk, r.n, r.idx);
            p.add(r.limit, regs::NCORES, regs::NCORES);
            p.divi(r.chunk, r.chunk, Operand::Reg(r.limit));
            p.li(r.limit, min_chunk);
            p.imax(r.chunk, r.chunk, r.limit);
            // counter += chunk; release; clamp the chunk end.
            p.add(r.limit, r.idx, r.chunk);
            p.sw(r.limit, r.scratch, 0);
            p.sw(regs::ZERO, r.scratch, 4);
            p.imin(r.limit, r.limit, r.n);
            chunk_setup(p);
            p.label(&head);
            body(p);
            p.addi(r.idx, r.idx, 1);
            p.blt(r.idx, r.limit, &head);
            p.j(&grab);
            // Drained: release the lock and leave.
            p.label(&out);
            p.sw(regs::ZERO, r.scratch, 4);
        }
    }
    p.label(&done);
    p.region_exit();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::mem::TCDM_BASE;
    use crate::cluster::{Cluster, Engine};
    use crate::config::ClusterConfig;
    use crate::isa::Program;
    use crate::transfp::FpMode;

    const MARKS: u32 = TCDM_BASE + 0x2000;
    const OUT: u32 = TCDM_BASE + 0x4000;

    /// A probe program: per index i, increment marks[i] and store
    /// f32(i) · 1.5 to out[i]. Bodies preserve idx/limit/n per the register
    /// contract; everything else is clobbered freely.
    fn probe(sched: Schedule, n: u32) -> Program {
        let mut p = ProgramBuilder::new("sched-probe");
        p.li(LoopRegs::KERNEL.n, n);
        parallel_for(
            &mut p,
            sched,
            LoopRegs::KERNEL,
            |_| {},
            |p| {
                let r = LoopRegs::KERNEL;
                // marks[idx] += 1 (each index is visited exactly once, so a
                // plain read-modify-write is race-free iff the invariant
                // holds — a lost update would leave a 0 or a 2).
                p.slli(20, r.idx, 2);
                p.li(21, MARKS);
                p.add(21, 21, 20);
                p.lw(22, 21, 0);
                p.addi(22, 22, 1);
                p.sw(22, 21, 0);
                // out[idx] = f32(idx) * 1.5
                p.fcvt_from_int(FpMode::F32, 23, r.idx);
                p.li(26, 1.5f32.to_bits());
                p.fmul(FpMode::F32, 23, 23, 26);
                p.li(21, OUT);
                p.add(21, 21, 20);
                p.sw(23, 21, 0);
            },
        );
        p.barrier();
        p.end();
        p.build()
    }

    fn policies(al: &mut Alloc) -> Vec<Schedule> {
        vec![
            Schedule::Static,
            Schedule::Dynamic { chunk: 1, queue: WorkQueue::alloc(al) },
            Schedule::Dynamic { chunk: 3, queue: WorkQueue::alloc(al) },
            Schedule::Guided { min_chunk: 1, queue: WorkQueue::alloc(al) },
            Schedule::Guided { min_chunk: 4, queue: WorkQueue::alloc(al) },
        ]
    }

    /// The scheduler invariant: every index in [0, n) is assigned exactly
    /// once, for every (policy × occupancy × trip count) combination —
    /// including the degenerate trip counts 0 and 1.
    #[test]
    fn every_index_assigned_exactly_once() {
        let cfg = ClusterConfig::new(8, 8, 0);
        for n in [0u32, 1, 5, 8, 17, 64] {
            let mut al = Alloc::new(&cfg);
            for sched in policies(&mut al) {
                for workers in [1usize, 3, 8] {
                    let mut cl = Cluster::new(cfg, probe(sched, n));
                    cl.limit_active_cores(workers);
                    cl.run().unwrap();
                    for i in 0..n {
                        let m = cl.mem.load(MARKS + 4 * i, crate::isa::MemSize::Word);
                        assert_eq!(
                            m, 1,
                            "{sched:?} n={n} workers={workers}: index {i} visited {m} times"
                        );
                    }
                    // Nothing past the trip count is touched.
                    let past = cl.mem.load(MARKS + 4 * n, crate::isa::MemSize::Word);
                    assert_eq!(past, 0, "{sched:?} n={n}: wrote past the trip count");
                }
            }
        }
    }

    /// Independent bodies produce bit-identical outputs under every policy
    /// (assignment only moves *where* an index runs, never what it computes).
    #[test]
    fn outputs_bit_identical_across_policies() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let n = 40u32;
        let mut al = Alloc::new(&cfg);
        let mut reference: Option<Vec<u32>> = None;
        for sched in policies(&mut al) {
            let mut cl = Cluster::new(cfg, probe(sched, n));
            cl.run().unwrap();
            let out: Vec<u32> =
                (0..n).map(|i| cl.mem.load(OUT + 4 * i, crate::isa::MemSize::Word)).collect();
            match &reference {
                None => reference = Some(out),
                Some(r) => assert_eq!(r, &out, "{sched:?} diverged"),
            }
        }
    }

    /// Dynamic self-scheduling is deterministic under the simulator's fixed
    /// arbitration order: two identical runs claim identical chunks and
    /// finish in identical cycle counts, on both issue engines.
    #[test]
    fn dynamic_is_deterministic_and_engine_exact() {
        let cfg = ClusterConfig::new(8, 2, 1);
        let mut al = Alloc::new(&cfg);
        let q = WorkQueue::alloc(&mut al);
        let sched = Schedule::Dynamic { chunk: 2, queue: q };
        let run = |engine: Engine| {
            let mut cl = Cluster::new(cfg, probe(sched, 33));
            let stats = cl.run_with(engine).unwrap();
            let out: Vec<u32> =
                (0..33).map(|i| cl.mem.load(OUT + 4 * i, crate::isa::MemSize::Word)).collect();
            (stats.total_cycles, stats.per_core.clone(), out)
        };
        let (c1, p1, o1) = run(Engine::Event);
        let (c2, p2, o2) = run(Engine::Event);
        assert_eq!((c1, &o1), (c2, &o2), "dynamic scheduling must be deterministic");
        assert_eq!(p1, p2);
        let (cr, pr, or) = run(Engine::Reference);
        assert_eq!(c1, cr, "engines disagree on a dynamic schedule");
        assert_eq!(p1, pr);
        assert_eq!(o1, or);
    }

    /// Guided chunks decay: with one worker the grab count is well below
    /// n/min_chunk but the loop still covers everything.
    #[test]
    fn guided_covers_with_decaying_chunks() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let mut al = Alloc::new(&cfg);
        let q = WorkQueue::alloc(&mut al);
        let n = 64u32;
        let mut cl = Cluster::new(cfg, probe(Schedule::Guided { min_chunk: 2, queue: q }, n));
        cl.run().unwrap();
        for i in 0..n {
            assert_eq!(cl.mem.load(MARKS + 4 * i, crate::isa::MemSize::Word), 1);
        }
        // The lock is released on exit.
        assert_eq!(cl.mem.load(q.addr + 4, crate::isa::MemSize::Word), 0);
    }

    /// Static scheduling at partial occupancy uses NCORES (the worker
    /// count), so chunks span the whole range for any occupancy.
    #[test]
    fn static_respects_occupancy() {
        let cfg = ClusterConfig::new(16, 16, 0);
        for workers in [1usize, 5, 16] {
            let mut cl = Cluster::new(cfg, probe(Schedule::Static, 31));
            cl.limit_active_cores(workers);
            cl.run().unwrap();
            for i in 0..31 {
                assert_eq!(
                    cl.mem.load(MARKS + 4 * i, crate::isa::MemSize::Word),
                    1,
                    "workers={workers} index {i}"
                );
            }
        }
    }
}
