//! SVM — linear support-vector-machine inference: `score = Σᵢ αᵢ·⟨svᵢ, x⟩ + b`
//! over `nsv` support vectors of dimension `d`, the supervised classifier of
//! the paper's ExG near-sensor pipelines (§5.2, [44]).
//!
//! Parallelization: support vectors are chunked across cores; each core
//! accumulates a partial score; after a barrier, core 0 reduces the
//! partials and writes the decision — the "sequential regions interleaved
//! with parallel loops" structure of §5.2.
//!
//! * **Scalar**: inner dot-product loop `p.lw ×2 + fmac`, plus one
//!   `fmac(α, dot)` per support vector.
//! * **Vector**: dimension pairs with the expanding dot product; the α
//!   weighting stays in binary32 (multi-format accumulation).

use super::{mirror, quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload};
use crate::config::ClusterConfig;
use crate::isa::{regs, ProgramBuilder};
use crate::runtime::{parallel_for, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{simd, FpMode, FpSpec};

/// Build the SVM workload. The output buffer holds `[score, class]` (class
/// is +1.0/−1.0 from the sign of the score).
pub fn build(variant: Variant, cfg: &ClusterConfig, nsv: usize, d: usize) -> Workload {
    assert!(d % 2 == 0);
    let mut w = match variant {
        Variant::Scalar | Variant::Scalar16(_) => build_scalar(SElem::of(variant), cfg, nsv, d),
        Variant::Vector(_) => build_vector(variant, cfg, nsv, d),
    };
    w.reference = reference(nsv, d);
    w
}

/// Binary64 ground truth `[score, class]` from the un-quantized inputs.
fn reference(nsv: usize, d: usize) -> Vec<f64> {
    let (svs, alphas, x, bias) = gen_inputs(nsv, d);
    let mut score = 0.0f64;
    for i in 0..nsv {
        let mut dot = 0.0f64;
        for j in 0..d {
            dot += svs[i * d + j] as f64 * x[j] as f64;
        }
        score += alphas[i] as f64 * dot;
    }
    score += bias as f64;
    vec![score, if score >= 0.0 { 1.0 } else { -1.0 }]
}

fn gen_inputs(nsv: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, f32) {
    let mut rng = Rng::new(0x5356_4D00); // "SVM"
    let svs = rng.f32_vec(nsv * d, -1.0, 1.0);
    let alphas: Vec<f32> = (0..nsv).map(|i| rng.f32_in(0.01, 0.5) * if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let x = rng.f32_vec(d, -1.0, 1.0);
    let bias = rng.f32_in(-0.2, 0.2);
    (svs, alphas, x, bias)
}

/// Max cores that might run the reduction (partials buffer size).
const MAX_CORES: usize = 16;

fn build_scalar(elem: SElem, cfg: &ClusterConfig, nsv: usize, d: usize) -> Workload {
    let mut al = Alloc::new(cfg);
    let sv_base = elem.alloc(&mut al, nsv * d);
    let a_base = elem.alloc(&mut al, nsv);
    let x_base = elem.alloc(&mut al, d);
    let part_base = elem.alloc(&mut al, MAX_CORES);
    let bias_base = elem.alloc(&mut al, 1);
    let out_base = elem.alloc(&mut al, 2);
    let (svs, alphas, x, bias) = gen_inputs(nsv, d);

    // Host mirror: per-core partials in chunk order, then core-0 reduction.
    let expected = score_mirror(elem, &svs, &alphas, &x, bias, nsv, d, cfg.cores);

    let (id, nc) = (regs::CORE_ID, regs::NCORES);
    let mut p = ProgramBuilder::new(format!("svm-{}", elem.suffix()));
    p.li(15, sv_base).li(16, a_base).li(17, x_base);
    p.li(24, nsv as u32);
    p.li(30, (d * elem.size() as usize) as u32);
    p.li(28, 0); // local score (accumulates across this core's chunk)
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.mul(20, 13, 30).add(20, 20, 15); // sv row
            p.mv(21, 17); // x ptr
            p.li(27, 0); // dot acc
            p.li(19, d as u32);
            p.hwloop(19);
            elem.load_pi(p, 26, 20, 1);
            elem.load_pi(p, 29, 21, 1);
            p.fmac(elem.mode, 27, 26, 29);
            p.hwloop_end();
            p.slli(26, 13, elem.shift()).add(26, 26, 16);
            elem.load(p, 26, 26, 0); // α_i
            p.fmac(elem.mode, 28, 26, 27); // score += α·dot
        },
    );
    // Publish the partial score.
    p.li(25, part_base);
    p.slli(26, id, elem.shift()).add(26, 26, 25);
    elem.store(&mut p, 28, 26, 0);
    p.barrier();
    // Core 0: reduce partials + bias, take the sign.
    p.bne(id, regs::ZERO, "red_skip");
    p.li(20, part_base);
    p.li(28, 0);
    p.mv(19, nc);
    p.hwloop(19);
    elem.load_pi(&mut p, 26, 20, 1);
    p.fadd(elem.mode, 28, 28, 26);
    p.hwloop_end();
    p.li(26, bias_base);
    elem.load(&mut p, 26, 26, 0);
    p.fadd(elem.mode, 28, 28, 26);
    p.li(27, out_base);
    elem.store(&mut p, 28, 27, 0);
    // class = score >= 0 ? +1 : −1 (fcmp + select).
    p.li(26, 0);
    p.fcmp(elem.mode, crate::transfp::CmpPred::Le, 29, 26, 28); // 0 <= score
    p.li(26, elem.q(1.0));
    p.bne(29, regs::ZERO, "pos");
    p.li(26, elem.q(-1.0));
    p.label("pos");
    elem.store(&mut p, 26, 27, 1);
    p.label("red_skip");
    p.barrier();
    p.end();

    Workload {
        name: format!("SVM-{}", elem.suffix()),
        program: p.build(),
        stage: vec![
            (sv_base, elem.stage(&svs)),
            (a_base, elem.stage(&alphas)),
            (x_base, elem.stage(&x)),
            (part_base, elem.stage_zeros(MAX_CORES)),
            (bias_base, elem.stage(&[bias])),
        ],
        out_addr: out_base,
        out_len: 2,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

/// Score mirror for `workers` active cores (chunked like the kernel),
/// computed on register cells in the element format.
#[allow(clippy::too_many_arguments)]
fn score_mirror(
    elem: SElem,
    svs: &[f32],
    alphas: &[f32],
    x: &[f32],
    bias: f32,
    nsv: usize,
    d: usize,
    workers: usize,
) -> Vec<f64> {
    let svq = elem.quantize(svs);
    let aq = elem.quantize(alphas);
    let xq = elem.quantize(x);
    let chunk = nsv.div_ceil(workers);
    let mut partials = vec![0u32; workers];
    for (w, part) in partials.iter_mut().enumerate() {
        let lo = (w * chunk).min(nsv);
        let hi = ((w + 1) * chunk).min(nsv);
        for i in lo..hi {
            let dot = mirror::dot(elem, (0..d).map(|j| (svq[i * d + j], xq[j])));
            *part = elem.fma(aq[i], dot, *part);
        }
    }
    let mut score = 0u32;
    for pt in &partials {
        score = elem.add(score, *pt);
    }
    score = elem.add(score, elem.q(bias));
    let class = if elem.le(elem.q(0.0), score) { 1.0 } else { -1.0 };
    vec![elem.to_f64(score), class]
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, nsv: usize, d: usize) -> Workload {
    let spec: &'static FpSpec = spec_of(variant);
    let mode = variant.mode();
    let dw = d / 2;
    let mut al = Alloc::new(cfg);
    let sv_base = al.halves(nsv * d);
    let a_base = al.f32s(nsv); // α stays binary32 (multi-format accumulate)
    let x_base = al.halves(d);
    let part_base = al.f32s(MAX_CORES);
    let bias_base = al.f32s(1);
    let out_base = al.f32s(2);
    let (svs, alphas, x, bias) = gen_inputs(nsv, d);
    let svq = quantize16(spec, &svs);
    let xq = quantize16(spec, &x);

    // Mirror: expanding dot product per pair, α in f32.
    let expected = {
        let svw = super::pack_words(&svq);
        let xw = super::pack_words(&xq);
        let workers = cfg.cores;
        let chunk = nsv.div_ceil(workers);
        let mut partials = vec![0.0f32; workers];
        for (w, part) in partials.iter_mut().enumerate() {
            let lo = (w * chunk).min(nsv);
            let hi = ((w + 1) * chunk).min(nsv);
            for i in lo..hi {
                let mut dot = 0u32;
                for jp in 0..dw {
                    dot = simd::vdotp_widen(spec, svw[i * dw + jp], xw[jp], dot);
                }
                *part = alphas[i].mul_add(f32::from_bits(dot), *part);
            }
        }
        let mut score = 0.0f32;
        for pt in &partials {
            score += pt;
        }
        score += bias;
        vec![score as f64, if score >= 0.0 { 1.0 } else { -1.0 }]
    };

    let (id, nc) = (regs::CORE_ID, regs::NCORES);
    let mut p = ProgramBuilder::new("svm-vector");
    p.li(15, sv_base).li(16, a_base).li(17, x_base);
    p.li(24, nsv as u32);
    p.li(30, (dw * 4) as u32);
    p.li(28, 0);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.mul(20, 13, 30).add(20, 20, 15);
            p.mv(21, 17);
            p.li(27, 0);
            p.li(19, dw as u32);
            p.hwloop(19);
            p.lw_pi(26, 20, 4);
            p.lw_pi(29, 21, 4);
            p.fdotp(mode, 27, 26, 29);
            p.hwloop_end();
            p.slli(26, 13, 2).add(26, 26, 16);
            p.lw(26, 26, 0);
            p.fmac(FpMode::F32, 28, 26, 27);
        },
    );
    p.li(25, part_base);
    p.slli(26, id, 2).add(26, 26, 25);
    p.sw(28, 26, 0);
    p.barrier();
    p.bne(id, regs::ZERO, "red_skip");
    p.li(20, part_base);
    p.li(28, 0);
    p.mv(19, nc);
    p.hwloop(19);
    p.lw_pi(26, 20, 4);
    p.fadd(FpMode::F32, 28, 28, 26);
    p.hwloop_end();
    p.li(26, bias_base);
    p.lw(26, 26, 0);
    p.fadd(FpMode::F32, 28, 28, 26);
    p.li(27, out_base);
    p.sw(28, 27, 0);
    p.li(26, 0);
    p.fcmp(FpMode::F32, crate::transfp::CmpPred::Le, 29, 26, 28);
    p.li(26, 1.0f32.to_bits());
    p.bne(29, regs::ZERO, "pos");
    p.li(26, (-1.0f32).to_bits());
    p.label("pos");
    p.sw(26, 27, 4);
    p.label("red_skip");
    p.barrier();
    p.end();

    Workload {
        name: format!("SVM-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![
            (sv_base, Staged::U16(svq)),
            (a_base, Staged::F32(alphas)),
            (x_base, Staged::U16(xq)),
            (part_base, Staged::F32(vec![0.0; MAX_CORES])),
            (bias_base, Staged::F32(vec![bias])),
        ],
        out_addr: out_base,
        out_len: 2,
        out_fmt: OutFmt::F32,
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_exact_all_cores() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = build(Variant::Scalar, &cfg, 32, 16);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
        assert!(out[1] == 1.0 || out[1] == -1.0);
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 32, 16);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
            assert!(out[1] == 1.0 || out[1] == -1.0);
        }
    }

    #[test]
    fn vector_exact() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::VEC, &cfg, 32, 16);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn scalar_and_vector_agree_on_class() {
        let cfg = ClusterConfig::new(8, 8, 1);
        let ws = build(Variant::Scalar, &cfg, 64, 32);
        let wv = build(Variant::VEC, &cfg, 64, 32);
        let (_, os) = ws.run(&cfg).unwrap();
        let (_, ov) = wv.run(&cfg).unwrap();
        assert_eq!(os[1], ov[1], "16-bit quantization must not flip the decision");
        assert!((os[0] - ov[0]).abs() < 0.05 * os[0].abs().max(1.0));
    }
}
