//! Shared host-mirror helpers on raw `u32` register cells.
//!
//! Every kernel carries a host mirror that reproduces the datapath's
//! arithmetic bit-for-bit to generate its golden (`Workload::expected`).
//! The element-format primitives live on [`SElem`]; this module holds the
//! *reduction shapes* those mirrors kept re-implementing per kernel since
//! the precision ladder landed: ordered FMA dot products (FIR taps, MATMUL
//! rows, CONV windows, SVM feature dots) and squared Euclidean distances
//! (KMEANS assignment), plus the lane-0 widening FMA the packed CONV
//! mirror uses. Accumulation order is the kernels' order — first pair
//! first — because a mirror is only correct if it rounds exactly like the
//! emitted instruction stream.

use super::SElem;
use crate::transfp::{scalar, FpSpec};

/// Ordered element-format dot product: `acc = fma(a, b, acc)` over the
/// pairs, starting from +0.0 (the all-zero cell in every format).
pub fn dot(elem: SElem, pairs: impl IntoIterator<Item = (u32, u32)>) -> u32 {
    pairs.into_iter().fold(0u32, |acc, (a, b)| elem.fma(a, b, acc))
}

/// Ordered squared Euclidean distance between two cell slices:
/// `acc = fma(d, d, acc)` with `d = a[i] - b[i]`, in index order.
pub fn dist2(elem: SElem, a: &[u32], b: &[u32]) -> u32 {
    a.iter().zip(b).fold(0u32, |acc, (&x, &y)| {
        let d = elem.sub(x, y);
        elem.fma(d, d, acc)
    })
}

/// Lane-0 widening FMA mirror (`fmac.s.h`): f32 `acc += a.lane0 · b.lane0`
/// with the 16-bit operands widened exactly.
pub fn fma_widen(spec: &FpSpec, a: u32, b: u32, acc: u32) -> u32 {
    scalar::fma_widen(spec, a as u16, b as u16, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Variant;
    use crate::transfp::spec::F16;

    #[test]
    fn dot_matches_manual_fma_chain() {
        for v in [Variant::Scalar, Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let e = SElem::of(v);
            let a: Vec<u32> = [1.5f32, -2.0, 0.25, 3.0].iter().map(|&x| e.q(x)).collect();
            let b: Vec<u32> = [2.0f32, 0.5, -4.0, 1.0].iter().map(|&x| e.q(x)).collect();
            let mut acc = 0u32;
            for (x, y) in a.iter().zip(&b) {
                acc = e.fma(*x, *y, acc);
            }
            let got = dot(e, a.iter().copied().zip(b.iter().copied()));
            assert_eq!(got, acc, "{v:?}: dot must fold in kernel order");
            // 1.5·2 + (−2)·0.5 + 0.25·(−4) + 3·1 = 4
            assert_eq!(e.to_f64(got), 4.0);
        }
    }

    #[test]
    fn dot_is_order_sensitive_like_the_datapath() {
        // In binary16 the ulp at 2048 is 2, so small terms round differently
        // depending on whether they land before or after the big one — the
        // helper must preserve the kernels' accumulation order.
        let e = SElem::of(Variant::SCALAR_F16);
        let one = e.q(1.0);
        let fwd = dot(e, vec![(e.q(2048.0), one), (e.q(3.0), one), (e.q(3.0), one)]);
        let rev = dot(e, vec![(e.q(3.0), one), (e.q(3.0), one), (e.q(2048.0), one)]);
        assert_eq!(e.to_f64(fwd), 2056.0, "2051 and 2055 round up at ties-to-even");
        assert_eq!(e.to_f64(rev), 2054.0, "6 + 2048 is exact");
    }

    #[test]
    fn dist2_matches_manual_expansion() {
        let e = SElem::of(Variant::Scalar);
        let a: Vec<u32> = [1.0f32, 2.0, 3.0].iter().map(|&x| e.q(x)).collect();
        let b: Vec<u32> = [0.0f32, 4.0, 1.0].iter().map(|&x| e.q(x)).collect();
        // 1 + 4 + 4 = 9
        assert_eq!(e.to_f64(dist2(e, &a, &b)), 9.0);
        assert_eq!(dist2(e, &[], &[]), 0);
    }

    #[test]
    fn fma_widen_accumulates_in_f32() {
        let one = F16.from_f64(1.0) as u32;
        let acc = fma_widen(&F16, one, one, 2.5f32.to_bits());
        assert_eq!(f32::from_bits(acc), 3.5);
    }
}
