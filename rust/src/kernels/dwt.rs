//! DWT — multi-level discrete wavelet transform with a 4-tap (db2) filter
//! bank: each level halves the signal through a low-pass/high-pass pair
//! (feature extraction, §5.2).
//!
//! Parallelization follows the paper: data parallelism *within* each level,
//! an event-unit **barrier between levels** (the sequential-stage structure
//! that caps DWT's parallel speed-up around 8, §5.3.1).
//!
//! * **Scalar**: per output, the four taps share each sample load between
//!   the LP and HP accumulators (`lw x + lw h + lw g + fmac + fmac`).
//! * **Vector**: the (lo, hi) pair *is* the packed vector: each sample is
//!   duplicated into both lanes with `pv.pack` and multiply-accumulated
//!   against the packed (h[k], g[k]) coefficient table with `vfmac` — both
//!   filter outputs per instruction.
//!
//! Output layout: `[approx_L | detail_L | detail_{L-1} | … | detail_1]`.

use super::{quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload};
use crate::config::ClusterConfig;
use crate::isa::{regs, ProgramBuilder};
use crate::runtime::{parallel_for, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{simd, FpSpec};

const TAPS: usize = 4;

/// db2 filter bank (orthonormal pair), low-pass h and high-pass g.
fn filters() -> ([f32; 4], [f32; 4]) {
    let h = [0.482_962_9f32, 0.836_516_3, 0.224_143_87, -0.129_409_52];
    let g = [h[3], -h[2], h[1], -h[0]];
    (h, g)
}

/// Build the DWT workload: `n`-sample signal, `levels` decomposition levels.
pub fn build(variant: Variant, cfg: &ClusterConfig, n: usize, levels: usize) -> Workload {
    assert!(n % (1 << levels) == 0 && levels >= 1);
    let mut w = match variant {
        Variant::Scalar | Variant::Scalar16(_) => {
            build_scalar(SElem::of(variant), cfg, n, levels)
        }
        Variant::Vector(_) => build_vector(variant, cfg, n, levels),
    };
    w.reference = reference(n, levels);
    w
}

/// Binary64 ground truth (zero-extended edges, same output layout).
fn reference(n: usize, levels: usize) -> Vec<f64> {
    let x = gen_signal(n);
    let (h, g) = filters();
    let mut out = vec![0.0f64; n];
    let mut cur: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    for l in 1..=levels {
        let half = cur.len() / 2;
        let get = |i: usize| if i < cur.len() { cur[i] } else { 0.0 };
        let mut approx = vec![0.0f64; half];
        for i in 0..half {
            let (mut lo, mut hi) = (0.0f64, 0.0f64);
            for k in 0..TAPS {
                let xv = get(2 * i + k);
                lo += h[k] as f64 * xv;
                hi += g[k] as f64 * xv;
            }
            approx[i] = lo;
            out[(n >> l) + i] = hi;
        }
        cur = approx;
    }
    out[..cur.len()].copy_from_slice(&cur);
    out
}

fn gen_signal(n: usize) -> Vec<f32> {
    let mut rng = Rng::new(0x4457_5400); // "DWT"
    (0..n)
        .map(|i| {
            let t = i as f32 / 64.0;
            (6.283 * t).sin() * 0.5 + rng.f32_in(-0.2, 0.2)
        })
        .collect()
}

/// Result layout offsets: (detail offset per level, final approx length).
/// Level l (1-based) produces n/2^l detail coefficients at offset n/2^l.
pub fn detail_offsets(n: usize, levels: usize) -> (Vec<usize>, usize) {
    let offs = (1..=levels).map(|l| n >> l).collect();
    (offs, n >> levels)
}

fn build_scalar(elem: SElem, cfg: &ClusterConfig, n: usize, levels: usize) -> Workload {
    let mut al = Alloc::new(cfg);
    // Ping-pong work buffers (padded by TAPS for the zero-extended edge),
    // the result buffer, then the h/g filter tables.
    let w0_base = elem.alloc(&mut al, n + TAPS);
    let w1_base = elem.alloc(&mut al, n + TAPS);
    let r_base = elem.alloc(&mut al, n);
    let hg_base = elem.alloc(&mut al, 2 * TAPS);
    let x = gen_signal(n);
    let (h, g) = filters();

    // Host mirror (element-format FMA on register cells, tap order,
    // zero-extended edges).
    let hq = elem.quantize(&h);
    let gq = elem.quantize(&g);
    let mut expected = vec![0.0f64; n];
    {
        let mut cur: Vec<u32> = elem.quantize(&x);
        for l in 1..=levels {
            let half = cur.len() / 2;
            let get = |i: usize| if i < cur.len() { cur[i] } else { 0 };
            let mut approx = vec![0u32; half];
            for i in 0..half {
                let (mut lo, mut hi) = (0u32, 0u32);
                for k in 0..TAPS {
                    let xv = get(2 * i + k);
                    lo = elem.fma(hq[k], xv, lo);
                    hi = elem.fma(gq[k], xv, hi);
                }
                approx[i] = lo;
                expected[(n >> l) + i] = elem.to_f64(hi);
            }
            cur = approx;
        }
        for (i, a) in cur.iter().enumerate() {
            expected[i] = elem.to_f64(*a);
        }
    }

    let id = regs::CORE_ID;
    let mut p = ProgramBuilder::new(format!("dwt-{}", elem.suffix()));
    p.li(15, w0_base).li(16, w1_base).li(17, r_base);
    p.li(4, hg_base); // h table
    p.li(9, hg_base + (TAPS as i32 * elem.size()) as u32); // g table
    p.li(24, (n / 2) as u32); // outputs at current level
    for l in 1..=levels {
        // Split this level's outputs across cores through the runtime.
        parallel_for(
            &mut p,
            Schedule::Static,
            LoopRegs::KERNEL,
            |p| {
                // Walking pointers: x (2 samples per output), approx out,
                // detail out — materialized from the chunk start.
                p.slli(20, 13, elem.shift() + 1).add(20, 20, 15);
                p.slli(25, 13, elem.shift());
                p.add(29, 25, 16); // approx ptr = out + size·start
                p.add(23, 25, 17).addi(23, 23, (n >> l) as i32 * elem.size());
            },
            |p| {
                // Taps fully unrolled with static offsets (the compiler's
                // obvious lowering for a fixed 4-tap filter).
                p.li(27, 0); // lo acc
                p.li(28, 0); // hi acc
                for k in 0..TAPS as i32 {
                    elem.load(p, 26, 20, k);
                    elem.load(p, 5, 4, k);
                    elem.load(p, 6, 9, k);
                    p.fmac(elem.mode, 27, 5, 26);
                    p.fmac(elem.mode, 28, 6, 26);
                }
                p.addi(20, 20, 2 * elem.size());
                elem.store_pi(p, 27, 29, 1);
                elem.store_pi(p, 28, 23, 1);
            },
        );
        let lvl = format!("lvl{l}_");
        // Core 0 zero-pads the TAPS samples after this level's approx so the
        // next level sees a zero-extended edge (the ping-pong buffer holds
        // stale data there otherwise).
        p.bne(id, regs::ZERO, &format!("{lvl}nopad"));
        let half = n >> l;
        for k in 0..TAPS {
            elem.store(&mut p, regs::ZERO, 16, (half + k) as i32);
        }
        p.label(&format!("{lvl}nopad"));
        p.barrier(); // level boundary
        // Swap buffers, halve the level size.
        p.mv(25, 15).mv(15, 16).mv(16, 25);
        p.srli(24, 24, 1);
    }
    // Copy the final approximation into r[0 .. n>>levels] (parallel).
    let alen = (n >> levels) as u32;
    p.li(24, alen);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.slli(25, 13, elem.shift());
            p.add(20, 25, 15);
            elem.load(p, 26, 20, 0);
            p.add(21, 25, 17);
            elem.store(p, 26, 21, 0);
        },
    );
    p.barrier();
    p.end();

    // Stage: signal into w0 (padded with zeros), filters after the buffers.
    let mut stage_sig = x.clone();
    stage_sig.extend(vec![0.0f32; TAPS]);
    let mut coefs = h.to_vec();
    coefs.extend(g);
    Workload {
        name: format!("DWT-{}", elem.suffix()),
        program: p.build(),
        stage: vec![
            (w0_base, elem.stage(&stage_sig)),
            (w1_base, elem.stage_zeros(n + TAPS)),
            (hg_base, elem.stage(&coefs)),
        ],
        out_addr: r_base,
        out_len: n,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, n: usize, levels: usize) -> Workload {
    let spec: &'static FpSpec = spec_of(variant);
    let mode = variant.mode();
    let mut al = Alloc::new(cfg);
    let w0_base = al.halves(n + TAPS);
    let w1_base = al.halves(n + TAPS);
    let r_base = al.halves(n);
    let hg_base = al.halves(2 * TAPS);
    let x = gen_signal(n);
    let (h, g) = filters();
    let xq = {
        let mut q = quantize16(spec, &x);
        q.extend(vec![0u16; TAPS]);
        q
    };
    // Packed (h[k], g[k]) table.
    let hgq: Vec<u16> = (0..TAPS)
        .flat_map(|k| {
            [spec.from_f64(h[k] as f64), spec.from_f64(g[k] as f64)]
        })
        .collect();

    // Host mirror: vfmac on (lo,hi) accumulator pairs, 16-bit arithmetic.
    let mut expected = vec![0.0f64; n];
    {
        let mut cur: Vec<u16> = xq[..n].to_vec();
        for l in 1..=levels {
            let half = cur.len() / 2;
            let get = |i: usize| if i < cur.len() { cur[i] } else { 0 };
            let mut approx = vec![0u16; half];
            for i in 0..half {
                let mut acc = 0u32; // packed (lo, hi)
                for k in 0..TAPS {
                    let xd = simd::pack2(get(2 * i + k), get(2 * i + k));
                    let hg = simd::pack2(hgq[2 * k], hgq[2 * k + 1]);
                    acc = simd::vmac(spec, xd, hg, acc);
                }
                let (lo, hi) = simd::unpack2(acc);
                approx[i] = lo;
                expected[(n >> l) + i] = spec.to_f64(hi);
            }
            cur = approx;
        }
        for (i, a) in cur.iter().enumerate() {
            expected[i] = spec.to_f64(*a);
        }
    }

    let id = regs::CORE_ID;
    let mut p = ProgramBuilder::new("dwt-vector");
    p.li(15, w0_base).li(16, w1_base).li(17, r_base);
    p.li(24, (n / 2) as u32);
    for l in 1..=levels {
        parallel_for(
            &mut p,
            Schedule::Static,
            LoopRegs::KERNEL,
            |p| {
                p.li(21, hg_base);
                p.slli(20, 13, 2).add(20, 20, 15); // sample ptr (2 lanes/out)
                p.slli(25, 13, 1);
                p.add(29, 25, 16); // approx lane ptr
                p.add(23, 25, 17).addi(23, 23, ((n >> l) * 2) as i32); // detail
            },
            |p| {
                p.li(27, 0); // (lo,hi) accumulator pair
                // Unrolled taps: lh sample, pv.pack duplicate, vfmac against
                // the packed (h[k], g[k]) table — both filters per
                // instruction.
                for k in 0..TAPS as i32 {
                    p.lh(26, 20, 2 * k);
                    p.vpack_lo(26, 26, 26);
                    p.lw(5, 21, 4 * k);
                    p.fmac(mode, 27, 26, 5);
                }
                p.addi(20, 20, 4);
                // Store lo lane → approx, hi lane → detail.
                p.sh(27, 29, 0);
                p.addi(29, 29, 2);
                p.vshuffle(27, 27, 0b01); // hi → low lane
                p.sh(27, 23, 0);
                p.addi(23, 23, 2);
            },
        );
        let lvl = format!("lvl{l}_");
        // Zero-pad the edge for the next level (see the scalar variant).
        p.bne(id, regs::ZERO, &format!("{lvl}nopad"));
        let half = n >> l;
        for k in 0..TAPS {
            p.sh(regs::ZERO, 16, (2 * (half + k)) as i32);
        }
        p.label(&format!("{lvl}nopad"));
        p.barrier();
        p.mv(25, 15).mv(15, 16).mv(16, 25);
        p.srli(24, 24, 1);
    }
    // Copy final approx lanes into r[0..].
    let alen = (n >> levels) as u32;
    p.li(24, alen);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.slli(25, 13, 1);
            p.add(20, 25, 15);
            p.lh(26, 20, 0);
            p.add(21, 25, 17);
            p.sh(26, 21, 0);
        },
    );
    p.barrier();
    p.end();

    Workload {
        name: format!("DWT-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![
            (w0_base, Staged::U16(xq)),
            (w1_base, Staged::U16(vec![0; n + TAPS])),
            (hg_base, Staged::U16(hgq)),
        ],
        out_addr: r_base,
        out_len: n,
        out_fmt: OutFmt::Pack16(spec),
        expected,
        rtol: 1e-9,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_exact_multicore() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = build(Variant::Scalar, &cfg, 64, 3);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
        let (_, o1) = w.run_on(&cfg, 1).unwrap();
        w.verify(&o1).unwrap();
    }

    #[test]
    fn vector_exact() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::VEC, &cfg, 64, 3);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 64, 3);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
            let (_, o1) = w.run_on(&cfg, 1).unwrap();
            w.verify(&o1).unwrap();
        }
    }

    #[test]
    fn barriers_limit_parallel_speedup() {
        // §5.3.1: DWT saturates well below ideal because of per-level
        // barriers and halving work.
        let cfg = ClusterConfig::new(16, 16, 1);
        let w = build(Variant::Scalar, &cfg, 512, 3);
        let (s1, _) = w.run_on(&cfg, 1).unwrap();
        let (s16, _) = w.run_on(&cfg, 16).unwrap();
        let speedup = s1.total_cycles as f64 / s16.total_cycles as f64;
        assert!(speedup > 4.0 && speedup < 13.0, "DWT speedup = {speedup}");
    }
}
