//! FIR — finite impulse response filter, T taps over an N-sample window
//! (data acquisition front-end, §5.2). Outputs are partitioned statically
//! across cores (outer-loop data parallelism).
//!
//! * **Scalar**: inner tap loop of `p.lw ×2 + fmac` in a hardware loop —
//!   Table 3's 0.32 / 0.65 intensity mix.
//! * **Vector**: the paper's "advanced manual vectorization" (§5.3.1):
//!   two adjacent outputs share each tap-pair load; the odd-aligned sample
//!   pair is assembled with `pv.shuffle`/`pv.pack` from two aligned loads;
//!   two expanding dot products accumulate in binary32; `vfcpka` packs the
//!   result pair.

use super::{
    mirror, pack_words, quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload,
};
use crate::config::ClusterConfig;
use crate::isa::ProgramBuilder;
use crate::runtime::{parallel_for, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{cast, simd};

/// Build the FIR workload: `n` outputs of a `taps`-tap filter.
pub fn build(variant: Variant, cfg: &ClusterConfig, n: usize, taps: usize) -> Workload {
    assert!(n % 2 == 0 && taps % 2 == 0);
    let mut w = match variant {
        Variant::Scalar | Variant::Scalar16(_) => build_scalar(SElem::of(variant), cfg, n, taps),
        Variant::Vector(_) => build_vector(variant, cfg, n, taps),
    };
    w.reference = reference(n, taps);
    w
}

/// Binary64 ground truth from the un-quantized f32 inputs (accuracy
/// baseline shared by every precision rung).
fn reference(n: usize, taps: usize) -> Vec<f64> {
    let (x, h) = gen_inputs(n, taps);
    (0..n)
        .map(|i| (0..taps).map(|t| h[t] as f64 * x[i + t] as f64).sum())
        .collect()
}

fn gen_inputs(n: usize, taps: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0x4649_5200); // "FIR"
    let x = rng.f32_vec(n + taps, -1.0, 1.0);
    // Plausible band-pass-ish taps, bounded.
    let h: Vec<f32> = (0..taps)
        .map(|t| {
            let w = (t as f32 + 0.5) / taps as f32;
            (6.283 * 3.0 * w).sin() / (taps as f32 * w + 1.0)
        })
        .collect();
    (x, h)
}

fn build_scalar(elem: SElem, cfg: &ClusterConfig, n: usize, taps: usize) -> Workload {
    let mut al = Alloc::new(cfg);
    let x_base = elem.alloc(&mut al, n + taps);
    let h_base = elem.alloc(&mut al, taps);
    let y_base = elem.alloc(&mut al, n);
    let (x, h) = gen_inputs(n, taps);

    // Host mirror: same tap order, element-format FMA on register cells
    // (bit-identical to the datapath on every rung of the ladder).
    let xs = elem.quantize(&x);
    let hs = elem.quantize(&h);
    let expected: Vec<f64> = (0..n)
        .map(|i| elem.to_f64(mirror::dot(elem, (0..taps).map(|t| (hs[t], xs[i + t])))))
        .collect();

    let mut p = ProgramBuilder::new(format!("fir-{}", elem.suffix()));
    p.li(24, n as u32);
    p.li(15, x_base).li(16, h_base).li(17, y_base);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |p| {
            // y_ptr walks from y + size·chunk_start.
            p.slli(25, 13, elem.shift()).add(23, 25, 17);
        },
        |p| {
            p.slli(20, 13, elem.shift()).add(20, 20, 15); // x_ptr = x + size·i
            p.mv(21, 16); // h_ptr
            p.li(28, 0); // acc
            p.li(19, taps as u32);
            p.hwloop(19);
            elem.load_pi(p, 26, 20, 1);
            elem.load_pi(p, 27, 21, 1);
            p.fmac(elem.mode, 28, 27, 26);
            p.hwloop_end();
            elem.store_pi(p, 28, 23, 1);
        },
    );
    p.barrier();
    p.end();

    Workload {
        name: format!("FIR-{}", elem.suffix()),
        program: p.build(),
        stage: vec![(x_base, elem.stage(&x)), (h_base, elem.stage(&h))],
        out_addr: y_base,
        out_len: n,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, n: usize, taps: usize) -> Workload {
    let spec = spec_of(variant);
    let mode = variant.mode();
    let mut al = Alloc::new(cfg);
    let x_base = al.halves(n + taps + 2);
    let h_base = al.halves(taps);
    let y_base = al.halves(n);
    let (x, h) = gen_inputs(n, taps);
    let mut xq = quantize16(spec, &x);
    xq.extend([0u16; 2]); // guard pair for the trailing misaligned load
    let hq = quantize16(spec, &h);

    // Host mirror: per output pair, tap pairs, two expanding dot products
    // (even alignment direct, odd alignment via pack(w0.hi, w1.lo)).
    let xw = pack_words(&xq);
    let hw = pack_words(&hq);
    let mut expected = vec![0.0f64; n];
    for ip in 0..n / 2 {
        let mut acc0 = 0u32;
        let mut acc1 = 0u32;
        for tp in 0..taps / 2 {
            let hpair = hw[tp];
            let w0 = xw[ip + tp];
            let w1 = xw[ip + tp + 1];
            let odd = simd::vpack_lo(simd::vshuffle(w0, 0b11), w1); // (w0.hi, w1.lo)
            acc0 = simd::vdotp_widen(spec, hpair, w0, acc0);
            acc1 = simd::vdotp_widen(spec, hpair, odd, acc1);
        }
        let cpk = cast::cpka(spec, acc0, acc1);
        let (lo, hi) = simd::unpack2(cpk);
        expected[2 * ip] = spec.to_f64(lo);
        expected[2 * ip + 1] = spec.to_f64(hi);
    }

    let mut p = ProgramBuilder::new("fir-vector");
    let npairs = (n / 2) as u32;
    p.li(24, npairs);
    p.li(15, x_base).li(16, h_base).li(17, y_base);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |p| {
            // y_ptr walks from y + 4·chunk_start (one word per pair).
            p.slli(25, 13, 2).add(23, 25, 17);
        },
        |p| {
            p.slli(20, 13, 2).add(20, 20, 15); // x_ptr = x + 4·ip
            p.mv(21, 16); // h_ptr
            p.li(27, 0); // acc0
            p.li(28, 0); // acc1
            p.li(19, (taps / 2) as u32);
            p.hwloop(19);
            p.lw_pi(5, 21, 4); // h pair
            p.lw_pi(6, 20, 4); // w0 (aligned)
            p.lw(7, 20, 0); // w1 (next pair, re-read next iteration)
            p.vshuffle(8, 6, 0b11); // (w0.hi, w0.hi)
            p.vpack_lo(8, 8, 7); // odd pair (w0.hi, w1.lo)
            p.fdotp(mode, 27, 5, 6);
            p.fdotp(mode, 28, 5, 8);
            p.hwloop_end();
            p.cpka(mode, 9, 27, 28);
            p.sw_pi(9, 23, 4);
        },
    );
    p.barrier();
    p.end();

    Workload {
        name: format!("FIR-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![(x_base, Staged::U16(xq)), (h_base, Staged::U16(hq))],
        out_addr: y_base,
        out_len: n,
        out_fmt: OutFmt::Pack16(spec),
        expected,
        rtol: 1e-9,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfp::FpMode;

    #[test]
    fn scalar_exact() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = build(Variant::Scalar, &cfg, 64, 16);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
        let (_, out1) = w.run_on(&cfg, 1).unwrap();
        w.verify(&out1).unwrap();
    }

    #[test]
    fn vector_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 8, 0);
        for v in [Variant::VEC, Variant::Vector(FpMode::VecBf16)] {
            let w = build(v, &cfg, 64, 16);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
        }
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 64, 16);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
            let (_, o1) = w.run_on(&cfg, 1).unwrap();
            w.verify(&o1).unwrap();
        }
    }

    #[test]
    fn reference_tracks_all_rungs() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let r = build(Variant::Scalar, &cfg, 64, 16).reference.clone();
        assert_eq!(r.len(), 64);
        for v in [Variant::Scalar, Variant::SCALAR_F16, Variant::VEC] {
            let w = build(v, &cfg, 64, 16);
            assert_eq!(w.reference, r, "{}: reference must be variant-independent", w.name);
            // Every rung's own mirror stays close to the f64 ground truth
            // (16-bit rungs within their quantization noise).
            let tol = if v == Variant::Scalar { 1e-5 } else { 0.05 };
            for (e, g) in w.expected.iter().zip(&w.reference) {
                assert!((e - g).abs() <= tol * g.abs().max(1.0), "{}: {e} vs {g}", w.name);
            }
        }
    }

    #[test]
    fn vector_faster_than_scalar() {
        let cfg = ClusterConfig::new(16, 16, 1);
        let ws = build(Variant::Scalar, &cfg, 256, 32);
        let wv = build(Variant::VEC, &cfg, 256, 32);
        let (ss, _) = ws.run(&cfg).unwrap();
        let (sv, _) = wv.run(&cfg).unwrap();
        let speedup = ss.total_cycles as f64 / sv.total_cycles as f64;
        assert!(speedup > 1.3 && speedup < 2.2, "FIR vector speedup = {speedup}");
    }
}
