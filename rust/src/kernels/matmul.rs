//! MATMUL — dense n×n single-precision / packed-16 matrix multiply (BLAS-3),
//! one of the two "basic linear algebra subprograms commonly used in DSP"
//! (§5.2). Rows are partitioned statically across cores (outer-loop data
//! parallelism).
//!
//! * **Scalar**: classic i/j/k with a hardware inner loop of
//!   `p.lw (post-inc) ×2 + fmac` — the FP/mem intensity of Table 3 row
//!   MATMUL emerges from exactly this mix.
//! * **Vector**: the paper's strategy (§5.3.1): both operands vectorized
//!   (B pre-transposed at staging time, k-dimension packed 2×16), the inner
//!   loop unrolled over two output columns sharing one A-pair load, the
//!   expanding dot-product intrinsic (`vfdotpex.s.h`) accumulating in
//!   binary32, and **cast-and-pack** (`vfcpka`) assembling the packed
//!   16-bit result pair.

use super::{
    mirror, pack_words, quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload,
};
use crate::cluster::mem::L2_BASE;
use crate::config::ClusterConfig;
use crate::isa::{regs, ProgramBuilder};
use crate::runtime::{parallel_for, team, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{scalar, simd, FpMode};

/// Build the MATMUL workload: C = A·B with n×n operands.
pub fn build(variant: Variant, cfg: &ClusterConfig, n: usize) -> Workload {
    assert!(n.is_power_of_two(), "bank-stagger masks require power-of-two n");
    let mut w = match variant {
        Variant::Scalar | Variant::Scalar16(_) => build_scalar(SElem::of(variant), cfg, n),
        Variant::Vector(_) => build_vector(variant, cfg, n),
    };
    w.reference = reference(n);
    w
}

/// Binary64 ground truth C = A·B from the un-quantized f32 inputs.
fn reference(n: usize) -> Vec<f64> {
    let (a, b) = gen_inputs(n);
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += a[i * n + k] as f64 * b[k * n + j] as f64;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

fn gen_inputs(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0x4D41_544D); // "MATM"
    let a = rng.f32_vec(n * n, -1.0, 1.0);
    let b = rng.f32_vec(n * n, -1.0, 1.0);
    (a, b)
}

fn build_scalar(elem: SElem, cfg: &ClusterConfig, n: usize) -> Workload {
    let mut al = Alloc::new(cfg);
    let a_base = elem.alloc(&mut al, n * n);
    let b_base = elem.alloc(&mut al, n * n);
    let c_base = elem.alloc(&mut al, n * n);

    let (a, b) = gen_inputs(n);

    // Host mirror: identical op order (k ascending, element-format FMA on
    // register cells) → exact match on every rung.
    let aq = elem.quantize(&a);
    let bq = elem.quantize(&b);
    let mut expected = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let acc = mirror::dot(elem, (0..n).map(|k| (aq[i * n + k], bq[k * n + j])));
            expected[i * n + j] = elem.to_f64(acc);
        }
    }

    let mut p = ProgramBuilder::new(format!("matmul-{}", elem.suffix()));
    // r24 = n; the runtime owns r12/r13/r14/r25 (LoopRegs::KERNEL).
    p.li(24, n as u32);
    p.li(15, a_base).li(16, b_base).li(17, c_base);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            // r25 = size*n*i; r23 = C row base; r22 = A row base.
            p.mul(25, 13, 24).slli(25, 25, elem.shift());
            p.add(23, 25, 17); // c_row
            p.add(22, 25, 15); // a_row
            // Stagger the column start per core (j0 = 2·core_id mod n) so
            // that concurrent B-column walks hit different TCDM banks — B's
            // stride is n elements, which aliases to a single bank for
            // power-of-two n.
            p.slli(9, regs::CORE_ID, 1);
            p.andi(9, 9, (n - 1) as i32); // j0
            p.li(18, 0); // column count
            p.label("col");
            {
                p.mv(20, 22); // a_ptr
                p.slli(21, 9, elem.shift()).add(21, 21, 16); // b_ptr = B + size·j
                p.li(28, 0); // acc = 0.0
                p.li(19, n as u32);
                p.hwloop(19);
                elem.load_pi(p, 26, 20, 1);
                elem.load_pi(p, 27, 21, n as i32);
                p.fmac(elem.mode, 28, 26, 27);
                p.hwloop_end();
                p.slli(25, 9, elem.shift()).add(25, 25, 23);
                elem.store(p, 28, 25, 0); // C[i][j]
                // j = (j + 1) mod n
                p.addi(9, 9, 1);
                p.andi(9, 9, (n - 1) as i32);
                p.addi(18, 18, 1);
                p.blt(18, 24, "col");
            }
        },
    );
    p.barrier();
    p.end();

    Workload {
        name: format!("MATMUL-{}", elem.suffix()),
        program: p.build(),
        stage: vec![(a_base, elem.stage(&a)), (b_base, elem.stage(&b))],
        out_addr: c_base,
        out_len: n * n,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, n: usize) -> Workload {
    let spec = spec_of(variant);
    let mode = variant.mode();
    let mut al = Alloc::new(cfg);
    let halfwords = n * n;
    let a_base = al.halves(halfwords); // A row-major, k packed
    let b_base = al.halves(halfwords); // B row-major, j packed (natural layout)
    let c_base = al.halves(halfwords); // C row-major, j packed

    let (a, b) = gen_inputs(n);
    let aq = quantize16(spec, &a);
    let bq = quantize16(spec, &b);

    // Host mirror with identical semantics: for each 2×2 (k,j) tile, load
    // B rows k and k+1 packed along j, transpose with pv.pack lo/hi, and
    // feed two expanding dot products with a shared A pair — exactly the
    // §5.3.1 recipe ("unrolling the two inner loops, adding shuffle
    // operations to compute the transpose, and using a dot-product
    // intrinsic").
    let aw = pack_words(&aq);
    let bw = pack_words(&bq);
    let row_w = n / 2;
    let mut expected = vec![0.0f64; n * n];
    for i in 0..n {
        for jp in 0..n / 2 {
            let mut acc0 = 0u32;
            let mut acc1 = 0u32;
            for kk in 0..n / 2 {
                let apair = aw[i * row_w + kk];
                let w0 = bw[(2 * kk) * row_w + jp];
                let w1 = bw[(2 * kk + 1) * row_w + jp];
                let col0 = simd::vpack_lo(w0, w1);
                let col1 = simd::vpack_hi(w0, w1);
                acc0 = simd::vdotp_widen(spec, apair, col0, acc0);
                acc1 = simd::vdotp_widen(spec, apair, col1, acc1);
            }
            let c = crate::transfp::cast::cpka(spec, acc0, acc1);
            let (lo, hi) = simd::unpack2(c);
            expected[i * n + 2 * jp] = spec.to_f64(lo);
            expected[i * n + 2 * jp + 1] = spec.to_f64(hi);
        }
    }

    let mut p = ProgramBuilder::new("matmul-vector");
    p.li(24, n as u32);
    p.li(15, a_base).li(16, b_base).li(17, c_base);
    p.li(30, row_w as u32); // words per packed row
    p.slli(31, 30, 3); // 2 packed rows in bytes (row_w*4*2)
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            // r22 = A row base; r23 = C row base (both i*row_w words)
            p.mul(25, 13, 30).slli(25, 25, 2);
            p.add(22, 25, 15);
            p.add(23, 25, 17);
            // Staggered column-pair start (see the scalar variant): B's
            // packed row stride aliases banks for power-of-two n.
            p.andi(4, regs::CORE_ID, (row_w - 1) as i32); // jp0
            p.li(18, 0); // column-pair count
            p.label("col");
            {
                p.mv(20, 22); // a_ptr
                p.slli(21, 4, 2).add(21, 21, 16); // b_ptr0 = B + 4*jp (row 0)
                p.slli(29, 30, 2).add(29, 29, 21); // b_ptr1 = b_ptr0 + one row
                p.li(27, 0); // acc0 (f32)
                p.li(28, 0); // acc1 (f32)
                p.li(19, (n / 2) as u32);
                p.hwloop(19);
                p.lw_pi(26, 20, 4); // A[i][k..k+1]
                {
                    let two_rows = (row_w * 8) as i32;
                    p.lw_pi(5, 21, two_rows); // B[k][j..j+1]
                    p.lw_pi(6, 29, two_rows); // B[k+1][j..j+1]
                }
                p.vpack_lo(7, 5, 6); // (B[k][j],   B[k+1][j])   — pv.pack
                p.vpack_hi(8, 5, 6); // (B[k][j+1], B[k+1][j+1])
                p.fdotp(mode, 27, 26, 7);
                p.fdotp(mode, 28, 26, 8);
                p.hwloop_end();
                // Cast-and-pack the two f32 accumulators into one word.
                p.cpka(mode, 9, 27, 28);
                p.slli(25, 4, 2).add(25, 25, 23);
                p.sw(9, 25, 0);
                // jp = (jp + 1) mod row_w
                p.addi(4, 4, 1);
                p.andi(4, 4, (row_w - 1) as i32);
                p.addi(18, 18, 1);
                p.blt(18, 30, "col");
            }
        },
    );
    p.barrier();
    p.end();

    Workload {
        name: format!("MATMUL-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![(a_base, Staged::U16(aq)), (b_base, Staged::U16(bq))],
        out_addr: c_base,
        out_len: n * n,
        out_fmt: OutFmt::Pack16(spec),
        expected,
        rtol: 1e-9,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

/// DMA double-buffered tiled MATMUL (binary32 scalar): A, B and C live in
/// **L2** — the dataset no longer has to fit the TCDM — and the kernel
/// streams A/C row tiles through ping-pong TCDM buffers while B stays
/// TCDM-resident. Core 0 is the tile master: it programs the memory-mapped
/// DMA, spin-waits on `STATUS`, and releases the team for each tile over
/// the event unit's [`team::EV_TILE_READY`] line; the prefetch of tile
/// `t+1` overlaps the compute of tile `t` (classic near-sensor double
/// buffering, §3.1's DMA + §4's runtime). Outputs are bit-identical to the
/// untiled scalar kernel — tiling moves data, never arithmetic.
pub fn build_tiled(cfg: &ClusterConfig, n: usize, tiles: usize) -> Workload {
    // No bank-stagger masks here (the B walk goes through the resident
    // TCDM copy row-by-row), so n need not be a power of two — the default
    // "bigger than TCDM" scenario is n = 96.
    assert!(tiles >= 1 && n % tiles == 0, "tiles must divide n");
    let tile_rows = n / tiles;
    let tile_words = (tile_rows * n) as u32;

    // L2 layout: A | B | C, row-major f32.
    let a_l2 = L2_BASE;
    let b_l2 = L2_BASE + (n * n * 4) as u32;
    let c_l2 = L2_BASE + (2 * n * n * 4) as u32;
    // TCDM layout: resident B + ping-pong A/C tile buffers.
    let mut al = Alloc::new(cfg);
    let b_tcdm = al.f32s(n * n);
    let abuf = [al.f32s(tile_rows * n), al.f32s(tile_rows * n)];
    let cbuf = [al.f32s(tile_rows * n), al.f32s(tile_rows * n)];

    let (a, b) = gen_inputs(n);
    // Host mirror: identical arithmetic to the untiled scalar kernel
    // (k ascending, f32 FMA) — the tiled schedule must be bit-identical.
    let f32e = SElem::of(Variant::Scalar);
    let mut expected = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let acc = mirror::dot(
                f32e,
                (0..n).map(|k| (a[i * n + k].to_bits(), b[k * n + j].to_bits())),
            );
            expected[i * n + j] = f32::from_bits(acc) as f64;
        }
    }

    let mut p = ProgramBuilder::new(format!("matmul-tiled{tiles}-scalar"));
    // Prologue: stage B and the first A tile, then release the team.
    team::master_only(&mut p, "boot", &mut |p| {
        team::dma_copy(p, 1, 2, b_l2, b_tcdm, (n * n) as u32);
        team::dma_copy(p, 1, 2, a_l2, abuf[0], tile_words);
        team::dma_wait(p, 1, 2);
        team::signal_tile_ready(p);
    });
    p.li(16, b_tcdm);
    p.li(30, n as u32);
    for t in 0..tiles {
        let buf = t % 2;
        // Everyone (master included — it buffered its own signal) waits for
        // tile t's data.
        team::wait_tile_ready(&mut p);
        // Master prefetches tile t+1 into the other buffer: the transfer
        // overlaps this tile's compute.
        if t + 1 < tiles {
            team::master_only(&mut p, &format!("pf{t}"), &mut |p| {
                let src = a_l2 + ((t + 1) * tile_rows * n * 4) as u32;
                team::dma_copy(p, 1, 2, src, abuf[(t + 1) % 2], tile_words);
            });
        }
        // Compute tile t: rows split across the team by the runtime. The
        // region spans setup through the joining barrier, so the
        // attribution report shows per-tile compute + imbalance cost.
        p.region_enter(&format!("tile{t}"));
        p.li(15, abuf[buf]);
        p.li(17, cbuf[buf]);
        p.li(24, tile_rows as u32);
        let col = format!("t{t}_col");
        parallel_for(
            &mut p,
            Schedule::Static,
            LoopRegs::KERNEL,
            |_| {},
            |p| {
                // r22 = A tile row; r23 = C tile row.
                p.mul(25, 13, 30).slli(25, 25, 2);
                p.add(22, 25, 15);
                p.add(23, 25, 17);
                p.li(18, 0); // j
                p.label(&col);
                {
                    p.mv(20, 22); // a_ptr
                    p.slli(21, 18, 2).add(21, 21, 16); // b_ptr = B + 4·j
                    p.li(28, 0); // acc
                    p.li(19, n as u32);
                    p.hwloop(19);
                    p.lw_pi(26, 20, 4);
                    p.lw_pi(27, 21, (n * 4) as i32);
                    p.fmac(FpMode::F32, 28, 26, 27);
                    p.hwloop_end();
                    p.slli(25, 18, 2).add(25, 25, 23);
                    p.sw(28, 25, 0);
                    p.addi(18, 18, 1);
                    p.blt(18, 30, &col);
                }
            },
        );
        p.barrier(); // tile compute complete
        p.region_exit();
        // Master: write the C tile back, drain the channel (writeback +
        // any prefetch), and release the team for the next tile.
        team::master_only(&mut p, &format!("wb{t}"), &mut |p| {
            team::dma_copy(p, 1, 2, cbuf[buf], c_l2 + (t * tile_rows * n * 4) as u32, tile_words);
            team::dma_wait(p, 1, 2);
            if t + 1 < tiles {
                team::signal_tile_ready(p);
            }
        });
    }
    p.barrier(); // join
    p.end();

    Workload {
        name: format!("MATMUL-tiled{tiles}-scalar"),
        program: p.build(),
        stage: vec![(a_l2, Staged::F32(a)), (b_l2, Staged::F32(b))],
        out_addr: c_l2,
        out_len: n * n,
        out_fmt: OutFmt::F32,
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: reference(n),
    }
}

// Mirror that the scalar path truly is plain f32 (used by docs/tests).
#[allow(dead_code)]
fn host_fma_chain(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0.0f32, |acc, (x, y)| scalar_fma(acc, *x, *y))
}

#[inline]
fn scalar_fma(acc: f32, x: f32, y: f32) -> f32 {
    f32::from_bits(scalar::fma32(x.to_bits(), y.to_bits(), acc.to_bits()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfp::FpMode;

    #[test]
    fn scalar_exact_on_one_and_eight_cores() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = build(Variant::Scalar, &cfg, 16);
        let (_, out1) = w.run_on(&cfg, 1).unwrap();
        w.verify(&out1).unwrap();
        let (_, out8) = w.run(&cfg).unwrap();
        w.verify(&out8).unwrap();
    }

    #[test]
    fn vector_f16_exact_mirror() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::VEC, &cfg, 16);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn vector_bf16_exact_mirror() {
        let cfg = ClusterConfig::new(8, 8, 1);
        let w = build(Variant::Vector(FpMode::VecBf16), &cfg, 16);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 16);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
            let (_, o1) = w.run_on(&cfg, 1).unwrap();
            w.verify(&o1).unwrap();
        }
    }

    #[test]
    fn tiled_exact_and_double_buffered() {
        let cfg = ClusterConfig::new(8, 8, 1);
        // Small instance: exactness across tile counts and occupancies.
        for tiles in [1usize, 2, 4] {
            let w = build_tiled(&cfg, 16, tiles);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap_or_else(|e| panic!("tiles={tiles}: {e}"));
            let (_, o1) = w.run_on(&cfg, 1).unwrap();
            w.verify(&o1).unwrap_or_else(|e| panic!("tiles={tiles} solo: {e}"));
        }
        // The tiled schedule computes exactly what the untiled kernel does.
        let tiled = build_tiled(&cfg, 16, 4);
        let flat = build(Variant::Scalar, &cfg, 16);
        assert_eq!(tiled.expected, flat.expected, "tiling must not move arithmetic");
    }

    #[test]
    fn tiled_handles_datasets_larger_than_tcdm() {
        // 3·96²·4 B ≈ 108 kB of operands against a 64 kB TCDM: only the
        // resident B copy plus the ping-pong tiles live on-cluster.
        let cfg = ClusterConfig::new(8, 8, 1);
        let w = build_tiled(&cfg, 96, 8);
        let dataset = 3 * 96 * 96 * 4;
        assert!(dataset > cfg.tcdm_bytes(), "scenario must exceed the TCDM");
        let (stats, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
        assert!(stats.total_cycles > 0);
    }

    #[test]
    fn intensities_near_table3() {
        let cfg = ClusterConfig::new(8, 8, 1);
        for (variant, (fp_ref, mem_ref)) in [
            (Variant::Scalar, (0.28, 0.58)),
            (Variant::VEC, (0.27, 0.41)),
        ] {
            let w = build(variant, &cfg, 32);
            let (stats, _) = w.run(&cfg).unwrap();
            let agg = stats.aggregate();
            let fp = agg.fp_intensity();
            let mem = agg.mem_intensity();
            assert!((fp - fp_ref).abs() < 0.10, "{}: fp={fp} vs {fp_ref}", w.name);
            assert!((mem - mem_ref).abs() < 0.15, "{}: mem={mem} vs {mem_ref}", w.name);
        }
    }

    #[test]
    fn vector_speedup_over_scalar() {
        let cfg = ClusterConfig::new(8, 8, 1);
        let ws = build(Variant::Scalar, &cfg, 32);
        let wv = build(Variant::VEC, &cfg, 32);
        let (ss, _) = ws.run(&cfg).unwrap();
        let (sv, _) = wv.run(&cfg).unwrap();
        let speedup = ss.total_cycles as f64 / sv.total_cycles as f64;
        assert!(speedup > 1.3 && speedup < 2.3, "vectorization speedup = {speedup}");
    }
}
