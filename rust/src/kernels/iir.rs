//! IIR — order-2 (biquad) infinite impulse response filter over an
//! N-sample stream (§5.2). The recursion `y[n] = w[n] + a1·y[n-1] +
//! a2·y[n-2]` is the parallelism-limiting data dependency the paper
//! discusses.
//!
//! * **Scalar**: two phases separated by a barrier — the feed-forward part
//!   `w[n] = b0·x[n] + b1·x[n-1] + b2·x[n-2]` is data-parallel; the
//!   feedback recursion runs *sequentially on core 0* (the "regions with
//!   sequential execution" of §5.2 that cap IIR's speed-up).
//! * **Vector**: the block formulation of recursive filters ([45]):
//!   y-pairs are produced two at a time from the transformed coefficients
//!
//!   ```text
//!   (y[n], y[n+1]) = M·(y[n-2], y[n-1]) + (w'[n], w'[n+1])
//!   ```
//!
//!   where `M` and the modified feed-forward taps are computed offline (the
//!   "algebraic transformations applied off-line" of §5.2). The recursion
//!   over pairs is still sequential — the vector IIR's parallel section is
//!   only its feed-forward phase, reproducing the paper's observation that
//!   IIR is the worst-scaling benchmark.

use super::{quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload};
use crate::config::ClusterConfig;
use crate::isa::{regs, ProgramBuilder};
use crate::runtime::{parallel_for, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{simd, FpSpec};

/// Biquad coefficients (stable low-pass; poles at 0.5 ± 0.3i).
const B: [f32; 3] = [0.2929, 0.5858, 0.2929];
const A: [f32; 2] = [1.0, -0.34]; // y += a1·y[n-1] + a2·y[n-2]

/// Build the IIR workload over `n` samples.
pub fn build(variant: Variant, cfg: &ClusterConfig, n: usize) -> Workload {
    assert!(n % 2 == 0);
    let mut w = match variant {
        Variant::Scalar | Variant::Scalar16(_) => build_scalar(SElem::of(variant), cfg, n),
        Variant::Vector(_) => build_vector(variant, cfg, n),
    };
    w.reference = reference(n);
    w
}

/// Binary64 ground truth: the direct biquad recursion.
fn reference(n: usize) -> Vec<f64> {
    let x = gen_signal(n);
    let xg = |i: i64| if i < 0 { 0.0f64 } else { x[i as usize] as f64 };
    let (b0, b1, b2) = (B[0] as f64, B[1] as f64, B[2] as f64);
    let (a1, a2) = (A[0] as f64, A[1] as f64);
    let mut out = vec![0.0f64; n];
    let (mut y1, mut y2) = (0.0f64, 0.0f64);
    for i in 0..n {
        let w = b0 * xg(i as i64) + b1 * xg(i as i64 - 1) + b2 * xg(i as i64 - 2);
        let y = w + a1 * y1 + a2 * y2;
        out[i] = y;
        y2 = y1;
        y1 = y;
    }
    out
}

fn gen_signal(n: usize) -> Vec<f32> {
    let mut rng = Rng::new(0x4949_5200); // "IIR"
    (0..n)
        .map(|i| {
            let t = i as f32 / 32.0;
            0.5 * (6.283 * t).sin() + rng.f32_in(-0.25, 0.25)
        })
        .collect()
}

fn build_scalar(elem: SElem, cfg: &ClusterConfig, n: usize) -> Workload {
    let mut al = Alloc::new(cfg);
    let x_base = elem.alloc(&mut al, n + 2); // two leading zeros (x[-1], x[-2])
    let w_base = elem.alloc(&mut al, n + 2); // two leading zeros (y[-1], y[-2] workspace)
    let y_base = elem.alloc(&mut al, n + 2);
    let c_base = elem.alloc(&mut al, 5); // b0 b1 b2 a1 a2
    let x = gen_signal(n);

    // Host mirror on register cells (element-format mul/FMA, same order).
    let mut expected = vec![0.0f64; n];
    {
        let xq = elem.quantize(&x);
        let bq = elem.quantize(&B);
        let aq = elem.quantize(&A);
        let xg = |i: i64| if i < 0 { 0u32 } else { xq[i as usize] };
        let mut w = vec![0u32; n];
        for i in 0..n {
            let mut acc = elem.mul(bq[0], xg(i as i64));
            acc = elem.fma(bq[1], xg(i as i64 - 1), acc);
            acc = elem.fma(bq[2], xg(i as i64 - 2), acc);
            w[i] = acc;
        }
        let mut y1 = 0u32;
        let mut y2 = 0u32;
        for i in 0..n {
            let mut acc = w[i];
            acc = elem.fma(aq[0], y1, acc);
            acc = elem.fma(aq[1], y2, acc);
            expected[i] = elem.to_f64(acc);
            y2 = y1;
            y1 = acc;
        }
    }

    let two = (2 * elem.size()) as u32; // byte offset of the first sample
    let id = regs::CORE_ID;
    let mut p = ProgramBuilder::new(format!("iir-{}", elem.suffix()));
    p.li(15, x_base + two).li(16, w_base + two).li(17, y_base + two);
    p.li(4, c_base);
    elem.load(&mut p, 5, 4, 0); // b0
    elem.load(&mut p, 6, 4, 1); // b1
    elem.load(&mut p, 7, 4, 2); // b2
    // Phase 1: parallel feed-forward.
    p.li(24, n as u32);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.slli(20, 13, elem.shift()).add(20, 20, 15); // &x[i]
            elem.load(p, 26, 20, 0);
            elem.load(p, 27, 20, -1);
            elem.load(p, 29, 20, -2);
            p.fmul(elem.mode, 28, 5, 26);
            p.fmac(elem.mode, 28, 6, 27);
            p.fmac(elem.mode, 28, 7, 29);
            p.slli(21, 13, elem.shift()).add(21, 21, 16);
            elem.store(p, 28, 21, 0);
        },
    );
    p.barrier();
    // Phase 2: sequential feedback on core 0 (the scaling bottleneck).
    p.bne(id, regs::ZERO, "fb_skip");
    elem.load(&mut p, 5, 4, 3); // a1
    elem.load(&mut p, 6, 4, 4); // a2
    p.li(26, 0); // y1
    p.li(27, 0); // y2
    p.mv(20, 16); // w ptr
    p.mv(21, 17); // y ptr
    p.li(19, n as u32);
    p.hwloop(19);
    elem.load_pi(&mut p, 28, 20, 1); // acc = w[i]
    p.fmac(elem.mode, 28, 5, 26); // += a1·y1
    p.fmac(elem.mode, 28, 6, 27); // += a2·y2
    p.mv(27, 26); // y2 = y1
    p.mv(26, 28); // y1 = acc
    elem.store_pi(&mut p, 28, 21, 1);
    p.hwloop_end();
    p.label("fb_skip");
    p.barrier();
    p.end();

    let mut xs = vec![0.0f32; 2];
    xs.extend(x);
    Workload {
        name: format!("IIR-{}", elem.suffix()),
        program: p.build(),
        stage: vec![
            (x_base, elem.stage(&xs)),
            (c_base, elem.stage(&[B[0], B[1], B[2], A[0], A[1]])),
        ],
        out_addr: y_base + two,
        out_len: n,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

/// Offline block transformation ([45]): express (y[2k], y[2k+1]) from
/// (y[2k-2], y[2k-1]) and the feed-forward pair.
///
/// With a1,a2 the feedback taps:
///   y[2k]   = w[2k]               + a1·y[2k-1] + a2·y[2k-2]
///   y[2k+1] = w[2k+1] + a1·y[2k]  + a2·y[2k-1]
///           = w[2k+1] + a1·w[2k] + (a1²+a2)·y[2k-1] + a1·a2·y[2k-2]
/// so the 2×2 recursion matrix over (y_prev2, y_prev1) is
///   M = [ a2      a1     ]
///       [ a1·a2   a1²+a2 ]
/// and the block feed-forward is (w[2k], w[2k+1] + a1·w[2k]).
fn block_matrix() -> [f32; 4] {
    let (a1, a2) = (A[0], A[1]);
    [a2, a1, a1 * a2, a1 * a1 + a2]
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, n: usize) -> Workload {
    let spec: &'static FpSpec = spec_of(variant);
    let mode = variant.mode();
    let mut al = Alloc::new(cfg);
    let x_base = al.halves(n + 4);
    let w_base = al.halves(n + 4); // modified feed-forward pairs
    let y_base = al.halves(n + 4);
    let c_base = al.halves(16); // packed coefficient constants
    let x = gen_signal(n);
    let xq = {
        let mut q = vec![0u16; 2];
        q.extend(quantize16(spec, &x));
        q.extend([0u16; 2]);
        q
    };
    let m = block_matrix();

    // Packed constants:
    //   word 0: (b0, b0)  word 1: (b1, b1)  word 2: (b2, b2)
    //   word 3: (a1, 0) — for w'[2k+1] = w[2k+1] + a1·w[2k]
    //   word 4: (m00, m10) column 0 of M
    //   word 5: (m01, m11) column 1 of M
    let packed_consts: Vec<u16> = {
        let q = |v: f32| spec.from_f64(v as f64);
        vec![
            q(B[0]), q(B[0]),
            q(B[1]), q(B[1]),
            q(B[2]), q(B[2]),
            q(A[0]), q(0.0),
            q(m[0]), q(m[2]),
            q(m[1]), q(m[3]),
        ]
    };

    // Host mirror (exact packed-op order).
    let mut expected = vec![0.0f64; n];
    {
        let xw: Vec<u32> = xq.chunks(2).map(|c| simd::pack2(c[0], c[1])).collect();
        let cw: Vec<u32> =
            packed_consts.chunks(2).map(|c| simd::pack2(c[0], c[1])).collect();
        // Phase 1: w pairs. Pair k covers samples (2k, 2k+1); xw[k+1] is the
        // aligned pair (x[2k], x[2k+1]) given the 2-lane zero prefix.
        let mut w = vec![0u32; n / 2];
        for k in 0..n / 2 {
            let cur = xw[k + 1];
            let prev = xw[k];
            // shifted-by-1 pair (x[2k-1], x[2k]).
            let sh1 = simd::vpack_lo(simd::vshuffle(prev, 0b11), cur);
            let mut acc = simd::vmul(spec, cw[0], cur);
            acc = simd::vmac(spec, cw[1], sh1, acc);
            acc = simd::vmac(spec, cw[2], prev, acc);
            w[k] = acc;
        }
        // Phase 2 (sequential): w' then the block recursion.
        let mut ys = 0u32; // (y_prev2, y_prev1)
        for k in 0..n / 2 {
            // w' = w + a1x·(w.lo dup in hi position): (w0, w1 + a1·w0)
            let wlo = simd::vshuffle(w[k], 0b00); // (w0, w0)
            let a1x = simd::vshuffle(cw[3], 0b01); // (0, a1)
            let wp = simd::vmac(spec, a1x, wlo, w[k]);
            // y_pair = M·ys + wp  (columns: m·ys.lo + m·ys.hi)
            let ylo = simd::vshuffle(ys, 0b00);
            let yhi = simd::vshuffle(ys, 0b11);
            let mut acc = simd::vmac(spec, cw[4], ylo, wp);
            acc = simd::vmac(spec, cw[5], yhi, acc);
            let (l0, l1) = simd::unpack2(acc);
            expected[2 * k] = spec.to_f64(l0);
            expected[2 * k + 1] = spec.to_f64(l1);
            ys = acc;
        }
    }

    let id = regs::CORE_ID;
    let mut p = ProgramBuilder::new("iir-vector");
    p.li(15, x_base).li(16, w_base).li(17, y_base);
    p.li(4, c_base);
    // Load the six packed constants into r1..r3, r5..r7.
    p.lw(1, 4, 0); // b0b0
    p.lw(2, 4, 4); // b1b1
    p.lw(3, 4, 8); // b2b2
    p.lw(5, 4, 12); // (a1, 0)
    p.lw(6, 4, 16); // M col 0
    p.lw(7, 4, 20); // M col 1
    // Phase 1: parallel feed-forward over pairs.
    p.li(24, (n / 2) as u32);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.slli(20, 13, 2).add(20, 20, 15); // &xw[k] (prev pair)
            p.lw(26, 20, 4); // cur = (x[2k], x[2k+1])
            p.lw(27, 20, 0); // prev
            p.vshuffle(8, 27, 0b11);
            p.vpack_lo(8, 8, 26); // sh1 = (x[2k-1], x[2k])
            p.fmul(mode, 28, 1, 26);
            p.fmac(mode, 28, 2, 8);
            p.fmac(mode, 28, 3, 27);
            p.slli(21, 13, 2).add(21, 21, 16);
            p.sw(28, 21, 0);
        },
    );
    p.barrier();
    // Phase 2: sequential block recursion on core 0.
    p.bne(id, regs::ZERO, "fb_skip");
    p.vshuffle(5, 5, 0b01); // a1x = (0, a1)
    p.li(26, 0); // ys = (y_prev2, y_prev1) = 0
    p.mv(20, 16); // w ptr
    p.mv(21, 17); // y ptr
    p.li(19, (n / 2) as u32);
    p.hwloop(19);
    p.lw_pi(27, 20, 4); // w pair
    p.vshuffle(28, 27, 0b00); // (w0, w0)
    p.fmac(mode, 27, 5, 28); // w' = w + (0,a1)·(w0,w0)
    p.vshuffle(28, 26, 0b00); // ylo dup
    p.vshuffle(29, 26, 0b11); // yhi dup
    p.fmac(mode, 27, 6, 28); // += M·col0
    p.fmac(mode, 27, 7, 29); // += M·col1
    p.mv(26, 27); // ys = y pair
    p.sw_pi(27, 21, 4);
    p.hwloop_end();
    p.label("fb_skip");
    p.barrier();
    p.end();

    Workload {
        name: format!("IIR-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![(x_base, Staged::U16(xq)), (c_base, Staged::U16(packed_consts))],
        out_addr: y_base,
        out_len: n,
        out_fmt: OutFmt::Pack16(spec),
        expected,
        rtol: 1e-9,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_exact() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = build(Variant::Scalar, &cfg, 64);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn vector_exact_mirror() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::VEC, &cfg, 64);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 64);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
        }
    }

    #[test]
    fn block_form_matches_direct_recursion() {
        // The offline transformation must be algebraically equivalent
        // (checked in f64 to isolate the algebra from rounding).
        let n = 32;
        let w: Vec<f64> = (0..n).map(|i| ((i * 37 % 11) as f64 - 5.0) / 7.0).collect();
        let (a1, a2) = (A[0] as f64, A[1] as f64);
        // Direct.
        let mut direct = vec![0.0f64; n];
        let (mut y1, mut y2) = (0.0, 0.0);
        for i in 0..n {
            let y = w[i] + a1 * y1 + a2 * y2;
            direct[i] = y;
            y2 = y1;
            y1 = y;
        }
        // Block (matrix in f64 — this test checks the algebra, not the f32
        // rounding of the stored coefficients).
        let m = [a2, a1, a1 * a2, a1 * a1 + a2];
        let _ = block_matrix();
        let mut blocked = vec![0.0f64; n];
        let (mut p2, mut p1) = (0.0, 0.0);
        for k in 0..n / 2 {
            let w0 = w[2 * k];
            let w1 = w[2 * k + 1] + a1 * w0;
            let y0 = w0 + m[0] * p2 + m[1] * p1;
            let y1v = w1 + m[2] * p2 + m[3] * p1;
            blocked[2 * k] = y0;
            blocked[2 * k + 1] = y1v;
            p2 = y0;
            p1 = y1v;
        }
        for i in 0..n {
            assert!((direct[i] - blocked[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn sequential_region_limits_speedup() {
        // §5.3.1: IIR's parallel speed-up is modest.
        let cfg = ClusterConfig::new(16, 16, 1);
        let w = build(Variant::Scalar, &cfg, 512);
        let (s1, _) = w.run_on(&cfg, 1).unwrap();
        let (s16, _) = w.run_on(&cfg, 16).unwrap();
        let speedup = s1.total_cycles as f64 / s16.total_cycles as f64;
        assert!(speedup > 1.2 && speedup < 8.0, "IIR speedup = {speedup}");
    }
}
