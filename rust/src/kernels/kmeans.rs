//! KMEANS — one Lloyd iteration (assignment + centroid update) over N
//! D-dimensional points with K centroids; the unsupervised classifier of
//! the paper's ExG domain (§5.2).
//!
//! The assignment phase is data-parallel over points with the centroid loop
//! fully unrolled (K accumulators, each point dimension loaded once — the
//! high FP / low memory intensity of Table 3: 0.55 / 0.36). The update
//! phase is parallel over centroids, separated by barriers, and finishes
//! with an `fdiv` per dimension on the shared DIV-SQRT block.
//!
//! * **Scalar**: `fsub` + `fmac` per (dim × centroid) in binary32.
//! * **Vector**: dimensions packed 2×16: `vfsub` + expanding `vfdotpex`
//!   per (dim-pair × centroid) with binary32 distance accumulators.

use super::{mirror, quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload};
use crate::config::ClusterConfig;
use crate::isa::{regs, Operand, ProgramBuilder};
use crate::runtime::{parallel_for, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{scalar as sfp, simd, CmpPred, FpMode, FpSpec};

/// Build the KMEANS workload: `n` points, `d` dims, `k` centroids.
/// The result buffer holds the K×D updated centroids.
pub fn build(variant: Variant, cfg: &ClusterConfig, n: usize, d: usize, k: usize) -> Workload {
    assert!(k == 4, "the kernel unrolls exactly 4 centroids (K=4)");
    assert!(d % 2 == 0);
    let mut w = match variant {
        Variant::Scalar | Variant::Scalar16(_) => build_scalar(SElem::of(variant), cfg, n, d, k),
        Variant::Vector(_) => build_vector(variant, cfg, n, d, k),
    };
    w.reference = reference(n, d, k);
    w
}

/// Binary64 ground truth: one Lloyd iteration entirely in f64 (strict `<`
/// argmin, mean update, empty clusters keep the old centroid).
fn reference(n: usize, d: usize, k: usize) -> Vec<f64> {
    let (pts, cent) = gen_inputs(n, d, k);
    let p = |i: usize, j: usize| pts[i * d + j] as f64;
    let assign: Vec<usize> = (0..n)
        .map(|i| {
            let mut best = 0usize;
            let mut bestv = f64::INFINITY;
            for c in 0..k {
                let mut acc = 0.0f64;
                for j in 0..d {
                    let diff = p(i, j) - cent[c * d + j] as f64;
                    acc += diff * diff;
                }
                if acc < bestv {
                    bestv = acc;
                    best = c;
                }
            }
            best
        })
        .collect();
    let mut out = vec![0.0f64; k * d];
    for c in 0..k {
        let members: Vec<usize> = (0..n).filter(|&i| assign[i] == c).collect();
        for j in 0..d {
            out[c * d + j] = if members.is_empty() {
                cent[c * d + j] as f64
            } else {
                members.iter().map(|&i| p(i, j)).sum::<f64>() / members.len() as f64
            };
        }
    }
    out
}

fn gen_inputs(n: usize, d: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0x4B4D_4541); // "KMEA"
    // Clustered points around k seeds.
    let seeds: Vec<Vec<f32>> = (0..k).map(|_| rng.f32_vec(d, -2.0, 2.0)).collect();
    let mut pts = Vec::with_capacity(n * d);
    for i in 0..n {
        let s = &seeds[i % k];
        for j in 0..d {
            pts.push(s[j] + rng.f32_in(-0.5, 0.5));
        }
    }
    // Initial centroids: first k points, perturbed.
    let mut cent = Vec::with_capacity(k * d);
    for c in 0..k {
        for j in 0..d {
            cent.push(pts[c * d + j] + rng.f32_in(-0.1, 0.1));
        }
    }
    (pts, cent)
}

/// Host mirror of the scalar assignment on register cells: squared
/// distances via element-format FMA in dimension order, centroids
/// unrolled; strict `<` argmin (first wins ties, quiet compares).
fn assign_scalar(elem: SElem, pts: &[u32], cent: &[u32], n: usize, d: usize, k: usize) -> Vec<usize> {
    (0..n)
        .map(|i| {
            let mut best = 0usize;
            let mut bestv = elem.q(f32::INFINITY);
            for c in 0..k {
                let acc = mirror::dist2(elem, &pts[i * d..(i + 1) * d], &cent[c * d..(c + 1) * d]);
                if elem.lt(acc, bestv) {
                    bestv = acc;
                    best = c;
                }
            }
            best
        })
        .collect()
}

/// Centroid update mirror: per-centroid sums in point order, element-format
/// adds, then one divide per dimension (empty clusters keep the old
/// centroid).
fn update_centroids(
    elem: SElem,
    pts: &[u32],
    cent: &[u32],
    assign: &[usize],
    n: usize,
    d: usize,
    k: usize,
) -> Vec<f64> {
    let mut out = vec![0.0f64; k * d];
    for c in 0..k {
        let mut count = 0i32;
        let mut sums = vec![0u32; d];
        for i in 0..n {
            if assign[i] == c {
                count += 1;
                for j in 0..d {
                    sums[j] = elem.add(sums[j], pts[i * d + j]);
                }
            }
        }
        for j in 0..d {
            out[c * d + j] = if count == 0 {
                elem.to_f64(cent[c * d + j])
            } else {
                elem.to_f64(elem.div(sums[j], elem.from_int(count)))
            };
        }
    }
    out
}

fn build_scalar(elem: SElem, cfg: &ClusterConfig, n: usize, d: usize, k: usize) -> Workload {
    let mut al = Alloc::new(cfg);
    let pts_base = elem.alloc(&mut al, n * d);
    let cent_base = elem.alloc(&mut al, k * d);
    let assign_base = al.words(n);
    let newc_base = elem.alloc(&mut al, k * d);
    let (pts, cent) = gen_inputs(n, d, k);
    let ptsq = elem.quantize(&pts);
    let centq = elem.quantize(&cent);
    let assign = assign_scalar(elem, &ptsq, &centq, n, d, k);
    let expected = update_centroids(elem, &ptsq, &centq, &assign, n, d, k);

    let (id, nc) = (regs::CORE_ID, regs::NCORES);
    let mut p = ProgramBuilder::new(format!("kmeans-{}", elem.suffix()));
    p.li(15, pts_base).li(16, cent_base).li(17, assign_base);
    // ---- Phase 1: assignment, parallel over points.
    p.li(24, n as u32);
    p.li(30, (d * elem.size() as usize) as u32); // row bytes
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.mul(20, 13, 30).add(20, 20, 15); // point ptr
            p.mv(21, 16); // centroid ptr (walks all K rows)
            p.li(5, 0).li(6, 0).li(7, 0).li(8, 0); // 4 distance accs (0.0)
            p.li(19, d as u32);
            p.hwloop(19);
            elem.load_pi(p, 26, 20, 1); // x[j] — loaded once for all 4
            elem.load(p, 27, 21, 0);
            p.fsub(elem.mode, 27, 26, 27);
            p.fmac(elem.mode, 5, 27, 27);
            elem.load(p, 27, 21, d as i32);
            p.fsub(elem.mode, 27, 26, 27);
            p.fmac(elem.mode, 6, 27, 27);
            elem.load(p, 27, 21, (2 * d) as i32);
            p.fsub(elem.mode, 27, 26, 27);
            p.fmac(elem.mode, 7, 27, 27);
            elem.load(p, 27, 21, (3 * d) as i32);
            p.fsub(elem.mode, 27, 26, 27);
            p.fmac(elem.mode, 8, 27, 27);
            p.addi(21, 21, elem.size());
            p.hwloop_end();
            // Argmin over r5..r8 (strict less-than, first wins).
            p.li(28, 0); // best index
            p.mv(29, 5); // best value
            for (c, acc) in [(1u32, 6u8), (2, 7), (3, 8)] {
                p.fcmp(elem.mode, CmpPred::Lt, 26, acc, 29);
                p.beq(26, regs::ZERO, &format!("ge{c}"));
                p.li(28, c);
                p.mv(29, acc);
                p.label(&format!("ge{c}"));
            }
            p.slli(26, 13, 2).add(26, 26, 17);
            p.sw(28, 26, 0);
        },
    );
    p.barrier();
    // ---- Phase 2: update, centroid c handled by core (c mod workers).
    p.li(24, k as u32);
    p.li(13, 0);
    p.label("upd_c");
    {
        // Does this core own centroid r13?
        p.rem(25, 13, Operand::Reg(nc));
        p.bne(25, id, "upd_next");
        // Accumulate sums for centroid r13 in a TCDM scratch row (reuse the
        // output row): zero it first.
        p.mul(22, 13, 30);
        p.li(26, newc_base);
        p.add(22, 22, 26); // out row
        p.li(19, d as u32);
        p.mv(20, 22);
        p.hwloop(19);
        elem.store_pi(&mut p, regs::ZERO, 20, 1);
        p.hwloop_end();
        p.li(27, 0); // count
        p.li(18, 0); // i
        p.li(31, n as u32);
        p.label("upd_pt");
        {
            p.slli(26, 18, 2).add(26, 26, 17);
            p.lw(26, 26, 0); // assign[i]
            p.bne(26, 13, "upd_ptnext");
            p.addi(27, 27, 1);
            p.mul(20, 18, 30).add(20, 20, 15); // point row
            p.mv(21, 22); // sums row
            p.li(19, d as u32);
            p.hwloop(19);
            elem.load_pi(&mut p, 26, 20, 1);
            elem.load(&mut p, 29, 21, 0);
            p.fadd(elem.mode, 29, 29, 26);
            elem.store_pi(&mut p, 29, 21, 1);
            p.hwloop_end();
            p.label("upd_ptnext");
            p.addi(18, 18, 1);
            p.blt(18, 31, "upd_pt");
        }
        // Divide by count (or copy the old centroid when empty).
        p.beq(27, regs::ZERO, "upd_empty");
        p.fcvt_from_int(elem.mode, 27, 27);
        p.mv(21, 22);
        p.li(19, d as u32);
        p.hwloop(19);
        elem.load(&mut p, 29, 21, 0);
        p.fdiv(elem.mode, 29, 29, 27); // shared DIV-SQRT block
        elem.store_pi(&mut p, 29, 21, 1);
        p.hwloop_end();
        p.j("upd_next");
        p.label("upd_empty");
        p.mul(20, 13, 30).add(20, 20, 16);
        p.mv(21, 22);
        p.li(19, d as u32);
        p.hwloop(19);
        elem.load_pi(&mut p, 29, 20, 1);
        elem.store_pi(&mut p, 29, 21, 1);
        p.hwloop_end();
        p.label("upd_next");
        p.addi(13, 13, 1);
        p.blt(13, 24, "upd_c");
    }
    p.barrier();
    p.end();

    Workload {
        name: format!("KMEANS-{}", elem.suffix()),
        program: p.build(),
        stage: vec![(pts_base, elem.stage(&pts)), (cent_base, elem.stage(&cent))],
        out_addr: newc_base,
        out_len: k * d,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, n: usize, d: usize, k: usize) -> Workload {
    let spec: &'static FpSpec = spec_of(variant);
    let mode = variant.mode();
    let mut al = Alloc::new(cfg);
    let pts_base = al.halves(n * d);
    let cent_base = al.halves(k * d);
    let assign_base = al.words(n);
    let newc_base = al.halves(k * d);
    let (pts, cent) = gen_inputs(n, d, k);
    let ptsq = quantize16(spec, &pts);
    let centq = quantize16(spec, &cent);

    // Mirror of the packed assignment: vfsub + vfdotpex per dim pair.
    let ptsw = super::pack_words(&ptsq);
    let centw = super::pack_words(&centq);
    let dw = d / 2;
    let assign: Vec<usize> = (0..n)
        .map(|i| {
            let mut best = 0usize;
            let mut bestv = f32::INFINITY;
            for c in 0..k {
                let mut acc = 0u32;
                for jp in 0..dw {
                    let diff =
                        simd::vsub(spec, ptsw[i * dw + jp], centw[c * dw + jp]);
                    acc = simd::vdotp_widen(spec, diff, diff, acc);
                }
                let v = f32::from_bits(acc);
                if v < bestv {
                    bestv = v;
                    best = c;
                }
            }
            best
        })
        .collect();
    // Update mirror: packed vadd sums, scalar-f32 divide per lane after
    // widening, result re-quantized.
    let expected: Vec<f64> = {
        let mut out = vec![0.0f64; k * d];
        for c in 0..k {
            let mut count = 0u32;
            let mut sums = vec![0u32; dw]; // packed 16-bit pairs
            for i in 0..n {
                if assign[i] == c {
                    count += 1;
                    for jp in 0..dw {
                        sums[jp] = simd::vadd(spec, sums[jp], ptsw[i * dw + jp]);
                    }
                }
            }
            for jp in 0..dw {
                let (lo, hi) = simd::unpack2(sums[jp]);
                for (lane, bits) in [(0usize, lo), (1, hi)] {
                    let j = 2 * jp + lane;
                    out[c * d + j] = if count == 0 {
                        spec.to_f64(centq[c * d + j])
                    } else {
                        // fdiv in the 16-bit format (DIV-SQRT block).
                        let cnt16 = spec.from_f64(count as f64);
                        spec.to_f64(sfp::div16(spec, bits, cnt16))
                    };
                }
            }
        }
        out
    };

    let (id, nc) = (regs::CORE_ID, regs::NCORES);
    let mut p = ProgramBuilder::new("kmeans-vector");
    p.li(15, pts_base).li(16, cent_base).li(17, assign_base);
    p.li(24, n as u32);
    p.li(30, (dw * 4) as u32); // packed row bytes
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.mul(20, 13, 30).add(20, 20, 15);
            p.mv(21, 16);
            p.li(5, 0).li(6, 0).li(7, 0).li(8, 0); // f32 distance accs
            p.li(19, dw as u32);
            p.hwloop(19);
            p.lw_pi(26, 20, 4); // point dim pair
            p.lw(27, 21, 0);
            p.fsub(mode, 27, 26, 27);
            p.fdotp(mode, 5, 27, 27);
            p.lw(27, 21, (dw * 4) as i32);
            p.fsub(mode, 27, 26, 27);
            p.fdotp(mode, 6, 27, 27);
            p.lw(27, 21, (2 * dw * 4) as i32);
            p.fsub(mode, 27, 26, 27);
            p.fdotp(mode, 7, 27, 27);
            p.lw(27, 21, (3 * dw * 4) as i32);
            p.fsub(mode, 27, 26, 27);
            p.fdotp(mode, 8, 27, 27);
            p.addi(21, 21, 4);
            p.hwloop_end();
            p.li(28, 0);
            p.mv(29, 5);
            for (c, acc) in [(1u32, 6u8), (2, 7), (3, 8)] {
                p.fcmp(FpMode::F32, CmpPred::Lt, 26, acc, 29);
                p.beq(26, regs::ZERO, &format!("ge{c}"));
                p.li(28, c);
                p.mv(29, acc);
                p.label(&format!("ge{c}"));
            }
            p.slli(26, 13, 2).add(26, 26, 17);
            p.sw(28, 26, 0);
        },
    );
    p.barrier();
    // Update phase: centroid per core, packed sums, 16-bit divides.
    p.li(24, k as u32);
    p.li(13, 0);
    p.label("upd_c");
    {
        p.rem(25, 13, Operand::Reg(nc));
        p.bne(25, id, "upd_next");
        p.mul(22, 13, 30);
        p.li(26, newc_base);
        p.add(22, 22, 26);
        p.li(19, dw as u32);
        p.mv(20, 22);
        p.hwloop(19);
        p.sw_pi(regs::ZERO, 20, 4);
        p.hwloop_end();
        p.li(27, 0);
        p.li(18, 0);
        p.li(31, n as u32);
        p.label("upd_pt");
        {
            p.slli(26, 18, 2).add(26, 26, 17);
            p.lw(26, 26, 0);
            p.bne(26, 13, "upd_ptnext");
            p.addi(27, 27, 1);
            p.mul(20, 18, 30).add(20, 20, 15);
            p.mv(21, 22);
            p.li(19, dw as u32);
            p.hwloop(19);
            p.lw_pi(26, 20, 4);
            p.lw(29, 21, 0);
            p.fadd(mode, 29, 29, 26);
            p.sw_pi(29, 21, 4);
            p.hwloop_end();
            p.label("upd_ptnext");
            p.addi(18, 18, 1);
            p.blt(18, 31, "upd_pt");
        }
        p.beq(27, regs::ZERO, "upd_empty");
        // count as a 16-bit scalar for the lane-wise divide.
        p.fcvt_from_int(
            if spec.exp_bits == 5 { FpMode::F16 } else { FpMode::Bf16 },
            27,
            27,
        );
        p.mv(21, 22);
        p.li(19, d as u32); // per-lane halfword divides
        p.hwloop(19);
        p.lh(29, 21, 0);
        p.fdiv(
            if spec.exp_bits == 5 { FpMode::F16 } else { FpMode::Bf16 },
            29,
            29,
            27,
        );
        p.sh(29, 21, 0);
        p.addi(21, 21, 2);
        p.hwloop_end();
        p.j("upd_next");
        p.label("upd_empty");
        p.mul(20, 13, 30).add(20, 20, 16);
        p.mv(21, 22);
        p.li(19, dw as u32);
        p.hwloop(19);
        p.lw_pi(29, 20, 4);
        p.sw_pi(29, 21, 4);
        p.hwloop_end();
        p.label("upd_next");
        p.addi(13, 13, 1);
        p.blt(13, 24, "upd_c");
    }
    p.barrier();
    p.end();

    Workload {
        name: format!("KMEANS-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![(pts_base, Staged::U16(ptsq)), (cent_base, Staged::U16(centq))],
        out_addr: newc_base,
        out_len: k * d,
        out_fmt: OutFmt::Pack16(spec),
        expected,
        rtol: 1e-9,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_exact() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = build(Variant::Scalar, &cfg, 64, 8, 4);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
        let (_, o1) = w.run_on(&cfg, 1).unwrap();
        w.verify(&o1).unwrap();
    }

    #[test]
    fn vector_exact() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::VEC, &cfg, 64, 8, 4);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 64, 8, 4);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
        }
    }

    #[test]
    fn assignment_separates_clusters() {
        // The synthetic data is built from 4 seeds; the assignment must
        // recover a non-trivial partition (all 4 clusters populated).
        let elem = SElem::of(Variant::Scalar);
        let (pts, cent) = gen_inputs(128, 8, 4);
        let assign = assign_scalar(elem, &elem.quantize(&pts), &elem.quantize(&cent), 128, 8, 4);
        for c in 0..4 {
            assert!(assign.iter().filter(|&&a| a == c).count() > 8, "cluster {c} starved");
        }
    }

    #[test]
    fn uses_shared_divsqrt() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::Scalar, &cfg, 64, 8, 4);
        let mut cl = crate::cluster::Cluster::new(cfg, w.program.clone());
        w.stage_into(&mut cl.mem);
        cl.run().unwrap();
        assert!(cl.fpus.divsqrt_ops >= 32, "centroid update must use fdiv");
    }
}
