//! The eight near-sensor benchmarks of Table 3 — CONV, DWT, FFT, FIR, IIR,
//! KMEANS, MATMUL, SVM — each in a scalar-`float` and a packed-SIMD
//! 2×16-bit vector variant, written in the Xpulp-style ISA DSL with the
//! paper's parallelization strategy (§5.2):
//!
//! * data parallelism on the outer loops for CONV / FIR / MATMUL;
//! * stage-level parallelism with barriers for DWT / FFT / KMEANS / SVM;
//! * block-formulation recursion ([45]) for the vector IIR.
//!
//! Each builder returns a [`Workload`]: the SPMD program, the data to stage
//! into TCDM, and a host-computed golden output (from the *staged*, i.e.
//! already-quantized, inputs) with a variant-appropriate tolerance.

pub mod conv;
pub mod dwt;
pub mod fft;
pub mod fir;
pub mod iir;
pub mod kmeans;
pub mod matmul;
pub mod svm;

use crate::cluster::counters::RunStats;
use crate::cluster::mem::{Memory, TCDM_BASE};
use crate::cluster::{Cluster, Engine};
use crate::config::ClusterConfig;
use crate::isa::Program;
use crate::transfp::{simd, FpMode, FpSpec, BF16, F16};

/// Benchmark variant: scalar binary32 or packed-SIMD 2×16.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// `float` scalars.
    Scalar,
    /// 2×16-bit vectors in the given mode (`VecF16` or `VecBf16`). The paper
    /// reports a single number for both 16-bit formats (§5.2) — we support
    /// both and default to `VecF16`.
    Vector(FpMode),
}

impl Variant {
    /// Canonical vector variant used in the tables.
    pub const VEC: Variant = Variant::Vector(FpMode::VecF16);

    /// Short label (`scalar` / `vector`).
    pub fn label(&self) -> &'static str {
        match self {
            Variant::Scalar => "scalar",
            Variant::Vector(_) => "vector",
        }
    }

    /// The 16-bit spec for vector variants.
    pub fn spec(&self) -> Option<&'static FpSpec> {
        match self {
            Variant::Scalar => None,
            Variant::Vector(m) => m.spec(),
        }
    }

    /// The SIMD mode (F32 for scalar).
    pub fn mode(&self) -> FpMode {
        match self {
            Variant::Scalar => FpMode::F32,
            Variant::Vector(m) => *m,
        }
    }
}

/// Data staged into memory before a run.
#[derive(Debug, Clone)]
pub enum Staged {
    F32(Vec<f32>),
    U16(Vec<u16>),
    U32(Vec<u32>),
}

/// Output format of a workload's result buffer.
#[derive(Debug, Clone, Copy)]
pub enum OutFmt {
    /// binary32 words.
    F32,
    /// Packed 16-bit lanes in `spec`.
    Pack16(&'static FpSpec),
}

/// A runnable benchmark instance.
pub struct Workload {
    /// `<benchmark>-<variant>`.
    pub name: String,
    /// SPMD program.
    pub program: Program,
    /// (address, data) pairs written to TCDM before the run.
    pub stage: Vec<(u32, Staged)>,
    /// Result buffer address.
    pub out_addr: u32,
    /// Result length in elements.
    pub out_len: usize,
    /// Result element format.
    pub out_fmt: OutFmt,
    /// Golden output (computed on the host from the staged inputs).
    pub expected: Vec<f64>,
    /// Relative tolerance for verification.
    pub rtol: f64,
    /// Absolute tolerance floor.
    pub atol: f64,
}

impl Workload {
    /// Write the staged inputs into `mem`.
    pub fn stage_into(&self, mem: &mut Memory) {
        for (addr, data) in &self.stage {
            match data {
                Staged::F32(v) => mem.write_f32_slice(*addr, v),
                Staged::U16(v) => mem.write_u16_slice(*addr, v),
                Staged::U32(v) => mem.write_u32_slice(*addr, v),
            }
        }
    }

    /// Read the result buffer as f64 values.
    pub fn read_output(&self, mem: &Memory) -> Vec<f64> {
        match self.out_fmt {
            OutFmt::F32 => {
                mem.read_f32_slice(self.out_addr, self.out_len).iter().map(|&x| x as f64).collect()
            }
            OutFmt::Pack16(spec) => mem
                .read_u16_slice(self.out_addr, self.out_len)
                .iter()
                .map(|&b| spec.to_f64(b))
                .collect(),
        }
    }

    /// Run on `cfg` with all cores; returns (stats, outputs).
    pub fn run(&self, cfg: &ClusterConfig) -> (RunStats, Vec<f64>) {
        self.run_on(cfg, cfg.cores)
    }

    /// Run with only the first `workers` cores active (Fig 6 sweeps).
    pub fn run_on(&self, cfg: &ClusterConfig, workers: usize) -> (RunStats, Vec<f64>) {
        self.run_with(cfg, workers, Engine::Event)
    }

    /// Run on the selected issue engine (the differential harness compares
    /// [`Engine::Event`] against [`Engine::Reference`] cycle-for-cycle).
    pub fn run_with(
        &self,
        cfg: &ClusterConfig,
        workers: usize,
        engine: Engine,
    ) -> (RunStats, Vec<f64>) {
        let mut cl = Cluster::new(*cfg, self.program.clone());
        self.run_in_with(&mut cl, workers, engine)
    }

    /// Run inside an existing cluster built from this workload's program,
    /// resetting it first — sweeps and benches reuse the cluster's
    /// allocations (TCDM, I$, decoded program) across repetitions instead
    /// of rebuilding `Memory`/cores per run.
    pub fn run_in(&self, cl: &mut Cluster, workers: usize) -> (RunStats, Vec<f64>) {
        self.run_in_with(cl, workers, Engine::Event)
    }

    /// [`Self::run_in`] with an explicit engine.
    pub fn run_in_with(
        &self,
        cl: &mut Cluster,
        workers: usize,
        engine: Engine,
    ) -> (RunStats, Vec<f64>) {
        assert_eq!(
            (cl.program().name.as_str(), cl.program().len()),
            (self.program.name.as_str(), self.program.len()),
            "run_in: cluster was built for a different program than this workload"
        );
        debug_assert_eq!(
            cl.program().insns,
            self.program.insns,
            "run_in: cluster program diverges from this workload's program"
        );
        cl.reset();
        cl.limit_active_cores(workers);
        self.stage_into(&mut cl.mem);
        let stats = cl.run_with(engine);
        let out = self.read_output(&cl.mem);
        (stats, out)
    }

    /// Verify `outputs` against the golden values.
    pub fn verify(&self, outputs: &[f64]) -> Result<(), String> {
        if outputs.len() != self.expected.len() {
            return Err(format!(
                "{}: output length {} != expected {}",
                self.name,
                outputs.len(),
                self.expected.len()
            ));
        }
        for (i, (o, e)) in outputs.iter().zip(&self.expected).enumerate() {
            let tol = self.atol + self.rtol * e.abs();
            if (o - e).abs() > tol {
                return Err(format!(
                    "{}: mismatch at {i}: got {o}, expected {e} (|diff|={}, tol={tol})",
                    self.name,
                    (o - e).abs()
                ));
            }
        }
        Ok(())
    }
}

/// Bump allocator over the TCDM for kernel buffer layout.
pub struct Alloc {
    next: u32,
    limit: u32,
}

impl Alloc {
    /// Allocator over the TCDM of `cfg`.
    pub fn new(cfg: &ClusterConfig) -> Self {
        Alloc { next: TCDM_BASE, limit: TCDM_BASE + cfg.tcdm_bytes() as u32 }
    }

    /// Allocate `words` 32-bit words; returns the base address.
    pub fn words(&mut self, words: usize) -> u32 {
        let addr = self.next;
        self.next += (words * 4) as u32;
        assert!(self.next <= self.limit, "TCDM overflow: kernel working set too large");
        addr
    }

    /// Allocate room for `n` f32 elements.
    pub fn f32s(&mut self, n: usize) -> u32 {
        self.words(n)
    }

    /// Allocate room for `n` 16-bit lanes (packed two per word, rounded up).
    pub fn halves(&mut self, n: usize) -> u32 {
        self.words(n.div_ceil(2))
    }
}

/// Quantize f32 samples to 16-bit lanes of `spec`.
pub fn quantize16(spec: &FpSpec, data: &[f32]) -> Vec<u16> {
    data.iter().map(|&x| spec.from_f64(x as f64)).collect()
}

/// Dequantized view (the values the vector kernels actually compute on).
pub fn dequant(spec: &FpSpec, q: &[u16]) -> Vec<f64> {
    q.iter().map(|&b| spec.to_f64(b)).collect()
}

/// Pack 16-bit lanes into words (lane 2i → low half of word i).
pub fn pack_words(q: &[u16]) -> Vec<u32> {
    q.chunks(2).map(|c| simd::pack2(c[0], *c.get(1).unwrap_or(&0))).collect()
}

/// The benchmark suite of Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    Conv,
    Dwt,
    Fft,
    Fir,
    Iir,
    Kmeans,
    Matmul,
    Svm,
}

impl Benchmark {
    /// All benchmarks, in Table 3 order.
    pub fn all() -> [Benchmark; 8] {
        use Benchmark::*;
        [Conv, Dwt, Fft, Fir, Iir, Kmeans, Matmul, Svm]
    }

    /// Upper-case name as used in the tables.
    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::Conv => "CONV",
            Benchmark::Dwt => "DWT",
            Benchmark::Fft => "FFT",
            Benchmark::Fir => "FIR",
            Benchmark::Iir => "IIR",
            Benchmark::Kmeans => "KMEANS",
            Benchmark::Matmul => "MATMUL",
            Benchmark::Svm => "SVM",
        }
    }

    /// Parse a table name.
    pub fn parse(s: &str) -> Option<Benchmark> {
        Benchmark::all().into_iter().find(|b| b.name().eq_ignore_ascii_case(s))
    }

    /// Build the default-size workload for `variant` on a cluster config.
    /// Sizes are chosen from the paper's near-sensor domains (§5.2) and fit
    /// the 64 kB TCDM of the 8-core cluster.
    pub fn build(&self, variant: Variant, cfg: &ClusterConfig) -> Workload {
        match self {
            Benchmark::Conv => conv::build(variant, cfg, 32, 32),
            Benchmark::Dwt => dwt::build(variant, cfg, 512, 3),
            Benchmark::Fft => fft::build(variant, cfg, 256),
            Benchmark::Fir => fir::build(variant, cfg, 512, 32),
            Benchmark::Iir => iir::build(variant, cfg, 512),
            Benchmark::Kmeans => kmeans::build(variant, cfg, 256, 16, 4),
            Benchmark::Matmul => matmul::build(variant, cfg, 32),
            Benchmark::Svm => svm::build(variant, cfg, 64, 32),
        }
    }

    /// Paper Table 3 FP / memory intensity, for validation.
    pub fn table3_intensity(&self, variant: Variant) -> (f64, f64) {
        let scalar = matches!(variant, Variant::Scalar);
        match (self, scalar) {
            (Benchmark::Conv, true) => (0.33, 0.67),
            (Benchmark::Conv, false) => (0.28, 0.29),
            (Benchmark::Dwt, true) => (0.29, 0.59),
            (Benchmark::Dwt, false) => (0.21, 0.57),
            (Benchmark::Fft, true) => (0.32, 0.52),
            (Benchmark::Fft, false) => (0.26, 0.38),
            (Benchmark::Fir, true) => (0.32, 0.65),
            (Benchmark::Fir, false) => (0.32, 0.48),
            (Benchmark::Iir, true) => (0.19, 0.55),
            (Benchmark::Iir, false) => (0.17, 0.33),
            (Benchmark::Kmeans, true) => (0.55, 0.36),
            (Benchmark::Kmeans, false) => (0.44, 0.30),
            (Benchmark::Matmul, true) => (0.28, 0.58),
            (Benchmark::Matmul, false) => (0.27, 0.41),
            (Benchmark::Svm, true) => (0.27, 0.53),
            (Benchmark::Svm, false) => (0.21, 0.52),
        }
    }
}

/// 16-bit spec for a variant, defaulting to binary16.
pub fn spec_of(variant: Variant) -> &'static FpSpec {
    variant.spec().unwrap_or(&F16)
}

/// Both 16-bit formats (the tables report one number for both).
pub fn both_specs() -> [&'static FpSpec; 2] {
    [&F16, &BF16]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmark_names_roundtrip() {
        for b in Benchmark::all() {
            assert_eq!(Benchmark::parse(b.name()), Some(b));
        }
        assert_eq!(Benchmark::parse("nope"), None);
    }

    #[test]
    fn alloc_bumps_and_checks() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let mut a = Alloc::new(&cfg);
        let p1 = a.f32s(16);
        let p2 = a.halves(7); // 4 words
        let p3 = a.words(1);
        assert_eq!(p1, TCDM_BASE);
        assert_eq!(p2, TCDM_BASE + 64);
        assert_eq!(p3, TCDM_BASE + 64 + 16);
    }

    #[test]
    #[should_panic(expected = "TCDM overflow")]
    fn alloc_overflow_panics() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let mut a = Alloc::new(&cfg);
        a.words(64 * 1024); // 256 kB > 64 kB
    }

    #[test]
    fn quantize_pack_roundtrip() {
        let data = [1.0f32, -2.5, 0.1, 3.75, 9.0];
        let q = quantize16(&F16, &data);
        assert_eq!(q.len(), 5);
        let w = pack_words(&q);
        assert_eq!(w.len(), 3);
        let d = dequant(&F16, &q);
        assert_eq!(d[0], 1.0);
        assert_eq!(d[1], -2.5);
        assert!((d[2] - 0.1).abs() < 1e-3);
    }
}
