//! The eight near-sensor benchmarks of Table 3 — CONV, DWT, FFT, FIR, IIR,
//! KMEANS, MATMUL, SVM — each in a scalar-`float` and a packed-SIMD
//! 2×16-bit vector variant, written in the Xpulp-style ISA DSL with the
//! paper's parallelization strategy (§5.2):
//!
//! * data parallelism on the outer loops for CONV / FIR / MATMUL;
//! * stage-level parallelism with barriers for DWT / FFT / KMEANS / SVM;
//! * block-formulation recursion ([45]) for the vector IIR.
//!
//! Each builder returns a [`Workload`]: the SPMD program, the data to stage
//! into TCDM, and a host-computed golden output (from the *staged*, i.e.
//! already-quantized, inputs) with a variant-appropriate tolerance.

pub mod conv;
pub mod dwt;
pub mod fft;
pub mod fir;
pub mod iir;
pub mod kmeans;
pub mod matmul;
pub mod mirror;
pub mod svm;

use crate::cluster::backend::{BackendRun, EventBackend, ExecBackend, ReferenceBackend, RunError};
use crate::cluster::counters::RunStats;
use crate::cluster::mem::{Memory, TCDM_BASE};
use crate::cluster::{Cluster, CodeCache, CompiledBackend, Engine, FunctionalBackend};
use crate::config::ClusterConfig;
use crate::isa::{Program, ProgramBuilder, Reg};
use crate::transfp::{cast, scalar, simd, CmpPred, FpMode, FpSpec, BF16, F16};

/// Benchmark variant: one rung of the per-kernel precision ladder —
/// binary32 scalar, 16-bit scalar (`F16`/`Bf16`), or packed-SIMD 2×16.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// `float` scalars.
    Scalar,
    /// 16-bit *scalar* rungs (`FpMode::F16` or `FpMode::Bf16`): the same
    /// program structure as `Scalar`, but with halfword memory traffic and
    /// the FPnew 16-bit scalar datapath — the intermediate step of the
    /// transprecision ladder between binary32 and packed-SIMD.
    Scalar16(FpMode),
    /// 2×16-bit vectors in the given mode (`VecF16` or `VecBf16`). The paper
    /// reports a single number for both 16-bit formats (§5.2) — we support
    /// both and default to `VecF16`.
    Vector(FpMode),
}

impl Variant {
    /// Canonical vector variant used in the tables.
    pub const VEC: Variant = Variant::Vector(FpMode::VecF16);
    /// binary16 scalar rung.
    pub const SCALAR_F16: Variant = Variant::Scalar16(FpMode::F16);
    /// bfloat16 scalar rung.
    pub const SCALAR_BF16: Variant = Variant::Scalar16(FpMode::Bf16);

    /// Every buildable variant, in precision-ladder order (full binary32
    /// first, then scalar-16, then packed-16 — see `tuner::ladder`).
    pub fn all() -> [Variant; 5] {
        [
            Variant::Scalar,
            Variant::SCALAR_F16,
            Variant::SCALAR_BF16,
            Variant::Vector(FpMode::VecF16),
            Variant::Vector(FpMode::VecBf16),
        ]
    }

    /// Distinct, stable per-variant label used in CSV rows, reports and
    /// cache rows. Every buildable variant maps to a unique string (locked
    /// by the `labels_are_distinct_and_stable` test) so scalar-16 rungs
    /// never alias `scalar`, and the two vector formats never alias each
    /// other.
    pub fn label(&self) -> &'static str {
        match self {
            Variant::Scalar => "scalar",
            Variant::Scalar16(FpMode::F16) => "scalar-f16",
            Variant::Scalar16(FpMode::Bf16) => "scalar-bf16",
            // Degenerate modes no kernel builds; named for totality.
            Variant::Scalar16(_) => "scalar-16-invalid",
            Variant::Vector(FpMode::VecF16) => "vector-f16",
            Variant::Vector(FpMode::VecBf16) => "vector-bf16",
            Variant::Vector(_) => "vector-invalid",
        }
    }

    /// Parse a [`Variant::label`] back (buildable variants only).
    pub fn parse_label(s: &str) -> Option<Variant> {
        Variant::all().into_iter().find(|v| v.label() == s)
    }

    /// The 16-bit spec for 16-bit variants (scalar or vector).
    pub fn spec(&self) -> Option<&'static FpSpec> {
        match self {
            Variant::Scalar => None,
            Variant::Scalar16(m) | Variant::Vector(m) => m.spec(),
        }
    }

    /// The FP mode (F32 for the binary32 scalar).
    pub fn mode(&self) -> FpMode {
        match self {
            Variant::Scalar => FpMode::F32,
            Variant::Scalar16(m) | Variant::Vector(m) => *m,
        }
    }

    /// True for the rungs below full binary32 (anything the tuner may
    /// descend to).
    pub fn is_sub_f32(&self) -> bool {
        !matches!(self, Variant::Scalar)
    }
}

/// Data staged into memory before a run.
#[derive(Debug, Clone)]
pub enum Staged {
    F32(Vec<f32>),
    U16(Vec<u16>),
    U32(Vec<u32>),
}

/// Output format of a workload's result buffer.
#[derive(Debug, Clone, Copy)]
pub enum OutFmt {
    /// binary32 words.
    F32,
    /// Packed 16-bit lanes in `spec`.
    Pack16(&'static FpSpec),
}

/// A runnable benchmark instance.
pub struct Workload {
    /// `<benchmark>-<variant>`.
    pub name: String,
    /// SPMD program.
    pub program: Program,
    /// (address, data) pairs written to TCDM before the run.
    pub stage: Vec<(u32, Staged)>,
    /// Result buffer address.
    pub out_addr: u32,
    /// Result length in elements.
    pub out_len: usize,
    /// Result element format.
    pub out_fmt: OutFmt,
    /// Golden output (computed on the host from the staged inputs).
    pub expected: Vec<f64>,
    /// Relative tolerance for verification.
    pub rtol: f64,
    /// Absolute tolerance floor.
    pub atol: f64,
    /// Ground-truth output computed on the host in **binary64** from the
    /// original (un-quantized) f32 inputs — identical for every variant of
    /// a benchmark. This is the accuracy baseline the tuner measures each
    /// precision rung against (`tuner::accuracy`), as opposed to
    /// `expected`, which mirrors the variant's own arithmetic bit-exactly.
    pub reference: Vec<f64>,
}

impl Workload {
    /// Write the staged inputs into `mem`.
    pub fn stage_into(&self, mem: &mut Memory) {
        for (addr, data) in &self.stage {
            match data {
                Staged::F32(v) => mem.write_f32_slice(*addr, v),
                Staged::U16(v) => mem.write_u16_slice(*addr, v),
                Staged::U32(v) => mem.write_u32_slice(*addr, v),
            }
        }
    }

    /// Read the result buffer as f64 values.
    pub fn read_output(&self, mem: &Memory) -> Vec<f64> {
        match self.out_fmt {
            OutFmt::F32 => {
                mem.read_f32_slice(self.out_addr, self.out_len).iter().map(|&x| x as f64).collect()
            }
            OutFmt::Pack16(spec) => mem
                .read_u16_slice(self.out_addr, self.out_len)
                .iter()
                .map(|&b| spec.to_f64(b))
                .collect(),
        }
    }

    /// Run on `cfg` with all cores; returns (stats, outputs). A run that
    /// cannot terminate (hang, deadlock, architectural fault) comes back as
    /// a structured [`RunError`] instead of a panic.
    pub fn run(&self, cfg: &ClusterConfig) -> Result<(RunStats, Vec<f64>), RunError> {
        self.run_on(cfg, cfg.cores)
    }

    /// Run with only the first `workers` cores active (Fig 6 sweeps).
    pub fn run_on(
        &self,
        cfg: &ClusterConfig,
        workers: usize,
    ) -> Result<(RunStats, Vec<f64>), RunError> {
        self.run_with(cfg, workers, Engine::Event)
    }

    /// Run on the selected issue engine (the differential harness compares
    /// [`Engine::Event`] against [`Engine::Reference`] cycle-for-cycle).
    /// Routed through the [`ExecBackend`] tier like every golden run.
    pub fn run_with(
        &self,
        cfg: &ClusterConfig,
        workers: usize,
        engine: Engine,
    ) -> Result<(RunStats, Vec<f64>), RunError> {
        let backend: &dyn ExecBackend = match engine {
            Engine::Event => &EventBackend,
            Engine::Reference => &ReferenceBackend,
        };
        let (run, out) = self.run_on_backend(cfg, workers, backend)?;
        Ok((run.stats.expect("cycle-accurate backend returns stats"), out))
    }

    /// Run on any execution backend: stage, execute, read the output
    /// window. This is the single seam every golden/measurement run goes
    /// through — the backend decides whether time is modelled at all.
    pub fn run_on_backend(
        &self,
        cfg: &ClusterConfig,
        workers: usize,
        backend: &dyn ExecBackend,
    ) -> Result<(BackendRun, Vec<f64>), RunError> {
        let run =
            backend.run_program(cfg, &self.program, workers, &mut |mem| self.stage_into(mem))?;
        let out = self.read_output(&run.mem);
        Ok((run, out))
    }

    /// Architectural-only run on the [`FunctionalBackend`]: returns the
    /// retired-instruction count and the outputs. This is what the tuner's
    /// accuracy probes and the accuracy-only query fidelity execute.
    pub fn run_functional(
        &self,
        cfg: &ClusterConfig,
        workers: usize,
    ) -> Result<(u64, Vec<f64>), RunError> {
        let (run, out) = self.run_on_backend(cfg, workers, &FunctionalBackend)?;
        Ok((run.instrs, out))
    }

    /// Architectural-only run on the [`CompiledBackend`], translating
    /// through `cache` so repeated runs of the same program reuse one
    /// [`CompiledProgram`](crate::cluster::compiled::CompiledProgram).
    /// The compiled analogue of [`Self::run_functional`].
    pub fn run_compiled(
        &self,
        cfg: &ClusterConfig,
        workers: usize,
        cache: &std::sync::Arc<CodeCache>,
    ) -> Result<(u64, Vec<f64>), RunError> {
        let backend = CompiledBackend::with_cache(std::sync::Arc::clone(cache));
        let (run, out) = self.run_on_backend(cfg, workers, &backend)?;
        Ok((run.instrs, out))
    }

    /// Run inside an existing cluster built from this workload's program,
    /// resetting it first — sweeps and benches reuse the cluster's
    /// allocations (TCDM, I$, decoded program) across repetitions instead
    /// of rebuilding `Memory`/cores per run.
    pub fn run_in(
        &self,
        cl: &mut Cluster,
        workers: usize,
    ) -> Result<(RunStats, Vec<f64>), RunError> {
        self.run_in_with(cl, workers, Engine::Event)
    }

    /// [`Self::run_in`] with an explicit engine.
    pub fn run_in_with(
        &self,
        cl: &mut Cluster,
        workers: usize,
        engine: Engine,
    ) -> Result<(RunStats, Vec<f64>), RunError> {
        assert_eq!(
            (cl.program().name.as_str(), cl.program().len()),
            (self.program.name.as_str(), self.program.len()),
            "run_in: cluster was built for a different program than this workload"
        );
        debug_assert_eq!(
            cl.program().insns,
            self.program.insns,
            "run_in: cluster program diverges from this workload's program"
        );
        cl.reset();
        cl.limit_active_cores(workers);
        self.stage_into(&mut cl.mem);
        let stats = cl.run_with(engine)?;
        let out = self.read_output(&cl.mem);
        Ok((stats, out))
    }

    /// Run with a cycle-attribution tracer attached ([`crate::trace`]):
    /// returns the stats, the outputs, and the detached tracer holding the
    /// trace database and region attribution state. `transpfp trace` and
    /// the serve `trace` endpoint route through this.
    pub fn run_traced(
        &self,
        cfg: &ClusterConfig,
        workers: usize,
        engine: Engine,
        tcfg: crate::trace::TraceConfig,
    ) -> Result<(RunStats, Vec<f64>, Box<crate::trace::Tracer>), RunError> {
        let mut cl = Cluster::new(*cfg, self.program.clone());
        cl.attach_tracer(tcfg);
        let (stats, out) = self.run_in_with(&mut cl, workers, engine)?;
        let tracer = cl.take_tracer().expect("tracer attached above");
        Ok((stats, out, tracer))
    }

    /// Verify `outputs` against the golden values.
    pub fn verify(&self, outputs: &[f64]) -> Result<(), String> {
        if outputs.len() != self.expected.len() {
            return Err(format!(
                "{}: output length {} != expected {}",
                self.name,
                outputs.len(),
                self.expected.len()
            ));
        }
        for (i, (o, e)) in outputs.iter().zip(&self.expected).enumerate() {
            let tol = self.atol + self.rtol * e.abs();
            if (o - e).abs() > tol {
                return Err(format!(
                    "{}: mismatch at {i}: got {o}, expected {e} (|diff|={}, tol={tol})",
                    self.name,
                    (o - e).abs()
                ));
            }
        }
        Ok(())
    }
}

/// Bump allocator over the TCDM for kernel buffer layout.
pub struct Alloc {
    next: u32,
    limit: u32,
}

impl Alloc {
    /// Allocator over the TCDM of `cfg`.
    pub fn new(cfg: &ClusterConfig) -> Self {
        Alloc { next: TCDM_BASE, limit: TCDM_BASE + cfg.tcdm_bytes() as u32 }
    }

    /// Allocate `words` 32-bit words; returns the base address.
    pub fn words(&mut self, words: usize) -> u32 {
        let addr = self.next;
        self.next += (words * 4) as u32;
        assert!(self.next <= self.limit, "TCDM overflow: kernel working set too large");
        addr
    }

    /// Allocate room for `n` f32 elements.
    pub fn f32s(&mut self, n: usize) -> u32 {
        self.words(n)
    }

    /// Allocate room for `n` 16-bit lanes (packed two per word, rounded up).
    pub fn halves(&mut self, n: usize) -> u32 {
        self.words(n.div_ceil(2))
    }
}

/// Scalar element descriptor shared by the parametric scalar kernel
/// builders — the `F32 → scalar-16` rungs of the precision ladder. The
/// binary32 instantiation uses word memory accesses and the native-f32
/// datapath; the scalar-16 instantiations use halfword accesses (values in
/// lane 0 of the 32-bit register, like the hardware) and the 16-bit scalar
/// ops of [`crate::transfp::scalar`]. Host-mirror arithmetic runs on raw
/// `u32` register cells, so the F32 instantiation reproduces the
/// pre-ladder f32 mirrors bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct SElem {
    /// FP mode of every arithmetic instruction the builder emits
    /// (`F32`, `F16` or `Bf16`).
    pub mode: FpMode,
}

impl SElem {
    /// Descriptor for a scalar variant (panics on vector variants).
    pub fn of(variant: Variant) -> SElem {
        match variant {
            Variant::Scalar => SElem { mode: FpMode::F32 },
            Variant::Scalar16(m) => {
                assert!(
                    matches!(m, FpMode::F16 | FpMode::Bf16),
                    "Scalar16 requires a 16-bit scalar mode, got {m:?}"
                );
                SElem { mode: m }
            }
            Variant::Vector(_) => panic!("SElem describes scalar variants only"),
        }
    }

    /// The 16-bit spec (None for binary32).
    pub fn spec(&self) -> Option<&'static FpSpec> {
        self.mode.spec()
    }

    /// Element size in bytes (4 or 2).
    pub fn size(&self) -> i32 {
        match self.spec() {
            None => 4,
            Some(_) => 2,
        }
    }

    /// log2 of the element size — the shift for element-index → byte-offset
    /// address arithmetic.
    pub fn shift(&self) -> i32 {
        match self.spec() {
            None => 2,
            Some(_) => 1,
        }
    }

    /// Allocate room for `n` elements in the TCDM.
    pub fn alloc(&self, al: &mut Alloc, n: usize) -> u32 {
        match self.spec() {
            None => al.f32s(n),
            Some(_) => al.halves(n),
        }
    }

    /// Variant label suffix used in workload names (`scalar`,
    /// `scalar-f16`, `scalar-bf16`).
    pub fn suffix(&self) -> &'static str {
        match self.mode {
            FpMode::F32 => "scalar",
            FpMode::F16 => "scalar-f16",
            FpMode::Bf16 => "scalar-bf16",
            _ => unreachable!("SElem holds scalar modes only"),
        }
    }

    /// Output buffer format.
    pub fn out_fmt(&self) -> OutFmt {
        match self.spec() {
            None => OutFmt::F32,
            Some(s) => OutFmt::Pack16(s),
        }
    }

    /// Stage host f32 data in this element format.
    pub fn stage(&self, data: &[f32]) -> Staged {
        match self.spec() {
            None => Staged::F32(data.to_vec()),
            Some(s) => Staged::U16(quantize16(s, data)),
        }
    }

    /// `n` zero elements (0.0 is the all-zero pattern in every format).
    pub fn stage_zeros(&self, n: usize) -> Staged {
        match self.spec() {
            None => Staged::F32(vec![0.0; n]),
            Some(_) => Staged::U16(vec![0; n]),
        }
    }

    // ------------------------------------------------ program emission

    /// Element load at an element-indexed offset. 16-bit loads
    /// zero-extend (`lhu`): the scalar-16 ops read lane 0 only.
    pub fn load(&self, p: &mut ProgramBuilder, rd: Reg, base: Reg, elem_off: i32) {
        match self.spec() {
            None => p.lw(rd, base, elem_off * 4),
            Some(_) => p.lhu(rd, base, elem_off * 2),
        };
    }

    /// Post-increment element load advancing by `elems` elements.
    pub fn load_pi(&self, p: &mut ProgramBuilder, rd: Reg, base: Reg, elems: i32) {
        match self.spec() {
            None => p.lw_pi(rd, base, elems * 4),
            Some(_) => p.lhu_pi(rd, base, elems * 2),
        };
    }

    /// Element store at an element-indexed offset.
    pub fn store(&self, p: &mut ProgramBuilder, rs: Reg, base: Reg, elem_off: i32) {
        match self.spec() {
            None => p.sw(rs, base, elem_off * 4),
            Some(_) => p.sh(rs, base, elem_off * 2),
        };
    }

    /// Post-increment element store advancing by `elems` elements.
    pub fn store_pi(&self, p: &mut ProgramBuilder, rs: Reg, base: Reg, elems: i32) {
        match self.spec() {
            None => p.sw_pi(rs, base, elems * 4),
            Some(_) => p.sh_pi(rs, base, elems * 2),
        };
    }

    // ---------------------- host-mirror arithmetic on u32 register cells

    /// Quantize one f32 value into a register cell.
    pub fn q(&self, x: f32) -> u32 {
        match self.spec() {
            None => x.to_bits(),
            Some(s) => s.from_f64(x as f64) as u32,
        }
    }

    /// Quantize a host f32 slice into register cells.
    pub fn quantize(&self, xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|&x| self.q(x)).collect()
    }

    /// Widen a register cell to f64 (exact in every format).
    pub fn to_f64(&self, cell: u32) -> f64 {
        match self.spec() {
            None => f32::from_bits(cell) as f64,
            Some(s) => s.to_f64(cell as u16),
        }
    }

    /// `a + b` with the datapath's rounding.
    pub fn add(&self, a: u32, b: u32) -> u32 {
        match self.spec() {
            None => scalar::add32(a, b),
            Some(s) => scalar::add16(s, a as u16, b as u16) as u32,
        }
    }

    /// `a - b`.
    pub fn sub(&self, a: u32, b: u32) -> u32 {
        match self.spec() {
            None => scalar::sub32(a, b),
            Some(s) => scalar::sub16(s, a as u16, b as u16) as u32,
        }
    }

    /// `a * b`.
    pub fn mul(&self, a: u32, b: u32) -> u32 {
        match self.spec() {
            None => scalar::mul32(a, b),
            Some(s) => scalar::mul16(s, a as u16, b as u16) as u32,
        }
    }

    /// Fused `a*b + acc` (single rounding), mirroring `fmac`.
    pub fn fma(&self, a: u32, b: u32, acc: u32) -> u32 {
        match self.spec() {
            None => scalar::fma32(a, b, acc),
            Some(s) => scalar::fma16(s, a as u16, b as u16, acc as u16) as u32,
        }
    }

    /// `a / b` (DIV-SQRT block numerics).
    pub fn div(&self, a: u32, b: u32) -> u32 {
        match self.spec() {
            None => scalar::div32(a, b),
            Some(s) => scalar::div16(s, a as u16, b as u16) as u32,
        }
    }

    /// `fcvt` from a signed integer.
    pub fn from_int(&self, i: i32) -> u32 {
        match self.spec() {
            None => cast::i32_to_f32(i as u32),
            Some(s) => cast::i32_to_16(s, i as u32) as u32,
        }
    }

    /// Strict `a < b` with the datapath's quiet-compare semantics
    /// (NaN compares false).
    pub fn lt(&self, a: u32, b: u32) -> bool {
        let r = match self.spec() {
            None => scalar::cmp32(a, b, CmpPred::Lt),
            Some(s) => scalar::cmp16(s, a as u16, b as u16, CmpPred::Lt),
        };
        r == 1
    }

    /// `a <= b` (quiet; NaN compares false).
    pub fn le(&self, a: u32, b: u32) -> bool {
        let r = match self.spec() {
            None => scalar::cmp32(a, b, CmpPred::Le),
            Some(s) => scalar::cmp16(s, a as u16, b as u16, CmpPred::Le),
        };
        r == 1
    }
}

/// Quantize f32 samples to 16-bit lanes of `spec`.
pub fn quantize16(spec: &FpSpec, data: &[f32]) -> Vec<u16> {
    data.iter().map(|&x| spec.from_f64(x as f64)).collect()
}

/// Dequantized view (the values the vector kernels actually compute on).
pub fn dequant(spec: &FpSpec, q: &[u16]) -> Vec<f64> {
    q.iter().map(|&b| spec.to_f64(b)).collect()
}

/// Pack 16-bit lanes into words (lane 2i → low half of word i).
pub fn pack_words(q: &[u16]) -> Vec<u32> {
    q.chunks(2).map(|c| simd::pack2(c[0], *c.get(1).unwrap_or(&0))).collect()
}

/// The benchmark suite of Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    Conv,
    Dwt,
    Fft,
    Fir,
    Iir,
    Kmeans,
    Matmul,
    Svm,
}

impl Benchmark {
    /// All benchmarks, in Table 3 order.
    pub fn all() -> [Benchmark; 8] {
        use Benchmark::*;
        [Conv, Dwt, Fft, Fir, Iir, Kmeans, Matmul, Svm]
    }

    /// Upper-case name as used in the tables.
    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::Conv => "CONV",
            Benchmark::Dwt => "DWT",
            Benchmark::Fft => "FFT",
            Benchmark::Fir => "FIR",
            Benchmark::Iir => "IIR",
            Benchmark::Kmeans => "KMEANS",
            Benchmark::Matmul => "MATMUL",
            Benchmark::Svm => "SVM",
        }
    }

    /// Parse a table name.
    pub fn parse(s: &str) -> Option<Benchmark> {
        Benchmark::all().into_iter().find(|b| b.name().eq_ignore_ascii_case(s))
    }

    /// Build the default-size workload for `variant` on a cluster config.
    /// Sizes are chosen from the paper's near-sensor domains (§5.2) and fit
    /// the 64 kB TCDM of the 8-core cluster.
    pub fn build(&self, variant: Variant, cfg: &ClusterConfig) -> Workload {
        match self {
            Benchmark::Conv => conv::build(variant, cfg, 32, 32),
            Benchmark::Dwt => dwt::build(variant, cfg, 512, 3),
            Benchmark::Fft => fft::build(variant, cfg, 256),
            Benchmark::Fir => fir::build(variant, cfg, 512, 32),
            Benchmark::Iir => iir::build(variant, cfg, 512),
            Benchmark::Kmeans => kmeans::build(variant, cfg, 256, 16, 4),
            Benchmark::Matmul => matmul::build(variant, cfg, 32),
            Benchmark::Svm => svm::build(variant, cfg, 64, 32),
        }
    }

    /// DMA double-buffered tiled builder (the `--tiles` CLI knob): the
    /// dataset lives in L2 — sized beyond the TCDM — and is streamed
    /// through ping-pong TCDM buffers by the core-0 DMA master while the
    /// team computes (binary32 scalar). Available for the two streaming
    /// kernels (MATMUL n=96, CONV 128×66); `None` otherwise.
    pub fn build_tiled(&self, cfg: &ClusterConfig, tiles: usize) -> Option<Workload> {
        match self {
            Benchmark::Matmul => Some(matmul::build_tiled(cfg, 96, tiles)),
            Benchmark::Conv => Some(conv::build_tiled(cfg, 128, 66, tiles)),
            _ => None,
        }
    }

    /// Paper Table 3 FP / memory intensity, for validation. The scalar-16
    /// rungs share the scalar instruction mix (same program structure, only
    /// the access width and FP format change).
    pub fn table3_intensity(&self, variant: Variant) -> (f64, f64) {
        let scalar = matches!(variant, Variant::Scalar | Variant::Scalar16(_));
        match (self, scalar) {
            (Benchmark::Conv, true) => (0.33, 0.67),
            (Benchmark::Conv, false) => (0.28, 0.29),
            (Benchmark::Dwt, true) => (0.29, 0.59),
            (Benchmark::Dwt, false) => (0.21, 0.57),
            (Benchmark::Fft, true) => (0.32, 0.52),
            (Benchmark::Fft, false) => (0.26, 0.38),
            (Benchmark::Fir, true) => (0.32, 0.65),
            (Benchmark::Fir, false) => (0.32, 0.48),
            (Benchmark::Iir, true) => (0.19, 0.55),
            (Benchmark::Iir, false) => (0.17, 0.33),
            (Benchmark::Kmeans, true) => (0.55, 0.36),
            (Benchmark::Kmeans, false) => (0.44, 0.30),
            (Benchmark::Matmul, true) => (0.28, 0.58),
            (Benchmark::Matmul, false) => (0.27, 0.41),
            (Benchmark::Svm, true) => (0.27, 0.53),
            (Benchmark::Svm, false) => (0.21, 0.52),
        }
    }
}

/// 16-bit spec for a variant, defaulting to binary16.
pub fn spec_of(variant: Variant) -> &'static FpSpec {
    variant.spec().unwrap_or(&F16)
}

/// Both 16-bit formats (the tables report one number for both).
pub fn both_specs() -> [&'static FpSpec; 2] {
    [&F16, &BF16]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmark_names_roundtrip() {
        for b in Benchmark::all() {
            assert_eq!(Benchmark::parse(b.name()), Some(b));
        }
        assert_eq!(Benchmark::parse("nope"), None);
    }

    #[test]
    fn alloc_bumps_and_checks() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let mut a = Alloc::new(&cfg);
        let p1 = a.f32s(16);
        let p2 = a.halves(7); // 4 words
        let p3 = a.words(1);
        assert_eq!(p1, TCDM_BASE);
        assert_eq!(p2, TCDM_BASE + 64);
        assert_eq!(p3, TCDM_BASE + 64 + 16);
    }

    #[test]
    #[should_panic(expected = "TCDM overflow")]
    fn alloc_overflow_panics() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let mut a = Alloc::new(&cfg);
        a.words(64 * 1024); // 256 kB > 64 kB
    }

    /// Satellite gate: every buildable variant has a unique, *stable* label
    /// — CSV rows, cache rows and report tie-breaks all key on it, so
    /// scalar-16 rungs must never alias `scalar`, and the two vector
    /// formats must never alias each other.
    #[test]
    fn labels_are_distinct_and_stable() {
        let labels: Vec<&str> = Variant::all().iter().map(|v| v.label()).collect();
        assert_eq!(
            labels,
            vec!["scalar", "scalar-f16", "scalar-bf16", "vector-f16", "vector-bf16"],
            "variant labels are a stable external contract"
        );
        for (i, a) in labels.iter().enumerate() {
            for b in &labels[i + 1..] {
                assert_ne!(a, b, "aliased variant labels");
            }
        }
        // Labels round-trip through the parser.
        for v in Variant::all() {
            assert_eq!(Variant::parse_label(v.label()), Some(v));
        }
        assert_eq!(Variant::parse_label("vector"), None, "legacy coarse label is gone");
    }

    #[test]
    fn selem_arithmetic_mirrors_datapath() {
        // F32 cells are plain f32 bits.
        let e = SElem::of(Variant::Scalar);
        assert_eq!(e.size(), 4);
        assert_eq!(e.shift(), 2);
        assert_eq!(e.to_f64(e.fma(e.q(2.0), e.q(3.0), e.q(1.0))), 7.0);
        // 16-bit cells hold the value in the low half.
        let h = SElem::of(Variant::SCALAR_F16);
        assert_eq!(h.size(), 2);
        assert_eq!(h.shift(), 1);
        assert_eq!(h.q(1.0), 0x3C00);
        assert_eq!(h.to_f64(h.mul(h.q(3.0), h.q(4.0))), 12.0);
        assert!(h.lt(h.q(1.0), h.q(2.0)));
        assert!(!h.lt(h.q(2.0), h.q(1.0)));
        // from_int matches the cast path.
        assert_eq!(h.to_f64(h.from_int(100)), 100.0);
        let b = SElem::of(Variant::SCALAR_BF16);
        assert_eq!(b.to_f64(b.add(b.q(1.5), b.q(2.5))), 4.0);
        assert_eq!(b.suffix(), "scalar-bf16");
    }

    #[test]
    #[should_panic(expected = "scalar variants only")]
    fn selem_rejects_vector_variants() {
        let _ = SElem::of(Variant::VEC);
    }

    #[test]
    fn quantize_pack_roundtrip() {
        let data = [1.0f32, -2.5, 0.1, 3.75, 9.0];
        let q = quantize16(&F16, &data);
        assert_eq!(q.len(), 5);
        let w = pack_words(&q);
        assert_eq!(w.len(), 3);
        let d = dequant(&F16, &q);
        assert_eq!(d[0], 1.0);
        assert_eq!(d[1], -2.5);
        assert!((d[2] - 0.1).abs() < 1e-3);
    }
}
