//! FFT — decimation-in-frequency radix-2 FFT over N complex points (§5.2).
//!
//! Stage-level parallelism: the butterflies of each stage are split across
//! cores with an event-unit barrier between stages. The output is left in
//! the natural DIF (bit-reversed) order, as is customary for convolution /
//! spectral-energy pipelines that never materialize the reordered spectrum.
//!
//! * **Scalar**: interleaved (re, im) pairs; the butterfly is
//!   `u' = u + v`, `v' = (u − v)·W` with the 7-op complex multiply the
//!   paper quotes for the scalar variant.
//! * **Vector**: one complex value *is* one packed (re, im) register; add /
//!   subtract map 1:1 onto `vfadd`/`vfsub`, and the complex multiply is the
//!   10-op shuffle + `vfmul`/`vfadd`/`vfsub` sequence of §5.3.1 — which is
//!   exactly why the paper caps FFT's vectorization gain at ~1.43×.

use super::{quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload};
use crate::config::ClusterConfig;
use crate::isa::ProgramBuilder;
use crate::runtime::{parallel_for, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{simd, FpSpec};

/// Build the FFT workload over `n` complex points (power of two).
pub fn build(variant: Variant, cfg: &ClusterConfig, n: usize) -> Workload {
    assert!(n.is_power_of_two() && n >= 8);
    let mut w = match variant {
        Variant::Scalar | Variant::Scalar16(_) => build_scalar(SElem::of(variant), cfg, n),
        Variant::Vector(_) => build_vector(variant, cfg, n),
    };
    w.reference = reference(n);
    w
}

/// Binary64 ground truth: the same DIF butterfly network computed in f64
/// with exact twiddles (output left in bit-reversed order, like the
/// kernel).
fn reference(n: usize) -> Vec<f64> {
    let x = gen_signal(n);
    let mut d: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let stages = n.trailing_zeros() as usize;
    for s in 0..stages {
        let half = n >> (s + 1);
        let groups = 1 << s;
        for grp in 0..groups {
            let base = grp * (n >> s);
            for j in 0..half {
                let (iu, iv) = (base + j, base + j + half);
                let (ur, ui) = (d[2 * iu], d[2 * iu + 1]);
                let (vr, vi) = (d[2 * iv], d[2 * iv + 1]);
                let ang =
                    -2.0 * std::f64::consts::PI * (j * groups) as f64 / n as f64;
                let (wr, wi) = (ang.cos(), ang.sin());
                let (tr, ti) = (ur - vr, ui - vi);
                d[2 * iu] = ur + vr;
                d[2 * iu + 1] = ui + vi;
                d[2 * iv] = tr * wr - ti * wi;
                d[2 * iv + 1] = ti * wr + tr * wi;
            }
        }
    }
    d
}

fn gen_signal(n: usize) -> Vec<f32> {
    // Interleaved (re, im): a two-tone signal with noise, scaled to keep
    // f16 magnitudes comfortable across all log2(n) growth stages.
    let mut rng = Rng::new(0x4646_5400); // "FFT"
    let mut v = Vec::with_capacity(2 * n);
    for i in 0..n {
        let t = i as f32;
        let re = 0.25 * (6.283 * 8.0 * t / n as f32).sin()
            + 0.125 * (6.283 * 21.0 * t / n as f32).cos()
            + rng.f32_in(-0.05, 0.05);
        v.push(re);
        v.push(0.0);
    }
    v
}

/// Twiddle table W_n^k = exp(-2πik/n), k < n/2, interleaved (re, im), f32.
fn twiddles(n: usize) -> Vec<f32> {
    (0..n / 2)
        .flat_map(|k| {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            [ang.cos() as f32, ang.sin() as f32]
        })
        .collect()
}

fn build_scalar(elem: SElem, cfg: &ClusterConfig, n: usize) -> Workload {
    let mut al = Alloc::new(cfg);
    let x_base = elem.alloc(&mut al, 2 * n);
    let w_base = elem.alloc(&mut al, n);
    let x = gen_signal(n);
    let tw = twiddles(n);

    // Host mirror: DIF in the same op order (element-format fmul/fsub/fmac
    // on register cells).
    let expected = {
        let mut d: Vec<u32> = elem.quantize(&x);
        let twq = elem.quantize(&tw);
        let stages = n.trailing_zeros() as usize;
        for s in 0..stages {
            let half = n >> (s + 1);
            let groups = 1 << s;
            for grp in 0..groups {
                let base = grp * (n >> s);
                for j in 0..half {
                    let (iu, iv) = (base + j, base + j + half);
                    let (ur, ui) = (d[2 * iu], d[2 * iu + 1]);
                    let (vr, vi) = (d[2 * iv], d[2 * iv + 1]);
                    let (wr, wi) = (twq[2 * (j * groups)], twq[2 * (j * groups) + 1]);
                    let (tr, ti) = (elem.sub(ur, vr), elem.sub(ui, vi));
                    d[2 * iu] = elem.add(ur, vr);
                    d[2 * iu + 1] = elem.add(ui, vi);
                    // 5-op complex multiply (fmul, fmul, fsub, fmul, fmac).
                    let m1 = elem.mul(ti, wi);
                    let re = elem.sub(elem.mul(tr, wr), m1);
                    let m2 = elem.mul(tr, wi);
                    let im = elem.fma(ti, wr, m2);
                    d[2 * iv] = re;
                    d[2 * iv + 1] = im;
                }
            }
        }
        d.iter().map(|&v| elem.to_f64(v)).collect::<Vec<f64>>()
    };

    // log2 bytes per complex point (two elements).
    let cshift = elem.shift() + 1;
    let mut p = ProgramBuilder::new(format!("fft-{}", elem.suffix()));
    p.li(15, x_base).li(16, w_base);
    let stages = n.trailing_zeros() as usize;
    for s in 0..stages {
        let half = (n >> (s + 1)) as u32; // butterflies per group
        // Each core takes a slice of the flat butterfly index b ∈ [0, n/2):
        // grp = b / half, j = b % half (divisions strength-reduced to shifts
        // since half is a power of two).
        let half_shift = half.trailing_zeros();
        p.li(24, (n / 2) as u32);
        parallel_for(
            &mut p,
            Schedule::Static,
            LoopRegs::KERNEL,
            |_| {},
            |p| {
                // j = b & (half-1); grp = b >> half_shift
                p.andi(18, 13, (half - 1) as i32);
                p.srli(20, 13, half_shift as i32);
                // iu = grp*(n>>s) + j ; iv = iu + half
                p.slli(20, 20, (n >> s).trailing_zeros() as i32);
                p.add(20, 20, 18);
                // u_ptr = x + csize*iu ; v_ptr = u_ptr + csize*half
                p.slli(20, 20, cshift).add(20, 20, 15);
                p.addi(21, 20, 2 * elem.size() * half as i32);
                // w_ptr = w + csize*(j*groups)
                p.slli(22, 18, cshift + s as i32).add(22, 22, 16);
                // Loads.
                elem.load(p, 5, 20, 0); // ur
                elem.load(p, 6, 20, 1); // ui
                elem.load(p, 7, 21, 0); // vr
                elem.load(p, 8, 21, 1); // vi
                elem.load(p, 26, 22, 0); // wr
                elem.load(p, 27, 22, 1); // wi
                // u' = u + v (2 ops); t = u − v (2 ops).
                p.fadd(elem.mode, 28, 5, 7);
                p.fadd(elem.mode, 29, 6, 8);
                p.fsub(elem.mode, 5, 5, 7);
                p.fsub(elem.mode, 6, 6, 8);
                elem.store(p, 28, 20, 0);
                elem.store(p, 29, 20, 1);
                // v' = t·W — the 5-op complex multiply (7 cycles with deps).
                p.fmul(elem.mode, 30, 6, 27); // m1 = ti*wi
                p.fmul(elem.mode, 31, 5, 26); // tr*wr
                p.fsub(elem.mode, 31, 31, 30); // re
                p.fmul(elem.mode, 30, 5, 27); // m2 = tr*wi
                p.fmac(elem.mode, 30, 6, 26); // im = ti*wr + m2
                elem.store(p, 31, 21, 0);
                elem.store(p, 30, 21, 1);
            },
        );
        p.barrier();
    }
    p.end();

    Workload {
        name: format!("FFT-{}", elem.suffix()),
        program: p.build(),
        stage: vec![(x_base, elem.stage(&x)), (w_base, elem.stage(&tw))],
        out_addr: x_base,
        out_len: 2 * n,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, n: usize) -> Workload {
    let spec: &'static FpSpec = spec_of(variant);
    let mode = variant.mode();
    let mut al = Alloc::new(cfg);
    let x_base = al.halves(2 * n); // one word per complex point
    let w_base = al.halves(n);
    let x = gen_signal(n);
    let tw = twiddles(n);
    let xq = quantize16(spec, &x);
    let twq = quantize16(spec, &tw);

    // Host mirror: packed complex butterflies (vadd/vsub/vmul + shuffles).
    let expected = {
        let mut d: Vec<u32> =
            xq.chunks(2).map(|c| simd::pack2(c[0], c[1])).collect();
        let w: Vec<u32> = twq.chunks(2).map(|c| simd::pack2(c[0], c[1])).collect();
        let stages = n.trailing_zeros() as usize;
        for s in 0..stages {
            let half = n >> (s + 1);
            let groups = 1 << s;
            for grp in 0..groups {
                let base = grp * (n >> s);
                for j in 0..half {
                    let (iu, iv) = (base + j, base + j + half);
                    let (u, v) = (d[iu], d[iv]);
                    let wv = w[j * groups];
                    d[iu] = simd::vadd(spec, u, v);
                    let t = simd::vsub(spec, u, v);
                    d[iv] = cplx_mul_packed(spec, t, wv);
                }
            }
        }
        d.iter()
            .flat_map(|&wv| {
                let (re, im) = simd::unpack2(wv);
                [spec.to_f64(re), spec.to_f64(im)]
            })
            .collect::<Vec<f64>>()
    };

    let mut p = ProgramBuilder::new("fft-vector");
    p.li(15, x_base).li(16, w_base);
    let stages = n.trailing_zeros() as usize;
    for s in 0..stages {
        let half = (n >> (s + 1)) as u32;
        let half_shift = half.trailing_zeros();
        p.li(24, (n / 2) as u32);
        parallel_for(
            &mut p,
            Schedule::Static,
            LoopRegs::KERNEL,
            |_| {},
            |p| {
                p.andi(18, 13, (half - 1) as i32);
                p.srli(20, 13, half_shift as i32);
                p.slli(20, 20, (n >> s).trailing_zeros() as i32);
                p.add(20, 20, 18);
                p.slli(20, 20, 2).add(20, 20, 15); // u_ptr (4 B per complex)
                p.addi(21, 20, (4 * half) as i32); // v_ptr
                p.slli(22, 18, (2 + s) as i32).add(22, 22, 16); // w_ptr
                p.lw(5, 20, 0); // u
                p.lw(6, 21, 0); // v
                p.lw(7, 22, 0); // W
                p.fadd(mode, 8, 5, 6); // u' both lanes
                p.fsub(mode, 9, 5, 6); // t
                p.sw(8, 20, 0);
                // Complex multiply t·W — the 10-op §5.3.1 sequence.
                p.vshuffle(26, 7, 0b01); // (wi, wr)
                p.fmul(mode, 27, 9, 7); // (tr·wr, ti·wi)
                p.fmul(mode, 28, 9, 26); // (tr·wi, ti·wr)
                p.vshuffle(29, 27, 0b01);
                p.fsub(mode, 27, 27, 29); // lane0 = re
                p.vshuffle(29, 28, 0b01);
                p.fadd(mode, 28, 28, 29); // lane0 = im
                p.vpack_lo(27, 27, 28); // (re, im)
                p.sw(27, 21, 0);
            },
        );
        p.barrier();
    }
    p.end();

    Workload {
        name: format!("FFT-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![(x_base, Staged::U16(xq)), (w_base, Staged::U16(twq))],
        out_addr: x_base,
        out_len: 2 * n,
        out_fmt: OutFmt::Pack16(spec),
        expected,
        rtol: 1e-9,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

/// Packed complex multiply with the same rounding steps as the kernel.
fn cplx_mul_packed(spec: &FpSpec, t: u32, w: u32) -> u32 {
    let wsw = simd::vshuffle(w, 0b01);
    let m1 = simd::vmul(spec, t, w); // (tr·wr, ti·wi)
    let m2 = simd::vmul(spec, t, wsw); // (tr·wi, ti·wr)
    let re = simd::vsub(spec, m1, simd::vshuffle(m1, 0b01));
    let im = simd::vadd(spec, m2, simd::vshuffle(m2, 0b01));
    simd::vpack_lo(re, im)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference DFT for sanity (O(n²), f64).
    fn dft(x: &[f32]) -> Vec<(f64, f64)> {
        let n = x.len() / 2;
        (0..n)
            .map(|k| {
                let mut re = 0.0f64;
                let mut im = 0.0f64;
                for t in 0..n {
                    let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                    let (xr, xi) = (x[2 * t] as f64, x[2 * t + 1] as f64);
                    re += xr * ang.cos() - xi * ang.sin();
                    im += xr * ang.sin() + xi * ang.cos();
                }
                (re, im)
            })
            .collect()
    }

    fn bitrev(i: usize, bits: usize) -> usize {
        let mut r = 0;
        for b in 0..bits {
            r |= ((i >> b) & 1) << (bits - 1 - b);
        }
        r
    }

    #[test]
    fn scalar_exact_and_matches_dft() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let n = 32;
        let w = build(Variant::Scalar, &cfg, n);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
        // Cross-check the mirror itself against an O(n²) DFT, undoing the
        // bit-reversed order.
        let x = gen_signal(n);
        let spectrum = dft(&x);
        let bits = n.trailing_zeros() as usize;
        for k in 0..n {
            let (er, ei) = spectrum[k];
            let pos = bitrev(k, bits);
            assert!(
                (out[2 * pos] - er).abs() < 2e-3 && (out[2 * pos + 1] - ei).abs() < 2e-3,
                "bin {k}: ({}, {}) vs ({er}, {ei})",
                out[2 * pos],
                out[2 * pos + 1]
            );
        }
    }

    #[test]
    fn vector_exact_mirror() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::VEC, &cfg, 32);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 32);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
        }
    }

    #[test]
    fn reference_is_bitrev_spectrum() {
        // The f64 reference must agree with the O(n²) DFT after undoing
        // the bit-reversed order — tighter than the f32 mirror check.
        let n = 32;
        let r = reference(n);
        let spectrum = dft(&gen_signal(n));
        let bits = n.trailing_zeros() as usize;
        for k in 0..n {
            let (er, ei) = spectrum[k];
            let pos = bitrev(k, bits);
            assert!(
                (r[2 * pos] - er).abs() < 1e-9 && (r[2 * pos + 1] - ei).abs() < 1e-9,
                "bin {k}"
            );
        }
    }

    #[test]
    fn vector_gain_is_modest() {
        // §5.3.1: the 10-cycle packed complex multiply caps the gain ≈1.43.
        let cfg = ClusterConfig::new(8, 8, 1);
        let ws = build(Variant::Scalar, &cfg, 128);
        let wv = build(Variant::VEC, &cfg, 128);
        let (ss, _) = ws.run(&cfg).unwrap();
        let (sv, _) = wv.run(&cfg).unwrap();
        let gain = ss.total_cycles as f64 / sv.total_cycles as f64;
        assert!(gain > 1.05 && gain < 1.6, "FFT vector gain = {gain}");
    }
}
