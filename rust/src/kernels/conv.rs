//! CONV — 2D 3×3 convolution over a W×H image, "the most computing-
//! intensive kernel in CNN workloads" (§5.2). Output rows are partitioned
//! statically across cores.
//!
//! * **Scalar**: per output pixel, a 3-row loop of `p.lw pixel + p.lw coef +
//!   fmac` triples (coefficients re-streamed from TCDM — the Table 3
//!   0.33 / 0.67 mix).
//! * **Vector**: the low-memory-intensity variant of Table 3 (0.28 / 0.29):
//!   the six packed coefficient words are *register-resident* (loaded once
//!   per core), each image row contributes two aligned pair loads, and the
//!   misaligned pairs are built with `pv.shuffle`/`pv.pack`; expanding dot
//!   products accumulate two neighbouring outputs in binary32.

use super::{
    mirror, pack_words, quantize16, spec_of, Alloc, OutFmt, SElem, Staged, Variant, Workload,
};
use crate::cluster::mem::L2_BASE;
use crate::config::ClusterConfig;
use crate::isa::ProgramBuilder;
use crate::runtime::{parallel_for, team, LoopRegs, Schedule};
use crate::testutil::Rng;
use crate::transfp::{cast, simd, FpMode};

/// Build the CONV workload: 3×3 kernel over a `w`×`h` image (valid region).
pub fn build(variant: Variant, cfg: &ClusterConfig, w: usize, h: usize) -> Workload {
    assert!(w % 2 == 0 && w >= 8 && h >= 4);
    let mut wl = match variant {
        Variant::Scalar | Variant::Scalar16(_) => build_scalar(SElem::of(variant), cfg, w, h),
        Variant::Vector(_) => build_vector(variant, cfg, w, h),
    };
    wl.reference = reference(w, h);
    wl
}

/// Binary64 ground truth from the un-quantized f32 inputs.
fn reference(w: usize, h: usize) -> Vec<f64> {
    let (ow, oh) = (w - 2, h - 2);
    let (img, k) = gen_inputs(w, h);
    let mut out = vec![0.0f64; ow * oh];
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0.0f64;
            for r in 0..3 {
                for c in 0..3 {
                    acc += k[r * 3 + c] as f64 * img[(oy + r) * w + ox + c] as f64;
                }
            }
            out[oy * ow + ox] = acc;
        }
    }
    out
}

fn gen_inputs(w: usize, h: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0x434F_4E56); // "CONV"
    let img = rng.f32_vec(w * h, -1.0, 1.0);
    // Sharpen-like 3×3 kernel.
    let k = vec![0.0625f32, 0.125, 0.0625, 0.125, 0.25, 0.125, 0.0625, 0.125, 0.0625];
    (img, k)
}

fn build_scalar(elem: SElem, cfg: &ClusterConfig, w: usize, h: usize) -> Workload {
    let (ow, oh) = (w - 2, h - 2);
    let mut al = Alloc::new(cfg);
    let img_base = elem.alloc(&mut al, w * h);
    let k_base = elem.alloc(&mut al, 9);
    let out_base = elem.alloc(&mut al, ow * oh);
    let (img, k) = gen_inputs(w, h);

    // Host mirror: rows outer, cols inner, element-format FMA in (r, c)
    // order on register cells.
    let imq = elem.quantize(&img);
    let kq = elem.quantize(&k);
    let mut expected = vec![0.0f64; ow * oh];
    for oy in 0..oh {
        for ox in 0..ow {
            let window = (0..3)
                .flat_map(|r| (0..3).map(move |c| (r, c)))
                .map(|(r, c)| (kq[r * 3 + c], imq[(oy + r) * w + ox + c]));
            expected[oy * ow + ox] = elem.to_f64(mirror::dot(elem, window));
        }
    }

    let mut p = ProgramBuilder::new(format!("conv-{}", elem.suffix()));
    p.li(24, oh as u32); // output rows
    p.li(15, img_base).li(16, k_base).li(17, out_base);
    p.li(30, w as u32).li(31, ow as u32);
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            // out_ptr = out + size*ow*oy ; in row base = img + size*w*oy
            p.mul(25, 13, 31).slli(25, 25, elem.shift()).add(23, 25, 17);
            p.mul(25, 13, 30).slli(25, 25, elem.shift()).add(22, 25, 15);
            p.mv(20, 22); // walking pixel ptr (top-left of the window)
            p.li(18, 0); // ox
            p.label("col");
            {
                // 3×3 fully unrolled with static offsets (the natural
                // compiler lowering for a constant-size window) — pure
                // load/load/fmac mix.
                p.li(28, 0); // acc
                for r in 0..3i32 {
                    for c in 0..3i32 {
                        elem.load(p, 26, 20, r * w as i32 + c);
                        elem.load(p, 27, 16, r * 3 + c);
                        p.fmac(elem.mode, 28, 27, 26);
                    }
                }
                p.addi(20, 20, elem.size()); // slide the window
                elem.store_pi(p, 28, 23, 1);
                p.addi(18, 18, 1);
                p.blt(18, 31, "col");
            }
        },
    );
    p.barrier();
    p.end();

    Workload {
        name: format!("CONV-{}", elem.suffix()),
        program: p.build(),
        stage: vec![(img_base, elem.stage(&img)), (k_base, elem.stage(&k))],
        out_addr: out_base,
        out_len: ow * oh,
        out_fmt: elem.out_fmt(),
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

fn build_vector(variant: Variant, cfg: &ClusterConfig, w: usize, h: usize) -> Workload {
    let spec = spec_of(variant);
    let mode = variant.mode();
    let (ow, oh) = (w - 2, h - 2);
    let ow_pairs = ow / 2;
    let mut al = Alloc::new(cfg);
    let img_base = al.halves(w * h);
    let k_base = al.halves(12); // 3 rows × 2 packed words (c0c1, c2·pad)
    let out_base = al.halves(ow_pairs * 2 * oh);
    let (img, k) = gen_inputs(w, h);
    let imq = quantize16(spec, &img);
    // Pack coefficients row-wise: (k0,k1), (k2,0) per row.
    let mut kp = Vec::new();
    for r in 0..3 {
        kp.extend([k[r * 3], k[r * 3 + 1], k[r * 3 + 2], 0.0]);
    }
    let kq = quantize16(spec, &kp);

    // Host mirror. Per output pair (ox even): for each window row:
    //   w0 = (p0,p1), w1 = (p2,p3) aligned pair loads;
    //   acc0 += k01·w0 + k2x·(p2,·) ; acc1 += k01·(p1,p2) + k2x·(p3,·).
    let imw = pack_words(&imq);
    let kw = pack_words(&kq);
    let row_w = w / 2;
    let mut expected = vec![0.0f64; ow_pairs * 2 * oh];
    for oy in 0..oh {
        for op in 0..ow_pairs {
            let mut acc0 = 0u32;
            let mut acc1 = 0u32;
            for r in 0..3 {
                let base = (oy + r) * row_w + op;
                let w0 = imw[base];
                let w1 = imw[base + 1];
                let k01 = kw[r * 2];
                let k2x = kw[r * 2 + 1];
                let mid = simd::vpack_lo(simd::vshuffle(w0, 0b11), w1); // (p1,p2)
                let hi3 = simd::vshuffle(w1, 0b01); // (p3,·)
                acc0 = simd::vdotp_widen(spec, k01, w0, acc0);
                // Third column element: widening multi-format FMA on lane 0
                // (c2·p2) — not a dot product with a wasted zero lane.
                acc0 = mirror::fma_widen(spec, k2x, w1, acc0);
                acc1 = simd::vdotp_widen(spec, k01, mid, acc1);
                acc1 = mirror::fma_widen(spec, k2x, hi3, acc1);
            }
            let cpk = cast::cpka(spec, acc0, acc1);
            let (lo, hi) = simd::unpack2(cpk);
            expected[oy * ow_pairs * 2 + 2 * op] = spec.to_f64(lo);
            expected[oy * ow_pairs * 2 + 2 * op + 1] = spec.to_f64(hi);
        }
    }

    let mut p = ProgramBuilder::new("conv-vector");
    p.li(24, oh as u32);
    p.li(15, img_base).li(17, out_base);
    p.li(30, row_w as u32).li(31, ow_pairs as u32);
    // Register-resident packed coefficients: r1..r6 (loaded once — this is
    // what pushes the memory intensity down to Table 3's 0.29).
    p.li(25, k_base);
    for i in 0..6u8 {
        p.lw_pi(1 + i, 25, 4);
    }
    parallel_for(
        &mut p,
        Schedule::Static,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            p.mul(25, 13, 31).slli(25, 25, 2).add(23, 25, 17); // out row ptr
            p.mul(25, 13, 30).slli(25, 25, 2).add(22, 25, 15); // img row base
            p.li(18, 0); // output pair index
            p.label("col");
            {
                p.slli(20, 18, 2).add(20, 20, 22); // window ptr
                p.li(27, 0); // acc0
                p.li(28, 0); // acc1
                let row_bytes = (row_w * 4) as i32;
                for r in 0..3u8 {
                    let k01 = 1 + 2 * r; // coef regs r1..r6
                    let k2x = 2 + 2 * r;
                    p.lw(26, 20, 0); // w0
                    p.lw(29, 20, 4); // w1
                    if r < 2 {
                        p.addi(20, 20, row_bytes); // next window row
                    }
                    p.vshuffle(7, 26, 0b11);
                    p.vpack_lo(7, 7, 29); // mid = (p1,p2)
                    p.vshuffle(8, 29, 0b01); // (p3,·)
                    p.fdotp(mode, 27, k01, 26);
                    p.fmac_widen(mode, 27, k2x, 29); // c2·p2 (lane 0, f32 acc)
                    p.fdotp(mode, 28, k01, 7);
                    p.fmac_widen(mode, 28, k2x, 8); // c2·p3
                }
                p.cpka(mode, 9, 27, 28);
                p.sw_pi(9, 23, 4);
                p.addi(18, 18, 1);
                p.blt(18, 31, "col");
            }
        },
    );
    p.barrier();
    p.end();

    Workload {
        name: format!("CONV-vector-{}", if spec.exp_bits == 5 { "f16" } else { "bf16" }),
        program: p.build(),
        stage: vec![(img_base, Staged::U16(imq)), (k_base, Staged::U16(kq))],
        out_addr: out_base,
        out_len: ow_pairs * 2 * oh,
        out_fmt: OutFmt::Pack16(spec),
        expected,
        rtol: 1e-9,
        atol: 1e-12,
        reference: Vec::new(),
    }
}

/// DMA double-buffered band-tiled CONV (binary32 scalar): the image and the
/// output live in **L2**; the kernel streams bands of `oh/tiles` output
/// rows (plus the 2-row halo) through ping-pong TCDM buffers. Core 0
/// masters the DMA and releases the team per band over
/// [`team::EV_TILE_READY`]; the next band's input transfer overlaps this
/// band's compute. Arithmetic is identical to the untiled scalar kernel.
pub fn build_tiled(cfg: &ClusterConfig, w: usize, h: usize, tiles: usize) -> Workload {
    assert!(w % 2 == 0 && w >= 8 && h >= 4);
    let (ow, oh) = (w - 2, h - 2);
    assert!(tiles >= 1 && oh % tiles == 0, "tiles must divide the output rows");
    let band_rows = oh / tiles;
    let in_band_words = ((band_rows + 2) * w) as u32; // band + 2-row halo
    let out_band_words = (band_rows * ow) as u32;

    // L2 layout: image | output.
    let img_l2 = L2_BASE;
    let out_l2 = L2_BASE + (w * h * 4) as u32;
    // TCDM layout: 3×3 coefficients + ping-pong input/output bands.
    let mut al = Alloc::new(cfg);
    let k_base = al.f32s(9);
    let ibuf = [al.f32s((band_rows + 2) * w), al.f32s((band_rows + 2) * w)];
    let obuf = [al.f32s(band_rows * ow), al.f32s(band_rows * ow)];

    let (img, k) = gen_inputs(w, h);
    // Host mirror: identical (r, c) FMA order to the untiled scalar kernel.
    let f32e = SElem::of(Variant::Scalar);
    let mut expected = vec![0.0f64; ow * oh];
    for oy in 0..oh {
        for ox in 0..ow {
            let window = (0..3)
                .flat_map(|r| (0..3).map(move |c| (r, c)))
                .map(|(r, c)| (k[r * 3 + c].to_bits(), img[(oy + r) * w + ox + c].to_bits()));
            expected[oy * ow + ox] = f32::from_bits(mirror::dot(f32e, window)) as f64;
        }
    }

    let mut p = ProgramBuilder::new(format!("conv-tiled{tiles}-scalar"));
    // Prologue: stage the first input band, then release the team.
    team::master_only(&mut p, "boot", &mut |p| {
        team::dma_copy(p, 1, 2, img_l2, ibuf[0], in_band_words);
        team::dma_wait(p, 1, 2);
        team::signal_tile_ready(p);
    });
    p.li(16, k_base);
    p.li(30, w as u32).li(31, ow as u32);
    for t in 0..tiles {
        let buf = t % 2;
        team::wait_tile_ready(&mut p);
        if t + 1 < tiles {
            team::master_only(&mut p, &format!("pf{t}"), &mut |p| {
                let src = img_l2 + ((t + 1) * band_rows * w * 4) as u32;
                team::dma_copy(p, 1, 2, src, ibuf[(t + 1) % 2], in_band_words);
            });
        }
        // Band compute region: setup through the joining barrier, one
        // attribution row per band per core.
        p.region_enter(&format!("band{t}"));
        p.li(15, ibuf[buf]);
        p.li(17, obuf[buf]);
        p.li(24, band_rows as u32);
        let col = format!("b{t}_col");
        parallel_for(
            &mut p,
            Schedule::Static,
            LoopRegs::KERNEL,
            |_| {},
            |p| {
                // Local row i: windows start at buffer row i, outputs go to
                // buffer row i.
                p.mul(25, 13, 31).slli(25, 25, 2).add(23, 25, 17); // out ptr
                p.mul(25, 13, 30).slli(25, 25, 2).add(22, 25, 15); // band row
                p.mv(20, 22);
                p.li(18, 0); // ox
                p.label(&col);
                {
                    p.li(28, 0); // acc
                    for r in 0..3i32 {
                        for c in 0..3i32 {
                            p.lw(26, 20, (r * w as i32 + c) * 4);
                            p.lw(27, 16, (r * 3 + c) * 4);
                            p.fmac(FpMode::F32, 28, 27, 26);
                        }
                    }
                    p.addi(20, 20, 4); // slide the window
                    p.sw_pi(28, 23, 4);
                    p.addi(18, 18, 1);
                    p.blt(18, 31, &col);
                }
            },
        );
        p.barrier(); // band compute complete
        p.region_exit();
        team::master_only(&mut p, &format!("wb{t}"), &mut |p| {
            let dst = out_l2 + (t * band_rows * ow * 4) as u32;
            team::dma_copy(p, 1, 2, obuf[buf], dst, out_band_words);
            team::dma_wait(p, 1, 2);
            if t + 1 < tiles {
                team::signal_tile_ready(p);
            }
        });
    }
    p.barrier(); // join
    p.end();

    Workload {
        name: format!("CONV-tiled{tiles}-scalar"),
        program: p.build(),
        stage: vec![(img_l2, Staged::F32(img)), (k_base, Staged::F32(k))],
        out_addr: out_l2,
        out_len: ow * oh,
        out_fmt: OutFmt::F32,
        expected,
        rtol: 0.0,
        atol: 1e-12,
        reference: reference(w, h),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_exact() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = build(Variant::Scalar, &cfg, 16, 8);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn scalar16_exact_both_formats() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for v in [Variant::SCALAR_F16, Variant::SCALAR_BF16] {
            let w = build(v, &cfg, 16, 8);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap();
        }
    }

    #[test]
    fn vector_exact() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let w = build(Variant::VEC, &cfg, 16, 8);
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn tiled_exact_across_tile_counts() {
        let cfg = ClusterConfig::new(8, 8, 1);
        for tiles in [1usize, 2, 3, 6] {
            let w = build_tiled(&cfg, 16, 8, tiles);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap_or_else(|e| panic!("tiles={tiles}: {e}"));
        }
        let (_, solo) = build_tiled(&cfg, 16, 8, 2).run_on(&cfg, 1).unwrap();
        build_tiled(&cfg, 16, 8, 2).verify(&solo).unwrap();
        // Tiling never moves arithmetic.
        let flat = build(Variant::Scalar, &cfg, 16, 8);
        assert_eq!(build_tiled(&cfg, 16, 8, 2).expected, flat.expected);
    }

    #[test]
    fn tiled_handles_images_larger_than_tcdm() {
        // 128×66 image + 126×64 output ≈ 66 kB of f32 against a 64 kB TCDM.
        let cfg = ClusterConfig::new(8, 8, 1);
        let w = build_tiled(&cfg, 128, 66, 8);
        assert!((128 * 66 + 126 * 64) * 4 > cfg.tcdm_bytes());
        let (_, out) = w.run(&cfg).unwrap();
        w.verify(&out).unwrap();
    }

    #[test]
    fn vector_low_memory_intensity() {
        // Table 3: CONV vector has a distinctly low memory intensity (0.29)
        // thanks to register-resident coefficients.
        let cfg = ClusterConfig::new(8, 8, 1);
        let w = build(Variant::VEC, &cfg, 32, 32);
        let (stats, _) = w.run(&cfg).unwrap();
        let mem = stats.aggregate().mem_intensity();
        assert!(mem < 0.40, "vector CONV mem intensity = {mem}");
    }
}
