//! Area model (Fig 4, §3.3), GF 22FDX.
//!
//! Component model calibrated to the three Table 6 area anchors:
//! 16c16f1p = **2.10 mm²**, 16c16f0p = **1.80 mm²**, 8c4f1p = **0.97 mm²**,
//! and to the §3.3 narrative: area grows linearly with the FPU count, each
//! pipeline stage adds register area per FPU, and the cluster total grows
//! *less than linearly* with cores because DMA / event unit / shared I$
//! banks are not duplicated.

use crate::config::ClusterConfig;

/// RI5CY core + private interconnect ports, mm².
const A_CORE: f64 = 0.040;
/// FPnew instance with 0 pipeline stages, mm².
const A_FPU0: f64 = 0.025;
/// Register area per added FPU pipeline stage, mm² (from the 16-FPU anchor
/// pair: (2.10 − 1.80)/16).
const A_FPU_STAGE: f64 = 0.01875;
/// TCDM SRAM area per kB, mm² (≈3.1 mm²/MB for the wide-voltage macros).
const A_TCDM_PER_KB: f64 = 0.40 / 128.0;
/// Shared blocks (I$ banks, DMA, event unit, log interconnect): affine in
/// the core count — the sub-linear term of §3.3.
const A_SHARED_BASE: f64 = 0.190;
const A_SHARED_PER_CORE: f64 = 0.0106;
/// FPU-sharing interconnect, per FPU port.
const A_FPU_ITC_PER_FPU: f64 = 0.001;
/// Shared DIV-SQRT block.
const A_DIVSQRT: f64 = 0.008;

/// Total cluster area in mm².
pub fn area_mm2(cfg: &ClusterConfig) -> f64 {
    let cores = cfg.cores as f64;
    let fpus = cfg.fpus as f64;
    let tcdm_kb = cfg.tcdm_bytes() as f64 / 1024.0;
    let fpu = A_FPU0 + A_FPU_STAGE * cfg.pipe as f64;
    // Private FPUs (1/1) need no sharing interconnect (§3.2).
    let itc = if cfg.fpus < cfg.cores { A_FPU_ITC_PER_FPU * fpus } else { 0.0 };
    A_CORE * cores
        + fpu * fpus
        + A_TCDM_PER_KB * tcdm_kb
        + A_SHARED_BASE
        + A_SHARED_PER_CORE * cores
        + itc
        + A_DIVSQRT
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol_pct: f64) -> bool {
        (a - b).abs() / b * 100.0 <= tol_pct
    }

    /// Table 6 anchors within 3%.
    #[test]
    fn table6_anchors() {
        let a = area_mm2(&ClusterConfig::new(16, 16, 1));
        assert!(close(a, 2.10, 3.0), "16c16f1p = {a}");
        let a = area_mm2(&ClusterConfig::new(16, 16, 0));
        assert!(close(a, 1.80, 3.0), "16c16f0p = {a}");
        let a = area_mm2(&ClusterConfig::new(8, 4, 1));
        assert!(close(a, 0.97, 3.0), "8c4f1p = {a}");
    }

    /// §3.3: area grows linearly in the FPU count (fixed cores/pipe).
    #[test]
    fn linear_in_fpus() {
        let a2 = area_mm2(&ClusterConfig::new(8, 2, 1));
        let a4 = area_mm2(&ClusterConfig::new(8, 4, 1));
        let a8 = area_mm2(&ClusterConfig::new(8, 8, 1));
        let d1 = a4 - a2;
        let d2 = a8 - a4;
        assert!(d1 > 0.0 && d2 > 0.0);
        // Slope doubles with the FPU increment (2→4 vs 4→8), modulo the
        // interconnect disappearing at 1/1.
        assert!(close(d2 / d1, 2.0, 15.0), "d1={d1} d2={d2}");
    }

    /// §3.3: pipeline stages add area monotonically.
    #[test]
    fn pipeline_adds_area() {
        for cores in [8usize, 16] {
            for fpus in [cores / 4, cores / 2, cores] {
                let a0 = area_mm2(&ClusterConfig::new(cores, fpus, 0));
                let a1 = area_mm2(&ClusterConfig::new(cores, fpus, 1));
                let a2 = area_mm2(&ClusterConfig::new(cores, fpus, 2));
                assert!(a0 < a1 && a1 < a2);
            }
        }
    }

    /// §3.3: 8→16 cores less than doubles the area (shared blocks).
    #[test]
    fn sublinear_in_cores() {
        let a8 = area_mm2(&ClusterConfig::new(8, 8, 1));
        let a16 = area_mm2(&ClusterConfig::new(16, 16, 1));
        assert!(a16 < 2.0 * a8, "a8={a8} a16={a16}");
        assert!(a16 > 1.5 * a8, "16c still has 2× cores/FPUs/TCDM");
    }
}
