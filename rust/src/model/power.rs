//! Activity-based power/energy model (Fig 5, Tables 4–5 energy efficiency).
//!
//! PrimeTime power analysis is not available, so energy is modelled
//! per-event: the simulator's performance counters drive per-component
//! energies-per-cycle (22FDX-class constants, NT = 0.65 V). The *shape*
//! effects the paper reports all emerge from the counters themselves:
//!
//! * 1/4 → 1/2 sharing raises power because contention stalls vanish and
//!   the cluster does more work per cycle (§3.3);
//! * 1/2 → 1/1 lowers power slightly: the sharing interconnect disappears
//!   (and with it the timing pressure on FPU paths), while the extra private
//!   units sit underutilized at <50% FP intensity;
//! * pipeline registers add clocking energy per stage, but two stages relax
//!   timing pressure and the per-op energy drops below the 1-stage point;
//! * sleeping (event-unit gated) cores cost almost nothing — the mechanism
//!   behind "energy efficiency is not affected by parallelization
//!   effectiveness" (§7).
//!
//! Absolute calibration: the Gflop/s/W peaks of Tables 4/5 (167 vector /
//! 99 scalar on FIR at 16c16f0p) pin the global scale; see
//! `coordinator::tests::energy_anchor`.

use super::area::area_mm2;
use crate::cluster::counters::RunStats;
use crate::config::{ClusterConfig, Corner};

/// Per-cycle activity rates extracted from a run (cluster-wide sums divided
/// by total cycles).
#[derive(Debug, Clone, Copy)]
pub struct Activity {
    /// Σ core-active cycles / total.
    pub active: f64,
    /// Σ attributable stall cycles (core clocked but held) / total.
    pub stalled: f64,
    /// Σ gated cycles (barrier sleep + finished-early) / total.
    pub gated: f64,
    /// Scalar FP ops per cycle (cluster-wide).
    pub fp_scalar: f64,
    /// Packed-SIMD FP ops per cycle.
    pub fp_vec: f64,
    /// TCDM accesses per cycle.
    pub tcdm: f64,
    /// Instruction fetches per cycle (≈ active).
    pub ifetch: f64,
}

impl Activity {
    /// Extract rates from run statistics for an `ncores` cluster.
    pub fn from_stats(stats: &RunStats) -> Activity {
        let agg = stats.aggregate();
        let core_cycles: u64 = stats.per_core.iter().map(|c| c.cycles).sum();
        Self::from_parts(&agg, stats.total_cycles, stats.per_core.len(), core_cycles)
    }

    /// Rates from aggregated counters plus the Σ per-core cycle span —
    /// exactly the fields a cached [`crate::coordinator::Measurement`]
    /// carries, so the fig 5 power report regenerates from the measurement
    /// cache without re-simulating. `from_stats` delegates here, keeping
    /// one implementation.
    pub fn from_parts(
        agg: &crate::cluster::counters::CoreCounters,
        total_cycles: u64,
        ncores: usize,
        core_cycles: u64,
    ) -> Activity {
        let t = total_cycles.max(1) as f64;
        let ncores = ncores as f64;
        let active = agg.active as f64;
        // Cores that finish early are clock-gated until the last one ends
        // (Σ (total − cycles_i) = n·total − Σ cycles_i).
        let finished_early = ncores * total_cycles as f64 - core_cycles as f64;
        let gated = agg.barrier_idle as f64 + finished_early;
        let stalled = (ncores * t - active - gated).max(0.0);
        Activity {
            active: active / t,
            stalled: stalled / t,
            gated: gated / t,
            fp_scalar: (agg.fp_instrs - agg.fp_vec_instrs) as f64 / t,
            fp_vec: agg.fp_vec_instrs as f64 / t,
            tcdm: agg.mem_instrs as f64 / t,
            ifetch: active / t,
        }
    }

    /// Activity of a cached measurement (physical core count from its
    /// configuration — inactive team members count as gated, which is what
    /// makes partial-occupancy power cheap in fig 5).
    pub fn from_measurement(m: &crate::coordinator::Measurement) -> Activity {
        Self::from_parts(&m.agg, m.cycles, m.cfg.cores, m.core_cycles)
    }
}

// ---- NT (0.65 V) energy constants, pJ per event/cycle. ----
// Global calibration factor pinning the Tables 4/5 efficiency peaks.
const CAL: f64 = 1.58;
/// RI5CY core, issuing.
const E_CORE_ACTIVE: f64 = 2.10 * CAL;
/// Core held in a stall (clocks toggling, no issue).
const E_CORE_STALL: f64 = 1.20 * CAL;
/// Clock-gated core (event-unit sleep).
const E_CORE_GATED: f64 = 0.10 * CAL;
/// Scalar FP operation on FPnew.
const E_FPU_SCALAR: f64 = 1.70 * CAL;
/// Packed-SIMD FP operation (two 16-bit slices; < 2× scalar).
const E_FPU_VEC: f64 = 2.40 * CAL;
/// FPU clock tree per instance per cycle (FPnew clock-gates idle units, so
/// this is small), plus per pipeline stage (registers keep clocking).
const E_FPU_STATIC: f64 = 0.035 * CAL;
const E_FPU_STATIC_STAGE: f64 = 0.050 * CAL;
/// TCDM SRAM + log interconnect per access.
const E_TCDM_ACCESS: f64 = 1.05 * CAL;
/// I$ fetch per active cycle.
const E_ICACHE_FETCH: f64 = 0.65 * CAL;
/// Cluster interconnect + I$ control: superlinear in cores (§3.3).
const E_INTERCO_BASE: f64 = 0.012 * CAL;
const E_INTERCO_EXP: f64 = 1.35;
/// FPU sharing interconnect per cycle per port (absent at 1/1 sharing).
const E_FPU_ITC_PORT: f64 = 0.055 * CAL;
/// Leakage ∝ area, pJ/cycle per mm² at 100 MHz-equivalent.
const E_LEAK_PER_MM2: f64 = 0.30 * CAL;

/// Per-op energy multiplier by pipeline stages: registers add clock energy
/// (1 stage), but the relaxed timing pressure of 2 stages shrinks the
/// combinational cells (§3.3: "power consumption tends to decrease").
fn pipe_op_factor(pipe: u32) -> f64 {
    match pipe {
        0 => 1.00,
        1 => 1.16,
        _ => 1.06,
    }
}

/// Extra per-op factor when the sharing interconnect sits in the FPU path
/// (timing pressure, §3.3); removed for private FPUs.
fn sharing_op_factor(cfg: &ClusterConfig) -> f64 {
    if cfg.fpus < cfg.cores {
        1.10
    } else {
        1.0
    }
}

/// Dynamic-energy voltage scaling relative to NT (CV²).
fn vdd_factor(corner: Corner) -> f64 {
    let r = corner.vdd() / Corner::Nt.vdd();
    r * r
}

/// Cluster energy per cycle in pJ for the given activity.
pub fn energy_per_cycle_pj(cfg: &ClusterConfig, corner: Corner, a: &Activity) -> f64 {
    let cores_dyn = a.active * E_CORE_ACTIVE + a.stalled * E_CORE_STALL + a.gated * E_CORE_GATED;
    let fpu_ops = (a.fp_scalar * E_FPU_SCALAR + a.fp_vec * E_FPU_VEC)
        * pipe_op_factor(cfg.pipe)
        * sharing_op_factor(cfg);
    let fpu_static =
        cfg.fpus as f64 * (E_FPU_STATIC + E_FPU_STATIC_STAGE * cfg.pipe as f64);
    let itc = if cfg.fpus < cfg.cores { E_FPU_ITC_PORT * cfg.fpus as f64 } else { 0.0 };
    let mem = a.tcdm * E_TCDM_ACCESS;
    let ifetch = a.ifetch * E_ICACHE_FETCH;
    let interco = E_INTERCO_BASE * (cfg.cores as f64).powf(E_INTERCO_EXP);
    let dynamic = cores_dyn + fpu_ops + fpu_static + itc + mem + ifetch + interco;
    let leak = E_LEAK_PER_MM2 * area_mm2(cfg) * if corner == Corner::St { 2.2 } else { 1.0 };
    dynamic * vdd_factor(corner) + leak
}

/// Power in mW at `freq_mhz` (Fig 5 uses 100 MHz for all configurations).
pub fn power_mw(cfg: &ClusterConfig, corner: Corner, a: &Activity, freq_mhz: f64) -> f64 {
    energy_per_cycle_pj(cfg, corner, a) * freq_mhz * 1e-3
}

/// Energy efficiency in Gflop/s/W given flops/cycle (frequency-independent:
/// 1 flop/pJ = 1000 Gflop/s/W).
pub fn gflops_per_watt(cfg: &ClusterConfig, corner: Corner, a: &Activity, flops_per_cycle: f64) -> f64 {
    1000.0 * flops_per_cycle / energy_per_cycle_pj(cfg, corner, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic "matmul-like" activity for an `n`-core cluster: 90%
    /// active, FP intensity ~0.3, memory intensity ~0.5.
    fn act(n: usize, vec: bool) -> Activity {
        let nf = n as f64;
        Activity {
            active: 0.90 * nf,
            stalled: 0.08 * nf,
            gated: 0.02 * nf,
            fp_scalar: if vec { 0.0 } else { 0.28 * nf },
            fp_vec: if vec { 0.27 * nf } else { 0.0 },
            tcdm: 0.5 * nf,
            ifetch: 0.9 * nf,
        }
    }

    #[test]
    fn st_costs_more_than_nt() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let a = act(8, false);
        let nt = energy_per_cycle_pj(&cfg, Corner::Nt, &a);
        let st = energy_per_cycle_pj(&cfg, Corner::St, &a);
        assert!(st > 1.3 * nt, "CV² scaling: st={st} nt={nt}");
    }

    /// §3.3: at equal activity, 1p draws more than 0p; 2p sits in between.
    #[test]
    fn pipeline_power_ordering() {
        let a = act(8, false);
        let p = |pipe| power_mw(&ClusterConfig::new(8, 4, pipe), Corner::Nt, &a, 100.0);
        let (p0, p1, p2) = (p(0), p(1), p(2));
        assert!(p1 > p0, "pipe registers cost energy: {p1} vs {p0}");
        assert!(p2 < p1, "relaxed timing at 2p: {p2} vs {p1}");
        assert!(p2 > p0);
    }

    /// §3.3: removing the sharing interconnect at 1/1 offsets the extra
    /// units — power does not grow from 1/2 to 1/1 at equal activity.
    #[test]
    fn private_fpus_not_more_power_than_half_sharing() {
        let a = act(8, false);
        let half = power_mw(&ClusterConfig::new(8, 4, 1), Corner::Nt, &a, 100.0);
        let private = power_mw(&ClusterConfig::new(8, 8, 1), Corner::Nt, &a, 100.0);
        assert!(private < half * 1.05, "1/1={private} vs 1/2={half}");
    }

    /// Gated cores are nearly free: a cluster with half its cores asleep
    /// draws much less than one fully stalled.
    #[test]
    fn gating_saves_energy() {
        let mut asleep = act(16, false);
        asleep.active = 8.0 * 0.9;
        asleep.gated = 8.0 + 8.0 * 0.1;
        asleep.stalled = 0.0;
        let mut busy = act(16, false);
        busy.stalled += busy.gated;
        busy.gated = 0.0;
        let cfg = ClusterConfig::new(16, 16, 0);
        let e_sleep = energy_per_cycle_pj(&cfg, Corner::Nt, &asleep);
        let e_busy = energy_per_cycle_pj(&cfg, Corner::Nt, &busy);
        assert!(e_sleep < 0.75 * e_busy, "{e_sleep} vs {e_busy}");
    }

    /// Fig 5 ballpark: a 16-core NT cluster at 100 MHz draws a handful of mW.
    #[test]
    fn absolute_power_is_ulp_class() {
        let p = power_mw(&ClusterConfig::new(16, 16, 0), Corner::Nt, &act(16, true), 100.0);
        assert!(p > 3.0 && p < 30.0, "NT power at 100 MHz = {p} mW");
    }

    /// Clock-gating regression goldens (§Runtime of EXPERIMENTS.md): the
    /// energy deltas between gated and stalled cores are pinned against
    /// hand-computed constants — 1.738 pJ/core/cycle at NT (= (1.20 − 0.10)
    /// e-units × the 1.58 calibration factor). These lock the fig 5
    /// partial-occupancy numbers: an idle team member costs exactly the
    /// gated rate, never the stalled one.
    #[test]
    fn clock_gating_goldens() {
        let cfg = ClusterConfig::new(8, 8, 0);
        let zero = Activity {
            active: 0.0,
            stalled: 0.0,
            gated: 0.0,
            fp_scalar: 0.0,
            fp_vec: 0.0,
            tcdm: 0.0,
            ifetch: 0.0,
        };
        // All-gated vs all-stalled 8-core cluster: Δ = 8 × 1.738 pJ/cycle.
        let gated8 = Activity { gated: 8.0, ..zero };
        let stalled8 = Activity { stalled: 8.0, ..zero };
        let dg = energy_per_cycle_pj(&cfg, Corner::Nt, &stalled8)
            - energy_per_cycle_pj(&cfg, Corner::Nt, &gated8);
        assert!((dg - 8.0 * 1.738).abs() < 1e-9, "all-gated delta = {dg}");

        // 1-of-8 busy (barrier-idle imbalance): the 7 sleepers cost exactly
        // 7 × 1.738 pJ/cycle less than 7 stalled cores would.
        let one_busy_gated = Activity { active: 1.0, gated: 7.0, ifetch: 1.0, ..zero };
        let one_busy_stalled = Activity { active: 1.0, stalled: 7.0, ifetch: 1.0, ..zero };
        let d1 = energy_per_cycle_pj(&cfg, Corner::Nt, &one_busy_stalled)
            - energy_per_cycle_pj(&cfg, Corner::Nt, &one_busy_gated);
        assert!((d1 - 7.0 * 1.738).abs() < 1e-9, "1-of-8 delta = {d1}");

        // An all-gated core costs 0.158 pJ/cycle (0.10 × 1.58): the gated
        // vs zero-activity delta is exactly 8 of those.
        let dz = energy_per_cycle_pj(&cfg, Corner::Nt, &gated8)
            - energy_per_cycle_pj(&cfg, Corner::Nt, &zero);
        assert!((dz - 8.0 * 0.158).abs() < 1e-9, "gated floor delta = {dz}");

        // Zero-cycle program: Activity extraction degrades to the static
        // floor (no NaNs, no negative rates), identical to explicit zeros.
        let empty = RunStats { per_core: vec![], total_cycles: 0 };
        let a = Activity::from_stats(&empty);
        for r in [a.active, a.stalled, a.gated, a.fp_scalar, a.fp_vec, a.tcdm, a.ifetch] {
            assert_eq!(r, 0.0);
        }
        let e0 = energy_per_cycle_pj(&cfg, Corner::Nt, &a);
        assert!(e0.is_finite() && e0 > 0.0);
        assert_eq!(e0, energy_per_cycle_pj(&cfg, Corner::Nt, &zero));
    }

    /// `from_parts` (the cached-measurement path) is bit-identical to
    /// `from_stats` on imbalanced runs — fig 5 from the cache equals fig 5
    /// from a live simulation.
    #[test]
    fn from_parts_matches_from_stats() {
        use crate::cluster::counters::CoreCounters;
        let mk = |cycles: u64, active: u64, idle: u64| CoreCounters {
            cycles,
            active,
            barrier_idle: idle,
            fp_instrs: active / 3,
            fp_vec_instrs: active / 9,
            mem_instrs: active / 2,
            ..Default::default()
        };
        // 1-of-8-busy shape: core 0 runs the whole span, the rest sleep.
        let mut per_core = vec![mk(1000, 950, 0)];
        per_core.extend(std::iter::repeat(mk(1000, 20, 930)).take(7));
        let stats = RunStats { per_core: per_core.clone(), total_cycles: 1000 };
        let a = Activity::from_stats(&stats);
        let agg = stats.aggregate();
        let core_cycles: u64 = per_core.iter().map(|c| c.cycles).sum();
        let b = Activity::from_parts(&agg, 1000, 8, core_cycles);
        for (x, y) in [
            (a.active, b.active),
            (a.stalled, b.stalled),
            (a.gated, b.gated),
            (a.fp_scalar, b.fp_scalar),
            (a.fp_vec, b.fp_vec),
            (a.tcdm, b.tcdm),
            (a.ifetch, b.ifetch),
        ] {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn activity_extraction() {
        use crate::cluster::counters::{CoreCounters, RunStats};
        let c = CoreCounters {
            cycles: 100,
            active: 70,
            fp_instrs: 30,
            fp_vec_instrs: 10,
            mem_instrs: 20,
            barrier_idle: 10,
            ..Default::default()
        };
        let stats = RunStats { per_core: vec![c, c], total_cycles: 100 };
        let a = Activity::from_stats(&stats);
        assert!((a.active - 1.4).abs() < 1e-9);
        assert!((a.fp_scalar - 0.4).abs() < 1e-9);
        assert!((a.fp_vec - 0.2).abs() < 1e-9);
        assert!((a.tcdm - 0.4).abs() < 1e-9);
        assert!((a.gated - 0.2).abs() < 1e-9);
        assert!((a.stalled - 0.4).abs() < 1e-9);
    }
}
