//! Maximum-frequency model (Fig 3, §3.3).
//!
//! Synthesis/P&R is not available in this environment, so fmax is an
//! analytic model over the *structural critical paths the paper describes*,
//! calibrated to every number the paper states:
//!
//! * Table 6 anchors (worst-case corners): 16c16f1p @0.8 V = **0.37 GHz**,
//!   16c16f0p @0.65 V = **0.30 GHz**, 8c4f1p @0.8 V = **0.43 GHz**.
//! * §3.3 narrative: with 0 pipeline stages the ID/EX→FPU→EX/WB path
//!   dominates; adding one stage gains ~50% at NT but is capped at ST by the
//!   structural TCDM-SRAM→log-interconnect→core path; a second stage gains
//!   only slightly and at NT runs into I$-control paths; 16-core clusters
//!   are slower than 8-core ones (longer interconnect); the FPU sharing
//!   interconnect's frequency impact is "negligible" (small per-sharing
//!   period adder).
//!
//! The model returns the minimum over the candidate paths, each expressed as
//! a period in ns.

use crate::config::{ClusterConfig, Corner};

/// Critical-path periods in ns for a configuration/corner.
#[derive(Debug, Clone, Copy)]
pub struct Paths {
    /// ID/EX → (sharing interconnect) → FPU → EX/WB, shortened by pipelining.
    pub fpu: f64,
    /// TCDM SRAM → logarithmic interconnect → core (structural; ST-binding).
    pub tcdm: f64,
    /// Interconnect control → shared I$ (NT-binding at 2 stages).
    pub icache: f64,
}

/// Compute the candidate critical paths.
pub fn paths(cfg: &ClusterConfig, corner: Corner) -> Paths {
    // FPU datapath period by pipeline stages, per corner.
    let fpu_base = match (corner, cfg.pipe) {
        // NT: 0p → 1p is "almost 50%" (3.33 → 2.32 ns).
        (Corner::Nt, 0) => 3.333,
        (Corner::Nt, 1) => 2.320,
        (Corner::Nt, 2) => 2.260,
        // ST: proportionally faster cells.
        (Corner::St, 0) => 2.899,
        (Corner::St, 1) => 2.100,
        (Corner::St, 2) => 2.050,
        _ => unreachable!("pipe validated ≤ 2"),
    };
    // Sharing interconnect adds a negligible mux/tree delay that grows with
    // the sharing factor (log2 of cores-per-FPU); zero for private FPUs.
    let sharing_levels = (cfg.sharing_div() as f64).log2();
    let fpu = fpu_base * (1.0 + 0.006 * sharing_levels);

    // TCDM path: wide-voltage-range SRAMs are comparatively slow at ST
    // (§3.3), and the log interconnect deepens with the core count.
    let tcdm = match (corner, cfg.cores <= 8) {
        (Corner::St, true) => 2.326,  // ⇒ 430 MHz cap for the 8-core ST cluster
        (Corner::St, false) => 2.703, // ⇒ 370 MHz cap for the 16-core ST cluster
        // Wide-voltage-range SRAMs barely slow down at NT (§3.3): the TCDM
        // path is nearly flat across corners.
        (Corner::Nt, true) => 2.300,
        (Corner::Nt, false) => 2.700,
    };

    // I$ control path — the structurally binding path at NT once the FPU is
    // pipelined (§3.3 mentions it for the 2-stage NT configurations).
    let icache = match (corner, cfg.cores <= 8) {
        (Corner::Nt, true) => 2.340,
        (Corner::Nt, false) => 2.720,
        (Corner::St, true) => 2.000,
        (Corner::St, false) => 2.100,
    };

    Paths { fpu, tcdm, icache }
}

/// Maximum operating frequency in MHz (worst-case signoff corner, like the
/// paper's implementation flow).
pub fn fmax_mhz(cfg: &ClusterConfig, corner: Corner) -> f64 {
    let p = paths(cfg, corner);
    let period = p.fpu.max(p.tcdm).max(p.icache);
    1000.0 / period
}

/// Fig 3 helper: (min, median, max) fmax across the FPU counts available for
/// a given core count / pipeline / corner.
pub fn fig3_spread(cores: usize, pipe: u32, corner: Corner) -> (f64, f64, f64) {
    let mut f: Vec<f64> = [4usize, 2, 1]
        .iter()
        .map(|div| fmax_mhz(&ClusterConfig::new(cores, cores / div, pipe), corner))
        .collect();
    f.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (f[0], f[1], f[2])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol_pct: f64) -> bool {
        (a - b).abs() / b * 100.0 <= tol_pct
    }

    /// The three Table 6 frequency anchors hold within 2%.
    #[test]
    fn table6_anchors() {
        let f = fmax_mhz(&ClusterConfig::new(16, 16, 1), Corner::St);
        assert!(close(f, 370.0, 2.0), "16c16f1p ST = {f}");
        let f = fmax_mhz(&ClusterConfig::new(16, 16, 0), Corner::Nt);
        assert!(close(f, 300.0, 2.0), "16c16f0p NT = {f}");
        let f = fmax_mhz(&ClusterConfig::new(8, 4, 1), Corner::St);
        assert!(close(f, 430.0, 2.0), "8c4f1p ST = {f}");
    }

    /// §3.3: NT gains ~50% from 0p→1p; ST gains much less (TCDM-capped).
    #[test]
    fn pipelining_gains_match_narrative() {
        let nt0 = fmax_mhz(&ClusterConfig::new(8, 8, 0), Corner::Nt);
        let nt1 = fmax_mhz(&ClusterConfig::new(8, 8, 1), Corner::Nt);
        let gain_nt = nt1 / nt0;
        assert!(gain_nt > 1.40 && gain_nt < 1.55, "NT 0p→1p gain = {gain_nt}");

        let st0 = fmax_mhz(&ClusterConfig::new(8, 8, 0), Corner::St);
        let st1 = fmax_mhz(&ClusterConfig::new(8, 8, 1), Corner::St);
        let gain_st = st1 / st0;
        assert!(gain_st < gain_nt, "ST gain must be structurally capped");
        assert!(gain_st > 1.0 && gain_st < 1.3, "ST 0p→1p gain = {gain_st}");
    }

    /// §3.3: the second stage adds only slightly, never hurts fmax.
    #[test]
    fn second_stage_slight_increase() {
        for corner in [Corner::Nt, Corner::St] {
            for cores in [8usize, 16] {
                let f1 = fmax_mhz(&ClusterConfig::new(cores, cores, 1), corner);
                let f2 = fmax_mhz(&ClusterConfig::new(cores, cores, 2), corner);
                assert!(f2 >= f1, "{cores}c {corner}: f2={f2} < f1={f1}");
                assert!(f2 / f1 < 1.10, "2p gain should be slight: {}", f2 / f1);
            }
        }
    }

    /// §3.3: 16-core clusters run slower than 8-core ones.
    #[test]
    fn sixteen_cores_slower() {
        for corner in [Corner::Nt, Corner::St] {
            for pipe in 0..=2 {
                let f8 = fmax_mhz(&ClusterConfig::new(8, 8, pipe), corner);
                let f16 = fmax_mhz(&ClusterConfig::new(16, 16, pipe), corner);
                assert!(f16 <= f8, "pipe={pipe} {corner}: 16c must not be faster");
            }
        }
    }

    /// §3.2/§3.3: sharing-interconnect impact on fmax is negligible (<2%).
    #[test]
    fn sharing_impact_negligible() {
        for pipe in 0..=2 {
            let (lo, _, hi) = fig3_spread(8, pipe, Corner::St);
            assert!((hi - lo) / hi < 0.02, "pipe={pipe}: spread {lo}..{hi}");
        }
    }

    /// NT is always slower than ST for the same configuration.
    #[test]
    fn nt_slower_than_st() {
        for cfg in ClusterConfig::design_space() {
            assert!(fmax_mhz(&cfg, Corner::Nt) <= fmax_mhz(&cfg, Corner::St) + 1e-9, "{cfg}");
        }
    }
}
