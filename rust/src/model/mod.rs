//! Analytic frequency / power / area models (Figs 3–5), calibrated to every
//! anchor the paper publishes (Table 6 triples, §3.3 narrative, Tables 4/5
//! efficiency peaks). The simulator produces cycles and activity; these
//! models convert them into Gflop/s, Gflop/s/W and Gflop/s/mm².

pub mod area;
pub mod freq;
pub mod power;

pub use area::area_mm2;
pub use freq::{fig3_spread, fmax_mhz};
pub use power::{energy_per_cycle_pj, gflops_per_watt, power_mw, Activity};

use crate::cluster::counters::RunStats;
use crate::config::{ClusterConfig, Corner};

/// The three paper metrics for one (config, benchmark) measurement.
#[derive(Debug, Clone, Copy)]
pub struct Metrics {
    /// Gflop/s at the ST fmax (Tables 4/5 compute performance at 0.8 V).
    pub perf_gflops: f64,
    /// Gflop/s/W at NT (Tables 4/5 compute energy efficiency at 0.65 V).
    pub energy_eff: f64,
    /// Gflop/s/mm² at ST.
    pub area_eff: f64,
    /// Raw flops/cycle (frequency-independent).
    pub flops_per_cycle: f64,
}

/// Convert a run into the paper's three metrics.
pub fn metrics(cfg: &ClusterConfig, stats: &RunStats) -> Metrics {
    let fpc = stats.flops_per_cycle();
    let act = Activity::from_stats(stats);
    let f_st = fmax_mhz(cfg, Corner::St);
    let perf = fpc * f_st * 1e6 / 1e9;
    let eff = gflops_per_watt(cfg, Corner::Nt, &act, fpc);
    let aeff = perf / area_mm2(cfg);
    Metrics { perf_gflops: perf, energy_eff: eff, area_eff: aeff, flops_per_cycle: fpc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::counters::CoreCounters;

    #[test]
    fn metrics_pipeline() {
        let cfg = ClusterConfig::new(16, 16, 1);
        let c = CoreCounters {
            cycles: 1000,
            active: 900,
            instrs: 900,
            fp_instrs: 300,
            fp_vec_instrs: 300,
            flops: 1200,
            mem_instrs: 300,
            ..Default::default()
        };
        let stats = RunStats { per_core: vec![c; 16], total_cycles: 1000 };
        let m = metrics(&cfg, &stats);
        // 19.2 flops/cycle at 370 MHz ≈ 7.1 Gflop/s.
        assert!((m.flops_per_cycle - 19.2).abs() < 1e-9);
        assert!(m.perf_gflops > 6.5 && m.perf_gflops < 7.6, "{}", m.perf_gflops);
        assert!(m.energy_eff > 50.0 && m.energy_eff < 400.0, "{}", m.energy_eff);
        assert!((m.area_eff - m.perf_gflops / area_mm2(&cfg)).abs() < 1e-9);
    }
}
