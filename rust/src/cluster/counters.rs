//! Per-core performance counters, mirroring the non-intrusive counters of
//! the paper's FPGA emulator (§5.1): "total, active, L2/TCDM memory stalls,
//! TCDM contention, FPU stall, FPU contention, FPU write-back stall,
//! instruction cache miss".

/// Counters recorded by one core during a run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoreCounters {
    /// Wall-clock cycles from reset to this core's `End`.
    pub cycles: u64,
    /// Cycles in which the core issued (or was executing a multi-cycle
    /// integer op) — the "active" state of §5.1.
    pub active: u64,
    /// Retired instructions.
    pub instrs: u64,
    /// Retired integer/control instructions.
    pub int_instrs: u64,
    /// Retired FP instructions (FPU + DIV-SQRT + moves/casts).
    pub fp_instrs: u64,
    /// Of which packed-SIMD (both 16-bit lanes active).
    pub fp_vec_instrs: u64,
    /// Retired loads/stores.
    pub mem_instrs: u64,
    /// Floating-point operations performed (FMA = 2, SIMD ×lanes).
    pub flops: u64,
    /// Stall cycles waiting on a TCDM bank lost to another core.
    pub tcdm_cont: u64,
    /// Stall cycles on L2 accesses (latency) and DMA waits.
    pub l2_stall: u64,
    /// Stall cycles waiting for an FP result (FPU latency / load-use on FP).
    pub fpu_stall: u64,
    /// Stall cycles losing FPU-port arbitration to another core.
    pub fpu_cont: u64,
    /// Stall cycles waiting for the shared DIV-SQRT block.
    pub divsqrt_cont: u64,
    /// Write-back port conflicts between a delayed FPU result and an
    /// integer/LSU write (§5.3.3).
    pub wb_stall: u64,
    /// Load-use interlock stalls on integer loads.
    pub load_stall: u64,
    /// Instruction-cache miss stall cycles.
    pub icache_stall: u64,
    /// Cycles asleep at an event-unit barrier (clock-gated; §5.3 notes these
    /// cycles are cheap thanks to the power-saving policies).
    pub barrier_idle: u64,
    /// Taken-branch penalty cycles.
    pub branch_stall: u64,
}

impl CoreCounters {
    /// Sum of all categorized non-active cycles (diagnostic).
    pub fn stalls(&self) -> u64 {
        self.tcdm_cont
            + self.l2_stall
            + self.fpu_stall
            + self.fpu_cont
            + self.divsqrt_cont
            + self.wb_stall
            + self.load_stall
            + self.icache_stall
            + self.barrier_idle
            + self.branch_stall
    }

    /// FP intensity: FP instructions / total instructions (Table 3).
    pub fn fp_intensity(&self) -> f64 {
        if self.instrs == 0 {
            0.0
        } else {
            self.fp_instrs as f64 / self.instrs as f64
        }
    }

    /// Memory intensity: loads+stores / total instructions (Table 3).
    pub fn mem_intensity(&self) -> f64 {
        if self.instrs == 0 {
            0.0
        } else {
            self.mem_instrs as f64 / self.instrs as f64
        }
    }

    /// Stall cycles by cause, in field order, with their stable names —
    /// the taxonomy the trace layer's `StallCause` mirrors.
    pub fn stall_breakdown(&self) -> [(&'static str, u64); 10] {
        [
            ("tcdm_cont", self.tcdm_cont),
            ("l2_stall", self.l2_stall),
            ("fpu_stall", self.fpu_stall),
            ("fpu_cont", self.fpu_cont),
            ("divsqrt_cont", self.divsqrt_cont),
            ("wb_stall", self.wb_stall),
            ("load_stall", self.load_stall),
            ("icache_stall", self.icache_stall),
            ("barrier_idle", self.barrier_idle),
            ("branch_stall", self.branch_stall),
        ]
    }

    /// Field-wise difference `self − prev`, used by the trace layer's
    /// snapshot-diff attribution. Wrapping so a partial snapshot can never
    /// panic; in normal use counters only grow.
    pub fn delta_from(&self, prev: &CoreCounters) -> CoreCounters {
        CoreCounters {
            cycles: self.cycles.wrapping_sub(prev.cycles),
            active: self.active.wrapping_sub(prev.active),
            instrs: self.instrs.wrapping_sub(prev.instrs),
            int_instrs: self.int_instrs.wrapping_sub(prev.int_instrs),
            fp_instrs: self.fp_instrs.wrapping_sub(prev.fp_instrs),
            fp_vec_instrs: self.fp_vec_instrs.wrapping_sub(prev.fp_vec_instrs),
            mem_instrs: self.mem_instrs.wrapping_sub(prev.mem_instrs),
            flops: self.flops.wrapping_sub(prev.flops),
            tcdm_cont: self.tcdm_cont.wrapping_sub(prev.tcdm_cont),
            l2_stall: self.l2_stall.wrapping_sub(prev.l2_stall),
            fpu_stall: self.fpu_stall.wrapping_sub(prev.fpu_stall),
            fpu_cont: self.fpu_cont.wrapping_sub(prev.fpu_cont),
            divsqrt_cont: self.divsqrt_cont.wrapping_sub(prev.divsqrt_cont),
            wb_stall: self.wb_stall.wrapping_sub(prev.wb_stall),
            load_stall: self.load_stall.wrapping_sub(prev.load_stall),
            icache_stall: self.icache_stall.wrapping_sub(prev.icache_stall),
            barrier_idle: self.barrier_idle.wrapping_sub(prev.barrier_idle),
            branch_stall: self.branch_stall.wrapping_sub(prev.branch_stall),
        }
    }

    /// Field-wise accumulate. Unlike [`CoreCounters::merge`] (which takes
    /// the max of wall-clock `cycles`), this sums `cycles` too — the
    /// operand is an interval delta, not a whole-run counter set.
    pub fn accumulate(&mut self, d: &CoreCounters) {
        self.cycles += d.cycles;
        self.active += d.active;
        self.instrs += d.instrs;
        self.int_instrs += d.int_instrs;
        self.fp_instrs += d.fp_instrs;
        self.fp_vec_instrs += d.fp_vec_instrs;
        self.mem_instrs += d.mem_instrs;
        self.flops += d.flops;
        self.tcdm_cont += d.tcdm_cont;
        self.l2_stall += d.l2_stall;
        self.fpu_stall += d.fpu_stall;
        self.fpu_cont += d.fpu_cont;
        self.divsqrt_cont += d.divsqrt_cont;
        self.wb_stall += d.wb_stall;
        self.load_stall += d.load_stall;
        self.icache_stall += d.icache_stall;
        self.barrier_idle += d.barrier_idle;
        self.branch_stall += d.branch_stall;
    }

    /// Accumulate another core's counters (for cluster aggregates).
    pub fn merge(&mut self, o: &CoreCounters) {
        self.cycles = self.cycles.max(o.cycles);
        self.active += o.active;
        self.instrs += o.instrs;
        self.int_instrs += o.int_instrs;
        self.fp_instrs += o.fp_instrs;
        self.fp_vec_instrs += o.fp_vec_instrs;
        self.mem_instrs += o.mem_instrs;
        self.flops += o.flops;
        self.tcdm_cont += o.tcdm_cont;
        self.l2_stall += o.l2_stall;
        self.fpu_stall += o.fpu_stall;
        self.fpu_cont += o.fpu_cont;
        self.divsqrt_cont += o.divsqrt_cont;
        self.wb_stall += o.wb_stall;
        self.load_stall += o.load_stall;
        self.icache_stall += o.icache_stall;
        self.barrier_idle += o.barrier_idle;
        self.branch_stall += o.branch_stall;
    }
}

/// Whole-cluster result of a run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Per-core counters.
    pub per_core: Vec<CoreCounters>,
    /// Total cycles until the last core finished.
    pub total_cycles: u64,
}

impl RunStats {
    /// Aggregate counters over all cores.
    pub fn aggregate(&self) -> CoreCounters {
        let mut agg = CoreCounters::default();
        for c in &self.per_core {
            agg.merge(c);
        }
        agg.cycles = self.total_cycles;
        agg
    }

    /// Total flops across the cluster.
    pub fn flops(&self) -> u64 {
        self.per_core.iter().map(|c| c.flops).sum()
    }

    /// Flops per cycle — the frequency-independent performance figure the
    /// analytic models scale by fmax.
    pub fn flops_per_cycle(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.flops() as f64 / self.total_cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensities() {
        let c = CoreCounters { instrs: 100, fp_instrs: 28, mem_instrs: 58, ..Default::default() };
        assert!((c.fp_intensity() - 0.28).abs() < 1e-12);
        assert!((c.mem_intensity() - 0.58).abs() < 1e-12);
        assert_eq!(CoreCounters::default().fp_intensity(), 0.0);
    }

    #[test]
    fn delta_and_accumulate_round_trip() {
        let prev = CoreCounters { cycles: 10, active: 6, tcdm_cont: 4, ..Default::default() };
        let now = CoreCounters { cycles: 25, active: 14, tcdm_cont: 9, instrs: 7, ..Default::default() };
        let d = now.delta_from(&prev);
        assert_eq!(d.cycles, 15);
        assert_eq!(d.active, 8);
        assert_eq!(d.tcdm_cont, 5);
        assert_eq!(d.instrs, 7);
        let mut acc = prev;
        acc.accumulate(&d);
        assert_eq!(acc, now);
        let names: Vec<&str> = now.stall_breakdown().iter().map(|(n, _)| *n).collect();
        assert_eq!(names.len(), 10);
        assert_eq!(now.stall_breakdown()[0], ("tcdm_cont", 9));
        // The breakdown must cover stalls() exactly — no hidden bucket.
        let sum: u64 = now.stall_breakdown().iter().map(|(_, v)| v).sum();
        assert_eq!(sum, now.stalls());
    }

    #[test]
    fn merge_and_aggregate() {
        let a = CoreCounters { cycles: 100, flops: 10, instrs: 50, ..Default::default() };
        let b = CoreCounters { cycles: 120, flops: 14, instrs: 60, ..Default::default() };
        let stats = RunStats { per_core: vec![a, b], total_cycles: 120 };
        let agg = stats.aggregate();
        assert_eq!(agg.cycles, 120);
        assert_eq!(agg.flops, 24);
        assert_eq!(agg.instrs, 110);
        assert!((stats.flops_per_cycle() - 0.2).abs() < 1e-12);
    }
}
