//! Cycle-accurate cluster simulator (§3.1–§3.2).
//!
//! The [`Cluster`] owns the cores, the banked TCDM, the shared FPU
//! subsystem, the DIV-SQRT block, the shared I$ model and the event unit,
//! and advances them under a single global clock. Arbitration fairness
//! (round-robin of the FPU interconnect and TCDM logarithmic interconnect)
//! is modelled by rotating the core issue order every cycle.
//!
//! Timing model summary (per instruction class):
//!
//! | class | issue→reuse | result→consumer |
//! |---|---|---|
//! | int ALU / Li | 1 cycle | next cycle (full forwarding) |
//! | int div/rem | 35 cycles (iterative, core blocks) | at completion |
//! | load (TCDM) | 1 cycle + bank contention retries | +2 (1 load-use bubble) |
//! | load/store (L2) | 15 cycles (core blocks on the demux) | at completion |
//! | taken branch | 3 cycles (2 flush bubbles) | — |
//! | hw-loop back-edge | 0 overhead | — |
//! | FP (FPU) | 1 cycle + port contention retries | +1+`pipe` cycles |
//! | FP div/sqrt | 1 cycle + unit-busy wait | 11/7/6 cycles (f32/f16/bf16) |
//! | barrier | sleeps until all arrive, +2 wake | — |
//!
//! With `pipe == 2` an FP result's delayed write-back conflicts with the
//! register-file write of an int/LSU instruction issued in the immediately
//! following slot (§5.3.3) — modelled as a 1-cycle `wb_stall`.

pub mod core;
pub mod counters;
pub mod event;
pub mod fpu;
pub mod icache;
pub mod mem;

use crate::config::ClusterConfig;
use crate::isa::insn::Insn;
use crate::isa::Program;

use self::core::{Core, CoreState, Producer};
use self::counters::{CoreCounters, RunStats};
use self::event::EventUnit;
use self::fpu::FpuSubsystem;
use self::icache::ICache;
use self::mem::{Memory, Region};

/// Latency of the iterative integer divider (RI5CY serial divider).
const INT_DIV_LATENCY: u64 = 35;
/// Taken-branch penalty (total cycles occupied by the branch).
const TAKEN_BRANCH_CYCLES: u64 = 3;

/// The simulated cluster.
pub struct Cluster {
    /// Configuration under simulation.
    pub cfg: ClusterConfig,
    /// Cores.
    pub cores: Vec<Core>,
    /// TCDM + L2.
    pub mem: Memory,
    /// Shared FPUs + DIV-SQRT.
    pub fpus: FpuSubsystem,
    /// Shared instruction cache.
    pub icache: ICache,
    /// Event unit (barriers).
    pub event: EventUnit,
    /// The SPMD program all cores run.
    program: Program,
    /// Current cycle.
    pub now: u64,
    /// Hard cycle limit (deadlock guard).
    pub max_cycles: u64,
    /// Disable I$ cold-miss modelling (always-hit). Used by micro-timing
    /// tests that reason about exact cycle counts.
    pub perfect_icache: bool,
    /// Issue tracing enabled (TRANSPFP_TRACE env var, cached at build time —
    /// checking the environment per issued instruction costs ~30% of the
    /// whole simulator; see EXPERIMENTS.md §Perf).
    trace: bool,
}

impl Cluster {
    /// Build a cluster running `program` on every core.
    pub fn new(cfg: ClusterConfig, program: Program) -> Self {
        let cores = (0..cfg.cores).map(|i| Core::new(i, cfg.cores)).collect();
        Cluster {
            cores,
            mem: Memory::new(&cfg),
            fpus: FpuSubsystem::new(cfg.fpus),
            icache: ICache::new(program.len()),
            event: EventUnit::new(cfg.cores),
            program,
            now: 0,
            max_cycles: 2_000_000_000,
            perfect_icache: false,
            trace: std::env::var_os("TRANSPFP_TRACE").is_some(),
            cfg,
        }
    }

    /// Restrict execution to the first `n` cores; the rest terminate
    /// immediately (used by the Fig 6 speed-up sweeps, which run 1..=N
    /// workers on an N-core cluster). The event unit is resized so barriers
    /// wait only for active workers — the paper's kernels take the worker
    /// count as a parameter (§5.2).
    pub fn limit_active_cores(&mut self, n: usize) {
        assert!(n >= 1 && n <= self.cfg.cores);
        for c in self.cores.iter_mut().skip(n) {
            c.state = CoreState::Done;
        }
        self.event = EventUnit::new(n);
        // The HAL reports the worker count, not the physical core count.
        for c in self.cores.iter_mut().take(n) {
            c.set_reg(crate::isa::regs::NCORES, n as u32);
        }
    }

    /// Run to completion; returns per-core counters.
    pub fn run(&mut self) -> RunStats {
        while self.now < self.max_cycles {
            if self.step() {
                break;
            }
        }
        assert!(self.now < self.max_cycles, "simulation exceeded max_cycles (deadlock?)");
        let per_core: Vec<CoreCounters> = self.cores.iter().map(|c| c.counters).collect();
        let total_cycles = per_core.iter().map(|c| c.cycles).max().unwrap_or(0);
        RunStats { per_core, total_cycles }
    }

    /// Advance one cycle. Returns true when every core is done.
    fn step(&mut self) -> bool {
        let n = self.cores.len();
        let rot = (self.now as usize) % n;
        let mut all_done = true;
        let mut min_next = u64::MAX;
        for k in 0..n {
            // Branch instead of modulo: the `%` showed up in the profile.
            let ci = if rot + k >= n { rot + k - n } else { rot + k };
            match self.cores[ci].state {
                CoreState::Done => continue,
                CoreState::Sleeping { .. } => {
                    all_done = false;
                    continue; // woken by the barrier completion
                }
                CoreState::Running => {
                    all_done = false;
                    if self.cores[ci].next_issue > self.now {
                        min_next = min_next.min(self.cores[ci].next_issue);
                        continue;
                    }
                    self.issue(ci);
                    min_next = min_next.min(self.cores[ci].next_issue);
                }
            }
        }
        if all_done {
            return true;
        }
        // Fast-forward across cycles where no core can issue (barrier sleeps
        // resolve inside issue(); DIV-SQRT / L2 waits are bulk-attributed).
        self.now = if min_next == u64::MAX { self.now + 1 } else { min_next.max(self.now + 1) };
        false
    }

    /// Attempt to issue the next instruction of core `ci` at `self.now`.
    fn issue(&mut self, ci: usize) {
        let t = self.now;
        let insn = self.program.insns[self.cores[ci].pc as usize];
        if self.trace {
            eprintln!("t={t} core={ci} pc={} {:?}", self.cores[ci].pc, insn);
        }

        // 1. Instruction fetch through the shared I$.
        let fetched =
            if self.perfect_icache { t } else { self.icache.fetch(self.cores[ci].pc, t) };
        if fetched > t {
            let c = &mut self.cores[ci];
            c.counters.icache_stall += fetched - t;
            c.next_issue = fetched;
            return;
        }

        // 2. Operand scoreboard.
        let (ready, who) = self.cores[ci].operands_ready(&insn);
        if ready > t {
            let c = &mut self.cores[ci];
            let wait = ready - t;
            match who {
                Producer::Fpu | Producer::DivSqrt => c.counters.fpu_stall += wait,
                Producer::Load => c.counters.load_stall += wait,
                Producer::None => {}
            }
            c.next_issue = ready;
            return;
        }

        // 3. Write-back port conflict (§5.3.3): only with 2 pipeline stages,
        // when an int/LSU write follows an FP op back-to-back. The FPU's
        // result skid register absorbs two of every three collisions, so one
        // in three costs a stall (matching the ~10% penalty of Fig 8).
        if self.cfg.pipe >= 2
            && !insn.is_fp()
            && writes_reg(&insn)
            && self.cores[ci].last_fp_issue == t.wrapping_sub(1)
        {
            let c = &mut self.cores[ci];
            c.wb_skid += 1;
            if c.wb_skid >= 3 {
                c.wb_skid = 0;
                c.counters.wb_stall += 1;
                c.next_issue = t + 1;
                return;
            }
        }

        // 4. Class-specific structural hazards + execution.
        match insn {
            Insn::Alu { op, rd, rs1, rhs } => {
                let c = &mut self.cores[ci];
                c.exec_alu(op, rd, rs1, rhs);
                let lat = if matches!(op, crate::isa::AluOp::Div | crate::isa::AluOp::Rem) {
                    INT_DIV_LATENCY
                } else {
                    1
                };
                c.counters.active += lat;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.next_issue = t + lat;
                c.advance_pc();
            }
            Insn::Li { rd, imm } => {
                let c = &mut self.cores[ci];
                c.set_reg(rd, imm);
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.next_issue = t + 1;
                c.advance_pc();
            }
            Insn::Load { rd, base, offset, post_inc, size } => {
                let addr =
                    (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                match self.mem.region_of(addr) {
                    Region::Tcdm => {
                        let bank = self.mem.bank_of(addr);
                        if !self.mem.claim_bank(bank, t) {
                            let c = &mut self.cores[ci];
                            c.counters.tcdm_cont += 1;
                            c.next_issue = t + 1;
                            return;
                        }
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        c.exec_load(&self.mem, rd, addr, size);
                        c.reg_ready[rd as usize] = t + 2; // 1 load-use bubble
                        c.reg_producer[rd as usize] = Producer::Load;
                        c.counters.active += 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + 1;
                        c.advance_pc();
                    }
                    Region::L2 => {
                        let lat = self.cfg.l2_latency();
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        c.exec_load(&self.mem, rd, addr, size);
                        c.counters.active += 1;
                        c.counters.l2_stall += lat - 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + lat; // core blocks on the demux
                        c.advance_pc();
                    }
                }
            }
            Insn::Store { rs, base, offset, post_inc, size } => {
                let addr =
                    (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                match self.mem.region_of(addr) {
                    Region::Tcdm => {
                        let bank = self.mem.bank_of(addr);
                        if !self.mem.claim_bank(bank, t) {
                            let c = &mut self.cores[ci];
                            c.counters.tcdm_cont += 1;
                            c.next_issue = t + 1;
                            return;
                        }
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        let v = c.reg(rs);
                        self.mem.store(addr, size, v);
                        c.counters.active += 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + 1;
                        c.advance_pc();
                    }
                    Region::L2 => {
                        let lat = self.cfg.l2_latency();
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        let v = c.reg(rs);
                        self.mem.store(addr, size, v);
                        c.counters.active += 1;
                        c.counters.l2_stall += lat - 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + lat;
                        c.advance_pc();
                    }
                }
            }
            Insn::Branch { cond, rs1, rs2, target } => {
                let c = &mut self.cores[ci];
                let taken = c.branch_taken(cond, rs1, rs2);
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                if taken {
                    c.pc = target;
                    c.counters.branch_stall += TAKEN_BRANCH_CYCLES - 1;
                    c.next_issue = t + TAKEN_BRANCH_CYCLES;
                } else {
                    c.next_issue = t + 1;
                    c.advance_pc();
                }
            }
            Insn::Jump { target } => {
                let c = &mut self.cores[ci];
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.pc = target;
                c.counters.branch_stall += TAKEN_BRANCH_CYCLES - 1;
                c.next_issue = t + TAKEN_BRANCH_CYCLES;
            }
            Insn::HwLoop { count, start, end } => {
                let c = &mut self.cores[ci];
                let n = c.reg(count);
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.next_issue = t + 1;
                if n == 0 {
                    c.pc = end;
                } else {
                    c.hwloops.push((start, end, n));
                    c.pc = start;
                }
            }
            Insn::Fp { op, mode, rd, rs1, rs2 } => {
                if op.is_alu_class() {
                    // Integer-SIMD lane permutation: plain 1-cycle ALU op.
                    let c = &mut self.cores[ci];
                    c.exec_fp(op, mode, rd, rs1, rs2);
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    c.next_issue = t + 1;
                    c.advance_pc();
                } else if op.is_divsqrt() {
                    match self.fpus.try_divsqrt(mode, t) {
                        Err(free) => {
                            let c = &mut self.cores[ci];
                            c.counters.divsqrt_cont += free - t;
                            c.next_issue = free;
                        }
                        Ok(done) => {
                            let c = &mut self.cores[ci];
                            let flops = c.exec_fp(op, mode, rd, rs1, rs2);
                            c.reg_ready[rd as usize] = done;
                            c.reg_producer[rd as usize] = Producer::DivSqrt;
                            c.counters.active += 1;
                            c.counters.instrs += 1;
                            c.counters.fp_instrs += 1;
                            c.counters.flops += flops;
                            c.next_issue = t + 1;
                            c.advance_pc();
                        }
                    }
                } else {
                    let fpu = self.cfg.fpu_of_core(ci);
                    if !self.fpus.try_issue(fpu, t) {
                        let c = &mut self.cores[ci];
                        c.counters.fpu_cont += 1;
                        c.next_issue = t + 1;
                        return;
                    }
                    let pipe = self.cfg.pipe as u64;
                    let c = &mut self.cores[ci];
                    let flops = c.exec_fp(op, mode, rd, rs1, rs2);
                    c.reg_ready[rd as usize] = t + 1 + pipe;
                    c.reg_producer[rd as usize] = Producer::Fpu;
                    c.last_fp_issue = t;
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.fp_instrs += 1;
                    if mode.is_vector() {
                        c.counters.fp_vec_instrs += 1;
                    }
                    c.counters.flops += flops;
                    c.next_issue = t + 1;
                    c.advance_pc();
                }
            }
            Insn::Barrier => {
                // Count the barrier instruction itself.
                {
                    let c = &mut self.cores[ci];
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    c.advance_pc();
                }
                match self.event.arrive(ci, t) {
                    Some(wake) => {
                        // Wake everyone (including self).
                        for c in self.cores.iter_mut() {
                            match c.state {
                                CoreState::Sleeping { since } => {
                                    c.counters.barrier_idle += wake - since;
                                    c.state = CoreState::Running;
                                    c.next_issue = wake;
                                }
                                CoreState::Running if c.id == ci => {
                                    c.counters.barrier_idle += wake - (t + 1);
                                    c.next_issue = wake;
                                }
                                _ => {}
                            }
                        }
                    }
                    None => {
                        let c = &mut self.cores[ci];
                        c.state = CoreState::Sleeping { since: t + 1 };
                        c.next_issue = u64::MAX; // woken explicitly
                    }
                }
            }
            Insn::End => {
                let c = &mut self.cores[ci];
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.cycles = t;
                c.state = CoreState::Done;
            }
        }
    }
}

impl Core {
    /// Advance past the current instruction, honouring hardware loops.
    fn advance_pc(&mut self) {
        let mut next = self.pc + 1;
        while let Some((start, end, remaining)) = self.hwloops.last_mut() {
            if next == *end {
                if *remaining > 1 {
                    *remaining -= 1;
                    next = *start;
                    break;
                } else {
                    self.hwloops.pop();
                    // fall through: check enclosing loop against `next`
                }
            } else {
                break;
            }
        }
        self.pc = next;
    }
}

/// Does the instruction write an integer/FP destination register?
fn writes_reg(i: &Insn) -> bool {
    match i {
        Insn::Alu { .. } | Insn::Li { .. } | Insn::Load { .. } => true,
        // Post-increment stores update the base register.
        Insn::Store { post_inc, .. } => *post_inc != 0,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{regs, ProgramBuilder};
    use crate::transfp::FpMode;

    fn cfg(c: usize, f: usize, p: u32) -> ClusterConfig {
        ClusterConfig::new(c, f, p)
    }

    /// A one-core program that stores 1+2 to TCDM.
    #[test]
    fn minimal_program_runs() {
        let mut b = ProgramBuilder::new("min");
        b.li(1, 1).li(2, 2).add(3, 1, 2);
        b.li(4, mem::TCDM_BASE).sw(3, 4, 0).end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        let stats = cl.run();
        assert_eq!(cl.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word), 3);
        // All 8 cores ran the same SPMD program; the stores collide benignly.
        assert_eq!(stats.per_core.len(), 8);
        assert!(stats.total_cycles > 0);
    }

    /// Hardware loops execute the body exactly `count` times, zero overhead.
    #[test]
    fn hwloop_iterations_and_zero_overhead() {
        let mut b = ProgramBuilder::new("hwl");
        b.li(1, 10); // count
        b.li(2, 0); // acc
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.hwloop_end();
        b.li(5, mem::TCDM_BASE).sw(2, 5, 0).end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        let stats = cl.run();
        assert_eq!(cl.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word), 10);
        // Body = 10 instructions total for the loop, no branch penalties.
        let c = &stats.per_core[0];
        assert_eq!(c.branch_stall, 0);
        assert_eq!(c.instrs, 3 + 10 + 3);
    }

    /// Nested hardware loops.
    #[test]
    fn nested_hwloops() {
        let mut b = ProgramBuilder::new("hwl2");
        b.li(1, 3).li(2, 4).li(3, 0);
        b.hwloop(1);
        b.hwloop(2);
        b.addi(3, 3, 1);
        b.hwloop_end();
        b.hwloop_end();
        b.li(5, mem::TCDM_BASE).sw(3, 5, 0).end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        cl.run();
        assert_eq!(cl.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word), 12);
    }

    /// FP latency: dependent back-to-back FMAs stall `pipe` cycles each.
    #[test]
    fn fp_dependency_stalls_scale_with_pipe() {
        let run = |pipe: u32| -> u64 {
            let mut b = ProgramBuilder::new("dep");
            b.li(1, 1065353216); // 1.0f32
            b.li(2, 1065353216);
            b.li(3, 0);
            for _ in 0..32 {
                b.fmac(FpMode::F32, 3, 1, 2); // each depends on previous (rd acc)
            }
            b.end();
            let mut cl = Cluster::new(cfg(8, 8, pipe), b.build());
            cl.perfect_icache = true;
            cl.limit_active_cores(1);
            let stats = cl.run();
            stats.per_core[0].fpu_stall
        };
        assert_eq!(run(0), 0);
        assert_eq!(run(1), 32 - 1); // first has no predecessor in flight
        assert_eq!(run(2), 2 * 31);
    }

    /// FPU sharing: two cores on one FPU contend; private FPUs don't.
    #[test]
    fn fpu_contention_depends_on_sharing() {
        let prog = || {
            let mut b = ProgramBuilder::new("cont");
            b.li(1, 1065353216);
            b.li(2, 1065353216);
            // Independent FP ops (different destinations) — saturate the port.
            for i in 0..16 {
                b.fadd(FpMode::F32, 20 + (i % 8) as u8, 1, 2);
            }
            b.end();
            b.build()
        };
        let mut shared = Cluster::new(cfg(8, 2, 1), prog());
        let s = shared.run();
        let cont: u64 = s.per_core.iter().map(|c| c.fpu_cont).sum();
        assert!(cont > 0, "4 cores per FPU must contend");

        let mut private = Cluster::new(cfg(8, 8, 1), prog());
        let p = private.run();
        let cont_p: u64 = p.per_core.iter().map(|c| c.fpu_cont).sum();
        assert_eq!(cont_p, 0, "private FPUs never contend");
        assert!(s.total_cycles > p.total_cycles);
    }

    /// Barrier synchronizes cores with different amounts of work.
    #[test]
    fn barrier_waits_for_slowest() {
        let mut b = ProgramBuilder::new("bar");
        // Core 0 does extra work before the barrier.
        b.bne(regs::CORE_ID, regs::ZERO, "sync");
        b.li(1, 200);
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.hwloop_end();
        b.label("sync");
        b.barrier();
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        let stats = cl.run();
        // Everyone finishes at roughly the same cycle, after core 0's work.
        let idle: u64 = stats.per_core.iter().map(|c| c.barrier_idle).sum();
        assert!(idle > 7 * 150, "waiters must have slept: {idle}");
        let spread = stats.per_core.iter().map(|c| c.cycles).max().unwrap()
            - stats.per_core.iter().map(|c| c.cycles).min().unwrap();
        assert!(spread <= 16, "cores should finish together, spread={spread}");
    }

    /// TCDM bank conflicts: all cores hammering one bank contend; separate
    /// banks don't.
    #[test]
    fn tcdm_bank_conflicts() {
        let same_bank = {
            let mut b = ProgramBuilder::new("same");
            b.li(1, mem::TCDM_BASE);
            b.li(3, 64);
            b.hwloop(3);
            b.lw(2, 1, 0); // every core, same address → same bank
            b.hwloop_end();
            b.end();
            let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
            let s = cl.run();
            s.per_core.iter().map(|c| c.tcdm_cont).sum::<u64>()
        };
        let spread_banks = {
            let mut b = ProgramBuilder::new("spread");
            b.li(1, mem::TCDM_BASE);
            b.slli(4, regs::CORE_ID, 2);
            b.add(1, 1, 4); // each core its own word → its own bank
            b.li(3, 64);
            b.hwloop(3);
            b.lw(2, 1, 0);
            b.hwloop_end();
            b.end();
            let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
            let s = cl.run();
            s.per_core.iter().map(|c| c.tcdm_cont).sum::<u64>()
        };
        assert!(same_bank > 100, "same-bank access must contend: {same_bank}");
        assert_eq!(spread_banks, 0, "interleaved accesses must not contend");
    }

    /// DIV-SQRT is shared and non-pipelined: divide-heavy code serializes.
    #[test]
    fn divsqrt_serializes_across_cores() {
        let mut b = ProgramBuilder::new("div");
        b.li(1, 1077936128); // 3.0f32
        b.li(2, 1073741824); // 2.0f32
        b.fdiv(FpMode::F32, 3, 1, 2);
        b.fadd(FpMode::F32, 4, 3, 3); // depends on the divide
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        let stats = cl.run();
        let cont: u64 = stats.per_core.iter().map(|c| c.divsqrt_cont).sum();
        assert!(cont > 0, "8 cores sharing one DIV-SQRT must queue");
        assert_eq!(f32::from_bits(cl.cores[0].reg(4)), 3.0);
    }

    /// WB-port conflict exists only with 2 pipeline stages.
    #[test]
    fn wb_conflict_only_with_two_stages() {
        let prog = || {
            let mut b = ProgramBuilder::new("wb");
            b.li(1, 1065353216);
            b.li(2, 1065353216);
            b.li(5, mem::TCDM_BASE);
            for _ in 0..16 {
                b.fadd(FpMode::F32, 3, 1, 2);
                b.addi(6, 6, 1); // int op right after FP → WB clash at 2p
            }
            b.end();
            b.build()
        };
        for pipe in [0u32, 1] {
            let mut cl = Cluster::new(cfg(8, 8, pipe), prog());
            cl.perfect_icache = true;
            cl.limit_active_cores(1);
            let s = cl.run();
            assert_eq!(s.per_core[0].wb_stall, 0, "pipe={pipe}");
        }
        let mut cl = Cluster::new(cfg(8, 8, 2), prog());
        cl.perfect_icache = true;
        cl.limit_active_cores(1);
        let s = cl.run();
        // 16 collision events; the skid register absorbs 2 of 3 → 5 stalls.
        assert_eq!(s.per_core[0].wb_stall, 5);
    }

    /// Branch penalties: taken costs 2 extra cycles, not-taken none.
    #[test]
    fn branch_penalties() {
        let mut b = ProgramBuilder::new("br");
        b.li(1, 8);
        b.label("loop");
        b.addi(1, 1, -1);
        b.bne(1, 0, "loop"); // taken 7×, not-taken 1×
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        let s = cl.run();
        assert_eq!(s.per_core[0].branch_stall, 7 * 2);
    }

    /// L2 accesses block the core for the 15-cycle latency.
    #[test]
    fn l2_latency_blocks() {
        let mut b = ProgramBuilder::new("l2");
        b.li(1, mem::L2_BASE);
        b.lw(2, 1, 0);
        b.lw(3, 1, 4);
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        let s = cl.run();
        assert_eq!(s.per_core[0].l2_stall, 2 * 14);
        assert!(s.total_cycles >= 30);
    }

    /// Fig 6 support: limiting active cores terminates the others.
    #[test]
    fn limit_active_cores_works() {
        let mut b = ProgramBuilder::new("lim");
        b.barrier(); // only the active cores participate
        b.end();
        let mut cl = Cluster::new(cfg(16, 16, 0), b.build());
        cl.limit_active_cores(4);
        let s = cl.run();
        assert!(s.total_cycles < 50, "4-way barrier must not deadlock");
        assert_eq!(cl.cores[0].reg(regs::NCORES), 4);
    }
}
