//! Cycle-accurate cluster simulator (§3.1–§3.2).
//!
//! The [`Cluster`] owns the cores, the banked TCDM, the shared FPU
//! subsystem, the DIV-SQRT block, the shared I$ model and the event unit,
//! and advances them under a single global clock. Arbitration fairness
//! (round-robin of the FPU interconnect and TCDM logarithmic interconnect)
//! is modelled by rotating the core issue order every cycle.
//!
//! Two issue engines execute the same timing model:
//!
//! * [`Engine::Event`] (default, [`engine`]) — the production hot path: an
//!   event-driven scheduler keyed on each core's `next_issue` plus batched
//!   straight-line execution of predecoded instructions between contention
//!   points. Cycle-for-cycle identical to the reference engine (enforced by
//!   the differential tests in `tests/differential.rs`).
//! * [`Engine::Reference`] ([`reference`]) — the original per-cycle
//!   rotate-and-scan loop, kept as the executable specification.
//!
//! Timing model summary (per instruction class):
//!
//! | class | issue→reuse | result→consumer |
//! |---|---|---|
//! | int ALU / Li | 1 cycle | next cycle (full forwarding) |
//! | int div/rem | 35 cycles (iterative, core blocks) | at completion |
//! | load (TCDM) | 1 cycle + bank contention retries | +2 (1 load-use bubble) |
//! | load/store (L2) | 15 cycles (core blocks on the demux) | at completion |
//! | taken branch | 3 cycles (2 flush bubbles) | — |
//! | hw-loop back-edge | 0 overhead | — |
//! | FP (FPU) | 1 cycle + port contention retries | +1+`pipe` cycles |
//! | FP div/sqrt | 1 cycle + unit-busy wait | 11/7/6 cycles (f32/f16/bf16) |
//! | barrier | sleeps until all arrive, +2 wake | — |
//!
//! With `pipe == 2` an FP result's delayed write-back conflicts with the
//! register-file write of an int/LSU instruction issued in the immediately
//! following slot (§5.3.3) — modelled as a 1-cycle `wb_stall`.

pub mod backend;
pub mod compiled;
pub mod core;
pub mod counters;
pub mod engine;
pub mod event;
pub mod fpu;
pub mod functional;
pub mod icache;
pub mod mem;
pub mod reference;

pub use backend::{
    BackendKind, BackendRun, EventBackend, ExecBackend, ReferenceBackend, RunError, Watchdog,
};
pub use compiled::{CodeCache, CompiledBackend, DEFAULT_CODE_CAPACITY};
pub use functional::FunctionalBackend;

use crate::config::ClusterConfig;
use crate::isa::decoded::DecodedProgram;
use crate::isa::Program;

pub(crate) use crate::isa::decoded::{INT_DIV_LATENCY, TAKEN_BRANCH_CYCLES};

use self::core::{Core, CoreState, Producer};
use self::counters::{CoreCounters, RunStats};
use self::event::EventUnit;
use self::fpu::FpuSubsystem;
use self::icache::ICache;
use self::mem::{DmaCtl, Memory, Region};
use crate::isa::insn::AmoOp;
use crate::trace::{StallCause, TraceConfig, Tracer};

/// Which issue engine executes a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Event-driven scheduler with batched straight-line runs (default).
    Event,
    /// Per-cycle rotate-and-scan loop (the executable specification).
    Reference,
}

/// Where a single-event upset lands (see [`crate::faults`]).
///
/// Sites are addressed modulo the physical structure they target, so a
/// campaign can sample them uniformly from a plain integer stream without
/// knowing the configuration's exact sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Flip `1 << (bit % 32)` in TCDM word `word % tcdm_words`.
    TcdmWord { word: u32, bit: u32 },
    /// Flip `1 << (bit % 32)` in register `reg % 32` of core `core % n`.
    /// Writes to x0 are masked by the register file, as in hardware.
    RegCell { core: u32, reg: u32, bit: u32 },
    /// Flip `1 << (bit % 32)` in word `word % len` of the next DMA
    /// transfer's payload (an in-flight bus upset).
    DmaPayload { word: u32, bit: u32 },
}

/// A fault armed to strike at (or immediately after) a simulated cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArmedFault {
    /// First cycle at which the upset may be applied. The engines apply it
    /// at the first issue opportunity with `t >= cycle` (exactly once).
    pub cycle: u64,
    /// Target structure and bit.
    pub site: FaultSite,
}

/// The simulated cluster.
pub struct Cluster {
    /// Configuration under simulation.
    pub cfg: ClusterConfig,
    /// Cores.
    pub cores: Vec<Core>,
    /// TCDM + L2.
    pub mem: Memory,
    /// Shared FPUs + DIV-SQRT.
    pub fpus: FpuSubsystem,
    /// Shared instruction cache.
    pub icache: ICache,
    /// Event unit (barriers + software event lines).
    pub event: EventUnit,
    /// Memory-mapped cluster DMA (double-buffered tiling).
    pub dmac: DmaCtl,
    /// The SPMD program all cores run.
    program: Program,
    /// Predecoded form of `program` (resolved read sets, static classes,
    /// latencies, hw-loop metadata) — the event engine's working set.
    decoded: DecodedProgram,
    /// Current cycle.
    pub now: u64,
    /// Hard cycle limit (deadlock guard).
    pub max_cycles: u64,
    /// At most one armed single-event upset, consumed when it strikes.
    fault: Option<ArmedFault>,
    /// Disable I$ cold-miss modelling (always-hit). Used by micro-timing
    /// tests that reason about exact cycle counts.
    pub perfect_icache: bool,
    /// Issue tracing enabled (TRANSPFP_TRACE env var, cached at build time —
    /// checking the environment per issued instruction costs ~30% of the
    /// whole simulator; see EXPERIMENTS.md §Perf).
    trace: bool,
    /// Attached cycle-attribution tracer ([`crate::trace`]); `None` means
    /// tracing is off and every hook site reduces to one predictable
    /// branch. Boxed so the disabled path keeps `Cluster` compact.
    tracer: Option<Box<Tracer>>,
}

impl Cluster {
    /// Build a cluster running `program` on every core.
    pub fn new(cfg: ClusterConfig, program: Program) -> Self {
        let cores = (0..cfg.cores).map(|i| Core::new(i, cfg.cores)).collect();
        let decoded = DecodedProgram::decode(&program);
        Cluster {
            cores,
            mem: Memory::new(&cfg),
            fpus: FpuSubsystem::new(cfg.fpus),
            icache: ICache::new(program.len()),
            event: EventUnit::new(cfg.cores),
            dmac: DmaCtl::default(),
            program,
            decoded,
            now: 0,
            max_cycles: 2_000_000_000,
            fault: None,
            perfect_icache: false,
            trace: std::env::var_os("TRANSPFP_TRACE").is_some(),
            tracer: None,
            cfg,
        }
    }

    /// Attach a cycle-attribution tracer (replacing any existing one). The
    /// region marker table is resolved from the program's side table; both
    /// timed engines then feed it issue/stall/wake/DMA records.
    pub fn attach_tracer(&mut self, cfg: TraceConfig) {
        let tr = Tracer::new(cfg, self.cfg.cores, &self.program.name, &self.program.markers);
        self.tracer = Some(Box::new(tr));
    }

    /// The attached tracer, if any.
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_deref()
    }

    /// Detach and return the tracer (e.g. to fold into an
    /// [`crate::trace::AttributionReport`]).
    pub fn take_tracer(&mut self) -> Option<Box<Tracer>> {
        self.tracer.take()
    }

    /// Reset every subsystem to its power-on state, **reusing all
    /// allocations** (TCDM array, L2 backing, I$ tags, decoded program).
    /// Sweeps and benches call this between repetitions instead of
    /// rebuilding `Memory`/cores per run; a reset cluster is
    /// indistinguishable from a freshly built one (asserted by the
    /// differential tests). Re-activates all cores — re-apply
    /// [`Self::limit_active_cores`] afterwards if needed.
    pub fn reset(&mut self) {
        let n = self.cfg.cores;
        for c in self.cores.iter_mut() {
            c.reset(n);
        }
        self.mem.reset();
        self.fpus.reset();
        self.icache.reset();
        self.event.reset(n);
        self.dmac.reset();
        self.now = 0;
        self.fault = None;
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.reset();
        }
    }

    /// Engine hook: an issue attempt reached instruction dispatch at cycle
    /// `t`. `#[cold]` keeps the body out of the tracing-off hot path; call
    /// sites guard with `self.tracer.is_some()`.
    #[cold]
    pub(crate) fn trace_issue(&mut self, ci: usize, pc: u32, t: u64) {
        let counters = self.cores[ci].counters;
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.on_issue(ci, pc, t, &counters);
        }
    }

    /// Engine hook: a stall counter was bumped by `amount` at cycle `t`.
    #[cold]
    pub(crate) fn trace_stall(&mut self, ci: usize, pc: u32, t: u64, cause: StallCause, amount: u64) {
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.on_stall(ci, pc, t, cause, amount);
        }
    }

    /// Engine hook: core `ci` retired `End` at cycle `t`.
    #[cold]
    pub(crate) fn trace_end(&mut self, ci: usize, t: u64) {
        let counters = self.cores[ci].counters;
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.on_end(ci, t, &counters);
        }
    }

    /// Arm a single-event upset. The run engines consume it at the first
    /// issue opportunity at or after `f.cycle`; at most one fault is armed
    /// at a time (campaigns inject one upset per run).
    pub fn arm_fault(&mut self, f: ArmedFault) {
        self.fault = Some(f);
    }

    /// Apply an armed upset to the targeted structure. Shared by both
    /// cycle-accurate engines.
    pub(crate) fn apply_fault(&mut self, site: FaultSite) {
        match site {
            FaultSite::TcdmWord { word, bit } => {
                let words = (self.mem.tcdm_bytes() / 4) as u32;
                let addr = mem::TCDM_BASE + (word % words.max(1)) * 4;
                let v = self.mem.load(addr, crate::isa::MemSize::Word);
                self.mem.store(addr, crate::isa::MemSize::Word, v ^ (1 << (bit % 32)));
            }
            FaultSite::RegCell { core, reg, bit } => {
                let ci = (core as usize) % self.cores.len();
                let r = (reg % 32) as u8;
                let v = self.cores[ci].reg(r);
                self.cores[ci].set_reg(r, v ^ (1 << (bit % 32)));
            }
            FaultSite::DmaPayload { word, bit } => {
                self.dmac.corrupt_next(word, 1 << (bit % 32));
            }
        }
    }

    /// Restrict execution to the first `n` cores; the rest terminate
    /// immediately (used by the Fig 6 speed-up sweeps, which run 1..=N
    /// workers on an N-core cluster). The event unit is resized so barriers
    /// wait only for active workers — the paper's kernels take the worker
    /// count as a parameter (§5.2).
    pub fn limit_active_cores(&mut self, n: usize) {
        assert!(n >= 1 && n <= self.cfg.cores);
        for c in self.cores.iter_mut().skip(n) {
            c.state = CoreState::Done;
        }
        self.event.reset(n);
        // The HAL reports the worker count, not the physical core count.
        for c in self.cores.iter_mut().take(n) {
            c.set_reg(crate::isa::regs::NCORES, n as u32);
        }
    }

    /// Run to completion on the default (event-driven) engine; returns
    /// per-core counters. A run that cannot terminate comes back as a
    /// structured [`RunError`] instead of a panic.
    pub fn run(&mut self) -> Result<RunStats, RunError> {
        self.run_with(Engine::Event)
    }

    /// Run to completion on the selected engine.
    pub fn run_with(&mut self, engine: Engine) -> Result<RunStats, RunError> {
        match engine {
            Engine::Event => self.run_event(),
            Engine::Reference => self.run_reference(),
        }
    }

    /// Gather the per-core counters into a [`RunStats`].
    pub(crate) fn collect_stats(&self) -> RunStats {
        let per_core: Vec<CoreCounters> = self.cores.iter().map(|c| c.counters).collect();
        let total_cycles = per_core.iter().map(|c| c.cycles).max().unwrap_or(0);
        RunStats { per_core, total_cycles }
    }

    /// The predecoded program (read-only view).
    pub fn decoded(&self) -> &DecodedProgram {
        &self.decoded
    }

    /// The program this cluster was built for (read-only view).
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// Shared accessors for the engines.
    pub(crate) fn trace_enabled(&self) -> bool {
        self.trace
    }

    /// Execute the data phase of a TCDM atomic for core `ci` at cycle `t`
    /// (the caller has already won the bank grant): read-modify-write the
    /// word and arm the scoreboard like a load. Shared verbatim by both
    /// issue engines so the functional semantics exist exactly once.
    pub(crate) fn exec_amo(
        &mut self,
        ci: usize,
        op: AmoOp,
        rd: crate::isa::Reg,
        addr: u32,
        rs: crate::isa::Reg,
        t: u64,
    ) {
        let v = self.cores[ci].reg(rs);
        let old = self.mem.amo(op, addr, v);
        let c = &mut self.cores[ci];
        c.set_reg(rd, old);
        c.reg_ready[rd as usize] = t + 2; // 1 load-use bubble, like a load
        c.reg_producer[rd as usize] = Producer::Load;
        c.counters.active += 1;
        c.counters.instrs += 1;
        c.counters.mem_instrs += 1;
    }

    /// Store to a memory-mapped DMA register for core `ci` at cycle `t`
    /// (single-cycle peripheral access, no bank arbitration).
    pub(crate) fn exec_dma_store(&mut self, ci: usize, addr: u32, rs: crate::isa::Reg, t: u64) {
        debug_assert!(matches!(self.mem.region_of(addr), Region::Dma));
        let v = self.cores[ci].reg(rs);
        let off = addr - mem::DMA_BASE;
        let busy_before = self.dmac.engine.busy_until;
        self.dmac.store(&mut self.mem, off, v, t);
        if off == mem::dma_reg::CMD {
            // A `CMD` store queued one transfer; the engine's busy horizon
            // moved from `busy_before` to its new value.
            let pc = self.cores[ci].pc;
            let words = self.dmac.len_words();
            let done = self.dmac.engine.busy_until;
            if let Some(tr) = self.tracer.as_deref_mut() {
                tr.on_dma(ci, pc, t, busy_before.max(t), done, words);
            }
        }
        let c = &mut self.cores[ci];
        c.counters.active += 1;
        c.counters.instrs += 1;
        c.counters.mem_instrs += 1;
    }

    /// Load from a memory-mapped DMA register (`STATUS` polling) for core
    /// `ci` at cycle `t`. Result arrives with a load-use bubble like a TCDM
    /// load.
    pub(crate) fn exec_dma_load(&mut self, ci: usize, addr: u32, rd: crate::isa::Reg, t: u64) {
        debug_assert!(matches!(self.mem.region_of(addr), Region::Dma));
        let v = self.dmac.load(addr - mem::DMA_BASE, t);
        let c = &mut self.cores[ci];
        c.set_reg(rd, v);
        c.reg_ready[rd as usize] = t + 2;
        c.reg_producer[rd as usize] = Producer::Load;
        c.counters.active += 1;
        c.counters.instrs += 1;
        c.counters.mem_instrs += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{regs, ProgramBuilder};
    use crate::transfp::FpMode;

    fn cfg(c: usize, f: usize, p: u32) -> ClusterConfig {
        ClusterConfig::new(c, f, p)
    }

    /// Run the same program on both engines and assert cycle-identical
    /// stats; returns the event-engine stats.
    fn run_both(cfg: ClusterConfig, prog: crate::isa::Program, workers: Option<usize>) -> RunStats {
        let mut a = Cluster::new(cfg, prog.clone());
        let mut b = Cluster::new(cfg, prog);
        if let Some(w) = workers {
            a.limit_active_cores(w);
            b.limit_active_cores(w);
        }
        let sa = a.run_with(Engine::Event).unwrap();
        let sb = b.run_with(Engine::Reference).unwrap();
        assert_eq!(sa.total_cycles, sb.total_cycles, "engines disagree on total cycles");
        for (i, (x, y)) in sa.per_core.iter().zip(&sb.per_core).enumerate() {
            assert_eq!(x, y, "engines disagree on core {i}");
        }
        sa
    }

    /// A one-core program that stores 1+2 to TCDM.
    #[test]
    fn minimal_program_runs() {
        let mut b = ProgramBuilder::new("min");
        b.li(1, 1).li(2, 2).add(3, 1, 2);
        b.li(4, mem::TCDM_BASE).sw(3, 4, 0).end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        let stats = cl.run().unwrap();
        assert_eq!(cl.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word), 3);
        // All 8 cores ran the same SPMD program; the stores collide benignly.
        assert_eq!(stats.per_core.len(), 8);
        assert!(stats.total_cycles > 0);
    }

    /// Hardware loops execute the body exactly `count` times, zero overhead.
    #[test]
    fn hwloop_iterations_and_zero_overhead() {
        let mut b = ProgramBuilder::new("hwl");
        b.li(1, 10); // count
        b.li(2, 0); // acc
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.hwloop_end();
        b.li(5, mem::TCDM_BASE).sw(2, 5, 0).end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        let stats = cl.run().unwrap();
        assert_eq!(cl.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word), 10);
        // Body = 10 instructions total for the loop, no branch penalties.
        let c = &stats.per_core[0];
        assert_eq!(c.branch_stall, 0);
        assert_eq!(c.instrs, 3 + 10 + 3);
    }

    /// Nested hardware loops.
    #[test]
    fn nested_hwloops() {
        let mut b = ProgramBuilder::new("hwl2");
        b.li(1, 3).li(2, 4).li(3, 0);
        b.hwloop(1);
        b.hwloop(2);
        b.addi(3, 3, 1);
        b.hwloop_end();
        b.hwloop_end();
        b.li(5, mem::TCDM_BASE).sw(3, 5, 0).end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        cl.run().unwrap();
        assert_eq!(cl.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word), 12);
    }

    /// FP latency: dependent back-to-back FMAs stall `pipe` cycles each.
    #[test]
    fn fp_dependency_stalls_scale_with_pipe() {
        let run = |pipe: u32| -> u64 {
            let mut b = ProgramBuilder::new("dep");
            b.li(1, 1065353216); // 1.0f32
            b.li(2, 1065353216);
            b.li(3, 0);
            for _ in 0..32 {
                b.fmac(FpMode::F32, 3, 1, 2); // each depends on previous (rd acc)
            }
            b.end();
            let mut cl = Cluster::new(cfg(8, 8, pipe), b.build());
            cl.perfect_icache = true;
            cl.limit_active_cores(1);
            let stats = cl.run().unwrap();
            stats.per_core[0].fpu_stall
        };
        assert_eq!(run(0), 0);
        assert_eq!(run(1), 32 - 1); // first has no predecessor in flight
        assert_eq!(run(2), 2 * 31);
    }

    /// FPU sharing: two cores on one FPU contend; private FPUs don't.
    #[test]
    fn fpu_contention_depends_on_sharing() {
        let prog = || {
            let mut b = ProgramBuilder::new("cont");
            b.li(1, 1065353216);
            b.li(2, 1065353216);
            // Independent FP ops (different destinations) — saturate the port.
            for i in 0..16 {
                b.fadd(FpMode::F32, 20 + (i % 8) as u8, 1, 2);
            }
            b.end();
            b.build()
        };
        let mut shared = Cluster::new(cfg(8, 2, 1), prog());
        let s = shared.run().unwrap();
        let cont: u64 = s.per_core.iter().map(|c| c.fpu_cont).sum();
        assert!(cont > 0, "4 cores per FPU must contend");

        let mut private = Cluster::new(cfg(8, 8, 1), prog());
        let p = private.run().unwrap();
        let cont_p: u64 = p.per_core.iter().map(|c| c.fpu_cont).sum();
        assert_eq!(cont_p, 0, "private FPUs never contend");
        assert!(s.total_cycles > p.total_cycles);
    }

    /// Barrier synchronizes cores with different amounts of work.
    #[test]
    fn barrier_waits_for_slowest() {
        let mut b = ProgramBuilder::new("bar");
        // Core 0 does extra work before the barrier.
        b.bne(regs::CORE_ID, regs::ZERO, "sync");
        b.li(1, 200);
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.hwloop_end();
        b.label("sync");
        b.barrier();
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        let stats = cl.run().unwrap();
        // Everyone finishes at roughly the same cycle, after core 0's work.
        let idle: u64 = stats.per_core.iter().map(|c| c.barrier_idle).sum();
        assert!(idle > 7 * 150, "waiters must have slept: {idle}");
        let spread = stats.per_core.iter().map(|c| c.cycles).max().unwrap()
            - stats.per_core.iter().map(|c| c.cycles).min().unwrap();
        assert!(spread <= 16, "cores should finish together, spread={spread}");
    }

    /// TCDM bank conflicts: all cores hammering one bank contend; separate
    /// banks don't.
    #[test]
    fn tcdm_bank_conflicts() {
        let same_bank = {
            let mut b = ProgramBuilder::new("same");
            b.li(1, mem::TCDM_BASE);
            b.li(3, 64);
            b.hwloop(3);
            b.lw(2, 1, 0); // every core, same address → same bank
            b.hwloop_end();
            b.end();
            let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
            let s = cl.run().unwrap();
            s.per_core.iter().map(|c| c.tcdm_cont).sum::<u64>()
        };
        let spread_banks = {
            let mut b = ProgramBuilder::new("spread");
            b.li(1, mem::TCDM_BASE);
            b.slli(4, regs::CORE_ID, 2);
            b.add(1, 1, 4); // each core its own word → its own bank
            b.li(3, 64);
            b.hwloop(3);
            b.lw(2, 1, 0);
            b.hwloop_end();
            b.end();
            let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
            let s = cl.run().unwrap();
            s.per_core.iter().map(|c| c.tcdm_cont).sum::<u64>()
        };
        assert!(same_bank > 100, "same-bank access must contend: {same_bank}");
        assert_eq!(spread_banks, 0, "interleaved accesses must not contend");
    }

    /// DIV-SQRT is shared and non-pipelined: divide-heavy code serializes.
    #[test]
    fn divsqrt_serializes_across_cores() {
        let mut b = ProgramBuilder::new("div");
        b.li(1, 1077936128); // 3.0f32
        b.li(2, 1073741824); // 2.0f32
        b.fdiv(FpMode::F32, 3, 1, 2);
        b.fadd(FpMode::F32, 4, 3, 3); // depends on the divide
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        let stats = cl.run().unwrap();
        let cont: u64 = stats.per_core.iter().map(|c| c.divsqrt_cont).sum();
        assert!(cont > 0, "8 cores sharing one DIV-SQRT must queue");
        assert_eq!(f32::from_bits(cl.cores[0].reg(4)), 3.0);
    }

    /// WB-port conflict exists only with 2 pipeline stages.
    #[test]
    fn wb_conflict_only_with_two_stages() {
        let prog = || {
            let mut b = ProgramBuilder::new("wb");
            b.li(1, 1065353216);
            b.li(2, 1065353216);
            b.li(5, mem::TCDM_BASE);
            for _ in 0..16 {
                b.fadd(FpMode::F32, 3, 1, 2);
                b.addi(6, 6, 1); // int op right after FP → WB clash at 2p
            }
            b.end();
            b.build()
        };
        for pipe in [0u32, 1] {
            let mut cl = Cluster::new(cfg(8, 8, pipe), prog());
            cl.perfect_icache = true;
            cl.limit_active_cores(1);
            let s = cl.run().unwrap();
            assert_eq!(s.per_core[0].wb_stall, 0, "pipe={pipe}");
        }
        let mut cl = Cluster::new(cfg(8, 8, 2), prog());
        cl.perfect_icache = true;
        cl.limit_active_cores(1);
        let s = cl.run().unwrap();
        // 16 collision events; the skid register absorbs 2 of 3 → 5 stalls.
        assert_eq!(s.per_core[0].wb_stall, 5);
    }

    /// Branch penalties: taken costs 2 extra cycles, not-taken none.
    #[test]
    fn branch_penalties() {
        let mut b = ProgramBuilder::new("br");
        b.li(1, 8);
        b.label("loop");
        b.addi(1, 1, -1);
        b.bne(1, 0, "loop"); // taken 7×, not-taken 1×
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        let s = cl.run().unwrap();
        assert_eq!(s.per_core[0].branch_stall, 7 * 2);
    }

    /// L2 accesses block the core for the 15-cycle latency.
    #[test]
    fn l2_latency_blocks() {
        let mut b = ProgramBuilder::new("l2");
        b.li(1, mem::L2_BASE);
        b.lw(2, 1, 0);
        b.lw(3, 1, 4);
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(1);
        let s = cl.run().unwrap();
        assert_eq!(s.per_core[0].l2_stall, 2 * 14);
        assert!(s.total_cycles >= 30);
    }

    /// Fig 6 support: limiting active cores terminates the others.
    #[test]
    fn limit_active_cores_works() {
        let mut b = ProgramBuilder::new("lim");
        b.barrier(); // only the active cores participate
        b.end();
        let mut cl = Cluster::new(cfg(16, 16, 0), b.build());
        cl.limit_active_cores(4);
        let s = cl.run().unwrap();
        assert!(s.total_cycles < 50, "4-way barrier must not deadlock");
        assert_eq!(cl.cores[0].reg(regs::NCORES), 4);
    }

    /// The two engines produce cycle-identical stats on hand-built micro
    /// programs that exercise every hazard path: hw loops, branches, WB
    /// conflicts, TCDM contention, FPU contention, DIV-SQRT queueing,
    /// barriers with skewed arrival, and L2 blocking.
    #[test]
    fn engines_cycle_identical_on_micro_programs() {
        let mixed = || {
            let mut b = ProgramBuilder::new("mixed");
            b.li(1, 1065353216).li(2, 1073741824);
            b.li(5, mem::TCDM_BASE);
            b.slli(6, regs::CORE_ID, 2).add(5, 5, 6);
            b.li(7, 24);
            b.hwloop(7);
            b.fmac(FpMode::F32, 3, 1, 2);
            b.sw(3, 5, 0);
            b.lw(4, 5, 0);
            b.addi(6, 6, 1);
            b.hwloop_end();
            b.fdiv(FpMode::F32, 8, 2, 1);
            b.barrier();
            b.bne(regs::CORE_ID, regs::ZERO, "skip");
            b.li(9, mem::L2_BASE);
            b.lw(9, 9, 0);
            b.label("skip");
            b.barrier();
            b.end();
            b.build()
        };
        for c in [cfg(8, 2, 0), cfg(8, 4, 1), cfg(8, 8, 2), cfg(16, 8, 1)] {
            run_both(c, mixed(), None);
        }
        // Single-worker (solo fast path) and partial occupancy.
        for workers in [1usize, 3] {
            run_both(cfg(8, 4, 2), mixed(), Some(workers));
        }
    }

    /// Software events: workers sleep on a line, the master raises it after
    /// doing extra work; sleepers are gated (barrier_idle) meanwhile. Both
    /// engines agree cycle-for-cycle.
    #[test]
    fn set_event_wakes_waiters_and_buffers_for_the_rest() {
        let prog = || {
            let mut b = ProgramBuilder::new("ev");
            b.beq(regs::CORE_ID, regs::ZERO, "master");
            b.wait_event(5);
            b.j("join");
            b.label("master");
            b.li(1, 100);
            b.hwloop(1);
            b.addi(2, 2, 1);
            b.hwloop_end();
            b.set_event(5);
            // The master buffered its own event: consumed without sleeping.
            b.wait_event(5);
            b.label("join");
            b.barrier();
            b.end();
            b.build()
        };
        for c in [cfg(8, 8, 0), cfg(8, 2, 1), cfg(16, 8, 2)] {
            let s = run_both(c, prog(), None);
            let idle: u64 = s.per_core.iter().skip(1).map(|x| x.barrier_idle).sum();
            assert!(idle > (c.cores as u64 - 1) * 80, "waiters must sleep: {idle}");
        }
        // Partial occupancy (including solo, where the master's own
        // buffered wait must not deadlock).
        for workers in [1usize, 3] {
            run_both(cfg(8, 4, 1), prog(), Some(workers));
        }
    }

    /// TCDM atomics: concurrent fetch-and-add claims every value exactly
    /// once; the bank arbitration serializes deterministically.
    #[test]
    fn amo_add_is_atomic_under_contention() {
        let prog = || {
            let mut b = ProgramBuilder::new("amo");
            b.li(1, mem::TCDM_BASE);
            b.li(2, 1);
            b.amo_add(3, 1, 0, 2); // r3 = old counter; counter += 1
            // Publish each core's claimed ticket to its own slot.
            b.slli(4, regs::CORE_ID, 2);
            b.add(4, 4, 1);
            b.sw(3, 4, 4); // slots start at TCDM_BASE + 4
            b.barrier();
            b.end();
            b.build()
        };
        let s = run_both(cfg(8, 8, 0), prog(), None);
        assert_eq!(s.per_core.len(), 8);
        let mut cl = Cluster::new(cfg(8, 8, 0), prog());
        cl.run().unwrap();
        assert_eq!(cl.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word), 8);
        let mut tickets: Vec<u32> = (0..8)
            .map(|i| cl.mem.load(mem::TCDM_BASE + 4 + 4 * i, crate::isa::MemSize::Word))
            .collect();
        tickets.sort_unstable();
        assert_eq!(tickets, (0..8).collect::<Vec<u32>>(), "each ticket claimed exactly once");
    }

    /// Atomic swap implements a test-and-set lock: the critical section is
    /// mutually exclusive (counter increments are never lost).
    #[test]
    fn amo_swap_lock_excludes() {
        let prog = || {
            let mut b = ProgramBuilder::new("lock");
            // lock at TCDM_BASE, shared counter at TCDM_BASE+4.
            b.li(1, mem::TCDM_BASE);
            b.label("acq");
            b.li(2, 1);
            b.amo_swap(2, 1, 0, 2);
            b.bne(2, regs::ZERO, "acq");
            // Critical section: non-atomic read-modify-write.
            b.lw(3, 1, 4);
            b.addi(3, 3, 1);
            b.sw(3, 1, 4);
            b.sw(regs::ZERO, 1, 0); // release
            b.barrier();
            b.end();
            b.build()
        };
        let s = run_both(cfg(8, 4, 1), prog(), None);
        assert_eq!(s.per_core.len(), 8);
        let mut cl = Cluster::new(cfg(8, 4, 1), prog());
        cl.run().unwrap();
        assert_eq!(cl.mem.load(mem::TCDM_BASE + 4, crate::isa::MemSize::Word), 8);
    }

    /// Memory-mapped DMA: the master stages an L2 block into TCDM, spins on
    /// STATUS, signals via an event; workers then read the staged data.
    #[test]
    fn dma_roundtrip_through_registers() {
        let prog = || {
            let mut b = ProgramBuilder::new("dma");
            b.bne(regs::CORE_ID, regs::ZERO, "worker");
            // Program SRC/DST/LEN, trigger, spin until done.
            b.li(1, mem::DMA_BASE);
            b.li(2, mem::L2_BASE);
            b.sw(2, 1, mem::dma_reg::SRC as i32);
            b.li(2, mem::TCDM_BASE);
            b.sw(2, 1, mem::dma_reg::DST as i32);
            b.li(2, 4);
            b.sw(2, 1, mem::dma_reg::LEN as i32);
            b.sw(2, 1, mem::dma_reg::CMD as i32);
            b.label("spin");
            b.lw(3, 1, mem::dma_reg::STATUS as i32);
            b.bne(3, regs::ZERO, "spin");
            b.set_event(0);
            b.label("worker");
            b.wait_event(0);
            // Everyone loads the staged word.
            b.li(4, mem::TCDM_BASE);
            b.lw(5, 4, 0);
            b.barrier();
            b.end();
            b.build()
        };
        for c in [cfg(8, 8, 0), cfg(8, 2, 2)] {
            let mut a = Cluster::new(c, prog());
            a.mem.write_u32_slice(mem::L2_BASE, &[0xABCD_1234, 2, 3, 4]);
            let mut r = Cluster::new(c, prog());
            r.mem.write_u32_slice(mem::L2_BASE, &[0xABCD_1234, 2, 3, 4]);
            let sa = a.run_with(Engine::Event).unwrap();
            let sr = r.run_with(Engine::Reference).unwrap();
            assert_eq!(sa.total_cycles, sr.total_cycles, "engines disagree on {c}");
            for (x, y) in sa.per_core.iter().zip(&sr.per_core) {
                assert_eq!(x, y);
            }
            assert_eq!(a.cores[3].reg(5), 0xABCD_1234);
            assert_eq!(a.dmac.words_moved(), 4);
            // The transfer costs setup + words, so the run can't be trivial.
            assert!(sa.total_cycles > 14);
        }
        // Solo: the master path batches straight-line through trigger + spin.
        let mut solo = Cluster::new(cfg(8, 8, 1), prog());
        solo.mem.write_u32_slice(mem::L2_BASE, &[7, 8, 9, 10]);
        solo.limit_active_cores(1);
        let mut solo_ref = Cluster::new(cfg(8, 8, 1), prog());
        solo_ref.mem.write_u32_slice(mem::L2_BASE, &[7, 8, 9, 10]);
        solo_ref.limit_active_cores(1);
        let se = solo.run_with(Engine::Event).unwrap();
        let sf = solo_ref.run_with(Engine::Reference).unwrap();
        assert_eq!(se.total_cycles, sf.total_cycles);
        assert_eq!(solo.cores[0].reg(5), 7);
    }

    /// reset() returns the cluster to a state indistinguishable from a
    /// freshly constructed one.
    #[test]
    fn reset_reproduces_fresh_run() {
        let prog = || {
            let mut b = ProgramBuilder::new("rst");
            b.li(1, 1065353216).li(2, 1073741824);
            b.li(5, mem::TCDM_BASE);
            b.li(7, 16);
            b.hwloop(7);
            b.fadd(FpMode::F32, 3, 1, 2);
            b.sw_pi(3, 5, 4);
            b.hwloop_end();
            b.barrier();
            b.end();
            b.build()
        };
        let c = cfg(8, 4, 1);
        let mut fresh = Cluster::new(c, prog());
        let s1 = fresh.run().unwrap();

        let mut reused = Cluster::new(c, prog());
        let _ = reused.run().unwrap();
        reused.reset();
        let s2 = reused.run().unwrap();

        assert_eq!(s1.total_cycles, s2.total_cycles);
        for (a, b) in s1.per_core.iter().zip(&s2.per_core) {
            assert_eq!(a, b);
        }
        assert_eq!(
            fresh.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word),
            reused.mem.load(mem::TCDM_BASE, crate::isa::MemSize::Word)
        );
    }

    /// reset() also restores the active-core limit to "all".
    #[test]
    fn reset_after_limit_active_cores() {
        let mut b = ProgramBuilder::new("lim-rst");
        b.barrier();
        b.end();
        let mut cl = Cluster::new(cfg(8, 8, 0), b.build());
        cl.limit_active_cores(2);
        cl.run().unwrap();
        cl.reset();
        // All 8 cores participate again; the 8-way barrier must complete.
        let s = cl.run().unwrap();
        assert!(s.total_cycles < 50);
        assert_eq!(cl.cores[0].reg(regs::NCORES), 8);
        assert_eq!(s.per_core.iter().filter(|c| c.instrs > 0).count(), 8);
    }
}
