//! Functional (architectural-only) execution backend.
//!
//! [`FunctionalBackend`] interprets a predecoded program **in program order
//! per core** with correct TCDM / atomic / event-line / DMA *semantics* but
//! no cycle accounting at all: no event queue, no scoreboard, no bank or
//! FPU arbitration, no I$ model. It reuses the exact functional primitives
//! the cycle-accurate engines execute through — [`Core::exec_alu`],
//! [`Core::exec_fp`], [`Core::exec_load`], [`Memory::amo`], the
//! [`EventUnit`] and the [`DmaCtl`] front-end — so the architectural result
//! (final registers, memory image) is identical to the timed engines for
//! every program whose cross-core behaviour is synchronization-determined
//! (all 8 kernels: static work sharing, barrier/event handshakes). Programs
//! that *self-schedule* through TCDM atomics still produce the identical
//! memory image (the work-sharing invariant: every index runs exactly once,
//! bodies are index-pure) but distribute chunks by backend timing, so their
//! per-core registers are compared only under deterministic schedules in
//! the three-way wall (`tests/differential.rs`).
//!
//! ## Scheduling model
//!
//! Cores run round-robin, each **to its next blocking point**: an
//! unsatisfied `WaitEvent`, an incomplete `Barrier`, or `End`. Everything
//! else — including DMA `STATUS` polls, which report zero outstanding
//! transfers because data moves at trigger time — executes straight
//! through. A full pass in which no core is runnable while some still
//! sleep is a [`RunError::Deadlock`], mirroring the timed engines' guard;
//! a per-run retired-instruction budget (the watchdog's `max_instrs`)
//! bounds pathological spin loops the way `max_cycles` bounds the timed
//! engines, surfacing as [`RunError::Timeout`].
//!
//! ## Fast path
//!
//! The interpreter shares the predecoder's straight-line fast-path table
//! ([`DecodedProgram::local_run_len`], also consulted by the event
//! engine's batcher): while the table proves the pc starts a run of
//! core-local instructions, dispatch stays in a tight tier that never
//! touches memory, the DMA or the event unit. The `benches/backend.rs`
//! gate holds the result to ≥ 50× the event engine's instruction
//! throughput on the kernel suite.

use super::backend::{BackendRun, ExecBackend, RunError, Watchdog};
use super::core::{Core, CoreState};
use super::event::EventUnit;
use super::mem::{DmaCtl, Memory, Region, DMA_BASE};
use crate::config::ClusterConfig;
use crate::isa::decoded::{DecodedProgram, OpClass};
use crate::isa::insn::Insn;
use crate::isa::{regs, Program};

/// Retired-instruction budget per run — the functional analogue of the
/// timed engines' `max_cycles` deadlock guard.
const MAX_INSTRS: u64 = 2_000_000_000;

/// The architectural-only execution tier.
pub struct FunctionalBackend;

impl ExecBackend for FunctionalBackend {
    fn name(&self) -> &'static str {
        "functional"
    }

    fn is_cycle_accurate(&self) -> bool {
        false
    }

    fn run_watched(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
        wd: Watchdog,
    ) -> Result<BackendRun, RunError> {
        FunctionalBackend::run_decoded_watched(
            cfg,
            &DecodedProgram::decode(program),
            workers,
            stage,
            wd.max_instrs,
        )
    }
}

impl FunctionalBackend {
    /// Execute an already-predecoded program (benches and repeated probes
    /// skip the re-decode) under the default instruction budget.
    pub fn run_decoded(
        cfg: &ClusterConfig,
        decoded: &DecodedProgram,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> Result<BackendRun, RunError> {
        Self::run_decoded_watched(cfg, decoded, workers, stage, MAX_INSTRS)
    }

    /// Execute an already-predecoded program with an explicit retired-
    /// instruction budget (the functional tier's watchdog).
    pub fn run_decoded_watched(
        cfg: &ClusterConfig,
        decoded: &DecodedProgram,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
        max_instrs: u64,
    ) -> Result<BackendRun, RunError> {
        assert!(workers >= 1 && workers <= cfg.cores, "occupancy out of range");
        let n = cfg.cores;
        // Mirror `Cluster::new` + `limit_active_cores` exactly, so inactive
        // cores' register files match the timed engines bit-for-bit.
        let mut cores: Vec<Core> = (0..n).map(|i| Core::new(i, n)).collect();
        for c in cores.iter_mut().skip(workers) {
            c.state = CoreState::Done;
        }
        for c in cores.iter_mut().take(workers) {
            c.set_reg(regs::NCORES, workers as u32);
        }
        let mut mem = Memory::new(cfg);
        stage(&mut mem);
        let mut event = EventUnit::new(workers);
        let mut dmac = DmaCtl::default();

        let mut total = 0u64;
        loop {
            let mut ran = false;
            for ci in 0..workers {
                if !matches!(cores[ci].state, CoreState::Running) {
                    continue;
                }
                ran = true;
                run_core(
                    ci,
                    decoded,
                    workers,
                    &mut cores,
                    &mut mem,
                    &mut event,
                    &mut dmac,
                    &mut total,
                    max_instrs,
                )?;
            }
            if !ran {
                break;
            }
        }
        let asleep =
            cores.iter().filter(|c| matches!(c.state, CoreState::Sleeping { .. })).count();
        if asleep > 0 {
            return Err(RunError::Deadlock { asleep });
        }
        Ok(BackendRun {
            regs: cores.iter().map(|c| c.regs).collect(),
            mem,
            stats: None,
            instrs: total,
        })
    }
}

/// Run core `ci` until it blocks (event sleep, incomplete barrier) or
/// terminates, accumulating retired instructions into `total`. Crossing
/// `max_instrs` is the watchdog tripping on an unsynchronized spin loop
/// and surfaces as [`RunError::Timeout`].
#[allow(clippy::too_many_arguments)]
fn run_core(
    ci: usize,
    decoded: &DecodedProgram,
    workers: usize,
    cores: &mut [Core],
    mem: &mut Memory,
    event: &mut EventUnit,
    dmac: &mut DmaCtl,
    total: &mut u64,
    max_instrs: u64,
) -> Result<(), RunError> {
    let insns = decoded.insns.as_slice();
    let run_len = decoded.local_run_len.as_slice();
    loop {
        // ---- Tier 1: straight-line core-local run (shared fast-path
        // table; the same instruction set the event engine batches).
        {
            let c = &mut cores[ci];
            while run_len[c.pc as usize] != 0 {
                let d = &insns[c.pc as usize];
                *total += 1;
                if *total > max_instrs {
                    return Err(RunError::Timeout { budget: max_instrs });
                }
                c.counters.instrs += 1;
                match d.class {
                    OpClass::Alu => {
                        let Insn::Alu { op, rd, rs1, rhs } = d.insn else { unreachable!() };
                        c.exec_alu(op, rd, rs1, rhs);
                        c.advance_decoded(d.flags);
                    }
                    OpClass::Li => {
                        let Insn::Li { rd, imm } = d.insn else { unreachable!() };
                        c.set_reg(rd, imm);
                        c.advance_decoded(d.flags);
                    }
                    OpClass::FpAlu => {
                        let Insn::Fp { op, mode, rd, rs1, rs2 } = d.insn else {
                            unreachable!()
                        };
                        let _ = c.exec_fp(op, mode, rd, rs1, rs2);
                        c.advance_decoded(d.flags);
                    }
                    OpClass::Branch => {
                        let Insn::Branch { cond, rs1, rs2, target } = d.insn else {
                            unreachable!()
                        };
                        if c.branch_taken(cond, rs1, rs2) {
                            c.pc = target;
                        } else {
                            c.advance_decoded(d.flags);
                        }
                    }
                    OpClass::Jump => {
                        let Insn::Jump { target } = d.insn else { unreachable!() };
                        c.pc = target;
                    }
                    OpClass::HwLoop => {
                        let Insn::HwLoop { count, start, end } = d.insn else { unreachable!() };
                        let iters = c.reg(count);
                        if iters == 0 {
                            c.pc = end;
                        } else {
                            c.hwloops.push((start, end, iters));
                            c.pc = start;
                        }
                    }
                    OpClass::End => {
                        c.state = CoreState::Done;
                        return Ok(());
                    }
                    _ => unreachable!("non-local class inside a straight-line run"),
                }
            }
        }

        // ---- Tier 2: one shared-resource instruction (memory, FP
        // datapath, atomics, event unit), then back to the fast path.
        let pc = cores[ci].pc as usize;
        let d = &insns[pc];
        *total += 1;
        if *total > max_instrs {
            return Err(RunError::Timeout { budget: max_instrs });
        }
        cores[ci].counters.instrs += 1;
        match d.class {
            OpClass::Load => {
                let Insn::Load { rd, base, offset, post_inc, size } = d.insn else {
                    unreachable!()
                };
                let c = &mut cores[ci];
                let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                match mem.region_of(addr) {
                    Region::Dma => {
                        // Transfers complete at trigger time, so `STATUS`
                        // reads as drained.
                        let v = dmac.load(addr - DMA_BASE, u64::MAX);
                        c.set_reg(rd, v);
                    }
                    _ => c.exec_load(mem, rd, addr, size),
                }
                c.advance_decoded(d.flags);
            }
            OpClass::Store => {
                let Insn::Store { rs, base, offset, post_inc, size } = d.insn else {
                    unreachable!()
                };
                let c = &mut cores[ci];
                let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                // Value read after the post-increment, like the engines.
                let v = c.reg(rs);
                match mem.region_of(addr) {
                    Region::Dma => dmac.store(mem, addr - DMA_BASE, v, 0),
                    _ => mem.store(addr, size, v),
                }
                c.advance_decoded(d.flags);
            }
            OpClass::Fp | OpClass::FpDivSqrt => {
                let Insn::Fp { op, mode, rd, rs1, rs2 } = d.insn else { unreachable!() };
                let c = &mut cores[ci];
                let _ = c.exec_fp(op, mode, rd, rs1, rs2);
                c.advance_decoded(d.flags);
            }
            OpClass::Amo => {
                let Insn::Amo { op, rd, base, offset, rs } = d.insn else { unreachable!() };
                let c = &mut cores[ci];
                let addr = (c.reg(base) as i64 + offset as i64) as u32;
                if !matches!(mem.region_of(addr), Region::Tcdm) {
                    return Err(RunError::Fault(format!("atomic outside TCDM at {addr:#x}")));
                }
                let v = c.reg(rs);
                let old = mem.amo(op, addr, v);
                c.set_reg(rd, old);
                c.advance_decoded(d.flags);
            }
            OpClass::WaitEvent => {
                let Insn::WaitEvent { ev } = d.insn else { unreachable!() };
                cores[ci].advance_decoded(d.flags);
                if !event.wait_event(ci, ev) {
                    cores[ci].state = CoreState::Sleeping { since: 0 };
                    return Ok(());
                }
            }
            OpClass::SetEvent => {
                let Insn::SetEvent { ev } = d.insn else { unreachable!() };
                cores[ci].advance_decoded(d.flags);
                for w in event.set_event(ev) {
                    cores[w].state = CoreState::Running;
                }
            }
            OpClass::Barrier => {
                cores[ci].advance_decoded(d.flags);
                if event.arrive(ci, 0).is_some() {
                    // Wake every barrier sleeper; cores parked on a
                    // software event line stay asleep (only a SetEvent may
                    // release them) — same rule as the timed engines.
                    for (w, c) in cores.iter_mut().enumerate().take(workers) {
                        if matches!(c.state, CoreState::Sleeping { .. })
                            && !event.is_event_waiting(w)
                        {
                            c.state = CoreState::Running;
                        }
                    }
                    // The arriving core completed the barrier: it keeps
                    // running; the woken cores resume on their next slot.
                } else {
                    cores[ci].state = CoreState::Sleeping { since: 0 };
                    return Ok(());
                }
            }
            _ => unreachable!("local class dispatched on the shared-resource path"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::backend::BackendKind;
    use crate::cluster::mem::{dma_reg, L2_BASE, TCDM_BASE};
    use crate::isa::{MemSize, ProgramBuilder};
    use crate::kernels::{Benchmark, Variant};

    fn run_functional(
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> BackendRun {
        FunctionalBackend.run_program(cfg, program, workers, stage).expect("program terminates")
    }

    /// Static-scheduled kernels: the functional backend reproduces the
    /// event engine's outputs, registers and TCDM image bit-for-bit, at
    /// full and partial occupancy.
    #[test]
    fn matches_event_engine_on_static_kernels() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for (b, v) in [
            (Benchmark::Fir, Variant::Scalar),
            (Benchmark::Matmul, Variant::VEC),
            (Benchmark::Kmeans, Variant::SCALAR_BF16),
        ] {
            let w = b.build(v, &cfg);
            for workers in [1usize, 3, 8] {
                let (ev, ev_out) =
                    w.run_on_backend(&cfg, workers, BackendKind::Event.get()).unwrap();
                let (fu, fu_out) = w.run_on_backend(&cfg, workers, &FunctionalBackend).unwrap();
                let ctx = format!("{} {} with {workers} workers", b.name(), v.label());
                assert_eq!(ev_out, fu_out, "{ctx}: outputs differ");
                assert_eq!(ev.regs, fu.regs, "{ctx}: registers differ");
                assert_eq!(ev.mem.tcdm_words(), fu.mem.tcdm_words(), "{ctx}: TCDM differs");
                assert_eq!(ev.instrs, fu.instrs, "{ctx}: retired counts differ");
                w.verify(&fu_out).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            }
        }
    }

    /// Concurrent fetch-and-add claims every ticket exactly once (the
    /// interleaving is the functional scheduler's, not the timed one's, but
    /// atomicity and coverage are identical).
    #[test]
    fn amo_tickets_claimed_exactly_once() {
        let mut b = ProgramBuilder::new("amo-f");
        b.li(1, TCDM_BASE);
        b.li(2, 1);
        b.amo_add(3, 1, 0, 2);
        b.slli(4, regs::CORE_ID, 2);
        b.add(4, 4, 1);
        b.sw(3, 4, 4);
        b.barrier();
        b.end();
        let cfg = ClusterConfig::new(8, 8, 0);
        let run = run_functional(&cfg, &b.build(), 8, &mut |_| {});
        assert_eq!(run.mem.load(TCDM_BASE, MemSize::Word), 8);
        let mut tickets: Vec<u32> =
            (0..8).map(|i| run.mem.load(TCDM_BASE + 4 + 4 * i, MemSize::Word)).collect();
        tickets.sort_unstable();
        assert_eq!(tickets, (0..8).collect::<Vec<u32>>());
    }

    /// Master/worker event handshake: workers park on the line, the master
    /// raises it, everyone joins — and a double run is deterministic.
    #[test]
    fn event_handshake_completes_deterministically() {
        let prog = || {
            let mut b = ProgramBuilder::new("ev-f");
            b.beq(regs::CORE_ID, regs::ZERO, "master");
            b.wait_event(5);
            b.j("join");
            b.label("master");
            b.li(1, 100);
            b.hwloop(1);
            b.addi(2, 2, 1);
            b.hwloop_end();
            b.set_event(5);
            b.wait_event(5); // consumes the master's own buffered event
            b.label("join");
            b.barrier();
            b.end();
            b.build()
        };
        let cfg = ClusterConfig::new(8, 2, 1);
        let a = run_functional(&cfg, &prog(), 8, &mut |_| {});
        let b = run_functional(&cfg, &prog(), 8, &mut |_| {});
        assert_eq!(a.regs, b.regs);
        assert_eq!(a.instrs, b.instrs);
        assert_eq!(a.regs[0][2], 100, "master ran its pre-signal work");
    }

    /// The memory-mapped DMA works functionally: transfers land at trigger
    /// time and `STATUS` polls drain immediately.
    #[test]
    fn dma_roundtrip_is_functional() {
        let mut b = ProgramBuilder::new("dma-f");
        b.bne(regs::CORE_ID, regs::ZERO, "worker");
        b.li(1, DMA_BASE);
        b.li(2, L2_BASE);
        b.sw(2, 1, dma_reg::SRC as i32);
        b.li(2, TCDM_BASE);
        b.sw(2, 1, dma_reg::DST as i32);
        b.li(2, 4);
        b.sw(2, 1, dma_reg::LEN as i32);
        b.sw(2, 1, dma_reg::CMD as i32);
        b.label("spin");
        b.lw(3, 1, dma_reg::STATUS as i32);
        b.bne(3, regs::ZERO, "spin");
        b.set_event(0);
        b.label("worker");
        b.wait_event(0);
        b.li(4, TCDM_BASE);
        b.lw(5, 4, 0);
        b.barrier();
        b.end();
        let cfg = ClusterConfig::new(8, 8, 0);
        let run = run_functional(&cfg, &b.build(), 8, &mut |mem| {
            mem.write_u32_slice(L2_BASE, &[0xABCD_1234, 2, 3, 4]);
        });
        for regs in &run.regs {
            assert_eq!(regs[5], 0xABCD_1234, "every core read the staged word");
        }
        assert_eq!(run.mem.load(TCDM_BASE + 12, MemSize::Word), 4);
    }

    /// A core waiting on a line nobody raises is a structured deadlock
    /// error, not a panic and not a hang.
    #[test]
    fn unraisable_event_line_is_a_deadlock() {
        let mut b = ProgramBuilder::new("dead-f");
        b.bne(regs::CORE_ID, regs::ZERO, "worker");
        b.end();
        b.label("worker");
        b.wait_event(9);
        b.end();
        let cfg = ClusterConfig::new(8, 8, 0);
        let err = FunctionalBackend
            .run_program(&cfg, &b.build(), 8, &mut |_| {})
            .expect_err("7 cores park on a line nobody raises");
        assert_eq!(err, RunError::Deadlock { asleep: 7 });
    }

    /// Partial occupancy mirrors `limit_active_cores`: inactive cores never
    /// run and barriers span exactly the team.
    #[test]
    fn partial_occupancy_runs_and_inactive_cores_stay_reset() {
        let mut b = ProgramBuilder::new("occ-f");
        b.li(1, TCDM_BASE);
        b.slli(2, regs::CORE_ID, 2);
        b.add(1, 1, 2);
        b.sw(regs::NCORES, 1, 0);
        b.barrier();
        b.end();
        let cfg = ClusterConfig::new(16, 8, 0);
        let run = run_functional(&cfg, &b.build(), 3, &mut |_| {});
        for i in 0..3u32 {
            assert_eq!(run.mem.load(TCDM_BASE + 4 * i, MemSize::Word), 3);
        }
        assert_eq!(run.mem.load(TCDM_BASE + 12, MemSize::Word), 0, "core 3 must not run");
        // Inactive cores keep the reset-time register file.
        assert_eq!(run.regs[5][regs::CORE_ID as usize], 5);
        assert_eq!(run.regs[5][regs::NCORES as usize], 16);
    }
}
