//! Reference issue engine: the original per-cycle rotate-and-scan loop.
//!
//! This is the executable specification of the timing model. Every cycle it
//! walks all cores in rotated priority order (the round-robin fairness of
//! the FPU interconnect and TCDM logarithmic interconnect), attempts to
//! issue on each runnable core, and fast-forwards the clock to the next
//! `next_issue`. The event-driven engine ([`super::engine`]) must produce
//! bit-identical `RunStats` — enforced by `tests/differential.rs` across
//! kernels, variants, configurations and random programs.
//!
//! Keep this loop boring and obviously correct; optimizations belong in the
//! event engine.

use crate::isa::insn::Insn;

use super::backend::RunError;
use super::core::{CoreState, Producer};
use super::counters::RunStats;
use super::event::WAKEUP_LATENCY;
use super::mem::Region;
use super::{Cluster, INT_DIV_LATENCY, TAKEN_BRANCH_CYCLES};
use crate::trace::{StallCause, TraceKind};

impl Cluster {
    /// Run to completion on the per-cycle reference loop. Exceeding
    /// `self.max_cycles` is a [`RunError::Timeout`]; a cluster whose
    /// remaining cores are all asleep is a [`RunError::Deadlock`].
    pub fn run_reference(&mut self) -> Result<RunStats, RunError> {
        while self.now < self.max_cycles {
            if self.step()? {
                return Ok(self.collect_stats());
            }
        }
        Err(RunError::Timeout { budget: self.max_cycles })
    }

    /// Advance one cycle. Returns true when every core is done.
    fn step(&mut self) -> Result<bool, RunError> {
        if let Some(f) = self.fault {
            if self.now >= f.cycle {
                self.fault = None;
                self.apply_fault(f.site);
            }
        }
        let n = self.cores.len();
        let rot = (self.now as usize) % n;
        let mut all_done = true;
        let mut min_next = u64::MAX;
        for k in 0..n {
            // Branch instead of modulo: the `%` showed up in the profile.
            let ci = if rot + k >= n { rot + k - n } else { rot + k };
            match self.cores[ci].state {
                CoreState::Done => continue,
                CoreState::Sleeping { .. } => {
                    all_done = false;
                    continue; // woken by the barrier completion
                }
                CoreState::Running => {
                    all_done = false;
                    if self.cores[ci].next_issue > self.now {
                        min_next = min_next.min(self.cores[ci].next_issue);
                        continue;
                    }
                    self.issue(ci)?;
                    min_next = min_next.min(self.cores[ci].next_issue);
                }
            }
        }
        if all_done {
            return Ok(true);
        }
        // Nobody left running while somebody still sleeps: no SetEvent or
        // barrier arrival can ever come, so the sleepers wait forever.
        if !self.cores.iter().any(|c| matches!(c.state, CoreState::Running)) {
            let asleep = self
                .cores
                .iter()
                .filter(|c| matches!(c.state, CoreState::Sleeping { .. }))
                .count();
            if asleep > 0 {
                return Err(RunError::Deadlock { asleep });
            }
        }
        // Fast-forward across cycles where no core can issue (barrier sleeps
        // resolve inside issue(); DIV-SQRT / L2 waits are bulk-attributed).
        self.now = if min_next == u64::MAX { self.now + 1 } else { min_next.max(self.now + 1) };
        Ok(false)
    }

    /// Attempt to issue the next instruction of core `ci` at `self.now`.
    fn issue(&mut self, ci: usize) -> Result<(), RunError> {
        let t = self.now;
        // Capture the attempt pc before any arm rewrites it (branch/jump).
        let pc = self.cores[ci].pc;
        let insn = self.program.insns[pc as usize];
        if self.trace_enabled() {
            eprintln!("t={t} core={ci} pc={pc} {insn:?}");
        }

        // 1. Instruction fetch through the shared I$.
        let fetched =
            if self.perfect_icache { t } else { self.icache.fetch(self.cores[ci].pc, t) };
        if fetched > t {
            let c = &mut self.cores[ci];
            c.counters.icache_stall += fetched - t;
            c.next_issue = fetched;
            if self.tracer.is_some() {
                self.trace_stall(ci, pc, t, StallCause::Icache, fetched - t);
            }
            return Ok(());
        }

        // 2. Operand scoreboard.
        let (ready, who) = self.cores[ci].operands_ready(&insn);
        if ready > t {
            let wait = ready - t;
            let cause = {
                let c = &mut self.cores[ci];
                let cause = match who {
                    Producer::Fpu | Producer::DivSqrt => {
                        c.counters.fpu_stall += wait;
                        Some(StallCause::FpuLatency)
                    }
                    Producer::Load => {
                        c.counters.load_stall += wait;
                        Some(StallCause::LoadUse)
                    }
                    Producer::None => None,
                };
                c.next_issue = ready;
                cause
            };
            if let Some(cause) = cause {
                if self.tracer.is_some() {
                    self.trace_stall(ci, pc, t, cause, wait);
                }
            }
            return Ok(());
        }

        // 3. Write-back port conflict (§5.3.3): only with 2 pipeline stages,
        // when an int/LSU write follows an FP op back-to-back. The FPU's
        // result skid register absorbs two of every three collisions, so one
        // in three costs a stall (matching the ~10% penalty of Fig 8).
        if self.cfg.pipe >= 2
            && !insn.is_fp()
            && insn.writes_int_reg()
            && self.cores[ci].last_fp_issue == t.wrapping_sub(1)
        {
            let c = &mut self.cores[ci];
            c.wb_skid += 1;
            if c.wb_skid >= 3 {
                c.wb_skid = 0;
                c.counters.wb_stall += 1;
                c.next_issue = t + 1;
                if self.tracer.is_some() {
                    self.trace_stall(ci, pc, t, StallCause::Writeback, 1);
                }
                return Ok(());
            }
        }

        // 4. Class-specific structural hazards + execution.
        if self.tracer.is_some() {
            self.trace_issue(ci, pc, t);
        }
        match insn {
            Insn::Alu { op, rd, rs1, rhs } => {
                let c = &mut self.cores[ci];
                c.exec_alu(op, rd, rs1, rhs);
                let lat = if matches!(op, crate::isa::AluOp::Div | crate::isa::AluOp::Rem) {
                    INT_DIV_LATENCY
                } else {
                    1
                };
                c.counters.active += lat;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.next_issue = t + lat;
                c.advance_pc();
            }
            Insn::Li { rd, imm } => {
                let c = &mut self.cores[ci];
                c.set_reg(rd, imm);
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.next_issue = t + 1;
                c.advance_pc();
            }
            Insn::Load { rd, base, offset, post_inc, size } => {
                let addr =
                    (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                match self.mem.region_of(addr) {
                    Region::Dma => {
                        let addr = self.cores[ci].mem_addr_and_postinc(base, offset, post_inc);
                        self.exec_dma_load(ci, addr, rd, t);
                        let c = &mut self.cores[ci];
                        c.next_issue = t + 1;
                        c.advance_pc();
                    }
                    Region::Tcdm => {
                        let bank = self.mem.bank_of(addr);
                        if !self.mem.claim_bank(bank, t) {
                            let c = &mut self.cores[ci];
                            c.counters.tcdm_cont += 1;
                            c.next_issue = t + 1;
                            if self.tracer.is_some() {
                                self.trace_stall(ci, pc, t, StallCause::TcdmContention, 1);
                            }
                            return Ok(());
                        }
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        c.exec_load(&self.mem, rd, addr, size);
                        c.reg_ready[rd as usize] = t + 2; // 1 load-use bubble
                        c.reg_producer[rd as usize] = Producer::Load;
                        c.counters.active += 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + 1;
                        c.advance_pc();
                    }
                    Region::L2 => {
                        let lat = self.cfg.l2_latency();
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        c.exec_load(&self.mem, rd, addr, size);
                        c.counters.active += 1;
                        c.counters.l2_stall += lat - 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + lat; // core blocks on the demux
                        c.advance_pc();
                        if self.tracer.is_some() {
                            self.trace_stall(ci, pc, t, StallCause::L2, lat - 1);
                        }
                    }
                }
            }
            Insn::Store { rs, base, offset, post_inc, size } => {
                let addr =
                    (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                match self.mem.region_of(addr) {
                    Region::Dma => {
                        let addr = self.cores[ci].mem_addr_and_postinc(base, offset, post_inc);
                        self.exec_dma_store(ci, addr, rs, t);
                        let c = &mut self.cores[ci];
                        c.next_issue = t + 1;
                        c.advance_pc();
                    }
                    Region::Tcdm => {
                        let bank = self.mem.bank_of(addr);
                        if !self.mem.claim_bank(bank, t) {
                            let c = &mut self.cores[ci];
                            c.counters.tcdm_cont += 1;
                            c.next_issue = t + 1;
                            if self.tracer.is_some() {
                                self.trace_stall(ci, pc, t, StallCause::TcdmContention, 1);
                            }
                            return Ok(());
                        }
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        let v = c.reg(rs);
                        self.mem.store(addr, size, v);
                        c.counters.active += 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + 1;
                        c.advance_pc();
                    }
                    Region::L2 => {
                        let lat = self.cfg.l2_latency();
                        let c = &mut self.cores[ci];
                        let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                        let v = c.reg(rs);
                        self.mem.store(addr, size, v);
                        c.counters.active += 1;
                        c.counters.l2_stall += lat - 1;
                        c.counters.instrs += 1;
                        c.counters.mem_instrs += 1;
                        c.next_issue = t + lat;
                        c.advance_pc();
                        if self.tracer.is_some() {
                            self.trace_stall(ci, pc, t, StallCause::L2, lat - 1);
                        }
                    }
                }
            }
            Insn::Branch { cond, rs1, rs2, target } => {
                let c = &mut self.cores[ci];
                let taken = c.branch_taken(cond, rs1, rs2);
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                if taken {
                    c.pc = target;
                    c.counters.branch_stall += TAKEN_BRANCH_CYCLES - 1;
                    c.next_issue = t + TAKEN_BRANCH_CYCLES;
                    if self.tracer.is_some() {
                        self.trace_stall(ci, pc, t, StallCause::Branch, TAKEN_BRANCH_CYCLES - 1);
                    }
                } else {
                    c.next_issue = t + 1;
                    c.advance_pc();
                }
            }
            Insn::Jump { target } => {
                let c = &mut self.cores[ci];
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.pc = target;
                c.counters.branch_stall += TAKEN_BRANCH_CYCLES - 1;
                c.next_issue = t + TAKEN_BRANCH_CYCLES;
                if self.tracer.is_some() {
                    self.trace_stall(ci, pc, t, StallCause::Branch, TAKEN_BRANCH_CYCLES - 1);
                }
            }
            Insn::HwLoop { count, start, end } => {
                let c = &mut self.cores[ci];
                let n = c.reg(count);
                c.counters.active += 1;
                c.counters.instrs += 1;
                c.counters.int_instrs += 1;
                c.next_issue = t + 1;
                if n == 0 {
                    c.pc = end;
                } else {
                    c.hwloops.push((start, end, n));
                    c.pc = start;
                }
            }
            Insn::Fp { op, mode, rd, rs1, rs2 } => {
                if op.is_alu_class() {
                    // Integer-SIMD lane permutation: plain 1-cycle ALU op.
                    let c = &mut self.cores[ci];
                    c.exec_fp(op, mode, rd, rs1, rs2);
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    c.next_issue = t + 1;
                    c.advance_pc();
                } else if op.is_divsqrt() {
                    match self.fpus.try_divsqrt(mode, t) {
                        Err(free) => {
                            let c = &mut self.cores[ci];
                            c.counters.divsqrt_cont += free - t;
                            c.next_issue = free;
                            if self.tracer.is_some() {
                                self.trace_stall(
                                    ci,
                                    pc,
                                    t,
                                    StallCause::DivSqrtContention,
                                    free - t,
                                );
                            }
                        }
                        Ok(done) => {
                            let c = &mut self.cores[ci];
                            let flops = c.exec_fp(op, mode, rd, rs1, rs2);
                            c.reg_ready[rd as usize] = done;
                            c.reg_producer[rd as usize] = Producer::DivSqrt;
                            c.counters.active += 1;
                            c.counters.instrs += 1;
                            c.counters.fp_instrs += 1;
                            c.counters.flops += flops;
                            c.next_issue = t + 1;
                            c.advance_pc();
                        }
                    }
                } else {
                    let fpu = self.cfg.fpu_of_core(ci);
                    if !self.fpus.try_issue(fpu, t) {
                        let c = &mut self.cores[ci];
                        c.counters.fpu_cont += 1;
                        c.next_issue = t + 1;
                        if self.tracer.is_some() {
                            self.trace_stall(ci, pc, t, StallCause::FpuContention, 1);
                        }
                        return Ok(());
                    }
                    let pipe = self.cfg.pipe as u64;
                    let c = &mut self.cores[ci];
                    let flops = c.exec_fp(op, mode, rd, rs1, rs2);
                    c.reg_ready[rd as usize] = t + 1 + pipe;
                    c.reg_producer[rd as usize] = Producer::Fpu;
                    c.last_fp_issue = t;
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.fp_instrs += 1;
                    if mode.is_vector() {
                        c.counters.fp_vec_instrs += 1;
                    }
                    c.counters.flops += flops;
                    c.next_issue = t + 1;
                    c.advance_pc();
                }
            }
            Insn::Amo { op, rd, base, offset, rs } => {
                let addr = (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                if !matches!(self.mem.region_of(addr), Region::Tcdm) {
                    return Err(RunError::Fault(format!("atomic outside TCDM at {addr:#x}")));
                }
                let bank = self.mem.bank_of(addr);
                if !self.mem.claim_bank(bank, t) {
                    let c = &mut self.cores[ci];
                    c.counters.tcdm_cont += 1;
                    c.next_issue = t + 1;
                    if self.tracer.is_some() {
                        self.trace_stall(ci, pc, t, StallCause::TcdmContention, 1);
                    }
                    return Ok(());
                }
                self.exec_amo(ci, op, rd, addr, rs, t);
                let c = &mut self.cores[ci];
                c.next_issue = t + 1;
                c.advance_pc();
            }
            Insn::WaitEvent { ev } => {
                // Count the instruction itself.
                {
                    let c = &mut self.cores[ci];
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    c.advance_pc();
                }
                if self.event.wait_event(ci, ev) {
                    self.cores[ci].next_issue = t + 1; // buffered: no sleep
                } else {
                    let c = &mut self.cores[ci];
                    c.state = CoreState::Sleeping { since: t + 1 };
                    c.next_issue = u64::MAX; // woken by a SetEvent
                }
            }
            Insn::SetEvent { ev } => {
                {
                    let c = &mut self.cores[ci];
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    c.next_issue = t + 1;
                    c.advance_pc();
                }
                let wake = t + WAKEUP_LATENCY;
                for w in self.event.set_event(ev) {
                    let c = &mut self.cores[w];
                    if let CoreState::Sleeping { since } = c.state {
                        c.counters.barrier_idle += wake - since;
                        c.state = CoreState::Running;
                        c.next_issue = wake;
                        if let Some(tr) = self.tracer.as_deref_mut() {
                            tr.on_wake(w, c.pc, TraceKind::EventWait, since, wake);
                        }
                    }
                }
            }
            Insn::Barrier => {
                // Count the barrier instruction itself.
                {
                    let c = &mut self.cores[ci];
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    c.advance_pc();
                }
                match self.event.arrive(ci, t) {
                    Some(wake) => {
                        // Wake everyone (including self) — except cores
                        // parked on a software event line, which only a
                        // SetEvent may release.
                        let event = &self.event;
                        for c in self.cores.iter_mut() {
                            match c.state {
                                CoreState::Sleeping { since }
                                    if !event.is_event_waiting(c.id) =>
                                {
                                    c.counters.barrier_idle += wake - since;
                                    c.state = CoreState::Running;
                                    c.next_issue = wake;
                                    if let Some(tr) = self.tracer.as_deref_mut() {
                                        tr.on_wake(c.id, c.pc, TraceKind::Barrier, since, wake);
                                    }
                                }
                                CoreState::Running if c.id == ci => {
                                    c.counters.barrier_idle += wake - (t + 1);
                                    c.next_issue = wake;
                                    if let Some(tr) = self.tracer.as_deref_mut() {
                                        tr.on_wake(c.id, c.pc, TraceKind::Barrier, t + 1, wake);
                                    }
                                }
                                _ => {}
                            }
                        }
                    }
                    None => {
                        let c = &mut self.cores[ci];
                        c.state = CoreState::Sleeping { since: t + 1 };
                        c.next_issue = u64::MAX; // woken explicitly
                    }
                }
            }
            Insn::End => {
                // `End` retires in zero cycles and deliberately does NOT
                // count an active cycle, so `active + stalls == cycles`
                // holds exactly per core (the trace layer reconciles on
                // this invariant).
                {
                    let c = &mut self.cores[ci];
                    c.counters.instrs += 1;
                    c.counters.cycles = t;
                    c.state = CoreState::Done;
                }
                if self.tracer.is_some() {
                    self.trace_end(ci, t);
                }
            }
        }
        Ok(())
    }
}
