//! Event unit (§3.1): low-overhead barrier synchronization with sleep, plus
//! the software event lines the fork-join runtime is built on.
//!
//! A core reaching a barrier sends its arrival to the event unit and goes to
//! sleep (clock-gated — these cycles are cheap in the power model, the
//! mechanism behind the paper's "energy efficiency is not affected by the
//! effectiveness of parallelization"). When the last core arrives, all
//! sleepers are woken after a fixed 2-cycle wake-up.
//!
//! **Software events** ([`NUM_EVENTS`] lines) follow the PULP event-unit
//! model: `SetEvent` broadcasts a line to every core; cores *waiting* on
//! that line wake after the same 2-cycle latency, every other core buffers
//! it (one sticky bit per line per core — multiple sets before a wait
//! collapse). `WaitEvent` consumes a buffered event without sleeping, or
//! registers the core as a waiter and puts it to sleep. Event sleep and
//! barrier sleep are distinct: a completing barrier never wakes a core
//! parked on an event line, and vice versa.

/// Wake-up latency after the last barrier arrival / an event set.
pub const WAKEUP_LATENCY: u64 = 2;

/// Number of software event lines (PULP SW events).
pub const NUM_EVENTS: usize = 32;

/// Barrier + software-event state for one cluster.
#[derive(Debug, Clone)]
pub struct EventUnit {
    ncores: usize,
    arrived: Vec<bool>,
    count: usize,
    /// Per-core buffered-event bitmask (bit `ev` set = line `ev` pending).
    buffered: Vec<u32>,
    /// Per-core event line the core is currently sleeping on.
    waiting: Vec<Option<u8>>,
    /// Monotonically increasing barrier generation (for debugging/tests).
    pub generation: u64,
}

impl EventUnit {
    /// Event unit for `ncores` cores.
    pub fn new(ncores: usize) -> Self {
        EventUnit {
            ncores,
            arrived: vec![false; ncores],
            count: 0,
            buffered: vec![0; ncores],
            waiting: vec![None; ncores],
            generation: 0,
        }
    }

    /// Reset to an empty barrier over `ncores` cores, keeping the
    /// allocation where possible.
    pub fn reset(&mut self, ncores: usize) {
        self.ncores = ncores;
        self.arrived.clear();
        self.arrived.resize(ncores, false);
        self.count = 0;
        self.buffered.clear();
        self.buffered.resize(ncores, 0);
        self.waiting.clear();
        self.waiting.resize(ncores, None);
        self.generation = 0;
    }

    /// Core `id` arrives at the barrier at `cycle`. Returns `Some(wake_cycle)`
    /// if this arrival completes the barrier (all cores then resume at
    /// `wake_cycle`), `None` if the core must sleep.
    pub fn arrive(&mut self, id: usize, cycle: u64) -> Option<u64> {
        assert!(!self.arrived[id], "core {id} double-arrived at barrier");
        self.arrived[id] = true;
        self.count += 1;
        if self.count == self.ncores {
            self.arrived.iter_mut().for_each(|a| *a = false);
            self.count = 0;
            self.generation += 1;
            Some(cycle + WAKEUP_LATENCY)
        } else {
            None
        }
    }

    /// Number of cores currently waiting at the barrier.
    pub fn waiting(&self) -> usize {
        self.count
    }

    /// Core `id` waits on event line `ev`. Returns `true` if a buffered
    /// event was consumed (the core continues without sleeping); `false`
    /// registers the core as a waiter (it must sleep until a `set_event`).
    pub fn wait_event(&mut self, id: usize, ev: u8) -> bool {
        assert!((ev as usize) < NUM_EVENTS, "event line {ev} out of range");
        let bit = 1u32 << ev;
        if self.buffered[id] & bit != 0 {
            self.buffered[id] &= !bit;
            true
        } else {
            debug_assert!(self.waiting[id].is_none(), "core {id} already event-waiting");
            self.waiting[id] = Some(ev);
            false
        }
    }

    /// Raise event line `ev` for every core. Cores waiting on `ev` are
    /// returned (in core-id order) and deregistered — the caller wakes them
    /// [`WAKEUP_LATENCY`] later; every other core (including the setter)
    /// buffers the line.
    pub fn set_event(&mut self, ev: u8) -> Vec<usize> {
        assert!((ev as usize) < NUM_EVENTS, "event line {ev} out of range");
        let bit = 1u32 << ev;
        let mut woken = Vec::new();
        for id in 0..self.ncores {
            if self.waiting[id] == Some(ev) {
                self.waiting[id] = None;
                woken.push(id);
            } else {
                self.buffered[id] |= bit;
            }
        }
        woken
    }

    /// True if core `id` is asleep on an event line (as opposed to a
    /// barrier) — barrier completion must not wake such cores.
    pub fn is_event_waiting(&self, id: usize) -> bool {
        self.waiting[id].is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_completes_on_last_arrival() {
        let mut eu = EventUnit::new(4);
        assert_eq!(eu.arrive(0, 10), None);
        assert_eq!(eu.arrive(2, 12), None);
        assert_eq!(eu.arrive(3, 15), None);
        assert_eq!(eu.waiting(), 3);
        assert_eq!(eu.arrive(1, 20), Some(22));
        assert_eq!(eu.waiting(), 0);
        assert_eq!(eu.generation, 1);
    }

    #[test]
    fn barrier_reusable() {
        let mut eu = EventUnit::new(2);
        assert_eq!(eu.arrive(0, 1), None);
        assert_eq!(eu.arrive(1, 5), Some(7));
        assert_eq!(eu.arrive(1, 9), None);
        assert_eq!(eu.arrive(0, 11), Some(13));
        assert_eq!(eu.generation, 2);
    }

    #[test]
    #[should_panic(expected = "double-arrived")]
    fn double_arrival_is_a_bug() {
        let mut eu = EventUnit::new(2);
        eu.arrive(0, 1);
        eu.arrive(0, 2);
    }

    #[test]
    fn events_buffer_and_wake() {
        let mut eu = EventUnit::new(3);
        // Core 1 waits first, core 2 will see a buffered event.
        assert!(!eu.wait_event(1, 5));
        assert!(eu.is_event_waiting(1));
        let woken = eu.set_event(5);
        assert_eq!(woken, vec![1]);
        assert!(!eu.is_event_waiting(1));
        // Cores 0 and 2 (and the setter) buffered the line.
        assert!(eu.wait_event(0, 5), "buffered event consumed without sleep");
        assert!(eu.wait_event(2, 5));
        // The buffer is consumed: a second wait sleeps.
        assert!(!eu.wait_event(2, 5));
    }

    #[test]
    fn events_are_per_line() {
        let mut eu = EventUnit::new(2);
        assert!(!eu.wait_event(0, 3));
        // Raising a different line does not wake the line-3 waiter.
        assert_eq!(eu.set_event(4), Vec::<usize>::new());
        assert!(eu.is_event_waiting(0));
        assert_eq!(eu.set_event(3), vec![0]);
        // Line 4 stayed buffered for core 0 meanwhile.
        assert!(eu.wait_event(0, 4));
    }

    #[test]
    fn multiple_sets_collapse() {
        let mut eu = EventUnit::new(1);
        eu.set_event(7);
        eu.set_event(7);
        assert!(eu.wait_event(0, 7));
        assert!(!eu.wait_event(0, 7), "sets collapse into one sticky bit");
    }

    #[test]
    fn reset_clears_events() {
        let mut eu = EventUnit::new(2);
        eu.set_event(1);
        assert!(!eu.wait_event(0, 2));
        eu.reset(2);
        assert!(!eu.is_event_waiting(0));
        assert!(!eu.wait_event(0, 1), "buffered events cleared by reset");
    }
}
