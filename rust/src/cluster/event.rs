//! Event unit (§3.1): low-overhead barrier synchronization with sleep.
//!
//! A core reaching a barrier sends its arrival to the event unit and goes to
//! sleep (clock-gated — these cycles are cheap in the power model, the
//! mechanism behind the paper's "energy efficiency is not affected by the
//! effectiveness of parallelization"). When the last core arrives, all
//! sleepers are woken after a fixed 2-cycle wake-up.

/// Wake-up latency after the last arrival.
pub const WAKEUP_LATENCY: u64 = 2;

/// Barrier state for one cluster.
#[derive(Debug, Clone)]
pub struct EventUnit {
    ncores: usize,
    arrived: Vec<bool>,
    count: usize,
    /// Monotonically increasing barrier generation (for debugging/tests).
    pub generation: u64,
}

impl EventUnit {
    /// Event unit for `ncores` cores.
    pub fn new(ncores: usize) -> Self {
        EventUnit { ncores, arrived: vec![false; ncores], count: 0, generation: 0 }
    }

    /// Reset to an empty barrier over `ncores` cores, keeping the
    /// allocation where possible.
    pub fn reset(&mut self, ncores: usize) {
        self.ncores = ncores;
        self.arrived.clear();
        self.arrived.resize(ncores, false);
        self.count = 0;
        self.generation = 0;
    }

    /// Core `id` arrives at the barrier at `cycle`. Returns `Some(wake_cycle)`
    /// if this arrival completes the barrier (all cores then resume at
    /// `wake_cycle`), `None` if the core must sleep.
    pub fn arrive(&mut self, id: usize, cycle: u64) -> Option<u64> {
        assert!(!self.arrived[id], "core {id} double-arrived at barrier");
        self.arrived[id] = true;
        self.count += 1;
        if self.count == self.ncores {
            self.arrived.iter_mut().for_each(|a| *a = false);
            self.count = 0;
            self.generation += 1;
            Some(cycle + WAKEUP_LATENCY)
        } else {
            None
        }
    }

    /// Number of cores currently waiting.
    pub fn waiting(&self) -> usize {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_completes_on_last_arrival() {
        let mut eu = EventUnit::new(4);
        assert_eq!(eu.arrive(0, 10), None);
        assert_eq!(eu.arrive(2, 12), None);
        assert_eq!(eu.arrive(3, 15), None);
        assert_eq!(eu.waiting(), 3);
        assert_eq!(eu.arrive(1, 20), Some(22));
        assert_eq!(eu.waiting(), 0);
        assert_eq!(eu.generation, 1);
    }

    #[test]
    fn barrier_reusable() {
        let mut eu = EventUnit::new(2);
        assert_eq!(eu.arrive(0, 1), None);
        assert_eq!(eu.arrive(1, 5), Some(7));
        assert_eq!(eu.arrive(1, 9), None);
        assert_eq!(eu.arrive(0, 11), Some(13));
        assert_eq!(eu.generation, 2);
    }

    #[test]
    #[should_panic(expected = "double-arrived")]
    fn double_arrival_is_a_bug() {
        let mut eu = EventUnit::new(2);
        eu.arrive(0, 1);
        eu.arrive(0, 2);
    }
}
