//! Cluster memory subsystem: the multi-banked TCDM behind the single-cycle
//! word-interleaved logarithmic interconnect (§3.1), and the 15-cycle L2
//! scratchpad at the SoC level.
//!
//! Timing: each TCDM bank accepts one request per cycle. Simultaneous
//! requests to the same bank are arbitrated round-robin (rotating priority in
//! the cluster's issue loop); losers stall one cycle and retry — exactly the
//! "TCDM contention" counter of §5.1.

use super::super::config::ClusterConfig;
use crate::isa::insn::AmoOp;
use crate::isa::MemSize;

/// Base address of the TCDM scratchpad (PULP cluster address map).
pub const TCDM_BASE: u32 = 0x1000_0000;
/// Base address of the memory-mapped cluster DMA (MCHAN-style) registers.
pub const DMA_BASE: u32 = 0x1B00_0000;
/// Base address of the SoC L2 memory.
pub const L2_BASE: u32 = 0x1C00_0000;

/// DMA register offsets from [`DMA_BASE`]. Stores latch `SRC`/`DST`/`LEN`;
/// a store to `CMD` (any value) enqueues the transfer. Loads from `STATUS`
/// return the number of transfers still in flight at the load's cycle —
/// the runtime's `dma_wait` spins on it reaching zero.
pub mod dma_reg {
    /// Source byte address (word-aligned).
    pub const SRC: u32 = 0x0;
    /// Destination byte address (word-aligned).
    pub const DST: u32 = 0x4;
    /// Transfer length in 32-bit words.
    pub const LEN: u32 = 0x8;
    /// Write: trigger the latched transfer.
    pub const CMD: u32 = 0xC;
    /// Read: outstanding (not yet completed) transfer count.
    pub const STATUS: u32 = 0x0;
}

/// Which memory region an address falls into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    Tcdm,
    /// Memory-mapped DMA registers.
    Dma,
    L2,
}

/// Byte-addressable memory with word-interleaved banking (TCDM) plus the L2.
#[derive(Debug, Clone)]
pub struct Memory {
    tcdm: Vec<u32>,
    /// L2 storage, grown lazily: zero-filling the full 512 kB per run cost
    /// ~15% of short simulations (EXPERIMENTS.md §Perf).
    l2: Vec<u32>,
    l2_capacity: usize,
    nbanks: usize,
    /// Per-bank: cycle index of the last granted access (one grant/cycle).
    bank_busy_at: Vec<u64>,
}

impl Memory {
    /// Allocate the memories for `cfg`.
    pub fn new(cfg: &ClusterConfig) -> Self {
        Memory {
            tcdm: vec![0; cfg.tcdm_bytes() / 4],
            l2: Vec::new(),
            l2_capacity: cfg.l2_bytes() / 4,
            nbanks: cfg.tcdm_banks(),
            bank_busy_at: vec![u64::MAX; cfg.tcdm_banks()],
        }
    }

    /// Reset contents to zero, keeping the TCDM allocation and dropping the
    /// lazily-grown L2 back to empty (its backing capacity is retained by
    /// `Vec::clear`, so a reset-and-rerun does not reallocate).
    pub fn reset(&mut self) {
        self.tcdm.fill(0);
        self.l2.clear();
        self.bank_busy_at.fill(u64::MAX);
    }

    /// Which region (and word index) an address maps to. Panics on
    /// out-of-range addresses — kernels own their layout.
    pub fn region_of(&self, addr: u32) -> Region {
        if addr >= L2_BASE {
            Region::L2
        } else if addr >= DMA_BASE {
            Region::Dma
        } else {
            debug_assert!(addr >= TCDM_BASE, "address {addr:#x} below TCDM");
            Region::Tcdm
        }
    }

    /// TCDM bank of an address (word-interleaved).
    pub fn bank_of(&self, addr: u32) -> usize {
        (((addr - TCDM_BASE) / 4) as usize) % self.nbanks
    }

    /// Try to claim `bank` for `cycle`; true = granted. The issue loop's
    /// rotating core priority provides the round-robin fairness.
    pub fn claim_bank(&mut self, bank: usize, cycle: u64) -> bool {
        if self.bank_busy_at[bank] == cycle {
            false
        } else {
            self.bank_busy_at[bank] = cycle;
            true
        }
    }

    fn slot(&mut self, addr: u32) -> &mut u32 {
        match self.region_of(addr) {
            Region::Dma => panic!("DMA registers at {addr:#x} are not backed memory"),
            Region::Tcdm => {
                let idx = ((addr - TCDM_BASE) / 4) as usize;
                &mut self.tcdm[idx]
            }
            Region::L2 => {
                let idx = ((addr - L2_BASE) / 4) as usize;
                assert!(idx < self.l2_capacity, "L2 overflow at {addr:#x}");
                if idx >= self.l2.len() {
                    self.l2.resize(idx + 1, 0);
                }
                &mut self.l2[idx]
            }
        }
    }

    fn word(&self, addr: u32) -> u32 {
        match self.region_of(addr) {
            Region::Dma => panic!("DMA registers at {addr:#x} are not backed memory"),
            Region::Tcdm => self.tcdm[((addr - TCDM_BASE) / 4) as usize],
            Region::L2 => {
                let idx = ((addr - L2_BASE) / 4) as usize;
                assert!(idx < self.l2_capacity, "L2 overflow at {addr:#x}");
                self.l2.get(idx).copied().unwrap_or(0)
            }
        }
    }

    /// Functional load.
    pub fn load(&self, addr: u32, size: MemSize) -> u32 {
        let w = self.word(addr & !3);
        match size {
            MemSize::Word => {
                debug_assert!(addr % 4 == 0, "unaligned word load at {addr:#x}");
                w
            }
            MemSize::Half | MemSize::HalfU => {
                debug_assert!(addr % 2 == 0, "unaligned half load at {addr:#x}");
                let sh = (addr & 2) * 8;
                let h = (w >> sh) as u16;
                if matches!(size, MemSize::Half) {
                    h as i16 as i32 as u32
                } else {
                    h as u32
                }
            }
            MemSize::Byte | MemSize::ByteU => {
                let sh = (addr & 3) * 8;
                let b = (w >> sh) as u8;
                if matches!(size, MemSize::Byte) {
                    b as i8 as i32 as u32
                } else {
                    b as u32
                }
            }
        }
    }

    /// Functional store.
    pub fn store(&mut self, addr: u32, size: MemSize, value: u32) {
        let slot = self.slot(addr & !3);
        match size {
            MemSize::Word => {
                debug_assert!(addr % 4 == 0, "unaligned word store at {addr:#x}");
                *slot = value;
            }
            MemSize::Half | MemSize::HalfU => {
                debug_assert!(addr % 2 == 0, "unaligned half store at {addr:#x}");
                let sh = (addr & 2) * 8;
                *slot = (*slot & !(0xFFFFu32 << sh)) | ((value & 0xFFFF) << sh);
            }
            MemSize::Byte | MemSize::ByteU => {
                let sh = (addr & 3) * 8;
                *slot = (*slot & !(0xFFu32 << sh)) | ((value & 0xFF) << sh);
            }
        }
    }

    /// Mutable word-aligned span of `words` words fully inside one region;
    /// `None` sends the caller down the per-word masking path. L2 spans are
    /// grown (zero-filled) to cover the range, exactly like per-word writes
    /// would.
    fn words_mut(&mut self, addr: u32, words: usize) -> Option<&mut [u32]> {
        if addr % 4 != 0 || words == 0 {
            return None;
        }
        match self.region_of(addr) {
            Region::Dma => None,
            Region::Tcdm => {
                let idx = ((addr - TCDM_BASE) / 4) as usize;
                self.tcdm.get_mut(idx..idx + words)
            }
            Region::L2 => {
                let idx = ((addr - L2_BASE) / 4) as usize;
                if idx + words > self.l2_capacity {
                    return None; // per-word path raises the overflow panic
                }
                if idx + words > self.l2.len() {
                    self.l2.resize(idx + words, 0);
                }
                Some(&mut self.l2[idx..idx + words])
            }
        }
    }

    /// Shared word-aligned span, if the whole range is backed (an L2 range
    /// beyond the lazily-grown backing reads as zeros via the per-word
    /// path).
    fn words_ref(&self, addr: u32, words: usize) -> Option<&[u32]> {
        if addr % 4 != 0 || words == 0 {
            return None;
        }
        match self.region_of(addr) {
            Region::Dma => None,
            Region::Tcdm => {
                let idx = ((addr - TCDM_BASE) / 4) as usize;
                self.tcdm.get(idx..idx + words)
            }
            Region::L2 => {
                let idx = ((addr - L2_BASE) / 4) as usize;
                if idx + words > self.l2_capacity {
                    return None;
                }
                self.l2.get(idx..idx + words)
            }
        }
    }

    /// Bulk write of f32 values starting at `addr` (harness data staging).
    /// Word-aligned single-region spans take a direct copy; anything else
    /// falls back to per-word stores.
    pub fn write_f32_slice(&mut self, addr: u32, data: &[f32]) {
        if let Some(dst) = self.words_mut(addr, data.len()) {
            for (d, v) in dst.iter_mut().zip(data) {
                *d = v.to_bits();
            }
            return;
        }
        for (i, v) in data.iter().enumerate() {
            self.store(addr + 4 * i as u32, MemSize::Word, v.to_bits());
        }
    }

    /// Bulk read of f32 values.
    pub fn read_f32_slice(&self, addr: u32, len: usize) -> Vec<f32> {
        if let Some(src) = self.words_ref(addr, len) {
            return src.iter().map(|&w| f32::from_bits(w)).collect();
        }
        (0..len).map(|i| f32::from_bits(self.load(addr + 4 * i as u32, MemSize::Word))).collect()
    }

    /// Bulk write of raw 16-bit lanes (packed vectors). Word-aligned runs
    /// are packed two lanes per word and copied; a trailing odd lane (or an
    /// unaligned base) uses the masking path.
    pub fn write_u16_slice(&mut self, addr: u32, data: &[u16]) {
        if addr % 4 == 0 {
            let pairs = data.len() / 2;
            if let Some(dst) = self.words_mut(addr, pairs) {
                for (d, p) in dst.iter_mut().zip(data.chunks_exact(2)) {
                    *d = p[0] as u32 | ((p[1] as u32) << 16);
                }
                if data.len() % 2 == 1 {
                    let i = data.len() - 1;
                    self.store(addr + 2 * i as u32, MemSize::HalfU, data[i] as u32);
                }
                return;
            }
        }
        for (i, v) in data.iter().enumerate() {
            self.store(addr + 2 * i as u32, MemSize::HalfU, *v as u32);
        }
    }

    /// Bulk read of raw 16-bit lanes.
    pub fn read_u16_slice(&self, addr: u32, len: usize) -> Vec<u16> {
        if addr % 4 == 0 {
            if let Some(src) = self.words_ref(addr, len / 2) {
                let mut out = Vec::with_capacity(len);
                for &w in src {
                    out.push(w as u16);
                    out.push((w >> 16) as u16);
                }
                if len % 2 == 1 {
                    out.push(self.load(addr + 2 * (len - 1) as u32, MemSize::HalfU) as u16);
                }
                return out;
            }
        }
        (0..len).map(|i| self.load(addr + 2 * i as u32, MemSize::HalfU) as u16).collect()
    }

    /// Bulk write of raw words.
    pub fn write_u32_slice(&mut self, addr: u32, data: &[u32]) {
        if let Some(dst) = self.words_mut(addr, data.len()) {
            dst.copy_from_slice(data);
            return;
        }
        for (i, v) in data.iter().enumerate() {
            self.store(addr + 4 * i as u32, MemSize::Word, *v);
        }
    }

    /// TCDM capacity in bytes.
    pub fn tcdm_bytes(&self) -> usize {
        self.tcdm.len() * 4
    }

    /// Raw word-level view of the whole TCDM (the three-way differential
    /// wall compares final memory images across backends).
    pub fn tcdm_words(&self) -> &[u32] {
        &self.tcdm
    }

    /// Data phase of a TCDM atomic: read-modify-write one word, returning
    /// the old value. This is the single functional definition of the AMO
    /// semantics, shared by both cycle-accurate issue engines (via
    /// [`super::Cluster::exec_amo`]) and the functional backend.
    pub fn amo(&mut self, op: AmoOp, addr: u32, operand: u32) -> u32 {
        let old = self.load(addr, MemSize::Word);
        let new = match op {
            AmoOp::Add => old.wrapping_add(operand),
            AmoOp::Swap => operand,
        };
        self.store(addr, MemSize::Word, new);
        old
    }

    /// `memcpy`-style block move of `words` words from `src` to `dst`, used
    /// by the DMA engine. Returns `false` (no copy performed) when either
    /// range is unaligned, out of range, or the ranges are same-region and
    /// overlapping — callers then take the sequential per-word path.
    pub(crate) fn copy_words(&mut self, src: u32, dst: u32, words: usize) -> bool {
        if words == 0 {
            return true;
        }
        if src % 4 != 0 || dst % 4 != 0 {
            return false;
        }
        let (sr, dr) = (self.region_of(src), self.region_of(dst));
        if sr == Region::Dma || dr == Region::Dma {
            return false;
        }
        if sr == dr {
            let overlap = src < dst + 4 * words as u32 && dst < src + 4 * words as u32;
            if overlap {
                return false;
            }
        }
        // Reads of unallocated L2 words return zero: grow the source range
        // first so a plain slice copy sees the same values.
        if sr == Region::L2 {
            let idx = ((src - L2_BASE) / 4) as usize;
            if idx + words > self.l2_capacity {
                return false;
            }
            if idx + words > self.l2.len() {
                self.l2.resize(idx + words, 0);
            }
        }
        match (sr, dr) {
            (Region::L2, Region::Tcdm) => {
                let si = ((src - L2_BASE) / 4) as usize;
                let di = ((dst - TCDM_BASE) / 4) as usize;
                if di + words > self.tcdm.len() {
                    return false;
                }
                let (tcdm, l2) = (&mut self.tcdm, &self.l2);
                tcdm[di..di + words].copy_from_slice(&l2[si..si + words]);
            }
            (Region::Tcdm, Region::L2) => {
                let si = ((src - TCDM_BASE) / 4) as usize;
                let di = ((dst - L2_BASE) / 4) as usize;
                if si + words > self.tcdm.len() || di + words > self.l2_capacity {
                    return false;
                }
                if di + words > self.l2.len() {
                    self.l2.resize(di + words, 0);
                }
                let (l2, tcdm) = (&mut self.l2, &self.tcdm);
                l2[di..di + words].copy_from_slice(&tcdm[si..si + words]);
            }
            (Region::Tcdm, Region::Tcdm) => {
                let si = ((src - TCDM_BASE) / 4) as usize;
                let di = ((dst - TCDM_BASE) / 4) as usize;
                if si + words > self.tcdm.len() || di + words > self.tcdm.len() {
                    return false;
                }
                self.tcdm.copy_within(si..si + words, di);
            }
            (Region::L2, Region::L2) => {
                let si = ((src - L2_BASE) / 4) as usize;
                let di = ((dst - L2_BASE) / 4) as usize;
                if di + words > self.l2_capacity {
                    return false;
                }
                if di + words > self.l2.len() {
                    self.l2.resize(di + words, 0);
                }
                self.l2.copy_within(si..si + words, di);
            }
            // DMA-register endpoints were rejected above.
            (Region::Dma, _) | (_, Region::Dma) => unreachable!(),
        }
        true
    }
}

/// Cluster DMA engine (§3.1): moves blocks between L2 and TCDM at one word
/// per cycle after a fixed setup latency, without occupying the cores. Used
/// by the examples to stage input windows like a real near-sensor pipeline.
#[derive(Debug, Clone, Default)]
pub struct Dma {
    /// Cycle at which the running transfer (if any) completes.
    pub busy_until: u64,
    /// Total words moved (for power accounting).
    pub words_moved: u64,
}

impl Dma {
    /// Program a transfer of `words` 32-bit words from `src` to `dst`
    /// starting not before `now`; returns the completion cycle.
    /// Functionally copies immediately (the simulator is in-order; kernels
    /// must wait on the returned cycle before touching the data, which the
    /// harness enforces by starting cores after DMA completion).
    pub fn transfer(
        &mut self,
        mem: &mut Memory,
        now: u64,
        src: u32,
        dst: u32,
        words: u32,
    ) -> u64 {
        const SETUP: u64 = 10; // command + L2 latency
        if !mem.copy_words(src, dst, words as usize) {
            // Unaligned / overlapping / partially-backed ranges: the
            // word-at-a-time path preserves the exact sequential semantics.
            for i in 0..words {
                let v = mem.load(src + 4 * i, MemSize::Word);
                mem.store(dst + 4 * i, MemSize::Word, v);
            }
        }
        self.words_moved += words as u64;
        let start = self.busy_until.max(now);
        self.busy_until = start + SETUP + words as u64;
        self.busy_until
    }
}

/// Memory-mapped front-end of the cluster [`Dma`]: the `SRC`/`DST`/`LEN`
/// latches behind [`DMA_BASE`], the `CMD` trigger, and the outstanding-
/// transfer `STATUS` the runtime's `dma_wait` spin-polls. Programs drive it
/// with plain stores/loads; the simulator intercepts the [`Region::Dma`]
/// address range in both issue engines (at the global clock, in rotation
/// order — so concurrent programming from several cores is deterministic).
///
/// The data movement is performed functionally at trigger time (kernels
/// must not read the destination before `STATUS` drains — the runtime's
/// double-buffer protocol guarantees that); the *timing* is the [`Dma`]
/// model's: 10-cycle setup + 1 word/cycle, transfers queued back-to-back.
#[derive(Debug, Clone, Default)]
pub struct DmaCtl {
    /// Latched source/destination byte addresses and length in words.
    src: u32,
    dst: u32,
    len: u32,
    /// The timing + copy engine.
    pub engine: Dma,
    /// Completion cycles of triggered transfers (monotone — the single
    /// channel serializes), pruned as they pass.
    pending: Vec<u64>,
    /// Armed in-flight upset: `(word, mask)` XORed into word `word % len`
    /// of the next transfer's destination, then disarmed. See
    /// [`crate::faults`].
    corrupt: Option<(u32, u32)>,
}

impl DmaCtl {
    /// Reset to power-on state, keeping allocations.
    pub fn reset(&mut self) {
        self.src = 0;
        self.dst = 0;
        self.len = 0;
        self.engine = Dma { busy_until: 0, words_moved: 0 };
        self.pending.clear();
        self.corrupt = None;
    }

    /// Arm a single-event upset on the next triggered transfer: XOR `mask`
    /// into destination word `word % len` right after the payload lands
    /// (a bus flip while the data was in flight).
    pub fn corrupt_next(&mut self, word: u32, mask: u32) {
        self.corrupt = Some((word, mask));
    }

    /// Store `value` to the DMA register at byte offset `off` at `cycle`.
    /// A `CMD` store triggers the latched transfer against `mem`.
    pub fn store(&mut self, mem: &mut Memory, off: u32, value: u32, cycle: u64) {
        match off {
            dma_reg::SRC => self.src = value,
            dma_reg::DST => self.dst = value,
            dma_reg::LEN => self.len = value,
            dma_reg::CMD => {
                let done = self.engine.transfer(mem, cycle, self.src, self.dst, self.len);
                if let Some((word, mask)) = self.corrupt.take() {
                    if self.len > 0 {
                        let addr = self.dst + 4 * (word % self.len);
                        let v = mem.load(addr, MemSize::Word);
                        mem.store(addr, MemSize::Word, v ^ mask);
                    }
                }
                self.pending.push(done);
            }
            _ => panic!("store to unknown DMA register offset {off:#x}"),
        }
    }

    /// Load the DMA register at byte offset `off` at `cycle`. `STATUS`
    /// returns the number of transfers still in flight.
    pub fn load(&mut self, off: u32, cycle: u64) -> u32 {
        match off {
            dma_reg::STATUS => {
                // Prune completed transfers (both engines load at the same
                // deterministic cycle, so pruning cannot diverge).
                self.pending.retain(|&d| d > cycle);
                self.pending.len() as u32
            }
            _ => panic!("load from unknown DMA register offset {off:#x}"),
        }
    }

    /// Words moved so far (power accounting / tests).
    pub fn words_moved(&self) -> u64 {
        self.engine.words_moved
    }

    /// Currently latched transfer length in words (the trace layer labels
    /// DMA-start records with it).
    pub fn len_words(&self) -> u32 {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem8() -> Memory {
        Memory::new(&ClusterConfig::new(8, 4, 1))
    }

    #[test]
    fn banking_is_word_interleaved() {
        let m = mem8();
        assert_eq!(m.bank_of(TCDM_BASE), 0);
        assert_eq!(m.bank_of(TCDM_BASE + 4), 1);
        assert_eq!(m.bank_of(TCDM_BASE + 4 * 16), 0); // 16 banks for 8 cores
        assert_eq!(m.region_of(TCDM_BASE + 100), Region::Tcdm);
        assert_eq!(m.region_of(L2_BASE + 8), Region::L2);
    }

    #[test]
    fn bank_claims_conflict_within_cycle() {
        let mut m = mem8();
        assert!(m.claim_bank(3, 10));
        assert!(!m.claim_bank(3, 10)); // same cycle: contention
        assert!(m.claim_bank(3, 11)); // next cycle ok
        assert!(m.claim_bank(4, 10)); // other bank unaffected
    }

    #[test]
    fn sub_word_accesses() {
        let mut m = mem8();
        let a = TCDM_BASE + 64;
        m.store(a, MemSize::Word, 0xDEADBEEF);
        assert_eq!(m.load(a, MemSize::Word), 0xDEADBEEF);
        assert_eq!(m.load(a, MemSize::HalfU), 0xBEEF);
        assert_eq!(m.load(a + 2, MemSize::HalfU), 0xDEAD);
        assert_eq!(m.load(a, MemSize::Half), 0xFFFFBEEF); // sign-extended
        assert_eq!(m.load(a + 3, MemSize::ByteU), 0xDE);
        m.store(a + 2, MemSize::HalfU, 0x1234);
        assert_eq!(m.load(a, MemSize::Word), 0x1234BEEF);
        m.store(a + 1, MemSize::ByteU, 0x77);
        assert_eq!(m.load(a, MemSize::Word), 0x123477EF);
    }

    #[test]
    fn slices_roundtrip() {
        let mut m = mem8();
        let a = TCDM_BASE + 1024;
        m.write_f32_slice(a, &[1.0, -2.5, 3.25]);
        assert_eq!(m.read_f32_slice(a, 3), vec![1.0, -2.5, 3.25]);
        m.write_u16_slice(a, &[0x3C00, 0xC000]);
        assert_eq!(m.read_u16_slice(a, 2), vec![0x3C00, 0xC000]);
    }

    #[test]
    fn bulk_paths_match_per_word_semantics() {
        let mut m = mem8();
        // Odd-length u16 slice exercises the word fast path + masked tail.
        let a = TCDM_BASE + 512;
        m.write_u16_slice(a, &[1, 2, 3, 4, 5]);
        assert_eq!(m.read_u16_slice(a, 5), vec![1, 2, 3, 4, 5]);
        // Unaligned base falls back to the masking path.
        m.write_u16_slice(a + 2, &[7, 8, 9]);
        assert_eq!(m.read_u16_slice(a + 2, 3), vec![7, 8, 9]);
        assert_eq!(m.read_u16_slice(a, 1), vec![1]); // neighbour untouched
        // L2 bulk write grows the lazy backing; reads past it return zeros.
        m.write_u32_slice(L2_BASE + 64, &[10, 11, 12]);
        assert_eq!(m.load(L2_BASE + 64, MemSize::Word), 10);
        assert_eq!(m.read_f32_slice(L2_BASE + 4096, 2), vec![0.0, 0.0]);
    }

    #[test]
    fn reset_zeroes_and_keeps_capacity() {
        let mut m = mem8();
        m.write_u32_slice(TCDM_BASE, &[1, 2, 3]);
        m.write_u32_slice(L2_BASE, &[4, 5]);
        assert!(m.claim_bank(0, 7));
        m.reset();
        assert_eq!(m.read_u16_slice(TCDM_BASE, 2), vec![0, 0]);
        assert_eq!(m.load(L2_BASE, MemSize::Word), 0);
        assert!(m.claim_bank(0, 7), "bank grants cleared by reset");
        assert_eq!(m.tcdm_bytes(), 64 * 1024);
    }

    #[test]
    fn dma_overlapping_ranges_match_sequential_copy() {
        // Overlapping same-region copy must behave like the per-word loop.
        let mut m = mem8();
        let a = TCDM_BASE + 256;
        m.write_u32_slice(a, &[1, 2, 3, 4]);
        let mut dma = Dma::default();
        dma.transfer(&mut m, 0, a, a + 4, 4); // dst overlaps src
        // Sequential per-word semantics smear the first element forward.
        let got: Vec<u32> =
            (0..5).map(|i| m.load(a + 4 * i, MemSize::Word)).collect();
        assert_eq!(got, vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn dma_ctl_latches_triggers_and_reports_status() {
        let mut m = mem8();
        let mut ctl = DmaCtl::default();
        m.write_f32_slice(L2_BASE, &[1.0, 2.0, 3.0]);
        ctl.store(&mut m, dma_reg::SRC, L2_BASE, 100);
        ctl.store(&mut m, dma_reg::DST, TCDM_BASE, 100);
        ctl.store(&mut m, dma_reg::LEN, 3, 100);
        assert_eq!(ctl.load(dma_reg::STATUS, 100), 0);
        ctl.store(&mut m, dma_reg::CMD, 0, 100);
        // Data moves functionally at trigger; timing completes at 100+10+3.
        assert_eq!(m.read_f32_slice(TCDM_BASE, 3), vec![1.0, 2.0, 3.0]);
        assert_eq!(ctl.load(dma_reg::STATUS, 100), 1);
        assert_eq!(ctl.load(dma_reg::STATUS, 112), 1);
        assert_eq!(ctl.load(dma_reg::STATUS, 113), 0);
        // Back-to-back transfers queue on the single channel.
        ctl.store(&mut m, dma_reg::CMD, 0, 120);
        ctl.store(&mut m, dma_reg::CMD, 0, 120);
        assert_eq!(ctl.load(dma_reg::STATUS, 120), 2);
        assert_eq!(ctl.load(dma_reg::STATUS, 120 + 2 * 13), 0);
        assert_eq!(ctl.words_moved(), 9);
        ctl.reset();
        assert_eq!(ctl.load(dma_reg::STATUS, 0), 0);
    }

    #[test]
    fn dma_region_is_mapped() {
        let m = mem8();
        assert_eq!(m.region_of(DMA_BASE), Region::Dma);
        assert_eq!(m.region_of(DMA_BASE + dma_reg::CMD), Region::Dma);
        assert_eq!(m.region_of(L2_BASE), Region::L2);
        assert_eq!(m.region_of(TCDM_BASE + 64), Region::Tcdm);
    }

    #[test]
    fn dma_copies_and_accounts_time() {
        let mut m = mem8();
        let mut dma = Dma::default();
        m.write_f32_slice(L2_BASE, &[5.0, 6.0, 7.0, 8.0]);
        let done = dma.transfer(&mut m, 100, L2_BASE, TCDM_BASE, 4);
        assert_eq!(done, 100 + 10 + 4);
        assert_eq!(m.read_f32_slice(TCDM_BASE, 4), vec![5.0, 6.0, 7.0, 8.0]);
        // Back-to-back transfers queue.
        let done2 = dma.transfer(&mut m, 100, L2_BASE, TCDM_BASE + 16, 2);
        assert_eq!(done2, done + 10 + 2);
        assert_eq!(dma.words_moved, 6);
    }
}
