//! Shared two-level instruction-cache model (§3.1).
//!
//! The benchmarks are loop kernels, so the dominant I$ behaviour is the cold
//! fill of each line followed by hits; we model exactly that: the first core
//! to touch a line pays the refill from L2, concurrent requesters of the
//! same in-flight line wait for the same fill (the shared bank behaviour
//! that makes the shared I$ "optimized for SIMD/data-parallel workloads"),
//! and everything after is a single-cycle hit.

/// Instructions per cache line (128-bit lines, 4 × 32-bit instructions).
pub const INSNS_PER_LINE: usize = 4;

/// Refill latency from L2 in cycles.
pub const REFILL_LATENCY: u64 = 12;

/// Shared instruction cache: line-granular fill tracking.
#[derive(Debug, Clone)]
pub struct ICache {
    /// Per line: cycle at which the line becomes available; `u64::MAX` if
    /// never requested.
    line_ready: Vec<u64>,
    /// Miss count (lines filled).
    pub fills: u64,
}

impl ICache {
    /// Cache sized for a program of `program_len` instructions.
    pub fn new(program_len: usize) -> Self {
        ICache {
            line_ready: vec![u64::MAX; program_len / INSNS_PER_LINE + 1],
            fills: 0,
        }
    }

    /// Reset tags and counters, keeping the allocation.
    pub fn reset(&mut self) {
        self.line_ready.fill(u64::MAX);
        self.fills = 0;
    }

    /// Cycle at which the line holding `pc` becomes (or became) available;
    /// `u64::MAX` if it was never requested. Pure lookup — the batched
    /// issue engine uses it to decide whether a fetch can be a guaranteed
    /// hit without mutating fill state.
    #[inline]
    pub fn peek(&self, pc: u32) -> u64 {
        self.line_ready[pc as usize / INSNS_PER_LINE]
    }

    /// A core fetches instruction index `pc` at `cycle`. Returns the cycle
    /// at which the fetch completes (== `cycle` on a hit).
    pub fn fetch(&mut self, pc: u32, cycle: u64) -> u64 {
        let line = pc as usize / INSNS_PER_LINE;
        let ready = self.line_ready[line];
        if ready == u64::MAX {
            // Cold miss: start the refill.
            let done = cycle + REFILL_LATENCY;
            self.line_ready[line] = done;
            self.fills += 1;
            done
        } else if ready > cycle {
            // Fill in flight (another core missed first): wait for it.
            ready
        } else {
            cycle
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_miss_then_hits() {
        let mut ic = ICache::new(64);
        assert_eq!(ic.fetch(0, 100), 100 + REFILL_LATENCY);
        assert_eq!(ic.fills, 1);
        // Same line, later: hit.
        assert_eq!(ic.fetch(3, 200), 200);
        // Different line: new miss.
        assert_eq!(ic.fetch(4, 200), 200 + REFILL_LATENCY);
        assert_eq!(ic.fills, 2);
    }

    #[test]
    fn concurrent_requesters_share_fill() {
        let mut ic = ICache::new(16);
        let done = ic.fetch(8, 50);
        // A second core hits the in-flight fill and waits for the same cycle.
        assert_eq!(ic.fetch(9, 52), done);
        assert_eq!(ic.fills, 1);
    }

    #[test]
    fn peek_never_mutates() {
        let mut ic = ICache::new(16);
        assert_eq!(ic.peek(0), u64::MAX);
        assert_eq!(ic.fills, 0);
        let done = ic.fetch(0, 10);
        assert_eq!(ic.peek(3), done); // same line
        assert_eq!(ic.peek(4), u64::MAX); // next line untouched
        ic.reset();
        assert_eq!(ic.peek(0), u64::MAX);
        assert_eq!(ic.fills, 0);
    }
}
