//! Per-core state and functional execution for the RI5CY-like core model.
//!
//! Timing (stalls, arbitration, pipelining) lives in the cluster's issue
//! loop ([`super::Cluster`]); this module owns the architectural state —
//! registers, PC, hardware-loop stack, scoreboard — and the *functional*
//! semantics of each instruction, built on [`crate::transfp`].

use super::counters::CoreCounters;
use super::mem::Memory;
use crate::isa::insn::{AluOp, BrCond, FpOp, Insn, Operand, Reg};
use crate::transfp::{cast, scalar, simd, FpMode};

/// What produced the pending value of a register (stall attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Producer {
    #[default]
    None,
    /// FPU datapath (latency stall → `fpu_stall`).
    Fpu,
    /// Load unit (load-use stall → `load_stall`).
    Load,
    /// Shared DIV-SQRT block (→ `fpu_stall`).
    DivSqrt,
}

/// Execution state of a core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    Running,
    /// Asleep at an event-unit barrier since the carried cycle.
    Sleeping { since: u64 },
    Done,
}

/// One RI5CY-like core.
#[derive(Debug, Clone)]
pub struct Core {
    /// Core index within the cluster.
    pub id: usize,
    /// Register file (x0 hardwired to zero).
    pub regs: [u32; 32],
    /// Program counter (instruction index).
    pub pc: u32,
    /// Earliest cycle at which the core may issue again.
    pub next_issue: u64,
    /// Per-register earliest consume cycle (scoreboard).
    pub reg_ready: [u64; 32],
    /// Producer of each register's pending value.
    pub reg_producer: [Producer; 32],
    /// Hardware-loop stack: (start, end, remaining iterations).
    pub hwloops: Vec<(u32, u32, u32)>,
    /// Cycle of the most recent FP issue (write-back port model).
    pub last_fp_issue: u64,
    /// WB-conflict skid counter: the FPU's result register absorbs two of
    /// every three int-after-FP write-back collisions (§5.3.3 shows only a
    /// ~10% cycle penalty at 2 stages, not one stall per collision).
    pub wb_skid: u8,
    /// Execution state.
    pub state: CoreState,
    /// Performance counters.
    pub counters: CoreCounters,
}

impl Core {
    /// Fresh core `id` of `ncores`, with the HAL convention registers set
    /// (core id / ncores — §4's parallel runtime).
    pub fn new(id: usize, ncores: usize) -> Self {
        let mut regs = [0u32; 32];
        regs[crate::isa::regs::CORE_ID as usize] = id as u32;
        regs[crate::isa::regs::NCORES as usize] = ncores as u32;
        Core {
            id,
            regs,
            pc: 0,
            next_issue: 0,
            reg_ready: [0; 32],
            reg_producer: [Producer::None; 32],
            hwloops: Vec::with_capacity(2),
            // Sentinel that can never equal `t - 1` (t=0 wraps to u64::MAX).
            last_fp_issue: u64::MAX - 1,
            wb_skid: 0,
            state: CoreState::Running,
            counters: CoreCounters::default(),
        }
    }

    /// Read a register (x0 reads as zero).
    #[inline]
    pub fn reg(&self, r: u8) -> u32 {
        if r == 0 {
            0
        } else {
            self.regs[r as usize]
        }
    }

    /// Write a register (writes to x0 are dropped) and clear its scoreboard
    /// entry unless the caller re-arms it.
    #[inline]
    pub fn set_reg(&mut self, r: u8, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    /// Reset the core to its post-reset architectural state (HAL registers
    /// re-seeded), keeping allocations. Used by [`super::Cluster::reset`].
    pub fn reset(&mut self, ncores: usize) {
        self.regs = [0; 32];
        self.regs[crate::isa::regs::CORE_ID as usize] = self.id as u32;
        self.regs[crate::isa::regs::NCORES as usize] = ncores as u32;
        self.pc = 0;
        self.next_issue = 0;
        self.reg_ready = [0; 32];
        self.reg_producer = [Producer::None; 32];
        self.hwloops.clear();
        self.last_fp_issue = u64::MAX - 1;
        self.wb_skid = 0;
        self.state = CoreState::Running;
        self.counters = CoreCounters::default();
    }

    /// Latest ready-cycle over the registers an instruction reads, together
    /// with the producer responsible (for stall attribution). The read set
    /// comes from [`Insn::read_regs`] — the same source the predecode pass
    /// resolves once per program.
    pub fn operands_ready(&self, insn: &Insn) -> (u64, Producer) {
        let (regs, n) = insn.read_regs();
        self.scoreboard_ready(&regs[..n as usize])
    }

    /// Scoreboard check over a resolved read set (predecoded path).
    #[inline]
    pub fn scoreboard_ready(&self, reads: &[Reg]) -> (u64, Producer) {
        let mut worst = 0u64;
        let mut who = Producer::None;
        for &r in reads {
            let t = self.reg_ready[r as usize];
            if t > worst {
                worst = t;
                who = self.reg_producer[r as usize];
            }
        }
        (worst, who)
    }

    /// Advance past an executed instruction using its predecoded flags: the
    /// [`crate::isa::decoded::flag::LOOP_END_NEXT`] bit proves whether the
    /// hw-loop stack can possibly act, so the common case is a plain
    /// increment. Shared by the event engine's batcher and the functional
    /// interpreter.
    #[inline(always)]
    pub(crate) fn advance_decoded(&mut self, flags: u8) {
        if flags & crate::isa::decoded::flag::LOOP_END_NEXT != 0 {
            self.advance_pc();
        } else {
            self.pc += 1;
        }
    }

    /// Advance past the current instruction, honouring hardware loops.
    pub(crate) fn advance_pc(&mut self) {
        let mut next = self.pc + 1;
        while let Some((start, end, remaining)) = self.hwloops.last_mut() {
            if next == *end {
                if *remaining > 1 {
                    *remaining -= 1;
                    next = *start;
                    break;
                } else {
                    self.hwloops.pop();
                    // fall through: check enclosing loop against `next`
                }
            } else {
                break;
            }
        }
        self.pc = next;
    }

    /// Execute an integer ALU op functionally.
    pub fn exec_alu(&mut self, op: AluOp, rd: u8, rs1: u8, rhs: Operand) {
        let a = self.reg(rs1) as i32;
        let b = match rhs {
            Operand::Reg(r) => self.reg(r) as i32,
            Operand::Imm(i) => i,
        };
        let v = match op {
            AluOp::Add => a.wrapping_add(b),
            AluOp::Sub => a.wrapping_sub(b),
            AluOp::Sll => ((a as u32) << (b & 31)) as i32,
            AluOp::Srl => ((a as u32) >> (b & 31)) as i32,
            AluOp::Sra => a >> (b & 31),
            AluOp::And => a & b,
            AluOp::Or => a | b,
            AluOp::Xor => a ^ b,
            AluOp::Slt => (a < b) as i32,
            AluOp::Sltu => ((a as u32) < (b as u32)) as i32,
            AluOp::Mul => a.wrapping_mul(b),
            AluOp::Div => {
                if b == 0 {
                    -1
                } else {
                    a.wrapping_div(b)
                }
            }
            AluOp::Rem => {
                if b == 0 {
                    a
                } else {
                    a.wrapping_rem(b)
                }
            }
            AluOp::Min => a.min(b),
            AluOp::Max => a.max(b),
            AluOp::Abs => a.wrapping_abs(),
            AluOp::Mac => (self.reg(rd) as i32).wrapping_add(a.wrapping_mul(b)),
        };
        self.set_reg(rd, v as u32);
    }

    /// Evaluate a branch condition.
    pub fn branch_taken(&self, cond: BrCond, rs1: u8, rs2: u8) -> bool {
        let (a, b) = (self.reg(rs1), self.reg(rs2));
        match cond {
            BrCond::Eq => a == b,
            BrCond::Ne => a != b,
            BrCond::Lt => (a as i32) < (b as i32),
            BrCond::Ge => (a as i32) >= (b as i32),
            BrCond::Ltu => a < b,
            BrCond::Geu => a >= b,
        }
    }

    /// Execute a floating-point op functionally (numerics only — timing is
    /// the cluster's job). Returns the flop count contributed.
    pub fn exec_fp(&mut self, op: FpOp, mode: FpMode, rd: u8, rs1: u8, rs2: u8) -> u64 {
        use FpMode::*;
        let a = self.reg(rs1);
        let b = self.reg(rs2);
        let d = self.reg(rd);
        let v = match (op, mode) {
            // --- binary32 scalar
            (FpOp::Add, F32) => scalar::add32(a, b),
            (FpOp::Sub, F32) => scalar::sub32(a, b),
            (FpOp::Mul, F32) => scalar::mul32(a, b),
            (FpOp::Mac, F32) => scalar::fma32(a, b, d),
            (FpOp::Min, F32) => scalar::min32(a, b),
            (FpOp::Max, F32) => scalar::max32(a, b),
            (FpOp::Cmp(p), F32) => scalar::cmp32(a, b, p),
            (FpOp::Div, F32) => scalar::div32(a, b),
            (FpOp::Sqrt, F32) => scalar::sqrt32(a),
            (FpOp::Neg, F32) => a ^ 0x8000_0000,
            (FpOp::AbsF, F32) => a & 0x7FFF_FFFF,
            (FpOp::FromInt, F32) => cast::i32_to_f32(a),
            (FpOp::ToInt, F32) => cast::f32_to_i32(a),
            // --- 16-bit scalar (lane 0 of the register)
            (FpOp::Add, F16 | Bf16) => {
                scalar::add16(mode.spec().unwrap(), a as u16, b as u16) as u32
            }
            (FpOp::Sub, F16 | Bf16) => {
                scalar::sub16(mode.spec().unwrap(), a as u16, b as u16) as u32
            }
            (FpOp::Mul, F16 | Bf16) => {
                scalar::mul16(mode.spec().unwrap(), a as u16, b as u16) as u32
            }
            (FpOp::Mac, F16 | Bf16) => {
                scalar::fma16(mode.spec().unwrap(), a as u16, b as u16, d as u16) as u32
            }
            (FpOp::MacWiden, F16 | Bf16 | VecF16 | VecBf16) => {
                scalar::fma_widen(mode.spec().unwrap(), a as u16, b as u16, d)
            }
            (FpOp::Min, F16 | Bf16) => {
                scalar::min16(mode.spec().unwrap(), a as u16, b as u16) as u32
            }
            (FpOp::Max, F16 | Bf16) => {
                scalar::max16(mode.spec().unwrap(), a as u16, b as u16) as u32
            }
            (FpOp::Cmp(p), F16 | Bf16) => scalar::cmp16(mode.spec().unwrap(), a as u16, b as u16, p),
            (FpOp::Div, F16 | Bf16) => {
                scalar::div16(mode.spec().unwrap(), a as u16, b as u16) as u32
            }
            (FpOp::Sqrt, F16 | Bf16) => scalar::sqrt16(mode.spec().unwrap(), a as u16) as u32,
            (FpOp::Neg, F16 | Bf16) => (a as u16 ^ 0x8000) as u32,
            (FpOp::AbsF, F16 | Bf16) => (a as u16 & 0x7FFF) as u32,
            (FpOp::FromInt, F16 | Bf16) => cast::i32_to_16(mode.spec().unwrap(), a) as u32,
            (FpOp::ToInt, F16 | Bf16) => cast::f16_to_i32(mode.spec().unwrap(), a as u16),
            (FpOp::CvtDown, F16 | Bf16 | VecF16 | VecBf16) => {
                cast::f32_to_16(mode.spec().unwrap(), a) as u32
            }
            (FpOp::CvtUp, F16 | Bf16 | VecF16 | VecBf16) => {
                cast::f16_to_32(mode.spec().unwrap(), a as u16)
            }
            // --- packed-SIMD 2×16
            (FpOp::Add, VecF16 | VecBf16) => simd::vadd(mode.spec().unwrap(), a, b),
            (FpOp::Sub, VecF16 | VecBf16) => simd::vsub(mode.spec().unwrap(), a, b),
            (FpOp::Mul, VecF16 | VecBf16) => simd::vmul(mode.spec().unwrap(), a, b),
            (FpOp::Mac, VecF16 | VecBf16) => simd::vmac(mode.spec().unwrap(), a, b, d),
            (FpOp::DotpWiden, VecF16 | VecBf16) => simd::vdotp_widen(mode.spec().unwrap(), a, b, d),
            (FpOp::Min, VecF16 | VecBf16) => simd::vmin(mode.spec().unwrap(), a, b),
            (FpOp::Max, VecF16 | VecBf16) => simd::vmax(mode.spec().unwrap(), a, b),
            (FpOp::Cmp(p), VecF16 | VecBf16) => simd::vcmp(mode.spec().unwrap(), a, b, p),
            (FpOp::Neg, VecF16 | VecBf16) => a ^ 0x8000_8000,
            (FpOp::AbsF, VecF16 | VecBf16) => a & 0x7FFF_7FFF,
            (FpOp::Cpka, VecF16 | VecBf16) => cast::cpka(mode.spec().unwrap(), a, b),
            (FpOp::Shuffle, _) => simd::vshuffle(a, rs2 as u32),
            (FpOp::PackLo, _) => simd::vpack_lo(a, b),
            (FpOp::PackHi, _) => simd::vpack_hi(a, b),
            (op, mode) => panic!("unsupported FP op/mode combination {op:?}/{mode:?}"),
        };
        self.set_reg(rd, v);
        let flops = op.flops_per_lane()
            * if matches!(op, FpOp::DotpWiden) {
                1 // flops_per_lane already reports the full 4
            } else {
                mode.lanes() as u64
            };
        flops
    }

    /// Functional memory address of a load/store (before post-increment),
    /// plus application of the post-increment to the base register.
    pub fn mem_addr_and_postinc(&mut self, base: u8, offset: i32, post_inc: i32) -> u32 {
        let addr = (self.reg(base) as i64 + offset as i64) as u32;
        if post_inc != 0 {
            let nb = (self.reg(base) as i64 + post_inc as i64) as u32;
            self.set_reg(base, nb);
        }
        addr
    }

    /// Execute a load functionally.
    pub fn exec_load(&mut self, mem: &Memory, rd: u8, addr: u32, size: crate::isa::MemSize) {
        let v = mem.load(addr, size);
        self.set_reg(rd, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfp::spec::F16;

    #[test]
    fn x0_is_hardwired() {
        let mut c = Core::new(0, 8);
        c.set_reg(0, 1234);
        assert_eq!(c.reg(0), 0);
    }

    #[test]
    fn hal_registers_initialized() {
        let c = Core::new(3, 16);
        assert_eq!(c.reg(crate::isa::regs::CORE_ID), 3);
        assert_eq!(c.reg(crate::isa::regs::NCORES), 16);
    }

    #[test]
    fn alu_semantics() {
        let mut c = Core::new(0, 1);
        c.set_reg(1, (-7i32) as u32);
        c.set_reg(2, 3);
        c.exec_alu(AluOp::Div, 3, 1, Operand::Reg(2));
        assert_eq!(c.reg(3) as i32, -2);
        c.exec_alu(AluOp::Rem, 4, 1, Operand::Reg(2));
        assert_eq!(c.reg(4) as i32, -1);
        c.exec_alu(AluOp::Div, 5, 1, Operand::Imm(0));
        assert_eq!(c.reg(5) as i32, -1); // div-by-zero per RISC-V
        c.set_reg(6, 5);
        c.exec_alu(AluOp::Mac, 6, 1, Operand::Reg(2)); // 5 + (-7*3)
        assert_eq!(c.reg(6) as i32, -16);
        c.exec_alu(AluOp::Abs, 7, 1, Operand::Imm(0));
        assert_eq!(c.reg(7), 7);
    }

    #[test]
    fn fp_exec_and_flops() {
        let mut c = Core::new(0, 1);
        c.set_reg(1, 2.0f32.to_bits());
        c.set_reg(2, 3.0f32.to_bits());
        c.set_reg(3, 10.0f32.to_bits());
        let fl = c.exec_fp(FpOp::Mac, FpMode::F32, 3, 1, 2);
        assert_eq!(f32::from_bits(c.reg(3)), 16.0);
        assert_eq!(fl, 2);

        // SIMD mac: 2 lanes × 2 flops.
        let v1 = simd::pack2(F16.from_f64(1.0), F16.from_f64(2.0));
        let v2 = simd::pack2(F16.from_f64(3.0), F16.from_f64(4.0));
        c.set_reg(4, v1);
        c.set_reg(5, v2);
        c.set_reg(6, 0);
        let fl = c.exec_fp(FpOp::Mac, FpMode::VecF16, 6, 4, 5);
        assert_eq!(fl, 4);
        let (lo, hi) = simd::unpack2(c.reg(6));
        assert_eq!(F16.to_f64(lo), 3.0);
        assert_eq!(F16.to_f64(hi), 8.0);

        // Dot product: 4 flops, f32 accumulator.
        c.set_reg(7, 0);
        let fl = c.exec_fp(FpOp::DotpWiden, FpMode::VecF16, 7, 4, 5);
        assert_eq!(fl, 4);
        assert_eq!(f32::from_bits(c.reg(7)), 11.0);
    }

    #[test]
    fn branches() {
        let mut c = Core::new(0, 1);
        c.set_reg(1, 5);
        c.set_reg(2, 5);
        assert!(c.branch_taken(BrCond::Eq, 1, 2));
        assert!(!c.branch_taken(BrCond::Ne, 1, 2));
        c.set_reg(3, (-1i32) as u32);
        assert!(c.branch_taken(BrCond::Lt, 3, 1)); // signed
        assert!(!c.branch_taken(BrCond::Ltu, 3, 1)); // unsigned: 0xFFFF… > 5
    }

    #[test]
    fn post_increment_addressing() {
        let mut c = Core::new(0, 1);
        c.set_reg(5, 0x1000_0000);
        let addr = c.mem_addr_and_postinc(5, 0, 4);
        assert_eq!(addr, 0x1000_0000);
        assert_eq!(c.reg(5), 0x1000_0004);
        let addr = c.mem_addr_and_postinc(5, 8, -4);
        assert_eq!(addr, 0x1000_000C);
        assert_eq!(c.reg(5), 0x1000_0000);
    }
}
