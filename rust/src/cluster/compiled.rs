//! Compiled (direct-threaded) execution backend — tier four.
//!
//! [`CompiledBackend`] translates a [`DecodedProgram`] once into a flat,
//! pre-resolved dispatch structure and then executes *that*, instead of
//! re-matching on the `Insn` enum and its embedded operands at every
//! retired instruction the way the functional interpreter does. Two
//! structures come out of translation:
//!
//! * a per-pc [`Step`] array: every instruction lowered to a flat variant
//!   with its operands and predecode flags extracted — one match on a
//!   shallow enum per dispatch, no nested `let ... else` destructuring;
//! * a fused-block table: each maximal straight-line run of core-local
//!   register ops (integer ALU, load-immediate, FP-ALU permutes — the
//!   compilable subset of the [`DecodedProgram::local_run_len`] regions,
//!   excluding control transfers whose successor depends on run state)
//!   becomes one [`FusedBlock`]: a superinstruction that executes the whole
//!   run with a single watchdog charge and a single pc update;
//! * a loop-trace table: each innermost hot-loop body — found from the
//!   hw-loop metadata ([`DecodedProgram::hw_loop_bodies`]) and from
//!   conditional backward branches — becomes one [`LoopTrace`]: a
//!   superinstruction that executes **whole iterations**, including the
//!   back-edge and the hw-loop counter decrement, in a single dispatch
//!   with one batched watchdog check per iteration.
//!
//! ## Trace formation rules
//!
//! A candidate region `[head, tail]` (a hw-loop body `[start, end)`, or
//! `[target, branch]` for a conditional branch whose target is at or
//! before it) compiles to a trace iff every instruction in it is
//! *trace-admissible* — integer ALU, load-immediate, any FP datapath op,
//! plain loads/stores, and conditional branches — and no instruction
//! before the tail sits on a hw-loop end boundary (`LOOP_END_NEXT`).
//! Atomics, barriers, event waits/sets, jumps, nested `HwLoop` setup and
//! `End` disqualify the region, which also means an outer loop whose body
//! contains an inner loop's setup never traces: only innermost loops do.
//!
//! ## Trace bail-outs
//!
//! Execution falls out of a trace back to per-step dispatch on:
//!
//! * **side-exits** — any taken branch other than the tail back-edge
//!   leaves the trace at its target, charging exactly the ops retired;
//! * **memory-ordering hazards** — a load/store whose address resolves
//!   into the DMA window bails *before* any architectural mutation (the
//!   post-increment included) so the per-step path replays the op with
//!   full DMA semantics;
//! * **trip-count exhaustion** — the tail's `advance_decoded` walks the
//!   real hw-loop stack (nested and shared-end boundaries included), so
//!   falling out lands exactly where the functional tier would;
//! * **watchdog pressure** — an iteration is entered only when its whole
//!   length fits the remaining instruction budget; otherwise the trace
//!   exits with nothing charged and the per-step path charges one at a
//!   time, tripping `Timeout { budget }` at the tier-identical count.
//!
//! Contention points — atomics, event waits, barriers, DMA — and every
//! bail-out fall back to exactly the functional interpreter's dispatch
//! semantics, one instruction at a time, so the architectural result
//! (outputs, registers, TCDM image, retired count) and the error
//! classification (deadlock / timeout / fault) are bit-identical to the
//! functional tier — and through it to both timed engines.
//! `tests/differential.rs` asserts this as a four-way wall.
//!
//! ## Code cache
//!
//! Translations are content-addressed by [`DecodedProgram::fingerprint`]
//! and kept in a [`CodeCache`] — 16-way sharded like the coordinator's
//! `MeasurementCache`, so concurrent sweep workers hitting the same
//! program neither contend on one lock nor translate twice. A warm
//! `tune --probe compiled` over the full ladder performs **zero**
//! re-translations (gated in `benches/backend.rs` and the tuner tests);
//! the invalidation rule is the fingerprint itself — editing a kernel
//! changes its key, and stale translations are simply never addressed
//! again. Growth is bounded: the cache holds at most its configured
//! capacity (default [`DEFAULT_CODE_CAPACITY`]), evicting the
//! least-recently-used entry of the inserting shard when full, so a
//! fuzzed random-program load cannot grow it without bound — evictions
//! are counted and surfaced in the `serve` stats endpoint.
//!
//! ## Watchdog
//!
//! The retired-instruction budget is honored exactly: a fused block or a
//! trace iteration is taken only when its whole length fits under the
//! budget; otherwise the ops run through the one-at-a-time path with the
//! functional tier's charge-then-check ordering, so `Timeout { budget }`
//! trips after the same retired count on both tiers.
//!
//! `benches/backend.rs` gates this tier at ≥ 10× the functional
//! interpreter's instruction throughput on the loop-dominated kernels
//! (FIR, MATMUL, KMEANS — where the paper's cycles are) and ≥ 5× on the
//! straight-line remainder of the suite.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::backend::{BackendRun, ExecBackend, RunError, Watchdog};
use super::core::{Core, CoreState};
use super::event::EventUnit;
use super::mem::{DmaCtl, Memory, Region, DMA_BASE};
use crate::config::ClusterConfig;
use crate::isa::decoded::{flag, DecodedInsn, DecodedProgram, OpClass};
use crate::isa::insn::{AluOp, AmoOp, BrCond, FpOp, Insn, MemSize, Operand, Reg};
use crate::isa::{regs, Program};
use crate::transfp::FpMode;

/// Retired-instruction budget per run — identical to the functional
/// tier's, so default-watchdog behavior matches across both untimed tiers.
const MAX_INSTRS: u64 = 2_000_000_000;

/// One pre-resolved core-local register op inside a [`FusedBlock`]. Only
/// ops with a statically-known sequential successor qualify, so executing
/// a block never consults the hw-loop stack or the flags byte.
#[derive(Debug, Clone, Copy)]
enum MicroOp {
    Alu { op: AluOp, rd: Reg, rs1: Reg, rhs: Operand },
    Li { rd: Reg, imm: u32 },
    Fp { op: FpOp, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg },
}

/// A superinstruction: one maximal straight-line run of [`MicroOp`]s,
/// executed with a single watchdog charge and a single pc update.
#[derive(Debug)]
struct FusedBlock {
    /// The run's ops, in program order.
    ops: Box<[MicroOp]>,
    /// pc after the block (head + len — the run is sequential by
    /// construction).
    next: u32,
}

/// One instruction lowered to a flat, operand-resolved dispatch variant.
/// The `flags` byte is the predecoded [`flag`] set — consulted only for
/// the sequential-advance path (`LOOP_END_NEXT`), exactly like the
/// functional interpreter.
#[derive(Debug, Clone, Copy)]
enum Step {
    Alu { op: AluOp, rd: Reg, rs1: Reg, rhs: Operand, flags: u8 },
    Li { rd: Reg, imm: u32, flags: u8 },
    Fp { op: FpOp, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg, flags: u8 },
    Branch { cond: BrCond, rs1: Reg, rs2: Reg, target: u32, flags: u8 },
    Jump { target: u32 },
    HwLoop { count: Reg, start: u32, end: u32 },
    Load { rd: Reg, base: Reg, offset: i32, post_inc: i32, size: MemSize, flags: u8 },
    Store { rs: Reg, base: Reg, offset: i32, post_inc: i32, size: MemSize, flags: u8 },
    Amo { op: AmoOp, rd: Reg, base: Reg, offset: i32, rs: Reg, flags: u8 },
    Barrier { flags: u8 },
    WaitEvent { ev: u8, flags: u8 },
    SetEvent { ev: u8, flags: u8 },
    End,
}

/// One pre-resolved instruction inside a [`LoopTrace`]. Unlike a
/// [`MicroOp`], trace ops may touch memory (plain loads/stores) and
/// transfer control (conditional branches) — the trace executor handles
/// their hazards and exits explicitly.
#[derive(Debug, Clone, Copy)]
enum TraceOp {
    Alu { op: AluOp, rd: Reg, rs1: Reg, rhs: Operand },
    Li { rd: Reg, imm: u32 },
    Fp { op: FpOp, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg },
    Load { rd: Reg, base: Reg, offset: i32, post_inc: i32, size: MemSize },
    Store { rs: Reg, base: Reg, offset: i32, post_inc: i32, size: MemSize },
    Branch { cond: BrCond, rs1: Reg, rs2: Reg, target: u32 },
}

/// A hot-loop superinstruction: the ops of one innermost loop body
/// (`[head, head + ops.len())` in pc space), executed whole iterations at
/// a time — back-edge and hw-loop counter decrement included — with one
/// batched watchdog check per iteration.
#[derive(Debug)]
struct LoopTrace {
    /// First pc of the body (the trace dispatches when a core lands here).
    head: u32,
    /// The body, in program order; the last op is the loop tail.
    ops: Box<[TraceOp]>,
    /// Predecode flags of the tail instruction — handed to
    /// `advance_decoded` so the real hw-loop stack walk (nested loops,
    /// shared end boundaries) decides the back-edge.
    tail_flags: u8,
}

/// A translated program: dense per-pc steps plus the fused-block and
/// loop-trace tables. `blocks[pc]` / `traces[pc]` are `Some` only at the
/// *head* of a run or loop body — a branch into the middle lands on the
/// per-step path and stays correct (it just forgoes fusion until the next
/// head).
#[derive(Debug)]
pub struct CompiledProgram {
    steps: Vec<Step>,
    blocks: Vec<Option<FusedBlock>>,
    traces: Vec<Option<LoopTrace>>,
}

/// True if the instruction may join a fused block: a core-local register
/// op whose successor is statically `pc + 1`. Control transfers (branches,
/// jumps, hw-loop setup, `End`) are local but end a block, as does any op
/// sitting on a hw-loop back-edge (`LOOP_END_NEXT`), whose successor
/// depends on the loop stack at run time.
fn fusable(d: &DecodedInsn) -> bool {
    matches!(d.class, OpClass::Alu | OpClass::Li | OpClass::FpAlu) && !d.has(flag::LOOP_END_NEXT)
}

/// Lower one decoded instruction to its flat dispatch variant.
fn step_of(d: &DecodedInsn) -> Step {
    let flags = d.flags;
    match d.insn {
        Insn::Alu { op, rd, rs1, rhs } => Step::Alu { op, rd, rs1, rhs, flags },
        Insn::Li { rd, imm } => Step::Li { rd, imm, flags },
        Insn::Load { rd, base, offset, post_inc, size } => {
            Step::Load { rd, base, offset, post_inc, size, flags }
        }
        Insn::Store { rs, base, offset, post_inc, size } => {
            Step::Store { rs, base, offset, post_inc, size, flags }
        }
        Insn::Branch { cond, rs1, rs2, target } => Step::Branch { cond, rs1, rs2, target, flags },
        Insn::Jump { target } => Step::Jump { target },
        Insn::HwLoop { count, start, end } => Step::HwLoop { count, start, end },
        Insn::Fp { op, mode, rd, rs1, rs2 } => Step::Fp { op, mode, rd, rs1, rs2, flags },
        Insn::Amo { op, rd, base, offset, rs } => Step::Amo { op, rd, base, offset, rs, flags },
        Insn::Barrier => Step::Barrier { flags },
        Insn::WaitEvent { ev } => Step::WaitEvent { ev, flags },
        Insn::SetEvent { ev } => Step::SetEvent { ev, flags },
        Insn::End => Step::End,
    }
}

/// Lower one fusable instruction to its block micro-op.
fn micro_of(d: &DecodedInsn) -> MicroOp {
    match d.insn {
        Insn::Alu { op, rd, rs1, rhs } => MicroOp::Alu { op, rd, rs1, rhs },
        Insn::Li { rd, imm } => MicroOp::Li { rd, imm },
        Insn::Fp { op, mode, rd, rs1, rs2 } => MicroOp::Fp { op, mode, rd, rs1, rs2 },
        ref other => unreachable!("non-fusable insn in a fused run: {other:?}"),
    }
}

/// True if the instruction may live inside a loop trace: anything the
/// trace executor can run without consulting the event unit, the DMA
/// controller (statically) or the scheduler. Atomics are excluded — their
/// TCDM-region fault path must stay on per-step dispatch — as are all
/// blocking and control-setup ops.
fn traceable(d: &DecodedInsn) -> bool {
    matches!(
        d.class,
        OpClass::Alu
            | OpClass::Li
            | OpClass::FpAlu
            | OpClass::Fp
            | OpClass::FpDivSqrt
            | OpClass::Load
            | OpClass::Store
            | OpClass::Branch
    )
}

/// Lower one trace-admissible instruction to its trace op.
fn trace_op(d: &DecodedInsn) -> TraceOp {
    match d.insn {
        Insn::Alu { op, rd, rs1, rhs } => TraceOp::Alu { op, rd, rs1, rhs },
        Insn::Li { rd, imm } => TraceOp::Li { rd, imm },
        Insn::Fp { op, mode, rd, rs1, rs2 } => TraceOp::Fp { op, mode, rd, rs1, rs2 },
        Insn::Load { rd, base, offset, post_inc, size } => {
            TraceOp::Load { rd, base, offset, post_inc, size }
        }
        Insn::Store { rs, base, offset, post_inc, size } => {
            TraceOp::Store { rs, base, offset, post_inc, size }
        }
        Insn::Branch { cond, rs1, rs2, target } => TraceOp::Branch { cond, rs1, rs2, target },
        ref other => unreachable!("non-traceable insn in a loop trace: {other:?}"),
    }
}

/// Translate a predecoded program: lower every pc to a [`Step`], fuse
/// every maximal straight-line run of length ≥ 2 into a block at its head,
/// and compile every qualifying innermost loop body into a [`LoopTrace`].
fn translate(decoded: &DecodedProgram) -> CompiledProgram {
    let n = decoded.insns.len();
    let steps: Vec<Step> = decoded.insns.iter().map(step_of).collect();
    let mut blocks: Vec<Option<FusedBlock>> = (0..n).map(|_| None).collect();
    let mut pc = 0usize;
    while pc < n {
        if !fusable(&decoded.insns[pc]) {
            pc += 1;
            continue;
        }
        let start = pc;
        while pc < n && fusable(&decoded.insns[pc]) {
            pc += 1;
        }
        // A one-op "block" would only add an indirection over its step.
        if pc - start >= 2 {
            let ops: Box<[MicroOp]> = decoded.insns[start..pc].iter().map(micro_of).collect();
            blocks[start] = Some(FusedBlock { ops, next: pc as u32 });
        }
    }

    // Loop-trace candidates: hw-loop bodies first (the paper's hot path),
    // then conditional-backward-branch loops. First qualifying candidate
    // at a head wins.
    let mut traces: Vec<Option<LoopTrace>> = (0..n).map(|_| None).collect();
    let mut candidates: Vec<(u32, u32)> = decoded.hw_loop_bodies();
    for (pc, d) in decoded.insns.iter().enumerate() {
        if let Insn::Branch { target, .. } = d.insn {
            if target as usize <= pc {
                candidates.push((target, pc as u32));
            }
        }
    }
    for (head, tail) in candidates {
        let (h, t) = (head as usize, tail as usize);
        if t >= n || traces[h].is_some() {
            continue;
        }
        let body = &decoded.insns[h..=t];
        if !body.iter().all(traceable) {
            continue;
        }
        // An interior hw-loop end boundary means a *different* loop closes
        // mid-region; its back-edge bookkeeping needs per-step dispatch.
        if body[..body.len() - 1].iter().any(|d| d.has(flag::LOOP_END_NEXT)) {
            continue;
        }
        let ops: Box<[TraceOp]> = body.iter().map(trace_op).collect();
        traces[h] = Some(LoopTrace { head, ops, tail_flags: decoded.insns[t].flags });
    }
    CompiledProgram { steps, blocks, traces }
}

/// Execute one fused micro-op. No pc bookkeeping — the caller sets
/// `pc = block.next` once after the run.
#[inline(always)]
fn exec_micro(c: &mut Core, op: &MicroOp) {
    match *op {
        MicroOp::Alu { op, rd, rs1, rhs } => c.exec_alu(op, rd, rs1, rhs),
        MicroOp::Li { rd, imm } => c.set_reg(rd, imm),
        MicroOp::Fp { op, mode, rd, rs1, rs2 } => {
            let _ = c.exec_fp(op, mode, rd, rs1, rs2);
        }
    }
}

/// Execute a loop trace: whole iterations per dispatch until a bail-out.
///
/// Watchdog accounting is exact: an iteration is entered only when its
/// full length fits the remaining budget (so the budget-pressure exit
/// charges nothing and leaves `pc` at the head for the per-step path),
/// and every other exit charges precisely the ops retired — `i` for a
/// hazard bail *before* op `i`, `i + 1` for a taken side-exit branch,
/// the full length for a completed iteration. The caller re-dispatches
/// from wherever `pc` lands.
fn run_trace(c: &mut Core, mem: &mut Memory, tr: &LoopTrace, total: &mut u64, max_instrs: u64) {
    let len = tr.ops.len() as u64;
    let last = tr.ops.len() - 1;
    'iter: while *total + len <= max_instrs {
        for (i, op) in tr.ops.iter().enumerate() {
            match *op {
                TraceOp::Alu { op, rd, rs1, rhs } => c.exec_alu(op, rd, rs1, rhs),
                TraceOp::Li { rd, imm } => c.set_reg(rd, imm),
                TraceOp::Fp { op, mode, rd, rs1, rs2 } => {
                    let _ = c.exec_fp(op, mode, rd, rs1, rs2);
                }
                TraceOp::Load { rd, base, offset, post_inc, size } => {
                    // Address from the *pre-increment* base; the hazard
                    // check must run before any mutation so the per-step
                    // replay sees untouched state.
                    let addr = (c.reg(base) as i64 + offset as i64) as u32;
                    if matches!(mem.region_of(addr), Region::Dma) {
                        *total += i as u64;
                        c.counters.instrs += i as u64;
                        c.pc = tr.head + i as u32;
                        return;
                    }
                    if post_inc != 0 {
                        let nb = (c.reg(base) as i64 + post_inc as i64) as u32;
                        c.set_reg(base, nb);
                    }
                    c.exec_load(mem, rd, addr, size);
                }
                TraceOp::Store { rs, base, offset, post_inc, size } => {
                    let addr = (c.reg(base) as i64 + offset as i64) as u32;
                    if matches!(mem.region_of(addr), Region::Dma) {
                        *total += i as u64;
                        c.counters.instrs += i as u64;
                        c.pc = tr.head + i as u32;
                        return;
                    }
                    if post_inc != 0 {
                        let nb = (c.reg(base) as i64 + post_inc as i64) as u32;
                        c.set_reg(base, nb);
                    }
                    // Value read after the post-increment, like the engines.
                    let v = c.reg(rs);
                    mem.store(addr, size, v);
                }
                TraceOp::Branch { cond, rs1, rs2, target } => {
                    if c.branch_taken(cond, rs1, rs2) {
                        if i == last && target == tr.head {
                            // The defining back-edge: a whole iteration
                            // retired in one charge.
                            *total += len;
                            c.counters.instrs += len;
                            continue 'iter;
                        }
                        // Side-exit mid-iteration.
                        *total += i as u64 + 1;
                        c.counters.instrs += i as u64 + 1;
                        c.pc = target;
                        return;
                    }
                    // Not taken: sequential successor (interior branches
                    // never sit on a loop end boundary — formation rule).
                }
            }
        }
        // The tail retired without transferring control: charge the
        // iteration, then let the real hw-loop stack walk decide the
        // back-edge (counter decrement, nested/shared ends, fall-out).
        *total += len;
        c.counters.instrs += len;
        c.pc = tr.head + last as u32;
        c.advance_decoded(tr.tail_flags);
        if c.pc != tr.head {
            return;
        }
    }
    // Budget pressure: nothing charged, pc still at the head.
}

/// Default [`CodeCache`] capacity (resident translations). Far above any
/// real working set — the full tune ladder is 40 programs — so eviction
/// only engages under adversarial (fuzzed random-program) load.
pub const DEFAULT_CODE_CAPACITY: usize = 1024;

/// One resident translation with its recency stamp.
struct CacheEntry {
    prog: Arc<CompiledProgram>,
    last_use: u64,
}

/// Content-addressed translation cache, shared across sweep workers.
///
/// Sharded 16 ways on the program fingerprint (the same discipline as the
/// coordinator's `MeasurementCache`): concurrent workers translating
/// *different* programs never contend, and workers asking for the *same*
/// program serialize on one shard and translate exactly once — the miss
/// counter is therefore an exact count of translations performed, which is
/// what the warm-probe economics gates audit.
///
/// Residency is bounded: capacity is split evenly across the shards
/// (`max(1, capacity / 16)` entries per shard), and an insert into a full
/// shard first evicts that shard's least-recently-used entry. Hits refresh
/// recency through a global monotonic tick. `len() == misses - evictions`
/// holds at all times.
pub struct CodeCache {
    shards: [Mutex<HashMap<u64, CacheEntry>>; 16],
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    tick: AtomicU64,
    shard_cap: usize,
}

impl Default for CodeCache {
    fn default() -> CodeCache {
        CodeCache::new()
    }
}

impl CodeCache {
    /// An empty cache with the default capacity.
    pub fn new() -> CodeCache {
        CodeCache::with_capacity(DEFAULT_CODE_CAPACITY)
    }

    /// An empty cache bounded to `capacity` resident translations
    /// (rounded down to a multiple of the 16 shards, minimum 16).
    pub fn with_capacity(capacity: usize) -> CodeCache {
        CodeCache {
            shards: std::array::from_fn(|_| Mutex::new(HashMap::new())),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            shard_cap: (capacity / 16).max(1),
        }
    }

    /// The bound on resident translations.
    pub fn capacity(&self) -> usize {
        self.shard_cap * 16
    }

    /// The process-wide cache every [`CompiledBackend::shared`] instance
    /// uses (CLI runs, sweeps and benches all share translations).
    pub fn global() -> &'static CodeCache {
        static GLOBAL: OnceLock<CodeCache> = OnceLock::new();
        GLOBAL.get_or_init(CodeCache::new)
    }

    /// The translation for `decoded`, reused if its fingerprint is
    /// resident. Translation happens under the shard lock, so a program is
    /// translated exactly once no matter how many workers race on it —
    /// unless capacity pressure evicted it in between, in which case the
    /// re-translation is an honest new miss.
    pub fn translate(&self, decoded: &DecodedProgram) -> Arc<CompiledProgram> {
        let key = decoded.fingerprint();
        let shard = &self.shards[(key as usize) & 15];
        let mut map = shard.lock().unwrap();
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        if let Some(hit) = map.get_mut(&key) {
            hit.last_use = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&hit.prog);
        }
        if map.len() >= self.shard_cap {
            // LRU-ish: evict this shard's stalest entry to stay bounded.
            if let Some(&victim) = map.iter().min_by_key(|(_, e)| e.last_use).map(|(k, _)| k) {
                map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let compiled = Arc::new(translate(decoded));
        self.misses.fetch_add(1, Ordering::Relaxed);
        map.insert(key, CacheEntry { prog: Arc::clone(&compiled), last_use: now });
        compiled
    }

    /// (hits, misses) so far. `misses` equals translations performed.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Translations dropped under capacity pressure.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Number of resident translations.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True if no translation is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The compiled (direct-threaded) execution tier.
///
/// `CompiledBackend::shared()` uses the process-wide [`CodeCache`]; tests
/// and engines that need isolated hit/miss accounting construct one with
/// [`CompiledBackend::with_cache`].
pub struct CompiledBackend {
    cache: Option<Arc<CodeCache>>,
}

impl CompiledBackend {
    /// A backend over the process-wide code cache (`const`, so it can back
    /// the `&'static dyn ExecBackend` the selector hands out).
    pub const fn shared() -> CompiledBackend {
        CompiledBackend { cache: None }
    }

    /// A backend over an explicit cache (isolated accounting).
    pub fn with_cache(cache: Arc<CodeCache>) -> CompiledBackend {
        CompiledBackend { cache: Some(cache) }
    }

    /// The cache this backend translates through.
    pub fn cache(&self) -> &CodeCache {
        match &self.cache {
            Some(c) => c,
            None => CodeCache::global(),
        }
    }
}

impl ExecBackend for CompiledBackend {
    fn name(&self) -> &'static str {
        "compiled"
    }

    fn is_cycle_accurate(&self) -> bool {
        false
    }

    fn run_watched(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
        wd: Watchdog,
    ) -> Result<BackendRun, RunError> {
        let decoded = DecodedProgram::decode(program);
        let compiled = self.cache().translate(&decoded);
        run_compiled_watched(cfg, &compiled, workers, stage, wd.max_instrs)
    }
}

/// Execute a translated program. The scheduling model is byte-for-byte the
/// functional interpreter's: cores run round-robin, each to its next
/// blocking point; a full pass with no runnable core while some sleep is a
/// [`RunError::Deadlock`]; the retired-instruction watchdog surfaces as
/// [`RunError::Timeout`] after the identical retired count.
pub fn run_compiled_watched(
    cfg: &ClusterConfig,
    compiled: &CompiledProgram,
    workers: usize,
    stage: &mut dyn FnMut(&mut Memory),
    max_instrs: u64,
) -> Result<BackendRun, RunError> {
    assert!(workers >= 1 && workers <= cfg.cores, "occupancy out of range");
    let n = cfg.cores;
    // Mirror `Cluster::new` + `limit_active_cores` exactly, so inactive
    // cores' register files match the other tiers bit-for-bit.
    let mut cores: Vec<Core> = (0..n).map(|i| Core::new(i, n)).collect();
    for c in cores.iter_mut().skip(workers) {
        c.state = CoreState::Done;
    }
    for c in cores.iter_mut().take(workers) {
        c.set_reg(regs::NCORES, workers as u32);
    }
    let mut mem = Memory::new(cfg);
    stage(&mut mem);
    let mut event = EventUnit::new(workers);
    let mut dmac = DmaCtl::default();

    let mut total = 0u64;
    loop {
        let mut ran = false;
        for ci in 0..workers {
            if !matches!(cores[ci].state, CoreState::Running) {
                continue;
            }
            ran = true;
            run_core(
                ci,
                compiled,
                workers,
                &mut cores,
                &mut mem,
                &mut event,
                &mut dmac,
                &mut total,
                max_instrs,
            )?;
        }
        if !ran {
            break;
        }
    }
    let asleep = cores.iter().filter(|c| matches!(c.state, CoreState::Sleeping { .. })).count();
    if asleep > 0 {
        return Err(RunError::Deadlock { asleep });
    }
    Ok(BackendRun { regs: cores.iter().map(|c| c.regs).collect(), mem, stats: None, instrs: total })
}

/// [`run_compiled_watched`] under the default instruction budget.
pub fn run_compiled(
    cfg: &ClusterConfig,
    compiled: &CompiledProgram,
    workers: usize,
    stage: &mut dyn FnMut(&mut Memory),
) -> Result<BackendRun, RunError> {
    run_compiled_watched(cfg, compiled, workers, stage, MAX_INSTRS)
}

/// Run core `ci` until it blocks (event sleep, incomplete barrier) or
/// terminates. Loop traces execute whole iterations per dispatch and
/// fused blocks whole straight-line runs, each with one batched watchdog
/// charge when the length fits under the budget; near the budget (and at
/// every pc that is not a trace or block head) dispatch is one [`Step`]
/// at a time with the functional tier's exact charge-then-check ordering,
/// so the retired count at a [`RunError::Timeout`] is tier-identical.
#[allow(clippy::too_many_arguments)]
fn run_core(
    ci: usize,
    compiled: &CompiledProgram,
    workers: usize,
    cores: &mut [Core],
    mem: &mut Memory,
    event: &mut EventUnit,
    dmac: &mut DmaCtl,
    total: &mut u64,
    max_instrs: u64,
) -> Result<(), RunError> {
    loop {
        // ---- Loop-trace fast path: whole iterations at a time.
        {
            let c = &mut cores[ci];
            if let Some(tr) = compiled.traces[c.pc as usize].as_ref() {
                run_trace(c, mem, tr, total, max_instrs);
            }
        }

        // ---- Fused fast path: whole straight-line runs at a time.
        {
            let c = &mut cores[ci];
            while let Some(block) = compiled.blocks[c.pc as usize].as_ref() {
                let len = block.ops.len() as u64;
                if *total + len > max_instrs {
                    // Too close to the budget to batch — fall through to
                    // the per-step path, which charges one at a time and
                    // trips the watchdog at the exact functional count.
                    break;
                }
                *total += len;
                c.counters.instrs += len;
                for op in block.ops.iter() {
                    exec_micro(c, op);
                }
                c.pc = block.next;
            }
        }

        // ---- Per-step path: one pre-resolved instruction.
        let pc = cores[ci].pc as usize;
        *total += 1;
        if *total > max_instrs {
            return Err(RunError::Timeout { budget: max_instrs });
        }
        cores[ci].counters.instrs += 1;
        match compiled.steps[pc] {
            Step::Alu { op, rd, rs1, rhs, flags } => {
                let c = &mut cores[ci];
                c.exec_alu(op, rd, rs1, rhs);
                c.advance_decoded(flags);
            }
            Step::Li { rd, imm, flags } => {
                let c = &mut cores[ci];
                c.set_reg(rd, imm);
                c.advance_decoded(flags);
            }
            Step::Fp { op, mode, rd, rs1, rs2, flags } => {
                let c = &mut cores[ci];
                let _ = c.exec_fp(op, mode, rd, rs1, rs2);
                c.advance_decoded(flags);
            }
            Step::Branch { cond, rs1, rs2, target, flags } => {
                let c = &mut cores[ci];
                if c.branch_taken(cond, rs1, rs2) {
                    c.pc = target;
                } else {
                    c.advance_decoded(flags);
                }
            }
            Step::Jump { target } => cores[ci].pc = target,
            Step::HwLoop { count, start, end } => {
                let c = &mut cores[ci];
                let iters = c.reg(count);
                if iters == 0 {
                    c.pc = end;
                } else {
                    c.hwloops.push((start, end, iters));
                    c.pc = start;
                }
            }
            Step::End => {
                cores[ci].state = CoreState::Done;
                return Ok(());
            }
            Step::Load { rd, base, offset, post_inc, size, flags } => {
                let c = &mut cores[ci];
                let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                match mem.region_of(addr) {
                    Region::Dma => {
                        // Transfers complete at trigger time, so `STATUS`
                        // reads as drained — same as the functional tier.
                        let v = dmac.load(addr - DMA_BASE, u64::MAX);
                        c.set_reg(rd, v);
                    }
                    _ => c.exec_load(mem, rd, addr, size),
                }
                c.advance_decoded(flags);
            }
            Step::Store { rs, base, offset, post_inc, size, flags } => {
                let c = &mut cores[ci];
                let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                // Value read after the post-increment, like the engines.
                let v = c.reg(rs);
                match mem.region_of(addr) {
                    Region::Dma => dmac.store(mem, addr - DMA_BASE, v, 0),
                    _ => mem.store(addr, size, v),
                }
                c.advance_decoded(flags);
            }
            Step::Amo { op, rd, base, offset, rs, flags } => {
                let c = &mut cores[ci];
                let addr = (c.reg(base) as i64 + offset as i64) as u32;
                if !matches!(mem.region_of(addr), Region::Tcdm) {
                    return Err(RunError::Fault(format!("atomic outside TCDM at {addr:#x}")));
                }
                let v = c.reg(rs);
                let old = mem.amo(op, addr, v);
                c.set_reg(rd, old);
                c.advance_decoded(flags);
            }
            Step::WaitEvent { ev, flags } => {
                cores[ci].advance_decoded(flags);
                if !event.wait_event(ci, ev) {
                    cores[ci].state = CoreState::Sleeping { since: 0 };
                    return Ok(());
                }
            }
            Step::SetEvent { ev, flags } => {
                cores[ci].advance_decoded(flags);
                for w in event.set_event(ev) {
                    cores[w].state = CoreState::Running;
                }
            }
            Step::Barrier { flags } => {
                cores[ci].advance_decoded(flags);
                if event.arrive(ci, 0).is_some() {
                    // Wake every barrier sleeper; cores parked on a
                    // software event line stay asleep (only a SetEvent may
                    // release them) — same rule as every other tier.
                    for (w, c) in cores.iter_mut().enumerate().take(workers) {
                        if matches!(c.state, CoreState::Sleeping { .. })
                            && !event.is_event_waiting(w)
                        {
                            c.state = CoreState::Running;
                        }
                    }
                } else {
                    cores[ci].state = CoreState::Sleeping { since: 0 };
                    return Ok(());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::functional::FunctionalBackend;
    use crate::isa::ProgramBuilder;
    use crate::kernels::{Benchmark, Variant};

    /// The compiled tier reproduces the functional tier bit-for-bit on
    /// kernels: outputs, registers, TCDM image and retired counts.
    #[test]
    fn matches_functional_tier_on_kernels() {
        let cfg = ClusterConfig::new(8, 4, 1);
        for (b, v) in [
            (Benchmark::Fir, Variant::Scalar),
            (Benchmark::Matmul, Variant::VEC),
            (Benchmark::Dwt, Variant::SCALAR_F16),
        ] {
            let w = b.build(v, &cfg);
            for workers in [1usize, 3, 8] {
                let (fu, fu_out) = w.run_on_backend(&cfg, workers, &FunctionalBackend).unwrap();
                let (co, co_out) =
                    w.run_on_backend(&cfg, workers, &CompiledBackend::shared()).unwrap();
                let ctx = format!("{} {} with {workers} workers", b.name(), v.label());
                assert_eq!(fu_out, co_out, "{ctx}: outputs differ");
                assert_eq!(fu.regs, co.regs, "{ctx}: registers differ");
                assert_eq!(fu.mem.tcdm_words(), co.mem.tcdm_words(), "{ctx}: TCDM differs");
                assert_eq!(fu.instrs, co.instrs, "{ctx}: retired counts differ");
                assert!(co.stats.is_none(), "compiled tier is architectural-only");
            }
        }
    }

    /// Translation shape: straight-line register runs fuse into blocks at
    /// their heads, contention points and hw-loop back-edges do not.
    #[test]
    fn fused_blocks_cover_exactly_the_compilable_runs() {
        let mut b = ProgramBuilder::new("blocks");
        b.li(1, 7); // 0: fusable ┐
        b.addi(2, 1, 1); // 1: fusable ┘ block [0,2)
        b.lw(3, 1, 0); // 2: contention point
        b.li(4, 2); // 3: fusable, but the run below is length 1 + loop
        b.hwloop(4); // 4: control — never fused
        b.addi(5, 5, 1); // 5: fusable ┐
        b.addi(6, 6, 1); // 6: back-edge (LOOP_END_NEXT) — not fusable
        b.hwloop_end();
        b.barrier(); // 7
        b.end(); // 8
        let program = b.build();
        let decoded = DecodedProgram::decode(&program);
        let compiled = translate(&decoded);
        assert!(compiled.blocks[0].is_some(), "run head must carry a block");
        let blk = compiled.blocks[0].as_ref().unwrap();
        assert_eq!((blk.ops.len(), blk.next), (2, 2));
        for pc in 1..compiled.blocks.len() {
            assert!(compiled.blocks[pc].is_none(), "pc {pc} must not be a block head");
        }
        assert_eq!(compiled.steps.len(), decoded.insns.len());
    }

    /// A jump into the middle of a fused run executes correctly: mid-run
    /// pcs carry no block head, so the per-step path takes over there.
    #[test]
    fn branch_into_block_middle_is_correct() {
        let mut b = ProgramBuilder::new("midjump");
        b.li(9, 1); // 0 ┐
        b.addi(2, 2, 10); // 1 │ fused block [0,3)
        b.label("mid");
        b.addi(2, 2, 100); // 2 ┘ ← jump target (mid-run)
        b.beq(9, regs::ZERO, "done"); // 3: taken on the second pass
        b.li(9, 0); // 4
        b.j("mid"); // 5: backward jump into the run's middle
        b.label("done");
        b.end(); // 6
        let program = b.build();
        let compiled = translate(&DecodedProgram::decode(&program));
        let head = compiled.blocks[0].as_ref().expect("run head at pc 0");
        assert_eq!((head.ops.len(), head.next), (3, 3));
        assert!(compiled.blocks[2].is_none(), "mid-run pc must not be a block head");

        let cfg = ClusterConfig::new(8, 2, 0);
        let fu = FunctionalBackend.run_program(&cfg, &program, 1, &mut |_| {}).unwrap();
        let co = CompiledBackend::shared().run_program(&cfg, &program, 1, &mut |_| {}).unwrap();
        assert_eq!(fu.regs, co.regs);
        assert_eq!(fu.instrs, co.instrs);
        // 10 + 100 on the first pass, + 100 after the mid-entry jump.
        assert_eq!(co.regs[0][2], 210);
    }

    /// Watchdog parity (satellite): across budgets spanning the exact
    /// retired count, the compiled tier returns the identical
    /// `Ok`/`Timeout { budget }` outcome as the functional tier — the
    /// batched block charge never shifts the trip point.
    #[test]
    fn watchdog_timeout_parity_with_functional_tier() {
        let cfg = ClusterConfig::new(8, 4, 1);
        let w = Benchmark::Fir.build(Variant::Scalar, &cfg);
        let (instrs, _) = w.run_functional(&cfg, cfg.cores).unwrap();
        for budget in [1, 2, instrs - 1, instrs, instrs + 1] {
            let wd = Watchdog::with_budget(budget);
            let fu = FunctionalBackend.run_watched(&cfg, &w.program, cfg.cores, &mut |mem| {
                w.stage_into(mem)
            }, wd);
            let co = CompiledBackend::shared().run_watched(
                &cfg,
                &w.program,
                cfg.cores,
                &mut |mem| w.stage_into(mem),
                wd,
            );
            match (fu, co) {
                (Ok(f), Ok(c)) => {
                    assert!(budget >= instrs, "budget {budget} must not complete");
                    assert_eq!(f.instrs, c.instrs, "budget {budget}: retired counts differ");
                }
                (Err(fe), Err(ce)) => {
                    assert!(budget < instrs, "budget {budget} must complete");
                    assert_eq!(fe, RunError::Timeout { budget });
                    assert_eq!(ce, RunError::Timeout { budget });
                }
                (f, c) => panic!("budget {budget}: outcomes diverge: {f:?} vs {c:?}"),
            }
        }
    }

    /// Code-cache economics: the first translation is a miss, every rerun
    /// of the same program is a hit, and distinct programs get distinct
    /// entries. Misses count translations exactly.
    #[test]
    fn code_cache_translates_each_program_exactly_once() {
        let cache = Arc::new(CodeCache::new());
        let backend = CompiledBackend::with_cache(Arc::clone(&cache));
        let cfg = ClusterConfig::new(8, 2, 0);
        let w = Benchmark::Fir.build(Variant::Scalar, &cfg);
        assert!(cache.is_empty());
        for rep in 0..5 {
            w.run_on_backend(&cfg, cfg.cores, &backend).unwrap();
            let (hits, misses) = cache.stats();
            assert_eq!((hits, misses), (rep, 1), "rep {rep}");
        }
        let w2 = Benchmark::Matmul.build(Variant::VEC, &cfg);
        w2.run_on_backend(&cfg, cfg.cores, &backend).unwrap();
        assert_eq!(cache.stats(), (4, 2));
        assert_eq!(cache.len(), 2);
    }

    /// Concurrent workers racing on one program translate it exactly once
    /// (the shard lock is held across translation).
    #[test]
    fn concurrent_translation_is_exactly_once() {
        let cache = CodeCache::new();
        let cfg = ClusterConfig::new(8, 2, 0);
        let w = Benchmark::Conv.build(Variant::Scalar, &cfg);
        let decoded = DecodedProgram::decode(&w.program);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..4 {
                        cache.translate(&decoded);
                    }
                });
            }
        });
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 1, "one translation no matter the race");
        assert_eq!(hits, 31);
        assert_eq!(cache.len(), 1);
    }

    /// Error-class parity on the structured error paths: deadlock and
    /// fault classify identically to the functional tier.
    #[test]
    fn error_classification_matches_functional_tier() {
        let cfg = ClusterConfig::new(8, 8, 0);
        // Deadlock: workers park on a line nobody raises.
        let mut b = ProgramBuilder::new("dead-c");
        b.bne(regs::CORE_ID, regs::ZERO, "worker");
        b.end();
        b.label("worker");
        b.wait_event(9);
        b.end();
        let p = b.build();
        let fu = FunctionalBackend.run_program(&cfg, &p, 8, &mut |_| {}).unwrap_err();
        let co = CompiledBackend::shared().run_program(&cfg, &p, 8, &mut |_| {}).unwrap_err();
        assert_eq!(fu, RunError::Deadlock { asleep: 7 });
        assert_eq!(co, fu);

        // Fault: an atomic outside TCDM.
        let mut b = ProgramBuilder::new("fault-c");
        b.li(1, 0x1C00_0000); // L2 — not a legal atomic target
        b.li(2, 1);
        b.amo_add(3, 1, 0, 2);
        b.end();
        let p = b.build();
        let fu = FunctionalBackend.run_program(&cfg, &p, 1, &mut |_| {}).unwrap_err();
        let co = CompiledBackend::shared().run_program(&cfg, &p, 1, &mut |_| {}).unwrap_err();
        assert_eq!(fu.class(), "fault");
        assert_eq!(co, fu);
    }

    /// Trace formation shape: innermost hw-loop bodies and backward-branch
    /// loops trace at their heads; regions holding a nested loop setup or a
    /// contention point do not trace at all.
    #[test]
    fn loop_traces_cover_innermost_loops_only() {
        let mut b = ProgramBuilder::new("shape");
        b.li(1, 3); // 0
        b.li(2, 4); // 1
        b.hwloop(1); // 2: outer setup
        b.hwloop(2); // 3: inner setup — disqualifies the outer body
        b.addi(3, 3, 1); // 4: inner body → 1-op trace at pc 4
        b.hwloop_end();
        b.addi(4, 4, 1); // 5: outer tail
        b.hwloop_end();
        b.end(); // 6
        let compiled = translate(&DecodedProgram::decode(&b.build()));
        let inner = compiled.traces[4].as_ref().expect("inner body must trace");
        assert_eq!((inner.head, inner.ops.len()), (4, 1));
        assert!(compiled.traces[3].is_none(), "outer body holds a HwLoop — no trace");
        assert!(compiled.traces[5].is_none(), "outer tail is not a loop head");

        // A backward conditional branch forms a trace; an atomic in the
        // body disqualifies it.
        let mut b = ProgramBuilder::new("branchy");
        b.li(1, 10); // 0
        b.label("spin");
        b.addi(1, 1, -1); // 1: head
        b.bne(1, regs::ZERO, "spin"); // 2: back-edge
        b.li(2, 0x1000_0000); // 3
        b.label("amo");
        b.amo_add(3, 2, 0, 1); // 4: atomic — never traced
        b.bne(3, regs::ZERO, "amo"); // 5
        b.end(); // 6
        let compiled = translate(&DecodedProgram::decode(&b.build()));
        let spin = compiled.traces[1].as_ref().expect("branch loop must trace");
        assert_eq!((spin.head, spin.ops.len()), (1, 2));
        assert!(compiled.traces[4].is_none(), "atomic body must stay per-step");
    }

    /// Trip-count edge cases (satellite): zero, one, and a large count all
    /// reproduce the functional tier exactly — outputs, registers and
    /// retired counts — through the traced hw-loop path.
    #[test]
    fn traced_hw_loops_match_functional_at_trip_count_edges() {
        let counted = |n: u32| {
            let mut b = ProgramBuilder::new("count");
            b.li(1, n); // 0
            b.hwloop(1); // 1
            b.addi(2, 2, 1); // 2: body head (traced)
            b.addi(3, 3, 2); // 3: tail
            b.hwloop_end();
            b.addi(4, 4, 7); // 4: after the loop
            b.end(); // 5
            b.build()
        };
        let cfg = ClusterConfig::new(8, 2, 0);
        assert!(
            translate(&DecodedProgram::decode(&counted(2))).traces[2].is_some(),
            "the counted body must trace"
        );
        for n in [0u32, 1, 2, 65_535] {
            let p = counted(n);
            let fu = FunctionalBackend.run_program(&cfg, &p, 1, &mut |_| {}).unwrap();
            let co = CompiledBackend::shared().run_program(&cfg, &p, 1, &mut |_| {}).unwrap();
            assert_eq!(fu.regs, co.regs, "trip count {n}: registers differ");
            assert_eq!(fu.instrs, co.instrs, "trip count {n}: retired counts differ");
            assert_eq!(co.regs[0][2], n, "trip count {n}: body executions");
            assert_eq!(co.regs[0][4], 7, "trip count {n}: fall-through ran once");
        }
    }

    /// Nested hw loops: only the inner body traces, and the outer loop's
    /// bookkeeping (stack walk at the shared tail) stays exact.
    #[test]
    fn nested_hw_loops_match_functional_tier() {
        let prog = || {
            let mut b = ProgramBuilder::new("nest");
            b.li(1, 3);
            b.li(2, 4);
            b.hwloop(1);
            b.hwloop(2);
            b.addi(3, 3, 1); // inner body: runs 3 × 4 times
            b.hwloop_end();
            b.addi(4, 4, 1); // outer tail: runs 3 times
            b.hwloop_end();
            b.end();
            b.build()
        };
        let cfg = ClusterConfig::new(8, 2, 0);
        let fu = FunctionalBackend.run_program(&cfg, &prog(), 1, &mut |_| {}).unwrap();
        let co = CompiledBackend::shared().run_program(&cfg, &prog(), 1, &mut |_| {}).unwrap();
        assert_eq!(fu.regs, co.regs);
        assert_eq!(fu.instrs, co.instrs);
        assert_eq!(co.regs[0][3], 12, "inner body ran 3 × 4 times");
        assert_eq!(co.regs[0][4], 3, "outer tail ran 3 times");
    }

    /// A side-exit mid-iteration (satellite): a taken non-back-edge branch
    /// leaves the trace at its target with exactly the retired ops charged.
    #[test]
    fn trace_side_exit_matches_functional_tier() {
        let prog = || {
            let mut b = ProgramBuilder::new("exit");
            b.li(1, 0); // 0
            b.li(2, 57); // 1
            b.label("loop");
            b.addi(1, 1, 1); // 2: head
            b.beq(1, 2, "out"); // 3: side-exit when r1 == 57
            b.bne(1, regs::ZERO, "loop"); // 4: back-edge (always taken)
            b.label("out");
            b.addi(3, 3, 9); // 5
            b.end(); // 6
            b.build()
        };
        let compiled = translate(&DecodedProgram::decode(&prog()));
        let tr = compiled.traces[2].as_ref().expect("branch loop must trace");
        assert_eq!(tr.ops.len(), 3);
        let cfg = ClusterConfig::new(8, 2, 0);
        let fu = FunctionalBackend.run_program(&cfg, &prog(), 1, &mut |_| {}).unwrap();
        let co = CompiledBackend::shared().run_program(&cfg, &prog(), 1, &mut |_| {}).unwrap();
        assert_eq!(fu.regs, co.regs);
        assert_eq!(fu.instrs, co.instrs, "side-exit must charge the exact retired count");
        assert_eq!(co.regs[0][1], 57, "exited on the 57th iteration");
        assert_eq!(co.regs[0][3], 9, "landed at the side-exit target");
    }

    /// Capacity bound (satellite): a churn of distinct programs cannot grow
    /// the cache past its capacity; `len() == misses - evictions` holds and
    /// a re-translation after eviction is an honest new miss.
    #[test]
    fn code_cache_eviction_bounds_residency() {
        let tiny = |i: u32| {
            let mut b = ProgramBuilder::new("tiny");
            b.li(1, i);
            b.end();
            DecodedProgram::decode(&b.build())
        };
        let cache = CodeCache::with_capacity(16); // one entry per shard
        assert_eq!(cache.capacity(), 16);
        let progs: Vec<DecodedProgram> = (0..40).map(tiny).collect();
        for d in &progs {
            cache.translate(d);
        }
        let (hits, misses) = cache.stats();
        assert_eq!((hits, misses), (0, 40), "40 distinct programs, all cold");
        assert!(cache.len() <= cache.capacity(), "residency must stay bounded");
        assert_eq!(cache.len() as u64, misses - cache.evictions());
        assert!(cache.evictions() >= 40 - 16);

        // Translating the full set again stays bounded; every evicted
        // program re-translates as a new miss, never a stale hit.
        for d in &progs {
            cache.translate(d);
        }
        let (hits2, misses2) = cache.stats();
        assert_eq!(hits2 + misses2, 80, "every request is a hit or a miss");
        assert!(cache.len() <= cache.capacity());
        assert_eq!(cache.len() as u64, misses2 - cache.evictions());
    }

    /// LRU within a shard: a re-touched entry survives an insert that
    /// evicts its stalest neighbor.
    #[test]
    fn recently_used_translations_survive_eviction() {
        let tiny = |i: u32| {
            let mut b = ProgramBuilder::new("tiny");
            b.li(1, i);
            b.end();
            DecodedProgram::decode(&b.build())
        };
        // Find three distinct programs landing in one shard (pigeonhole
        // over 16 shards guarantees a trio well before i = 200).
        let mut by_shard: HashMap<usize, Vec<DecodedProgram>> = HashMap::new();
        let trio = (0..200)
            .map(tiny)
            .find_map(|d| {
                let bucket = by_shard.entry((d.fingerprint() as usize) & 15).or_default();
                bucket.push(d);
                (bucket.len() == 3).then(|| bucket.clone())
            })
            .expect("three programs must share a shard");
        let (a, b, c) = (&trio[0], &trio[1], &trio[2]);

        let cache = CodeCache::with_capacity(32); // two entries per shard
        cache.translate(a); // miss — shard {a}
        cache.translate(b); // miss — shard {a, b} (full)
        cache.translate(a); // hit — refreshes a; b is now stalest
        cache.translate(c); // miss — evicts b, not a
        assert_eq!(cache.evictions(), 1);
        cache.translate(a); // still resident
        assert_eq!(cache.stats(), (2, 3), "the re-touched entry survived");
        cache.translate(b); // evicted → honest re-translation
        assert_eq!(cache.stats(), (2, 4));
    }

    /// The event-handshake blocking semantics survive compilation: parked
    /// cores wake on the set, buffered events are consumed, and the run is
    /// deterministic.
    #[test]
    fn event_handshake_matches_functional_tier() {
        let prog = || {
            let mut b = ProgramBuilder::new("ev-c");
            b.beq(regs::CORE_ID, regs::ZERO, "master");
            b.wait_event(5);
            b.j("join");
            b.label("master");
            b.li(1, 100);
            b.hwloop(1);
            b.addi(2, 2, 1);
            b.hwloop_end();
            b.set_event(5);
            b.wait_event(5); // consumes the master's own buffered event
            b.label("join");
            b.barrier();
            b.end();
            b.build()
        };
        let cfg = ClusterConfig::new(8, 2, 1);
        let fu = FunctionalBackend.run_program(&cfg, &prog(), 8, &mut |_| {}).unwrap();
        let co = CompiledBackend::shared().run_program(&cfg, &prog(), 8, &mut |_| {}).unwrap();
        assert_eq!(fu.regs, co.regs);
        assert_eq!(fu.instrs, co.instrs);
        assert_eq!(co.regs[0][2], 100, "master ran its pre-signal work");
        assert_eq!(fu.mem.tcdm_words(), co.mem.tcdm_words());
    }
}
