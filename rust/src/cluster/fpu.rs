//! FPU subsystem: shared FPnew instances behind the partial interconnect
//! (§3.2) and the separately-shared iterative DIV-SQRT block.
//!
//! Each FPU instance accepts at most one operation per cycle (it is either
//! fully pipelined, or — with 0 stages — occupied for the single cycle of
//! the operation). Cores are statically mapped to instances with interleaved
//! allocation ([`crate::config::ClusterConfig::fpu_of_core`]); simultaneous
//! requests from cores sharing an instance are arbitrated fairly (the issue
//! loop rotates priority), the losers stalling with `fpu_cont`.
//!
//! The DIV-SQRT block is a single cluster-shared unit, iterative and *not*
//! pipelined: it is busy for the full latency of the running operation —
//! 11 / 7 / 6 cycles for float / float16 / bfloat16 (§3.2).

use crate::transfp::FpMode;

/// Shared FPU port state for one cluster.
#[derive(Debug, Clone)]
pub struct FpuSubsystem {
    /// Per-FPU: cycle of the last accepted op (one issue per cycle).
    port_busy_at: Vec<u64>,
    /// Cycle until which the DIV-SQRT block is busy (exclusive).
    divsqrt_busy_until: u64,
    /// Accepted operations per FPU (for utilization / power).
    pub ops_accepted: Vec<u64>,
    /// DIV-SQRT operations issued.
    pub divsqrt_ops: u64,
}

impl FpuSubsystem {
    /// Subsystem with `nfpus` instances.
    pub fn new(nfpus: usize) -> Self {
        FpuSubsystem {
            port_busy_at: vec![u64::MAX; nfpus],
            divsqrt_busy_until: 0,
            ops_accepted: vec![0; nfpus],
            divsqrt_ops: 0,
        }
    }

    /// Reset to power-on state, keeping allocations.
    pub fn reset(&mut self) {
        self.port_busy_at.fill(u64::MAX);
        self.divsqrt_busy_until = 0;
        self.ops_accepted.fill(0);
        self.divsqrt_ops = 0;
    }

    /// Try to issue a (non-divsqrt) op on FPU `fpu` at `cycle`.
    /// True = accepted; false = port already granted this cycle (contention).
    pub fn try_issue(&mut self, fpu: usize, cycle: u64) -> bool {
        if self.port_busy_at[fpu] == cycle {
            false
        } else {
            self.port_busy_at[fpu] = cycle;
            self.ops_accepted[fpu] += 1;
            true
        }
    }

    /// DIV-SQRT latency for a format (§3.2).
    pub fn divsqrt_latency(mode: FpMode) -> u64 {
        match mode {
            FpMode::F32 => 11,
            FpMode::F16 | FpMode::VecF16 => 7,
            FpMode::Bf16 | FpMode::VecBf16 => 6,
        }
    }

    /// Try to start a divide/sqrt at `cycle`. Returns `Ok(done_cycle)` when
    /// the unit is free (result available at `done_cycle`), or
    /// `Err(free_cycle)` when busy (caller retries then, counting
    /// `divsqrt_cont`).
    pub fn try_divsqrt(&mut self, mode: FpMode, cycle: u64) -> Result<u64, u64> {
        if cycle < self.divsqrt_busy_until {
            Err(self.divsqrt_busy_until)
        } else {
            let done = cycle + Self::divsqrt_latency(mode);
            self.divsqrt_busy_until = done;
            self.divsqrt_ops += 1;
            Ok(done)
        }
    }

    /// Mean ops per FPU (utilization input for the power model).
    pub fn total_ops(&self) -> u64 {
        self.ops_accepted.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_issue_per_cycle_per_fpu() {
        let mut f = FpuSubsystem::new(2);
        assert!(f.try_issue(0, 5));
        assert!(!f.try_issue(0, 5)); // contention
        assert!(f.try_issue(1, 5)); // other instance free
        assert!(f.try_issue(0, 6)); // next cycle ok (pipelined)
        assert_eq!(f.total_ops(), 3);
    }

    #[test]
    fn divsqrt_latencies_match_paper() {
        assert_eq!(FpuSubsystem::divsqrt_latency(FpMode::F32), 11);
        assert_eq!(FpuSubsystem::divsqrt_latency(FpMode::F16), 7);
        assert_eq!(FpuSubsystem::divsqrt_latency(FpMode::Bf16), 6);
    }

    #[test]
    fn divsqrt_not_pipelined() {
        let mut f = FpuSubsystem::new(1);
        let done = f.try_divsqrt(FpMode::F32, 10).unwrap();
        assert_eq!(done, 21);
        // Busy until 21: a second request at 15 must wait.
        assert_eq!(f.try_divsqrt(FpMode::F16, 15), Err(21));
        // At 21 the unit is free again.
        assert_eq!(f.try_divsqrt(FpMode::F16, 21), Ok(28));
        assert_eq!(f.divsqrt_ops, 2);
    }
}
