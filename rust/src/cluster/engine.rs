//! Event-driven issue engine: the production hot path.
//!
//! Replaces the reference engine's O(cores) per-cycle scan with
//!
//! 1. a **min-heap scheduler** keyed on each core's `next_issue`, so only
//!    cores that can actually issue at the current event time are touched
//!    (same-cycle peers are replayed in the rotated priority order that
//!    models the round-robin arbitration fairness);
//! 2. **batched straight-line execution** of predecoded instructions
//!    ([`crate::isa::decoded`]): once a core holds the issue slot, it keeps
//!    executing *local* instructions — ops that touch no order-sensitive
//!    shared resource (int ALU/Li, branches, hw-loop setup, lane permutes,
//!    `End`) — ahead of the global clock, absorbing scoreboard and I$
//!    bookkeeping into the run instead of paying a scheduler round trip per
//!    instruction. The batch stops at every contention point: TCDM bank
//!    claims, FPU port arbitration on *shared* FPUs, the DIV-SQRT block,
//!    barriers, and non-resident I$ lines. Those execute only at the global
//!    event time, in rotation order — keeping arbitration bit-exact.
//!
//! Two run-time refinements widen the local set soundly:
//! * **private FPUs** (`fpus == cores`): FPU-port claims cannot contend
//!   across cores, so FP datapath ops batch too;
//! * **solo mode** (exactly one runnable core at `run` start): nothing can
//!   contend at all, so memory, DIV-SQRT and barriers also batch — a whole
//!   single-worker run executes as one straight-line sweep.
//!
//! Cycle-exactness against the reference engine is enforced by the
//! differential tests (`tests/differential.rs` and the micro programs in
//! `super::tests`); the invariants the equivalence rests on are written up
//! in EXPERIMENTS.md §Perf.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::isa::decoded::{flag, DecodedInsn, OpClass};
use crate::isa::insn::Insn;

use super::backend::RunError;
use super::core::{Core, CoreState, Producer};
use super::counters::RunStats;
use super::event::WAKEUP_LATENCY;
use super::mem::Region;
use super::{Cluster, TAKEN_BRANCH_CYCLES};
use crate::trace::{StallCause, TraceKind};

/// Advance past an executed instruction: the predecoded `LOOP_END_NEXT`
/// flag proves whether the hw-loop stack can possibly act, so the common
/// case is a plain increment (shared with the functional interpreter via
/// [`Core::advance_decoded`]).
#[inline(always)]
fn advance(c: &mut Core, d: &DecodedInsn) {
    c.advance_decoded(d.flags);
}

impl Cluster {
    /// Run to completion on the event-driven engine. A program that
    /// outlives `self.max_cycles` is a [`RunError::Timeout`]; a cluster
    /// whose remaining cores are all asleep on a line that can never
    /// complete is a [`RunError::Deadlock`].
    pub fn run_event(&mut self) -> Result<RunStats, RunError> {
        let n = self.cores.len();
        let runnable =
            self.cores.iter().filter(|c| !matches!(c.state, CoreState::Done)).count();
        let solo = runnable == 1;
        let fp_private = self.cfg.fpus == self.cfg.cores;

        // One live heap entry per running core, keyed (next_issue, id).
        let mut heap: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::with_capacity(n + 1);
        for c in &self.cores {
            if matches!(c.state, CoreState::Running) {
                heap.push(Reverse((c.next_issue, c.id as u32)));
            }
        }
        let mut ready: Vec<u32> = Vec::with_capacity(n);
        let mut woken: Vec<usize> = Vec::with_capacity(n);

        while let Some(&Reverse((now, _))) = heap.peek() {
            if now >= self.max_cycles {
                return Err(RunError::Timeout { budget: self.max_cycles });
            }
            // Collect every core issuing at this event time.
            ready.clear();
            while let Some(&Reverse((t, ci))) = heap.peek() {
                if t != now {
                    break;
                }
                heap.pop();
                let c = &self.cores[ci as usize];
                if matches!(c.state, CoreState::Running) && c.next_issue == now {
                    ready.push(ci);
                }
            }
            if ready.is_empty() {
                continue;
            }
            self.now = now;
            if ready.len() > 1 {
                // Rotated priority order — the arbitration fairness model.
                let rot = (now as usize) % n;
                ready.sort_unstable_by_key(|&ci| {
                    let k = ci as usize;
                    if k >= rot {
                        k - rot
                    } else {
                        k + n - rot
                    }
                });
            }
            for idx in 0..ready.len() {
                let ci = ready[idx] as usize;
                if !matches!(self.cores[ci].state, CoreState::Running)
                    || self.cores[ci].next_issue != now
                {
                    continue;
                }
                self.issue_batch(ci, solo, fp_private, &mut woken)?;
                let c = &self.cores[ci];
                if matches!(c.state, CoreState::Running) && c.next_issue != u64::MAX {
                    heap.push(Reverse((c.next_issue, ci as u32)));
                }
                for w in woken.drain(..) {
                    heap.push(Reverse((self.cores[w].next_issue, w as u32)));
                }
            }
        }
        let asleep = self
            .cores
            .iter()
            .filter(|c| matches!(c.state, CoreState::Sleeping { .. }))
            .count();
        if asleep > 0 {
            return Err(RunError::Deadlock { asleep });
        }
        Ok(self.collect_stats())
    }

    /// Issue for core `ci` starting at `self.now`, batching as far down the
    /// straight-line run as locality allows. `woken` receives the ids of
    /// cores released by a completed barrier (to be rescheduled by the
    /// caller).
    fn issue_batch(
        &mut self,
        ci: usize,
        solo: bool,
        fp_private: bool,
        woken: &mut Vec<usize>,
    ) -> Result<(), RunError> {
        let now = self.now;
        let max_cycles = self.max_cycles;
        let perfect_icache = self.perfect_icache;
        let trace = self.trace_enabled();
        let pipe2 = self.cfg.pipe >= 2;
        let pipe = self.cfg.pipe as u64;
        let l2_lat = self.cfg.l2_latency();
        let fpu_idx = self.cfg.fpu_of_core(ci);
        // Batch cursor: the core's private clock, ≥ the global clock.
        let mut t = now;
        loop {
            if t >= max_cycles {
                return Err(RunError::Timeout { budget: max_cycles });
            }
            if let Some(f) = self.fault {
                if t >= f.cycle {
                    self.fault = None;
                    self.apply_fault(f.site);
                }
            }
            let pc = self.cores[ci].pc as usize;
            let d = self.decoded.insns[pc];
            // A non-zero straight-line fast-path entry is exactly the
            // "touches no order-sensitive shared resource" predicate (the
            // table is the LOCAL flag in run-length form — see
            // `DecodedProgram::local_run_len`, shared with the functional
            // interpreter).
            let local = self.decoded.local_run_len[pc] != 0
                || solo
                || (fp_private && matches!(d.class, OpClass::Fp));
            if !local && t > now {
                // Contention point reached mid-batch: surrender the slot and
                // re-arbitrate at the proper global cycle (traced on the
                // re-issue, so traces stay one line per attempt).
                self.cores[ci].next_issue = t;
                return Ok(());
            }
            if trace {
                eprintln!("t={t} core={ci} pc={pc} {:?}", d.insn);
            }

            // --- 1. Instruction fetch through the shared I$. Resident lines
            // are hits at any cursor; fills only ever start at the global
            // cycle (or any cycle in solo mode), where intra-cycle order
            // cannot matter.
            if !perfect_icache {
                let line_ready = self.icache.peek(pc as u32);
                if line_ready > t {
                    if t == now || solo {
                        let fetched = self.icache.fetch(pc as u32, t);
                        self.cores[ci].counters.icache_stall += fetched - t;
                        if self.tracer.is_some() {
                            self.trace_stall(ci, pc as u32, t, StallCause::Icache, fetched - t);
                        }
                        if local {
                            t = fetched;
                            continue; // same pc: guaranteed hit at `fetched`
                        }
                        self.cores[ci].next_issue = fetched;
                    } else {
                        self.cores[ci].next_issue = t;
                    }
                    return Ok(());
                }
            }

            // --- 2. Operand scoreboard.
            let (opr_ready, who) =
                self.cores[ci].scoreboard_ready(&d.reads[..d.nreads as usize]);
            if opr_ready > t {
                let wait = opr_ready - t;
                let cause = {
                    let c = &mut self.cores[ci];
                    match who {
                        Producer::Fpu | Producer::DivSqrt => {
                            c.counters.fpu_stall += wait;
                            Some(StallCause::FpuLatency)
                        }
                        Producer::Load => {
                            c.counters.load_stall += wait;
                            Some(StallCause::LoadUse)
                        }
                        Producer::None => None,
                    }
                };
                if let Some(cause) = cause {
                    if self.tracer.is_some() {
                        self.trace_stall(ci, pc as u32, t, cause, wait);
                    }
                }
                if local {
                    t = opr_ready; // the re-attempt folds into the batch
                } else {
                    self.cores[ci].next_issue = opr_ready;
                    return Ok(());
                }
            }

            // --- 3. Write-back port conflict (§5.3.3). Absorbing the stall
            // is exact: the reference re-attempt at t+1 cannot re-trigger
            // (the core issued no FP op at t).
            if pipe2
                && d.flags & flag::FP == 0
                && d.flags & flag::WRITES_REG != 0
                && self.cores[ci].last_fp_issue == t.wrapping_sub(1)
            {
                let c = &mut self.cores[ci];
                c.wb_skid += 1;
                if c.wb_skid >= 3 {
                    c.wb_skid = 0;
                    c.counters.wb_stall += 1;
                    if self.tracer.is_some() {
                        self.trace_stall(ci, pc as u32, t, StallCause::Writeback, 1);
                    }
                    t += 1;
                    if !local {
                        self.cores[ci].next_issue = t;
                        return Ok(());
                    }
                }
            }

            // --- 4. Class dispatch at cursor `t`.
            if self.tracer.is_some() {
                self.trace_issue(ci, pc as u32, t);
            }
            match d.class {
                OpClass::Alu => {
                    let Insn::Alu { op, rd, rs1, rhs } = d.insn else { unreachable!() };
                    let c = &mut self.cores[ci];
                    c.exec_alu(op, rd, rs1, rhs);
                    c.counters.active += d.latency;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    t += d.latency;
                    advance(c, &d);
                }
                OpClass::Li => {
                    let Insn::Li { rd, imm } = d.insn else { unreachable!() };
                    let c = &mut self.cores[ci];
                    c.set_reg(rd, imm);
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    t += 1;
                    advance(c, &d);
                }
                OpClass::FpAlu => {
                    let Insn::Fp { op, mode, rd, rs1, rs2 } = d.insn else { unreachable!() };
                    let c = &mut self.cores[ci];
                    c.exec_fp(op, mode, rd, rs1, rs2);
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    t += 1;
                    advance(c, &d);
                }
                OpClass::Branch => {
                    let Insn::Branch { cond, rs1, rs2, target } = d.insn else {
                        unreachable!()
                    };
                    let c = &mut self.cores[ci];
                    let taken = c.branch_taken(cond, rs1, rs2);
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    if taken {
                        c.pc = target;
                        c.counters.branch_stall += TAKEN_BRANCH_CYCLES - 1;
                        if self.tracer.is_some() {
                            self.trace_stall(
                                ci,
                                pc as u32,
                                t,
                                StallCause::Branch,
                                TAKEN_BRANCH_CYCLES - 1,
                            );
                        }
                        t += TAKEN_BRANCH_CYCLES;
                    } else {
                        t += 1;
                        advance(c, &d);
                    }
                }
                OpClass::Jump => {
                    let Insn::Jump { target } = d.insn else { unreachable!() };
                    let c = &mut self.cores[ci];
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    c.pc = target;
                    c.counters.branch_stall += TAKEN_BRANCH_CYCLES - 1;
                    if self.tracer.is_some() {
                        self.trace_stall(
                            ci,
                            pc as u32,
                            t,
                            StallCause::Branch,
                            TAKEN_BRANCH_CYCLES - 1,
                        );
                    }
                    t += TAKEN_BRANCH_CYCLES;
                }
                OpClass::HwLoop => {
                    let Insn::HwLoop { count, start, end } = d.insn else { unreachable!() };
                    let c = &mut self.cores[ci];
                    let iters = c.reg(count);
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.int_instrs += 1;
                    t += 1;
                    if iters == 0 {
                        c.pc = end;
                    } else {
                        c.hwloops.push((start, end, iters));
                        c.pc = start;
                    }
                }
                OpClass::End => {
                    // `End` retires in zero cycles and deliberately does NOT
                    // count an active cycle, so `active + stalls == cycles`
                    // holds exactly per core (the trace layer reconciles on
                    // this invariant).
                    {
                        let c = &mut self.cores[ci];
                        c.counters.instrs += 1;
                        c.counters.cycles = t;
                        c.state = CoreState::Done;
                    }
                    if self.tracer.is_some() {
                        self.trace_end(ci, t);
                    }
                    return Ok(());
                }
                OpClass::Load => {
                    let Insn::Load { rd, base, offset, post_inc, size } = d.insn else {
                        unreachable!()
                    };
                    let addr = (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                    match self.mem.region_of(addr) {
                        Region::Dma => {
                            let addr =
                                self.cores[ci].mem_addr_and_postinc(base, offset, post_inc);
                            self.exec_dma_load(ci, addr, rd, t);
                            let c = &mut self.cores[ci];
                            t += 1;
                            advance(c, &d);
                        }
                        Region::Tcdm => {
                            let bank = self.mem.bank_of(addr);
                            if !self.mem.claim_bank(bank, t) {
                                let c = &mut self.cores[ci];
                                c.counters.tcdm_cont += 1;
                                c.next_issue = t + 1;
                                if self.tracer.is_some() {
                                    self.trace_stall(
                                        ci,
                                        pc as u32,
                                        t,
                                        StallCause::TcdmContention,
                                        1,
                                    );
                                }
                                return Ok(());
                            }
                            let c = &mut self.cores[ci];
                            let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                            c.exec_load(&self.mem, rd, addr, size);
                            c.reg_ready[rd as usize] = t + 2; // 1 load-use bubble
                            c.reg_producer[rd as usize] = Producer::Load;
                            c.counters.active += 1;
                            c.counters.instrs += 1;
                            c.counters.mem_instrs += 1;
                            t += 1;
                            advance(c, &d);
                        }
                        Region::L2 => {
                            let c = &mut self.cores[ci];
                            let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                            c.exec_load(&self.mem, rd, addr, size);
                            c.counters.active += 1;
                            c.counters.l2_stall += l2_lat - 1;
                            c.counters.instrs += 1;
                            c.counters.mem_instrs += 1;
                            t += l2_lat; // core blocks on the demux
                            advance(c, &d);
                            if self.tracer.is_some() {
                                self.trace_stall(
                                    ci,
                                    pc as u32,
                                    t - l2_lat,
                                    StallCause::L2,
                                    l2_lat - 1,
                                );
                            }
                        }
                    }
                }
                OpClass::Store => {
                    let Insn::Store { rs, base, offset, post_inc, size } = d.insn else {
                        unreachable!()
                    };
                    let addr = (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                    match self.mem.region_of(addr) {
                        Region::Dma => {
                            let addr =
                                self.cores[ci].mem_addr_and_postinc(base, offset, post_inc);
                            self.exec_dma_store(ci, addr, rs, t);
                            let c = &mut self.cores[ci];
                            t += 1;
                            advance(c, &d);
                        }
                        Region::Tcdm => {
                            let bank = self.mem.bank_of(addr);
                            if !self.mem.claim_bank(bank, t) {
                                let c = &mut self.cores[ci];
                                c.counters.tcdm_cont += 1;
                                c.next_issue = t + 1;
                                if self.tracer.is_some() {
                                    self.trace_stall(
                                        ci,
                                        pc as u32,
                                        t,
                                        StallCause::TcdmContention,
                                        1,
                                    );
                                }
                                return Ok(());
                            }
                            let c = &mut self.cores[ci];
                            let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                            let v = c.reg(rs);
                            self.mem.store(addr, size, v);
                            let c = &mut self.cores[ci];
                            c.counters.active += 1;
                            c.counters.instrs += 1;
                            c.counters.mem_instrs += 1;
                            t += 1;
                            advance(c, &d);
                        }
                        Region::L2 => {
                            let c = &mut self.cores[ci];
                            let addr = c.mem_addr_and_postinc(base, offset, post_inc);
                            let v = c.reg(rs);
                            self.mem.store(addr, size, v);
                            let c = &mut self.cores[ci];
                            c.counters.active += 1;
                            c.counters.l2_stall += l2_lat - 1;
                            c.counters.instrs += 1;
                            c.counters.mem_instrs += 1;
                            t += l2_lat;
                            advance(c, &d);
                            if self.tracer.is_some() {
                                self.trace_stall(
                                    ci,
                                    pc as u32,
                                    t - l2_lat,
                                    StallCause::L2,
                                    l2_lat - 1,
                                );
                            }
                        }
                    }
                }
                OpClass::Fp => {
                    let Insn::Fp { op, mode, rd, rs1, rs2 } = d.insn else { unreachable!() };
                    if !self.fpus.try_issue(fpu_idx, t) {
                        if t > now {
                            // Defensive: a batched (private-FPU) claim can
                            // never lose; re-arbitrate via the scheduler.
                            self.cores[ci].next_issue = t;
                            return Ok(());
                        }
                        let c = &mut self.cores[ci];
                        c.counters.fpu_cont += 1;
                        c.next_issue = t + 1;
                        if self.tracer.is_some() {
                            self.trace_stall(ci, pc as u32, t, StallCause::FpuContention, 1);
                        }
                        return Ok(());
                    }
                    let c = &mut self.cores[ci];
                    let flops = c.exec_fp(op, mode, rd, rs1, rs2);
                    c.reg_ready[rd as usize] = t + 1 + pipe;
                    c.reg_producer[rd as usize] = Producer::Fpu;
                    c.last_fp_issue = t;
                    c.counters.active += 1;
                    c.counters.instrs += 1;
                    c.counters.fp_instrs += 1;
                    if d.flags & flag::VEC != 0 {
                        c.counters.fp_vec_instrs += 1;
                    }
                    c.counters.flops += flops;
                    t += 1;
                    advance(c, &d);
                }
                OpClass::FpDivSqrt => {
                    let Insn::Fp { op, mode, rd, rs1, rs2 } = d.insn else { unreachable!() };
                    match self.fpus.try_divsqrt(mode, t) {
                        Err(free) => {
                            self.cores[ci].counters.divsqrt_cont += free - t;
                            if self.tracer.is_some() {
                                self.trace_stall(
                                    ci,
                                    pc as u32,
                                    t,
                                    StallCause::DivSqrtContention,
                                    free - t,
                                );
                            }
                            if solo {
                                t = free; // only contender: retry in-batch
                                continue;
                            }
                            self.cores[ci].next_issue = free;
                            return Ok(());
                        }
                        Ok(done) => {
                            let c = &mut self.cores[ci];
                            let flops = c.exec_fp(op, mode, rd, rs1, rs2);
                            c.reg_ready[rd as usize] = done;
                            c.reg_producer[rd as usize] = Producer::DivSqrt;
                            c.counters.active += 1;
                            c.counters.instrs += 1;
                            c.counters.fp_instrs += 1;
                            c.counters.flops += flops;
                            t += 1;
                            advance(c, &d);
                        }
                    }
                }
                OpClass::Amo => {
                    let Insn::Amo { op, rd, base, offset, rs } = d.insn else { unreachable!() };
                    let addr = (self.cores[ci].reg(base) as i64 + offset as i64) as u32;
                    if !matches!(self.mem.region_of(addr), Region::Tcdm) {
                        return Err(RunError::Fault(format!("atomic outside TCDM at {addr:#x}")));
                    }
                    let bank = self.mem.bank_of(addr);
                    if !self.mem.claim_bank(bank, t) {
                        let c = &mut self.cores[ci];
                        c.counters.tcdm_cont += 1;
                        c.next_issue = t + 1;
                        if self.tracer.is_some() {
                            self.trace_stall(ci, pc as u32, t, StallCause::TcdmContention, 1);
                        }
                        return Ok(());
                    }
                    self.exec_amo(ci, op, rd, addr, rs, t);
                    let c = &mut self.cores[ci];
                    t += 1;
                    advance(c, &d);
                }
                OpClass::WaitEvent => {
                    let Insn::WaitEvent { ev } = d.insn else { unreachable!() };
                    {
                        let c = &mut self.cores[ci];
                        c.counters.active += 1;
                        c.counters.instrs += 1;
                        c.counters.int_instrs += 1;
                        advance(c, &d);
                    }
                    if self.event.wait_event(ci, ev) {
                        t += 1; // buffered event: consumed without sleeping
                    } else {
                        let c = &mut self.cores[ci];
                        c.state = CoreState::Sleeping { since: t + 1 };
                        c.next_issue = u64::MAX; // woken by a SetEvent
                        return Ok(());
                    }
                }
                OpClass::SetEvent => {
                    let Insn::SetEvent { ev } = d.insn else { unreachable!() };
                    {
                        let c = &mut self.cores[ci];
                        c.counters.active += 1;
                        c.counters.instrs += 1;
                        c.counters.int_instrs += 1;
                        advance(c, &d);
                    }
                    let wake = t + WAKEUP_LATENCY;
                    for w in self.event.set_event(ev) {
                        let c = &mut self.cores[w];
                        if let CoreState::Sleeping { since } = c.state {
                            c.counters.barrier_idle += wake - since;
                            c.state = CoreState::Running;
                            c.next_issue = wake;
                            if let Some(tr) = self.tracer.as_deref_mut() {
                                tr.on_wake(w, c.pc, TraceKind::EventWait, since, wake);
                            }
                            woken.push(w);
                        }
                    }
                    if solo {
                        t += 1; // no sleepers to hand to the scheduler
                        continue;
                    }
                    self.cores[ci].next_issue = t + 1;
                    return Ok(()); // reschedule so woken cores enter the heap
                }
                OpClass::Barrier => {
                    // Count the barrier instruction itself.
                    {
                        let c = &mut self.cores[ci];
                        c.counters.active += 1;
                        c.counters.instrs += 1;
                        c.counters.int_instrs += 1;
                        advance(c, &d);
                    }
                    match self.event.arrive(ci, t) {
                        Some(wake) => {
                            // Wake everyone (including self) — except cores
                            // parked on a software event line, which only a
                            // SetEvent may release.
                            let event = &self.event;
                            for c in self.cores.iter_mut() {
                                match c.state {
                                    CoreState::Sleeping { since }
                                        if !event.is_event_waiting(c.id) =>
                                    {
                                        c.counters.barrier_idle += wake - since;
                                        c.state = CoreState::Running;
                                        c.next_issue = wake;
                                        if let Some(tr) = self.tracer.as_deref_mut() {
                                            tr.on_wake(
                                                c.id,
                                                c.pc,
                                                TraceKind::Barrier,
                                                since,
                                                wake,
                                            );
                                        }
                                        woken.push(c.id);
                                    }
                                    CoreState::Running if c.id == ci => {
                                        c.counters.barrier_idle += wake - (t + 1);
                                        c.next_issue = wake;
                                        if let Some(tr) = self.tracer.as_deref_mut() {
                                            tr.on_wake(
                                                c.id,
                                                c.pc,
                                                TraceKind::Barrier,
                                                t + 1,
                                                wake,
                                            );
                                        }
                                    }
                                    _ => {}
                                }
                            }
                            if solo {
                                t = wake; // nobody to re-arbitrate against
                                continue;
                            }
                            return Ok(());
                        }
                        None => {
                            let c = &mut self.cores[ci];
                            c.state = CoreState::Sleeping { since: t + 1 };
                            c.next_issue = u64::MAX; // woken explicitly
                            return Ok(());
                        }
                    }
                }
            }
            // Local instruction executed — continue the straight-line batch.
        }
    }
}
