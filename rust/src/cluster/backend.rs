//! Tiered execution backends.
//!
//! The paper's transprecision flow separates *what a kernel computes*
//! (format/vector choice, §4–§5) from *what it costs* (cycles/energy,
//! §6–§7). [`ExecBackend`] is that split as an interface: a backend runs a
//! program on a [`ClusterConfig`] at a given occupancy and returns the
//! **architectural** result — final register files, the memory image, the
//! retired-instruction count — plus cycle-accurate [`RunStats`] when the
//! backend models time at all. Three tiers implement it:
//!
//! | backend | timing | use |
//! |---|---|---|
//! | [`EventBackend`] | cycle-accurate (event engine) | measurements (default) |
//! | [`ReferenceBackend`] | cycle-accurate (per-cycle spec) | differential wall |
//! | [`crate::cluster::FunctionalBackend`] | none | accuracy probes, goldens |
//!
//! All three execute the same predecoded stream with the same functional
//! semantics (`Core::exec_*`, `Memory::amo`, the event unit, the DMA
//! front-end), so their architectural results agree — enforced by the
//! three-way wall in `tests/differential.rs`. What the tier changes is the
//! *price*: the functional backend interprets in program order with no
//! event queue or hazard bookkeeping, targeting well over an order of
//! magnitude more instruction throughput than the event engine
//! (`benches/backend.rs` gates ≥ 50×), which is what lets the tuner probe
//! every ladder rung's accuracy before paying for timing.

use super::counters::RunStats;
use super::functional::FunctionalBackend;
use super::mem::Memory;
use super::{Cluster, Engine};
use crate::config::ClusterConfig;
use crate::isa::Program;

/// Architectural result of one backend run.
pub struct BackendRun {
    /// Final register file of every core (including inactive cores, which
    /// keep their reset state — identical across backends by construction).
    pub regs: Vec<[u32; 32]>,
    /// Memory after the run (read result windows from here).
    pub mem: Memory,
    /// Cycle-accurate statistics; `None` for architectural-only backends.
    pub stats: Option<RunStats>,
    /// Total instructions retired across all cores (throughput accounting;
    /// identical across backends for programs free of timing-dependent spin
    /// loops).
    pub instrs: u64,
}

/// A tier that can execute a program on a cluster configuration.
pub trait ExecBackend: Sync {
    /// Stable name (CLI `--backend` values, bench/report labels).
    fn name(&self) -> &'static str;

    /// True if [`ExecBackend::run_program`] returns `Some` stats.
    fn is_cycle_accurate(&self) -> bool;

    /// Execute `program` on a fresh cluster of `cfg` with the first
    /// `workers` cores active. `stage` is called once to write input data
    /// into the zeroed memory before execution starts.
    fn run_program(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> BackendRun;
}

/// Shared cycle-accurate implementation behind [`EventBackend`] and
/// [`ReferenceBackend`]: build a cluster, stage, run on the chosen issue
/// engine, and move the architectural state out.
fn run_cluster(
    cfg: &ClusterConfig,
    program: &Program,
    workers: usize,
    stage: &mut dyn FnMut(&mut Memory),
    engine: Engine,
) -> BackendRun {
    let mut cl = Cluster::new(*cfg, program.clone());
    cl.limit_active_cores(workers);
    stage(&mut cl.mem);
    let stats = cl.run_with(engine);
    let instrs = stats.per_core.iter().map(|c| c.instrs).sum();
    let Cluster { cores, mem, .. } = cl;
    BackendRun {
        regs: cores.iter().map(|c| c.regs).collect(),
        mem,
        stats: Some(stats),
        instrs,
    }
}

/// The event-driven cycle-accurate engine (the measurement default).
pub struct EventBackend;

impl ExecBackend for EventBackend {
    fn name(&self) -> &'static str {
        "event"
    }

    fn is_cycle_accurate(&self) -> bool {
        true
    }

    fn run_program(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> BackendRun {
        run_cluster(cfg, program, workers, stage, Engine::Event)
    }
}

/// The per-cycle reference engine (the executable timing specification).
pub struct ReferenceBackend;

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn is_cycle_accurate(&self) -> bool {
        true
    }

    fn run_program(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> BackendRun {
        run_cluster(cfg, program, workers, stage, Engine::Reference)
    }
}

/// Backend selector (CLI `--backend`, bench loops, tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Event,
    Reference,
    Functional,
}

impl BackendKind {
    /// Every tier, cycle-accurate first.
    pub fn all() -> [BackendKind; 3] {
        [BackendKind::Event, BackendKind::Reference, BackendKind::Functional]
    }

    /// The backend instance for this selector.
    pub fn get(self) -> &'static dyn ExecBackend {
        match self {
            BackendKind::Event => &EventBackend,
            BackendKind::Reference => &ReferenceBackend,
            BackendKind::Functional => &FunctionalBackend,
        }
    }

    /// Stable name (matches [`ExecBackend::name`]).
    pub fn name(self) -> &'static str {
        self.get().name()
    }

    /// Forwarder to [`ExecBackend::run_program`] (saves callers importing
    /// the trait).
    pub fn run_program(
        self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> BackendRun {
        self.get().run_program(cfg, program, workers, stage)
    }

    /// Parse a CLI `--backend` value.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "event" => Some(BackendKind::Event),
            "reference" | "ref" => Some(BackendKind::Reference),
            "functional" | "func" => Some(BackendKind::Functional),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{regs, ProgramBuilder};

    #[test]
    fn kinds_roundtrip_and_resolve() {
        for k in BackendKind::all() {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
            assert_eq!(k.get().name(), k.name());
        }
        assert_eq!(BackendKind::parse("ref"), Some(BackendKind::Reference));
        assert_eq!(BackendKind::parse("func"), Some(BackendKind::Functional));
        assert_eq!(BackendKind::parse("turbo"), None);
        assert!(BackendKind::Event.get().is_cycle_accurate());
        assert!(BackendKind::Reference.get().is_cycle_accurate());
        assert!(!BackendKind::Functional.get().is_cycle_accurate());
    }

    /// All three tiers agree architecturally on a staged micro program, and
    /// only the cycle-accurate tiers report stats.
    #[test]
    fn three_tiers_agree_on_a_micro_program() {
        use crate::cluster::mem::TCDM_BASE;
        let mut b = ProgramBuilder::new("tiers");
        b.li(1, TCDM_BASE);
        b.slli(2, regs::CORE_ID, 2);
        b.add(1, 1, 2);
        b.lw(3, 1, 0); // staged per-core word
        b.addi(3, 3, 1);
        b.sw(3, 1, 32); // publish to a second window
        b.barrier();
        b.end();
        let program = b.build();
        let cfg = ClusterConfig::new(8, 4, 1);
        let staged: Vec<u32> = (0..8u32).map(|i| 100 + i).collect();
        let run = |k: BackendKind| {
            k.get().run_program(&cfg, &program, cfg.cores, &mut |mem| {
                mem.write_u32_slice(TCDM_BASE, &staged);
            })
        };
        let ev = run(BackendKind::Event);
        let rf = run(BackendKind::Reference);
        let fu = run(BackendKind::Functional);
        assert!(ev.stats.is_some() && rf.stats.is_some() && fu.stats.is_none());
        assert_eq!(ev.regs, rf.regs);
        assert_eq!(ev.regs, fu.regs);
        assert_eq!(ev.mem.tcdm_words(), rf.mem.tcdm_words());
        assert_eq!(ev.mem.tcdm_words(), fu.mem.tcdm_words());
        assert_eq!(ev.instrs, fu.instrs);
        for i in 0..8u32 {
            assert_eq!(
                fu.mem.load(TCDM_BASE + 32 + 4 * i, crate::isa::MemSize::Word),
                101 + i
            );
        }
    }
}
