//! Tiered execution backends.
//!
//! The paper's transprecision flow separates *what a kernel computes*
//! (format/vector choice, §4–§5) from *what it costs* (cycles/energy,
//! §6–§7). [`ExecBackend`] is that split as an interface: a backend runs a
//! program on a [`ClusterConfig`] at a given occupancy and returns the
//! **architectural** result — final register files, the memory image, the
//! retired-instruction count — plus cycle-accurate [`RunStats`] when the
//! backend models time at all. Four tiers implement it:
//!
//! | backend | timing | use |
//! |---|---|---|
//! | [`EventBackend`] | cycle-accurate (event engine) | measurements (default) |
//! | [`ReferenceBackend`] | cycle-accurate (per-cycle spec) | differential wall |
//! | [`crate::cluster::FunctionalBackend`] | none | accuracy probes, goldens |
//! | [`crate::cluster::CompiledBackend`] | none | fast probes, large sweeps |
//!
//! All four execute the same functional semantics (`Core::exec_*`,
//! `Memory::amo`, the event unit, the DMA front-end), so their
//! architectural results agree — enforced by the four-way wall in
//! `tests/differential.rs`. What the tier changes is the *price*: the
//! functional backend interprets the predecoded stream in program order
//! with no event queue or hazard bookkeeping (`benches/backend.rs` gates
//! ≥ 50× the event engine's instruction throughput), and the compiled
//! backend translates the program once into pre-resolved dispatch steps,
//! fused straight-line blocks, and loop traces that retire whole
//! innermost-loop iterations per dispatch, cached by content fingerprint
//! in a capacity-bounded code cache (gated ≥ 10× the functional tier on
//! the loop-dominated kernels, ≥ 5× on the straight-line remainder) —
//! which is what lets the tuner probe every ladder rung's accuracy
//! before paying for timing.
//!
//! Since the robustness PR every tier returns `Result<BackendRun,
//! RunError>` instead of panicking: a hung program trips the [`Watchdog`]
//! (cycle budget on the timed engines, instruction budget on the
//! functional interpreter) as [`RunError::Timeout`], a cluster whose
//! remaining cores are all asleep on a barrier or event line that can
//! never complete is [`RunError::Deadlock`], and detectable architectural
//! violations (e.g. an atomic outside TCDM) are [`RunError::Fault`]. The
//! error-path **classification is tier-identical** — asserted by the
//! error-parity wall in `tests/differential.rs` — so the coordinator and
//! the fault-injection campaigns in [`crate::faults`] can treat the error
//! class as a property of the program, not of the backend that ran it.

use std::fmt;

use super::compiled::CompiledBackend;
use super::counters::RunStats;
use super::functional::FunctionalBackend;
use super::mem::Memory;
use super::{Cluster, Engine};
use crate::config::ClusterConfig;
use crate::isa::Program;

/// Structured execution error: why a run did not complete.
///
/// The three classes mirror the fault-injection outcome taxonomy
/// (EXPERIMENTS.md §Faults): `Timeout` and `Deadlock` both classify as a
/// *hang* (the watchdog turned it into an error instead of a stuck
/// process), `Fault` classifies as a *crash*. [`RunError::class`] is the
/// stable cross-tier label the differential wall compares.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunError {
    /// Every remaining core is asleep at a barrier or software event line
    /// that can never complete. `asleep` is how many cores were parked.
    Deadlock { asleep: usize },
    /// The watchdog budget ran out before the program terminated: the cycle
    /// budget on the timed engines, the instruction budget on the
    /// functional tier. The budget that tripped is carried for the report.
    Timeout { budget: u64 },
    /// A detectable architectural violation (e.g. an atomic outside TCDM).
    /// The payload is a human-readable description; worker panics caught by
    /// the coordinator are also quarantined into this class.
    Fault(String),
}

impl RunError {
    /// Stable classification label, identical across tiers for the same
    /// program (the error-parity differential wall asserts this). Note the
    /// watchdog *budgets* differ across tiers (cycles vs instructions), so
    /// parity is asserted on the class, not the payload.
    pub fn class(&self) -> &'static str {
        match self {
            RunError::Deadlock { .. } => "deadlock",
            RunError::Timeout { .. } => "timeout",
            RunError::Fault(_) => "fault",
        }
    }
}

impl fmt::Display for RunError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RunError::Deadlock { asleep } => write!(
                f,
                "deadlock: {asleep} core(s) asleep at a barrier or event line that can never \
                 complete"
            ),
            RunError::Timeout { budget } => {
                write!(f, "timeout: watchdog budget of {budget} exhausted before termination")
            }
            RunError::Fault(msg) => write!(f, "fault: {msg}"),
        }
    }
}

impl std::error::Error for RunError {}

/// Configurable hang watchdog: the timed engines charge against
/// `max_cycles`, the functional interpreter against `max_instrs`. The
/// defaults match the pre-robustness guard values, so fault-free runs are
/// bit-identical to the old behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Watchdog {
    /// Cycle budget for the event/reference engines.
    pub max_cycles: u64,
    /// Retired-instruction budget (across all cores) for the functional
    /// interpreter.
    pub max_instrs: u64,
}

impl Default for Watchdog {
    fn default() -> Watchdog {
        Watchdog { max_cycles: 2_000_000_000, max_instrs: 2_000_000_000 }
    }
}

impl Watchdog {
    /// A watchdog with both budgets set to `budget` (CLI `--budget`-style
    /// single-knob callers).
    pub fn with_budget(budget: u64) -> Watchdog {
        Watchdog { max_cycles: budget, max_instrs: budget }
    }
}

/// Architectural result of one backend run.
pub struct BackendRun {
    /// Final register file of every core (including inactive cores, which
    /// keep their reset state — identical across backends by construction).
    pub regs: Vec<[u32; 32]>,
    /// Memory after the run (read result windows from here).
    pub mem: Memory,
    /// Cycle-accurate statistics; `None` for architectural-only backends.
    pub stats: Option<RunStats>,
    /// Total instructions retired across all cores (throughput accounting;
    /// identical across backends for programs free of timing-dependent spin
    /// loops).
    pub instrs: u64,
}

/// A tier that can execute a program on a cluster configuration.
pub trait ExecBackend: Sync {
    /// Stable name (CLI `--backend` values, bench/report labels).
    fn name(&self) -> &'static str;

    /// True if [`ExecBackend::run_program`] returns `Some` stats.
    fn is_cycle_accurate(&self) -> bool;

    /// Execute `program` on a fresh cluster of `cfg` with the first
    /// `workers` cores active, under an explicit hang watchdog. `stage` is
    /// called once to write input data into the zeroed memory before
    /// execution starts. Never panics on hangs or deadlocks — they come
    /// back as structured [`RunError`]s.
    fn run_watched(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
        wd: Watchdog,
    ) -> Result<BackendRun, RunError>;

    /// [`ExecBackend::run_watched`] under the default watchdog.
    fn run_program(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> Result<BackendRun, RunError> {
        self.run_watched(cfg, program, workers, stage, Watchdog::default())
    }
}

/// Shared cycle-accurate implementation behind [`EventBackend`] and
/// [`ReferenceBackend`]: build a cluster, stage, run on the chosen issue
/// engine, and move the architectural state out.
fn run_cluster(
    cfg: &ClusterConfig,
    program: &Program,
    workers: usize,
    stage: &mut dyn FnMut(&mut Memory),
    engine: Engine,
    wd: Watchdog,
) -> Result<BackendRun, RunError> {
    let mut cl = Cluster::new(*cfg, program.clone());
    cl.max_cycles = wd.max_cycles;
    cl.limit_active_cores(workers);
    stage(&mut cl.mem);
    let stats = cl.run_with(engine)?;
    let instrs = stats.per_core.iter().map(|c| c.instrs).sum();
    let Cluster { cores, mem, .. } = cl;
    Ok(BackendRun {
        regs: cores.iter().map(|c| c.regs).collect(),
        mem,
        stats: Some(stats),
        instrs,
    })
}

/// The event-driven cycle-accurate engine (the measurement default).
pub struct EventBackend;

impl ExecBackend for EventBackend {
    fn name(&self) -> &'static str {
        "event"
    }

    fn is_cycle_accurate(&self) -> bool {
        true
    }

    fn run_watched(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
        wd: Watchdog,
    ) -> Result<BackendRun, RunError> {
        run_cluster(cfg, program, workers, stage, Engine::Event, wd)
    }
}

/// The per-cycle reference engine (the executable timing specification).
pub struct ReferenceBackend;

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn is_cycle_accurate(&self) -> bool {
        true
    }

    fn run_watched(
        &self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
        wd: Watchdog,
    ) -> Result<BackendRun, RunError> {
        run_cluster(cfg, program, workers, stage, Engine::Reference, wd)
    }
}

/// Backend selector (CLI `--backend`, bench loops, tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Event,
    Reference,
    Functional,
    Compiled,
}

impl BackendKind {
    /// Every tier, cycle-accurate first.
    pub fn all() -> [BackendKind; 4] {
        [
            BackendKind::Event,
            BackendKind::Reference,
            BackendKind::Functional,
            BackendKind::Compiled,
        ]
    }

    /// The backend instance for this selector.
    pub fn get(self) -> &'static dyn ExecBackend {
        match self {
            BackendKind::Event => &EventBackend,
            BackendKind::Reference => &ReferenceBackend,
            BackendKind::Functional => &FunctionalBackend,
            BackendKind::Compiled => {
                // Translations go through the process-wide code cache.
                static COMPILED: CompiledBackend = CompiledBackend::shared();
                &COMPILED
            }
        }
    }

    /// Stable name (matches [`ExecBackend::name`]).
    pub fn name(self) -> &'static str {
        self.get().name()
    }

    /// Forwarder to [`ExecBackend::run_program`] (saves callers importing
    /// the trait).
    pub fn run_program(
        self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
    ) -> Result<BackendRun, RunError> {
        self.get().run_program(cfg, program, workers, stage)
    }

    /// Forwarder to [`ExecBackend::run_watched`].
    pub fn run_watched(
        self,
        cfg: &ClusterConfig,
        program: &Program,
        workers: usize,
        stage: &mut dyn FnMut(&mut Memory),
        wd: Watchdog,
    ) -> Result<BackendRun, RunError> {
        self.get().run_watched(cfg, program, workers, stage, wd)
    }

    /// Parse a CLI `--backend` value.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "event" => Some(BackendKind::Event),
            "reference" | "ref" => Some(BackendKind::Reference),
            "functional" | "func" => Some(BackendKind::Functional),
            "compiled" | "comp" => Some(BackendKind::Compiled),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{regs, ProgramBuilder};

    #[test]
    fn kinds_roundtrip_and_resolve() {
        for k in BackendKind::all() {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
            assert_eq!(k.get().name(), k.name());
        }
        assert_eq!(BackendKind::parse("ref"), Some(BackendKind::Reference));
        assert_eq!(BackendKind::parse("func"), Some(BackendKind::Functional));
        assert_eq!(BackendKind::parse("comp"), Some(BackendKind::Compiled));
        assert_eq!(BackendKind::parse("turbo"), None);
        assert!(BackendKind::Event.get().is_cycle_accurate());
        assert!(BackendKind::Reference.get().is_cycle_accurate());
        assert!(!BackendKind::Functional.get().is_cycle_accurate());
        assert!(!BackendKind::Compiled.get().is_cycle_accurate());
    }

    #[test]
    fn run_error_classes_and_display() {
        let d = RunError::Deadlock { asleep: 7 };
        let t = RunError::Timeout { budget: 1000 };
        let f = RunError::Fault("atomic outside TCDM at 0x1c000000".into());
        assert_eq!(d.class(), "deadlock");
        assert_eq!(t.class(), "timeout");
        assert_eq!(f.class(), "fault");
        assert!(d.to_string().contains("7 core(s)"));
        assert!(t.to_string().contains("1000"));
        assert!(f.to_string().contains("atomic outside TCDM"));
        assert_eq!(Watchdog::with_budget(42), Watchdog { max_cycles: 42, max_instrs: 42 });
    }

    /// All four tiers agree architecturally on a staged micro program, and
    /// only the cycle-accurate tiers report stats.
    #[test]
    fn four_tiers_agree_on_a_micro_program() {
        use crate::cluster::mem::TCDM_BASE;
        let mut b = ProgramBuilder::new("tiers");
        b.li(1, TCDM_BASE);
        b.slli(2, regs::CORE_ID, 2);
        b.add(1, 1, 2);
        b.lw(3, 1, 0); // staged per-core word
        b.addi(3, 3, 1);
        b.sw(3, 1, 32); // publish to a second window
        b.barrier();
        b.end();
        let program = b.build();
        let cfg = ClusterConfig::new(8, 4, 1);
        let staged: Vec<u32> = (0..8u32).map(|i| 100 + i).collect();
        let run = |k: BackendKind| {
            k.get()
                .run_program(&cfg, &program, cfg.cores, &mut |mem| {
                    mem.write_u32_slice(TCDM_BASE, &staged);
                })
                .expect("micro program terminates")
        };
        let ev = run(BackendKind::Event);
        let rf = run(BackendKind::Reference);
        let fu = run(BackendKind::Functional);
        let co = run(BackendKind::Compiled);
        assert!(ev.stats.is_some() && rf.stats.is_some());
        assert!(fu.stats.is_none() && co.stats.is_none());
        assert_eq!(ev.regs, rf.regs);
        assert_eq!(ev.regs, fu.regs);
        assert_eq!(ev.regs, co.regs);
        assert_eq!(ev.mem.tcdm_words(), rf.mem.tcdm_words());
        assert_eq!(ev.mem.tcdm_words(), fu.mem.tcdm_words());
        assert_eq!(ev.mem.tcdm_words(), co.mem.tcdm_words());
        assert_eq!(ev.instrs, fu.instrs);
        assert_eq!(ev.instrs, co.instrs);
        for i in 0..8u32 {
            assert_eq!(
                fu.mem.load(TCDM_BASE + 32 + 4 * i, crate::isa::MemSize::Word),
                101 + i
            );
        }
    }
}
