//! Seeded SEU campaigns with structured outcome classification.
//!
//! A campaign samples `(cycle, site)` upset points per **target** (a
//! benchmark × precision-ladder rung on one [`ClusterConfig`]), injects
//! exactly one bit flip per run through [`Cluster::arm_fault`], and
//! classifies every injected run against two oracles:
//!
//! * the **fault-free baseline** of the same target (bit compare — detects
//!   *any* architectural divergence), and
//! * the binary64 [`Workload::reference`] (quantitative error — decides
//!   whether a divergence still lands inside the application's accuracy
//!   budget, the transprecision notion of "good enough").
//!
//! The taxonomy is the classic five-way split (see EXPERIMENTS.md §Faults):
//! [`Outcome::Masked`], [`Outcome::Tolerable`], [`Outcome::Sdc`],
//! [`Outcome::Crash`], [`Outcome::Hang`]. Per-target **vulnerability** is
//! the fraction of non-benign points, `(sdc + crash + hang) / points`.
//!
//! Determinism: all points are sampled serially up front from one
//! [`Rng`] stream keyed by the campaign seed, then executed by the
//! coordinator's quarantining worker pool — so the outcome CSV is
//! bit-identical across runs and across `--jobs` worker counts.

use std::fmt;

use super::recovery::{retry_with_backoff, RecoveryPolicy};
use crate::cluster::{ArmedFault, Cluster, Engine, FaultSite, RunError};
use crate::config::ClusterConfig;
use crate::coordinator::run_parallel_reported;
use crate::kernels::{Benchmark, Variant, Workload};
use crate::report::Table;
use crate::testutil::Rng;
use crate::tuner::error_stats;

/// Which physical structure class a campaign may upset (CLI `--sites`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteClass {
    /// TCDM data words ([`FaultSite::TcdmWord`]).
    Tcdm,
    /// Register-file cells ([`FaultSite::RegCell`]).
    Reg,
    /// In-flight DMA payload words ([`FaultSite::DmaPayload`]).
    Dma,
}

impl SiteClass {
    /// Every class, in CSV/report order.
    pub fn all() -> [SiteClass; 3] {
        [SiteClass::Tcdm, SiteClass::Reg, SiteClass::Dma]
    }

    /// Stable lower-case name (CLI values, CSV cells).
    pub fn name(self) -> &'static str {
        match self {
            SiteClass::Tcdm => "tcdm",
            SiteClass::Reg => "reg",
            SiteClass::Dma => "dma",
        }
    }

    /// Parse one CLI `--sites` element.
    pub fn parse(s: &str) -> Option<SiteClass> {
        match s {
            "tcdm" => Some(SiteClass::Tcdm),
            "reg" => Some(SiteClass::Reg),
            "dma" => Some(SiteClass::Dma),
            _ => None,
        }
    }

    /// Parse a comma-separated `--sites` list (e.g. `"tcdm,dma"`); `"all"`
    /// selects every class. Returns `None` on any unknown element or an
    /// empty list.
    pub fn parse_list(s: &str) -> Option<Vec<SiteClass>> {
        if s == "all" {
            return Some(SiteClass::all().to_vec());
        }
        let classes: Option<Vec<SiteClass>> =
            s.split(',').map(|e| SiteClass::parse(e.trim())).collect();
        classes.filter(|c| !c.is_empty())
    }
}

/// Full description of a campaign (what `transpfp inject` builds from its
/// flags).
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Configuration under attack.
    pub cfg: ClusterConfig,
    /// Seed of the single sampling stream (CLI `--seed`).
    pub seed: u64,
    /// Injected points per benchmark × variant target (CLI `--rate`).
    pub points_per_target: usize,
    /// Structure classes to sample sites from (CLI `--sites`).
    pub sites: Vec<SiteClass>,
    /// Relative-L2 accuracy budget separating [`Outcome::Tolerable`] from
    /// [`Outcome::Sdc`] (CLI `--budget`).
    pub budget: f64,
    /// Benchmarks to attack.
    pub benches: Vec<Benchmark>,
    /// Precision-ladder rungs to attack.
    pub variants: Vec<Variant>,
    /// Detect-and-retry policy for the detectable classes; `None` reports
    /// raw outcomes without re-execution.
    pub recovery: Option<RecoveryPolicy>,
}

impl CampaignSpec {
    /// Default campaign over the full suite at both table variants.
    pub fn new(cfg: ClusterConfig) -> CampaignSpec {
        CampaignSpec {
            cfg,
            seed: 1,
            points_per_target: 8,
            sites: SiteClass::all().to_vec(),
            budget: 1e-2,
            benches: Benchmark::all().to_vec(),
            variants: vec![Variant::Scalar, Variant::VEC],
            recovery: Some(RecoveryPolicy::default()),
        }
    }
}

/// Outcome class of one injected run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Outputs bit-identical to the fault-free baseline: the upset was
    /// architecturally absorbed (overwritten, dead value, or x0).
    Masked,
    /// Outputs diverged, but the error against the binary64 reference is
    /// within the campaign's accuracy budget — benign for this application.
    Tolerable,
    /// Silent data corruption: the run completed but its error exceeds the
    /// budget, with no architectural signal that anything went wrong.
    Sdc,
    /// The run ended in a detectable architectural violation
    /// ([`RunError::Fault`]) or a worker panic.
    Crash,
    /// The watchdog or deadlock detector stopped a run that would never
    /// terminate ([`RunError::Timeout`] / [`RunError::Deadlock`]).
    Hang,
}

impl Outcome {
    /// Every class, in CSV/report column order.
    pub fn all() -> [Outcome; 5] {
        [Outcome::Masked, Outcome::Tolerable, Outcome::Sdc, Outcome::Crash, Outcome::Hang]
    }

    /// Stable lower-case name (CSV cells, summary headers).
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Masked => "masked",
            Outcome::Tolerable => "tolerable",
            Outcome::Sdc => "sdc",
            Outcome::Crash => "crash",
            Outcome::Hang => "hang",
        }
    }

    /// Classes an online system can detect (and hence retry): the run
    /// itself reported an error. SDC is by definition *not* detectable.
    pub fn is_detectable(self) -> bool {
        matches!(self, Outcome::Crash | Outcome::Hang)
    }

    /// Classes counted into the vulnerability numerator.
    pub fn is_vulnerable(self) -> bool {
        matches!(self, Outcome::Sdc | Outcome::Crash | Outcome::Hang)
    }
}

impl fmt::Display for Outcome {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One classified injection point.
#[derive(Debug, Clone)]
pub struct PointReport {
    /// Campaign-wide point index (sampling order — stable across `--jobs`).
    pub index: usize,
    /// Target benchmark.
    pub bench: Benchmark,
    /// Target precision rung.
    pub variant: Variant,
    /// The injected upset.
    pub fault: ArmedFault,
    /// Classified outcome.
    pub outcome: Outcome,
    /// Did the detect-and-retry loop produce a clean re-run? Always `false`
    /// for undetectable outcomes and when recovery is disabled.
    pub recovered: bool,
    /// Retry attempts consumed (0 when recovery never ran).
    pub attempts: u32,
    /// Human-readable context: the structured error for crash/hang, the
    /// relative error for tolerable/SDC, empty for masked.
    pub detail: String,
}

/// A finished campaign: every sampled point, classified — none lost.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// Configuration that was attacked.
    pub cfg: ClusterConfig,
    /// Sampling seed.
    pub seed: u64,
    /// Accuracy budget used for the tolerable/SDC split.
    pub budget: f64,
    /// All points in sampling order.
    pub points: Vec<PointReport>,
}

impl CampaignReport {
    /// Per-class totals, in [`Outcome::all`] order.
    pub fn counts(&self) -> [usize; 5] {
        let mut n = [0usize; 5];
        for p in &self.points {
            let i = Outcome::all().iter().position(|&o| o == p.outcome).unwrap();
            n[i] += 1;
        }
        n
    }

    /// Whole-campaign vulnerability: non-benign points / all points.
    pub fn vulnerability(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        let bad = self.points.iter().filter(|p| p.outcome.is_vulnerable()).count();
        bad as f64 / self.points.len() as f64
    }

    /// Deterministic per-point CSV (header + one row per point in sampling
    /// order). Free-text details are sanitized so the row stays one line of
    /// plain comma-separated cells.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("index,bench,variant,cycle,site,outcome,recovered,attempts,detail\n");
        for p in &self.points {
            let site = match p.fault.site {
                FaultSite::TcdmWord { word, bit } => format!("tcdm:{word}:{bit}"),
                FaultSite::RegCell { core, reg, bit } => format!("reg:{core}:{reg}:{bit}"),
                FaultSite::DmaPayload { word, bit } => format!("dma:{word}:{bit}"),
            };
            let detail: String = p
                .detail
                .chars()
                .map(|c| if c == ',' || c == '\n' || c == '\r' { ';' } else { c })
                .collect();
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{}\n",
                p.index,
                p.bench.name(),
                p.variant.label(),
                p.fault.cycle,
                site,
                p.outcome,
                p.recovered,
                p.attempts,
                detail
            ));
        }
        s
    }

    /// Per-target vulnerability summary (kernel × rung), in first-appearance
    /// order of the campaign's points.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(vec![
            "kernel",
            "variant",
            "points",
            "masked",
            "tolerable",
            "sdc",
            "crash",
            "hang",
            "recovered",
            "vulnerability",
        ]);
        let mut targets: Vec<(Benchmark, Variant)> = Vec::new();
        for p in &self.points {
            if !targets.contains(&(p.bench, p.variant)) {
                targets.push((p.bench, p.variant));
            }
        }
        for (bench, variant) in targets {
            let pts: Vec<&PointReport> = self
                .points
                .iter()
                .filter(|p| p.bench == bench && p.variant == variant)
                .collect();
            let count = |o: Outcome| pts.iter().filter(|p| p.outcome == o).count();
            let recovered = pts.iter().filter(|p| p.recovered).count();
            let bad = pts.iter().filter(|p| p.outcome.is_vulnerable()).count();
            t.row(vec![
                bench.name().to_string(),
                variant.label().to_string(),
                pts.len().to_string(),
                count(Outcome::Masked).to_string(),
                count(Outcome::Tolerable).to_string(),
                count(Outcome::Sdc).to_string(),
                count(Outcome::Crash).to_string(),
                count(Outcome::Hang).to_string(),
                recovered.to_string(),
                format!("{:.3}", bad as f64 / pts.len().max(1) as f64),
            ]);
        }
        t
    }
}

/// One attacked benchmark × rung with its oracles.
struct Target {
    bench: Benchmark,
    variant: Variant,
    w: Workload,
    /// Fault-free output bit patterns (the Masked oracle).
    baseline_bits: Vec<u64>,
    /// Fault-free run length in cycles (sampling window for upset cycles).
    baseline_cycles: u64,
    /// Per-run cycle budget for injected runs: generous multiple of the
    /// fault-free length, so genuine hangs trip fast instead of burning the
    /// global 2×10⁹ default.
    watchdog: u64,
}

/// Execute one run of `w` on a fresh cluster, optionally with an armed
/// upset. Mirrors the backend seam's build→stage→run sequence, inlined
/// because the fault must be armed after staging (the backends own their
/// cluster and expose no injection hook — campaigns are the only caller
/// that needs one).
fn run_target(
    cfg: &ClusterConfig,
    w: &Workload,
    fault: Option<ArmedFault>,
    max_cycles: u64,
) -> Result<(u64, Vec<f64>), RunError> {
    let mut cl = Cluster::new(*cfg, w.program.clone());
    cl.max_cycles = max_cycles;
    cl.limit_active_cores(cfg.cores);
    w.stage_into(&mut cl.mem);
    if let Some(f) = fault {
        cl.arm_fault(f);
    }
    let stats = cl.run_with(Engine::Event)?;
    let out = w.read_output(&cl.mem);
    Ok((stats.total_cycles, out))
}

/// Classify one injected run against the fault-free baseline and the
/// binary64 reference. Pure on its inputs, so the taxonomy is unit-testable
/// without a simulator.
fn classify(
    result: Result<Vec<f64>, RunError>,
    baseline_bits: &[u64],
    reference: &[f64],
    budget: f64,
) -> (Outcome, String) {
    match result {
        Err(e @ (RunError::Timeout { .. } | RunError::Deadlock { .. })) => {
            (Outcome::Hang, e.to_string())
        }
        Err(e) => (Outcome::Crash, e.to_string()),
        Ok(out) => {
            let bits: Vec<u64> = out.iter().map(|x| x.to_bits()).collect();
            if bits == baseline_bits {
                return (Outcome::Masked, String::new());
            }
            let err = error_stats(&out, reference);
            let detail = format!("rel={:.3e}", err.rel);
            if err.within(budget) {
                (Outcome::Tolerable, detail)
            } else {
                (Outcome::Sdc, detail)
            }
        }
    }
}

/// Run a full campaign. Fails only if a *fault-free* baseline run fails
/// (the configuration itself is broken); injected runs never abort the
/// campaign — every sampled point comes back classified.
pub fn run_campaign(spec: &CampaignSpec) -> Result<CampaignReport, RunError> {
    // Phase 1 — fault-free baselines, serial (one per target).
    let mut targets = Vec::new();
    for &bench in &spec.benches {
        for &variant in &spec.variants {
            let w = bench.build(variant, &spec.cfg);
            let (baseline_cycles, out) = run_target(&spec.cfg, &w, None, 2_000_000_000)?;
            targets.push(Target {
                bench,
                variant,
                baseline_bits: out.iter().map(|x| x.to_bits()).collect(),
                baseline_cycles,
                watchdog: baseline_cycles.saturating_mul(4).saturating_add(10_000),
                w,
            });
        }
    }

    // Phase 2 — sample every point serially from one seeded stream, so the
    // point list (and through it the CSV) is independent of worker count.
    let sites = if spec.sites.is_empty() { SiteClass::all().to_vec() } else { spec.sites.clone() };
    let mut rng = Rng::new(spec.seed);
    let mut jobs: Vec<(usize, ArmedFault)> = Vec::new();
    for (ti, t) in targets.iter().enumerate() {
        for _ in 0..spec.points_per_target {
            let cycle = rng.below(t.baseline_cycles.max(1));
            let class = sites[rng.below(sites.len() as u64) as usize];
            let site = match class {
                SiteClass::Tcdm => {
                    FaultSite::TcdmWord { word: rng.next_u32(), bit: rng.next_u32() }
                }
                SiteClass::Reg => FaultSite::RegCell {
                    core: rng.next_u32(),
                    reg: rng.next_u32(),
                    bit: rng.next_u32(),
                },
                SiteClass::Dma => {
                    FaultSite::DmaPayload { word: rng.next_u32(), bit: rng.next_u32() }
                }
            };
            jobs.push((ti, ArmedFault { cycle, site }));
        }
    }

    // Phase 3 — inject in parallel under the quarantining pool: a panicking
    // point is reported as a crash, never lost, and never kills the sweep.
    let (results, quarantined) = run_parallel_reported(&jobs, |&(ti, fault)| {
        let t = &targets[ti];
        let res = run_target(&spec.cfg, &t.w, Some(fault), t.watchdog).map(|(_, out)| out);
        let (outcome, detail) = classify(res, &t.baseline_bits, &t.w.reference, spec.budget);
        let (recovered, attempts) = match (&spec.recovery, outcome.is_detectable()) {
            (Some(policy), true) => {
                let rec = retry_with_backoff(policy, t.watchdog, |_, cycle_budget| {
                    match run_target(&spec.cfg, &t.w, None, cycle_budget) {
                        Ok((_, out)) => {
                            let bits: Vec<u64> = out.iter().map(|x| x.to_bits()).collect();
                            if bits == t.baseline_bits {
                                Ok(())
                            } else {
                                Err("retry diverged from the fault-free baseline".into())
                            }
                        }
                        Err(e) => Err(e.to_string()),
                    }
                });
                (rec.recovered(), rec.attempts())
            }
            _ => (false, 0),
        };
        (outcome, recovered, attempts, detail)
    });

    let mut points = Vec::with_capacity(jobs.len());
    for (i, (&(ti, fault), slot)) in jobs.iter().zip(results).enumerate() {
        let t = &targets[ti];
        let (outcome, recovered, attempts, detail) = match slot {
            Some(r) => r,
            // The worker itself panicked mid-injection: quarantined by the
            // pool, classified as a crash so the point is never lost.
            None => {
                let q = quarantined.iter().find(|q| q.index == i);
                let payload =
                    q.map(|q| q.payload.clone()).unwrap_or_else(|| "unknown panic".into());
                (Outcome::Crash, false, 0, format!("worker panicked: {payload}"))
            }
        };
        points.push(PointReport {
            index: i,
            bench: t.bench,
            variant: t.variant,
            fault,
            outcome,
            recovered,
            attempts,
            detail,
        });
    }
    Ok(CampaignReport { cfg: spec.cfg, seed: spec.seed, budget: spec.budget, points })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_classes_roundtrip_and_parse_lists() {
        for c in SiteClass::all() {
            assert_eq!(SiteClass::parse(c.name()), Some(c));
        }
        assert_eq!(SiteClass::parse("l2"), None);
        assert_eq!(SiteClass::parse_list("all"), Some(SiteClass::all().to_vec()));
        assert_eq!(
            SiteClass::parse_list("tcdm, dma"),
            Some(vec![SiteClass::Tcdm, SiteClass::Dma])
        );
        assert_eq!(SiteClass::parse_list("tcdm,bogus"), None);
        assert_eq!(SiteClass::parse_list(""), None);
    }

    #[test]
    fn classification_follows_the_taxonomy() {
        let baseline = [1.0f64.to_bits(), 2.0f64.to_bits()];
        let reference = [1.0, 2.0];
        // Bit-identical → masked, no detail.
        let (o, d) = classify(Ok(vec![1.0, 2.0]), &baseline, &reference, 1e-2);
        assert_eq!(o, Outcome::Masked);
        assert!(d.is_empty());
        // Divergent but within budget → tolerable.
        let (o, d) = classify(Ok(vec![1.0, 2.000001]), &baseline, &reference, 1e-2);
        assert_eq!(o, Outcome::Tolerable);
        assert!(d.starts_with("rel="));
        // Beyond budget → SDC.
        let (o, _) = classify(Ok(vec![1.0, 40.0]), &baseline, &reference, 1e-2);
        assert_eq!(o, Outcome::Sdc);
        // NaN output can never be within a finite budget → SDC.
        let (o, _) = classify(Ok(vec![1.0, f64::NAN]), &baseline, &reference, 1e-2);
        assert_eq!(o, Outcome::Sdc);
        // Structured errors → hang / hang / crash.
        let (o, d) = classify(Err(RunError::Timeout { budget: 9 }), &baseline, &reference, 1e-2);
        assert_eq!(o, Outcome::Hang);
        assert!(d.contains("timeout"));
        let (o, _) = classify(Err(RunError::Deadlock { asleep: 3 }), &baseline, &reference, 1e-2);
        assert_eq!(o, Outcome::Hang);
        let (o, d) = classify(Err(RunError::Fault("amo".into())), &baseline, &reference, 1e-2);
        assert_eq!(o, Outcome::Crash);
        assert!(d.contains("amo"));
        assert!(Outcome::Crash.is_detectable() && Outcome::Hang.is_detectable());
        assert!(!Outcome::Sdc.is_detectable());
        assert!(Outcome::Sdc.is_vulnerable() && !Outcome::Tolerable.is_vulnerable());
    }

    fn point(i: usize, outcome: Outcome, recovered: bool) -> PointReport {
        PointReport {
            index: i,
            bench: Benchmark::Fir,
            variant: Variant::Scalar,
            fault: ArmedFault { cycle: 10 * i as u64, site: FaultSite::TcdmWord { word: 3, bit: 7 } },
            outcome,
            recovered,
            attempts: recovered as u32,
            detail: String::new(),
        }
    }

    #[test]
    fn report_counts_vulnerability_and_csv_shape() {
        let report = CampaignReport {
            cfg: ClusterConfig::new(8, 4, 1),
            seed: 7,
            budget: 1e-2,
            points: vec![
                point(0, Outcome::Masked, false),
                point(1, Outcome::Tolerable, false),
                point(2, Outcome::Sdc, false),
                point(3, Outcome::Crash, true),
                point(4, Outcome::Hang, true),
                point(5, Outcome::Masked, false),
            ],
        };
        assert_eq!(report.counts(), [2, 1, 1, 1, 1]);
        assert!((report.vulnerability() - 0.5).abs() < 1e-12);
        let csv = report.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 7, "header + 6 points");
        assert_eq!(lines[0], "index,bench,variant,cycle,site,outcome,recovered,attempts,detail");
        assert!(lines[1].starts_with("0,FIR,scalar,0,tcdm:3:7,masked,false,0,"));
        assert!(lines[4].contains(",crash,true,1,"));
        let table = report.summary_table().render();
        assert!(table.contains("FIR"));
        assert!(table.contains("0.500"));
    }

    #[test]
    fn csv_details_never_break_the_row_structure() {
        let mut p = point(0, Outcome::Crash, false);
        p.detail = "fault: a, b\nand c".into();
        let report = CampaignReport {
            cfg: ClusterConfig::new(8, 4, 1),
            seed: 1,
            budget: 1e-2,
            points: vec![p],
        };
        let csv = report.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].matches(',').count(), 8, "exactly 9 cells per row");
        assert!(lines[1].ends_with("fault: a; b;and c"));
    }

    /// A tiny end-to-end campaign: every sampled point is classified, the
    /// CSV is bit-deterministic for a fixed seed, and a different seed
    /// samples different points.
    #[test]
    fn small_campaign_classifies_every_point_deterministically() {
        let mut spec = CampaignSpec::new(ClusterConfig::new(8, 4, 1));
        spec.seed = 42;
        spec.points_per_target = 3;
        spec.benches = vec![Benchmark::Fir];
        spec.variants = vec![Variant::Scalar];
        let a = run_campaign(&spec).expect("fault-free baseline runs");
        assert_eq!(a.points.len(), 3, "no sampled point may be lost");
        for p in &a.points {
            assert!(Outcome::all().contains(&p.outcome));
        }
        let b = run_campaign(&spec).expect("fault-free baseline runs");
        assert_eq!(a.to_csv(), b.to_csv(), "same seed must be bit-identical");
        spec.seed = 43;
        let c = run_campaign(&spec).expect("fault-free baseline runs");
        assert_ne!(a.to_csv(), c.to_csv(), "different seed must sample differently");
    }
}
