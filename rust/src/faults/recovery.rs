//! Bounded detect-and-retry recovery for the *detectable* fault classes.
//!
//! Crashes and hangs are detectable at run granularity: the watchdog or the
//! architectural fault check reports them as a structured
//! [`crate::cluster::RunError`] instead of silently corrupted data. A
//! runtime can therefore re-execute the run — the SEU model is transient,
//! so a clean retry normally succeeds — while widening the watchdog budget
//! each attempt in case the first detection was a too-tight budget rather
//! than a genuine hang. Points that stay broken after the retry budget are
//! **quarantined**: reported as persistent with the last observed error,
//! the way a runtime would fence a failing tile instead of retrying it
//! forever.
//!
//! The loop itself is policy-generic (it only sees a closure), so it is
//! unit-tested here with synthetic failures and reused by
//! [`super::campaign`] with real cluster re-runs.

/// Retry policy: how many times to re-execute a detected-faulty run and
/// how aggressively to widen the watchdog budget between attempts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Maximum re-executions before the point is quarantined.
    pub max_retries: u32,
    /// Watchdog-budget multiplier applied before *each* attempt (attempt
    /// `k` runs under `base_budget * factor^k`, saturating). Values below
    /// one are treated as one (no backoff).
    pub backoff_factor: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy { max_retries: 3, backoff_factor: 2 }
    }
}

/// Terminal state of a recovery loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Recovery {
    /// An attempt completed cleanly; `attempts` runs were consumed (≥ 1).
    Recovered { attempts: u32 },
    /// Every retry failed — the point is persistent and must be fenced.
    Quarantined { attempts: u32, last_error: String },
}

impl Recovery {
    /// Attempts consumed, whichever way the loop ended.
    pub fn attempts(&self) -> u32 {
        match self {
            Recovery::Recovered { attempts } | Recovery::Quarantined { attempts, .. } => *attempts,
        }
    }

    /// Did the loop end with a clean run?
    pub fn recovered(&self) -> bool {
        matches!(self, Recovery::Recovered { .. })
    }
}

/// Drive `attempt(k, budget)` for `k = 1..=max_retries` with an
/// exponentially widened budget, stopping at the first success. The
/// closure owns the actual re-execution; this loop owns the bound and the
/// backoff so both are testable without a simulator.
pub fn retry_with_backoff<F>(policy: &RecoveryPolicy, base_budget: u64, mut attempt: F) -> Recovery
where
    F: FnMut(u32, u64) -> Result<(), String>,
{
    let factor = policy.backoff_factor.max(1);
    let mut budget = base_budget;
    let mut last_error = String::from("no retries attempted");
    for k in 1..=policy.max_retries {
        budget = budget.saturating_mul(factor);
        match attempt(k, budget) {
            Ok(()) => return Recovery::Recovered { attempts: k },
            Err(e) => last_error = e,
        }
    }
    Recovery::Quarantined { attempts: policy.max_retries, last_error }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_retry_recovers_transient_failures() {
        let rec = retry_with_backoff(&RecoveryPolicy::default(), 100, |_, _| Ok(()));
        assert_eq!(rec, Recovery::Recovered { attempts: 1 });
        assert!(rec.recovered());
        assert_eq!(rec.attempts(), 1);
    }

    #[test]
    fn backoff_doubles_budget_every_attempt() {
        let mut budgets = Vec::new();
        let rec = retry_with_backoff(&RecoveryPolicy::default(), 100, |k, budget| {
            budgets.push(budget);
            if k < 3 {
                Err(format!("still broken at attempt {k}"))
            } else {
                Ok(())
            }
        });
        assert_eq!(budgets, vec![200, 400, 800]);
        assert_eq!(rec, Recovery::Recovered { attempts: 3 });
    }

    #[test]
    fn persistent_failures_are_quarantined_with_the_last_error() {
        let policy = RecoveryPolicy { max_retries: 4, backoff_factor: 3 };
        let rec = retry_with_backoff(&policy, 10, |k, _| Err(format!("attempt {k} failed")));
        assert_eq!(
            rec,
            Recovery::Quarantined { attempts: 4, last_error: "attempt 4 failed".into() }
        );
        assert!(!rec.recovered());
        assert_eq!(rec.attempts(), 4);
    }

    #[test]
    fn zero_retries_quarantines_without_running_the_closure() {
        let policy = RecoveryPolicy { max_retries: 0, backoff_factor: 2 };
        let rec = retry_with_backoff(&policy, 10, |_, _| {
            panic!("attempt closure must not run with max_retries = 0")
        });
        assert_eq!(
            rec,
            Recovery::Quarantined { attempts: 0, last_error: "no retries attempted".into() }
        );
    }

    #[test]
    fn budget_saturates_instead_of_overflowing() {
        let mut seen = 0u64;
        let rec = retry_with_backoff(
            &RecoveryPolicy { max_retries: 2, backoff_factor: u64::MAX },
            u64::MAX / 2,
            |_, budget| {
                seen = budget;
                Err("broken".into())
            },
        );
        assert_eq!(seen, u64::MAX);
        assert!(!rec.recovered());
    }
}
