//! Fault injection: seeded single-event-upset (SEU) campaigns against the
//! cycle-accurate cluster, with structured outcome classification and an
//! optional detect-and-retry recovery loop.
//!
//! Near-sensor clusters run at near-threshold voltages where single-event
//! upsets are a first-order concern; a simulator that can only *panic* on
//! a corrupted run cannot measure vulnerability. This module drives the
//! [`crate::cluster`] fault hooks ([`Cluster::arm_fault`]) end to end:
//!
//! * [`campaign`] — seeded campaigns sampling `(cycle, site)` upset points
//!   into TCDM words, register-file cells, and in-flight DMA payloads
//!   ([`FaultSite`]), classifying every injected run against the fault-free
//!   baseline and the binary64 [`crate::kernels::Workload::reference`]
//!   into the standard taxonomy (masked / tolerable / SDC / crash / hang).
//!   Campaigns are bit-deterministic: the same seed and parameters produce
//!   the same outcome CSV regardless of the `--jobs` worker count.
//! * [`recovery`] — a bounded detect-and-retry policy (exponential
//!   watchdog-budget backoff) for the *detectable* outcome classes; points
//!   that stay broken after the retry budget are quarantined, mirroring
//!   how a runtime would fence a persistently-failing tile.
//!
//! The CLI front-end is `transpfp inject` (see EXPERIMENTS.md §Faults).
//!
//! [`Cluster::arm_fault`]: crate::cluster::Cluster::arm_fault

pub mod campaign;
pub mod recovery;

pub use crate::cluster::{ArmedFault, FaultSite};
pub use campaign::{
    run_campaign, CampaignReport, CampaignSpec, Outcome, PointReport, SiteClass,
};
pub use recovery::{retry_with_backoff, Recovery, RecoveryPolicy};
